"""Paper Fig 3: total energy (J/token) vs batch size."""
from __future__ import annotations

from repro.core import SETUPS
from . import common


def run(arch: str = common.DEFAULT_ARCH,
        batches=common.DEFAULT_BATCHES):
    header = ["setup", "batch", "total_energy_kj", "joules_per_token"]
    rows = []
    for setup in SETUPS:
        for bs in batches:
            rec = common.run_point(setup, bs, arch)
            rows.append([setup, bs, round(rec.total_j / 1e3, 3),
                         round(rec.joules_per_token, 5)])
    common.print_table("Fig 3: energy vs batch size", header, rows)
    common.write_csv("fig3_energy.csv", header, rows)
    return rows


if __name__ == "__main__":
    run()
