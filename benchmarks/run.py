"""Benchmark driver: one harness per paper table/figure + claim validation
+ the roofline table (from dryrun_results.json when present).

All figures route through ``repro.exp``: the driver first warms the
shared Experiment-1 matrix as one ``Grid`` (fanning cache misses over
``--parallel`` processes), then each figure reads the warm
content-addressed cache. A second invocation performs zero simulations
and emits byte-identical artifacts; ``out/cache_stats.json`` records
the split (the warm-cache CI lane asserts on it).

  PYTHONPATH=src python -m benchmarks.run            # everything
  PYTHONPATH=src python -m benchmarks.run --quick    # reduced grid (CI)
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

from repro.exp import default_cache, sim_count, uncached_sim_count

from . import (common, fig1_latency, fig2_throughput, fig3_energy,
               fig4_breakdown, fig5_pareto, fig6_load_crossover,
               fig7_fleet_ratio, fig8_governor_pareto,
               fig10_reuse_crossover, fig11_scheduler_frontier,
               reuse_bench, roofline, validate_claims)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller batch grid (CI mode)")
    ap.add_argument("--arch", default=common.DEFAULT_ARCH)
    ap.add_argument("--skip-pareto", action="store_true")
    ap.add_argument("--skip-roofline", action="store_true",
                    help="skip the roofline table (it re-reads dryrun "
                         "artifacts or compiles a demo cell — work the "
                         "result cache cannot amortize; the warm-cache "
                         "CI lane skips it to time the matrix alone)")
    ap.add_argument("--parallel", type=int, default=1,
                    help="process-pool width for cache misses in the "
                         "shared sweeps")
    args = ap.parse_args(argv)

    batches = common.QUICK_BATCHES if args.quick else common.DEFAULT_BATCHES

    t0 = time.time()
    print(f"== benchmarks.run arch={args.arch} batches={batches}")
    # warm the shared Experiment-1 matrix once; figures then hit cache
    common.full_sweep(args.arch, batches, parallel=args.parallel)
    fig1_latency.run(args.arch, batches)
    fig2_throughput.run(args.arch, batches)
    fig3_energy.run(args.arch, batches)
    fig4_breakdown.run(args.arch)
    if not args.skip_pareto:
        fig5_pareto.run(args.arch, smoke=args.quick,
                        parallel=args.parallel)
    fig6_load_crossover.run(args.arch, smoke=args.quick)
    fig7_fleet_ratio.run(args.arch, smoke=args.quick,
                         n=16 if args.quick else common.OPEN_LOOP_N)
    fig8_governor_pareto.run(args.arch, smoke=args.quick)
    # figs 10/11 self-check their claims (assertions inside run());
    # --quick routes both onto their CI smoke grids
    fig10_reuse_crossover.run(args.arch, smoke=args.quick)
    fig11_scheduler_frontier.run(args.arch, smoke=args.quick)
    reuse_bench.run(arch=args.arch)
    failures = validate_claims.run(batches)
    if not args.skip_roofline:
        try:
            roofline.main([])
        except Exception as e:  # roofline needs dryrun artifacts/subprocess
            print(f"== roofline skipped: {type(e).__name__}: {e}")

    elapsed = time.time() - t0
    stats = {
        "arch": args.arch, "quick": bool(args.quick),
        "elapsed_s": round(elapsed, 3),
        "simulations": sim_count(),
        # simulations that bypassed the cache via a legacy fallback
        # (off-registry config / non-spec workload); the warm-cache CI
        # lane asserts this stays zero too — a benchmark path silently
        # regressing into the uncached branch is a bug
        "uncached_simulations": uncached_sim_count(),
        "cache": default_cache().stats.as_dict(),
        "cache_dir": default_cache().dir,
        "cached_records": len(default_cache()),
    }
    os.makedirs(common.OUT_DIR, exist_ok=True)
    with open(os.path.join(common.OUT_DIR, "cache_stats.json"), "w") as f:
        json.dump(stats, f, indent=2)
    print(f"\n== benchmarks.run done in {elapsed:.0f}s, "
          f"{failures} claim failures, {stats['simulations']} simulations "
          f"({stats['cache']['hits']} cache hits)")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
