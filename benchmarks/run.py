"""Benchmark driver: one harness per paper table/figure + claim validation
+ the roofline table (from dryrun_results.json when present).

  PYTHONPATH=src python -m benchmarks.run            # everything
  PYTHONPATH=src python -m benchmarks.run --quick    # reduced batch grid
"""
from __future__ import annotations

import argparse
import sys
import time

from . import (common, fig1_latency, fig2_throughput, fig3_energy,
               fig4_breakdown, fig5_pareto, fig6_load_crossover,
               fig8_governor_pareto, reuse_bench, roofline,
               validate_claims)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller batch grid (CI mode)")
    ap.add_argument("--arch", default=common.ARCH)
    ap.add_argument("--skip-pareto", action="store_true")
    args = ap.parse_args(argv)

    if args.quick:
        common.BATCHES = (2, 8, 16, 32)

    t0 = time.time()
    print(f"== benchmarks.run arch={args.arch} batches={common.BATCHES}")
    fig1_latency.run(args.arch)
    fig2_throughput.run(args.arch)
    fig3_energy.run(args.arch)
    fig4_breakdown.run(args.arch)
    if not args.skip_pareto:
        fig5_pareto.run(args.arch, smoke=args.quick)
    fig6_load_crossover.run(args.arch, smoke=args.quick)
    fig8_governor_pareto.run(args.arch, smoke=args.quick)
    reuse_bench.run()
    failures = validate_claims.run()
    try:
        roofline.main([])
    except Exception as e:     # roofline needs dryrun artifacts/subprocess
        print(f"== roofline skipped: {type(e).__name__}: {e}")
    print(f"\n== benchmarks.run done in {time.time() - t0:.0f}s, "
          f"{failures} claim failures")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
