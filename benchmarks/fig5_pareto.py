"""Paper Fig 5: TTFT-energy and TPOT-energy Pareto frontiers over the DVFS
grid (batch 16, input 16,384, output 256), plus the stage-wise independent
(phi_p, phi_d) search for the disaggregated setups.

Transfer energy is attributed per leg (store -> prefill side, fetch ->
decode side) from the routed path's actual LegCosts — see
``repro.core.dvfs.sweep_frequencies``.

  python -m benchmarks.fig5_pareto              # full grid, CSV
  python -m benchmarks.fig5_pareto --smoke      # CI: tiny grid + JSON
  python -m benchmarks.fig5_pareto --out f.json # archivable JSON
"""
from __future__ import annotations

from repro.configs import get_config
from repro.core import SETUPS, random_workload
from repro.core.costs import DEFAULT_FREQ_GRID
from repro.core.dvfs import (best_independent, best_total_energy,
                             sweep_frequencies, sweep_independent)
from . import common

GRID = DEFAULT_FREQ_GRID[::2] + (1.0,)    # 6-point grid keeps runtime sane
SMOKE_GRID = (0.42, 0.74, 1.0)

HEADER = ["setup", "phi", "median_ttft_s", "prefill_energy_kj",
          "median_tpot_ms", "decode_energy_kj"]
HEADER2 = ["setup", "phi_prefill", "phi_decode", "ttft_s", "tpot_ms",
           "stage_energy_kj"]


def run(arch: str = common.ARCH, *, smoke: bool = False, out: str = None):
    cfg = get_config(arch)
    grid = SMOKE_GRID if smoke else GRID
    batch = 8 if smoke else 16

    def _wl():
        return random_workload(batch, input_len=common.INPUT_LEN,
                               output_len=common.OUTPUT_LEN)

    rows = []
    sweeps = {}
    for setup in SETUPS:
        sw = sweep_frequencies(setup, cfg, _wl, freq_grid=grid)
        sweeps[setup] = sw
        for pp, dp in zip(sw.prefill_points, sw.decode_points):
            rows.append([setup, pp.phi, round(pp.latency_s, 4),
                         round(pp.energy_j / 1e3, 3),
                         round(dp.latency_s * 1e3, 3),
                         round(dp.energy_j / 1e3, 3)])
    common.print_table("Fig 5: latency-energy Pareto points", HEADER, rows)
    common.write_csv("fig5_pareto.csv", HEADER, rows)

    # stage-wise independent frequency search (disaggregation's edge)
    rows2 = []
    for setup in SETUPS:
        if setup.startswith("co"):
            best = best_total_energy(sweeps[setup])
        else:
            recs = sweep_independent(setup, cfg, _wl,
                                     freq_grid=grid if smoke
                                     else grid[::2] + (1.0,))
            b = best_independent(recs)
            best = {"phi_prefill": b["phi_prefill"],
                    "phi_decode": b["phi_decode"],
                    "ttft_s": b["ttft_s"], "tpot_s": b["tpot_s"],
                    "energy_j": b["energy_j"]}
        rows2.append([setup, best["phi_prefill"], best["phi_decode"],
                      round(best["ttft_s"], 4),
                      round(best["tpot_s"] * 1e3, 3),
                      round(best["energy_j"] / 1e3, 3)])
    common.print_table("Fig 5b: best (independent) frequency choices",
                       HEADER2, rows2)
    common.write_csv("fig5_best_freq.csv", HEADER2, rows2)

    # machine-checkable JSON (same interface as fig6/fig7/fig8) --------
    def _points(pts):
        return [{"phi": p.phi, "latency_s": round(p.latency_s, 6),
                 "energy_j": round(p.energy_j, 2)} for p in pts]

    by_stage_best = {r[0]: {"phi_prefill": r[1], "phi_decode": r[2],
                            "stage_energy_kj": r[5]} for r in rows2}
    co_best = by_stage_best["co-2gpus"]["stage_energy_kj"]
    dis_best = {s: by_stage_best[s]["stage_energy_kj"]
                for s in SETUPS if s.startswith("dis")}
    payload = {
        "arch": arch, "batch": batch, "phi_grid": list(grid),
        "input_len": common.INPUT_LEN, "output_len": common.OUTPUT_LEN,
        "points": [dict(zip(HEADER, r)) for r in rows],
        "best_frequency": [dict(zip(HEADER2, r)) for r in rows2],
        "frontiers": {
            s: {"prefill": _points(sweeps[s].prefill_frontier()),
                "decode": _points(sweeps[s].decode_frontier())}
            for s in SETUPS},
        # paper takeaway 2, machine-checkable: independent (phi_p,
        # phi_d) scaling never undercuts the colocated best
        "no_dis_energy_win": {
            "co_2gpus_best_kj": co_best,
            "dis_best_kj": dis_best,
            "holds": all(v > co_best for v in dis_best.values()),
        },
    }
    common.write_json(payload, "fig5_pareto.json", out=out)
    return payload


def main(argv=None):
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=common.ARCH)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny grid for CI; emits the same JSON artifact")
    ap.add_argument("--out", default=None,
                    help="JSON output path (default benchmarks/out/)")
    args = ap.parse_args(argv)
    run(args.arch, smoke=args.smoke, out=args.out)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
