"""Paper Fig 5: TTFT-energy and TPOT-energy Pareto frontiers over the DVFS
grid (batch 16, input 16,384, output 256), plus the stage-wise independent
(phi_p, phi_d) search for the disaggregated setups.

The frequency axis is a ``repro.exp`` Grid over ``phi`` (and the
independent search a grid over ``phi_prefill x phi_decode``): every
point is one cached Experiment, so re-plots and CI reruns cost cache
reads. Transfer energy is attributed per leg (store -> prefill side,
fetch -> decode side) from the routed path's actual LegCosts.

  python -m benchmarks.fig5_pareto              # full grid, CSV
  python -m benchmarks.fig5_pareto --smoke      # CI: tiny grid + JSON
  python -m benchmarks.fig5_pareto --out f.json # archivable JSON
"""
from __future__ import annotations

from typing import List

from repro.core import SETUPS
from repro.core.costs import DEFAULT_FREQ_GRID
from repro.core.energy import ParetoPoint, pareto_frontier
from repro.exp import Grid, RunRecord, run_grid
from . import common

GRID = DEFAULT_FREQ_GRID[::2] + (1.0,)    # 6-point grid keeps runtime sane
SMOKE_GRID = (0.42, 0.74, 1.0)

HEADER = ["setup", "phi", "median_ttft_s", "prefill_energy_kj",
          "median_tpot_ms", "decode_energy_kj"]
HEADER2 = ["setup", "phi_prefill", "phi_decode", "ttft_s", "tpot_ms",
           "stage_energy_kj"]


def _stage_points(setup: str, grid, recs: List[RunRecord]):
    """(prefill, decode) ParetoPoint lists for one setup's phi sweep —
    the exact shape ``dvfs.sweep_frequencies`` produced."""
    prefill_pts = [ParetoPoint(phi=phi, latency_s=r.metrics.median_ttft_s,
                               energy_j=r.prefill_side_j, label=setup)
                   for phi, r in zip(grid, recs)]
    decode_pts = [ParetoPoint(phi=phi, latency_s=r.metrics.median_tpot_s,
                              energy_j=r.decode_side_j, label=setup)
                  for phi, r in zip(grid, recs)]
    return prefill_pts, decode_pts


def run(arch: str = common.DEFAULT_ARCH, *, smoke: bool = False,
        out: str = None, parallel: int = 1):
    grid = SMOKE_GRID if smoke else GRID
    batch = 8 if smoke else 16
    base = common.closed_exp(SETUPS[0], batch, arch)

    # same-phi sweep: phi applied to every accelerator, as the paper does
    recs = run_grid(Grid(base, {"setup": SETUPS, "phi": grid}),
                    parallel=parallel)
    rows, sweeps = [], {}
    for i, setup in enumerate(SETUPS):
        chunk = recs[i * len(grid):(i + 1) * len(grid)]
        pp_pts, dp_pts = _stage_points(setup, grid, chunk)
        sweeps[setup] = (pp_pts, dp_pts)
        for pp, dp in zip(pp_pts, dp_pts):
            rows.append([setup, pp.phi, round(pp.latency_s, 4),
                         round(pp.energy_j / 1e3, 3),
                         round(dp.latency_s * 1e3, 3),
                         round(dp.energy_j / 1e3, 3)])
    common.print_table("Fig 5: latency-energy Pareto points", HEADER, rows)
    common.write_csv("fig5_pareto.csv", HEADER, rows)

    # stage-wise independent frequency search (disaggregation's edge) —
    # a phi_prefill x phi_decode grid per disaggregated setup
    grid2 = grid if smoke else grid[::2] + (1.0,)
    rows2 = []
    for setup in SETUPS:
        if setup.startswith("co"):
            pp_pts, dp_pts = sweeps[setup]
            best = min(
                ({"phi_prefill": pp.phi, "phi_decode": dp.phi,
                  "ttft_s": pp.latency_s, "tpot_s": dp.latency_s,
                  "energy_j": pp.energy_j + dp.energy_j}
                 for pp, dp in zip(pp_pts, dp_pts)),
                key=lambda b: b["energy_j"])
        else:
            pair_recs = run_grid(
                Grid(base.with_fleet(setup),
                     {"phi_prefill": grid2, "phi_decode": grid2}),
                parallel=parallel)
            best = min(
                ({"phi_prefill": pp, "phi_decode": pd,
                  "ttft_s": r.metrics.median_ttft_s,
                  "tpot_s": r.metrics.median_tpot_s,
                  "energy_j": r.prefill_side_j + r.decode_side_j}
                 for (pp, pd), r in zip(
                     ((p, d) for p in grid2 for d in grid2), pair_recs)),
                key=lambda b: b["energy_j"])
        rows2.append([setup, best["phi_prefill"], best["phi_decode"],
                      round(best["ttft_s"], 4),
                      round(best["tpot_s"] * 1e3, 3),
                      round(best["energy_j"] / 1e3, 3)])
    common.print_table("Fig 5b: best (independent) frequency choices",
                       HEADER2, rows2)
    common.write_csv("fig5_best_freq.csv", HEADER2, rows2)

    # machine-checkable JSON (same interface as fig6/fig7/fig8) --------
    def _points(pts):
        return [{"phi": p.phi, "latency_s": round(p.latency_s, 6),
                 "energy_j": round(p.energy_j, 2)} for p in pts]

    by_stage_best = {r[0]: {"phi_prefill": r[1], "phi_decode": r[2],
                            "stage_energy_kj": r[5]} for r in rows2}
    co_best = by_stage_best["co-2gpus"]["stage_energy_kj"]
    dis_best = {s: by_stage_best[s]["stage_energy_kj"]
                for s in SETUPS if s.startswith("dis")}
    payload = {
        "arch": arch, "batch": batch, "phi_grid": list(grid),
        "input_len": common.INPUT_LEN, "output_len": common.OUTPUT_LEN,
        "points": [dict(zip(HEADER, r)) for r in rows],
        "best_frequency": [dict(zip(HEADER2, r)) for r in rows2],
        "frontiers": {
            s: {"prefill": _points(pareto_frontier(sweeps[s][0])),
                "decode": _points(pareto_frontier(sweeps[s][1]))}
            for s in SETUPS},
        # paper takeaway 2, machine-checkable: independent (phi_p,
        # phi_d) scaling never undercuts the colocated best
        "no_dis_energy_win": {
            "co_2gpus_best_kj": co_best,
            "dis_best_kj": dis_best,
            "holds": all(v > co_best for v in dis_best.values()),
        },
    }
    common.write_json(payload, "fig5_pareto.json", out=out)
    return payload


def main(argv=None):
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=common.DEFAULT_ARCH)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny grid for CI; emits the same JSON artifact")
    ap.add_argument("--out", default=None,
                    help="JSON output path (default benchmarks/out/)")
    ap.add_argument("--parallel", type=int, default=1,
                    help="process-pool width for cache misses")
    args = ap.parse_args(argv)
    run(args.arch, smoke=args.smoke, out=args.out, parallel=args.parallel)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
