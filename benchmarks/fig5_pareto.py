"""Paper Fig 5: TTFT-energy and TPOT-energy Pareto frontiers over the DVFS
grid (batch 16, input 16,384, output 256), plus the stage-wise independent
(phi_p, phi_d) search for the disaggregated setups."""
from __future__ import annotations

from repro.configs import get_config
from repro.core import SETUPS, random_workload
from repro.core.costs import DEFAULT_FREQ_GRID
from repro.core.dvfs import (best_independent, best_total_energy,
                             sweep_frequencies, sweep_independent)
from . import common

GRID = DEFAULT_FREQ_GRID[::2] + (1.0,)    # 6-point grid keeps runtime sane


def _wl():
    return random_workload(16, input_len=common.INPUT_LEN,
                           output_len=common.OUTPUT_LEN)


def run(arch: str = common.ARCH):
    cfg = get_config(arch)
    header = ["setup", "phi", "median_ttft_s", "prefill_energy_kj",
              "median_tpot_ms", "decode_energy_kj"]
    rows = []
    sweeps = {}
    for setup in SETUPS:
        sw = sweep_frequencies(setup, cfg, _wl, freq_grid=GRID)
        sweeps[setup] = sw
        for pp, dp in zip(sw.prefill_points, sw.decode_points):
            rows.append([setup, pp.phi, round(pp.latency_s, 4),
                         round(pp.energy_j / 1e3, 3),
                         round(dp.latency_s * 1e3, 3),
                         round(dp.energy_j / 1e3, 3)])
    common.print_table("Fig 5: latency-energy Pareto points", header, rows)
    common.write_csv("fig5_pareto.csv", header, rows)

    # stage-wise independent frequency search (disaggregation's edge)
    header2 = ["setup", "phi_prefill", "phi_decode", "ttft_s", "tpot_ms",
               "stage_energy_kj"]
    rows2 = []
    for setup in SETUPS:
        if setup.startswith("co"):
            best = best_total_energy(sweeps[setup])
        else:
            recs = sweep_independent(setup, cfg, _wl,
                                     freq_grid=GRID[::2] + (1.0,))
            b = best_independent(recs)
            best = {"phi_prefill": b["phi_prefill"],
                    "phi_decode": b["phi_decode"],
                    "ttft_s": b["ttft_s"], "tpot_s": b["tpot_s"],
                    "energy_j": b["energy_j"]}
        rows2.append([setup, best["phi_prefill"], best["phi_decode"],
                      round(best["ttft_s"], 4),
                      round(best["tpot_s"] * 1e3, 3),
                      round(best["energy_j"] / 1e3, 3)])
    common.print_table("Fig 5b: best (independent) frequency choices",
                       header2, rows2)
    common.write_csv("fig5_best_freq.csv", header2, rows2)
    return rows, rows2


if __name__ == "__main__":
    run()
