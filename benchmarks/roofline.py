"""Roofline analysis (deliverable g): per (arch x shape) on the single-pod
mesh — the three terms, dominant bottleneck, MODEL_FLOPS ratio, and a
what-would-move-it note.

The numbers come from the dry-run's compiled artifacts; running compiles
in-process is impossible here (512 forced devices), so this module either
reads a ``dryrun_results.json`` produced by ``repro.launch.dryrun`` or
shells out per cell.

  PYTHONPATH=src python -m benchmarks.roofline --from-json dryrun.json
  PYTHONPATH=src python -m benchmarks.roofline --cells qwen3-1.7b:train_4k
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
from typing import Dict, List, Optional

from repro.configs import ALL_SHAPES, ASSIGNED_ARCHS
from . import common

DEFAULT_JSON = os.path.join(os.path.dirname(__file__), "..",
                            "dryrun_results.json")

_ADVICE = {
    "compute": ("compute-bound: raise MXU efficiency — larger fused matmul"
                " tiles, fewer f32 upcasts, remat policy that skips"
                " recomputing matmuls (dot-checkpointing)"),
    "memory": ("memory-bound: keep attention logits / scan state in VMEM"
               " (Pallas kernels), fuse norms into neighbors, cut f32"
               " intermediates, avoid involuntary SPMD remat copies"),
    "collective": ("collective-bound: reshard to cut all-gathers (batch-"
                   "parallel decode state), overlap DP all-reduce with"
                   " backward, int8-compress gradients, bucket small"
                   " collectives"),
}


def run_cell_subprocess(arch: str, shape: str, multi_pod: bool = False
                        ) -> Dict:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    code = (
        "import json\n"
        "from repro.launch.dryrun import run_cell\n"
        f"rec = run_cell({arch!r}, {shape!r}, {multi_pod}, verbose=False)\n"
        "rec.pop('traceback', None)\n"
        "print('REC:' + json.dumps(rec))\n")
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=3600)
    if proc.returncode != 0:
        return {"arch": arch, "shape": shape, "status": "fail",
                "error": proc.stderr[-500:]}
    line = [l for l in proc.stdout.splitlines() if l.startswith("REC:")][0]
    return json.loads(line[4:])


def table_from_records(records: List[Dict]) -> List[List]:
    rows = []
    for rec in records:
        if rec.get("mesh") not in (None, "16x16"):
            continue
        if rec["status"] == "skip":
            rows.append([rec["arch"], rec["shape"], "skip", "-", "-", "-",
                         "-", "-", rec.get("reason", "")[:50]])
            continue
        if rec["status"] != "ok" or "roofline" not in rec:
            rows.append([rec["arch"], rec["shape"], rec["status"], "-",
                         "-", "-", "-", "-", rec.get("error", "")[:50]])
            continue
        r = rec["roofline"]
        rows.append([
            rec["arch"], rec["shape"], "ok",
            f"{r['compute_s']:.4f}", f"{r['memory_s']:.4f}",
            f"{r['collective_s']:.4f}", r["dominant"],
            f"{r['useful_flops_ratio']:.3f}",
            _ADVICE[r["dominant"]][:60],
        ])
    return rows


HEADER = ["arch", "shape", "status", "compute_s", "memory_s",
          "collective_s", "dominant", "useful_ratio", "next_lever"]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--from-json", default=None)
    ap.add_argument("--cells", nargs="*", default=None,
                    help="arch:shape pairs to (re)compile")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    records: List[Dict] = []
    src = args.from_json or (DEFAULT_JSON if os.path.exists(DEFAULT_JSON)
                             else None)
    if src and not args.cells:
        with open(src) as f:
            records = json.load(f)
    elif args.cells:
        for cell in args.cells:
            arch, shape = cell.split(":")
            records.append(run_cell_subprocess(arch, shape))
    else:
        print("no dryrun_results.json found; compiling one demo cell "
              "(use launch.dryrun --all --out dryrun_results.json for the "
              "full 40-cell table)")
        records.append(run_cell_subprocess("qwen2-0.5b", "decode_32k"))

    rows = table_from_records(records)
    common.print_table("Roofline (single-pod 16x16, per-device terms)",
                       HEADER, rows)
    common.write_csv("roofline.csv", HEADER, rows)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(records, f, indent=1)
    return rows


if __name__ == "__main__":
    main()
