"""Fig 11 (new): the scheduler frontier — what intra-engine scheduling
buys BEFORE you pay for disaggregation (repro.sched, DESIGN.md s17).

Two machine-checked legs:

1. **Chunked-prefill interleaving moves the fig6 crossover.** Sweep
   offered rate x {co-2gpus serial, co-2gpus chunked-interleave,
   chunked+SRPT} against dis-ici at the interactive SLO. Serial
   colocation collapses once prefill-priority stalls blow the TPOT
   budget (paper finding F2); the chunked composer bounds every stall
   to one chunk, so the rate where dis-ici overtakes colocation rises —
   i.e. a scheduler, not new hardware, buys back part of the regime
   where disaggregation looked necessary.

2. **Intra-GPU P/D beats disk-mediated disaggregation wherever disk is
   even viable.** At the relaxed batch-tier SLO, sweep intra-gpu (the
   sixth setup: SM-partitioned P/D slices sharing one HBM pool) against
   dis-disk. Intra keeps phase isolation but its "transfer" is a
   pointer handoff: goodput dominates at every swept rate and its
   transfer energy is exactly zero, against dis-disk's per-request
   store+fetch joules.

Crossovers are read off the swept grid itself (piecewise-linear sign
change of the goodput gap) rather than ``crossover_rate`` bisection:
the bisection helper applies one kwargs set to both sides, and leg 1
needs a *different scheduler per side*.

  python -m benchmarks.fig11_scheduler_frontier            # full grid
  python -m benchmarks.fig11_scheduler_frontier --smoke    # CI grid
  ... --trace   # also run traced serial-vs-chunked runs above serial's
                # collapse, exporting Perfetto traces and checking the
                # blame shrink: chunking cuts the prefill-interference
                # share of TPOT blame (composed steps are productive
                # decode time, repro.obs.slo)
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

from repro.configs import get_config
from repro.core import SLO
from repro.exp import Experiment, run as run_exp
from repro.workload import DEFAULT_INTERACTIVE_SLO

from . import common

CHUNKED = {"composer": "chunked-interleave"}
CHUNKED_SRPT = {"composer": "chunked-interleave", "admission": "srpt"}
# (column label, scheduler knob) — None is the legacy serial/FCFS path
SCHED_VARIANTS = (("serial", None), ("chunked", CHUNKED),
                  ("chunked+srpt", CHUNKED_SRPT))
DEFAULT_SLO = DEFAULT_INTERACTIVE_SLO
# dis-disk attains 0 at the interactive SLO at ANY rate (fig6: the
# medium itself blows both targets), so leg 2 compares at the relaxed
# tier where disk-mediated disaggregation is actually deployable
BATCH_SLO = SLO(ttft_s=5.0, tpot_s=0.05)

ROW_HEADER = ["variant", "rate_rps", "goodput_rps", "attainment",
              "median_ttft_s", "median_tpot_ms", "transfer_j", "total_j"]


def grid_crossover(rates: Sequence[float], co: Sequence[float],
                   dis: Sequence[float]) -> Optional[float]:
    """Lowest rate where dis goodput reaches co goodput, linearly
    interpolated on the gap's sign change. None: co wins the whole
    grid (the crossover, if any, lies beyond max(rates))."""
    for i, r in enumerate(rates):
        gap = dis[i] - co[i]
        if gap < 0:
            continue
        if i == 0 or gap == 0.0:
            return r
        r0, gap0 = rates[i - 1], dis[i - 1] - co[i - 1]
        return r0 + (r - r0) * (-gap0) / (gap - gap0)
    return None


def _cell(setup, rate: float, slo: SLO, n: int, seed: int,
          arch: str, scheduler=None) -> Dict:
    """One swept cell through the shared content-addressed cache, with
    the energy-by-stage view leg 2's transfer-joules claim needs."""
    exp = Experiment.open(setup, rate, arch=arch, n=n, seed=seed, slo=slo)
    if scheduler is not None:
        exp = exp.with_scheduler(scheduler)
    rec = run_exp(exp)
    m, g, es = rec.metrics, rec.goodput, rec.energy_by_stage
    return {"rate_rps": rate, "goodput_rps": g["goodput_rps"],
            "attainment": g["attainment"],
            "median_ttft_s": m.median_ttft_s,
            "median_tpot_ms": m.median_tpot_s * 1e3,
            "transfer_j": es.get("transfer-store", 0.0)
            + es.get("transfer-fetch", 0.0),
            "total_j": sum(es.values())}


def _rows(cells: Dict[str, List[Dict]]) -> List[List]:
    rows = []
    for variant, pts in cells.items():
        for p in pts:
            rows.append([variant, p["rate_rps"],
                         round(p["goodput_rps"], 4),
                         round(p["attainment"], 4),
                         round(p["median_ttft_s"], 4),
                         round(p["median_tpot_ms"], 3),
                         round(p["transfer_j"], 1),
                         round(p["total_j"], 1)])
    return rows


# ----------------------------------------------------------------------
def run_traced(arch: str, *, rate: float, n: int, slo: SLO, seed: int
               ) -> Dict:
    """Traced co-2gpus runs, serial vs chunked, above serial's collapse
    rate: export Perfetto traces and measure how much of the TPOT blame
    each scheduler loses to prefill-interference. Chunked composed
    steps surface as productive decode time (``_TPOT_TERM['mixed']``),
    so the share must shrink."""
    from repro.core.orchestrator import make_cluster
    from repro.fleet import as_fleet_spec
    from repro.obs import (Tracer, assert_complete_lifecycles,
                           attribute_run, blame_table, chrome_trace,
                           validate_chrome_trace)
    from repro.workload import open_loop_workload

    cfg = get_config(arch)
    out = {"arch": arch, "rate_rps": rate, "n_requests": n, "seed": seed,
           "slo": {"ttft_s": slo.ttft_s, "tpot_s": slo.tpot_s},
           "variants": {}}
    for label, sched in (("serial", None), ("chunked", CHUNKED)):
        reqs = open_loop_workload(rate, n, slo=slo, seed=seed)
        tracer = Tracer()
        cluster = make_cluster(as_fleet_spec("co-2gpus"), cfg,
                               tracer=tracer, scheduler=sched)
        cluster.run(reqs)
        trace = chrome_trace(tracer, label=f"fig11 co-2gpus {label} "
                                           f"@ {rate} rps")
        validate_chrome_trace(trace)
        assert_complete_lifecycles(trace, n_requests=n)
        common.write_json(trace, f"fig11_trace_{label}.json")
        table = blame_table(attribute_run(reqs, slo, tracer))
        tpot = table["metrics"].get("tpot", {})
        total = tpot.get("total_overrun_s", 0.0)
        interference = tpot.get("terms", {}).get("prefill-interference",
                                                 0.0)
        share = interference / total if total else 0.0
        out["variants"][label] = {
            "violations": table["violations"],
            "tpot_overrun_s": total,
            "prefill_interference_share": share,
            "blame": table,
        }
        print(f"trace {label}: {table['violations']} violations, "
              f"prefill-interference share of TPOT blame {share:.2f}")
    common.write_json(out, "fig11_blame_shrink.json")
    return out


def check_blame_shrink(blame: Dict) -> None:
    serial = blame["variants"]["serial"]
    chunked = blame["variants"]["chunked"]
    assert serial["prefill_interference_share"] > 0.0, (
        "fig11 blame claim unverifiable: serial co-2gpus shows no "
        f"prefill-interference blame at rate {blame['rate_rps']} — "
        "raise the rate above the serial collapse")
    assert (chunked["prefill_interference_share"]
            < serial["prefill_interference_share"]), (
        "chunked-interleave did not shrink the prefill-interference "
        f"share: serial {serial['prefill_interference_share']:.3f} vs "
        f"chunked {chunked['prefill_interference_share']:.3f}")


# ----------------------------------------------------------------------
def check_claims(claims: Dict) -> None:
    """The two headline claims, machine-checked on every invocation
    (CI runs --smoke and asserts these same booleans off the JSON)."""
    assert claims["serial_crossover_rps"] is not None, (
        "serial co-2gpus never loses to dis-ici inside the swept grid — "
        "the crossover-shift claim needs a finite baseline crossover")
    c_serial = claims["serial_crossover_rps"]
    c_chunked = claims["chunked_crossover_rps"]
    assert c_chunked is None or c_chunked > c_serial, (
        f"chunked-interleave did not raise the dis-ici crossover: "
        f"serial {c_serial} vs chunked {c_chunked} req/s")
    assert claims["intra_dominates_disk_goodput"], (
        "intra-gpu goodput fell below dis-disk somewhere in the swept "
        f"grid: {claims['intra_vs_disk_gaps']}")
    assert claims["intra_transfer_j"] == 0.0 \
        and claims["disk_transfer_j"] > 0.0, (
        f"transfer-energy claim failed: intra {claims['intra_transfer_j']}"
        f" J vs disk {claims['disk_transfer_j']} J")


def run(arch: str = common.DEFAULT_ARCH, *, rates=None, intra_rates=None,
        n: int = common.OPEN_LOOP_N, slo: SLO = DEFAULT_SLO,
        smoke: bool = False, seed: int = 0, trace: bool = False) -> Dict:
    cfg = get_config(arch)
    if rates is None:
        rates = (3.0, 4.5, 6.0) if smoke else \
            (1.0, 2.0, 3.0, 3.5, 4.0, 4.5, 5.0, 6.0, 8.0, 12.0)
    if intra_rates is None:
        intra_rates = (1.0, 2.0) if smoke else \
            (0.5, 1.0, 1.5, 2.0, 3.0, 4.0)
    rates = tuple(rates)
    intra_rates = tuple(intra_rates)

    # -- leg 1: scheduler variants vs dis-ici at the interactive SLO --
    cells: Dict[str, List[Dict]] = {}
    for label, sched in SCHED_VARIANTS:
        cells[label] = [_cell("co-2gpus", r, slo, n, seed, arch,
                              scheduler=sched) for r in rates]
    cells["dis-ici"] = [_cell("dis-ici", r, slo, n, seed, arch)
                        for r in rates]

    dis_g = [p["goodput_rps"] for p in cells["dis-ici"]]
    crossovers = {}
    for label, _ in SCHED_VARIANTS:
        co_g = [p["goodput_rps"] for p in cells[label]]
        c = grid_crossover(rates, co_g, dis_g)
        crossovers[label] = None if c is None else round(c, 3)
        print(f"dis-ici overtakes co-2gpus[{label}] at "
              f"{'no swept rate' if c is None else f'~{c:.2f} req/s'}")

    # -- leg 2: intra-gpu vs dis-disk at the batch tier ---------------
    for setup in ("intra-gpu", "dis-disk"):
        cells[setup] = [_cell(setup, r, BATCH_SLO, n, seed, arch)
                        for r in intra_rates]
    intra, disk = cells["intra-gpu"], cells["dis-disk"]
    gaps = [round(i["goodput_rps"] - d["goodput_rps"], 4)
            for i, d in zip(intra, disk)]
    intra_xfer = max(p["transfer_j"] for p in intra)
    disk_xfer = min(p["transfer_j"] for p in disk)

    claims = {
        "serial_crossover_rps": crossovers["serial"],
        "chunked_crossover_rps": crossovers["chunked"],
        "chunking_raises_crossover": crossovers["serial"] is not None
        and (crossovers["chunked"] is None
             or crossovers["chunked"] > crossovers["serial"]),
        "intra_vs_disk_gaps": gaps,
        "intra_dominates_disk_goodput": all(g >= 0 for g in gaps),
        "intra_transfer_j": intra_xfer,
        "disk_transfer_j": disk_xfer,
        "intra_zero_transfer_joules": intra_xfer == 0.0 and disk_xfer > 0.0,
    }

    rows = _rows(cells)
    common.print_table("Fig 11: scheduler frontier", ROW_HEADER, rows)
    common.write_csv("fig11_scheduler_frontier.csv", ROW_HEADER, rows)
    payload = {
        "arch": arch, "n_requests": n, "seed": seed,
        "slo": {"ttft_s": slo.ttft_s, "tpot_s": slo.tpot_s},
        "batch_slo": {"ttft_s": BATCH_SLO.ttft_s,
                      "tpot_s": BATCH_SLO.tpot_s},
        "rates_rps": list(rates), "intra_rates_rps": list(intra_rates),
        "points": [dict(zip(ROW_HEADER, r)) for r in rows],
        "crossovers": crossovers,
        "claims": claims,
    }

    if trace:
        # traced pass above serial's collapse: the highest swept rate
        # where chunked still wins, so serial shows interference blame
        blame = run_traced(arch, rate=rates[-1] if smoke else 4.5,
                           n=n, slo=slo, seed=seed)
        check_blame_shrink(blame)
        print("fig11 blame claim holds: chunking shrinks the "
              "prefill-interference share of TPOT blame")
        payload["blame_shrink"] = {
            k: {kk: vv for kk, vv in v.items() if kk != "blame"}
            for k, v in blame["variants"].items()}

    common.write_json(payload, "fig11_scheduler_frontier.json")
    check_claims(claims)
    print("fig11 claims hold: chunking raises the dis-ici crossover "
          f"({claims['serial_crossover_rps']} -> "
          f"{claims['chunked_crossover_rps'] or 'beyond grid'} req/s); "
          "intra-gpu dominates dis-disk with zero transfer joules")
    return payload


def main(argv=None):
    ap = common.open_loop_arg_parser(__doc__)
    ap.add_argument("--ttft-slo", type=float, default=DEFAULT_SLO.ttft_s)
    ap.add_argument("--tpot-slo", type=float, default=DEFAULT_SLO.tpot_s)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny grid for CI; emits the same JSON artifact")
    ap.add_argument("--trace", action="store_true",
                    help="also export serial-vs-chunked Perfetto traces "
                         "and machine-check the blame-shrink claim")
    args = ap.parse_args(argv)
    run(args.arch, rates=args.rate, n=args.requests,
        slo=SLO(ttft_s=args.ttft_slo, tpot_s=args.tpot_slo),
        smoke=args.smoke, seed=args.seed, trace=args.trace)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
