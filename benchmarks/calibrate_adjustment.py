"""Calibrate the flash-adjustment access constant empirically.

``vmem_resident_traffic`` subtracts the attention logits/probs traffic the
Pallas kernels keep in VMEM. The subtraction needs the number of HBM
accesses XLA's lowering actually performs per (q, k) pair — assumed 16 B
per pair-access-set so far. This tool lowers a standalone reference
attention at several sizes, fits  bytes = a + c * pairs,  and reports c
(bytes per causal pair), for both forward-only and forward+backward.

  PYTHONPATH=src python -m benchmarks.calibrate_adjustment
"""
from __future__ import annotations

import numpy as np


def measure(train: bool = False):
    import jax
    import jax.numpy as jnp
    from repro.kernels import ref

    def fwd(q, k, v):
        return ref.flash_attention_ref(q, k, v, causal=True)

    def loss(q, k, v):
        return jnp.sum(fwd(q, k, v).astype(jnp.float32) ** 2)

    rows = []
    B, H, hd = 2, 4, 64
    for S in (256, 512, 1024, 2048):
        q = jax.ShapeDtypeStruct((B, S, H, hd), jnp.bfloat16)
        k = jax.ShapeDtypeStruct((B, S, H, hd), jnp.bfloat16)
        v = jax.ShapeDtypeStruct((B, S, H, hd), jnp.bfloat16)
        fn = jax.grad(loss, argnums=(0, 1, 2)) if train else fwd
        compiled = jax.jit(fn).lower(q, k, v).compile()
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        pairs = B * H * S * S / 2
        rows.append((pairs, float(ca["bytes accessed"])))
    # least-squares fit bytes = a + c * pairs
    x = np.array([r[0] for r in rows])
    y = np.array([r[1] for r in rows])
    c, a = np.polyfit(x, y, 1)
    return c, a, rows


def main():
    c_fwd, _, rows_f = measure(train=False)
    c_bwd, _, rows_b = measure(train=True)
    print("forward-only  bytes/pair:", round(c_fwd, 2))
    print("fwd+backward  bytes/pair:", round(c_bwd, 2))
    print("(current vmem_resident_traffic assumes 16 fwd / 48 train)")
    print("train_scale implied:", round(c_bwd / max(c_fwd, 1e-9), 2))
    return c_fwd, c_bwd


if __name__ == "__main__":
    main()
