"""Fig 6 (new): SLO-goodput vs offered load and the crossover rates.

The paper's central caveat quantified: sweep offered Poisson rate x
setup (the three dis-* rows are the KV transfer media), score each cell
with DistServe-style goodput (requests/s meeting BOTH the TTFT and TPOT
SLO), then bisect for each dis-* setup's *crossover load* against the
equal-resource co-2gpus baseline. On this cost model colocation wins
below the crossover (no interference to avoid, so the KV handoff is
pure overhead) and disaggregation wins above it (prefill-priority
stalls + preemption churn); slower media push the crossover up —
dis-disk typically never crosses.

  python -m benchmarks.fig6_load_crossover            # full grid
  python -m benchmarks.fig6_load_crossover --smoke    # CI: tiny grid + JSON
"""
from __future__ import annotations

from repro.configs import get_config
from repro.core import SLO
from repro.workload import (DEFAULT_INTERACTIVE_SLO, RatePoint,
                            crossover_rate, rate_grid)

from . import common

DIS_SETUPS = ("dis-ici", "dis-host", "dis-disk")
DEFAULT_SLO = DEFAULT_INTERACTIVE_SLO


def run(arch: str = common.DEFAULT_ARCH, *, rates=None,
        n: int = common.OPEN_LOOP_N,
        slo: SLO = DEFAULT_SLO, smoke: bool = False, seed: int = 0):
    cfg = get_config(arch)
    if rates is None:
        rates = (1.0, 2.0, 4.0) if smoke else (1.0, 2.0, 3.0, 4.0, 6.0,
                                               8.0, 12.0, 16.0, 24.0)
    setups = ("co-2gpus",) + DIS_SETUPS
    points = rate_grid(cfg, rates, setups=setups, slo=slo, n=n, seed=seed)
    rows = [p.as_row() for p in points]
    common.print_table("Fig 6: SLO goodput vs offered load",
                       RatePoint.ROW_HEADER, rows)
    common.write_csv("fig6_load_crossover.csv", RatePoint.ROW_HEADER, rows)

    lo, hi = min(rates), max(rates)
    iters = 2 if smoke else 5
    # seed the bisection cache with the grid cells already simulated;
    # the co-2gpus baseline is then shared across all three dis sweeps
    cache = {(p.setup, p.rate): p.goodput_rps for p in points}
    crossovers = {}
    for setup in DIS_SETUPS:
        if lo >= hi:
            print(f"{setup}: need >= 2 distinct rates to bracket a "
                  f"crossover (got {sorted(set(rates))})")
            crossovers[setup] = None
            continue
        c = crossover_rate(setup, cfg, baseline="co-2gpus", lo=lo, hi=hi,
                           iters=iters, cache=cache, slo=slo, n=n,
                           seed=seed)
        crossovers[setup] = (None if c is None else
                             {"rate_rps": round(c.rate, 3),
                              "winner_below": c.winner_below,
                              "winner_above": c.winner_above})
        if c is None:
            print(f"{setup}: no goodput crossover vs co-2gpus in "
                  f"[{lo}, {hi}] req/s")
        else:
            print(f"{setup}: goodput crossover vs co-2gpus at "
                  f"~{c.rate:.2f} req/s ({c.winner_below} wins below, "
                  f"{c.winner_above} above)")

    payload = {
        "arch": arch, "n_requests": n, "seed": seed,
        "slo": {"ttft_s": slo.ttft_s, "tpot_s": slo.tpot_s},
        "rates_rps": list(rates),
        "points": [dict(zip(RatePoint.ROW_HEADER, r)) for r in rows],
        "crossovers": crossovers,
    }
    common.write_json(payload, "fig6_load_crossover.json")
    return payload


def main(argv=None):
    ap = common.open_loop_arg_parser(__doc__)
    ap.add_argument("--ttft-slo", type=float, default=DEFAULT_SLO.ttft_s)
    ap.add_argument("--tpot-slo", type=float, default=DEFAULT_SLO.tpot_s)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny grid for CI; emits the same JSON artifact")
    args = ap.parse_args(argv)
    run(args.arch, rates=args.rate, n=args.requests,
        slo=SLO(ttft_s=args.ttft_slo, tpot_s=args.tpot_slo),
        smoke=args.smoke, seed=args.seed)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
