"""Fig 6 (new): SLO-goodput vs offered load and the crossover rates.

The paper's central caveat quantified: sweep offered Poisson rate x
setup (the three dis-* rows are the KV transfer media), score each cell
with DistServe-style goodput (requests/s meeting BOTH the TTFT and TPOT
SLO), then bisect for each dis-* setup's *crossover load* against the
equal-resource co-2gpus baseline. On this cost model colocation wins
below the crossover (no interference to avoid, so the KV handoff is
pure overhead) and disaggregation wins above it (prefill-priority
stalls + preemption churn); slower media push the crossover up —
dis-disk typically never crosses.

  python -m benchmarks.fig6_load_crossover            # full grid
  python -m benchmarks.fig6_load_crossover --smoke    # CI: tiny grid + JSON
  ... --trace   # also run one traced simulation per setup at the lowest
                # rate, exporting Perfetto traces (fig6_trace_<setup>.json)
                # and the per-setup SLO blame table (fig6_slo_blame.json)
                # that machine-checks the narrative: below the crossover,
                # dis violations are transfer+queue dominated
"""
from __future__ import annotations

from repro.configs import get_config
from repro.core import SLO
from repro.workload import (DEFAULT_INTERACTIVE_SLO, RatePoint,
                            crossover_rate, rate_grid)

from . import common

DIS_SETUPS = ("dis-ici", "dis-host", "dis-disk")
DEFAULT_SLO = DEFAULT_INTERACTIVE_SLO


def run_traced(arch: str, *, rate: float, n: int, slo: SLO, seed: int,
               setups=("co-2gpus",) + DIS_SETUPS):
    """One traced simulation per setup at ``rate`` (the below-crossover
    regime): exports a Perfetto-loadable trace per setup plus the
    aggregated SLO blame table. Traced runs are purely observational —
    the goodput numbers match the untraced grid cells bit-for-bit."""
    from repro.core.orchestrator import make_cluster
    from repro.obs import (Tracer, assert_complete_lifecycles,
                           attribute_run, blame_table, chrome_trace,
                           transfer_queue_share, validate_chrome_trace)
    from repro.workload import open_loop_workload

    cfg = get_config(arch)
    blame = {"arch": arch, "rate_rps": rate, "n_requests": n,
             "seed": seed,
             "slo": {"ttft_s": slo.ttft_s, "tpot_s": slo.tpot_s},
             "setups": {}}
    for setup in setups:
        reqs = open_loop_workload(rate, n, slo=slo, seed=seed)
        tracer = Tracer()
        cluster = make_cluster(setup, cfg, tracer=tracer)
        cluster.run(reqs)
        trace = chrome_trace(tracer, label=f"fig6 {setup} @ {rate} rps")
        validate_chrome_trace(trace)
        assert_complete_lifecycles(trace, n_requests=n)
        common.write_json(trace, f"fig6_trace_{setup}.json")
        table = blame_table(attribute_run(reqs, slo, tracer))
        table["transfer_queue_share_overall"] = transfer_queue_share(table)
        blame["setups"][setup] = table
        share = table["transfer_queue_share_overall"]
        print(f"trace {setup}: {len(tracer.events)} events, "
              f"{table['violations']} SLO violations, "
              f"transfer+queue share "
              f"{'n/a' if share is None else f'{share:.2f}'}")
    common.write_json(blame, "fig6_slo_blame.json")
    return blame


def check_blame_claim(blame: dict) -> None:
    """Machine-check of the fig6 narrative on a blame table produced
    below the crossover: every dis setup WITH violations loses its SLO
    budget to transfer+queue terms (share > 0.5, with at least one such
    setup present — dis-disk at any sane rate), while colocated
    violations, if any, are compute-bound (share < 0.5)."""
    dis_with_viol = [s for s in DIS_SETUPS
                     if blame["setups"].get(s, {}).get("violations")]
    assert dis_with_viol, (
        "fig6 claim unverifiable: no dis setup has SLO violations at "
        f"rate {blame['rate_rps']} — lower the SLO or raise the rate")
    for s in dis_with_viol:
        share = blame["setups"][s]["transfer_queue_share_overall"]
        assert share is not None and share > 0.5, (
            f"{s}: transfer+queue share {share} <= 0.5 — dis violations "
            "are not transfer+queue dominated below the crossover")
    co = blame["setups"].get("co-2gpus", {})
    if co.get("violations"):
        share = co["transfer_queue_share_overall"]
        assert share is not None and share < 0.5, (
            f"co-2gpus: transfer+queue share {share} >= 0.5 — colocated "
            "violations should be compute (interference) dominated")


def run(arch: str = common.DEFAULT_ARCH, *, rates=None,
        n: int = common.OPEN_LOOP_N,
        slo: SLO = DEFAULT_SLO, smoke: bool = False, seed: int = 0,
        trace: bool = False):
    cfg = get_config(arch)
    if rates is None:
        rates = (1.0, 2.0, 4.0) if smoke else (1.0, 2.0, 3.0, 4.0, 6.0,
                                               8.0, 12.0, 16.0, 24.0)
    setups = ("co-2gpus",) + DIS_SETUPS
    points = rate_grid(cfg, rates, setups=setups, slo=slo, n=n, seed=seed)
    rows = [p.as_row() for p in points]
    common.print_table("Fig 6: SLO goodput vs offered load",
                       RatePoint.ROW_HEADER, rows)
    common.write_csv("fig6_load_crossover.csv", RatePoint.ROW_HEADER, rows)

    lo, hi = min(rates), max(rates)
    iters = 2 if smoke else 5
    # seed the bisection cache with the grid cells already simulated;
    # the co-2gpus baseline is then shared across all three dis sweeps
    cache = {(p.setup, p.rate): p.goodput_rps for p in points}
    crossovers = {}
    for setup in DIS_SETUPS:
        if lo >= hi:
            print(f"{setup}: need >= 2 distinct rates to bracket a "
                  f"crossover (got {sorted(set(rates))})")
            crossovers[setup] = None
            continue
        c = crossover_rate(setup, cfg, baseline="co-2gpus", lo=lo, hi=hi,
                           iters=iters, cache=cache, slo=slo, n=n,
                           seed=seed)
        crossovers[setup] = (None if c is None else
                             {"rate_rps": round(c.rate, 3),
                              "winner_below": c.winner_below,
                              "winner_above": c.winner_above})
        if c is None:
            print(f"{setup}: no goodput crossover vs co-2gpus in "
                  f"[{lo}, {hi}] req/s")
        else:
            print(f"{setup}: goodput crossover vs co-2gpus at "
                  f"~{c.rate:.2f} req/s ({c.winner_below} wins below, "
                  f"{c.winner_above} above)")

    payload = {
        "arch": arch, "n_requests": n, "seed": seed,
        "slo": {"ttft_s": slo.ttft_s, "tpot_s": slo.tpot_s},
        "rates_rps": list(rates),
        "points": [dict(zip(RatePoint.ROW_HEADER, r)) for r in rows],
        "crossovers": crossovers,
    }
    common.write_json(payload, "fig6_load_crossover.json")

    if trace:
        # traced pass at the lowest rate — the below-crossover regime
        # where the blame table must show dis violations losing their
        # budget to transfer+queue, not compute
        blame = run_traced(arch, rate=lo, n=n, slo=slo, seed=seed)
        check_blame_claim(blame)
        print("fig6 blame claim holds: dis violations below the "
              "crossover are transfer+queue dominated")
        payload["slo_blame"] = blame
    return payload


def main(argv=None):
    ap = common.open_loop_arg_parser(__doc__)
    ap.add_argument("--ttft-slo", type=float, default=DEFAULT_SLO.ttft_s)
    ap.add_argument("--tpot-slo", type=float, default=DEFAULT_SLO.tpot_s)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny grid for CI; emits the same JSON artifact")
    ap.add_argument("--trace", action="store_true",
                    help="also export Perfetto traces + the SLO blame "
                         "table at the lowest rate, and machine-check "
                         "the fig6 narrative on it")
    args = ap.parse_args(argv)
    run(args.arch, rates=args.rate, n=args.requests,
        slo=SLO(ttft_s=args.ttft_slo, tpot_s=args.tpot_slo),
        smoke=args.smoke, seed=args.seed, trace=args.trace)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
