"""Simulator-core performance benchmark: the BENCH_simcore.json trajectory.

Times COLD simulations (no ``repro.exp`` result cache — clusters and
workloads are rebuilt every repetition) of three fixed-seed scenarios
through both steppers:

  small    1 colocated engine, light chat traffic
  medium   1P:1D over ici, the paper's canonical disaggregated pair
  fleet    8P:8D over ici under sustained load — the scale at which the
           exact per-token event loop became the bottleneck and the
           coalescing fast stepper (DESIGN.md section 13) earns its keep
  fleet-adaptive
           4P:4D with the adaptive fleet controller active — the bail
           rule (DESIGN.md section 14) sends BOTH steppers through the
           exact loop, so its speedup ratio is pinned near 1.0 and the
           --check guard catches the bail rule silently disappearing
           (a >1 ratio here would mean fast coalesced across controller
           ticks, which is exactly the bug the rule forbids)
  tiered-reuse
           2 colocated engines with per-engine tiered KV stores and the
           prefix-affinity router on a shared-prefix workload — the
           tiered bail rule (DESIGN.md section 15) pins this ratio near
           1.0 the same way: a >1 ratio means the fast stepper coalesced
           across tier lookups whose residency is routing-visible
  sched-interleave
           1 colocated engine running the chunked-interleave composer
           (repro.sched, DESIGN.md section 17) — composed mixed steps
           are never uniform decode runs, so the scheduler bail rule
           pins this ratio near 1.0 too: a >1 ratio means the fast
           stepper coalesced across composed steps it cannot price

The committed ``benchmarks/BENCH_simcore.json`` is the tracked baseline:
re-run with ``--check`` to compare the CURRENT tree against it, failing
on a >20% regression. Comparisons use the fast/exact *speedup ratio*,
not absolute wall-clock, so the check is portable across machines — a
slower CI box slows both steppers alike.

  PYTHONPATH=src python -m benchmarks.perf_bench             # measure
  PYTHONPATH=src python -m benchmarks.perf_bench --check     # vs baseline
  PYTHONPATH=src python -m benchmarks.perf_bench --update    # new baseline
  ... --quick    # fewer repetitions (CI; timings noisier, ratios fine)
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import Dict, Tuple

from repro.configs import get_config
from repro.core.orchestrator import make_cluster
from repro.fleet.cluster import STEPPERS
from repro.fleet.spec import FleetSpec
from repro.workload import (open_loop_workload, PaperFixedLengths,
                            RAGSharedPrefixLengths)

BASELINE = os.path.join(os.path.dirname(__file__), "BENCH_simcore.json")
OUT = os.path.join(os.path.dirname(__file__), "out", "BENCH_simcore.json")
ARCH = "llama32-3b"
# >20% drop in any scenario's speedup ratio fails --check
REGRESSION_FRACTION = 0.20

SCENARIOS: Dict[str, Tuple[FleetSpec, dict]] = {
    "small": (FleetSpec(n_colocated=1),
              dict(rate=8.0, n=40,
                   lengths=PaperFixedLengths(1024, 128), seed=0)),
    "medium": (FleetSpec(n_prefill=1, n_decode=1, medium="ici"),
               dict(rate=12.0, n=80,
                    lengths=PaperFixedLengths(2048, 256), seed=0)),
    "fleet": (FleetSpec(n_prefill=8, n_decode=8, medium="ici"),
              dict(rate=12.0, n=256,
                   lengths=PaperFixedLengths(2048, 768), seed=0)),
    "fleet-adaptive": (FleetSpec(n_prefill=4, n_decode=4, medium="ici",
                                 controller="adaptive"),
                       dict(rate=12.0, n=96,
                            lengths=PaperFixedLengths(1024, 256), seed=0)),
    "tiered-reuse": (FleetSpec(n_colocated=2, router="prefix-affinity",
                               reuse={"mode": "prefix",
                                      "tiers": {"hbm_pages": 64,
                                                "dram_pages": 128,
                                                "disk_pages": 256}}),
                     dict(rate=8.0, n=64, vocab_size=512,
                          lengths=RAGSharedPrefixLengths(prefix_len=2048),
                          seed=0)),
    "sched-interleave": (FleetSpec(n_colocated=1,
                                   scheduler={"composer":
                                              "chunked-interleave"}),
                         dict(rate=8.0, n=40,
                              lengths=PaperFixedLengths(1024, 128),
                              seed=0)),
}


def time_scenario(name: str, stepper: str, reps: int) -> Dict:
    """Best-of-``reps`` cold wall-clock for one (scenario, stepper).
    Cold = cluster construction + full simulation, fresh every rep
    (workload generation is excluded: it is stepper-independent)."""
    spec, wk = SCENARIOS[name]
    cfg = get_config(ARCH)
    best_s, steps = float("inf"), 0
    fastpath = {}
    for _ in range(reps):
        requests = open_loop_workload(**wk)
        t0 = time.perf_counter()
        cluster = make_cluster(spec, cfg)
        cluster.run(requests, stepper=stepper)
        elapsed = time.perf_counter() - t0
        if elapsed < best_s:
            best_s = elapsed
            steps = sum(e.steps for e in cluster.engines)
            # coalescing stats make a speedup regression diagnosable:
            # a dropped ratio with an unchanged coalesced fraction is a
            # constant-factor slowdown; a dropped fraction means runs
            # stopped being eligible (ISSUE 9 satellite 2). Identical
            # across reps (deterministic), recorded from the best one.
            fastpath = dict(cluster.fastpath_stats)
            fastpath["coalesced_step_fraction"] = round(
                fastpath["coalesced_step_fraction"], 4)
    return {"wall_s": round(best_s, 6), "engine_steps": steps,
            "events_per_s": round(steps / best_s, 1),
            "fastpath": fastpath}


def measure(reps: int) -> Dict:
    out = {"arch": ARCH, "scenarios": {}}
    for name in SCENARIOS:
        row = {}
        for stepper in STEPPERS:
            row[stepper] = time_scenario(name, stepper, reps)
            print(f"{name:7s} {stepper:6s} {row[stepper]['wall_s']*1e3:9.1f}ms"
                  f"  {row[stepper]['events_per_s']:12,.0f} steps/s")
        row["speedup"] = round(
            row["exact"]["wall_s"] / row["fast"]["wall_s"], 2)
        print(f"{name:7s} speedup {row['speedup']:.1f}x")
        out["scenarios"][name] = row
    return out


def check(current: Dict, baseline: Dict) -> int:
    """0 when every scenario's speedup is within REGRESSION_FRACTION of
    the committed baseline ratio, 1 otherwise."""
    failures = []
    for name, base_row in baseline["scenarios"].items():
        cur = current["scenarios"].get(name)
        if cur is None:
            failures.append(f"{name}: missing from current run")
            continue
        floor = base_row["speedup"] * (1.0 - REGRESSION_FRACTION)
        status = "ok" if cur["speedup"] >= floor else "REGRESSION"
        print(f"{name:7s} baseline {base_row['speedup']:6.1f}x  "
              f"current {cur['speedup']:6.1f}x  floor {floor:6.1f}x  "
              f"{status}")
        if cur["speedup"] < floor:
            failures.append(
                f"{name}: speedup {cur['speedup']}x < floor {floor:.1f}x "
                f"(baseline {base_row['speedup']}x)")
    for f in failures:
        print("FAIL", f, file=sys.stderr)
    return 1 if failures else 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--check", action="store_true",
                    help="compare against the committed baseline; exit 1 "
                         f"on a >{REGRESSION_FRACTION:.0%} speedup drop")
    ap.add_argument("--update", action="store_true",
                    help="overwrite the committed baseline")
    ap.add_argument("--quick", action="store_true",
                    help="2 repetitions instead of 4")
    args = ap.parse_args(argv)

    current = measure(reps=2 if args.quick else 4)
    os.makedirs(os.path.dirname(OUT), exist_ok=True)
    with open(OUT, "w") as f:
        json.dump(current, f, indent=2, sort_keys=True)
        f.write("\n")
    print("wrote", OUT)

    if args.update:
        with open(BASELINE, "w") as f:
            json.dump(current, f, indent=2, sort_keys=True)
            f.write("\n")
        print("baseline updated:", BASELINE)
        return 0
    if args.check:
        if not os.path.exists(BASELINE):
            print("no committed baseline at", BASELINE, file=sys.stderr)
            return 1
        with open(BASELINE) as f:
            return check(current, json.load(f))
    return 0


if __name__ == "__main__":
    sys.exit(main())
