"""Render EXPERIMENTS.md tables from dryrun_results.json, and the
per-stage idle/active energy breakdown from the fig8 governor JSON.

  PYTHONPATH=src python -m benchmarks.report --json dryrun_results.json \
      --write-experiments
  PYTHONPATH=src python -m benchmarks.report \
      --energy-json benchmarks/out/fig8_governor_pareto.json
  PYTHONPATH=src python -m benchmarks.report \
      --trace benchmarks/out/fig6_trace_dis-disk.json
"""
from __future__ import annotations

import argparse
import json
import os
import re
from typing import Dict, List

EXPERIMENTS = os.path.join(os.path.dirname(__file__), "..",
                           "EXPERIMENTS.md")


def dryrun_table(recs: List[Dict]) -> str:
    lines = [
        "| arch | shape | mesh | status | args/dev | temp/dev | compile |",
        "|---|---|---|---|---|---|---|",
    ]
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        if r["status"] == "skip":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                         f"skip (full attention at 500k) | – | – | – |")
            continue
        # memory_analysis of the partitioned module is per-device already
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['status']} | "
            f"{r['argument_bytes'] / 2**30:.2f} GiB | "
            f"{r['temp_bytes'] / 2**30:.1f} GiB | "
            f"{r['compile_s']:.0f} s |")
    ok = sum(r["status"] == "ok" for r in recs)
    skip = sum(r["status"] == "skip" for r in recs)
    fail = sum(r["status"] == "fail" for r in recs)
    head = (f"**{ok} ok / {skip} skip / {fail} fail** over "
            f"{len(recs)} cells. Bytes are per device "
            f"(arguments = params + optimizer state + inputs; temp = "
            f"compiler scratch).\n\n")
    return head + "\n".join(lines)


def roofline_table(recs: List[Dict]) -> str:
    lines = [
        "| arch | shape | compute_s | memory_s | collective_s | dominant "
        "| useful | next lever |",
        "|---|---|---|---|---|---|---|---|",
    ]
    lever = {
        "compute": "MXU efficiency: fused tiles, fewer f32 upcasts,"
                   " dot-saveable remat",
        "memory": "keep logits/scan state in VMEM (Pallas), fuse norms,"
                  " cut f32 intermediates, kill SPMD remat copies",
        "collective": "reshard (seq-shard KV / local MoE dispatch),"
                      " overlap+compress DP all-reduce",
    }
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"])):
        if r.get("mesh") != "16x16":
            continue
        if r["status"] == "skip":
            lines.append(f"| {r['arch']} | {r['shape']} | – | – | – | skip "
                         f"| – | sub-quadratic attention required |")
            continue
        if "roofline" not in r:
            continue
        t = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {t['compute_s']:.3f} | "
            f"{t['memory_s']:.3f} | {t['collective_s']:.3f} | "
            f"{t['dominant']} | {t['useful_flops_ratio']:.3f} | "
            f"{lever[t['dominant']][:55]} |")
    return "\n".join(lines)


ENERGY_STAGES = ("prefill", "decode", "transfer-store", "transfer-fetch")


def energy_table(payload: Dict) -> str:
    """Per-stage + idle/active energy columns for every (setup, rate,
    policy) cell of a fig8 governor JSON — the breakdown that makes the
    idle-power floor visible next to the active joules a governor can
    actually influence."""
    cols = " | ".join(f"{s}_j" for s in ENERGY_STAGES)
    lines = [
        f"| setup | rate | policy | {cols} | active_j | idle_j "
        "| idle_frac | attain |",
        "|---|---|---|" + "---|" * (len(ENERGY_STAGES) + 4),
    ]
    for r in sorted(payload["points"],
                    key=lambda r: (r["setup"], r["rate_rps"],
                                   r["policy"])):
        stages = " | ".join(
            f"{r.get('by_stage', {}).get(s, 0.0):.0f}"
            for s in ENERGY_STAGES)
        idle_frac = r["idle_j"] / max(r["total_j"], 1e-9)
        lines.append(
            f"| {r['setup']} | {r['rate_rps']} | {r['policy']} | "
            f"{stages} | {r['active_j']:.0f} | {r['idle_j']:.0f} | "
            f"{idle_frac:.0%} | {r['attainment']:.0%} |")
    return "\n".join(lines)


def reuse_verdicts(payload: Dict) -> str:
    """Human-readable verdicts for the fig10 claims block: did reuse
    engage, cut prefill joules, move the crossover, dent the energy
    gap. The booleans were machine-asserted when the figure ran; this
    renders the quantitative outcomes next to them."""
    c = payload["claims"]
    lines = [
        f"reuse engaged everywhere: {'yes' if c['reuse_engaged'] else 'NO'}",
        f"prefill joules cut by every reuse config: "
        f"{'yes' if c['prefill_j_cut_by_reuse'] else 'NO'}",
        "",
        "| reuse | dis setup | crossover (req/s) | shift vs none |",
        "|---|---|---|---|",
    ]
    shifts = c.get("crossover_shift", {})
    for reuse, per_dis in sorted(c["crossovers"].items()):
        for dis, x in sorted(per_dis.items()):
            sh = shifts.get(reuse, {}).get(dis)
            lines.append(
                f"| {reuse} | {dis} | "
                f"{'none in range' if x is None else x} | "
                f"{'–' if sh is None else f'{sh:+}'} |")
    lines += [
        "",
        f"energy gap dented anywhere: "
        f"{'yes' if c['gap_dented_anywhere'] else 'no'}",
        "| dis setup | rate | reuse | gap none (J) | gap reuse (J) "
        "| dent (J) |",
        "|---|---|---|---|---|---|",
    ]
    for g in c["gap_dent_at"]:
        lines.append(
            f"| {g['dis']} | {g['rate_rps']} | {g['reuse']} | "
            f"{g['gap_none_j']:+.0f} | {g['gap_reuse_j']:+.0f} | "
            f"{g['dent_j']:+.0f} |")
    return "\n".join(lines)


def fill(experiments_path: str, marker: str, content: str) -> None:
    """Idempotent fill between <!-- MARKER_BEGIN/END --> sentinels."""
    with open(experiments_path) as f:
        text = f.read()
    begin = f"<!-- {marker}_BEGIN -->"
    end = f"<!-- {marker}_END -->"
    assert begin in text and end in text, f"sentinels for {marker} missing"
    pre = text.split(begin)[0]
    post = text.split(end)[1]
    text = pre + begin + "\n" + content + "\n" + end + post
    with open(experiments_path, "w") as f:
        f.write(text)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="dryrun_results.json")
    ap.add_argument("--energy-json", default=None,
                    help="fig8 governor JSON: print the per-stage "
                         "idle/active energy breakdown instead")
    ap.add_argument("--reuse-json", default=None,
                    help="fig10 reuse JSON: print the claim verdicts "
                         "(crossover shifts, energy-gap dents) instead")
    ap.add_argument("--trace", default=None,
                    help="exported Chrome trace JSON (fig6_trace_*.json "
                         "or examples/trace_run.py output): print the "
                         "text Gantt summary instead")
    ap.add_argument("--write-experiments", action="store_true")
    args = ap.parse_args(argv)
    if args.trace:
        # lazy import: every other report mode works without PYTHONPATH
        from repro.obs.export import text_summary
        with open(args.trace) as f:
            print(text_summary(json.load(f)))
        return
    if args.reuse_json:
        with open(args.reuse_json) as f:
            print(reuse_verdicts(json.load(f)))
        return
    if args.energy_json:
        with open(args.energy_json) as f:
            print(energy_table(json.load(f)))
        return
    with open(args.json) as f:
        recs = json.load(f)
    dt = dryrun_table(recs)
    rt = roofline_table(recs)
    if args.write_experiments:
        fill(EXPERIMENTS, "DRYRUN", dt)
        fill(EXPERIMENTS, "ROOFLINE", rt)
        print("EXPERIMENTS.md updated")
    else:
        print(dt)
        print()
        print(rt)


if __name__ == "__main__":
    main()
