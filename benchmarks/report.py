"""Render EXPERIMENTS.md tables from dryrun_results.json.

  PYTHONPATH=src python -m benchmarks.report --json dryrun_results.json \
      --write-experiments
"""
from __future__ import annotations

import argparse
import json
import os
import re
from typing import Dict, List

EXPERIMENTS = os.path.join(os.path.dirname(__file__), "..",
                           "EXPERIMENTS.md")


def dryrun_table(recs: List[Dict]) -> str:
    lines = [
        "| arch | shape | mesh | status | args/dev | temp/dev | compile |",
        "|---|---|---|---|---|---|---|",
    ]
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        if r["status"] == "skip":
            lines.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                         f"skip (full attention at 500k) | – | – | – |")
            continue
        # memory_analysis of the partitioned module is per-device already
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['status']} | "
            f"{r['argument_bytes'] / 2**30:.2f} GiB | "
            f"{r['temp_bytes'] / 2**30:.1f} GiB | "
            f"{r['compile_s']:.0f} s |")
    ok = sum(r["status"] == "ok" for r in recs)
    skip = sum(r["status"] == "skip" for r in recs)
    fail = sum(r["status"] == "fail" for r in recs)
    head = (f"**{ok} ok / {skip} skip / {fail} fail** over "
            f"{len(recs)} cells. Bytes are per device "
            f"(arguments = params + optimizer state + inputs; temp = "
            f"compiler scratch).\n\n")
    return head + "\n".join(lines)


def roofline_table(recs: List[Dict]) -> str:
    lines = [
        "| arch | shape | compute_s | memory_s | collective_s | dominant "
        "| useful | next lever |",
        "|---|---|---|---|---|---|---|---|",
    ]
    lever = {
        "compute": "MXU efficiency: fused tiles, fewer f32 upcasts,"
                   " dot-saveable remat",
        "memory": "keep logits/scan state in VMEM (Pallas), fuse norms,"
                  " cut f32 intermediates, kill SPMD remat copies",
        "collective": "reshard (seq-shard KV / local MoE dispatch),"
                      " overlap+compress DP all-reduce",
    }
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"])):
        if r.get("mesh") != "16x16":
            continue
        if r["status"] == "skip":
            lines.append(f"| {r['arch']} | {r['shape']} | – | – | – | skip "
                         f"| – | sub-quadratic attention required |")
            continue
        if "roofline" not in r:
            continue
        t = r["roofline"]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {t['compute_s']:.3f} | "
            f"{t['memory_s']:.3f} | {t['collective_s']:.3f} | "
            f"{t['dominant']} | {t['useful_flops_ratio']:.3f} | "
            f"{lever[t['dominant']][:55]} |")
    return "\n".join(lines)


def fill(experiments_path: str, marker: str, content: str) -> None:
    """Idempotent fill between <!-- MARKER_BEGIN/END --> sentinels."""
    with open(experiments_path) as f:
        text = f.read()
    begin = f"<!-- {marker}_BEGIN -->"
    end = f"<!-- {marker}_END -->"
    assert begin in text and end in text, f"sentinels for {marker} missing"
    pre = text.split(begin)[0]
    post = text.split(end)[1]
    text = pre + begin + "\n" + content + "\n" + end + post
    with open(experiments_path, "w") as f:
        f.write(text)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="dryrun_results.json")
    ap.add_argument("--write-experiments", action="store_true")
    args = ap.parse_args(argv)
    with open(args.json) as f:
        recs = json.load(f)
    dt = dryrun_table(recs)
    rt = roofline_table(recs)
    if args.write_experiments:
        fill(EXPERIMENTS, "DRYRUN", dt)
        fill(EXPERIMENTS, "ROOFLINE", rt)
        print("EXPERIMENTS.md updated")
    else:
        print(dt)
        print()
        print(rt)


if __name__ == "__main__":
    main()
