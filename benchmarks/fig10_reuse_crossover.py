"""Fig 10 (new): shared-prefix KV reuse vs the fig6 crossover and the
idle-power energy gap.

Fig 6 located the load crossover where disaggregation starts beating
colocation on SLO goodput; fig 9 attacked the below-crossover energy gap
with sleep states. This figure asks what *KV reuse* does to both, under
the workload reuse actually targets: RAG-style requests sharing a long
document prefix (``RAGSharedPrefixLengths``). The grid is rate x reuse
mode (none / flat prefix cache / tiered prefix / tiered PIC) x tier
budget (``repro.kvstore.TierSpec``) x setup, with reuse fleets routed by
``prefix-affinity`` so requests land where their prefix is resident.
Tiered cells price every cross-tier page movement through the same
PCIe/DRAM/NVMe paths as the paper's transfer study — the ``tier-fetch``
/ ``tier-spill`` columns are those joules.

Machine-checked claims (asserted here and by CI on the smoke JSON):
  (a) reuse ENGAGES: every reuse cell reports ``reused_tok > 0``, and
      every tiered cell meters nonzero tier-spill joules;
  (b) reuse cuts prefill-stage joules vs the none cell at the same
      (setup, rate) — skipped prefill work is skipped energy;
  (c) whether reuse SHIFTS the fig6 goodput crossover is the headline
      question: ``crossovers`` holds the bisected crossover rate per
      reuse config and ``crossover_shift`` the delta vs none. Either
      direction (or "still no crossover") is reported — reuse relieves
      the prefill stage, which helps the colocated baseline too;
  (d) whether reuse DENTS the below-crossover energy gap:
      ``gap_dent_at`` compares (dis_total_j - co_total_j) with and
      without reuse at each rate; a negative ``dent_j`` means reuse
      narrowed the gap the idle floor opened.

  python -m benchmarks.fig10_reuse_crossover            # full grid
  python -m benchmarks.fig10_reuse_crossover --smoke    # CI: tiny + JSON
"""
from __future__ import annotations

from dataclasses import replace

from repro.core import SLO
from repro.exp import Experiment, ReuseSpec, TierSpec
from repro.exp import run as run_exp
from repro.workload import DEFAULT_INTERACTIVE_SLO, RAGSharedPrefixLengths

from . import common

DEFAULT_SLO = DEFAULT_INTERACTIVE_SLO
CO_SETUP, DIS_SETUPS = "co-2gpus", ("dis-ici", "dis-host")
# RAG shape: a shared document prefix plus a unique per-request tail —
# the workload whose prefill the paper's 16k analysis shape stresses,
# scaled to open-loop interactive rates
PREFIX_LEN, VOCAB = 2048, 512
PAGE = 16

# tier budgets in pages-of-16-tokens: "small" forces constant demotion
# traffic (HBM holds ~1/2 of one shared prefix), "large" keeps the
# working set HBM-resident after warmup
TIERS_SMALL = TierSpec(hbm_pages=64, dram_pages=256, disk_pages=1024)
TIERS_LARGE = TierSpec(hbm_pages=1024, dram_pages=4096, disk_pages=0)

# reuse configs: label -> (ReuseSpec | None). Reuse fleets route with
# prefix-affinity; the none fleet keeps the default router (on a cold
# fleet prefix-affinity IS least-outstanding-tokens byte-for-byte —
# tests/test_kvstore.py — so the comparison isolates reuse itself).
REUSE_CFGS = {
    "none": None,
    "prefix-flat": ReuseSpec(mode="prefix", page_size=PAGE),
    "prefix-tier-s": ReuseSpec(mode="prefix", page_size=PAGE,
                               tiers=TIERS_SMALL),
    "prefix-tier-l": ReuseSpec(mode="prefix", page_size=PAGE,
                               tiers=TIERS_LARGE),
    "pic-tier-s": ReuseSpec(mode="pic", page_size=PAGE,
                            tiers=TIERS_SMALL),
}

HEADER = ["setup", "rate_rps", "reuse", "attainment", "goodput_rps",
          "reused_tok", "prefill_j", "tier_fetch_j", "tier_spill_j",
          "idle_j", "total_j", "j_per_token"]


def _exp(setup, rate, reuse_name, *, arch, n, seed, slo):
    exp = Experiment.open(setup, rate, arch=arch, n=n, seed=seed, slo=slo,
                          lengths=RAGSharedPrefixLengths(
                              prefix_len=PREFIX_LEN),
                          vocab_size=VOCAB)
    reuse = REUSE_CFGS[reuse_name]
    if reuse is not None:
        # fleet-level: per-engine tiered stores + locality-aware routing
        exp = replace(exp, fleet=replace(exp.fleet, reuse=reuse,
                                         router="prefix-affinity"))
    return exp


def _cell(setup, rate, reuse_name, **kw):
    rec = run_exp(_exp(setup, rate, reuse_name, **kw))
    st = rec.energy_by_stage
    return {
        "setup": setup, "rate_rps": rate, "reuse": reuse_name,
        "attainment": round(rec.attainment, 4),
        "goodput_rps": round(rec.goodput_rps, 4),
        "reused_tok": rec.metrics.total_reused_tokens,
        "prefill_j": round(st.get("prefill", 0.0), 2),
        "tier_fetch_j": round(st.get("tier-fetch", 0.0), 4),
        "tier_spill_j": round(st.get("tier-spill", 0.0), 4),
        "idle_j": round(rec.idle_j, 2),
        "total_j": round(rec.total_j, 2),
        "j_per_token": round(rec.joules_per_token, 4),
    }


def _crossover(dis, reuse_name, lo, hi, gp, *, iters):
    """Bisect the rate where ``dis`` goodput overtakes the colocated
    baseline under one reuse config (both sides get the same config —
    the question is what reuse does to the *crossover*, not a reuse
    fleet vs a bare one). None when the sign never changes in [lo, hi]."""
    def diff(rate):
        return gp(dis, rate) - gp(CO_SETUP, rate)
    d_lo, d_hi = diff(lo), diff(hi)
    if d_lo == 0 and d_hi == 0:
        return None
    if (d_lo >= 0) == (d_hi >= 0):
        return None
    for _ in range(iters):
        mid = 0.5 * (lo + hi)
        if (diff(mid) >= 0) == (d_lo >= 0):
            lo = mid
        else:
            hi = mid
    return 0.5 * (lo + hi)


def run(arch: str = common.DEFAULT_ARCH, *, rates=None, n: int = None,
        slo: SLO = DEFAULT_SLO, smoke: bool = False, seed: int = 0,
        out: str = None):
    if rates is None:
        rates = (2.0, 6.0) if smoke else (1.0, 2.0, 4.0, 8.0, 16.0)
    if n is None:
        n = 20 if smoke else 120
    dis_setups = DIS_SETUPS[:1] if smoke else DIS_SETUPS
    reuse_names = (("none", "prefix-flat", "prefix-tier-s") if smoke
                   else tuple(REUSE_CFGS))
    kw = dict(arch=arch, n=n, seed=seed, slo=slo)

    records = []
    for setup in (CO_SETUP,) + dis_setups:
        for rate in rates:
            for reuse_name in reuse_names:
                records.append(_cell(setup, rate, reuse_name, **kw))

    rows = [[r[k] for k in HEADER] for r in records]
    common.print_table("Fig 10: KV reuse vs crossover + energy gap",
                       HEADER, rows)
    common.write_csv("fig10_reuse_crossover.csv", HEADER, rows)

    def cell(setup, rate, reuse_name):
        for r in records:
            if (r["setup"], r["rate_rps"], r["reuse"]) == \
                    (setup, rate, reuse_name):
                return r
        return None

    # (a) reuse engages -------------------------------------------------
    for r in records:
        if r["reuse"] != "none":
            assert r["reused_tok"] > 0, \
                f"reuse never engaged in {r['setup']}@{r['rate_rps']}" \
                f"/{r['reuse']}"
        if "tier" in r["reuse"]:
            assert r["tier_spill_j"] > 0, \
                f"tiered cell metered no spill joules: {r}"

    # (b) reuse cuts prefill-stage joules at fixed (setup, rate) --------
    for setup in (CO_SETUP,) + dis_setups:
        for rate in rates:
            base = cell(setup, rate, "none")
            for reuse_name in reuse_names:
                if reuse_name == "none":
                    continue
                r = cell(setup, rate, reuse_name)
                assert r["prefill_j"] < base["prefill_j"], \
                    (f"{reuse_name} did not cut prefill joules at "
                     f"{setup}@{rate}: {r['prefill_j']} vs "
                     f"{base['prefill_j']}")

    # (c) the crossover, per reuse config -------------------------------
    lo, hi = min(rates), max(rates)
    iters = 2 if smoke else 5
    gp_cache = {(r["setup"], r["rate_rps"], r["reuse"]): r["goodput_rps"]
                for r in records}
    crossovers = {}
    for reuse_name in reuse_names:
        def gp(setup, rate, _rn=reuse_name):
            key = (setup, rate, _rn)
            if key not in gp_cache:
                gp_cache[key] = _cell(setup, rate, _rn, **kw)["goodput_rps"]
            return gp_cache[key]
        per_dis = {}
        for dis in dis_setups:
            c = _crossover(dis, reuse_name, lo, hi, gp, iters=iters)
            per_dis[dis] = None if c is None else round(c, 3)
        crossovers[reuse_name] = per_dis

    shift = {}
    for reuse_name in reuse_names:
        if reuse_name == "none":
            continue
        per_dis = {}
        for dis in dis_setups:
            c0, c1 = crossovers["none"][dis], crossovers[reuse_name][dis]
            per_dis[dis] = (None if c0 is None or c1 is None
                            else round(c1 - c0, 3))
        shift[reuse_name] = per_dis
    for reuse_name, per_dis in crossovers.items():
        for dis, c in per_dis.items():
            print(f"crossover[{reuse_name}] {dis} vs {CO_SETUP}: "
                  f"{'none in range' if c is None else f'~{c} req/s'}")

    # (d) the below-crossover energy gap, with vs without reuse ---------
    gap_dent = []
    for dis in dis_setups:
        for rate in rates:
            base_gap = (cell(dis, rate, "none")["total_j"]
                        - cell(CO_SETUP, rate, "none")["total_j"])
            for reuse_name in reuse_names:
                if reuse_name == "none":
                    continue
                gap = (cell(dis, rate, reuse_name)["total_j"]
                       - cell(CO_SETUP, rate, reuse_name)["total_j"])
                gap_dent.append({
                    "dis": dis, "rate_rps": rate, "reuse": reuse_name,
                    "gap_none_j": round(base_gap, 2),
                    "gap_reuse_j": round(gap, 2),
                    "dent_j": round(gap - base_gap, 2)})
    dented = [g for g in gap_dent if g["dent_j"] < 0]
    for g in gap_dent:
        print(f"gap[{g['dis']}@{g['rate_rps']}/{g['reuse']}]: "
              f"{g['gap_none_j']:+.0f} J -> {g['gap_reuse_j']:+.0f} J "
              f"({g['dent_j']:+.0f} J)")

    payload = {
        "arch": arch, "n_requests": n, "seed": seed,
        "slo": {"ttft_s": slo.ttft_s, "tpot_s": slo.tpot_s},
        "rates_rps": list(rates),
        "prefix_len": PREFIX_LEN, "vocab_size": VOCAB,
        "setups": {"co": CO_SETUP, "dis": list(dis_setups)},
        "reuse_configs": {k: (None if v is None else v.encode())
                          for k, v in REUSE_CFGS.items()
                          if k in reuse_names},
        "points": records,
        "claims": {
            "reuse_engaged": True,          # asserted above
            "prefill_j_cut_by_reuse": True,  # asserted above
            "crossovers": crossovers,
            "crossover_shift": shift,
            "gap_dent_at": gap_dent,
            "gap_dented_anywhere": bool(dented),
        },
    }
    common.write_json(payload, "fig10_reuse_crossover.json", out=out)
    return payload


def main(argv=None):
    ap = common.open_loop_arg_parser(__doc__)
    ap.add_argument("--ttft-slo", type=float, default=DEFAULT_SLO.ttft_s)
    ap.add_argument("--tpot-slo", type=float, default=DEFAULT_SLO.tpot_s)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None,
                    help="JSON output path (default benchmarks/out/)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny grid for CI; emits the same JSON artifact")
    ap.set_defaults(requests=None)   # distinguish unset from explicit
    args = ap.parse_args(argv)
    run(args.arch, rates=args.rate, n=args.requests,
        slo=SLO(ttft_s=args.ttft_slo, tpot_s=args.tpot_slo),
        smoke=args.smoke, seed=args.seed, out=args.out)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
