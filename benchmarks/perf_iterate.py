"""Perf-iteration harness: hypothesis -> change -> re-lower -> compare.

Runs one (arch, shape) cell at the baseline and under a set of named
optimization flags (repro.dist.opt_flags), printing the roofline terms
side by side. Each invocation is one row of the EXPERIMENTS.md section
Perf log.

  PYTHONPATH=src python -m benchmarks.perf_iterate \
      --arch qwen3-1.7b --shape decode_32k --opt seq_shard_kv
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
from typing import Dict, Optional


def run_cell(arch: str, shape: str, opt: str = "",
             multi_pod: bool = False) -> Dict:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    if opt:
        env["REPRO_OPT"] = opt
    else:
        env.pop("REPRO_OPT", None)
    code = (
        "import json\n"
        "from repro.launch.dryrun import run_cell\n"
        f"rec = run_cell({arch!r}, {shape!r}, {multi_pod}, verbose=False)\n"
        "rec.pop('traceback', None)\n"
        "print('REC:' + json.dumps(rec))\n")
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=3600)
    if proc.returncode != 0:
        return {"status": "fail", "error": proc.stderr[-1500:]}
    line = [l for l in proc.stdout.splitlines() if l.startswith("REC:")][0]
    return json.loads(line[4:])


def _fmt(rec: Dict) -> str:
    if rec.get("status") != "ok":
        return f"FAIL: {rec.get('error', '?')[:200]}"
    r = rec["roofline"]
    return (f"compute={r['compute_s']:.4f}s memory={r['memory_s']:.4f}s "
            f"collective={r['collective_s']:.4f}s dom={r['dominant']} "
            f"useful={r['useful_flops_ratio']:.3f} "
            f"step={r['step_time_s']:.4f}s")


def compare(arch: str, shape: str, opt: str,
            baseline: Optional[Dict] = None) -> Dict:
    base = baseline or run_cell(arch, shape)
    tuned = run_cell(arch, shape, opt)
    print(f"cell: {arch} x {shape}")
    print(f"  baseline        : {_fmt(base)}")
    print(f"  +{opt:15s}: {_fmt(tuned)}")
    if base.get("status") == "ok" and tuned.get("status") == "ok":
        b, t = base["roofline"], tuned["roofline"]
        for term in ("compute_s", "memory_s", "collective_s",
                     "step_time_s"):
            if b[term] > 0:
                print(f"  {term:13s}: {b[term]:.4f} -> {t[term]:.4f}  "
                      f"({(1 - t[term] / b[term]) * 100:+.1f}% reduction)")
    return {"baseline": base, "tuned": tuned}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--opt", required=True,
                    help="comma-separated flag set to test")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)
    res = compare(args.arch, args.shape, args.opt)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(res, f, indent=1)


if __name__ == "__main__":
    main()
