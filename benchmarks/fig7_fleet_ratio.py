"""Fig 7 (new): goodput-optimal P:D ratio vs offered load and KV medium.

The fleet-scale version of the paper's central caveat: once a serving
pool has more than one instance per stage, the P:D instance *ratio*
joins load and medium as a first-order knob (P/D-Serve, FlowKV). Sweep
xP:yD shapes at a fixed instance budget x offered Poisson rate x KV
medium, score each cell with DistServe-style SLO goodput, and report
the goodput-optimal ratio per (medium, rate). A capacity check also
bisects ``max_goodput_rate`` for 1P:1D vs 2P:2D over ici — doubling
both stages must strictly raise the sustainable rate (the fleet's
scaling sanity bar, asserted by CI on the smoke JSON).

  python -m benchmarks.fig7_fleet_ratio            # full grid
  python -m benchmarks.fig7_fleet_ratio --smoke    # CI: tiny grid + JSON
"""
from __future__ import annotations

from repro.configs import get_config
from repro.core import SLO
from repro.fleet import FleetSpec
from repro.workload import (DEFAULT_INTERACTIVE_SLO, RatePoint,
                            max_goodput_rate, rate_grid)

from . import common

DEFAULT_SLO = DEFAULT_INTERACTIVE_SLO
# the fixed-budget ratio family (4 instances) plus the minimal fleet
RATIO_SHAPES = ((1, 3), (2, 2), (3, 1))
CAPACITY_SHAPES = ((1, 1), (2, 2))


def run(arch: str = common.DEFAULT_ARCH, *, rates=None,
        n: int = common.OPEN_LOOP_N,
        slo: SLO = DEFAULT_SLO, smoke: bool = False, seed: int = 0):
    cfg = get_config(arch)
    media = ("ici",) if smoke else ("ici", "host", "disk")
    if rates is None:
        rates = (4.0, 8.0) if smoke else (2.0, 4.0, 8.0, 16.0)

    # ratio grid: P:D shape x rate x medium, scored by SLO goodput ------
    specs = [FleetSpec.disaggregated(x, y, medium=m)
             for m in media for (x, y) in RATIO_SHAPES]
    points = rate_grid(cfg, rates, setups=specs, slo=slo, n=n, seed=seed)
    rows = [p.as_row() for p in points]
    common.print_table("Fig 7: SLO goodput by P:D ratio x load x medium",
                       RatePoint.ROW_HEADER, rows)
    common.write_csv("fig7_fleet_ratio.csv", RatePoint.ROW_HEADER, rows)

    by_cell = {(p.setup, p.rate): p.goodput_rps for p in points}
    optimal = {}
    for m in media:
        labels = [FleetSpec.disaggregated(x, y, medium=m).name
                  for (x, y) in RATIO_SHAPES]
        optimal[m] = {
            rate: max(labels, key=lambda s: by_cell[(s, rate)])
            for rate in rates}
        for rate, best in optimal[m].items():
            print(f"{m} @ {rate} req/s: goodput-optimal ratio {best}")

    # capacity check: 2P:2D must sustain strictly more than 1P:1D ------
    def probe_cap(shape, hi):
        spec = FleetSpec.disaggregated(*shape, medium="ici")
        return spec.name, max_goodput_rate(
            spec, cfg, slo=slo, lo=1.0, hi=hi,
            max_iters=6 if smoke else 10, rel_tol=0.1, n=n, seed=seed)

    # max_goodput_rate returns hi when hi still attains: a bracket
    # ceiling, not a measurement. A saturated BASELINE would make the
    # scaling comparison ceiling-vs-ceiling, so widen until the 1P:1D
    # number resolves; a saturated 2P:2D is fine (true cap >= ceiling
    # > the resolved baseline) and is flagged in the JSON.
    cap_hi = 64.0
    while True:
        base_name, base_cap = probe_cap(CAPACITY_SHAPES[0], cap_hi)
        if base_cap < cap_hi or cap_hi >= 1024.0:
            break
        cap_hi *= 2.0
    big_name, big_cap = probe_cap(CAPACITY_SHAPES[1], cap_hi)
    caps = {base_name: base_cap, big_name: big_cap}
    saturated = {name: bool(cap >= cap_hi) for name, cap in caps.items()}
    lo_cap, hi_cap = caps["1P1D-ici"], caps["2P2D-ici"]

    def fmt(name):
        return f"{'>=' if saturated[name] else ''}{caps[name]:.2f}"
    print(f"capacity: 1P1D-ici {fmt('1P1D-ici')} req/s, "
          f"2P2D-ici {fmt('2P2D-ici')} req/s "
          f"({'OK' if hi_cap > lo_cap else 'FLEET DOES NOT SCALE'})")

    payload = {
        "arch": arch, "n_requests": n, "seed": seed,
        "slo": {"ttft_s": slo.ttft_s, "tpot_s": slo.tpot_s},
        "media": list(media), "rates_rps": list(rates),
        "shapes": [f"{x}P{y}D" for (x, y) in RATIO_SHAPES],
        "points": [dict(zip(RatePoint.ROW_HEADER, r)) for r in rows],
        "optimal_ratio": optimal,
        "capacity": {
            "max_goodput_rate": caps,
            "bracket_saturated": saturated,   # value == probe hi bound
            "fleet_scales": bool(hi_cap > lo_cap),
        },
    }
    common.write_json(payload, "fig7_fleet_ratio.json")
    return payload


def main(argv=None):
    ap = common.open_loop_arg_parser(__doc__)
    ap.add_argument("--ttft-slo", type=float, default=DEFAULT_SLO.ttft_s)
    ap.add_argument("--tpot-slo", type=float, default=DEFAULT_SLO.tpot_s)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny grid for CI; emits the same JSON artifact")
    args = ap.parse_args(argv)
    n = args.requests
    if args.smoke and n == common.OPEN_LOOP_N:
        n = 16          # smaller smoke default unless --requests given
    run(args.arch, rates=args.rate, n=n,
        slo=SLO(ttft_s=args.ttft_slo, tpot_s=args.tpot_slo),
        smoke=args.smoke, seed=args.seed)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
