"""Fig 8 (new): online DVFS governors vs the static frequency frontier.

The paper's energy experiment is an *offline* grid — one phi per run
(fig5). This figure asks the question a deployment would: can an
*online* governor (repro.govern) reach a better (energy, goodput) point
than the best static frequency, and does adaptive stage-wise scaling
finally let disaggregation save energy? Method: for every setup x
offered rate, sweep the static phi grid on one open-loop workload (the
static Pareto frontier, fig5's open-loop twin), then run each governor
on the identical workload and overlay its realized point.

Reproduced conclusions (asserted by CI on the smoke JSON):
  (a) adaptivity works — at some rate the SLO-slack governor on dis-ici
      meets the SLO with energy <= the best *attaining* static
      colocated point (static-oracle parity without the oracle);
  (b) the paper's negative result survives adaptive DVFS — below the
      load crossover the cheapest attaining dis configuration, governed
      or static, still burns more energy than the colocated one, and at
      every matched (setup, rate, phi=1.0) pair the dis run carries
      strictly more idle-state joules: the gap is an idle-power floor,
      which no frequency policy can scale away (frequency only moves
      the ACTIVE term; the floor is static draw over accelerator-
      seconds, and disaggregation holds more of them idle).

  python -m benchmarks.fig8_governor_pareto            # full grid
  python -m benchmarks.fig8_governor_pareto --smoke    # CI: tiny + JSON
"""
from __future__ import annotations

from repro.core import SLO
from repro.exp import Experiment
from repro.exp import run as run_exp
from repro.workload import DEFAULT_INTERACTIVE_SLO

from . import common

DEFAULT_SLO = DEFAULT_INTERACTIVE_SLO
GOVERNORS = ("queue-depth", "slo-slack")
TARGET_ATTAINMENT = 0.9

HEADER = ["setup", "rate_rps", "policy", "attainment", "goodput_rps",
          "total_j", "active_j", "idle_j", "j_per_token", "decisions"]


def _cell(setup, arch, rate, *, slo, n, seed, phi=None, governor=None):
    """One (setup, rate, policy) cell through ``repro.exp``: metrics +
    the energy state split the governor experiments are about."""
    exp = Experiment.open(setup, rate, arch=arch, n=n, seed=seed, slo=slo)
    if phi is not None:
        exp = exp.with_phi(phi=phi)
    if governor is not None:
        exp = exp.with_governor(governor)
    rec = run_exp(exp)
    idle_j = rec.idle_j
    return {
        "setup": setup, "rate_rps": rate,
        "attainment": round(rec.attainment, 4),
        "goodput_rps": round(rec.goodput_rps, 4),
        "total_j": round(rec.total_j, 2),
        "active_j": round(rec.total_j - idle_j, 2),
        "idle_j": round(idle_j, 2),
        "j_per_token": round(rec.joules_per_token, 4),
        "decisions": rec.governor_decisions,
        "by_stage": {k: round(v, 2)
                     for k, v in sorted(rec.energy_by_stage.items())},
    }


def _frontier(static_pts):
    """Non-dominated (higher goodput, lower energy) static points."""
    pts = sorted(static_pts, key=lambda p: (-p["goodput_rps"],
                                            p["total_j"]))
    front, best_e = [], float("inf")
    for p in pts:
        if p["total_j"] < best_e:
            front.append(p)
            best_e = p["total_j"]
    return front


def run(arch: str = common.DEFAULT_ARCH, *, rates=None, n: int = None,
        slo: SLO = DEFAULT_SLO, smoke: bool = False, seed: int = 0,
        out: str = None):
    if rates is None:
        rates = (2.0, 3.0) if smoke else (1.0, 2.0, 3.0, 4.0, 6.0)
    if n is None:      # None = unset, so --smoke --requests 24 honors 24
        n = 16 if smoke else common.OPEN_LOOP_N
    setups = ("co-2gpus", "dis-ici") if smoke else \
        ("co-2gpus", "dis-ici", "dis-host")
    phi_grid = (0.42, 0.58, 0.74, 1.0) if smoke else \
        (0.26, 0.42, 0.58, 0.74, 0.9, 1.0)

    rows, records = [], []
    for setup in setups:
        for rate in rates:
            for phi in phi_grid:
                rec = _cell(setup, arch, rate, slo=slo, n=n, seed=seed,
                            phi=phi)
                rec["policy"] = f"static-{phi}"
                records.append(rec)
            for gov in GOVERNORS:
                rec = _cell(setup, arch, rate, slo=slo, n=n, seed=seed,
                            governor=gov)
                rec["policy"] = gov
                records.append(rec)
    for r in records:
        rows.append([r[k] for k in HEADER])
    common.print_table("Fig 8: governor vs static-frequency frontier",
                       HEADER, rows)
    common.write_csv("fig8_governor_pareto.csv", HEADER, rows)

    def cells(setup, rate, pred=lambda r: True):
        return [r for r in records
                if r["setup"] == setup and r["rate_rps"] == rate
                and pred(r)]

    def is_static(r):
        return r["policy"].startswith("static-")

    def attains(r):
        return r["attainment"] >= TARGET_ATTAINMENT

    # (a) adaptivity: SLO-slack on dis-ici vs the best ATTAINING static
    # colocated point at the same offered rate -------------------------
    adaptive_wins = []
    for rate in rates:
        co_static = [r for r in cells("co-2gpus", rate, is_static)
                     if attains(r)]
        gov = [r for r in cells("dis-ici", rate)
               if r["policy"] == "slo-slack" and attains(r)]
        if not co_static or not gov:
            continue
        best_co = min(r["total_j"] for r in co_static)
        if gov[0]["total_j"] <= best_co:
            adaptive_wins.append({"rate_rps": rate,
                                  "governor_j": gov[0]["total_j"],
                                  "best_static_co_j": best_co})
    for w in adaptive_wins:
        print(f"slo-slack(dis-ici) @ {w['rate_rps']} req/s: "
              f"{w['governor_j']:.0f} J <= best attaining static "
              f"co-2gpus point {w['best_static_co_j']:.0f} J")

    # (b) the idle floor: cheapest ATTAINING dis config (static or
    # governed) vs cheapest attaining co config, per rate --------------
    gaps = {}
    for rate in rates:
        co = [r for r in cells("co-2gpus", rate) if attains(r)]
        dis = [r for r in cells("dis-ici", rate) if attains(r)]
        if co and dis:
            gaps[rate] = round(min(r["total_j"] for r in dis)
                               - min(r["total_j"] for r in co), 2)
            print(f"dis-vs-co energy gap @ {rate} req/s (best attaining "
                  f"each): {gaps[rate]:+.0f} J")
    # mechanism: at matched phi=1.0 the dis run holds more idle joules
    idle_excess = {}
    for rate in rates:
        co = cells("co-2gpus", rate, lambda r: r["policy"] == "static-1.0")
        dis = cells("dis-ici", rate, lambda r: r["policy"] == "static-1.0")
        if co and dis:
            idle_excess[rate] = round(dis[0]["idle_j"] - co[0]["idle_j"], 2)

    payload = {
        "arch": arch, "n_requests": n, "seed": seed,
        "slo": {"ttft_s": slo.ttft_s, "tpot_s": slo.tpot_s},
        "rates_rps": list(rates), "setups": list(setups),
        "phi_grid": list(phi_grid), "governors": list(GOVERNORS),
        "target_attainment": TARGET_ATTAINMENT,
        "points": records,
        "static_frontier": {
            s: {str(rate): _frontier(cells(s, rate, is_static))
                for rate in rates} for s in setups},
        "adaptive_beats_static_co_at": adaptive_wins,
        "idle_floor": {
            "dis_minus_co_best_attaining_j": gaps,
            "dis_minus_co_idle_j_at_phi1": idle_excess,
            "gap_positive_at": [r for r, g in gaps.items() if g > 0],
        },
    }
    common.write_json(payload, "fig8_governor_pareto.json", out=out)
    return payload


def main(argv=None):
    ap = common.open_loop_arg_parser(__doc__)
    ap.add_argument("--ttft-slo", type=float, default=DEFAULT_SLO.ttft_s)
    ap.add_argument("--tpot-slo", type=float, default=DEFAULT_SLO.tpot_s)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None,
                    help="JSON output path (default benchmarks/out/)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny grid for CI; emits the same JSON artifact")
    ap.set_defaults(requests=None)   # distinguish unset from explicit
    args = ap.parse_args(argv)
    run(args.arch, rates=args.rate, n=args.requests,
        slo=SLO(ttft_s=args.ttft_slo, tpot_s=args.tpot_slo),
        smoke=args.smoke, seed=args.seed, out=args.out)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
