"""Fig 9 (new): adaptive fleets vs the idle-power floor.

Fig8 ended on the paper's surviving negative result: below the load
crossover, disaggregation burns more energy than colocation because its
extra accelerators sit idle — a floor no DVFS policy can scale away
(frequency only moves the ACTIVE term). This figure attacks the floor
directly with the ``repro.fleet.controller`` layer: online autoscaling
(scale-to-zero via the ``sleep`` power state), prefill<->decode role
flipping as the goodput-optimal P:D ratio drifts, and wake-latency-
priced re-provisioning — under the traffic shapes autoscaling papers
target (diurnal NHPP valleys, bursty gamma arrivals) at 10-100x the
rates the static figures sweep.

Reproduced/established conclusions (asserted by CI on the smoke JSON):
  (a) the adaptive controller on the disaggregated fleet saves total
      energy vs the same static fleet at matched SLO attainment, on at
      least one traffic x rate cell (``adaptive_saves_energy_at`` is
      non-empty) — scale-to-zero converts idle joules into sleep joules;
  (b) whether that closes the dis-vs-co gap is the headline question:
      ``gap_closed_at`` lists the cells where the adaptive dis fleet
      reaches or beats the colocated fleet's total energy. Either
      outcome is reported (an empty list means the floor survives even
      sleep states at those rates — the honest negative result).

  python -m benchmarks.fig9_adaptive_fleet            # full grid
  python -m benchmarks.fig9_adaptive_fleet --smoke    # CI: tiny + JSON
"""
from __future__ import annotations

from repro.core import SLO
from repro.exp import Experiment
from repro.exp import run as run_exp
from repro.fleet import ControllerSpec
from repro.workload import DEFAULT_INTERACTIVE_SLO, PaperFixedLengths

from . import common

DEFAULT_SLO = DEFAULT_INTERACTIVE_SLO
# interactive-scale shape (chatbot-ish), not the 16k analysis shape:
# the 10-100x rates only exist for requests this size
INPUT_LEN, OUTPUT_LEN = 1024, 128
CO_SETUP, DIS_SETUP = "co-4", "4P4D-ici"
TARGET_ATTAINMENT = 0.9
# matched-SLO comparison tolerance: adaptive must attain within this of
# the static run it is judged against (sleep/wake latency may cost a
# request or two at the margin without voiding the energy comparison)
ATTAINMENT_SLACK = 0.05

HEADER = ["traffic", "rate_rps", "setup", "policy", "attainment",
          "goodput_rps", "total_j", "active_j", "idle_j", "sleep_j",
          "j_per_token", "actions"]

# the controller under test: scale-to-zero quickly (the diurnal trough
# is short at benchmark scale), start from the minimal 1P+1D footprint,
# flip roles freely, target the shared interactive TTFT
ADAPTIVE = ControllerSpec(policy="adaptive", interval_s=0.1,
                          sleep_after_s=0.3, wake_latency_s=0.5,
                          initial_awake_prefill=1, initial_awake_decode=1,
                          target_ttft_s=DEFAULT_SLO.ttft_s)

TRAFFIC = {
    # raised-cosine day/night cycle: deep valleys where a static fleet
    # burns its idle floor and an adaptive one sleeps
    "diurnal": ("diurnal", {"period_s": 4.0, "floor": 0.1}),
    # heavy-tailed bursts (cv=4): long quiet gaps between clumps
    "bursty": ("gamma", {"cv": 4.0}),
}


def _cell(setup, arch, traffic, rate, *, slo, n, seed, controller=None):
    arrival, arrival_kw = TRAFFIC[traffic]
    exp = Experiment.open(setup, rate, arch=arch, n=n, seed=seed, slo=slo,
                          arrival=arrival, arrival_kw=arrival_kw,
                          lengths=PaperFixedLengths(INPUT_LEN, OUTPUT_LEN))
    if controller is not None:
        exp = exp.with_controller(controller)
    rec = run_exp(exp)
    by_stage = rec.energy_by_stage
    return {
        "traffic": traffic, "rate_rps": rate, "setup": setup,
        "attainment": round(rec.attainment, 4),
        "goodput_rps": round(rec.goodput_rps, 4),
        "total_j": round(rec.total_j, 2),
        "active_j": round(rec.total_j - rec.idle_j
                          - by_stage.get("sleep", 0.0), 2),
        "idle_j": round(rec.idle_j, 2),
        "sleep_j": round(by_stage.get("sleep", 0.0), 2),
        "j_per_token": round(rec.joules_per_token, 4),
        "actions": rec.controller_actions,
        "by_stage": {k: round(v, 2) for k, v in sorted(by_stage.items())},
    }


def run(arch: str = common.DEFAULT_ARCH, *, rates=None, n: int = None,
        slo: SLO = DEFAULT_SLO, smoke: bool = False, seed: int = 0,
        out: str = None):
    # "rate" is the PEAK rate for diurnal (nominal = peak*(1+floor)/2)
    # and the mean rate for bursty gamma; 10-100x fig8's 1-6 req/s grid
    if rates is None:
        rates = (20.0,) if smoke else (10.0, 20.0, 40.0, 80.0)
    if n is None:
        n = 60 if smoke else 400
    traffics = ("diurnal",) if smoke else tuple(TRAFFIC)

    records = []
    for traffic in traffics:
        for rate in rates:
            rec = _cell(CO_SETUP, arch, traffic, rate, slo=slo, n=n,
                        seed=seed)
            rec["policy"] = "static"
            records.append(rec)
            rec = _cell(DIS_SETUP, arch, traffic, rate, slo=slo, n=n,
                        seed=seed)
            rec["policy"] = "static"
            records.append(rec)
            rec = _cell(DIS_SETUP, arch, traffic, rate, slo=slo, n=n,
                        seed=seed, controller=ADAPTIVE)
            rec["policy"] = "adaptive"
            records.append(rec)

    rows = [[r[k] for k in HEADER] for r in records]
    common.print_table("Fig 9: adaptive fleet vs the idle-power floor",
                       HEADER, rows)
    common.write_csv("fig9_adaptive_fleet.csv", HEADER, rows)

    def cell(traffic, rate, setup, policy):
        for r in records:
            if (r["traffic"], r["rate_rps"], r["setup"],
                    r["policy"]) == (traffic, rate, setup, policy):
                return r
        return None

    # (a) adaptive vs static on the SAME dis fleet: energy down at
    # matched attainment ------------------------------------------------
    saves = []
    for traffic in traffics:
        for rate in rates:
            st = cell(traffic, rate, DIS_SETUP, "static")
            ad = cell(traffic, rate, DIS_SETUP, "adaptive")
            if (ad["attainment"] >= st["attainment"] - ATTAINMENT_SLACK
                    and ad["total_j"] < st["total_j"]):
                saves.append({
                    "traffic": traffic, "rate_rps": rate,
                    "adaptive_j": ad["total_j"],
                    "static_j": st["total_j"],
                    "saved_frac": round(1 - ad["total_j"]
                                        / st["total_j"], 4)})
    for s in saves:
        print(f"adaptive({DIS_SETUP}) @ {s['traffic']}/{s['rate_rps']} "
              f"req/s: {s['adaptive_j']:.0f} J vs static "
              f"{s['static_j']:.0f} J ({100 * s['saved_frac']:.1f}% "
              f"saved at matched attainment)")

    # (b) the headline: does sleeping + flipping close the dis-vs-co
    # gap? ---------------------------------------------------------------
    gap_closed, gap_open = [], []
    for traffic in traffics:
        for rate in rates:
            co = cell(traffic, rate, CO_SETUP, "static")
            ad = cell(traffic, rate, DIS_SETUP, "adaptive")
            entry = {"traffic": traffic, "rate_rps": rate,
                     "adaptive_dis_j": ad["total_j"],
                     "static_co_j": co["total_j"],
                     "gap_j": round(ad["total_j"] - co["total_j"], 2)}
            if (ad["attainment"] >= co["attainment"] - ATTAINMENT_SLACK
                    and ad["total_j"] <= co["total_j"]):
                gap_closed.append(entry)
            else:
                gap_open.append(entry)
    for e in gap_closed:
        print(f"gap CLOSED @ {e['traffic']}/{e['rate_rps']} req/s: "
              f"adaptive dis {e['adaptive_dis_j']:.0f} J <= co "
              f"{e['static_co_j']:.0f} J")
    for e in gap_open:
        print(f"gap open @ {e['traffic']}/{e['rate_rps']} req/s: "
              f"adaptive dis {e['adaptive_dis_j']:.0f} J vs co "
              f"{e['static_co_j']:.0f} J ({e['gap_j']:+.0f} J)")

    payload = {
        "arch": arch, "n_requests": n, "seed": seed,
        "slo": {"ttft_s": slo.ttft_s, "tpot_s": slo.tpot_s},
        "rates_rps": list(rates), "traffics": list(traffics),
        "setups": {"co": CO_SETUP, "dis": DIS_SETUP},
        "input_len": INPUT_LEN, "output_len": OUTPUT_LEN,
        "controller": {"policy": ADAPTIVE.policy,
                       "interval_s": ADAPTIVE.interval_s,
                       "sleep_after_s": ADAPTIVE.sleep_after_s,
                       "wake_latency_s": ADAPTIVE.wake_latency_s},
        "attainment_slack": ATTAINMENT_SLACK,
        "points": records,
        "adaptive_saves_energy_at": saves,
        "gap_closed_at": gap_closed,
        "gap_open_at": gap_open,
    }
    common.write_json(payload, "fig9_adaptive_fleet.json", out=out)
    return payload


def main(argv=None):
    ap = common.open_loop_arg_parser(__doc__)
    ap.add_argument("--ttft-slo", type=float, default=DEFAULT_SLO.ttft_s)
    ap.add_argument("--tpot-slo", type=float, default=DEFAULT_SLO.tpot_s)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None,
                    help="JSON output path (default benchmarks/out/)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny grid for CI; emits the same JSON artifact")
    ap.set_defaults(requests=None)   # distinguish unset from explicit
    args = ap.parse_args(argv)
    run(args.arch, rates=args.rate, n=args.requests,
        slo=SLO(ttft_s=args.ttft_slo, tpot_s=args.tpot_slo),
        smoke=args.smoke, seed=args.seed, out=args.out)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
