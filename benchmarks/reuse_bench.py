"""KV-reuse benchmark (paper section II-C): prefix matching vs PIC on a
RAG-style workload — every request shares an 8k-token document; prompts
differ in their opening tokens, so plain prefix matching whiffs while PIC
reuses the shared block (CacheBlend-style selective recompute).

Each mode is one ``repro.exp`` Experiment: the displaced shared document
is part of the ``ClosedLoop`` spec (``rag_doc_len``/``rag_doc_offset``)
and the cache configuration a ``ReuseSpec`` — so all three cells are
content-addressed and memoized like every other figure.

  PYTHONPATH=src python -m benchmarks.reuse_bench
"""
from __future__ import annotations

from repro.exp import ClosedLoop, Experiment, ReuseSpec
from repro.exp import run as run_exp
from . import common

VOCAB = 128_256
SHARED = 8_192


def _exp(mode: str, batch: int, arch: str) -> Experiment:
    """Shared document in the MIDDLE of each prompt (openings differ)."""
    return Experiment(
        arch=arch, fleet="co-2gpus",
        workload=ClosedLoop(batch=batch, input_len=16_384, output_len=256,
                            vocab_size=VOCAB, rag_doc_len=SHARED,
                            rag_doc_offset=1024),
        reuse=None if mode == "none" else ReuseSpec(
            mode=mode, capacity_pages=200_000, page_size=16,
            recompute_frac=0.15))


def run(batch: int = 16, arch: str = common.DEFAULT_ARCH):
    header = ["reuse", "median_ttft_s", "prefill_tput_tok_s",
              "reused_tokens", "joules_per_token"]
    rows = []
    for mode in ("none", "prefix", "pic"):
        rec = run_exp(_exp(mode, batch, arch))
        m = rec.metrics
        rows.append([mode, round(m.median_ttft_s, 3),
                     round(m.prefill_throughput_tok_s, 0),
                     m.total_reused_tokens,
                     round(rec.joules_per_token, 5)])
    common.print_table(
        "KV reuse (RAG workload, shared 8k doc, displaced)", header, rows)
    common.write_csv("reuse_bench.csv", header, rows)
    return rows


if __name__ == "__main__":
    run()
