"""KV-reuse benchmark (paper section II-C): prefix matching vs PIC on a
RAG-style workload — every request shares an 8k-token document; prompts
differ in their opening tokens, so plain prefix matching whiffs while PIC
reuses the shared block (CacheBlend-style selective recompute).

  PYTHONPATH=src python -m benchmarks.reuse_bench
"""
from __future__ import annotations

import numpy as np

from repro.configs import get_config
from repro.core import Cluster, random_workload
from repro.core.prefix_cache import PrefixCache
from . import common


def _rag_workload(batch, input_len=16_384, shared=8_192, vocab=128_256,
                  seed=0):
    """Shared document in the MIDDLE of each prompt (openings differ)."""
    rng = np.random.default_rng(seed)
    doc = rng.integers(0, vocab, shared)
    reqs = random_workload(batch, input_len=input_len, output_len=256,
                           vocab_size=vocab, seed=seed)
    for r in reqs:
        r.prompt_tokens[1024:1024 + shared] = doc   # displaced content
    return reqs


def run(batch: int = 16):
    cfg = get_config(common.ARCH)
    header = ["reuse", "median_ttft_s", "prefill_tput_tok_s",
              "reused_tokens", "joules_per_token"]
    rows = []
    for mode in ("none", "prefix", "pic"):
        cache = None
        reqs = _rag_workload(batch)
        if mode != "none":
            cache = PrefixCache(capacity_pages=200_000, page_size=16,
                                pic=(mode == "pic"), recompute_frac=0.15)
            # warm cache: a prior request already served the shared doc
            cache.insert(reqs[0].prompt_tokens)
        cluster = Cluster("co-2gpus", cfg)
        if cache is not None:
            for e in cluster.engines:
                e.prefix_cache = cache
        res = cluster.run(reqs)
        m = res.metrics
        reused = sum(r.reused_tokens for r in res.requests)
        rows.append([mode, round(m.median_ttft_s, 3),
                     round(m.prefill_throughput_tok_s, 0), reused,
                     round(res.joules_per_token, 5)])
    common.print_table(
        "KV reuse (RAG workload, shared 8k doc, displaced)", header, rows)
    common.write_csv("reuse_bench.csv", header, rows)
    return rows


if __name__ == "__main__":
    run()
