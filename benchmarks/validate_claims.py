"""Validate the reproduction against the paper's own claims (F1-F6,
DESIGN.md section 1). Run as part of ``python -m benchmarks.run``; every
check prints PASS/FAIL and the module exits nonzero on any FAIL.

Every probe is a ``repro.exp`` cell served from the shared result
cache, so claims re-validate for free after the figures have run.
"""
from __future__ import annotations

from repro.core import SETUPS
from repro.exp import Grid, run_grid
from . import common

CHECKS = []


def check(name):
    def deco(fn):
        CHECKS.append((name, fn))
        return fn
    return deco


@check("F1: co-2gpus achieves the best median TTFT while its KV pool "
       "capacity is not the binding constraint (batch <= 48)")
def f1(batches):
    # At batch 64 (32 seqs/accelerator = 60 GB prompt KV vs the 28 GB
    # pool) the capacity ceiling binds: half the sequences physically
    # cannot hold KV until wave 1 drains, so colocated TTFT inverts
    # against the streaming disaggregated prefill engine. The paper's
    # broader claim ("benefits depend on request load") is exactly this
    # mechanism; the divergence at 64 is documented in EXPERIMENTS.md.
    for bs in [b for b in batches if b <= 48]:
        co2 = common.run_point("co-2gpus", bs).metrics.median_ttft_s
        for s in SETUPS:
            if s == "co-2gpus":
                continue
            other = common.run_point(s, bs).metrics.median_ttft_s
            assert co2 <= other + 1e-9, \
                f"bs={bs}: {s} TTFT {other:.3f} < co-2gpus {co2:.3f}"


@check("F2: colocated TPOT cliffs at batch>=32 (eviction+recompute); "
       "disaggregated does not")
def f2(batches):
    lo = common.run_point("co-2gpus", 16).metrics
    hi = common.run_point("co-2gpus", 32).metrics
    assert hi.median_tpot_s > 1.8 * lo.median_tpot_s, "no co-2gpus cliff"
    assert hi.total_recomputed_tokens > 0, "cliff without recompute"
    dlo = common.run_point("dis-ici", 16).metrics
    dhi = common.run_point("dis-ici", 64).metrics
    assert dhi.median_tpot_s < 2.0 * dlo.median_tpot_s, "dis-ici cliffed"
    assert dhi.total_recomputed_tokens == 0


@check("F3: transfer-path order gpu(ici) < cpu(host) < disk in TTFT "
       "and energy/token")
def f3(batches):
    for bs in (8, 16, 64):
        t = {s: common.run_point(s, bs).metrics.median_ttft_s
             for s in ("dis-ici", "dis-host", "dis-disk")}
        assert t["dis-ici"] < t["dis-host"] < t["dis-disk"], f"bs={bs}: {t}"
        e = {s: common.run_point(s, bs).joules_per_token
             for s in ("dis-ici", "dis-host", "dis-disk")}
        assert e["dis-ici"] < e["dis-host"] < e["dis-disk"], f"bs={bs}: {e}"


@check("F4: disaggregated throughput saturates with batch; co-2gpus "
       "drops around 32")
def f4(batches):
    d16 = common.run_point("dis-ici", 16).metrics.decode_throughput_tok_s
    d64 = common.run_point("dis-ici", 64).metrics.decode_throughput_tok_s
    assert d64 >= d16 * 0.95, "dis throughput regressed with batch"
    assert d64 <= d16 * 1.6, "dis throughput kept scaling (should saturate)"
    c16 = common.run_point("co-2gpus", 16).metrics.decode_throughput_tok_s
    c32 = common.run_point("co-2gpus", 32).metrics.decode_throughput_tok_s
    assert c32 < c16, "co-2gpus did not drop at 32"


@check("F5: energy/token amortizes with batch, then co-2gpus spikes at "
       ">=32")
def f5(batches):
    e = {bs: common.run_point("co-2gpus", bs).joules_per_token
         for bs in (2, 16, 32)}
    assert e[16] < e[2], "no static-power amortization"
    assert e[32] > e[16], "no eviction energy spike"
    d = {bs: common.run_point("dis-ici", bs).joules_per_token
         for bs in (2, 16, 64)}
    assert d[16] < d[2] and d[64] <= d[16], "dis did not amortize"


@check("F6: latency-energy frontiers are U-curves; no disaggregated "
       "(phi_p, phi_d) beats colocated total energy")
def f6(batches):
    grid = (0.26, 0.42, 0.58, 0.74, 0.90, 1.0)

    def stage_energies(setup):
        """Per-phi (prefill-side, decode-side) active energy — the same
        per-leg attribution rule fig5 plots (RunRecord properties)."""
        recs = run_grid(Grid(common.closed_exp(setup, 16), {"phi": grid}))
        return ([r.prefill_side_j for r in recs],
                [r.decode_side_j for r in recs])

    co_pre, co_dec = stage_energies("co-2gpus")
    e_curve = [p + d for p, d in zip(co_pre, co_dec)]
    best = e_curve.index(min(e_curve))
    assert 0 < best < len(e_curve) - 1, f"colocated curve not U: {e_curve}"
    co_best = min(e_curve)
    for setup in ("dis-ici", "dis-host", "dis-disk"):
        pre, dec = stage_energies(setup)
        dis_best = min(pre) + min(dec)
        assert dis_best > co_best, \
            f"{setup} beat colocated energy ({dis_best} < {co_best})"


def run(batches=common.DEFAULT_BATCHES):
    print("\n== validate_claims: paper findings F1-F6")
    failures = 0
    for name, fn in CHECKS:
        try:
            fn(batches)
            print(f"  PASS {name}")
        except AssertionError as e:
            failures += 1
            print(f"  FAIL {name}: {e}")
    print(f"== validate_claims: {len(CHECKS) - failures}/{len(CHECKS)} "
          f"claims reproduced")
    return failures


if __name__ == "__main__":
    raise SystemExit(run())
