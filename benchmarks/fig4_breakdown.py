"""Paper Fig 4: per-component energy breakdown (accelerators / CPU / DRAM
/ disk / interconnect) per setup and batch size."""
from __future__ import annotations

from repro.core import SETUPS
from . import common

COMPONENTS = ("acc0", "acc1", "cpu", "dram", "disk", "pcie", "ici")


def run(arch: str = common.DEFAULT_ARCH, batches=(4, 16, 64)):
    header = ["setup", "batch"] + [f"{c}_kj" for c in COMPONENTS]
    rows = []
    for setup in SETUPS:
        for bs in batches:
            bd = common.run_point(setup, bs, arch).energy_by_component
            rows.append([setup, bs] + [round(bd.get(c, 0.0) / 1e3, 3)
                                       for c in COMPONENTS])
    common.print_table("Fig 4: component energy breakdown", header, rows)
    common.write_csv("fig4_breakdown.csv", header, rows)
    return rows


if __name__ == "__main__":
    run()
