"""Paper Fig 1: TTFT and TPOT vs batch size across the five setups.

``--rate`` switches the x-axis from batch size (the paper's infinite-
rate RandomDataset) to offered load: Poisson arrivals at each requested
rate over the same 16k/256 shape, reporting SLO-era open-loop metrics
(queue delay, attainment-ready percentiles). Every cell routes through
``repro.exp.run``, so a repeated invocation is pure cache reads.

  python -m benchmarks.fig1_latency                  # batch sweep
  python -m benchmarks.fig1_latency --rate 2 --rate 8
"""
from __future__ import annotations

from repro.core import SETUPS
from . import common


def run(arch: str = common.DEFAULT_ARCH,
        batches=common.DEFAULT_BATCHES):
    header = ["setup", "batch", "median_ttft_s", "p99_ttft_s",
              "median_tpot_ms", "p99_tpot_ms", "evictions",
              "recomputed_tokens"]
    rows = []
    for setup in SETUPS:
        for bs in batches:
            m = common.run_point(setup, bs, arch).metrics
            rows.append([setup, bs, round(m.median_ttft_s, 4),
                         round(m.p99_ttft_s, 4),
                         round(m.median_tpot_s * 1e3, 3),
                         round(m.p99_tpot_s * 1e3, 3),
                         m.total_evictions, m.total_recomputed_tokens])
    common.print_table("Fig 1: latency vs batch size", header, rows)
    common.write_csv("fig1_latency.csv", header, rows)
    return rows


def run_rates(rates, arch: str = common.DEFAULT_ARCH,
              n: int = common.OPEN_LOOP_N):
    header = ["setup", "rate_rps", "median_ttft_s", "p99_ttft_s",
              "median_tpot_ms", "p99_tpot_ms", "median_queue_s",
              "evictions"]
    rows = []
    for setup in SETUPS:
        for rate in rates:
            m = common.run_open_loop_point(setup, rate, arch, n=n).metrics
            rows.append([setup, rate, round(m.median_ttft_s, 4),
                         round(m.p99_ttft_s, 4),
                         round(m.median_tpot_s * 1e3, 3),
                         round(m.p99_tpot_s * 1e3, 3),
                         round(m.median_queue_s, 4), m.total_evictions])
    common.print_table("Fig 1 (open loop): latency vs offered rate",
                       header, rows)
    common.write_csv("fig1_latency_rate.csv", header, rows)
    return rows


def main(argv=None):
    args = common.open_loop_arg_parser(__doc__).parse_args(argv)
    if args.rate:
        return run_rates(args.rate, args.arch, n=args.requests)
    return run(args.arch)


if __name__ == "__main__":
    main()
