"""Paper Fig 1: TTFT and TPOT vs batch size across the five setups."""
from __future__ import annotations

from repro.core import SETUPS
from . import common


def run(arch: str = common.ARCH):
    header = ["setup", "batch", "median_ttft_s", "p99_ttft_s",
              "median_tpot_ms", "p99_tpot_ms", "evictions",
              "recomputed_tokens"]
    rows = []
    for setup in SETUPS:
        for bs in common.BATCHES:
            m = common.run_point(setup, bs, arch).metrics
            rows.append([setup, bs, round(m.median_ttft_s, 4),
                         round(m.p99_ttft_s, 4),
                         round(m.median_tpot_s * 1e3, 3),
                         round(m.p99_tpot_s * 1e3, 3),
                         m.total_evictions, m.total_recomputed_tokens])
    common.print_table("Fig 1: latency vs batch size", header, rows)
    common.write_csv("fig1_latency.csv", header, rows)
    return rows


if __name__ == "__main__":
    run()
