"""Paper Fig 2: prefill and decode throughput vs batch size."""
from __future__ import annotations

from repro.core import SETUPS
from . import common


def run(arch: str = common.ARCH):
    header = ["setup", "batch", "prefill_tput_tok_s", "decode_tput_tok_s",
              "makespan_s"]
    rows = []
    for setup in SETUPS:
        for bs in common.BATCHES:
            m = common.run_point(setup, bs, arch).metrics
            rows.append([setup, bs,
                         round(m.prefill_throughput_tok_s, 1),
                         round(m.decode_throughput_tok_s, 1),
                         round(m.makespan_s, 2)])
    common.print_table("Fig 2: throughput vs batch size", header, rows)
    common.write_csv("fig2_throughput.csv", header, rows)
    return rows


if __name__ == "__main__":
    run()
