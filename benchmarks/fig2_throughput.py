"""Paper Fig 2: prefill and decode throughput vs batch size.

``--rate`` switches to the open-loop axis: throughput plus goodput
(requests/s meeting the shared interactive SLO — TTFT<=2s, TPOT<=7.5ms,
``repro.workload.DEFAULT_INTERACTIVE_SLO``) at each offered Poisson
rate. Cells are ``repro.exp`` experiments served from the result cache.

  python -m benchmarks.fig2_throughput
  python -m benchmarks.fig2_throughput --rate 2 --rate 8
"""
from __future__ import annotations

from repro.core import SETUPS
from . import common


def run(arch: str = common.DEFAULT_ARCH,
        batches=common.DEFAULT_BATCHES):
    header = ["setup", "batch", "prefill_tput_tok_s", "decode_tput_tok_s",
              "makespan_s"]
    rows = []
    for setup in SETUPS:
        for bs in batches:
            m = common.run_point(setup, bs, arch).metrics
            rows.append([setup, bs,
                         round(m.prefill_throughput_tok_s, 1),
                         round(m.decode_throughput_tok_s, 1),
                         round(m.makespan_s, 2)])
    common.print_table("Fig 2: throughput vs batch size", header, rows)
    common.write_csv("fig2_throughput.csv", header, rows)
    return rows


def run_rates(rates, arch: str = common.DEFAULT_ARCH,
              n: int = common.OPEN_LOOP_N):
    header = ["setup", "rate_rps", "offered_rps", "prefill_tput_tok_s",
              "decode_tput_tok_s", "goodput_rps", "makespan_s"]
    rows = []
    for setup in SETUPS:
        for rate in rates:
            m = common.run_open_loop_point(setup, rate, arch, n=n).metrics
            rows.append([setup, rate, round(m.offered_rps, 3),
                         round(m.prefill_throughput_tok_s, 1),
                         round(m.decode_throughput_tok_s, 1),
                         round(m.goodput_rps, 3),
                         round(m.makespan_s, 2)])
    common.print_table("Fig 2 (open loop): throughput vs offered rate",
                       header, rows)
    common.write_csv("fig2_throughput_rate.csv", header, rows)
    return rows


def main(argv=None):
    args = common.open_loop_arg_parser(__doc__).parse_args(argv)
    if args.rate:
        return run_rates(args.rate, args.arch, n=args.requests)
    return run(args.arch)


if __name__ == "__main__":
    main()
