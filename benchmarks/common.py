"""Shared benchmark machinery over the ``repro.exp`` Experiment API.

Experiment 1 (Figs 1-4): input 16,384 / output 256, batch swept 2..64,
request rate infinite, five setups. Every cell is a declarative
``Experiment`` executed through ``repro.exp.run``, so results are
memoized in the content-addressed cache under ``benchmarks/out/cache``
— one simulation per unique spec, shared across figures, processes,
and reruns (``python -m benchmarks.run`` twice simulates nothing the
second time).
"""
from __future__ import annotations

import csv
import os
from typing import Dict, Iterable, List, Tuple

from repro.core import SETUPS
from repro.exp import Experiment, Grid, RunRecord, run, run_grid

DEFAULT_ARCH = os.environ.get("REPRO_BENCH_ARCH", "llama32-3b")
DEFAULT_BATCHES = (2, 4, 8, 16, 32, 48, 64)
QUICK_BATCHES = (2, 8, 16, 32)          # the --quick / CI grid
INPUT_LEN = 16_384
OUTPUT_LEN = 256
# open-loop mode (--rate): Poisson arrivals over the same paper shape
RATES = (1.0, 2.0, 4.0, 8.0, 16.0)
OPEN_LOOP_N = 24
OUT_DIR = os.path.join(os.path.dirname(__file__), "out")

def closed_exp(setup, batch: int, arch: str = DEFAULT_ARCH,
               **kw) -> Experiment:
    """The paper's Experiment-1 cell as a spec: ``batch`` requests of
    16,384/256 at t=0 on ``setup``. ``phi``/``phi_prefill``/
    ``phi_decode``/``governor`` map onto the fleet; they are part of
    the spec (hence the cache key), never an out-of-band override —
    anything else is a typo (the old **kw pass-through silently
    bypassed the cache and rebuilt the config twice)."""
    from repro.exp.spec import apply_spec_knobs
    exp = Experiment.closed(setup, batch, arch=arch,
                            input_len=INPUT_LEN, output_len=OUTPUT_LEN)
    exp, leftovers = apply_spec_knobs(exp, kw)
    if leftovers:
        raise TypeError(f"unknown experiment knobs: {sorted(leftovers)}")
    return exp


def run_point(setup, batch: int, arch: str = DEFAULT_ARCH,
              **kw) -> RunRecord:
    return run(closed_exp(setup, batch, arch, **kw))


def open_exp(setup, rate: float, arch: str = DEFAULT_ARCH,
             n: int = OPEN_LOOP_N, seed: int = 0) -> Experiment:
    """One open-loop cell spec: Poisson arrivals at ``rate`` req/s over
    the paper's fixed 16k/256 shape, scored against the shared
    interactive SLO so goodput/attainment columns are meaningful."""
    from repro.workload import DEFAULT_INTERACTIVE_SLO
    return Experiment.open(setup, rate, arch=arch, n=n, seed=seed,
                           slo=DEFAULT_INTERACTIVE_SLO)


def run_open_loop_point(setup, rate: float, arch: str = DEFAULT_ARCH,
                        n: int = OPEN_LOOP_N, seed: int = 0) -> RunRecord:
    return run(open_exp(setup, rate, arch, n=n, seed=seed))


def full_sweep(arch: str = DEFAULT_ARCH,
               batches: Iterable[int] = DEFAULT_BATCHES, *,
               parallel: int = 1
               ) -> Dict[Tuple[str, int], RunRecord]:
    """The whole Experiment-1 matrix as one grid: cache misses fan out
    over ``parallel`` processes; figures then hit the warm cache."""
    batches = tuple(batches)
    grid = Grid(closed_exp(SETUPS[0], batches[0], arch),
                {"setup": SETUPS, "batch": batches})
    recs = run_grid(grid, parallel=parallel)
    cells = [(s, b) for s in SETUPS for b in batches]
    return dict(zip(cells, recs))


def open_loop_arg_parser(doc: str) -> "argparse.ArgumentParser":
    """The --arch/--rate/--requests parser shared by the open-loop
    figures (fig1/fig2/fig6) so new knobs land in one place."""
    import argparse
    ap = argparse.ArgumentParser(description=doc)
    ap.add_argument("--arch", default=DEFAULT_ARCH)
    ap.add_argument("--rate", type=float, action="append", default=None,
                    help="open-loop offered rate (repeatable); omit for "
                         "the paper's batch sweep where applicable")
    ap.add_argument("--requests", type=int, default=OPEN_LOOP_N)
    return ap


def write_json(payload: Dict, name: str, out: str = None) -> str:
    """Write a figure's JSON artifact: ``name`` lands in OUT_DIR, an
    explicit ``out`` path is honored (parent dirs created either way).
    One helper so the artifact convention lives in one place."""
    import json
    path = out or os.path.join(OUT_DIR, name)
    parent = os.path.dirname(os.path.abspath(path))
    os.makedirs(parent, exist_ok=True)
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {path}")
    return path


def write_csv(name: str, header: List[str], rows: List[List]) -> str:
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, name)
    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(header)
        w.writerows(rows)
    return path


def print_table(title: str, header: List[str], rows: List[List]) -> None:
    print(f"\n== {title}")
    print(",".join(header))
    for r in rows:
        print(",".join(str(x) for x in r))
