"""Shared benchmark machinery: the paper's workload and setup sweep.

Experiment 1 (Figs 1-4): input 16,384 / output 256, batch swept 2..64,
request rate infinite, five setups. One sweep is shared by all figures
(module-level cache) so ``python -m benchmarks.run`` does each simulation
once.
"""
from __future__ import annotations

import csv
import os
from typing import Dict, Iterable, List, Tuple

from repro.configs import get_config
from repro.core import Cluster, SETUPS, SetupResult, random_workload

ARCH = os.environ.get("REPRO_BENCH_ARCH", "llama32-3b")
BATCHES = (2, 4, 8, 16, 32, 48, 64)
INPUT_LEN = 16_384
OUTPUT_LEN = 256
# open-loop mode (--rate): Poisson arrivals over the same paper shape
RATES = (1.0, 2.0, 4.0, 8.0, 16.0)
OPEN_LOOP_N = 24
OUT_DIR = os.path.join(os.path.dirname(__file__), "out")

_CACHE: Dict[Tuple[str, str, int], SetupResult] = {}
_RATE_CACHE: Dict[Tuple[str, str, float, int, int], SetupResult] = {}


def run_point(setup: str, batch: int, arch: str = ARCH,
              **kw) -> SetupResult:
    key = (arch, setup, batch)
    if key not in _CACHE and not kw:
        cfg = get_config(arch)
        reqs = random_workload(batch, input_len=INPUT_LEN,
                               output_len=OUTPUT_LEN)
        _CACHE[key] = Cluster(setup, cfg).run(reqs)
    if kw:
        cfg = get_config(arch)
        reqs = random_workload(batch, input_len=INPUT_LEN,
                               output_len=OUTPUT_LEN)
        return Cluster(setup, cfg, **kw).run(reqs)
    return _CACHE[key]


def run_open_loop_point(setup: str, rate: float, arch: str = ARCH,
                        n: int = OPEN_LOOP_N, seed: int = 0) -> SetupResult:
    """One open-loop cell: Poisson arrivals at ``rate`` req/s over the
    paper's fixed 16k/256 shape, scored against the shared interactive
    SLO so goodput/attainment columns are meaningful (cached like
    ``run_point``)."""
    from repro.workload import DEFAULT_INTERACTIVE_SLO, open_loop_workload
    key = (arch, setup, float(rate), n, seed)
    if key not in _RATE_CACHE:
        cfg = get_config(arch)
        reqs = open_loop_workload(rate, n, seed=seed,
                                  slo=DEFAULT_INTERACTIVE_SLO,
                                  lengths=None)  # paper-fixed 16k/256
        _RATE_CACHE[key] = Cluster(setup, cfg).run(reqs)
    return _RATE_CACHE[key]


def open_loop_arg_parser(doc: str) -> "argparse.ArgumentParser":
    """The --arch/--rate/--requests parser shared by the open-loop
    figures (fig1/fig2/fig6) so new knobs land in one place."""
    import argparse
    ap = argparse.ArgumentParser(description=doc)
    ap.add_argument("--arch", default=ARCH)
    ap.add_argument("--rate", type=float, action="append", default=None,
                    help="open-loop offered rate (repeatable); omit for "
                         "the paper's batch sweep where applicable")
    ap.add_argument("--requests", type=int, default=OPEN_LOOP_N)
    return ap


def full_sweep(arch: str = ARCH,
               batches: Iterable[int] = BATCHES
               ) -> Dict[Tuple[str, int], SetupResult]:
    return {(s, b): run_point(s, b, arch) for s in SETUPS for b in batches}


def write_json(payload: Dict, name: str, out: str = None) -> str:
    """Write a figure's JSON artifact: ``name`` lands in OUT_DIR, an
    explicit ``out`` path is honored (parent dirs created either way).
    One helper so the artifact convention lives in one place."""
    import json
    path = out or os.path.join(OUT_DIR, name)
    parent = os.path.dirname(os.path.abspath(path))
    os.makedirs(parent, exist_ok=True)
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {path}")
    return path


def write_csv(name: str, header: List[str], rows: List[List]) -> str:
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, name)
    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(header)
        w.writerows(rows)
    return path


def print_table(title: str, header: List[str], rows: List[List]) -> None:
    print(f"\n== {title}")
    print(",".join(header))
    for r in rows:
        print(",".join(str(x) for x in r))
