"""FleetCluster: the discrete-event serving loop over an xP:yD fleet.

The generalization of the paper's five two-accelerator setups to
arbitrary fleet shapes (``FleetSpec``): x prefill + y decode instances
(or n colocated), each with its own ``PagedKVPool``, per-instance DVFS
setting, and energy attribution under one shared ``EnergyMeter``.
Arriving requests are routed to a prefill instance by the frontend
``Router`` at their arrival event; a finished prefill's KV cache is
routed to a decode instance by the KV router at prefill completion and
streamed over that (prefill, decode) pair's own ``TransferPath`` — any
prefill instance can feed any decode instance over ici/host/disk.

The event loop, transfer legs, and energy integration are the ones the
1P:1D ``Cluster`` always ran (it is now a thin facade over this class,
see ``repro.core.orchestrator``); the parity regression in
``tests/test_fleet.py`` pins the 1P:1D and colocated special cases to
the pre-fleet metrics bit-for-bit.
"""
from __future__ import annotations

import heapq
import itertools
import os
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Tuple, Union

from repro.configs.base import ModelConfig
from repro.core.costs import AcceleratorSpec, CostModel, HostSpec
from repro.core.energy import EnergyMeter
from repro.core.engine import Engine, EngineSeq, RealExecutor
from repro.core.fastpath import coalesce_window
from repro.core.kvcache import PagedKVPool
from repro.core.request import Request, WorkloadMetrics, summarize
from repro.core.prefix_cache import PrefixCache
from repro.core.transfer import LegCost, TransferPath, make_path
from repro.govern import make_governor
from repro.kvstore import ReuseSpec, TieredKVStore, as_reuse_spec
from repro.govern.telemetry import ABSENT, IDLE, SLEEP, PowerTrace
from repro.obs.trace import (NULL_TRACER, Tracer,
                             controller_action_from_event,
                             event_from_controller_action)

from .controller import make_controller
from .router import Router
from .spec import FleetSpec, as_fleet_spec

Phi = Union[float, Tuple[float, ...]]

# Default stepper for FleetCluster.run: "fast" coalesces steady-state
# decode runs (repro.core.fastpath), "exact" is the retained one-step-
# per-token reference the parity harness differentially tests against.
# The two are observably identical (tests/test_fastpath_parity.py);
# REPRO_STEPPER=exact flips the default for debugging a suspect run.
STEPPERS = ("fast", "exact")
DEFAULT_STEPPER = os.environ.get("REPRO_STEPPER", "fast")


@dataclass
class SetupResult:
    setup: str
    metrics: WorkloadMetrics
    energy: EnergyMeter
    requests: List[Request]
    makespan_s: float
    total_tokens: int

    @property
    def joules_per_token(self) -> float:
        return self.energy.total_j / max(self.total_tokens, 1)


class FleetCluster:
    def __init__(self, spec: Union[str, FleetSpec], cfg: ModelConfig, *,
                 acc: Optional[AcceleratorSpec] = None,
                 host: Optional[HostSpec] = None,
                 phi: Optional[float] = None,
                 phi_prefill: Optional[Phi] = None,
                 phi_decode: Optional[Phi] = None,
                 governor: Optional[Union[str, Tuple[str, ...]]] = None,
                 reuse: Optional[Union[str, dict, ReuseSpec]] = None,
                 scheduler=None,
                 page_size: int = 16,
                 prefill_token_budget: int = 8192,
                 pool_bytes: Optional[float] = None,
                 executor_factory: Optional[Callable[
                     [Optional[TransferPath]], RealExecutor]] = None,
                 tracer: Optional[Tracer] = None):
        spec = as_fleet_spec(spec)
        if phi is not None or phi_prefill is not None \
                or phi_decode is not None:
            spec = spec.with_phi(phi=phi, phi_prefill=phi_prefill,
                                 phi_decode=phi_decode)
        if governor is not None:
            # sweep-plumbing override, mirroring the phi kwargs: any
            # entry point taking **cluster_kw can run a governor
            from dataclasses import replace
            spec = replace(spec, governor=governor)
        if reuse is not None:
            # same sweep-plumbing shape for KV reuse (DESIGN.md s15)
            from dataclasses import replace
            spec = replace(spec, reuse=reuse)
        if scheduler is not None:
            # same sweep-plumbing shape for the step scheduler (s17)
            from dataclasses import replace
            spec = replace(spec, scheduler=scheduler)
        self.spec = spec
        self.setup = spec.name
        self.cfg = cfg
        self.acc = acc or AcceleratorSpec()
        self.host = host or HostSpec()
        self.cost = CostModel(cfg, self.acc, self.host)
        # every run carries a power-state timeline (repro.govern): the
        # trace is observational — joule totals use the same call
        # sequence with or without it, so parity goldens stay bit-exact
        self.meter = EnergyMeter(trace=PowerTrace())
        # observability (repro.obs, DESIGN.md section 16): the tracer is
        # observational too — on or off, every simulated quantity is
        # bit-identical (tests/test_obs.py parity axis)
        self.tracer = tracer or NULL_TRACER
        # fastpath coalescing stats (window count / steps coalesced),
        # maintained by _run_loop; exact runs leave both at 0
        self.coalesce_windows = 0
        self.coalesced_steps = 0
        pool_bytes = pool_bytes or self.acc.kv_pool_gb * 1e9
        kv_per_tok = max(self.cost.kv_bytes_per_token, 1)

        def new_pool():
            return PagedKVPool.from_bytes(pool_bytes, kv_per_tok, page_size)

        self.engines: List[Engine] = []
        self.prefill_engines: List[Engine] = []
        self.decode_engines: List[Engine] = []
        # one TransferPath per (prefill, decode) pair: media with real
        # per-connection state (disk scratch files, staging buffers)
        # stay independent, and a future heterogeneous-media fleet only
        # has to change this map
        self.paths: Dict[Tuple[int, int], TransferPath] = {}
        self._events: List = []   # heap of (t, tiebreak, fn)
        self._counter = itertools.count()

        if spec.is_colocated:
            for i, phi_i in enumerate(spec.phis_prefill):
                ex = executor_factory(None) if executor_factory else None
                self.engines.append(Engine(
                    f"acc{i}", "colocated", self.cost, new_pool(),
                    self.meter, phi=phi_i,
                    prefill_token_budget=prefill_token_budget, executor=ex))
            self.prefill_engines = self.engines
        elif spec.is_intra:
            # intra-GPU P/D disaggregation (RAPID-Serve, DESIGN.md s17):
            # each accelerator is SM-partitioned into a prefill slice
            # and a decode slice — two engines whose CostModels are
            # complementary slices of ONE accelerator (rooflines and
            # power rails sum back to the whole part) sharing ONE KV
            # pool. The handoff never leaves HBM: no TransferPath, no
            # transfer joules, zero latency (_intra_handoff).
            cost_p = self.cost.slice(spec.intra_split)
            cost_d = self.cost.slice(1.0 - spec.intra_split)
            for i, (phi_p, phi_d) in enumerate(zip(spec.phis_prefill,
                                                   spec.phis_decode)):
                pool = new_pool()
                ex_p = executor_factory(None) if executor_factory else None
                ex_d = executor_factory(None) if executor_factory else None
                ep = Engine(f"acc{i}p", "prefill", cost_p, pool,
                            self.meter, phi=phi_p,
                            prefill_token_budget=prefill_token_budget,
                            executor=ex_p,
                            on_prefill_done=self._intra_handoff)
                ep.fleet_index = i
                ed = Engine(f"acc{i}d", "decode", cost_d, pool,
                            self.meter, phi=phi_d,
                            prefill_token_budget=prefill_token_budget,
                            executor=ex_d)
                ed.fleet_index = i
                ed.inflight_kv_pages = 0
                # the handoff target is the fixed same-accelerator peer
                # (KV is physically resident there already) — no KV
                # routing decision exists for this shape
                ep.intra_peer = ed
                self.prefill_engines.append(ep)
                self.decode_engines.append(ed)
            self.engines = self.prefill_engines + self.decode_engines
        else:
            x, y = spec.n_prefill, spec.n_decode
            for i in range(x):
                for j in range(y):
                    self.paths[(i, j)] = make_path(spec.medium, self.host)
            # engine executors are built path-less: the (prefill, decode)
            # pair — hence the path the real bytes travel — is only known
            # at transfer time, so _transfer runs the pair path's
            # store()/fetch() around the executor's payload
            for i, phi_i in enumerate(spec.phis_prefill):
                ex = executor_factory(None) if executor_factory else None
                eng = Engine(f"acc{i}", "prefill", self.cost, new_pool(),
                             self.meter, phi=phi_i,
                             prefill_token_budget=prefill_token_budget,
                             executor=ex, on_prefill_done=self._transfer)
                eng.fleet_index = i
                self.prefill_engines.append(eng)
            for j, phi_j in enumerate(spec.phis_decode):
                ex = executor_factory(None) if executor_factory else None
                eng = Engine(f"acc{x + j}", "decode", self.cost, new_pool(),
                             self.meter, phi=phi_j,
                             prefill_token_budget=prefill_token_budget,
                             executor=ex)
                eng.fleet_index = j
                # pages for transfers routed here but still in their
                # store leg (not yet in decode_queue): the kv-free-space
                # router subtracts this, else every prefill finishing
                # within one store-latency window picks the same target
                eng.inflight_kv_pages = 0
                self.decode_engines.append(eng)
            self.engines = self.prefill_engines + self.decode_engines

        # one governor instance per engine (controllers are stateful;
        # per-engine seeds keep any future stochastic policy decoupled
        # across instances). The default StaticGovernor keeps the
        # spec-configured phi — a no-op on the timing/energy stream.
        for idx, (eng, gname) in enumerate(zip(self.engines,
                                               spec.governors)):
            eng.governor = make_governor(gname,
                                         seed=spec.seed + 1000 + idx)

        for eng in self.engines:
            eng.tracer = self.tracer

        # per-step scheduler (repro.sched, DESIGN.md section 17): one
        # normalized SchedulerSpec shared by every engine. None leaves
        # Engine.scheduler = None — the legacy paths, byte-for-byte.
        if spec.scheduler is not None:
            for eng in self.engines:
                eng.scheduler = spec.scheduler

        # legacy attribute: the single transfer path of a 1P:1D fleet
        self.path: Optional[TransferPath] = self.paths.get((0, 0)) \
            if len(self.paths) == 1 else None

        # global engine index + pair paths keyed on it: role flips make
        # (prefill_index, decode_index) ambiguous, so the transfer code
        # looks paths up by (src.gidx, dst.gidx). Pre-populated with the
        # SAME TransferPath objects as self.paths (which is kept for
        # compatibility); pairs first connected after a flip get a
        # fresh path of the spec's medium lazily.
        for idx, e in enumerate(self.engines):
            e.gidx = idx
        x = spec.n_prefill
        self._pair_paths: Dict[Tuple[int, int], TransferPath] = {
            (i, x + j): p for (i, j), p in self.paths.items()}

        # ---- online fleet controller (repro.fleet.controller) --------
        # None = static fleet: every branch below is byte-for-byte the
        # pre-controller behavior (accept=None routers, no lifecycle
        # bookkeeping, no tick events).
        self.controller = None
        self.controller_log: List[dict] = []
        self._lifecycle: Dict[str, List[Tuple[float, str]]] = {}
        self._draining: Dict[Engine, str] = {}   # engine -> "sleep"|"flip"
        self._parked_requests: List[Request] = []
        self._parked_transfers: List[Tuple[Engine, EngineSeq, float]] = []
        self._pending_arrivals = 0
        if spec.controller is not None:
            self.controller = make_controller(spec.controller,
                                              seed=spec.seed + 2000)
            for e in self.engines:
                self._lifecycle[e.name] = [(0.0, "on")]
            self._apply_initial_awake()

        if self.controller is None:
            accept_p = accept_d = None
        elif spec.is_colocated:
            accept_p = lambda e: e.accepting          # noqa: E731
            accept_d = None
        else:
            # role-aware: a flipped engine moves between the two routers'
            # eligible sets without rebinding the router itself
            accept_p = lambda e: e.accepting and e.role != "decode"  # noqa: E731
            accept_d = lambda e: e.accepting and e.role == "decode"  # noqa: E731
        frontend_engines = self.prefill_engines if self.controller is None \
            else self.engines
        self.frontend = Router(frontend_engines, spec.router, spec.seed,
                               accept=accept_p)
        if not self.decode_engines:
            self.kv_router = None
        else:
            kv_engines = self.decode_engines if self.controller is None \
                else self.engines
            self.kv_router = Router(kv_engines, spec.kv_router,
                                    spec.seed + 1, accept=accept_d)

        # ---- KV reuse (repro.kvstore, DESIGN.md section 15) ----------
        self._reuse: Optional[ReuseSpec] = None
        self._shared_prefix_cache: Optional[PrefixCache] = None
        if spec.reuse is not None:
            self._attach_reuse(spec.reuse)

    # ------------------------------------------------------------------
    def _attach_reuse(self, reuse: Union[str, dict, ReuseSpec]) -> None:
        """Attach the spec'd KV reuse machinery to the engines. Flat
        (``tiers is None``): ONE shared ``PrefixCache`` across the fleet
        — the cluster-wide reuse the paper's section II-C experiments
        model, fast-stepper safe (lookups/inserts happen in exact
        submit/prefill steps). Tiered: one ``TieredKVStore`` PER engine
        (residency is the router's locality signal, so it must be
        per-instance), attached to every engine regardless of role so
        controller role flips keep their store. Real-executor engines
        are skipped — matched KV bytes are not actually materialized,
        same rule as ``Engine.prefix_cache``."""
        r = as_reuse_spec(reuse)
        self._reuse = r
        if r.tiers is None:
            pc = PrefixCache(capacity_pages=r.capacity_pages,
                             page_size=r.page_size,
                             pic=(r.mode == "pic"),
                             recompute_frac=r.recompute_frac)
            self._shared_prefix_cache = pc
            for e in self.engines:
                if e.executor is None:
                    e.prefix_cache = pc
            return
        page_bytes = max(self.cost.kv_bytes_per_token, 1) * r.page_size
        for e in self.engines:
            if e.executor is None:
                e.kv_store = TieredKVStore(
                    r.tiers, mode=r.mode, page_size=r.page_size,
                    recompute_frac=r.recompute_frac,
                    page_bytes=page_bytes, host=self.host)
                e.kv_store.tracer = self.tracer

    @property
    def tiered(self) -> bool:
        """Any engine carrying a TieredKVStore — the fast-stepper bail
        signal (checked on engines, not the spec, so tests attaching
        stores directly are covered too)."""
        return any(e.kv_store is not None for e in self.engines)

    @property
    def fastpath_stats(self) -> Dict[str, Union[int, float]]:
        """End-of-run coalescing summary: window count, steps coalesced,
        and the coalesced fraction of all engine steps (diagnosability
        companion to the perf lane's speedup numbers)."""
        total = sum(e.steps for e in self.engines)
        return {"windows": self.coalesce_windows,
                "coalesced_steps": self.coalesced_steps,
                "coalesced_step_fraction":
                    self.coalesced_steps / total if total else 0.0}

    def _warm_stores(self, requests: List[Request]) -> None:
        """``ReuseSpec.warm``: pre-insert request 0's prompt before the
        run so the very first lookup can hit (the reuse benchmarks'
        warmed-cache convention). Tiered warm inserts are priced like
        any other insert — overflow spills are metered at t=0."""
        r = self._reuse
        if r is None or not r.warm or not requests:
            return
        toks = requests[0].prompt_tokens
        if toks is None:
            return
        if self._shared_prefix_cache is not None:
            self._shared_prefix_cache.insert(toks)
            return
        for e in self.engines:
            if e.kv_store is not None:
                for leg in e.kv_store.insert(toks):
                    for comp, joules in leg.energy_j.items():
                        self.meter.add(comp, joules, stage="tier-spill")

    # ------------------------------------------------------------------
    def _push(self, t: float, fn: Callable[[], None]) -> None:
        heapq.heappush(self._events, (t, next(self._counter), fn))

    # ------------------------------------------------------------------
    def _pair_path(self, src: Engine, dst: Engine) -> TransferPath:
        key = (src.gidx, dst.gidx)
        path = self._pair_paths.get(key)
        if path is None:                 # pair first connected post-flip
            path = make_path(self.spec.medium, self.host)
            self._pair_paths[key] = path
        return path

    def _transfer(self, engine: Engine, seq: EngineSeq, t_done: float):
        """Store leg: runs right after prefill; pages stay held on the
        prefill accelerator until the store completes. The decode target
        is picked HERE (not at arrival), so the KV router sees decode
        pool pressure at transfer time. With a controller active the
        pick can come up empty (every decode instance asleep/draining):
        the handoff parks — pages still held, the backpressure is real —
        until ``_provide`` wakes or flips capacity."""
        dec = self.kv_router.pick(req=seq.req)
        if dec is None:
            self._parked_transfers.append((engine, seq, t_done))
            self._provide("decode", t_done)
            return
        self._start_transfer(engine, seq, t_done, dec)

    def _local_handoff(self, engine: Engine, seq: EngineSeq, t: float):
        """A prefill->decode handoff whose target IS the engine that
        prefilled it (possible only after a role flip): the KV is
        already resident in its HBM, so both legs are zero-cost — the
        pages are freed and immediately re-reserved under the decode
        role's prompt+output reservation discipline."""
        engine.pool.free_seq(seq.seq_id)
        seq.req.transfer_done_s = t
        if self.tracer.enabled:
            self.tracer.lifecycle("transfer_start", seq.req.req_id, t,
                                  src=engine.name, dst=engine.name)
            self.tracer.lifecycle("transfer_done", seq.req.req_id, t,
                                  src=engine.name, dst=engine.name)
        engine.t = max(engine.t, t)
        engine.enqueue_decode(seq, None, LegCost(0.0))

    def _intra_handoff(self, engine: Engine, seq: EngineSeq, t: float):
        """Prefill-slice -> decode-slice handoff inside ONE accelerator
        (the intra-gpu shape): the KV pages already live in the shared
        HBM pool, so there is no transfer leg at all — zero latency,
        zero joules, the dominance fig11 machine-checks against
        dis-disk. Like ``_local_handoff``, the pages are freed and
        immediately re-reserved under the decode slice's prompt+output
        reservation discipline (``engine.pool`` IS the peer's pool)."""
        dec = engine.intra_peer
        engine.pool.free_seq(seq.seq_id)
        seq.req.transfer_done_s = t
        if self.tracer.enabled:
            self.tracer.lifecycle("transfer_start", seq.req.req_id, t,
                                  src=engine.name, dst=dec.name)
            self.tracer.lifecycle("transfer_done", seq.req.req_id, t,
                                  src=engine.name, dst=dec.name)
        dec.t = max(dec.t, t)
        dec.enqueue_decode(seq, None, LegCost(0.0))

    def _start_transfer(self, engine: Engine, seq: EngineSeq,
                        t_done: float, dec: Engine):
        if dec is engine:
            self._local_handoff(engine, seq, t_done)
            return
        path = self._pair_path(engine, dec)
        nbytes = self.cost.kv_bytes(seq.ctx)
        store = path.store_cost(nbytes)
        fetch = path.fetch_cost(nbytes)
        # the store leg belongs to the PREFILL side of the handoff
        # (transfer-fetch is added by the decode engine at admission):
        # the DVFS sweeps attribute each leg's joules to its stage from
        # the routed pair's actual LegCost, not an arbitrary 50/50 split
        for comp, joules in store.energy_j.items():
            self.meter.add(comp, joules, stage="transfer-store")
        handle = None
        if engine.executor is not None:
            # real byte movement over the ROUTED pair's path (the
            # path-less executor just packages the state payload)
            handle = path.store(engine.executor.store(seq))

        t_arrive = t_done + store.latency_s
        seq.req.transfer_done_s = t_arrive
        if self.tracer.enabled:
            self.tracer.lifecycle("transfer_start", seq.req.req_id,
                                  t_done, src=engine.name, dst=dec.name)
            self.tracer.lifecycle("transfer_done", seq.req.req_id,
                                  t_arrive, src=engine.name,
                                  dst=dec.name)
            self.tracer.span(f"xfer:{engine.name}->{dec.name}",
                             "kv-store", t_done, t_arrive,
                             req=seq.req.req_id, nbytes=int(nbytes))
        reserve = seq.ctx + (seq.req.output_len - seq.req.generated) + 1
        inflight = dec.pool.pages_for(reserve)
        dec.inflight_kv_pages += inflight

        def deliver():
            engine.pool.free_seq(seq.seq_id)
            # both engines resume no earlier than the store completion:
            # the prefill engine may have been blocked on pool space
            engine.t = max(engine.t, t_arrive)
            # the reservation migrates from in-flight to decode_queue,
            # where the router's headroom counts it instead
            dec.inflight_kv_pages -= inflight
            payload = path.fetch(handle) if handle is not None else None
            dec.enqueue_decode(seq, payload, fetch)
            dec.t = max(dec.t, t_arrive)

        self._push(t_arrive, deliver)

    # ------------------------------------------------------------------
    def submit(self, requests: List[Request]) -> None:
        """Route every request through the event heap at its
        ``arrival_s``: an engine never sees a request before it arrives
        (submitting upfront let a staggered arrival be prefilled at t=0,
        yielding negative TTFT), and the frontend router scores live
        queue depths at the arrival instant rather than at submission.
        ``Engine.submit`` fast-forwards an idle engine's clock to the
        arrival instant; a busy engine (clock already past it) just
        queues the request."""
        self._pending_arrivals += len(requests)
        for r in requests:
            self._push(r.arrival_s, lambda r=r: self._on_arrival(r))

    def _on_arrival(self, r: Request) -> None:
        self._pending_arrivals -= 1
        if self.tracer.enabled:
            self.tracer.lifecycle("arrival", r.req_id, r.arrival_s)
        eng = self.frontend.pick(req=r)
        if eng is None:     # controller-active and nothing accepting
            self._parked_requests.append(r)
            self._provide("prefill", r.arrival_s)
            return
        if self.tracer.enabled:
            self.tracer.lifecycle("routed", r.req_id, r.arrival_s,
                                  engine=eng.name)
        eng.submit(r)

    # ------------------------------------------------------------------
    # fleet-controller lifecycle machinery (DESIGN.md section 14).
    # States per engine: on -> (drain ->) sleep -> wake -> on, plus
    # absent (never provisioned yet; wakes like sleep at 0 W history).
    # Invariants the primitives below maintain — the property tests in
    # tests/test_controller.py fuzz them under random schedules:
    #   * a sleeping/absent/waking/draining engine never ACCEPTS routed
    #     work (routers filter on e.accepting + role);
    #   * sleep requires a fully empty engine (quiescent, no pool seqs,
    #     no in-flight KV), so no request is ever stranded;
    #   * a drain completes only when the engine settles; drain-to-flip
    #     of a prefill engine tolerates pool pages held by its own
    #     PARKED handoffs (they become zero-cost local handoffs the
    #     moment the engine is decode-role);
    #   * every parked request/transfer triggers _provide(), which
    #     always lines up future capacity for that role (wake, cancel a
    #     drain, or flip the other role) — liveness.
    # ------------------------------------------------------------------
    def lifecycle_state(self, e: Engine) -> str:
        if self.controller is None:
            return "on"
        return self._lifecycle[e.name][-1][1]

    def _seg(self, e: Engine, t: float, state: str) -> None:
        lc = self._lifecycle[e.name]
        lc.append((max(t, lc[-1][0]), state))

    def _log(self, t: float, op: str, e: Engine, **kw) -> None:
        # the obs TraceEvent is the canonical record; the legacy dict
        # shape consumers subscript (entry["op"], ...) is derived from
        # it — one schema, two views (ISSUE 9 satellite 1)
        ev = event_from_controller_action(
            dict(t=round(float(t), 9), op=op, engine=e.name, **kw))
        if self.tracer.enabled:
            self.tracer.events.append(ev)
        self.controller_log.append(controller_action_from_event(ev))

    def _apply_initial_awake(self) -> None:
        """Engines beyond the controller's initial_awake_* counts start
        ABSENT (not provisioned): zero draw until first woken, never
        back-filled as idle joules."""
        cspec = self.controller.spec

        def limit(engines, k):
            if k is None or k < 0:
                return
            for e in engines[k:]:
                e.accepting = False
                self._lifecycle[e.name] = [(0.0, "absent")]

        if self.spec.is_colocated:
            limit(self.engines, cspec.initial_awake_prefill)
        else:
            limit(self.prefill_engines, cspec.initial_awake_prefill)
            limit(self.decode_engines, cspec.initial_awake_decode)

    # ---- controller-facing primitives --------------------------------
    def ctl_wake(self, e: Engine, t: float) -> bool:
        """sleep/absent -> wake -> (after wake_latency_s) on."""
        if self.lifecycle_state(e) not in ("sleep", "absent"):
            return False
        t = max(t, e.t)
        self._seg(e, t, "wake")
        self._log(t, "wake", e)
        t_ready = t + self.controller.spec.wake_latency_s

        def ready(e=e, t_ready=t_ready):
            self._seg(e, t_ready, "on")
            e.accepting = True
            e.t = max(e.t, t_ready)
            self._rebalance(t_ready)

        self._push(t_ready, ready)
        return True

    def ctl_sleep(self, e: Engine, t: float) -> bool:
        """Deep-sleep an empty, settled engine immediately."""
        if self.lifecycle_state(e) != "on" or e in self._draining:
            return False
        if not e._quiescent() or e.pool.seqs \
                or getattr(e, "inflight_kv_pages", 0):
            return False
        e.accepting = False
        t = max(t, e.t)
        self._seg(e, t, "sleep")
        self._log(t, "sleep", e)
        return True

    def ctl_drain(self, e: Engine, t: float, then: str = "sleep") -> bool:
        """Stop accepting now; apply ``then`` ("sleep" or "flip") once
        the engine settles."""
        assert then in ("sleep", "flip"), then
        if self.lifecycle_state(e) != "on" or e in self._draining:
            return False
        e.accepting = False
        self._draining[e] = then
        self._log(t, "drain", e, then=then)
        self._check_drains(t)
        return True

    def ctl_cancel_drain(self, e: Engine, t: float) -> bool:
        if e not in self._draining:
            return False
        del self._draining[e]
        e.accepting = True
        self._log(t, "cancel-drain", e)
        return True

    def ctl_flip_asleep(self, e: Engine, t: float) -> bool:
        """Flip the role of a sleeping/absent (hence empty) engine in
        place — repurposing a parked instance costs nothing."""
        if self.lifecycle_state(e) not in ("sleep", "absent"):
            return False
        if e.pool.seqs or not e._quiescent():
            return False
        self._flip_role(e)
        self._log(t, "flip", e, role=e.role, asleep=True)
        return True

    # ---- drain / flip internals --------------------------------------
    def _flip_role(self, e: Engine) -> None:
        e.role = "decode" if e.role == "prefill" else "prefill"
        e.on_prefill_done = self._transfer
        e._fastrun = None    # cached steady-state run keyed on old role

    def _drained(self, e: Engine, fate: str) -> bool:
        if not e._quiescent() or getattr(e, "inflight_kv_pages", 0):
            return False
        if not e.pool.seqs:
            return True
        if fate == "flip" and e.role == "prefill":
            # pages held only by this engine's own parked handoffs:
            # they self-deliver locally the moment the role flips
            parked_here = {s.seq_id for (src, s, _)
                           in self._parked_transfers if src is e}
            return set(e.pool.seqs) <= parked_here
        return False

    def _check_drains(self, t: float) -> bool:
        done = [e for e, fate in self._draining.items()
                if self._drained(e, fate)]
        for e in done:
            fate = self._draining.pop(e)
            tt = max(t, e.t)
            if fate == "sleep":
                self._seg(e, tt, "sleep")
                self._log(tt, "sleep", e)
            else:
                self._apply_flip(e, tt)
        if done:
            self._rebalance(t)
        return bool(done)

    def _apply_flip(self, e: Engine, t: float) -> None:
        self._flip_role(e)
        e.accepting = True
        e.t = max(e.t, t)
        self._log(t, "flip", e, role=e.role)
        if e.role == "decode":
            mine = [item for item in self._parked_transfers
                    if item[0] is e]
            for item in mine:
                self._parked_transfers.remove(item)
                _, seq, td = item
                self._local_handoff(e, seq, max(td, t))

    # ---- parked-work liveness ----------------------------------------
    def _flush(self, t: float) -> None:
        """Re-route parked requests/handoffs against current capacity."""
        still_r: List[Request] = []
        for r in self._parked_requests:
            eng = self.frontend.pick(req=r)
            if eng is None:
                still_r.append(r)
            else:
                if self.tracer.enabled:
                    self.tracer.lifecycle("routed", r.req_id, t,
                                          engine=eng.name)
                eng.submit(r)
        self._parked_requests = still_r
        still_t: List[Tuple[Engine, EngineSeq, float]] = []
        for (src, seq, td) in self._parked_transfers:
            dec = self.kv_router.pick(req=seq.req)
            if dec is None:
                still_t.append((src, seq, td))
            else:
                self._start_transfer(src, seq, max(td, t), dec)
        self._parked_transfers = still_t

    def _rebalance(self, t: float) -> None:
        if self.controller is None:
            return
        self._flush(t)
        if self._parked_requests:
            self._provide("prefill", t)
        if self._parked_transfers:
            self._provide("decode", t)

    def _provide(self, role: str, t: float) -> None:
        """Guarantee future capacity for ``role``. Tried in order:
        capacity already coming (accepting / waking / a pending flip),
        cancel a same-role drain, wake a sleeping same-role instance,
        repurpose the OTHER role (flip a sleeping one, retarget a
        drain-to-sleep, or drain-to-flip the least-loaded accepting
        one). Finite work + this chain being re-run at every settle
        point is the liveness argument: parked work always has capacity
        on the way."""
        if self.controller is None:
            return

        def has_role(e):
            if self.spec.is_colocated:
                return True
            want_decode = role == "decode"
            return (e.role == "decode") == want_decode

        same = [e for e in self.engines if has_role(e)]
        other = [e for e in self.engines if not has_role(e)]
        for e in same:
            if e.accepting or self.lifecycle_state(e) == "wake":
                return
        for e in same:
            if e in self._draining:
                self.ctl_cancel_drain(e, t)
                self._flush(t)
                return
        for e in same:
            if self.lifecycle_state(e) in ("sleep", "absent"):
                self.ctl_wake(e, t)
                return
        for e in other:
            if self._draining.get(e) == "flip":
                return
        for e in other:
            if self.lifecycle_state(e) in ("sleep", "absent") \
                    and not e.pool.seqs and e._quiescent():
                if self.ctl_flip_asleep(e, t):
                    self.ctl_wake(e, t)
                    return
        for e in other:
            if self._draining.get(e) == "sleep":
                self._draining[e] = "flip"
                self._log(t, "retarget-flip", e)
                self._check_drains(t)
                return
        cands = [e for e in other
                 if e.accepting and e not in self._draining]
        if cands:
            victim = min(cands,
                         key=lambda e: (e.outstanding_tokens(), e.gidx))
            self.ctl_drain(victim, t, then="flip")

    # ---- controller tick scheduling ----------------------------------
    def _work_pending(self) -> bool:
        if self._pending_arrivals or self._parked_requests \
                or self._parked_transfers:
            return True
        return any(not e._quiescent() or e.pool.seqs
                   or getattr(e, "inflight_kv_pages", 0)
                   for e in self.engines)

    def _schedule_tick(self, t: float) -> None:
        def tick(t=t):
            self.controller.on_tick(self, t)
            self._check_drains(t)
            self._rebalance(t)
            if self._work_pending():
                self._schedule_tick(t + self.controller.spec.interval_s)

        self._push(t, tick)

    # ------------------------------------------------------------------
    def _run_loop(self, max_steps: int, fast: bool) -> int:
        """The discrete-event loop. With ``fast=False`` this is the
        retained exact reference: pick the min-clock engine with work,
        fire any heap event due at-or-before its clock first, step it
        once. With ``fast=True`` the same loop first offers the
        candidate set to ``repro.core.fastpath.coalesce_window``, which
        advances every steady-state-decode engine to the next
        interesting time in vectorized runs and returns 0 whenever the
        situation is non-uniform (prefill, fetch, admission, online
        governor, pool pressure) — in which case this loop takes one
        exact step, keeping the two steppers observably identical."""
        order = {e: i for i, e in enumerate(self.engines)}
        steps = 0
        stalled = set()   # engines that made no progress; wait for an event
        while steps < max_steps:
            steps += 1
            candidates = [e for e in self.engines
                          if e not in stalled and e.has_work()]
            t_next_event = self._events[0][0] if self._events else None
            if candidates:
                eng = min(candidates, key=lambda e: e.t)
                # <= so an arrival at exactly the engine's clock is
                # admitted before the step that starts at that instant
                if t_next_event is not None and t_next_event <= eng.t:
                    _, _, fn = heapq.heappop(self._events)
                    fn()
                    stalled.clear()
                    continue
                if fast:
                    n = coalesce_window(candidates, order, t_next_event)
                    if n:
                        self.coalesce_windows += 1
                        self.coalesced_steps += n
                        continue
                if eng.step():
                    # a settling engine may complete a pending drain
                    # (sleep or flip), which can free parked work
                    if self._draining and self._check_drains(eng.t):
                        stalled.clear()
                    # engines SHARING this engine's pool (the intra-gpu
                    # P/D slices) may have stalled on pages this step
                    # just freed — un-stall them, since no heap event
                    # marks an in-HBM free. A no-op for per-engine
                    # pools: a stalled engine never shares a pool with
                    # a progressing one there.
                    if stalled:
                        freed = {s for s in stalled
                                 if s.pool is eng.pool}
                        if freed:
                            stalled -= freed
                else:
                    # no progress (e.g. pool blocked by in-flight stores):
                    # park until the next event frees resources
                    stalled.add(eng)
                continue
            if self._events:
                _, _, fn = heapq.heappop(self._events)
                fn()
                stalled.clear()
                continue
            break
        return steps

    # ------------------------------------------------------------------
    def _power_segments(self, e: Engine, t_start: float, t_end: float
                        ) -> Optional[List[Tuple[float, float, str]]]:
        """Lifecycle segments of [t_start, t_end] for end-of-run power
        attribution, or None for an engine that was simply ON the whole
        run — in which case run() takes the legacy makespan-minus-busy
        branch VERBATIM, keeping static fleets (and the no-op
        controller) bit-identical to pre-controller accounting."""
        lc = self._lifecycle.get(e.name) if self.controller is not None \
            else None
        if lc is None or (len(lc) == 1 and lc[0][1] == "on"):
            return None
        out: List[Tuple[float, float, str]] = []
        for i, (t0, state) in enumerate(lc):
            t1 = lc[i + 1][0] if i + 1 < len(lc) else t_end
            s0, s1 = max(t0, t_start), min(t1, t_end)
            if s1 > s0:
                out.append((s0, s1, state))
        return out

    # ------------------------------------------------------------------
    def run(self, requests: List[Request], max_steps: int = 2_000_000,
            stepper: Optional[str] = None) -> SetupResult:
        stepper = stepper or DEFAULT_STEPPER
        assert stepper in STEPPERS, stepper
        # the bail rule (DESIGN.md section 14): coalescing across a
        # controller's tick events would let fleet state change inside
        # a vectorized window, so controller-active runs take the exact
        # stepper unless the controller declares itself coalescible-
        # quiescent (only the no-op NullController does). Both steppers
        # therefore remain observably identical for every spec. A
        # tiered KV store bails the same way (DESIGN.md section 15):
        # submit-time lookups mutate cross-engine-visible residency and
        # inject tier-fetch occupancy mid-window, so coalescing across
        # them is unsound; flat shared reuse stays fast-eligible (its
        # lookups/inserts live entirely inside exact steps).
        # A non-coalescible SchedulerSpec (chunked-interleave / non-FCFS
        # admission, DESIGN.md section 17) bails identically: composed
        # steps and per-insert re-sorting break the uniform-run
        # precondition. The intra-gpu shape bails too — its two slices
        # share one pool, so a coalesced decode window would hide page
        # frees from the concurrently-stepping prefill slice.
        fast = stepper == "fast" \
            and (self.controller is None or self.controller.coalescible) \
            and not self.tiered \
            and (self.spec.scheduler is None
                 or self.spec.scheduler.coalescible) \
            and not self.spec.is_intra
        self._warm_stores(requests)
        self.submit(requests)
        if self.controller is not None and self.controller.wants_ticks:
            self._schedule_tick(self.controller.spec.interval_s)
        steps = self._run_loop(max_steps, fast=fast)

        unfinished = [r for r in requests if not r.done]
        assert not unfinished, (
            f"{self.setup}: {len(unfinished)} requests never finished "
            f"after {steps} loop iterations (deadlock?)")

        t_start = min(r.arrival_s for r in requests)
        t_end = max(r.finish_s for r in requests)
        makespan = t_end - t_start
        # idle (static) accelerator power over the inference period; the
        # joule lump keeps the exact pre-trace arithmetic (parity
        # goldens), while fill_idle writes the same idle power into the
        # timeline gap-by-gap so each accelerator's power-state trace
        # covers the whole run span. An engine whose lifecycle left the
        # always-on state instead pays segment-by-segment: idle draw
        # only while ON, idle draw (stage "wake") while waking, the
        # sleep residual while ASLEEP, and nothing while ABSENT — the
        # honest attribution that lets scale-to-zero attack the floor.
        trace = self.meter.trace
        for e in self.engines:
            # power comes from the ENGINE's cost model: for every fleet
            # shape but intra-gpu that is self.cost (the same object —
            # bit-identical accounting); an intra slice pays its
            # SM-fraction share of the static floor, so the two slices
            # of one accelerator sum to exactly one accelerator's idle
            # draw (the honest denominator for the energy verdicts)
            segs = self._power_segments(e, t_start, t_end)
            if segs is None:
                idle_s = max(makespan - e.busy_s, 0.0)
                self.meter.add_power(e.name, e.cost.idle_power_w(),
                                     idle_s, stage="idle")
                if trace is not None:
                    trace.fill_idle(e.name, t_start, t_end,
                                    e.cost.idle_power_w())
                continue
            for s0, s1, state in segs:
                if state == "on":
                    filled = trace.fill_idle(e.name, s0, s1,
                                             e.cost.idle_power_w())
                    self.meter.add(e.name,
                                   e.cost.idle_power_w() * filled,
                                   stage="idle")
                elif state == "wake":
                    self.meter.add_power(e.name, e.cost.idle_power_w(),
                                         s1 - s0, stage="wake", t0=s0,
                                         state=IDLE)
                elif state == "sleep":
                    self.meter.add_power(e.name, e.cost.sleep_power_w(),
                                         s1 - s0, stage="sleep", t0=s0,
                                         state=SLEEP)
                else:   # absent: 0 W, explicit interval (never idle-filled)
                    self.meter.add_power(e.name, 0.0, s1 - s0,
                                         stage="absent", t0=s0,
                                         state=ABSENT)
        # host-node baseline draw (IPMI-style whole-node accounting)
        self.meter.add_power("cpu", self.host.cpu_idle_w, makespan, "idle",
                             t0=t_start)
        self.meter.add_power("dram", self.host.dram_idle_w, makespan,
                             "idle", t0=t_start)
        self.meter.add_power("disk", self.host.disk_idle_w, makespan,
                             "idle", t0=t_start)

        total_tokens = sum(r.prompt_len + r.generated for r in requests)
        return SetupResult(setup=self.setup, metrics=summarize(requests),
                           energy=self.meter, requests=requests,
                           makespan_s=makespan, total_tokens=total_tokens)
