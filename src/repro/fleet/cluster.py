"""FleetCluster: the discrete-event serving loop over an xP:yD fleet.

The generalization of the paper's five two-accelerator setups to
arbitrary fleet shapes (``FleetSpec``): x prefill + y decode instances
(or n colocated), each with its own ``PagedKVPool``, per-instance DVFS
setting, and energy attribution under one shared ``EnergyMeter``.
Arriving requests are routed to a prefill instance by the frontend
``Router`` at their arrival event; a finished prefill's KV cache is
routed to a decode instance by the KV router at prefill completion and
streamed over that (prefill, decode) pair's own ``TransferPath`` — any
prefill instance can feed any decode instance over ici/host/disk.

The event loop, transfer legs, and energy integration are the ones the
1P:1D ``Cluster`` always ran (it is now a thin facade over this class,
see ``repro.core.orchestrator``); the parity regression in
``tests/test_fleet.py`` pins the 1P:1D and colocated special cases to
the pre-fleet metrics bit-for-bit.
"""
from __future__ import annotations

import heapq
import itertools
import os
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Tuple, Union

from repro.configs.base import ModelConfig
from repro.core.costs import AcceleratorSpec, CostModel, HostSpec
from repro.core.energy import EnergyMeter
from repro.core.engine import Engine, EngineSeq, RealExecutor
from repro.core.fastpath import coalesce_window
from repro.core.kvcache import PagedKVPool
from repro.core.request import Request, WorkloadMetrics, summarize
from repro.core.transfer import TransferPath, make_path
from repro.govern import make_governor
from repro.govern.telemetry import PowerTrace

from .router import Router
from .spec import FleetSpec, as_fleet_spec

Phi = Union[float, Tuple[float, ...]]

# Default stepper for FleetCluster.run: "fast" coalesces steady-state
# decode runs (repro.core.fastpath), "exact" is the retained one-step-
# per-token reference the parity harness differentially tests against.
# The two are observably identical (tests/test_fastpath_parity.py);
# REPRO_STEPPER=exact flips the default for debugging a suspect run.
STEPPERS = ("fast", "exact")
DEFAULT_STEPPER = os.environ.get("REPRO_STEPPER", "fast")


@dataclass
class SetupResult:
    setup: str
    metrics: WorkloadMetrics
    energy: EnergyMeter
    requests: List[Request]
    makespan_s: float
    total_tokens: int

    @property
    def joules_per_token(self) -> float:
        return self.energy.total_j / max(self.total_tokens, 1)


class FleetCluster:
    def __init__(self, spec: Union[str, FleetSpec], cfg: ModelConfig, *,
                 acc: Optional[AcceleratorSpec] = None,
                 host: Optional[HostSpec] = None,
                 phi: Optional[float] = None,
                 phi_prefill: Optional[Phi] = None,
                 phi_decode: Optional[Phi] = None,
                 governor: Optional[Union[str, Tuple[str, ...]]] = None,
                 page_size: int = 16,
                 prefill_token_budget: int = 8192,
                 pool_bytes: Optional[float] = None,
                 executor_factory: Optional[Callable[
                     [Optional[TransferPath]], RealExecutor]] = None):
        spec = as_fleet_spec(spec)
        if phi is not None or phi_prefill is not None \
                or phi_decode is not None:
            spec = spec.with_phi(phi=phi, phi_prefill=phi_prefill,
                                 phi_decode=phi_decode)
        if governor is not None:
            # sweep-plumbing override, mirroring the phi kwargs: any
            # entry point taking **cluster_kw can run a governor
            from dataclasses import replace
            spec = replace(spec, governor=governor)
        self.spec = spec
        self.setup = spec.name
        self.cfg = cfg
        self.acc = acc or AcceleratorSpec()
        self.host = host or HostSpec()
        self.cost = CostModel(cfg, self.acc, self.host)
        # every run carries a power-state timeline (repro.govern): the
        # trace is observational — joule totals use the same call
        # sequence with or without it, so parity goldens stay bit-exact
        self.meter = EnergyMeter(trace=PowerTrace())
        pool_bytes = pool_bytes or self.acc.kv_pool_gb * 1e9
        kv_per_tok = max(self.cost.kv_bytes_per_token, 1)

        def new_pool():
            return PagedKVPool.from_bytes(pool_bytes, kv_per_tok, page_size)

        self.engines: List[Engine] = []
        self.prefill_engines: List[Engine] = []
        self.decode_engines: List[Engine] = []
        # one TransferPath per (prefill, decode) pair: media with real
        # per-connection state (disk scratch files, staging buffers)
        # stay independent, and a future heterogeneous-media fleet only
        # has to change this map
        self.paths: Dict[Tuple[int, int], TransferPath] = {}
        self._events: List = []   # heap of (t, tiebreak, fn)
        self._counter = itertools.count()

        if spec.is_colocated:
            for i, phi_i in enumerate(spec.phis_prefill):
                ex = executor_factory(None) if executor_factory else None
                self.engines.append(Engine(
                    f"acc{i}", "colocated", self.cost, new_pool(),
                    self.meter, phi=phi_i,
                    prefill_token_budget=prefill_token_budget, executor=ex))
            self.prefill_engines = self.engines
        else:
            x, y = spec.n_prefill, spec.n_decode
            for i in range(x):
                for j in range(y):
                    self.paths[(i, j)] = make_path(spec.medium, self.host)
            # engine executors are built path-less: the (prefill, decode)
            # pair — hence the path the real bytes travel — is only known
            # at transfer time, so _transfer runs the pair path's
            # store()/fetch() around the executor's payload
            for i, phi_i in enumerate(spec.phis_prefill):
                ex = executor_factory(None) if executor_factory else None
                eng = Engine(f"acc{i}", "prefill", self.cost, new_pool(),
                             self.meter, phi=phi_i,
                             prefill_token_budget=prefill_token_budget,
                             executor=ex, on_prefill_done=self._transfer)
                eng.fleet_index = i
                self.prefill_engines.append(eng)
            for j, phi_j in enumerate(spec.phis_decode):
                ex = executor_factory(None) if executor_factory else None
                eng = Engine(f"acc{x + j}", "decode", self.cost, new_pool(),
                             self.meter, phi=phi_j,
                             prefill_token_budget=prefill_token_budget,
                             executor=ex)
                eng.fleet_index = j
                # pages for transfers routed here but still in their
                # store leg (not yet in decode_queue): the kv-free-space
                # router subtracts this, else every prefill finishing
                # within one store-latency window picks the same target
                eng.inflight_kv_pages = 0
                self.decode_engines.append(eng)
            self.engines = self.prefill_engines + self.decode_engines

        # one governor instance per engine (controllers are stateful;
        # per-engine seeds keep any future stochastic policy decoupled
        # across instances). The default StaticGovernor keeps the
        # spec-configured phi — a no-op on the timing/energy stream.
        for idx, (eng, gname) in enumerate(zip(self.engines,
                                               spec.governors)):
            eng.governor = make_governor(gname,
                                         seed=spec.seed + 1000 + idx)

        # legacy attribute: the single transfer path of a 1P:1D fleet
        self.path: Optional[TransferPath] = self.paths.get((0, 0)) \
            if len(self.paths) == 1 else None

        self.frontend = Router(self.prefill_engines, spec.router, spec.seed)
        self.kv_router = Router(self.decode_engines, spec.kv_router,
                                spec.seed + 1) \
            if self.decode_engines else None

    # ------------------------------------------------------------------
    def _push(self, t: float, fn: Callable[[], None]) -> None:
        heapq.heappush(self._events, (t, next(self._counter), fn))

    # ------------------------------------------------------------------
    def _transfer(self, engine: Engine, seq: EngineSeq, t_done: float):
        """Store leg: runs right after prefill; pages stay held on the
        prefill accelerator until the store completes. The decode target
        is picked HERE (not at arrival), so the KV router sees decode
        pool pressure at transfer time."""
        dec = self.kv_router.pick()
        path = self.paths[(engine.fleet_index, dec.fleet_index)]
        nbytes = self.cost.kv_bytes(seq.ctx)
        store = path.store_cost(nbytes)
        fetch = path.fetch_cost(nbytes)
        # the store leg belongs to the PREFILL side of the handoff
        # (transfer-fetch is added by the decode engine at admission):
        # the DVFS sweeps attribute each leg's joules to its stage from
        # the routed pair's actual LegCost, not an arbitrary 50/50 split
        for comp, joules in store.energy_j.items():
            self.meter.add(comp, joules, stage="transfer-store")
        handle = None
        if engine.executor is not None:
            # real byte movement over the ROUTED pair's path (the
            # path-less executor just packages the state payload)
            handle = path.store(engine.executor.store(seq))

        t_arrive = t_done + store.latency_s
        seq.req.transfer_done_s = t_arrive
        reserve = seq.ctx + (seq.req.output_len - seq.req.generated) + 1
        inflight = dec.pool.pages_for(reserve)
        dec.inflight_kv_pages += inflight

        def deliver():
            engine.pool.free_seq(seq.seq_id)
            # both engines resume no earlier than the store completion:
            # the prefill engine may have been blocked on pool space
            engine.t = max(engine.t, t_arrive)
            # the reservation migrates from in-flight to decode_queue,
            # where the router's headroom counts it instead
            dec.inflight_kv_pages -= inflight
            payload = path.fetch(handle) if handle is not None else None
            dec.enqueue_decode(seq, payload, fetch)
            dec.t = max(dec.t, t_arrive)

        self._push(t_arrive, deliver)

    # ------------------------------------------------------------------
    def submit(self, requests: List[Request]) -> None:
        """Route every request through the event heap at its
        ``arrival_s``: an engine never sees a request before it arrives
        (submitting upfront let a staggered arrival be prefilled at t=0,
        yielding negative TTFT), and the frontend router scores live
        queue depths at the arrival instant rather than at submission.
        ``Engine.submit`` fast-forwards an idle engine's clock to the
        arrival instant; a busy engine (clock already past it) just
        queues the request."""
        for r in requests:
            self._push(r.arrival_s,
                       lambda r=r: self.frontend.pick().submit(r))

    # ------------------------------------------------------------------
    def _run_loop(self, max_steps: int, fast: bool) -> int:
        """The discrete-event loop. With ``fast=False`` this is the
        retained exact reference: pick the min-clock engine with work,
        fire any heap event due at-or-before its clock first, step it
        once. With ``fast=True`` the same loop first offers the
        candidate set to ``repro.core.fastpath.coalesce_window``, which
        advances every steady-state-decode engine to the next
        interesting time in vectorized runs and returns 0 whenever the
        situation is non-uniform (prefill, fetch, admission, online
        governor, pool pressure) — in which case this loop takes one
        exact step, keeping the two steppers observably identical."""
        order = {e: i for i, e in enumerate(self.engines)}
        steps = 0
        stalled = set()   # engines that made no progress; wait for an event
        while steps < max_steps:
            steps += 1
            candidates = [e for e in self.engines
                          if e not in stalled and e.has_work()]
            t_next_event = self._events[0][0] if self._events else None
            if candidates:
                eng = min(candidates, key=lambda e: e.t)
                # <= so an arrival at exactly the engine's clock is
                # admitted before the step that starts at that instant
                if t_next_event is not None and t_next_event <= eng.t:
                    _, _, fn = heapq.heappop(self._events)
                    fn()
                    stalled.clear()
                    continue
                if fast and coalesce_window(candidates, order,
                                            t_next_event):
                    continue
                if not eng.step():
                    # no progress (e.g. pool blocked by in-flight stores):
                    # park until the next event frees resources
                    stalled.add(eng)
                continue
            if self._events:
                _, _, fn = heapq.heappop(self._events)
                fn()
                stalled.clear()
                continue
            break
        return steps

    # ------------------------------------------------------------------
    def run(self, requests: List[Request], max_steps: int = 2_000_000,
            stepper: Optional[str] = None) -> SetupResult:
        stepper = stepper or DEFAULT_STEPPER
        assert stepper in STEPPERS, stepper
        self.submit(requests)
        steps = self._run_loop(max_steps, fast=(stepper == "fast"))

        unfinished = [r for r in requests if not r.done]
        assert not unfinished, (
            f"{self.setup}: {len(unfinished)} requests never finished "
            f"after {steps} loop iterations (deadlock?)")

        t_start = min(r.arrival_s for r in requests)
        t_end = max(r.finish_s for r in requests)
        makespan = t_end - t_start
        # idle (static) accelerator power over the inference period; the
        # joule lump keeps the exact pre-trace arithmetic (parity
        # goldens), while fill_idle writes the same idle power into the
        # timeline gap-by-gap so each accelerator's power-state trace
        # covers the whole run span
        trace = self.meter.trace
        for e in self.engines:
            idle_s = max(makespan - e.busy_s, 0.0)
            self.meter.add_power(e.name, self.cost.idle_power_w(), idle_s,
                                 stage="idle")
            if trace is not None:
                trace.fill_idle(e.name, t_start, t_end,
                                self.cost.idle_power_w())
        # host-node baseline draw (IPMI-style whole-node accounting)
        self.meter.add_power("cpu", self.host.cpu_idle_w, makespan, "idle",
                             t0=t_start)
        self.meter.add_power("dram", self.host.dram_idle_w, makespan,
                             "idle", t0=t_start)
        self.meter.add_power("disk", self.host.disk_idle_w, makespan,
                             "idle", t0=t_start)

        total_tokens = sum(r.prompt_len + r.generated for r in requests)
        return SetupResult(setup=self.setup, metrics=summarize(requests),
                           energy=self.meter, requests=requests,
                           makespan_s=makespan, total_tokens=total_tokens)
