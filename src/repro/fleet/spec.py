"""FleetSpec: the shape of an xP:yD (or n-colocated) serving fleet.

The paper's five experimental setups are the smallest possible fleets —
one or two accelerators. P/D-Serve (arXiv 2408.08147) and FlowKV
(arXiv 2504.03775) show that at production scale the interesting knobs
are the prefill:decode instance *ratio* and how KV transfers are routed
across the pool; ``FleetSpec`` makes both first-class. A spec is a
frozen, hashable value object (sweep caches key on it) that fully
determines the fleet:

  * ``n_prefill`` x ``n_decode`` disaggregated instances with a KV
    ``medium`` (ici / host / disk), every (prefill, decode) pair getting
    its own ``TransferPath``; or ``n_colocated`` instances with no
    transfer at all; or ``n_intra`` intra-GPU-disaggregated accelerators
    (RAPID-Serve): each accelerator SM-partitioned into a prefill slice
    and a decode slice via ``CostModel.slice(intra_split)``, KV shared
    in-place in one pool — a sixth shape *between* co and dis, with
    per-slice phi/power but no transfer leg at all.
  * ``scheduler`` (repro.sched): the per-step batch-composition and
    admission policy of every engine. None = the legacy
    serialize-prefill FCFS engine byte-for-byte (spec encodings omit
    the key so every existing exp-cache hash is preserved).
  * per-instance DVFS settings: ``phi_prefill`` / ``phi_decode`` are a
    scalar (applied to every instance of the stage) or a tuple with one
    entry per instance — heterogeneous-frequency fleets fall out free.
  * ``router`` (frontend: which instance prefills a request) and
    ``kv_router`` (which decode instance receives the KV cache) name
    policies from ``repro.fleet.router``; ``seed`` drives their
    deterministic tie-breaking.

The legacy setup names map through ``FleetSpec.from_setup``: the
``Cluster`` facade in ``repro.core.orchestrator`` is exactly
``FleetCluster(FleetSpec.from_setup(setup), ...)``.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, replace
from typing import Optional, Tuple, Union

from repro.kvstore import ReuseSpec, as_reuse_spec
from repro.sched import SchedulerSpec, as_scheduler_spec

from .controller import ControllerSpec, as_controller_spec

# mirrors repro.core.orchestrator (defined here to keep the import
# direction fleet <- core.orchestrator acyclic; orchestrator re-exports)
SETUPS = ("co-1gpu", "co-2gpus", "dis-ici", "dis-host", "dis-disk")
DIS_PATH = {"dis-ici": "ici", "dis-host": "host", "dis-disk": "disk"}
MEDIA = ("ici", "host", "disk")

Phi = Union[float, Tuple[float, ...]]


def _canon_phi(value: Phi) -> Phi:
    """Scalar -> float, any sequence -> tuple of floats: list-valued or
    int-valued phis must hash and compare like their canonical twins
    (sweep caches key on the frozen spec)."""
    if isinstance(value, (int, float)):
        return float(value)
    return tuple(float(v) for v in value)


def _per_instance(value: Phi, n: int, what: str) -> Tuple[float, ...]:
    """Broadcast a scalar phi (or validate a per-instance tuple) to n."""
    if isinstance(value, (int, float)):
        vals = (float(value),) * n
    else:
        vals = tuple(float(v) for v in value)
        if len(vals) != n:
            raise ValueError(
                f"{what}: got {len(vals)} per-instance values for "
                f"{n} instances")
    if any(v <= 0 for v in vals):
        raise ValueError(f"{what}: phi must be > 0, got {vals}")
    return vals


@dataclass(frozen=True)
class FleetSpec:
    """x prefill + y decode instances over one KV medium, or n colocated."""
    n_prefill: int = 0
    n_decode: int = 0
    n_colocated: int = 0
    medium: Optional[str] = None        # ici / host / disk (disaggregated)
    phi_prefill: Phi = 1.0              # scalar or per-instance tuple
    phi_decode: Phi = 1.0
    router: str = "least-outstanding-tokens"   # frontend request routing
    kv_router: str = "kv-free-space"           # prefill-done -> decode
    seed: int = 0                              # tie-break determinism
    # online DVFS controller per instance (repro.govern): a registry
    # name applied to every engine, or a tuple with one name per engine
    # (prefill instances first, then decode). "static" keeps the
    # configured phi — bit-identical to pre-governor behavior.
    governor: Union[str, Tuple[str, ...]] = "static"
    # online fleet controller (repro.fleet.controller): autoscaling,
    # P<->D role-flipping, scale-to-zero. None = a static fleet (the
    # pre-controller code path, byte-for-byte — spec encodings omit the
    # key entirely so every existing exp-cache hash is preserved).
    # Accepts a policy name, a ControllerSpec, or a kwargs dict.
    controller: Optional[Union[str, dict, ControllerSpec]] = None
    # KV reuse at the fleet level (repro.kvstore, DESIGN.md section 15):
    # None = no reuse (the pre-reuse code path byte-for-byte — spec
    # encodings omit the key so every existing exp-cache hash is
    # preserved); a flat ReuseSpec attaches one shared PrefixCache to
    # every engine; a ReuseSpec with ``tiers`` set attaches a per-engine
    # TieredKVStore (and makes the fast stepper bail to exact). Accepts
    # a mode string ("prefix"/"pic"), a kwargs dict, or a ReuseSpec.
    reuse: Optional[Union[str, dict, ReuseSpec]] = None
    # per-step batch composition + admission order (repro.sched,
    # DESIGN.md section 17): None = the legacy serialize-prefill FCFS
    # engine byte-for-byte (spec encodings omit the key so every
    # existing exp-cache hash is preserved). Accepts a composer or
    # admission name ("chunked-interleave", "srpt", ...), a kwargs
    # dict, or a SchedulerSpec. Non-coalescible schedulers make the
    # fast stepper bail to exact.
    scheduler: Optional[Union[str, dict, SchedulerSpec]] = None
    # intra-GPU P/D disaggregation (the sixth setup): n_intra
    # accelerators, each split into a prefill slice of ``intra_split``
    # of the SMs/HBM-bandwidth/power rails and a decode slice of the
    # rest. Mutually exclusive with both n_colocated and xP:yD; no
    # medium (the KV pages never move — handoff is free and instant).
    n_intra: int = 0
    intra_split: float = 0.5

    # ------------------------------------------------------------------
    def __post_init__(self):
        object.__setattr__(self, "phi_prefill",
                           _canon_phi(self.phi_prefill))
        object.__setattr__(self, "phi_decode",
                           _canon_phi(self.phi_decode))
        object.__setattr__(self, "intra_split", float(self.intra_split))
        if self.n_intra:
            if self.n_prefill or self.n_decode or self.n_colocated:
                raise ValueError(
                    "a fleet is exactly one shape: got "
                    f"n_intra={self.n_intra} with n_colocated="
                    f"{self.n_colocated} / "
                    f"{self.n_prefill}P:{self.n_decode}D")
            if self.medium is not None:
                raise ValueError(
                    "intra-GPU fleets share KV in place: no medium")
            if not 0.0 < self.intra_split < 1.0:
                raise ValueError(
                    "intra_split is the prefill slice's SM fraction: "
                    f"need 0 < s < 1, got {self.intra_split}")
            if self.controller is not None:
                raise ValueError(
                    "fleet controllers (autoscale / role-flip) do not "
                    "apply to intra-GPU slices: the P/D split is a "
                    "static SM partition of one accelerator")
        elif self.n_colocated:
            if self.n_prefill or self.n_decode:
                raise ValueError(
                    "a fleet is either colocated or disaggregated: got "
                    f"n_colocated={self.n_colocated} with "
                    f"{self.n_prefill}P:{self.n_decode}D")
            if self.medium is not None:
                raise ValueError("colocated fleets have no KV medium")
            if self.n_colocated < 1:
                raise ValueError("n_colocated must be >= 1")
        else:
            if self.n_prefill < 1 or self.n_decode < 1:
                raise ValueError(
                    f"need >= 1 instance per stage, got "
                    f"{self.n_prefill}P:{self.n_decode}D")
            if self.medium not in MEDIA:
                raise ValueError(
                    f"disaggregated fleets need medium in {MEDIA}, "
                    f"got {self.medium!r}")
        if not isinstance(self.governor, str):
            object.__setattr__(self, "governor",
                               tuple(str(g) for g in self.governor))
        if self.controller is not None:
            object.__setattr__(self, "controller",
                               as_controller_spec(self.controller))
        if self.reuse is not None:
            object.__setattr__(self, "reuse", as_reuse_spec(self.reuse))
        if self.scheduler is not None:
            object.__setattr__(self, "scheduler",
                               as_scheduler_spec(self.scheduler))
        # broadcast now so a malformed tuple fails at spec construction
        self.phis_prefill
        self.phis_decode
        self.governors

    # ------------------------------------------------------------------
    @property
    def is_colocated(self) -> bool:
        return self.n_colocated > 0

    @property
    def is_intra(self) -> bool:
        """Intra-GPU P/D disaggregation: P and D slices of ONE
        accelerator, KV shared in-place (no transfer leg)."""
        return self.n_intra > 0

    @property
    def is_disaggregated(self) -> bool:
        """Cross-accelerator disaggregation (KV moves over a medium).
        Intra-GPU fleets are *not* disaggregated in this sense: their
        handoff never leaves HBM."""
        return not self.is_colocated and not self.is_intra

    @property
    def num_engines(self) -> int:
        if self.n_intra:
            return 2 * self.n_intra    # one P slice + one D slice each
        return self.n_colocated or (self.n_prefill + self.n_decode)

    @property
    def phis_prefill(self) -> Tuple[float, ...]:
        n = self.n_colocated or self.n_prefill or self.n_intra
        return _per_instance(self.phi_prefill, n, "phi_prefill")

    @property
    def phis_decode(self) -> Tuple[float, ...]:
        if self.is_colocated:
            return ()
        n = self.n_decode or self.n_intra
        return _per_instance(self.phi_decode, n, "phi_decode")

    @property
    def governors(self) -> Tuple[str, ...]:
        """Per-engine governor names, broadcast like the phis (engine
        order: prefill instances, then decode; or the colocated set).
        Name validity is checked by ``repro.govern.make_governor`` at
        cluster construction, keeping this module import-light."""
        n = self.num_engines
        if isinstance(self.governor, str):
            return (self.governor,) * n
        if len(self.governor) != n:
            raise ValueError(
                f"governor: got {len(self.governor)} per-instance names "
                f"for {n} engines")
        return self.governor

    @property
    def name(self) -> str:
        """Sweep-row label, e.g. ``2P2D-ici``, ``co-2``, ``intra-gpu``."""
        if self.is_intra:
            return "intra-gpu" if self.n_intra == 1 \
                else f"intra-{self.n_intra}"
        if self.is_colocated:
            return f"co-{self.n_colocated}"
        return f"{self.n_prefill}P{self.n_decode}D-{self.medium}"

    # ------------------------------------------------------------------
    @classmethod
    def colocated(cls, n: int, **kw) -> "FleetSpec":
        return cls(n_colocated=n, **kw)

    @classmethod
    def disaggregated(cls, n_prefill: int, n_decode: int,
                      medium: str = "ici", **kw) -> "FleetSpec":
        return cls(n_prefill=n_prefill, n_decode=n_decode, medium=medium,
                   **kw)

    @classmethod
    def from_setup(cls, setup: str, **kw) -> "FleetSpec":
        """The five legacy setups as minimal fleets (the Cluster facade)."""
        if setup not in SETUPS:
            raise ValueError(f"unknown setup {setup!r}; "
                             f"choose from {SETUPS}")
        if setup == "co-1gpu":
            return cls.colocated(1, **kw)
        if setup == "co-2gpus":
            return cls.colocated(2, **kw)
        return cls.disaggregated(1, 1, medium=DIS_PATH[setup], **kw)

    _NAME_RE = re.compile(r"^(\d+)P(\d+)D-(ici|host|disk)$")

    @classmethod
    def parse(cls, name: str, **kw) -> "FleetSpec":
        """Inverse of ``.name`` — ``"2P2D-ici"`` / ``"co-3"`` — also
        accepting the five legacy setup names (CLI flags and sweep-row
        labels round-trip through this)."""
        if name in SETUPS:
            return cls.from_setup(name, **kw)
        if name == "intra-gpu":
            return cls(n_intra=1, **kw)
        if name.startswith("intra-") and name[6:].isdigit():
            return cls(n_intra=int(name[6:]), **kw)
        if name.startswith("co-") and name[3:].isdigit():
            return cls.colocated(int(name[3:]), **kw)
        m = cls._NAME_RE.match(name)
        if m:
            return cls.disaggregated(int(m.group(1)), int(m.group(2)),
                                     m.group(3), **kw)
        raise ValueError(
            f"cannot parse fleet shape {name!r}: expected a setup name "
            f"{SETUPS}, 'co-<n>', 'intra-gpu'/'intra-<n>', or "
            f"'<x>P<y>D-<ici|host|disk>'")

    # ------------------------------------------------------------------
    def with_phi(self, phi: Optional[float] = None,
                 phi_prefill: Optional[Phi] = None,
                 phi_decode: Optional[Phi] = None) -> "FleetSpec":
        """Cluster-style frequency overrides: ``phi`` sets every stage
        unless a stage-specific value is given (the DVFS sweeps use
        this to re-run one spec across the frequency grid)."""
        pp = phi_prefill if phi_prefill is not None else \
            (phi if phi is not None else self.phi_prefill)
        pd = phi_decode if phi_decode is not None else \
            (phi if phi is not None else self.phi_decode)
        return replace(self, phi_prefill=pp, phi_decode=pd)


def as_fleet_spec(setup: Union[str, FleetSpec]) -> FleetSpec:
    """Normalize any accepted setup form — a FleetSpec, a legacy setup
    name, or a fleet-shape string like ``"2P2D-ici"`` / ``"co-3"``."""
    if isinstance(setup, FleetSpec):
        return setup
    return FleetSpec.parse(setup)


def setup_label(setup: Union[str, FleetSpec]) -> str:
    """Human/sweep-row label for either form."""
    return setup if isinstance(setup, str) else setup.name
