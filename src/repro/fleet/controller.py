"""Online fleet controllers: autoscaling, P<->D role-flipping, and
scale-to-zero (DESIGN.md section 14).

PR 4's fig8 proved the paper's negative energy verdict for
disaggregation is an *idle-power floor* — static fleets pay
``p_static_w`` on every provisioned accelerator for the whole run, and
no per-step DVFS policy can reach below it. The counter-moves all
require changing the fleet itself while it serves: put idle instances
into a deep-sleep state (``p_sleep_w`` residual draw, wake costs
latency), wake them against backlog, and flip a surplus instance's
prefill<->decode role as the goodput-optimal P:D ratio drifts with the
length mix (P/D-Serve's at-scale dynamic ratio adjustment, DualScale's
phase-aware placement — PAPERS.md).

The hook contract mirrors ``govern.Governor.on_step``: a controller is
a pure, seed-deterministic object the cluster calls at fixed simulated
intervals (``on_tick(cluster, t)``), acting only through the cluster's
lifecycle primitives (``ctl_wake`` / ``ctl_sleep`` / ``ctl_drain`` /
``ctl_flip_asleep``).  Determinism matters twice over: a fleet run must
be reproducible from ``(spec, workload)`` alone, and the differential
parity harness re-runs the same spec through both steppers.  A
controller whose actions depend on anything but cluster state at tick
time would break both.

Stepper interaction (the bail rule): the coalescing fast stepper
advances engines through vectorized decode runs *between* events, which
is only valid if nothing can change fleet state inside a window.  Tick
events bound every window, so a controller that never acts outside its
tick handler is safe — but conservatively, ``FleetCluster.run`` bails
to the exact stepper unless the controller declares itself
``coalescible`` (only the no-op ``NullController`` does).  Parity
between steppers therefore holds trivially for active controllers and
is fuzz-verified for the null one.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Union

import numpy as np


@dataclass(frozen=True)
class ControllerSpec:
    """Frozen, hashable controller configuration.

    Lives on ``FleetSpec.controller`` so the ``repro.exp``
    content-addressed cache keys on it like every other knob; every
    field is a scalar so the canonical-JSON spec hash stays trivial.
    ``policy`` names a class in ``CONTROLLERS``; the remaining fields
    parameterize whichever policy is chosen (unused ones are inert but
    still hash — two specs differing only in an inert field re-run,
    which is correct-if-conservative).
    """
    policy: str = "adaptive"
    # simulated seconds between on_tick invocations
    interval_s: float = 0.25
    # latency (not extra energy beyond idle draw) to wake a sleeping or
    # absent instance; the honest cost of scale-to-zero
    wake_latency_s: float = 0.5
    # idle dwell before the adaptive policy deep-sleeps an instance
    sleep_after_s: float = 1.0
    # never sleep below these awake floors (0 = true scale-to-zero)
    min_awake_prefill: int = 0
    min_awake_decode: int = 0
    # instances awake at t=0; -1 = all. The rest start ABSENT (never
    # provisioned) — they are woken on demand and their pre-wake window
    # is attributed at 0 W, not back-filled idle joules.
    initial_awake_prefill: int = -1
    initial_awake_decode: int = -1
    allow_flip: bool = True
    allow_sleep: bool = True
    # decode backlog per awake decode instance that triggers a wake
    wake_backlog_tokens: int = 4096
    # prefill backlog is judged against this TTFT budget (projected
    # queue delay > slo_safety * target_ttft_s wakes an instance)
    target_ttft_s: float = 2.0
    slo_safety: float = 0.7

    def __post_init__(self):
        if self.interval_s <= 0:
            raise ValueError(f"interval_s must be > 0, got {self.interval_s}")
        if self.wake_latency_s < 0 or self.sleep_after_s < 0:
            raise ValueError("wake_latency_s / sleep_after_s must be >= 0")


def as_controller_spec(
        value: Union[str, dict, ControllerSpec, None]
) -> Optional[ControllerSpec]:
    """Normalize the accepted ``FleetSpec.controller`` forms: a policy
    name, a kwargs dict (how decoded experiment specs arrive), a spec,
    or None."""
    if value is None or isinstance(value, ControllerSpec):
        return value
    if isinstance(value, str):
        return ControllerSpec(policy=value)
    if isinstance(value, dict):
        return ControllerSpec(**value)
    raise TypeError(f"cannot interpret controller spec {value!r}")


# ----------------------------------------------------------------------
class FleetController:
    """Base: ``on_tick(cluster, t)`` acts through the cluster's
    lifecycle primitives. Stateful (idle-dwell tracking, rng), so build
    a fresh instance per cluster (``make_controller``)."""

    name = "base"
    # a coalescible controller guarantees it never changes fleet state
    # (the fast stepper may coalesce across its ticks); anything that
    # can sleep/wake/flip must leave this False so runs bail to exact
    coalescible = False
    # whether the cluster should schedule periodic tick events at all
    wants_ticks = True

    def __init__(self, spec: ControllerSpec, seed: int = 0):
        self.spec = spec
        self.rng = np.random.default_rng(seed)

    def on_tick(self, cluster, t: float) -> None:
        raise NotImplementedError


class NullController(FleetController):
    """The static-equivalent no-op: never sleeps, wakes, or flips.
    Exists so the test layer can prove plumbing a controller through
    the cluster leaves every golden bit-identical (routers see the same
    candidate lists, the fast stepper stays engaged)."""

    name = "null"
    coalescible = True
    wants_ticks = False

    def on_tick(self, cluster, t):
        pass


class AdaptiveController(FleetController):
    """Backlog/SLO-slack-driven autoscaling + role-flipping +
    scale-to-zero — the policy fig9 sweeps.

    Per tick, in order:
      sleep  an awake instance idle for >= ``sleep_after_s`` (pool
             empty, nothing in flight, awake floor respected);
      wake   a sleeping/absent prefill instance when the projected
             prefill queue delay exceeds the TTFT budget, or a decode
             one when per-instance decode backlog exceeds
             ``wake_backlog_tokens``;
      flip   when the awake P:D split deviates >= 1 instance from the
             work-optimal ratio (remaining prefill vs decode tokens
             weighted by roofline per-token times): repurpose a
             sleeping surplus-role instance in place if one exists,
             else drain the least-loaded awake one (at most one
             drain-to-flip in flight).
    """

    name = "adaptive"

    def __init__(self, spec, seed=0):
        super().__init__(spec, seed)
        self._idle_since = {}          # engine name -> first-idle tick t
        self._rates = None             # (s/token prefill, s/token decode)

    # -- roofline per-token times, cached once per run ------------------
    def _per_token_s(self, cluster):
        if self._rates is None:
            cost = cluster.cost
            cp = 1.0 / cost.prefill_rate_tok_s(1.0)
            # nominal steady decode batch of 8 at 1k context each
            cd = cost.decode_cost(8, 8 * 1024).time(1.0) / 8.0
            self._rates = (cp, cd)
        return self._rates

    @staticmethod
    def _engine_idle(e) -> bool:
        return e._quiescent() and not e.pool.seqs \
            and not getattr(e, "inflight_kv_pages", 0)

    def on_tick(self, cluster, t):
        spec = self.spec
        colo = cluster.spec.is_colocated
        state = cluster.lifecycle_state
        awake = [e for e in cluster.engines
                 if state(e) == "on" and e not in cluster._draining]
        asleep = [e for e in cluster.engines
                  if state(e) in ("sleep", "absent")]

        def role_of(e):
            return "prefill" if colo or e.role != "decode" else "decode"

        # backlogs in tokens (parked work counts toward its stage)
        back_p = sum(r.prompt_len for r in cluster._parked_requests)
        back_d = sum(s.req.output_len - s.req.generated
                     for _, s, _ in cluster._parked_transfers)
        for e in cluster.engines:
            if role_of(e) == "prefill":
                back_p += e.outstanding_tokens()
            else:
                back_d += e.outstanding_tokens()

        # ---- sleep: idle-dwell tracked per instance -------------------
        if spec.allow_sleep:
            floors = {"prefill": spec.min_awake_prefill,
                      "decode": spec.min_awake_decode}
            n_awake = {"prefill": sum(role_of(e) == "prefill"
                                      for e in awake),
                       "decode": sum(role_of(e) == "decode"
                                     for e in awake)}
            parked = {"prefill": bool(cluster._parked_requests),
                      "decode": bool(cluster._parked_transfers)}
            for e in awake:
                if not self._engine_idle(e):
                    self._idle_since.pop(e.name, None)
                    continue
                since = self._idle_since.setdefault(e.name, t)
                role = role_of(e)
                if (t - since >= spec.sleep_after_s
                        and n_awake[role] > floors[role]
                        and not parked[role]):
                    if cluster.ctl_sleep(e, t):
                        n_awake[role] -= 1
                        self._idle_since.pop(e.name, None)

        # ---- wake against backlog / SLO slack -------------------------
        cp, cd = self._per_token_s(cluster)
        awake_p = [e for e in awake if role_of(e) == "prefill"
                   and e not in cluster._draining]
        awake_d = [e for e in awake if role_of(e) == "decode"
                   and e not in cluster._draining]
        budget_s = spec.slo_safety * spec.target_ttft_s
        if back_p > 0 and (not awake_p
                           or back_p * cp / len(awake_p) > budget_s):
            for e in asleep:
                if role_of(e) == "prefill":
                    cluster.ctl_wake(e, t)
                    break
        if back_d > 0 and not colo and (
                not awake_d
                or back_d / len(awake_d) > spec.wake_backlog_tokens):
            for e in asleep:
                if role_of(e) == "decode":
                    cluster.ctl_wake(e, t)
                    break

        # ---- flip toward the work-optimal awake P:D split -------------
        if colo or not spec.allow_flip:
            return
        if any(f == "flip" for f in cluster._draining.values()):
            return                      # at most one drain-to-flip
        n = len(awake_p) + len(awake_d)
        if n < 2 or (back_p <= 0 and back_d <= 0):
            return
        wp, wd = back_p * cp, back_d * cd
        if wp + wd <= 0:
            return
        target_p = round(n * wp / (wp + wd))
        target_p = min(max(target_p, 1 if back_p > 0 else 0), n - 1)
        surplus_role, = (["prefill"] if len(awake_p) - target_p >= 1 else
                         ["decode"] if target_p - len(awake_p) >= 1 else
                         [None])
        if surplus_role is None:
            return
        # repurpose a sleeping surplus-role instance for free if any
        for e in asleep:
            if role_of(e) == surplus_role:
                if cluster.ctl_flip_asleep(e, t):
                    cluster.ctl_wake(e, t)
                    return
        pool = awake_p if surplus_role == "prefill" else awake_d
        if len(pool) < 2:
            return                      # never drain the last instance
        victim = min(pool, key=lambda e: (e.outstanding_tokens(), e.gidx))
        cluster.ctl_drain(victim, t, then="flip")


class ScheduleController(FleetController):
    """Seeded random scale/flip/sleep schedule — not a serving policy
    but the adversary the fleet-invariant property tests run under: any
    action sequence it emits must preserve exactly-once completion,
    routing/lifecycle invariants, and power-trace coverage."""

    name = "schedule"

    def on_tick(self, cluster, t):
        state = cluster.lifecycle_state
        r = float(self.rng.random())
        if r < 0.30:
            cands = [e for e in cluster.engines
                     if state(e) in ("sleep", "absent")]
            if cands:
                cluster.ctl_wake(self._choose(cands), t)
        elif r < 0.55:
            cands = [e for e in cluster.engines
                     if state(e) == "on" and e.accepting
                     and e not in cluster._draining]
            if cands:
                cluster.ctl_drain(self._choose(cands), t, then="sleep")
        elif r < 0.75 and not cluster.spec.is_colocated:
            cands = [e for e in cluster.engines
                     if state(e) == "on" and e.accepting
                     and e not in cluster._draining]
            if cands:
                cluster.ctl_drain(self._choose(cands), t, then="flip")
        elif r < 0.85 and not cluster.spec.is_colocated:
            cands = [e for e in cluster.engines
                     if state(e) in ("sleep", "absent")]
            if cands:
                e = self._choose(cands)
                if cluster.ctl_flip_asleep(e, t):
                    cluster.ctl_wake(e, t)
        # else: no-op tick

    def _choose(self, cands):
        return cands[int(self.rng.integers(len(cands)))]


CONTROLLERS = {
    NullController.name: NullController,
    AdaptiveController.name: AdaptiveController,
    ScheduleController.name: ScheduleController,
}


def make_controller(spec: Union[str, dict, ControllerSpec],
                    seed: int = 0) -> FleetController:
    spec = as_controller_spec(spec)
    try:
        cls = CONTROLLERS[spec.policy]
    except KeyError:
        raise ValueError(f"unknown controller policy {spec.policy!r}; "
                         f"choose from {sorted(CONTROLLERS)}") from None
    return cls(spec, seed=seed)
