"""Load-aware request/KV routing across a fleet (P/D-Serve style).

Two routing decisions exist in a disaggregated fleet and both use the
same policy machinery:

  frontend   which prefill (or colocated) instance admits an arriving
             request — evaluated at the request's arrival event, so a
             load-aware policy sees the live queue state;
  kv         which decode instance receives a finished prefill's KV
             cache — evaluated at prefill completion, so pool pressure
             on the decode side steers the transfer.

Policies (registry ``POLICIES`` / ``make_policy``):

  round-robin              static rotation in arrival order; ignores
                           load entirely (the generalization of the old
                           ``Cluster.submit`` ``i % 2`` split, kept as
                           the regression baseline)
  least-outstanding-tokens pick the engine with the least queued work —
                           remaining prefill + remaining decode tokens
                           across every queue (``Engine.
                           outstanding_tokens``); the FlowKV-style
                           load-aware default for the frontend
  kv-free-space            pick the engine whose paged KV pool has the
                           most free pages — the natural signal for the
                           KV transfer target, where admission is gated
                           by pool reservations, not compute
  min-energy               pick the engine with the least projected
                           joules to absorb the work: the cost model's
                           per-token energy at the instance's CURRENT
                           phi (a governor may have downclocked it)
                           times its outstanding backlog — the
                           energy-aware policy fig8's fleet runs use
  prefix-affinity          pick the engine already holding the longest
                           matched prefix of the request's prompt in
                           its KV store (tiered or flat), falling back
                           to least-outstanding-tokens on cold
                           prefixes — the KV-locality frontend policy
                           DESIGN.md section 15's tiered store exists
                           to feed

Ties are broken with a ``numpy`` Generator seeded from the spec, so a
fleet run is reproducible from ``(spec, workload)`` alone: same seed,
same tie-break sequence, bit-identical metrics.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.core.engine import Engine


class Policy:
    """Base: ``select(engines, rng, req=None) -> engine``. Stateful
    policies (the round-robin rotation) keep their state on the
    instance, so build a fresh policy per router (``make_policy``).

    ``req`` is the request being routed (None for KV-transfer picks
    made before PR 8's threading, and in older call sites/tests) —
    only content-aware policies like ``prefix-affinity`` read it; the
    load-only policies accept and ignore it."""

    name = "base"

    def select(self, engines: Sequence[Engine],
               rng: np.random.Generator, req=None) -> Engine:
        raise NotImplementedError


def _argmin(engines: Sequence[Engine], key: Callable[[Engine], float],
            rng: np.random.Generator) -> Engine:
    """Lowest score wins; exact ties resolved by the seeded generator."""
    scores = [key(e) for e in engines]
    best = min(scores)
    ties = [i for i, s in enumerate(scores) if s == best]
    if len(ties) == 1:
        return engines[ties[0]]
    return engines[ties[int(rng.integers(len(ties)))]]


class RoundRobin(Policy):
    name = "round-robin"

    def __init__(self):
        self._i = 0

    def select(self, engines, rng, req=None):
        e = engines[self._i % len(engines)]
        self._i += 1
        return e


class LeastOutstandingTokens(Policy):
    name = "least-outstanding-tokens"

    def select(self, engines, rng, req=None):
        return _argmin(engines, lambda e: e.outstanding_tokens(), rng)


class KVFreeSpace(Policy):
    name = "kv-free-space"

    @staticmethod
    def _headroom(e: Engine) -> int:
        """Free pages minus reservations already routed here but not
        yet reflected in the pool: ``decode_queue`` entries reserve
        only at ``_admit``, and transfers still in their store leg
        (``inflight_kv_pages``, maintained by the fleet's ``_transfer``)
        have not even arrived — raw ``free_pages`` is blind to both, so
        a burst of prefill completions within one store-latency window
        would all pile onto the same instance."""
        pending = sum(
            e.pool.pages_for(s.ctx + (s.req.output_len - s.req.generated)
                             + 1)
            for s, _, _ in e.decode_queue)
        return e.pool.free_pages - pending \
            - getattr(e, "inflight_kv_pages", 0)

    def select(self, engines, rng, req=None):
        # most headroom == least pool pressure; negate for argmin
        return _argmin(engines, lambda e: -self._headroom(e), rng)


class MinEnergy(Policy):
    """Energy-aware routing (DESIGN.md section 11): fold each
    candidate's power state and projected joules-per-token into the
    score. The projection is first-order — ``CostModel.
    joules_per_token`` at the instance's *current* phi (so an instance a
    governor has parked at a low clock, whose marginal token is cheap,
    is preferred) times the tokens it would have to serve before going
    idle (its backlog + the new unit of work). Queue depth therefore
    still matters, but through the energy lens: a busy-but-efficient
    instance can beat an idle-but-pinned-at-phi-1.0 one."""

    name = "min-energy"

    @staticmethod
    def _projected_j(e: Engine) -> float:
        return e.cost.joules_per_token(e.phi, chunk=e.budget) \
            * (e.outstanding_tokens() + 1)

    def select(self, engines, rng, req=None):
        return _argmin(engines, self._projected_j, rng)


class PrefixAffinity(Policy):
    """KV-locality routing (Dynamo/SGLang cache-aware style, DESIGN.md
    section 15): score each engine by how many of the request's prompt
    tokens are already resident in its KV store (tiered
    ``TieredKVStore.peek_match`` or flat shared ``PrefixCache.
    peek_match`` — both probe without touching LRU order or counters),
    and break score ties by least-outstanding-tokens.

    The score tuple ``(-matched, outstanding)`` makes the cold-prefix /
    no-store case BYTE-IDENTICAL to plain least-outstanding-tokens:
    when every match is 0 the first component never discriminates, the
    tie set and the seeded rng draw sequence are exactly LOT's
    (tests/test_kvstore.py machine-checks this)."""

    name = "prefix-affinity"

    @staticmethod
    def _matched(e: Engine, req) -> int:
        if req is None:
            return 0
        toks = getattr(req, "prompt_tokens", None)
        if toks is None:
            return 0
        store = getattr(e, "kv_store", None)
        if store is not None:
            return store.peek_match(toks)
        cache = getattr(e, "prefix_cache", None)
        if cache is not None:
            return cache.peek_match(toks)
        return 0

    def select(self, engines, rng, req=None):
        return _argmin(
            engines,
            lambda e: (-self._matched(e, req), e.outstanding_tokens()),
            rng)


POLICIES = {
    RoundRobin.name: RoundRobin,
    LeastOutstandingTokens.name: LeastOutstandingTokens,
    KVFreeSpace.name: KVFreeSpace,
    MinEnergy.name: MinEnergy,
    PrefixAffinity.name: PrefixAffinity,
}


def make_policy(name: str) -> Policy:
    try:
        cls = POLICIES[name]
    except KeyError:
        raise ValueError(f"unknown router policy {name!r}; "
                         f"choose from {sorted(POLICIES)}") from None
    return cls()


class Router:
    """One routing decision point: a policy bound to its target engines
    and a seeded tie-break stream.

    ``accept`` (installed only by controller-active fleets) filters the
    candidate set per pick so routing never sees a sleeping, draining,
    or wrong-role instance; ``pick`` returns None when nothing is
    eligible and the cluster parks the work. When every engine is
    eligible the filtered list is the full list — identical contents
    and order, so policy state and tie-break rng draws match the
    static (accept=None) path bit-for-bit.
    """

    def __init__(self, engines: Sequence[Engine],
                 policy: str = "least-outstanding-tokens", seed: int = 0,
                 accept: Optional[Callable[[Engine], bool]] = None):
        if not engines:
            raise ValueError("router needs >= 1 target engine")
        self.engines: List[Engine] = list(engines)
        self.policy = make_policy(policy)
        self._rng = np.random.default_rng(seed)
        self.accept = accept
        # routing-decision counts per target engine (repro.obs reads
        # this into the metrics registry at end of run)
        self.picks: Dict[str, int] = {}

    def pick(self, req=None) -> Optional[Engine]:
        if self.accept is None:
            if len(self.engines) == 1:   # the 1P:1D / co-1gpu fast path
                e = self.engines[0]
            else:
                e = self.policy.select(self.engines, self._rng, req=req)
        else:
            cands = [e for e in self.engines if self.accept(e)]
            if not cands:
                return None
            if len(cands) == 1:
                e = cands[0]
            else:
                e = self.policy.select(cands, self._rng, req=req)
        key = getattr(e, "name", None)
        if key is not None:          # duck-typed test engines may lack it
            self.picks[key] = self.picks.get(key, 0) + 1
        return e
