"""Fleet-scale serving: xP:yD instance pools with load-aware KV routing
(DESIGN.md section 10).

The paper's five setups generalized to arbitrary fleet shapes — build a
``FleetSpec`` (x prefill : y decode over one KV medium, or n colocated),
serve any workload on a ``FleetCluster``, and let the pluggable
``Router`` policies balance requests and KV transfers across the pool.
The legacy ``Cluster`` is a 1-2 instance facade over this subsystem.
"""
# Fully initialize repro.core before touching .cluster: core's own init
# imports this package (orchestrator subclasses FleetCluster), and
# entering the cycle via .cluster would leave it partially initialized.
# core <-> fleet imports therefore always use the submodule form
# (repro.fleet.spec / repro.fleet.cluster), never the package.
import repro.core  # noqa: F401  (import-order side effect only)

from .cluster import FleetCluster, SetupResult
from .controller import (CONTROLLERS, AdaptiveController, ControllerSpec,
                         FleetController, NullController,
                         ScheduleController, as_controller_spec,
                         make_controller)
from .router import (KVFreeSpace, LeastOutstandingTokens, MinEnergy,
                     POLICIES, Policy, PrefixAffinity, RoundRobin,
                     Router, make_policy)
from .spec import (DIS_PATH, MEDIA, SETUPS, FleetSpec, as_fleet_spec,
                   setup_label)

__all__ = [
    "FleetCluster", "SetupResult",
    "Router", "Policy", "RoundRobin", "LeastOutstandingTokens",
    "KVFreeSpace", "MinEnergy", "PrefixAffinity", "POLICIES",
    "make_policy",
    "FleetSpec", "as_fleet_spec", "setup_label",
    "SETUPS", "DIS_PATH", "MEDIA",
    "ControllerSpec", "FleetController", "NullController",
    "AdaptiveController", "ScheduleController", "CONTROLLERS",
    "as_controller_spec", "make_controller",
]
