"""JAX version compatibility shims.

The distribution subsystem (``repro.dist``) targets the current jax API
(``jax.shard_map``, ``AbstractMesh(axis_sizes, axis_names)``); the pinned
container ships jax 0.4.37 where ``shard_map`` still lives under
``jax.experimental`` and ``AbstractMesh`` takes a ``((name, size), ...)``
shape tuple. Everything that is version-sensitive is funneled through this
module so the rest of the codebase (and the tests) can write against one
surface.
"""
from __future__ import annotations

from typing import Sequence, Tuple

import jax
from jax.sharding import AbstractMesh

if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:  # jax <= 0.4.x
    from jax.experimental.shard_map import shard_map  # noqa: F401


def abstract_mesh(axis_sizes: Sequence[int],
                  axis_names: Tuple[str, ...]) -> AbstractMesh:
    """``AbstractMesh((16, 16), ("data", "model"))`` on every jax version."""
    try:
        return AbstractMesh(tuple(axis_sizes), tuple(axis_names))
    except TypeError:  # jax <= 0.4.x: shape_tuple of (name, size) pairs
        return AbstractMesh(tuple(zip(axis_names, axis_sizes)))
