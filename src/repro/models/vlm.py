"""VLM backbone (internvl2-2b): InternViT frontend STUB + InternLM2 LM.

Per the assignment the modality frontend is a stub: ``input_specs()``
provides precomputed patch embeddings [B, num_patches, frontend_dim]. A
learned MLP projector maps them into the LM embedding space; the patch
tokens are prepended to the text tokens and the standard dense GQA
transformer (``transformer.py``) runs over the combined sequence.

Serving: prefill covers patches + prompt text; decode is standard LM
decode (the image contributes only KV-cache entries) — so the paper's
disaggregation applies unchanged, with a prefill payload enlarged by
``num_patches`` tokens of KV.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from . import layers as L
from . import transformer as TF

AttnCache = TF.AttnCache


# ----------------------------------------------------------------------
def init(rng, cfg: ModelConfig) -> Dict[str, Any]:
    k_tf, k_proj = jax.random.split(rng)
    params = TF.init(k_tf, cfg)
    pdt = L.dtype_of(cfg.param_dtype)
    params["projector"] = {
        "w": (jax.random.normal(k_proj, (cfg.vision.frontend_dim, cfg.d_model))
              * 0.02).astype(pdt),
        "b": jnp.zeros((cfg.d_model,), pdt),
    }
    return params


def _combined_embeddings(params, patches: jnp.ndarray, tokens: jnp.ndarray,
                         cfg: ModelConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """-> (x [B, Np+S, d], positions [B, Np+S])."""
    pj = params["projector"]
    cdt = L.dtype_of(cfg.compute_dtype)
    img = patches.astype(cdt) @ pj["w"] + pj["b"]             # [B, Np, d]
    txt = L.embed(params["embed"], tokens, cfg)               # [B, S, d]
    x = jnp.concatenate([img, txt], axis=1)
    B, S_all = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S_all), (B, S_all))
    return x, positions


# ----------------------------------------------------------------------
def forward(params, batch: Dict[str, jnp.ndarray], cfg: ModelConfig,
            remat: bool = False) -> jnp.ndarray:
    """batch: {"patches": [B,Np,fd], "tokens": [B,S]} -> logits over the
    text positions [B, S, V] (patch positions are dropped)."""
    patches, tokens = batch["patches"], batch["tokens"]
    Np = patches.shape[1]
    x, positions = _combined_embeddings(params, patches, tokens, cfg)
    logits = TF.forward_from_embeddings(params, x, positions, cfg, remat)
    return logits[:, Np:]


def prefill(params, batch: Dict[str, jnp.ndarray], cfg: ModelConfig,
            s_max: Optional[int] = None) -> Tuple[jnp.ndarray, AttnCache]:
    """Cache covers patch + text positions; s_max counts the combined len."""
    patches, tokens = batch["patches"], batch["tokens"]
    x, positions = _combined_embeddings(params, patches, tokens, cfg)
    return TF.prefill_from_embeddings(params, x, positions, cfg, s_max)


def decode_step(params, tokens: jnp.ndarray, cache: AttnCache,
                pos: jnp.ndarray, cfg: ModelConfig
                ) -> Tuple[jnp.ndarray, AttnCache]:
    """pos is the absolute position in the combined (patch+text) sequence."""
    return TF.decode_step(params, tokens, cache, pos, cfg)


def loss_fn(params, batch: Dict[str, jnp.ndarray], cfg: ModelConfig,
            remat: bool = True):
    logits = forward(params, batch, cfg, remat=remat)
    return TF.cross_entropy(logits, batch["targets"], batch.get("mask")), {}


def empty_cache(cfg: ModelConfig, batch: int, s_max: int,
                dtype=jnp.bfloat16) -> AttnCache:
    return TF.empty_cache(cfg, batch, s_max, dtype)
