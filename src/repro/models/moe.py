"""Mixture-of-experts transformer (deepseek-moe-16b, moonshot-v1-16b-a3b).

Attention is the same dense GQA as ``transformer.py``; the FFN of layers
``>= first_k_dense`` is a fine-grained MoE: ``num_experts`` routed experts of
width ``d_expert`` with top-k token choice, plus ``num_shared_experts``
always-on shared experts fused into one dense SwiGLU.

Dispatch is **sort-based with capacity** (not the GShard one-hot-einsum form,
whose [T, E, C] dispatch tensor is O(T^2) at training token counts):

  1. router top-k -> (expert_idx, weight) per token-slot, T*K slots
  2. argsort slots by expert id; rank-within-expert via the sorted-run trick
  3. scatter kept slots into an [E, C, d] buffer          (the all-to-all)
  4. batched per-expert SwiGLU einsum [E,C,d]x[E,d,f]     (EP-sharded on E)
  5. gather back + weighted combine                        (the return a2a)

Slots past capacity C = ceil(T*K/E * capacity_factor) are dropped (their
combine weight contributes nothing), matching standard capacity semantics.
With experts sharded on the ``model/expert`` mesh axes, step 3/5's scatter
and gather lower to the expert-parallel all-to-all exchange.
"""
from __future__ import annotations

import math
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops
from . import layers as L
from . import transformer as TF

AttnCache = TF.AttnCache


# ----------------------------------------------------------------------
# init
# ----------------------------------------------------------------------
def init_moe_ffn(rng, cfg: ModelConfig) -> Dict[str, jnp.ndarray]:
    m = cfg.moe
    d, f, E = cfg.d_model, m.d_expert, m.num_experts
    pdt = L.dtype_of(cfg.param_dtype)
    k = jax.random.split(rng, 5)
    std = 0.02
    out_std = std / math.sqrt(2 * cfg.num_layers)
    p = {
        "router": (jax.random.normal(k[0], (d, E)) * std).astype(jnp.float32),
        "w_gate": (jax.random.normal(k[1], (E, d, f)) * std).astype(pdt),
        "w_up": (jax.random.normal(k[2], (E, d, f)) * std).astype(pdt),
        "w_down": (jax.random.normal(k[3], (E, f, d)) * out_std).astype(pdt),
    }
    if m.num_shared_experts:
        fs = m.num_shared_experts * f
        ks = jax.random.split(k[4], 3)
        p["shared"] = {
            "w_gate": (jax.random.normal(ks[0], (d, fs)) * std).astype(pdt),
            "w_up": (jax.random.normal(ks[1], (d, fs)) * std).astype(pdt),
            "w_down": (jax.random.normal(ks[2], (fs, d)) * out_std).astype(pdt),
        }
    return p


def init_block(rng, cfg: ModelConfig, dense: bool) -> Dict[str, Any]:
    k1, k2 = jax.random.split(rng)
    pdt = L.dtype_of(cfg.param_dtype)
    ffn = (L.init_mlp(k2, cfg, d_ff=cfg.moe.dense_d_ff) if dense
           else init_moe_ffn(k2, cfg))
    return {
        "attn": L.init_attention(k1, cfg),
        "ffn": ffn,
        "norm_attn": L.init_rms_norm(cfg.d_model, pdt),
        "norm_mlp": L.init_rms_norm(cfg.d_model, pdt),
    }


def init(rng, cfg: ModelConfig) -> Dict[str, Any]:
    m = cfg.moe
    k_emb, k_dense, k_moe = jax.random.split(rng, 3)
    n_dense = m.first_k_dense
    n_moe = cfg.num_layers - n_dense
    params: Dict[str, Any] = {"embed": L.init_embedding(k_emb, cfg)}
    if n_dense:
        keys = jax.random.split(k_dense, n_dense)
        params["dense_layers"] = jax.vmap(
            lambda k: init_block(k, cfg, dense=True))(keys)
    keys = jax.random.split(k_moe, n_moe)
    params["moe_layers"] = jax.vmap(
        lambda k: init_block(k, cfg, dense=False))(keys)
    return params


# ----------------------------------------------------------------------
# routed expert dispatch (sort + scatter, capacity-bounded)
# ----------------------------------------------------------------------
def moe_ffn(p: Dict[str, jnp.ndarray], x: jnp.ndarray, cfg: ModelConfig,
            dropless: bool = False) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: [..., d] -> (y [..., d], aux_loss scalar).

    ``dropless=True`` (decode) sizes capacity at min(T*K, ceil(T*K/E *
    decode_capacity_factor)): exact for small batches (T*K <= C covers the
    all-to-one-expert worst case), and statistically-dropless-but-bounded
    for large decode batches — a dropped decode token is a wrong token, but
    a worst-case C = T*K buffer is 64x overcompute at E=64.
    Train/prefill use the standard capacity factor (drops allowed).
    """
    from repro.dist import opt_flags
    m = cfg.moe
    lead = x.shape[:-1]
    d = x.shape[-1]
    xt = x.reshape(-1, d)
    T = xt.shape[0]

    # local_moe_dispatch perf flag: sort/rank/scatter per data-shard-sized
    # token group (vmapped -> shard-local in the partitioned program)
    # instead of one global sort over the sharded token axis; only the
    # expert einsum crosses shards (the true MoE all-to-all).
    groups = 1
    if opt_flags.enabled("local_moe_dispatch"):
        for g in (16, 8, 4, 2):
            if T % g == 0 and T // g >= m.num_experts:
                groups = g
                break
    if groups > 1:
        xg = xt.reshape(groups, T // groups, d)
        y, counts, frac_probs = jax.vmap(
            lambda xs: _dispatch(p, xs, cfg, dropless))(xg)
        y = y.reshape(T, d)
        # aux loss from GLOBAL routing stats: summed counts and averaged
        # probs reproduce the ungrouped Switch loss (a mean of per-group
        # losses would not — f_e * P_e is quadratic in the stats)
        aux = _aux_loss(jnp.sum(counts, 0), jnp.mean(frac_probs, 0), cfg, T)
    else:
        y, counts, frac_probs = _dispatch(p, xt, cfg, dropless)
        aux = _aux_loss(counts, frac_probs, cfg, T)

    if m.num_shared_experts:
        s = p["shared"]
        sg = jnp.einsum("td,df->tf", xt, s["w_gate"])
        su = jnp.einsum("td,df->tf", xt, s["w_up"])
        y = y + jnp.einsum("tf,fd->td", jax.nn.silu(sg) * su, s["w_down"])

    return y.reshape(*lead, d).astype(x.dtype), aux


def _aux_loss(counts: jnp.ndarray, frac_probs: jnp.ndarray,
              cfg: ModelConfig, total_tokens: int) -> jnp.ndarray:
    """Switch load-balance loss E * sum f_e * P_e from routing stats."""
    m = cfg.moe
    frac_tokens = counts / (total_tokens * m.top_k)
    return (m.num_experts * jnp.sum(frac_tokens * frac_probs)
            * m.router_aux_loss)


def _dispatch(p, xt: jnp.ndarray, cfg: ModelConfig, dropless: bool
              ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Core sort+scatter dispatch over one token group. xt: [T, d].

    Returns (y, expert_counts, mean_probs); the caller assembles the aux
    loss so grouped dispatch can combine stats globally first."""
    m = cfg.moe
    E, K = m.num_experts, m.top_k
    T, d = xt.shape

    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)                     # [T, E]
    weight, idx = jax.lax.top_k(probs, K)                       # [T, K]
    weight = weight / jnp.maximum(
        jnp.sum(weight, axis=-1, keepdims=True), 1e-9)          # renormalize

    # routing stats for the load-balance loss (assembled by the caller)
    counts = jnp.zeros((E,), jnp.float32).at[idx.reshape(-1)].add(1.0)
    frac_probs = jnp.mean(probs, axis=0)

    # --- sort slots by expert; rank within expert run ---
    S = T * K
    flat_e = idx.reshape(S)                                     # slot->expert
    flat_t = jnp.repeat(jnp.arange(T), K)                       # slot->token
    flat_w = weight.reshape(S)
    order = jnp.argsort(flat_e)                                 # stable
    se = flat_e[order]
    # rank within equal-expert run: position - index of run start
    run_start = jnp.searchsorted(se, se, side="left")
    rank = jnp.arange(S) - run_start                            # [S]

    if dropless:
        C = min(S, max(int(math.ceil(S / E * m.decode_capacity_factor)), 1))
    else:
        C = max(int(math.ceil(S / E * m.capacity_factor)), 1)
    keep = rank < C
    # scatter destinations in the [E*C] buffer; dropped slots -> E*C (oob)
    dest = jnp.where(keep, se * C + rank, E * C)

    xe = jnp.zeros((E * C, d), xt.dtype).at[dest].set(
        xt[flat_t[order]], mode="drop")
    xe = xe.reshape(E, C, d)

    # --- batched per-expert SwiGLU ---
    gate = jnp.einsum("ecd,edf->ecf", xe, p["w_gate"])
    up = jnp.einsum("ecd,edf->ecf", xe, p["w_up"])
    h = jax.nn.silu(gate) * up
    ye = jnp.einsum("ecf,efd->ecd", h, p["w_down"]).reshape(E * C, d)

    # --- gather back + weighted combine ---
    back = jnp.where(keep[:, None], ye[jnp.minimum(dest, E * C - 1)], 0.0)
    contrib = back * flat_w[order][:, None].astype(back.dtype)
    y = jnp.zeros((T, d), back.dtype).at[flat_t[order]].add(contrib)
    return y, counts, frac_probs


# ----------------------------------------------------------------------
# blocks
# ----------------------------------------------------------------------
def block_forward(p, x, positions, cfg: ModelConfig, dense: bool, *,
                  return_kv: bool = False):
    h = L.rms_norm(x, p["norm_attn"], cfg.norm_eps)
    q, k, v = L.qkv_project(p["attn"], h, cfg)
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    attn = L.flash_gqa(q, k, v, causal=True, window=cfg.sliding_window)
    x = x + L.out_project(p["attn"], attn, cfg)
    h = L.rms_norm(x, p["norm_mlp"], cfg.norm_eps)
    if dense:
        ffn, aux = L.mlp_forward(p["ffn"], h, cfg), jnp.zeros((), jnp.float32)
    else:
        ffn, aux = moe_ffn(p["ffn"], h, cfg)
    x = x + ffn
    if return_kv:
        return x, aux, (k, v)
    return x, aux


def block_decode(p, x, cache_k, cache_v, pos, cfg: ModelConfig, dense: bool):
    h = L.rms_norm(x, p["norm_attn"], cfg.norm_eps)
    q, k, v = L.qkv_project(p["attn"], h, cfg)
    q = L.apply_rope(q, pos[:, None], cfg.rope_theta)
    k = L.apply_rope(k, pos[:, None], cfg.rope_theta)
    cache_k = L.cache_write(cache_k, k, pos)
    cache_v = L.cache_write(cache_v, v, pos)
    attn = L.cached_attention(q, cache_k, cache_v, pos,
                              window=cfg.sliding_window)
    x = x + L.out_project(p["attn"], attn, cfg)
    h = L.rms_norm(x, p["norm_mlp"], cfg.norm_eps)
    if dense:
        ffn = L.mlp_forward(p["ffn"], h, cfg)
    else:
        ffn, _ = moe_ffn(p["ffn"], h, cfg, dropless=True)
    x = x + ffn
    return x, cache_k, cache_v


# ----------------------------------------------------------------------
# model-level entry points (mirror transformer.py's API)
# ----------------------------------------------------------------------
def _scan_group(params_group, x, positions, cfg, dense, remat, collect_kv):
    def body(h, lp):
        if collect_kv:
            h, aux, kv = block_forward(lp, h, positions, cfg, dense,
                                       return_kv=True)
            return h, (aux, kv)
        h, aux = block_forward(lp, h, positions, cfg, dense)
        return h, aux
    if remat:
        body = L.remat_wrap(body)
    return L.layer_scan(body, x, params_group)


def forward(params, tokens: jnp.ndarray, cfg: ModelConfig,
            remat: bool = False) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """tokens: [B, S] -> (logits [B, S, V], aux_loss)."""
    B, S = tokens.shape
    x = L.embed(params["embed"], tokens, cfg)
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    aux_total = jnp.zeros((), jnp.float32)
    if "dense_layers" in params:
        x, aux = _scan_group(params["dense_layers"], x, positions, cfg,
                             True, remat, False)
        aux_total = aux_total + jnp.sum(aux)
    x, aux = _scan_group(params["moe_layers"], x, positions, cfg,
                         False, remat, False)
    aux_total = aux_total + jnp.sum(aux)
    return L.lm_logits(params["embed"], x, cfg), aux_total


def prefill(params, tokens: jnp.ndarray, cfg: ModelConfig,
            s_max: Optional[int] = None) -> Tuple[jnp.ndarray, AttnCache]:
    B, S = tokens.shape
    s_max = s_max or S
    x = L.embed(params["embed"], tokens, cfg)
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    all_k, all_v = [], []
    if "dense_layers" in params:
        x, (_, (ks, vs)) = _scan_group(params["dense_layers"], x, positions,
                                       cfg, True, False, True)
        all_k.append(ks)
        all_v.append(vs)
    x, (_, (ks, vs)) = _scan_group(params["moe_layers"], x, positions,
                                   cfg, False, False, True)
    all_k.append(ks)
    all_v.append(vs)
    ks = jnp.concatenate(all_k, axis=0) if len(all_k) > 1 else all_k[0]
    vs = jnp.concatenate(all_v, axis=0) if len(all_v) > 1 else all_v[0]
    if s_max > S:
        pad = [(0, 0), (0, 0), (0, s_max - S), (0, 0), (0, 0)]
        ks = jnp.pad(ks, pad)
        vs = jnp.pad(vs, pad)
    logits = L.lm_logits(params["embed"], x[:, -1:], cfg)[:, 0]
    return logits, AttnCache(k=ks, v=vs)


def decode_step(params, tokens: jnp.ndarray, cache: AttnCache,
                pos: jnp.ndarray, cfg: ModelConfig
                ) -> Tuple[jnp.ndarray, AttnCache]:
    x = L.embed(params["embed"], tokens[:, None], cfg)
    n_dense = cfg.moe.first_k_dense
    ck_d, cv_d = cache.k[:n_dense], cache.v[:n_dense]
    ck_m, cv_m = cache.k[n_dense:], cache.v[n_dense:]

    if n_dense:
        def body_d(h, xs):
            lp, ck, cv = xs
            h, ck, cv = block_decode(lp, h, ck, cv, pos, cfg, True)
            return h, (ck, cv)
        x, (ck_d, cv_d) = L.layer_scan(
            body_d, x, (params["dense_layers"], ck_d, cv_d))

    def body_m(h, xs):
        lp, ck, cv = xs
        h, ck, cv = block_decode(lp, h, ck, cv, pos, cfg, False)
        return h, (ck, cv)
    x, (ck_m, cv_m) = L.layer_scan(
        body_m, x, (params["moe_layers"], ck_m, cv_m))

    ks = jnp.concatenate([ck_d, ck_m], axis=0) if n_dense else ck_m
    vs = jnp.concatenate([cv_d, cv_m], axis=0) if n_dense else cv_m
    logits = L.lm_logits(params["embed"], x, cfg)[:, 0]
    return logits, AttnCache(k=ks, v=vs)


def loss_fn(params, batch: Dict[str, jnp.ndarray], cfg: ModelConfig,
            remat: bool = True):
    logits, aux = forward(params, batch["tokens"], cfg, remat=remat)
    ce = TF.cross_entropy(logits, batch["targets"], batch.get("mask"))
    return ce + aux, {"aux_loss": aux, "ce": ce}


def empty_cache(cfg: ModelConfig, batch: int, s_max: int,
                dtype=jnp.bfloat16) -> AttnCache:
    return TF.empty_cache(cfg, batch, s_max, dtype)
