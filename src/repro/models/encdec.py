"""Encoder-decoder backbone (seamless-m4t-medium).

The modality frontend is a STUB per the assignment: ``input_specs()``
provides precomputed frame embeddings [B, S_src, frontend_dim]; a learned
projector maps them to d_model. The encoder is bidirectional; the decoder
is causal with cross-attention into the encoder output.

Serving split (the paper's prefill/decode decomposition for enc-dec):
  prefill  = encoder forward + cross-KV projection + decoder-prefix forward
  decode   = one decoder token: cached self-attention + cross-attention
Handoff payload = decoder self-KV (grows per token) + cross-KV (fixed,
proportional to source length).
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops
from . import layers as L
from . import transformer as TF


class EncDecState(NamedTuple):
    self_k: jnp.ndarray    # [Ld, B, S_max, KV, hd]
    self_v: jnp.ndarray
    cross_k: jnp.ndarray   # [Ld, B, S_src, KV, hd]
    cross_v: jnp.ndarray


# ----------------------------------------------------------------------
# init
# ----------------------------------------------------------------------
def init_encoder_block(rng, cfg: ModelConfig) -> Dict[str, Any]:
    k1, k2 = jax.random.split(rng)
    pdt = L.dtype_of(cfg.param_dtype)
    return {
        "attn": L.init_attention(k1, cfg),
        "mlp": L.init_mlp(k2, cfg),
        "norm_attn": L.init_rms_norm(cfg.d_model, pdt),
        "norm_mlp": L.init_rms_norm(cfg.d_model, pdt),
    }


def init_decoder_block(rng, cfg: ModelConfig) -> Dict[str, Any]:
    k1, k2, k3 = jax.random.split(rng, 3)
    pdt = L.dtype_of(cfg.param_dtype)
    return {
        "self_attn": L.init_attention(k1, cfg),
        "cross_attn": L.init_attention(k2, cfg),
        "mlp": L.init_mlp(k3, cfg),
        "norm_self": L.init_rms_norm(cfg.d_model, pdt),
        "norm_cross": L.init_rms_norm(cfg.d_model, pdt),
        "norm_mlp": L.init_rms_norm(cfg.d_model, pdt),
    }


def init(rng, cfg: ModelConfig) -> Dict[str, Any]:
    e = cfg.encdec
    k_emb, k_enc, k_dec, k_proj = jax.random.split(rng, 4)
    pdt = L.dtype_of(cfg.param_dtype)
    enc_keys = jax.random.split(k_enc, e.num_encoder_layers)
    dec_keys = jax.random.split(k_dec, e.num_decoder_layers)
    return {
        "embed": L.init_embedding(k_emb, cfg),
        "frontend_proj": {
            "w": (jax.random.normal(k_proj, (e.frontend_dim, cfg.d_model))
                  * 0.02).astype(pdt),
            "b": jnp.zeros((cfg.d_model,), pdt),
        },
        "encoder": jax.vmap(lambda k: init_encoder_block(k, cfg))(enc_keys),
        "decoder": jax.vmap(lambda k: init_decoder_block(k, cfg))(dec_keys),
    }


# ----------------------------------------------------------------------
# encoder
# ----------------------------------------------------------------------
def encode(params, src_embeds: jnp.ndarray, cfg: ModelConfig,
           remat: bool = False) -> jnp.ndarray:
    """src_embeds: [B, S_src, frontend_dim] -> [B, S_src, d]."""
    fp = params["frontend_proj"]
    x = (src_embeds.astype(L.dtype_of(cfg.compute_dtype)) @ fp["w"] + fp["b"])
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))

    def body(h, lp):
        hn = L.rms_norm(h, lp["norm_attn"], cfg.norm_eps)
        q, k, v = L.qkv_project(lp["attn"], hn, cfg)
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)
        attn = L.flash_gqa(q, k, v, causal=False)
        h = h + L.out_project(lp["attn"], attn, cfg)
        hn = L.rms_norm(h, lp["norm_mlp"], cfg.norm_eps)
        h = h + L.mlp_forward(lp["mlp"], hn, cfg)
        return h, None

    if remat:
        body = L.remat_wrap(body)
    x, _ = L.layer_scan(body, x, params["encoder"])
    return x


def project_cross_kv(params, enc_out: jnp.ndarray, cfg: ModelConfig
                     ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """enc_out: [B, S_src, d] -> per-decoder-layer cross K/V
    [Ld, B, S_src, KV, hd]."""
    kv, hd = cfg.num_kv_heads, cfg.head_dim

    def body(_, lp):
        ca = lp["cross_attn"]
        k = jnp.einsum("bsd,de->bse", enc_out, ca["wk"])
        v = jnp.einsum("bsd,de->bse", enc_out, ca["wv"])
        if cfg.attn_qkv_bias:
            k = k + ca["bk"]
            v = v + ca["bv"]
        k = k.reshape(*enc_out.shape[:-1], kv, hd)
        v = v.reshape(*enc_out.shape[:-1], kv, hd)
        if cfg.qk_norm:
            k = L.rms_norm(k, ca["k_norm"], cfg.norm_eps)
        return None, (k, v)

    _, (ks, vs) = L.layer_scan(body, None, params["decoder"])
    return ks, vs


# ----------------------------------------------------------------------
# decoder blocks
# ----------------------------------------------------------------------
def _cross_attend(lp, h, cross_k, cross_v, cfg):
    """h: [B, T, d]; cross_k/v: [B, S_src, KV, hd]."""
    ca = lp["cross_attn"]
    hn = L.rms_norm(h, lp["norm_cross"], cfg.norm_eps)
    q = jnp.einsum("btd,de->bte", hn, ca["wq"])
    if cfg.attn_qkv_bias:
        q = q + ca["bq"]
    q = q.reshape(*hn.shape[:-1], cfg.num_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = L.rms_norm(q, ca["q_norm"], cfg.norm_eps)
    attn = L.flash_gqa(q, cross_k, cross_v, causal=False)
    return h + L.out_project(ca, attn, cfg)


def decoder_block_forward(lp, h, positions, cross_k, cross_v, cfg,
                          *, return_kv: bool = False):
    hn = L.rms_norm(h, lp["norm_self"], cfg.norm_eps)
    q, k, v = L.qkv_project(lp["self_attn"], hn, cfg)
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    attn = L.flash_gqa(q, k, v, causal=True)
    h = h + L.out_project(lp["self_attn"], attn, cfg)
    h = _cross_attend(lp, h, cross_k, cross_v, cfg)
    hn = L.rms_norm(h, lp["norm_mlp"], cfg.norm_eps)
    h = h + L.mlp_forward(lp["mlp"], hn, cfg)
    if return_kv:
        return h, (k, v)
    return h


def decoder_block_decode(lp, h, cache_k, cache_v, cross_k, cross_v, pos, cfg):
    hn = L.rms_norm(h, lp["norm_self"], cfg.norm_eps)
    q, k, v = L.qkv_project(lp["self_attn"], hn, cfg)
    q = L.apply_rope(q, pos[:, None], cfg.rope_theta)
    k = L.apply_rope(k, pos[:, None], cfg.rope_theta)
    cache_k = L.cache_write(cache_k, k, pos)
    cache_v = L.cache_write(cache_v, v, pos)
    attn = L.cached_attention(q, cache_k, cache_v, pos)
    h = h + L.out_project(lp["self_attn"], attn, cfg)
    h = _cross_attend(lp, h, cross_k, cross_v, cfg)
    hn = L.rms_norm(h, lp["norm_mlp"], cfg.norm_eps)
    h = h + L.mlp_forward(lp["mlp"], hn, cfg)
    return h, cache_k, cache_v


# ----------------------------------------------------------------------
# model-level entry points
# ----------------------------------------------------------------------
def forward(params, batch_or_tokens, cfg: ModelConfig, remat: bool = False,
            src_embeds: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Training forward. Accepts {"src_embeds", "tokens"} dict or
    (tokens, src_embeds=...). Returns decoder logits [B, S_tgt, V]."""
    if isinstance(batch_or_tokens, dict):
        tokens = batch_or_tokens["tokens"]
        src_embeds = batch_or_tokens["src_embeds"]
    else:
        tokens = batch_or_tokens
    enc_out = encode(params, src_embeds, cfg, remat=remat)
    cross_k, cross_v = project_cross_kv(params, enc_out, cfg)

    B, S = tokens.shape
    x = L.embed(params["embed"], tokens, cfg)
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))

    def body(h, xs):
        lp, ck, cv = xs
        return decoder_block_forward(lp, h, positions, ck, cv, cfg), None

    if remat:
        body = L.remat_wrap(body)
    x, _ = L.layer_scan(body, x, (params["decoder"], cross_k, cross_v))
    return L.lm_logits(params["embed"], x, cfg)


def prefill(params, batch: Dict[str, jnp.ndarray], cfg: ModelConfig,
            s_max: Optional[int] = None) -> Tuple[jnp.ndarray, EncDecState]:
    """batch: {"src_embeds": [B,S_src,fd], "tokens": [B,S_prefix]}."""
    src_embeds, tokens = batch["src_embeds"], batch["tokens"]
    enc_out = encode(params, src_embeds, cfg)
    cross_k, cross_v = project_cross_kv(params, enc_out, cfg)

    B, S = tokens.shape
    s_max = s_max or S
    x = L.embed(params["embed"], tokens, cfg)
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))

    def body(h, xs):
        lp, ck, cv = xs
        h, (k, v) = decoder_block_forward(lp, h, positions, ck, cv, cfg,
                                          return_kv=True)
        return h, (k, v)

    x, (ks, vs) = L.layer_scan(body, x, (params["decoder"], cross_k, cross_v))
    if s_max > S:
        pad = [(0, 0), (0, 0), (0, s_max - S), (0, 0), (0, 0)]
        ks = jnp.pad(ks, pad)
        vs = jnp.pad(vs, pad)
    logits = L.lm_logits(params["embed"], x[:, -1:], cfg)[:, 0]
    return logits, EncDecState(self_k=ks, self_v=vs,
                               cross_k=cross_k, cross_v=cross_v)


def decode_step(params, tokens: jnp.ndarray, state: EncDecState,
                pos: jnp.ndarray, cfg: ModelConfig
                ) -> Tuple[jnp.ndarray, EncDecState]:
    x = L.embed(params["embed"], tokens[:, None], cfg)

    def body(h, xs):
        lp, ck, cv, crk, crv = xs
        h, ck, cv = decoder_block_decode(lp, h, ck, cv, crk, crv, pos, cfg)
        return h, (ck, cv)

    x, (ks, vs) = L.layer_scan(
        body, x, (params["decoder"], state.self_k, state.self_v,
                  state.cross_k, state.cross_v))
    logits = L.lm_logits(params["embed"], x, cfg)[:, 0]
    return logits, EncDecState(self_k=ks, self_v=vs,
                               cross_k=state.cross_k, cross_v=state.cross_v)


def loss_fn(params, batch: Dict[str, jnp.ndarray], cfg: ModelConfig,
            remat: bool = True):
    logits = forward(params, batch, cfg, remat=remat)
    return TF.cross_entropy(logits, batch["targets"], batch.get("mask")), {}
