"""RWKV6 "Finch" (rwkv6-3b): attention-free, data-dependent per-channel decay.

Each block = time-mix (the matrix-valued recurrence, Pallas chunked-scan hot
spot) + channel-mix (token-shifted squared-ReLU FFN). There is **no KV
cache**: the per-sequence serving state is fixed-size —

  wkv   [L, B, NH, hd, hd]   recurrence state (key-dim x value-dim)
  tm_x  [L, B, d]            last token seen by time-mix token-shift
  cm_x  [L, B, d]            last token seen by channel-mix token-shift

which is what makes this arch the paper's degenerate-transfer case
(DESIGN.md section 8): the prefill->decode handoff payload is O(MB) and
independent of prompt length.
"""
from __future__ import annotations

import math
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops
from . import layers as L
from . import transformer as TF


class RWKVState(NamedTuple):
    wkv: jnp.ndarray    # [L, B, NH, hd, hd] f32
    tm_x: jnp.ndarray   # [L, B, d]
    cm_x: jnp.ndarray   # [L, B, d]


NUM_MIX = 5  # token-shift mixers: w, k, v, r, g


# ----------------------------------------------------------------------
# init
# ----------------------------------------------------------------------
def init_block(rng, cfg: ModelConfig) -> Dict[str, Any]:
    r = cfg.rwkv
    d, ff = cfg.d_model, cfg.d_ff
    nh = d // r.head_dim
    pdt = L.dtype_of(cfg.param_dtype)
    k = jax.random.split(rng, 12)
    std = 0.02
    out_std = std / math.sqrt(2 * cfg.num_layers)

    def mat(key, shape, s=std):
        return (jax.random.normal(key, shape) * s).astype(pdt)

    return {
        # --- time mix ---
        "mu_base": jnp.full((d,), 0.5, pdt),
        "mu": jnp.full((NUM_MIX, d), 0.5, pdt),
        "tm_w1": mat(k[0], (d, NUM_MIX * r.mix_lora)),
        "tm_w2": mat(k[1], (NUM_MIX, r.mix_lora, d)),
        "w0": jnp.full((d,), -1.0, pdt),          # base log-log decay
        "w1": mat(k[2], (d, r.decay_lora)),
        "w2": mat(k[3], (r.decay_lora, d)),
        "u": mat(k[4], (nh, r.head_dim), 0.1),    # per-head bonus
        "wr": mat(k[5], (d, d)),
        "wk": mat(k[6], (d, d)),
        "wv": mat(k[7], (d, d)),
        "wg": mat(k[8], (d, d)),
        "wo": mat(k[9], (d, d), out_std),
        "ln_x_scale": jnp.ones((d,), pdt),
        "ln_x_bias": jnp.zeros((d,), pdt),
        # --- channel mix ---
        "cm_mu_k": jnp.full((d,), 0.5, pdt),
        "cm_mu_r": jnp.full((d,), 0.5, pdt),
        "cm_wk": mat(k[10], (d, ff)),
        "cm_wv": mat(k[11], (ff, d), out_std),
        "cm_wr": mat(jax.random.fold_in(rng, 99), (d, d)),
        # --- norms ---
        "norm_tm": L.init_rms_norm(d, pdt),
        "norm_cm": L.init_rms_norm(d, pdt),
    }


def init(rng, cfg: ModelConfig) -> Dict[str, Any]:
    k_emb, k_layers = jax.random.split(rng)
    keys = jax.random.split(k_layers, cfg.num_layers)
    return {
        "embed": L.init_embedding(k_emb, cfg),
        "layers": jax.vmap(lambda k: init_block(k, cfg))(keys),
    }


def init_state(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> RWKVState:
    r = cfg.rwkv
    nh = cfg.d_model // r.head_dim
    Lc = cfg.num_layers
    return RWKVState(
        wkv=jnp.zeros((Lc, batch, nh, r.head_dim, r.head_dim), jnp.float32),
        tm_x=jnp.zeros((Lc, batch, cfg.d_model), dtype),
        cm_x=jnp.zeros((Lc, batch, cfg.d_model), dtype),
    )


# ----------------------------------------------------------------------
# token shift helpers
# ----------------------------------------------------------------------
def _shift_seq(x: jnp.ndarray, prev: Optional[jnp.ndarray]) -> jnp.ndarray:
    """[B, T, d] -> previous-token view; position 0 sees ``prev`` (or 0)."""
    shifted = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    if prev is not None:
        shifted = shifted.at[:, 0].set(prev)
    return shifted


def _decay(p, xw: jnp.ndarray) -> jnp.ndarray:
    """Data-dependent per-channel decay w in (0, 1). xw: [..., d]."""
    loglog = (p["w0"].astype(jnp.float32)
              + jnp.tanh(xw.astype(jnp.float32) @ p["w1"].astype(jnp.float32))
              @ p["w2"].astype(jnp.float32))
    return jnp.exp(-jnp.exp(loglog))


def _mix_inputs(p, x: jnp.ndarray, xx: jnp.ndarray) -> Tuple[jnp.ndarray, ...]:
    """Data-dependent token-shift lerp (ddlerp) for the 5 mixers."""
    base = x + xx * p["mu_base"].astype(x.dtype)
    lora = jnp.tanh(base.astype(jnp.float32)
                    @ p["tm_w1"].astype(jnp.float32))
    lora = lora.reshape(*lora.shape[:-1], NUM_MIX, -1)          # [...,5,lm]
    mix = jnp.einsum("...ml,mld->...md", lora,
                     p["tm_w2"].astype(jnp.float32))            # [...,5,d]
    mus = p["mu"].astype(jnp.float32)                           # [5, d]
    outs = []
    for i in range(NUM_MIX):
        outs.append(x + xx * (mus[i] + mix[..., i, :]).astype(x.dtype))
    return tuple(outs)  # xw, xk, xv, xr, xg


def _ln_x(p, y: jnp.ndarray, eps: float) -> jnp.ndarray:
    """Per-head group norm over head_dim (RWKV's ln_x), heads flattened."""
    yf = y.astype(jnp.float32)
    mean = jnp.mean(yf, axis=-1, keepdims=True)
    var = jnp.var(yf, axis=-1, keepdims=True)
    yn = (yf - mean) * jax.lax.rsqrt(var + eps)
    return yn


# ----------------------------------------------------------------------
# blocks (sequence form, for train/prefill)
# ----------------------------------------------------------------------
def time_mix_seq(p, x: jnp.ndarray, cfg: ModelConfig,
                 wkv_state: Optional[jnp.ndarray],
                 prev_x: Optional[jnp.ndarray]):
    B, T, d = x.shape
    hd = cfg.rwkv.head_dim
    nh = d // hd
    xx = _shift_seq(x, prev_x) - x
    xw, xk, xv, xr, xg = _mix_inputs(p, x, xx)
    r = (xr @ p["wr"]).reshape(B, T, nh, hd)
    k = (xk @ p["wk"]).reshape(B, T, nh, hd)
    v = (xv @ p["wv"]).reshape(B, T, nh, hd)
    g = jax.nn.silu(xg @ p["wg"])
    w = _decay(p, xw).reshape(B, T, nh, hd)

    y, wkv_state = ops.rwkv6(r, k, v, w.astype(jnp.float32), p["u"],
                             wkv_state)
    y = _ln_x(p, y.reshape(B, T, nh, hd), cfg.norm_eps).reshape(B, T, d)
    y = (y * p["ln_x_scale"].astype(jnp.float32)
         + p["ln_x_bias"].astype(jnp.float32)).astype(x.dtype)
    out = (y * g) @ p["wo"]
    return out, wkv_state, x[:, -1]


def channel_mix_seq(p, x: jnp.ndarray, prev_x: Optional[jnp.ndarray]):
    xx = _shift_seq(x, prev_x) - x
    xk = x + xx * p["cm_mu_k"].astype(x.dtype)
    xr = x + xx * p["cm_mu_r"].astype(x.dtype)
    k = jnp.square(jax.nn.relu(xk @ p["cm_wk"]))
    out = jax.nn.sigmoid(xr @ p["cm_wr"]) * (k @ p["cm_wv"])
    return out, x[:, -1]


def block_seq(p, x: jnp.ndarray, cfg: ModelConfig,
              state: Optional[Tuple] = None):
    """state: (wkv, tm_x, cm_x) for this layer, or None (fresh sequence)."""
    wkv, tm_x, cm_x = state if state is not None else (None, None, None)
    h = L.rms_norm(x, p["norm_tm"], cfg.norm_eps)
    dt, wkv, tm_x = time_mix_seq(p, h, cfg, wkv, tm_x)
    x = x + dt
    h = L.rms_norm(x, p["norm_cm"], cfg.norm_eps)
    dc, cm_x = channel_mix_seq(p, h, cm_x)
    x = x + dc
    return x, (wkv, tm_x, cm_x)


# ----------------------------------------------------------------------
# blocks (single-token form, for decode)
# ----------------------------------------------------------------------
def block_step(p, x: jnp.ndarray, cfg: ModelConfig, state: Tuple):
    """x: [B, d]; state: (wkv [B,NH,hd,hd], tm_x [B,d], cm_x [B,d])."""
    wkv, tm_x, cm_x = state
    B, d = x.shape
    hd = cfg.rwkv.head_dim
    nh = d // hd

    h = L.rms_norm(x, p["norm_tm"], cfg.norm_eps)
    xx = tm_x.astype(h.dtype) - h
    xw, xk, xv, xr, xg = _mix_inputs(p, h, xx)
    r = (xr @ p["wr"]).reshape(B, nh, hd)
    k = (xk @ p["wk"]).reshape(B, nh, hd)
    v = (xv @ p["wv"]).reshape(B, nh, hd)
    g = jax.nn.silu(xg @ p["wg"])
    w = _decay(p, xw).reshape(B, nh, hd)
    y, wkv = ops.rwkv6_step(r, k, v, w, p["u"], wkv)
    y = _ln_x(p, y, cfg.norm_eps).reshape(B, d)
    y = (y * p["ln_x_scale"].astype(jnp.float32)
         + p["ln_x_bias"].astype(jnp.float32)).astype(x.dtype)
    x = x + (y * g) @ p["wo"]
    new_tm_x = h

    h = L.rms_norm(x, p["norm_cm"], cfg.norm_eps)
    xxc = cm_x.astype(h.dtype) - h
    xkc = h + xxc * p["cm_mu_k"].astype(h.dtype)
    xrc = h + xxc * p["cm_mu_r"].astype(h.dtype)
    kc = jnp.square(jax.nn.relu(xkc @ p["cm_wk"]))
    x = x + jax.nn.sigmoid(xrc @ p["cm_wr"]) * (kc @ p["cm_wv"])
    new_cm_x = h
    return x, (wkv, new_tm_x, new_cm_x)


# ----------------------------------------------------------------------
# model-level entry points
# ----------------------------------------------------------------------
def forward(params, tokens: jnp.ndarray, cfg: ModelConfig,
            remat: bool = False) -> jnp.ndarray:
    x = L.embed(params["embed"], tokens, cfg)

    def body(h, lp):
        h, _ = block_seq(lp, h, cfg)
        return h, None

    if remat:
        body = L.remat_wrap(body)
    x, _ = L.layer_scan(body, x, params["layers"])
    return L.lm_logits(params["embed"], x, cfg)


def prefill(params, tokens: jnp.ndarray, cfg: ModelConfig,
            s_max: Optional[int] = None) -> Tuple[jnp.ndarray, RWKVState]:
    """Prefill = chunked scan over the prompt; returns fixed-size state."""
    del s_max  # state is fixed-size; no cache to pre-allocate
    x = L.embed(params["embed"], tokens, cfg)

    def body(h, lp):
        h, (wkv, tm_x, cm_x) = block_seq(lp, h, cfg)
        return h, (wkv, tm_x, cm_x)

    x, (wkv, tm_x, cm_x) = L.layer_scan(body, x, params["layers"])
    logits = L.lm_logits(params["embed"], x[:, -1:], cfg)[:, 0]
    return logits, RWKVState(wkv=wkv, tm_x=tm_x, cm_x=cm_x)


def decode_step(params, tokens: jnp.ndarray, state: RWKVState,
                pos: jnp.ndarray, cfg: ModelConfig
                ) -> Tuple[jnp.ndarray, RWKVState]:
    del pos  # recurrence is position-free
    x = L.embed(params["embed"], tokens[:, None], cfg)[:, 0]

    def body(h, xs):
        lp, wkv, tm_x, cm_x = xs
        h, (wkv, tm_x, cm_x) = block_step(lp, h, cfg, (wkv, tm_x, cm_x))
        return h, (wkv, tm_x, cm_x)

    x, (wkv, tm_x, cm_x) = L.layer_scan(
        body, x, (params["layers"], state.wkv, state.tm_x, state.cm_x))
    logits = L.lm_logits(params["embed"], x[:, None], cfg)[:, 0]
    return logits, RWKVState(wkv=wkv, tm_x=tm_x, cm_x=cm_x)


def loss_fn(params, batch: Dict[str, jnp.ndarray], cfg: ModelConfig,
            remat: bool = True):
    logits = forward(params, batch["tokens"], cfg, remat=remat)
    return TF.cross_entropy(logits, batch["targets"], batch.get("mask")), {}
