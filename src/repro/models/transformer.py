"""Dense decoder-only GQA transformer (yi-34b / qwen3 / command-r / qwen2 /
llama32-3b) with MaxText-style scanned layers.

Three entry points per model (the serving split the paper studies):
  forward      full-sequence training forward (causal)
  prefill      full-sequence forward that also returns the dense KV cache
  decode_step  one autoregressive token against the KV cache

KV cache layout: [L, B, S_max, KV, hd] stacked over layers so the layer
scan consumes it as xs. All attention math routes through repro.kernels.ops.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops
from . import layers as L


class AttnCache(NamedTuple):
    """Dense KV cache for attention archs. k/v: [L, B, S_max, KV, hd]."""
    k: jnp.ndarray
    v: jnp.ndarray


# ----------------------------------------------------------------------
# init
# ----------------------------------------------------------------------
def init_block(rng, cfg: ModelConfig) -> Dict[str, Any]:
    k1, k2 = jax.random.split(rng)
    pdt = L.dtype_of(cfg.param_dtype)
    return {
        "attn": L.init_attention(k1, cfg),
        "mlp": L.init_mlp(k2, cfg),
        "norm_attn": L.init_rms_norm(cfg.d_model, pdt),
        "norm_mlp": L.init_rms_norm(cfg.d_model, pdt),
    }


def init(rng, cfg: ModelConfig) -> Dict[str, Any]:
    k_emb, k_layers = jax.random.split(rng)
    layer_keys = jax.random.split(k_layers, cfg.num_layers)
    return {
        "embed": L.init_embedding(k_emb, cfg),
        "layers": jax.vmap(lambda k: init_block(k, cfg))(layer_keys),
    }


# ----------------------------------------------------------------------
# blocks
# ----------------------------------------------------------------------
def block_forward(p: Dict[str, Any], x: jnp.ndarray, positions: jnp.ndarray,
                  cfg: ModelConfig, *, return_kv: bool = False):
    """Full-seq pre-norm block. x: [B, S, d]; positions: [B, S]."""
    h = L.rms_norm(x, p["norm_attn"], cfg.norm_eps)
    q, k, v = L.qkv_project(p["attn"], h, cfg)
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    attn = L.flash_gqa(q, k, v, causal=True,
                        window=cfg.sliding_window)
    x = x + L.out_project(p["attn"], attn, cfg)
    h = L.rms_norm(x, p["norm_mlp"], cfg.norm_eps)
    x = x + L.mlp_forward(p["mlp"], h, cfg)
    if return_kv:
        return x, (k, v)
    return x


def block_decode(p: Dict[str, Any], x: jnp.ndarray, cache_k: jnp.ndarray,
                 cache_v: jnp.ndarray, pos: jnp.ndarray, cfg: ModelConfig):
    """One-token block step. x: [B, 1, d]; cache_*: [B, S_max, KV, hd];
    pos: [B] (index the new token is written at)."""
    h = L.rms_norm(x, p["norm_attn"], cfg.norm_eps)
    q, k, v = L.qkv_project(p["attn"], h, cfg)
    q = L.apply_rope(q, pos[:, None], cfg.rope_theta)
    k = L.apply_rope(k, pos[:, None], cfg.rope_theta)
    cache_k = L.cache_write(cache_k, k, pos)
    cache_v = L.cache_write(cache_v, v, pos)
    attn = L.cached_attention(q, cache_k, cache_v, pos,
                              window=cfg.sliding_window)
    x = x + L.out_project(p["attn"], attn, cfg)
    h = L.rms_norm(x, p["norm_mlp"], cfg.norm_eps)
    x = x + L.mlp_forward(p["mlp"], h, cfg)
    return x, cache_k, cache_v


# ----------------------------------------------------------------------
# model-level entry points
# ----------------------------------------------------------------------
def _scan_layers(body, x, layer_params, cfg: ModelConfig,
                 remat: bool = False, xs_extra=None):
    if remat:
        body = L.remat_wrap(body)
    xs = layer_params if xs_extra is None else (layer_params, *xs_extra)
    return L.layer_scan(body, x, xs)


def forward_from_embeddings(params, x: jnp.ndarray, positions: jnp.ndarray,
                            cfg: ModelConfig, remat: bool = False
                            ) -> jnp.ndarray:
    """x: [B, S, d] pre-embedded inputs -> logits [B, S, V] (VLM path)."""
    def body(h, lp):
        return block_forward(lp, h, positions, cfg), None

    x, _ = _scan_layers(body, x, params["layers"], cfg, remat)
    return L.lm_logits(params["embed"], x, cfg)


def forward(params, tokens: jnp.ndarray, cfg: ModelConfig,
            remat: bool = False) -> jnp.ndarray:
    """tokens: [B, S] -> logits [B, S, V]."""
    B, S = tokens.shape
    x = L.embed(params["embed"], tokens, cfg)
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    return forward_from_embeddings(params, x, positions, cfg, remat)


def prefill_from_embeddings(params, x: jnp.ndarray, positions: jnp.ndarray,
                            cfg: ModelConfig, s_max: Optional[int] = None
                            ) -> Tuple[jnp.ndarray, AttnCache]:
    """Pre-embedded prefill (VLM path). x: [B, S, d]."""
    B, S = x.shape[:2]
    s_max = s_max or S

    def body(h, lp):
        h, (k, v) = block_forward(lp, h, positions, cfg, return_kv=True)
        return h, (k, v)

    x, (ks, vs) = _scan_layers(body, x, params["layers"], cfg)
    if s_max > S:
        pad = [(0, 0), (0, s_max - S), (0, 0), (0, 0)]
        ks = jnp.pad(ks, [(0, 0)] + pad)
        vs = jnp.pad(vs, [(0, 0)] + pad)
    logits = L.lm_logits(params["embed"], x[:, -1:], cfg)[:, 0]
    return logits, AttnCache(k=ks, v=vs)


def prefill(params, tokens: jnp.ndarray, cfg: ModelConfig,
            s_max: Optional[int] = None
            ) -> Tuple[jnp.ndarray, AttnCache]:
    """tokens: [B, S] -> (last-position logits [B, V], cache)."""
    B, S = tokens.shape
    x = L.embed(params["embed"], tokens, cfg)
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    return prefill_from_embeddings(params, x, positions, cfg, s_max)


def decode_step(params, tokens: jnp.ndarray, cache: AttnCache,
                pos: jnp.ndarray, cfg: ModelConfig
                ) -> Tuple[jnp.ndarray, AttnCache]:
    """tokens: [B] new token ids; pos: [B] their positions.
    Returns (logits [B, V], updated cache)."""
    x = L.embed(params["embed"], tokens[:, None], cfg)

    def body(h, xs):
        lp, ck, cv = xs
        h, ck, cv = block_decode(lp, h, ck, cv, pos, cfg)
        return h, (ck, cv)

    x, (ks, vs) = L.layer_scan(body, x, (params["layers"], cache.k, cache.v))
    logits = L.lm_logits(params["embed"], x, cfg)[:, 0]
    return logits, AttnCache(k=ks, v=vs)


def loss_fn(params, batch: Dict[str, jnp.ndarray], cfg: ModelConfig,
            remat: bool = True) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    logits = forward(params, batch["tokens"], cfg, remat=remat)
    return cross_entropy(logits, batch["targets"], batch.get("mask")), {}


def cross_entropy(logits: jnp.ndarray, targets: jnp.ndarray,
                  mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def empty_cache(cfg: ModelConfig, batch: int, s_max: int,
                dtype=jnp.bfloat16) -> AttnCache:
    shape = (cfg.num_layers, batch, s_max, cfg.num_kv_heads, cfg.head_dim)
    return AttnCache(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype))
