"""Unified model API over the zoo — every engine/launcher call site uses this.

``Model(cfg)`` dispatches on ``cfg.family`` and normalizes the per-family
signatures to:

  init(rng) -> params
  loss(params, batch) -> (scalar, metrics)          batch: dict (train)
  forward(params, batch) -> logits
  prefill(params, batch, s_max) -> (logits[B,V], decode_state)
  decode_step(params, tokens[B], state, pos[B]) -> (logits[B,V], state)
  init_decode_state(batch_size, s_max) -> state pytree (zeros)
  train_inputs/prefill_inputs/decode_inputs(shape) -> ShapeDtypeStruct dicts
      (the dry-run stand-ins; weak-type-correct, no allocation)

The decode state is an opaque pytree: dense KV cache (dense/moe/vlm),
fixed-size recurrent state (ssm), mixed (hybrid), self+cross KV (encdec).
That opacity is what lets the serving core treat the paper's KV-transfer
paths uniformly across all ten architectures.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.configs.shapes import InputShape
from . import encdec as ED
from . import layers as L
from . import mamba2 as MB
from . import moe as MOE
from . import rwkv6 as RW
from . import transformer as TF
from . import vlm as VL


def _hybrid_window(cfg: ModelConfig, seq_len: int) -> int:
    """The shared attention block goes sliding-window at long context."""
    if cfg.family != "hybrid":
        return cfg.sliding_window
    w = cfg.hybrid.long_context_window
    return w if seq_len > 4 * w else 0


class Model:
    """Family-dispatched, signature-normalized model handle."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.family = cfg.family

    # ------------------------------------------------------------------
    def init(self, rng) -> Any:
        return {
            "dense": TF.init, "moe": MOE.init, "ssm": RW.init,
            "hybrid": MB.init, "encdec": ED.init, "vlm": VL.init,
        }[self.family](rng, self.cfg)

    def abstract_params(self) -> Any:
        return jax.eval_shape(lambda: self.init(jax.random.PRNGKey(0)))

    def param_count(self) -> int:
        import math
        return sum(math.prod(l.shape)
                   for l in jax.tree.leaves(self.abstract_params()))

    # ------------------------------------------------------------------
    def loss(self, params, batch: Dict[str, jnp.ndarray],
             remat: bool = True) -> Tuple[jnp.ndarray, Dict]:
        fn = {
            "dense": TF.loss_fn, "moe": MOE.loss_fn, "ssm": RW.loss_fn,
            "hybrid": MB.loss_fn, "encdec": ED.loss_fn, "vlm": VL.loss_fn,
        }[self.family]
        return fn(params, batch, self.cfg, remat=remat)

    def forward(self, params, batch: Dict[str, jnp.ndarray],
                remat: bool = False) -> jnp.ndarray:
        cfg = self.cfg
        if self.family in ("dense",):
            return TF.forward(params, batch["tokens"], cfg, remat)
        if self.family == "moe":
            return MOE.forward(params, batch["tokens"], cfg, remat)[0]
        if self.family == "ssm":
            return RW.forward(params, batch["tokens"], cfg, remat)
        if self.family == "hybrid":
            return MB.forward(params, batch["tokens"], cfg, remat)
        if self.family == "encdec":
            return ED.forward(params, batch, cfg, remat)
        if self.family == "vlm":
            return VL.forward(params, batch, cfg, remat)
        raise ValueError(self.family)

    # ------------------------------------------------------------------
    def prefill(self, params, batch: Dict[str, jnp.ndarray],
                s_max: Optional[int] = None) -> Tuple[jnp.ndarray, Any]:
        cfg = self.cfg
        if self.family == "dense":
            return TF.prefill(params, batch["tokens"], cfg, s_max)
        if self.family == "moe":
            return MOE.prefill(params, batch["tokens"], cfg, s_max)
        if self.family == "ssm":
            return RW.prefill(params, batch["tokens"], cfg, s_max)
        if self.family == "hybrid":
            S = batch["tokens"].shape[1]
            return MB.prefill(params, batch["tokens"], cfg, s_max,
                              window=_hybrid_window(cfg, s_max or S))
        if self.family == "encdec":
            return ED.prefill(params, batch, cfg, s_max)
        if self.family == "vlm":
            return VL.prefill(params, batch, cfg, s_max)
        raise ValueError(self.family)

    def decode_step(self, params, tokens: jnp.ndarray, state: Any,
                    pos: jnp.ndarray) -> Tuple[jnp.ndarray, Any]:
        cfg = self.cfg
        if self.family == "dense":
            return TF.decode_step(params, tokens, state, pos, cfg)
        if self.family == "moe":
            return MOE.decode_step(params, tokens, state, pos, cfg)
        if self.family == "ssm":
            return RW.decode_step(params, tokens, state, pos, cfg)
        if self.family == "hybrid":
            window = (cfg.hybrid.long_context_window
                      if state.attn_k.shape[2] == cfg.hybrid.long_context_window
                      else 0)
            return MB.decode_step(params, tokens, state, pos, cfg,
                                  window=window)
        if self.family == "encdec":
            return ED.decode_step(params, tokens, state, pos, cfg)
        if self.family == "vlm":
            return VL.decode_step(params, tokens, state, pos, cfg)
        raise ValueError(self.family)

    # ------------------------------------------------------------------
    def init_decode_state(self, batch_size: int, s_max: int,
                          dtype=jnp.bfloat16, s_src: int = 0) -> Any:
        cfg = self.cfg
        if self.family in ("dense", "moe", "vlm"):
            return TF.empty_cache(cfg, batch_size, s_max, dtype)
        if self.family == "ssm":
            return RW.init_state(cfg, batch_size, dtype)
        if self.family == "hybrid":
            return MB.init_state(cfg, batch_size, s_max, dtype,
                                 window=_hybrid_window(cfg, s_max))
        if self.family == "encdec":
            e = cfg.encdec
            Ld, kv, hd = e.num_decoder_layers, cfg.num_kv_heads, cfg.head_dim
            s_src = s_src or min(s_max, e.max_source_len)
            z = lambda s: jnp.zeros((Ld, batch_size, s, kv, hd), dtype)
            return ED.EncDecState(self_k=z(s_max), self_v=z(s_max),
                                  cross_k=z(s_src), cross_v=z(s_src))
        raise ValueError(self.family)

    # ------------------------------------------------------------------
    # Dry-run input stand-ins (ShapeDtypeStruct; no allocation)
    # ------------------------------------------------------------------
    def train_inputs(self, shape: InputShape) -> Dict[str, Any]:
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        i32 = functools.partial(jax.ShapeDtypeStruct, dtype=jnp.int32)
        bf16 = functools.partial(jax.ShapeDtypeStruct, dtype=jnp.bfloat16)
        if self.family == "encdec":
            return {"src_embeds": bf16((B, S, cfg.encdec.frontend_dim)),
                    "tokens": i32((B, S)), "targets": i32((B, S))}
        if self.family == "vlm":
            Np = cfg.vision.num_patches
            return {"patches": bf16((B, Np, cfg.vision.frontend_dim)),
                    "tokens": i32((B, S - Np)), "targets": i32((B, S - Np))}
        return {"tokens": i32((B, S)), "targets": i32((B, S))}

    def prefill_inputs(self, shape: InputShape) -> Dict[str, Any]:
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        i32 = functools.partial(jax.ShapeDtypeStruct, dtype=jnp.int32)
        bf16 = functools.partial(jax.ShapeDtypeStruct, dtype=jnp.bfloat16)
        if self.family == "encdec":
            # prompt == the source utterance; decoder starts from BOS
            return {"src_embeds": bf16((B, S, cfg.encdec.frontend_dim)),
                    "tokens": i32((B, 1))}
        if self.family == "vlm":
            Np = cfg.vision.num_patches
            return {"patches": bf16((B, Np, cfg.vision.frontend_dim)),
                    "tokens": i32((B, S - Np))}
        return {"tokens": i32((B, S))}

    def decode_inputs(self, shape: InputShape) -> Dict[str, Any]:
        """serve_step operands: one new token + the seq_len-deep state."""
        B, S = shape.global_batch, shape.seq_len
        state = jax.eval_shape(
            lambda: self.init_decode_state(B, S))
        return {
            "tokens": jax.ShapeDtypeStruct((B,), jnp.int32),
            "state": state,
            "pos": jax.ShapeDtypeStruct((B,), jnp.int32),
        }

    # ------------------------------------------------------------------
    # Concrete sample batches (CPU smoke tests / integration tests)
    # ------------------------------------------------------------------
    def sample_batch(self, rng, batch_size: int, seq_len: int,
                     kind: str = "train") -> Dict[str, jnp.ndarray]:
        cfg = self.cfg
        k1, k2, k3 = jax.random.split(rng, 3)
        toks = lambda k, shp: jax.random.randint(k, shp, 0, cfg.vocab_size)
        if self.family == "encdec":
            src = jax.random.normal(
                k3, (batch_size, seq_len, cfg.encdec.frontend_dim),
                jnp.float32) * 0.1
            if kind == "prefill":
                return {"src_embeds": src,
                        "tokens": toks(k1, (batch_size, 1))}
            return {"src_embeds": src,
                    "tokens": toks(k1, (batch_size, seq_len)),
                    "targets": toks(k2, (batch_size, seq_len))}
        if self.family == "vlm":
            Np = cfg.vision.num_patches
            S_txt = max(seq_len - Np, 1)
            patches = jax.random.normal(
                k3, (batch_size, Np, cfg.vision.frontend_dim),
                jnp.float32) * 0.1
            b = {"patches": patches, "tokens": toks(k1, (batch_size, S_txt))}
            if kind != "prefill":
                b["targets"] = toks(k2, (batch_size, S_txt))
            return b
        b = {"tokens": toks(k1, (batch_size, seq_len))}
        if kind != "prefill":
            b["targets"] = toks(k2, (batch_size, seq_len))
        return b


def get_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
