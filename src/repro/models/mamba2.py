"""Mamba2 blocks + the Zamba2 hybrid model (zamba2-2.7b).

Zamba2 = a backbone of Mamba2 blocks with ONE weight-tied ("shared") full
attention block invoked every ``hybrid.shared_attn_every`` layers. The
serving handoff state is therefore mixed (DESIGN.md section 8):

  conv   [L, B, cw-1, conv_dim]    causal-conv tail (fixed size)
  ssm    [L, B, NH, N, P]          SSD recurrence state (fixed size)
  attn   [G, B, S_cache, KV, hd]   KV cache of the G shared-block calls
                                   (the only per-token-growing part)

At 500k context the shared block runs with a sliding window
(``hybrid.long_context_window``) and its cache becomes a fixed-size ring —
that is what makes zamba2 a ``long_500k``-capable arch.
"""
from __future__ import annotations

import math
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops
from . import layers as L
from . import transformer as TF


class ZambaState(NamedTuple):
    conv: jnp.ndarray     # [L, B, cw-1, conv_dim]
    ssm: jnp.ndarray      # [L, B, NH, N, P] f32
    attn_k: jnp.ndarray   # [G, B, S_cache, KV, hd]
    attn_v: jnp.ndarray   # [G, B, S_cache, KV, hd]


def _dims(cfg: ModelConfig) -> Tuple[int, int, int, int]:
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    nh = d_in // s.head_dim
    conv_dim = d_in + 2 * s.state_dim
    return d_in, nh, conv_dim, s.state_dim


# ----------------------------------------------------------------------
# init
# ----------------------------------------------------------------------
def init_mamba_block(rng, cfg: ModelConfig) -> Dict[str, Any]:
    s = cfg.ssm
    d = cfg.d_model
    d_in, nh, conv_dim, N = _dims(cfg)
    pdt = L.dtype_of(cfg.param_dtype)
    k = jax.random.split(rng, 4)
    std = 0.02
    out_std = std / math.sqrt(2 * cfg.num_layers)
    # in_proj emits [z(d_in), x(d_in), B(N), C(N), dt(nh)]
    return {
        "in_proj": (jax.random.normal(k[0], (d, 2 * d_in + 2 * N + nh))
                    * std).astype(pdt),
        "conv_w": (jax.random.normal(k[1], (s.conv_width, conv_dim))
                   * (1.0 / math.sqrt(s.conv_width))).astype(pdt),
        "out_proj": (jax.random.normal(k[2], (d_in, d)) * out_std).astype(pdt),
        "gate_norm": jnp.ones((d_in,), pdt),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": (jax.random.uniform(k[3], (nh,), minval=-4.0, maxval=-1.0)
                    ).astype(jnp.float32),
        "norm": L.init_rms_norm(d, pdt),
    }


def init(rng, cfg: ModelConfig) -> Dict[str, Any]:
    k_emb, k_layers, k_attn = jax.random.split(rng, 3)
    keys = jax.random.split(k_layers, cfg.num_layers)
    pdt = L.dtype_of(cfg.param_dtype)
    return {
        "embed": L.init_embedding(k_emb, cfg),
        "mamba_layers": jax.vmap(lambda k: init_mamba_block(k, cfg))(keys),
        "shared_attn": {
            "attn": L.init_attention(k_attn, cfg),
            "norm": L.init_rms_norm(cfg.d_model, pdt),
        },
    }


def init_state(cfg: ModelConfig, batch: int, s_max: int,
               dtype=jnp.bfloat16, window: int = 0) -> ZambaState:
    s = cfg.ssm
    d_in, nh, conv_dim, N = _dims(cfg)
    G = cfg.num_layers // cfg.hybrid.shared_attn_every
    s_cache = min(window, s_max) if window else s_max
    return ZambaState(
        conv=jnp.zeros((cfg.num_layers, batch, s.conv_width - 1, conv_dim),
                       dtype),
        ssm=jnp.zeros((cfg.num_layers, batch, nh, N, s.head_dim),
                      jnp.float32),
        attn_k=jnp.zeros((G, batch, s_cache, cfg.num_kv_heads, cfg.head_dim),
                         dtype),
        attn_v=jnp.zeros((G, batch, s_cache, cfg.num_kv_heads, cfg.head_dim),
                         dtype),
    )


# ----------------------------------------------------------------------
# Mamba2 block (sequence form)
# ----------------------------------------------------------------------
def mamba_seq(p, x: jnp.ndarray, cfg: ModelConfig,
              conv_state: Optional[jnp.ndarray] = None,
              ssm_state: Optional[jnp.ndarray] = None):
    """x: [B, T, d] -> (out [B, T, d], (new_conv_state, new_ssm_state))."""
    s = cfg.ssm
    B, T, d = x.shape
    d_in, nh, conv_dim, N = _dims(cfg)

    proj = x @ p["in_proj"]                                    # [B,T,...]
    z, xc, Bm, Cm, dt_raw = jnp.split(
        proj, [d_in, 2 * d_in, 2 * d_in + N, 2 * d_in + 2 * N], axis=-1)

    # depthwise causal conv over [xc|B|C]
    xbc = jnp.concatenate([xc, Bm, Cm], axis=-1)               # [B,T,conv_dim]
    cw = s.conv_width
    if conv_state is None:
        tail = jnp.zeros((B, cw - 1, conv_dim), xbc.dtype)
    else:
        tail = conv_state.astype(xbc.dtype)
    padded = jnp.concatenate([tail, xbc], axis=1)              # [B,T+cw-1,...]
    w = p["conv_w"].astype(jnp.float32)
    conv = sum(padded[:, i:i + T].astype(jnp.float32) * w[i]
               for i in range(cw))
    conv = jax.nn.silu(conv).astype(xbc.dtype)
    new_conv_state = padded[:, -(cw - 1):] if cw > 1 else tail

    xc, Bm, Cm = jnp.split(conv, [d_in, d_in + N], axis=-1)
    xh = xc.reshape(B, T, nh, s.head_dim)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # [B,T,nh]
    A = -jnp.exp(p["A_log"])

    y, new_ssm = ops.mamba2(xh, dt, A, Bm, Cm, p["D"], ssm_state,
                            chunk=s.chunk_size)
    y = y.reshape(B, T, d_in)

    # gated RMSNorm (Mamba2's norm-before-out_proj with silu(z) gate)
    y = y * jax.nn.silu(z)
    y = L.rms_norm(y, p["gate_norm"], cfg.norm_eps)
    return y @ p["out_proj"], (new_conv_state, new_ssm)


def mamba_step(p, x: jnp.ndarray, cfg: ModelConfig,
               conv_state: jnp.ndarray, ssm_state: jnp.ndarray):
    """x: [B, d] single token -> (out [B, d], new states)."""
    s = cfg.ssm
    B, d = x.shape
    d_in, nh, conv_dim, N = _dims(cfg)

    proj = x @ p["in_proj"]
    z, xc, Bm, Cm, dt_raw = jnp.split(
        proj, [d_in, 2 * d_in, 2 * d_in + N, 2 * d_in + 2 * N], axis=-1)
    xbc = jnp.concatenate([xc, Bm, Cm], axis=-1)               # [B, conv_dim]

    w = p["conv_w"].astype(jnp.float32)
    window = jnp.concatenate(
        [conv_state.astype(jnp.float32), xbc.astype(jnp.float32)[:, None]],
        axis=1)                                                # [B, cw, cd]
    conv = jax.nn.silu(jnp.einsum("bwc,wc->bc", window, w)).astype(x.dtype)
    new_conv_state = window[:, 1:].astype(conv_state.dtype)

    xc, Bm, Cm = jnp.split(conv, [d_in, d_in + N], axis=-1)
    xh = xc.reshape(B, nh, s.head_dim)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # [B,nh]
    A = -jnp.exp(p["A_log"])
    y, new_ssm = ops.mamba2_step(xh, dt, A, Bm, Cm, p["D"], ssm_state)
    y = y.reshape(B, d_in)
    y = y * jax.nn.silu(z)
    y = L.rms_norm(y, p["gate_norm"], cfg.norm_eps)
    return y @ p["out_proj"], (new_conv_state, new_ssm)


# ----------------------------------------------------------------------
# shared attention block
# ----------------------------------------------------------------------
def shared_attn_seq(p, x: jnp.ndarray, positions: jnp.ndarray,
                    cfg: ModelConfig, window: int, *,
                    return_kv: bool = False):
    h = L.rms_norm(x, p["norm"], cfg.norm_eps)
    q, k, v = L.qkv_project(p["attn"], h, cfg)
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    attn = L.flash_gqa(q, k, v, causal=True, window=window)
    out = x + L.out_project(p["attn"], attn, cfg)
    if return_kv:
        return out, (k, v)
    return out


def _ring_write(cache: jnp.ndarray, val: jnp.ndarray, pos: jnp.ndarray,
                ring: bool) -> jnp.ndarray:
    """cache: [B, S_cache, KV, hd]; val: [B, 1, KV, hd]; pos: [B]."""
    slot = pos % cache.shape[1] if ring else pos
    return jax.vmap(lambda c, x, i: jax.lax.dynamic_update_slice(
        c, x, (i, 0, 0)))(cache, val.astype(cache.dtype), slot)


def shared_attn_step(p, x: jnp.ndarray, cache_k, cache_v, pos, cfg,
                     window: int):
    """x: [B, 1, d]. Ring cache when window>0 (cache size == window)."""
    h = L.rms_norm(x, p["norm"], cfg.norm_eps)
    q, k, v = L.qkv_project(p["attn"], h, cfg)
    q = L.apply_rope(q, pos[:, None], cfg.rope_theta)
    k = L.apply_rope(k, pos[:, None], cfg.rope_theta)
    ring = window > 0 and cache_k.shape[1] == window
    cache_k = _ring_write(cache_k, k, pos, ring)
    cache_v = _ring_write(cache_v, v, pos, ring)
    if ring:
        # every resident slot is within the window by construction
        B, _, H, hd = q.shape
        S_c = cache_k.shape[1]
        KV = cache_k.shape[2]
        G = H // KV
        qg = q.reshape(B, KV, G, hd).astype(jnp.float32)
        logits = jnp.einsum("bkgd,btkd->bkgt", qg,
                            cache_k.astype(jnp.float32)) / math.sqrt(hd)
        valid = jnp.arange(S_c)[None] <= pos[:, None]
        logits = jnp.where(valid[:, None, None], logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1)
        attn = jnp.einsum("bkgt,btkd->bkgd", probs,
                          cache_v.astype(jnp.float32))
        attn = attn.reshape(B, 1, H, hd).astype(q.dtype)
    else:
        attn = L.cached_attention(q, cache_k, cache_v, pos, window=window)
    out = x + L.out_project(p["attn"], attn, cfg)
    return out, cache_k, cache_v


# ----------------------------------------------------------------------
# model-level entry points
# ----------------------------------------------------------------------
def _group_params(params, cfg: ModelConfig):
    """Reshape stacked mamba layer params [L, ...] -> [G, every, ...]."""
    every = cfg.hybrid.shared_attn_every
    G = cfg.num_layers // every
    return jax.tree.map(
        lambda x: x.reshape(G, every, *x.shape[1:]), params["mamba_layers"]), G


def forward(params, tokens: jnp.ndarray, cfg: ModelConfig,
            remat: bool = False, window: int = 0) -> jnp.ndarray:
    B, S = tokens.shape
    x = L.embed(params["embed"], tokens, cfg)
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    grouped, G = _group_params(params, cfg)
    shared = params["shared_attn"]

    def group_body(h, group_lp):
        h = shared_attn_seq(shared, h, positions, cfg, window)

        def mamba_body(hh, lp):
            out, _ = mamba_seq(lp, hh, cfg)
            return hh + out, None

        h, _ = L.layer_scan(mamba_body, h, group_lp)
        return h, None

    if remat:
        group_body = L.remat_wrap(group_body)
    x, _ = L.layer_scan(group_body, x, grouped)
    return L.lm_logits(params["embed"], x, cfg)


def prefill(params, tokens: jnp.ndarray, cfg: ModelConfig,
            s_max: Optional[int] = None, window: int = 0
            ) -> Tuple[jnp.ndarray, ZambaState]:
    B, S = tokens.shape
    s_max = s_max or S
    s_cache = min(window, s_max) if window else s_max
    x = L.embed(params["embed"], tokens, cfg)
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    grouped, G = _group_params(params, cfg)
    shared = params["shared_attn"]

    def group_body(h, group_lp):
        h, (k, v) = shared_attn_seq(shared, h, positions, cfg, window,
                                    return_kv=True)

        def mamba_body(hh, lp):
            out, (cs, ss) = mamba_seq(lp, hh, cfg)
            return hh + out, (cs, ss)

        h, (conv_s, ssm_s) = L.layer_scan(mamba_body, h, group_lp)
        return h, (k, v, conv_s, ssm_s)

    x, (ks, vs, conv_s, ssm_s) = L.layer_scan(group_body, x, grouped)
    # ks/vs: [G, B, S, KV, hd]; conv_s/ssm_s: [G, every, B, ...] -> [L, B, ...]
    conv_s = conv_s.reshape(cfg.num_layers, *conv_s.shape[2:])
    ssm_s = ssm_s.reshape(cfg.num_layers, *ssm_s.shape[2:])

    if window and S > s_cache:
        # keep the last `window` tokens at their ring slots
        keep = jnp.arange(S - s_cache, S)
        slots = keep % s_cache
        ks_r = jnp.zeros((G, B, s_cache, *ks.shape[3:]), ks.dtype)
        ks_r = ks_r.at[:, :, slots].set(ks[:, :, keep])
        vs_r = jnp.zeros_like(ks_r)
        vs_r = vs_r.at[:, :, slots].set(vs[:, :, keep])
        ks, vs = ks_r, vs_r
    elif s_cache > S:
        pad = [(0, 0), (0, 0), (0, s_cache - S), (0, 0), (0, 0)]
        ks = jnp.pad(ks, pad)
        vs = jnp.pad(vs, pad)

    logits = L.lm_logits(params["embed"], x[:, -1:], cfg)[:, 0]
    return logits, ZambaState(conv=conv_s, ssm=ssm_s, attn_k=ks, attn_v=vs)


def decode_step(params, tokens: jnp.ndarray, state: ZambaState,
                pos: jnp.ndarray, cfg: ModelConfig, window: int = 0
                ) -> Tuple[jnp.ndarray, ZambaState]:
    x = L.embed(params["embed"], tokens[:, None], cfg)
    grouped, G = _group_params(params, cfg)
    shared = params["shared_attn"]
    every = cfg.hybrid.shared_attn_every
    conv = state.conv.reshape(G, every, *state.conv.shape[1:])
    ssm = state.ssm.reshape(G, every, *state.ssm.shape[1:])

    def group_body(h, xs):
        group_lp, ck, cv, conv_g, ssm_g = xs
        h, ck, cv = shared_attn_step(shared, h, ck, cv, pos, cfg, window)

        def mamba_body(hh, inner):
            lp, cs, ss = inner
            out, (cs, ss) = mamba_step(lp, hh[:, 0], cfg, cs, ss)
            return hh + out[:, None], (cs, ss)

        h, (conv_g, ssm_g) = L.layer_scan(mamba_body, h,
                                          (group_lp, conv_g, ssm_g))
        return h, (ck, cv, conv_g, ssm_g)

    x, (ks, vs, conv, ssm) = L.layer_scan(
        group_body, x, (grouped, state.attn_k, state.attn_v, conv, ssm))
    logits = L.lm_logits(params["embed"], x, cfg)[:, 0]
    return logits, ZambaState(
        conv=conv.reshape(cfg.num_layers, *conv.shape[2:]),
        ssm=ssm.reshape(cfg.num_layers, *ssm.shape[2:]),
        attn_k=ks, attn_v=vs)


def loss_fn(params, batch: Dict[str, jnp.ndarray], cfg: ModelConfig,
            remat: bool = True):
    logits = forward(params, batch["tokens"], cfg, remat=remat)
    return TF.cross_entropy(logits, batch["targets"], batch.get("mask")), {}
