from . import api, encdec, layers, mamba2, moe, rwkv6, transformer, vlm
from .api import Model, get_model

__all__ = ["Model", "get_model", "api", "layers", "transformer", "moe",
           "rwkv6", "mamba2", "encdec", "vlm"]
