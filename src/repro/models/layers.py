"""Common model building blocks (pure JAX, functional, pytree params).

Conventions:
  * params are nested dicts of jnp arrays; layer-stacked params carry a
    leading [L] axis and are consumed by jax.lax.scan (MaxText-style).
  * activations flow in ``cfg.compute_dtype``; norms/softmax/logits in f32.
  * attention math routes through ``repro.kernels.ops`` so the Pallas TPU
    kernels and the jnp references share one call site.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig

Params = Dict[str, jnp.ndarray]

# ----------------------------------------------------------------------
# Layer-scan unrolling. Default: rolled lax.scan (small HLO, fast compile).
# The roofline analysis sets full unrolling because XLA's cost_analysis
# counts a while-loop body ONCE, not times trip-count — rolled-scan FLOPs
# would understate the model by ~num_layers x.
# ----------------------------------------------------------------------
_SCAN_UNROLL = 1


def set_scan_unroll(unroll) -> None:
    """1 = rolled loop; True = fully unrolled (accurate cost_analysis)."""
    global _SCAN_UNROLL
    _SCAN_UNROLL = unroll


def layer_scan(body, init, xs, **kw):
    return jax.lax.scan(body, init, xs, unroll=_SCAN_UNROLL, **kw)


def remat_wrap(body):
    """Activation-checkpoint a layer body. With the ``remat_dots`` perf
    flag, matmul outputs are saved instead of recomputed (XLA's
    dots-saveable policy) — backward recompute then redoes only cheap
    elementwise work, cutting both recompute FLOPs and HBM traffic."""
    from repro.dist import opt_flags
    policy = None
    if opt_flags.enabled("remat_dots"):
        policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    return jax.checkpoint(body, prevent_cse=False, policy=policy)


def dtype_of(name: str):
    return {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
            "float16": jnp.float16}[name]


def flash_gqa(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
              causal: bool = True, window: int = 0,
              tp: int = 16) -> jnp.ndarray:
    """Full-sequence GQA attention with optional exact head regrouping.

    With the ``pad_heads`` perf flag and H % tp != 0 (yi-34b: 56, qwen2:
    14), queries are regrouped so the head dim divides the model axis:
    each kv head is DUPLICATED tp/KV times, and its G query heads are
    redistributed over the duplicates (zero-padded to equal group size).
    Zero q rows attend uniformly but their outputs are sliced away —
    bit-exact, and the pair tensors now shard tp-way instead of
    replicating across 'model'.
    """
    from repro.dist import opt_flags
    from repro.kernels import ops

    def _constrain_heads(*tensors):
        """head dim -> 'model' when divisible; everything else free."""
        if not opt_flags.enabled("head_shard_attn"):
            return tensors
        from jax.sharding import PartitionSpec as P
        out = []
        for t in tensors:
            if t.shape[2] % tp == 0:
                spec = P(*([P.UNCONSTRAINED] * 2 + ["model"]
                           + [P.UNCONSTRAINED] * (t.ndim - 3)))
                try:
                    t = jax.lax.with_sharding_constraint(t, spec)
                except Exception:
                    pass   # no mesh in scope (plain CPU tests)
            out.append(t)
        return tuple(out)

    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    if (not opt_flags.enabled("pad_heads") or H % tp == 0
            or tp % KV != 0 or KV >= tp):
        q, k, v = _constrain_heads(q, k, v)
        return ops.flash_attention(q, k, v, causal=causal, window=window)

    dup = tp // KV
    Gp = -(-G // dup)                     # q heads per duplicated kv head
    pad = dup * Gp - G
    qg = q.reshape(B, S, KV, G, hd)
    qg = jnp.pad(qg, [(0, 0), (0, 0), (0, 0), (0, pad), (0, 0)])
    # [B,S,KV,dup,Gp,hd] -> heads (KV*dup) * Gp, kv-major like GQA expects
    qg = qg.reshape(B, S, KV, dup, Gp, hd).reshape(B, S, KV * dup * Gp, hd)
    kd = jnp.repeat(k, dup, axis=2)
    vd = jnp.repeat(v, dup, axis=2)
    qg, kd, vd = _constrain_heads(qg, kd, vd)
    out = ops.flash_attention(qg, kd, vd, causal=causal, window=window)
    out = out.reshape(B, S, KV, dup * Gp, hd)[:, :, :, :G]
    return out.reshape(B, S, H, hd)


def cache_write(cache: jnp.ndarray, new: jnp.ndarray,
                pos: jnp.ndarray) -> jnp.ndarray:
    """Write one token's K or V into a [B, S, KV, hd] cache at per-batch
    position ``pos``. Default: per-batch dynamic_update_slice (a scatter).
    With ``masked_cache_update``, an elementwise select over the sequence
    dim — identical result, but it partitions cleanly when the cache is
    sharded (the scatter triggers SPMD full-rematerialization copies)."""
    from repro.dist import opt_flags
    if opt_flags.enabled("masked_cache_update"):
        idx = jnp.arange(cache.shape[1])[None, :, None, None]
        sel = idx == pos[:, None, None, None]
        return jnp.where(sel, new.astype(cache.dtype), cache)
    return jax.vmap(lambda c, x, i: jax.lax.dynamic_update_slice(
        c, x, (i, 0, 0)))(cache, new.astype(cache.dtype), pos)


# ----------------------------------------------------------------------
# Norms
# ----------------------------------------------------------------------
def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32)).astype(x.dtype)


def init_rms_norm(d: int, dtype) -> jnp.ndarray:
    return jnp.ones((d,), dtype=dtype)


# ----------------------------------------------------------------------
# Rotary position embeddings
# ----------------------------------------------------------------------
def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float) -> jnp.ndarray:
    """x: [..., seq, heads, head_dim]; positions: [..., seq]."""
    freqs = rope_frequencies(x.shape[-1], theta)           # [half]
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # [..., S, half]
    cos = jnp.cos(angles)[..., :, None, :]                 # [..., S, 1, half]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------------
# Attention (GQA with optional qk-norm / biases / sliding window)
# ----------------------------------------------------------------------
def init_attention(rng, cfg: ModelConfig, d_model: Optional[int] = None,
                   cross: bool = False) -> Params:
    d = d_model or cfg.d_model
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    pdt = dtype_of(cfg.param_dtype)
    k = jax.random.split(rng, 4)
    std = 0.02
    out_std = std / np.sqrt(2 * cfg.num_layers)
    p: Params = {
        "wq": (jax.random.normal(k[0], (d, h * hd)) * std).astype(pdt),
        "wk": (jax.random.normal(k[1], (d, kv * hd)) * std).astype(pdt),
        "wv": (jax.random.normal(k[2], (d, kv * hd)) * std).astype(pdt),
        "wo": (jax.random.normal(k[3], (h * hd, d)) * out_std).astype(pdt),
    }
    if cfg.attn_qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), pdt)
        p["bk"] = jnp.zeros((kv * hd,), pdt)
        p["bv"] = jnp.zeros((kv * hd,), pdt)
    if cfg.attn_out_bias:
        p["bo"] = jnp.zeros((d,), pdt)
    if cfg.qk_norm:
        p["q_norm"] = init_rms_norm(hd, pdt)
        p["k_norm"] = init_rms_norm(hd, pdt)
    if cross:
        p.pop("wq")  # cross-attn reuses q projection; keep separate k/v
        p["wq"] = (jax.random.normal(k[0], (d, h * hd)) * std).astype(pdt)
    return p


def qkv_project(p: Params, x: jnp.ndarray, cfg: ModelConfig
                ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """x: [B, S, d] -> q [B,S,H,hd], k/v [B,S,KV,hd]."""
    h, kv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = jnp.einsum("bsd,de->bse", x, p["wq"])
    k = jnp.einsum("bsd,de->bse", x, p["wk"])
    v = jnp.einsum("bsd,de->bse", x, p["wv"])
    if cfg.attn_qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    q = q.reshape(*x.shape[:-1], h, hd)
    k = k.reshape(*x.shape[:-1], kv, hd)
    v = v.reshape(*x.shape[:-1], kv, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    return q, k, v


def out_project(p: Params, attn: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """attn: [B, S, H, hd] -> [B, S, d]."""
    o = jnp.einsum("bsf,fd->bsd", attn.reshape(*attn.shape[:-2], -1), p["wo"])
    if cfg.attn_out_bias:
        o = o + p["bo"]
    return o


def attention_scores(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                     mask: Optional[jnp.ndarray]) -> jnp.ndarray:
    """Reference full-matrix attention. q [B,S,H,hd]; k,v [B,T,KV,hd].

    GQA: H = G * KV; computed grouped to avoid materializing repeated K/V.
    Used for training forward and small-scale serving; the Pallas flash /
    paged kernels are the TPU fast path (see repro.kernels).
    """
    B, S, H, hd = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    qg = q.reshape(B, S, KV, G, hd)
    logits = jnp.einsum("bskgd,btkd->bkgst", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) / np.sqrt(hd)
    if mask is not None:
        logits = jnp.where(mask[:, None, None, :, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v.astype(jnp.float32))
    return out.reshape(B, S, H, hd).astype(q.dtype)


def cached_attention(q: jnp.ndarray, cache_k: jnp.ndarray,
                     cache_v: jnp.ndarray, pos: jnp.ndarray,
                     window: int = 0) -> jnp.ndarray:
    """Decode-step attention against a dense KV cache.

    q: [B, 1, H, hd] (the new token's query, already rotated);
    cache_k/v: [B, T, KV, hd] (new K/V already written at ``pos``);
    pos: [B] per-sequence position of the new token.
    Reads the whole cache and masks positions > pos — the dense-cache
    analogue of the paged kernel (which skips unused pages instead).
    """
    B, _, H, hd = q.shape
    T, KV = cache_k.shape[1], cache_k.shape[2]
    G = H // KV
    qg = q.reshape(B, KV, G, hd).astype(jnp.float32)
    logits = jnp.einsum("bkgd,btkd->bkgt", qg,
                        cache_k.astype(jnp.float32)) / np.sqrt(hd)
    kpos = jnp.arange(T)[None, :]
    mask = kpos <= pos[:, None]
    if window > 0:
        mask = mask & (kpos > (pos[:, None] - window))
    logits = jnp.where(mask[:, None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgt,btkd->bkgd", probs,
                     cache_v.astype(jnp.float32))
    return out.reshape(B, 1, H, hd).astype(q.dtype)


def causal_mask(S: int, T: int, offset: int = 0,
                window: int = 0) -> jnp.ndarray:
    """[1, S, T] True where query i may attend key j."""
    qpos = jnp.arange(S)[:, None] + offset
    kpos = jnp.arange(T)[None, :]
    m = kpos <= qpos
    if window > 0:
        m = m & (kpos > qpos - window)
    return m[None]


# ----------------------------------------------------------------------
# FFN (SwiGLU / GELU)
# ----------------------------------------------------------------------
def init_mlp(rng, cfg: ModelConfig, d_ff: Optional[int] = None,
             d_model: Optional[int] = None) -> Params:
    d = d_model or cfg.d_model
    f = d_ff or cfg.d_ff
    pdt = dtype_of(cfg.param_dtype)
    k = jax.random.split(rng, 3)
    std = 0.02
    out_std = std / np.sqrt(2 * cfg.num_layers)
    p: Params = {
        "w_up": (jax.random.normal(k[1], (d, f)) * std).astype(pdt),
        "w_down": (jax.random.normal(k[2], (f, d)) * out_std).astype(pdt),
    }
    if cfg.act == "silu":
        p["w_gate"] = (jax.random.normal(k[0], (d, f)) * std).astype(pdt)
    if cfg.mlp_bias:
        p["b_up"] = jnp.zeros((f,), pdt)
        p["b_down"] = jnp.zeros((d,), pdt)
    return p


def mlp_forward(p: Params, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    up = jnp.einsum("...d,df->...f", x, p["w_up"])
    if cfg.mlp_bias:
        up = up + p["b_up"]
    if cfg.act == "silu":
        gate = jnp.einsum("...d,df->...f", x, p["w_gate"])
        hidden = jax.nn.silu(gate) * up
    else:
        hidden = jax.nn.gelu(up)
    out = jnp.einsum("...f,fd->...d", hidden, p["w_down"])
    if cfg.mlp_bias:
        out = out + p["b_down"]
    return out


# ----------------------------------------------------------------------
# Embedding / LM head
# ----------------------------------------------------------------------
def init_embedding(rng, cfg: ModelConfig) -> Params:
    pdt = dtype_of(cfg.param_dtype)
    k1, k2 = jax.random.split(rng)
    p: Params = {
        "embedding": (jax.random.normal(k1, (cfg.vocab_size, cfg.d_model))
                      * 0.02).astype(pdt),
        "final_norm": init_rms_norm(cfg.d_model, pdt),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = (jax.random.normal(k2, (cfg.d_model, cfg.vocab_size))
                        * 0.02).astype(pdt)
    return p


def embed(p: Params, tokens: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    return p["embedding"].astype(dtype_of(cfg.compute_dtype))[tokens]


def lm_logits(p: Params, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    from repro.dist import opt_flags
    x = rms_norm(x, p["final_norm"], cfg.norm_eps)
    w = (p["embedding"].T if cfg.tie_embeddings else p["lm_head"])
    if opt_flags.enabled("bf16_logits"):
        # keep the head matmul + logits tensor in bf16 (softmax/loss still
        # upcast): halves the largest single activation in the graph
        return jnp.einsum("...d,dv->...v", x, w)
    return jnp.einsum("...d,dv->...v", x.astype(jnp.float32),
                      w.astype(jnp.float32))
