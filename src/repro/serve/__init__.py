from . import steps
from .steps import StepBundle, build_decode_step, build_prefill_step, \
    build_step, build_train_step
