"""jit'd step builders with production shardings.

Used three ways:
  * launch/dryrun.py lowers+compiles them against ShapeDtypeStruct inputs
    on the production meshes (the multi-pod dry-run deliverable),
  * benchmarks/roofline.py reads their cost/memory analysis,
  * launch/train.py / launch/serve.py execute them for real (CPU-scale).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.configs.shapes import InputShape
from repro.dist.sharding import (batch_shardings, data_axes,
                                 opt_state_shardings, param_shardings,
                                 replicated, state_shardings)
from repro.models import get_model
from repro.train.optimizer import AdamWState, Optimizer, adamw, \
    apply_updates, cosine_schedule


class StepBundle(NamedTuple):
    """A jit'd step plus everything needed to lower or run it."""
    fn: Any                      # the jit'd callable
    abstract_args: Tuple         # ShapeDtypeStructs to .lower(*args) with
    shardings: Tuple             # in_shardings actually used
    model: Any


# ----------------------------------------------------------------------
def build_train_step(cfg: ModelConfig, mesh: Mesh, shape: InputShape, *,
                     remat: bool = True,
                     optimizer: Optional[Optimizer] = None) -> StepBundle:
    model = get_model(cfg)
    opt = optimizer or adamw(cosine_schedule(3e-4))
    abs_params = model.abstract_params()
    abs_opt = jax.eval_shape(opt.init, abs_params)
    p_sh = param_shardings(cfg, abs_params, mesh)
    # optimizer moments: ZeRO-sharded over data on top of the TP layout
    # (f32 m+v alone would exceed 16 GB HBM for the 34B archs otherwise)
    m_sh = opt_state_shardings(p_sh, abs_params, mesh)
    opt_sh = AdamWState(m=m_sh, v=m_sh, count=replicated(mesh))
    abs_batch = model.train_inputs(shape)
    b_sh = batch_shardings(abs_batch, mesh)

    def train_step(params, opt_state, batch):
        def loss_fn(p):
            loss, metrics = model.loss(p, batch, remat=remat)
            return loss, metrics
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params)
        updates, new_opt = opt.update(grads, opt_state, params)
        new_params = apply_updates(params, updates)
        return new_params, new_opt, loss

    fn = jax.jit(train_step,
                 in_shardings=(p_sh, opt_sh, b_sh),
                 out_shardings=(p_sh, opt_sh, None),
                 donate_argnums=(0, 1))
    return StepBundle(fn=fn, abstract_args=(abs_params, abs_opt, abs_batch),
                      shardings=(p_sh, opt_sh, b_sh), model=model)


# ----------------------------------------------------------------------
def build_prefill_step(cfg: ModelConfig, mesh: Mesh,
                       shape: InputShape) -> StepBundle:
    model = get_model(cfg)
    abs_params = model.abstract_params()
    p_sh = param_shardings(cfg, abs_params, mesh)
    abs_batch = model.prefill_inputs(shape)
    b_sh = batch_shardings(abs_batch, mesh)
    s_max = shape.seq_len

    def prefill_step(params, batch):
        return model.prefill(params, batch, s_max=s_max)

    fn = jax.jit(prefill_step, in_shardings=(p_sh, b_sh))
    return StepBundle(fn=fn, abstract_args=(abs_params, abs_batch),
                      shardings=(p_sh, b_sh), model=model)


# ----------------------------------------------------------------------
def build_decode_step(cfg: ModelConfig, mesh: Mesh,
                      shape: InputShape) -> StepBundle:
    """serve_step: one new token against a seq_len-deep decode state."""
    model = get_model(cfg)
    abs_params = model.abstract_params()
    p_sh = param_shardings(cfg, abs_params, mesh)
    inputs = model.decode_inputs(shape)
    abs_tokens, abs_state, abs_pos = (inputs["tokens"], inputs["state"],
                                      inputs["pos"])
    dp = data_axes(mesh)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    tok_spec = P(dp) if shape.global_batch % dp_size == 0 else P()
    tok_sh = NamedSharding(mesh, tok_spec)
    s_sh = state_shardings(abs_state, mesh)

    def serve_step(params, tokens, state, pos):
        return model.decode_step(params, tokens, state, pos)

    fn = jax.jit(serve_step,
                 in_shardings=(p_sh, tok_sh, s_sh, tok_sh),
                 donate_argnums=(2,))
    return StepBundle(fn=fn,
                      abstract_args=(abs_params, abs_tokens, abs_state,
                                     abs_pos),
                      shardings=(p_sh, tok_sh, s_sh, tok_sh), model=model)


# ----------------------------------------------------------------------
def build_step(kind: str, cfg: ModelConfig, mesh: Mesh,
               shape: InputShape, **kw) -> StepBundle:
    if kind == "train":
        return build_train_step(cfg, mesh, shape, **kw)
    if kind == "prefill":
        return build_prefill_step(cfg, mesh, shape)
    if kind == "decode":
        return build_decode_step(cfg, mesh, shape)
    raise ValueError(kind)
