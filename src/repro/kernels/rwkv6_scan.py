"""Pallas TPU kernel: chunked RWKV6 (Finch) recurrence.

rwkv6-3b is attention-free; its prefill hot spot is the data-dependent-decay
recurrence  S_{t+1} = diag(w_t) S_t + k_t v_t^T,  y_t = S_t^T r_t + bonus.

TPU adaptation (vs the CUDA kernel in the paper's lineage): instead of one
thread-per-channel serial scan, we use the *chunked* formulation —

  intra-chunk:  y_t += sum_{s<t} (r_t . d(s,t) k_s) v_s   (pairwise decay)
  inter-chunk:  y_t += (r_t * exp(lcum_{t-1})) @ S_0      (MXU matmul)
  state carry:  S_C = diag(exp(lcum_C)) S_0 + (k*exp(lcum_C - lcum))^T V

All decay exponent differences are <= 0 (decays are in (0,1)), so every
exp() argument is non-positive — numerically stable in f32 with no
re-normalization tricks. The state lives in VMEM scratch across the
sequential chunk grid dimension; chunk tiles of r/k/v/w stream HBM->VMEM.
The pairwise intra-chunk term is O(C^2 hd) on the VPU; C=64 keeps it minor
relative to the two MXU matmuls.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rwkv6_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref,
                  y_ref, sout_ref, state_ref, *, chunk: int):
    c = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(c == 0)
    def _init():
        state_ref[...] = s0_ref[...].reshape(state_ref.shape).astype(
            jnp.float32)

    hd = r_ref.shape[-1]
    r = r_ref[...].reshape(chunk, hd).astype(jnp.float32)
    k = k_ref[...].reshape(chunk, hd).astype(jnp.float32)
    v = v_ref[...].reshape(chunk, hd).astype(jnp.float32)
    w = w_ref[...].reshape(chunk, hd).astype(jnp.float32)
    u = u_ref[...].reshape(1, hd).astype(jnp.float32)

    logw = jnp.log(jnp.maximum(w, 1e-38))             # [C, hd], <= 0
    lcum = jnp.cumsum(logw, axis=0)                   # inclusive
    lprev = lcum - logw                               # exclusive

    S0 = state_ref[...]                               # [hd, hd] (key x value)

    # inter-chunk: (r * exp(lprev)) @ S0           -> MXU
    r_dec = r * jnp.exp(lprev)
    y = jax.lax.dot_general(r_dec, S0, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # intra-chunk pairwise decay attention (strictly lower triangular)
    #   A[t,s] = sum_c r[t,c] k[s,c] exp(lprev[t,c] - lcum[s,c]),  s < t
    diff = lprev[:, None, :] - lcum[None, :, :]       # [C, C, hd], <=0 on s<t
    tri = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) > \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    pair = jnp.where(tri[..., None], jnp.exp(diff), 0.0)
    A = jnp.einsum("tc,sc,tsc->ts", r, k, pair)
    # bonus diagonal: r_t . (u * k_t)
    bonus = jnp.sum(r * u * k, axis=-1)               # [C]
    A = A + jnp.diag(bonus)
    y = y + jax.lax.dot_general(A, v, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    y_ref[...] = y.reshape(y_ref.shape).astype(y_ref.dtype)

    # state carry: S = diag(exp(lcum_C)) S0 + (k * exp(lcum_C - lcum))^T V
    ltot = lcum[-1]                                   # [hd]
    k_dec = k * jnp.exp(ltot[None, :] - lcum)
    state_ref[...] = (jnp.exp(ltot)[:, None] * S0
                      + jax.lax.dot_general(
                          k_dec, v, (((0,), (0,)), ((), ())),
                          preferred_element_type=jnp.float32))

    @pl.when(c == nc - 1)
    def _final():
        sout_ref[...] = state_ref[...].reshape(sout_ref.shape)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def rwkv6_scan(r: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
               w: jnp.ndarray, u: jnp.ndarray, state: jnp.ndarray, *,
               chunk: int = 64, interpret: bool = False
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """r,k,v,w: [B, T, NH, hd]; u: [NH, hd]; state: [B, NH, hd, hd].

    Returns (y [B,T,NH,hd], final_state). T must be a chunk multiple
    (ops.py pads with w=1, k=0 which is a no-op for the recurrence).
    """
    B, T, NH, hd = r.shape
    assert T % chunk == 0, f"T={T} not a multiple of chunk={chunk}"
    nc = T // chunk

    # [B, T, NH, hd] -> [B, NH, T, hd] chunk-major access
    rt, kt, vt, wt = (x.transpose(0, 2, 1, 3) for x in (r, k, v, w))

    grid = (B, NH, nc)
    kernel = functools.partial(_rwkv6_kernel, chunk=chunk)
    seq_spec = pl.BlockSpec((1, 1, chunk, hd), lambda b, h, c: (b, h, c, 0))

    y, sout = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            seq_spec, seq_spec, seq_spec, seq_spec,
            pl.BlockSpec((1, hd), lambda b, h, c: (h, 0)),
            pl.BlockSpec((1, 1, hd, hd), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_specs=[
            seq_spec,
            pl.BlockSpec((1, 1, hd, hd), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, NH, T, hd), r.dtype),
            jax.ShapeDtypeStruct((B, NH, hd, hd), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        interpret=interpret,
    )(rt, kt, vt, wt, u, state)

    return y.transpose(0, 2, 1, 3), sout
