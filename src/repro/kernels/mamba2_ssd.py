"""Pallas TPU kernel: Mamba2 SSD chunked scan (zamba2's backbone blocks).

The SSD block-decomposition (Dao & Gu 2024) splits the scalar-decay SSM

    S_t = exp(A dt_t) S_{t-1} + B_t (dt_t x_t)^T ,   y_t = S_t^T C_t

into per-chunk dense work that is almost entirely MXU matmuls:

  intra:  Y += ((C B^T) * M) @ (dt*x)      M[t,s] = exp(L_t - L_s), s<=t
  inter:  Y += (C * exp(L)) @ S_0
  carry:  S_C = exp(L_C) S_0 + (B * exp(L_C - L))^T @ (dt*x)

(L = cumulative log-decay within the chunk; all exp args <= 0 -> stable.)

The [N, P] state sits in VMEM scratch across the sequential chunk grid
dimension; x/dt/B/C chunk tiles stream HBM->VMEM via BlockSpecs. B/C are
head-shared (1 group), so their tiles are fetched once per chunk per batch,
not once per head — the BlockSpec index_map ignores the head coordinate and
pallas' pipeline caches the unchanged block.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, d_ref, s0_ref,
                y_ref, sout_ref, state_ref, *, chunk: int):
    ci = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = s0_ref[...].reshape(state_ref.shape).astype(
            jnp.float32)

    P = x_ref.shape[-1]
    N = b_ref.shape[-1]
    x = x_ref[...].reshape(chunk, P).astype(jnp.float32)
    dt = dt_ref[...].reshape(chunk, 1).astype(jnp.float32)
    a = a_ref[0]                                       # scalar A (negative)
    bm = b_ref[...].reshape(chunk, N).astype(jnp.float32)
    cm = c_ref[...].reshape(chunk, N).astype(jnp.float32)
    d = d_ref[0]

    la = a * dt[:, 0]                                  # [C], <= 0
    L = jnp.cumsum(la)                                 # inclusive
    S0 = state_ref[...]                                # [N, P]

    xdt = x * dt                                       # dt-weighted input

    # intra-chunk: ((C B^T) * M) @ xdt
    scores = jax.lax.dot_general(cm, bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    diff = L[:, None] - L[None, :]                     # [C, C]
    tri = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    M = jnp.where(tri, jnp.exp(diff), 0.0)
    y = jax.lax.dot_general(scores * M, xdt, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # inter-chunk: (C * exp(L)) @ S0
    y = y + jax.lax.dot_general(cm * jnp.exp(L)[:, None], S0,
                                (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    # skip connection
    y = y + d * x
    y_ref[...] = y.reshape(y_ref.shape).astype(y_ref.dtype)

    # carry: S = exp(L_C) S0 + (B * exp(L_C - L))^T @ xdt
    ltot = L[-1]
    b_dec = bm * jnp.exp(ltot - L)[:, None]
    state_ref[...] = (jnp.exp(ltot) * S0
                      + jax.lax.dot_general(
                          b_dec, xdt, (((0,), (0,)), ((), ())),
                          preferred_element_type=jnp.float32))

    @pl.when(ci == nc - 1)
    def _final():
        sout_ref[...] = state_ref[...].reshape(sout_ref.shape)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def mamba2_ssd(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
               B_mat: jnp.ndarray, C_mat: jnp.ndarray, D: jnp.ndarray,
               state: jnp.ndarray, *, chunk: int = 128,
               interpret: bool = False
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: [B,T,NH,P]; dt: [B,T,NH]; A,D: [NH]; B_mat/C_mat: [B,T,N];
    state: [B,NH,N,P]. Returns (y [B,T,NH,P], final_state).

    T must be a chunk multiple (ops.py pads with dt=0, a no-op).
    """
    Bsz, T, NH, P = x.shape
    N = B_mat.shape[-1]
    assert T % chunk == 0, f"T={T} not a multiple of chunk={chunk}"
    nc = T // chunk

    xt = x.transpose(0, 2, 1, 3)                      # [B, NH, T, P]
    dtt = dt.transpose(0, 2, 1)                       # [B, NH, T]

    grid = (Bsz, NH, nc)
    kernel = functools.partial(_ssd_kernel, chunk=chunk)

    y, sout = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, chunk, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk), lambda b, h, c: (b, h, c)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
            # B/C are head-shared: index_map ignores h
            pl.BlockSpec((1, chunk, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
            pl.BlockSpec((1, 1, N, P), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, N, P), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bsz, NH, T, P), x.dtype),
            jax.ShapeDtypeStruct((Bsz, NH, N, P), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(xt, dtt, A, B_mat, C_mat, D, state)

    return y.transpose(0, 2, 1, 3), sout
