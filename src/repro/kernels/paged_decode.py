"""Pallas TPU kernel: paged attention for the decode stage.

Decode is the memory-bound stage (paper section II-A) and sets TPOT. The
central data structure of the paper's serving systems — the *paged KV
cache* (vLLM PagedAttention) — is indexed here directly on-chip:

  * The block table rides in SMEM as a *scalar-prefetch* operand
    (PrefetchScalarGridSpec). The K/V page BlockSpec index_map dereferences
    ``block_table[b, j]`` to pick which physical HBM page the pipeline DMAs
    into VMEM next — the gather never materializes a contiguous KV copy.
  * One grid cell per (batch, kv_head, page); online softmax accumulates in
    VMEM scratch across the sequential page dimension.
  * Pages past ``seq_len`` are skipped with pl.when — ragged batches pay
    only for their own length.
  * GQA: the G=H/KV query heads of a kv-head share the fetched page.

The per-token arithmetic intensity of decode is ~1 FLOP/byte of KV — this
kernel's job is purely to keep HBM streaming at line rate with no wasted
bytes, which is why page granularity (not sequence granularity) matters.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_kernel(block_table, seq_lens,          # scalar-prefetch (SMEM)
                  q_ref, k_ref, v_ref, o_ref,     # VMEM blocks
                  m_ref, l_ref, acc_ref, *,       # VMEM scratch
                  scale: float, page: int):
    b = pl.program_id(0)
    j = pl.program_id(2)            # page index within the sequence
    npages = pl.num_programs(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    seq_len = seq_lens[b]
    in_use = j * page < seq_len

    @pl.when(in_use)
    def _body():
        g, hd = q_ref.shape[-2], q_ref.shape[-1]
        q = q_ref[...].reshape(g, hd)
        k = k_ref[...].reshape(page, hd)
        v = v_ref[...].reshape(page, hd)

        s = jax.lax.dot_general(
            q.astype(jnp.float32), k.astype(jnp.float32),
            (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale      # [G, page]

        kpos = j * page + jax.lax.broadcasted_iota(jnp.int32, (1, page), 1)
        s = jnp.where(kpos < seq_len, s, NEG_INF)

        m_prev = m_ref[...]
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_next = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_next)
        p = jnp.exp(s - m_next)
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v.astype(jnp.float32), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_next

    @pl.when(j == npages - 1)
    def _finalize():
        l = l_ref[...]
        safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[...] = (acc_ref[...] / safe).reshape(o_ref.shape).astype(
            o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_attention(q: jnp.ndarray, k_pages: jnp.ndarray,
                    v_pages: jnp.ndarray, block_table: jnp.ndarray,
                    seq_lens: jnp.ndarray, *,
                    interpret: bool = False) -> jnp.ndarray:
    """q: [B, H, hd]; k_pages/v_pages: [P, page, KV, hd];
    block_table: [B, max_pages] int32; seq_lens: [B] int32 -> [B, H, hd].
    """
    B, H, hd = q.shape
    page, KV = k_pages.shape[1], k_pages.shape[2]
    G = H // KV
    max_pages = block_table.shape[1]
    scale = 1.0 / np.sqrt(hd)

    qg = q.reshape(B, KV, G, hd)
    # [P, page, KV, hd] -> [KV, P, page, hd]: page-major per kv-head so a
    # BlockSpec block is one physical page of one kv head.
    kp = k_pages.transpose(2, 0, 1, 3)
    vp = v_pages.transpose(2, 0, 1, 3)

    grid = (B, KV, max_pages)
    kernel = functools.partial(_paged_kernel, scale=scale, page=page)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, G, hd),
                         lambda b, h, j, bt, sl: (b, h, 0, 0)),
            # Dereference the block table to pick the HBM page to DMA.
            pl.BlockSpec((1, 1, page, hd),
                         lambda b, h, j, bt, sl: (h, bt[b, j], 0, 0)),
            pl.BlockSpec((1, 1, page, hd),
                         lambda b, h, j, bt, sl: (h, bt[b, j], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd),
                               lambda b, h, j, bt, sl: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
    )

    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, G, hd), q.dtype),
        interpret=interpret,
    )(block_table, seq_lens, qg, kp, vp)

    return out.reshape(B, H, hd)
