"""Pure-jnp oracles for every Pallas kernel in this package.

These are the correctness references (kernel tests assert allclose against
them) AND the CPU/dry-run execution path (``ops.py`` dispatches here when not
running on TPU, so the whole framework runs on CPU and the lowered HLO used
for roofline analysis is clean XLA attention/scan code).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


# ----------------------------------------------------------------------
# Flash attention (prefill): causal GQA attention, optional sliding window
# ----------------------------------------------------------------------
def flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        *, causal: bool = True, window: int = 0,
                        q_offset: int = 0) -> jnp.ndarray:
    """q: [B, S, H, hd]; k, v: [B, T, KV, hd] -> [B, S, H, hd].

    ``q_offset`` places the query block at absolute position offset within
    the key sequence (used for chunked prefill).
    """
    B, S, H, hd = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    qg = q.reshape(B, S, KV, G, hd).astype(jnp.float32)
    logits = jnp.einsum("bskgd,btkd->bkgst", qg, k.astype(jnp.float32))
    logits = logits / np.sqrt(hd)
    qpos = jnp.arange(S) + q_offset
    kpos = jnp.arange(T)
    mask = jnp.ones((S, T), dtype=bool)
    if causal:
        mask = kpos[None, :] <= qpos[:, None]
    if window > 0:
        mask = mask & (kpos[None, :] > qpos[:, None] - window)
    logits = jnp.where(mask[None, None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", probs, v.astype(jnp.float32))
    return out.reshape(B, S, H, hd).astype(q.dtype)


# ----------------------------------------------------------------------
# Paged attention (decode): one query token vs block-table-indexed KV pages
# ----------------------------------------------------------------------
def paged_attention_ref(q: jnp.ndarray, k_pages: jnp.ndarray,
                        v_pages: jnp.ndarray, block_table: jnp.ndarray,
                        seq_lens: jnp.ndarray) -> jnp.ndarray:
    """q: [B, H, hd]; k_pages/v_pages: [P, page, KV, hd];
    block_table: [B, max_pages] int32 (entries past the sequence are
    arbitrary); seq_lens: [B] int32 -> out [B, H, hd].
    """
    B, H, hd = q.shape
    page = k_pages.shape[1]
    KV = k_pages.shape[2]
    G = H // KV
    max_pages = block_table.shape[1]
    T = max_pages * page

    # Gather this sequence's pages into a contiguous [B, T, KV, hd] view.
    k_seq = k_pages[block_table].reshape(B, T, KV, hd)
    v_seq = v_pages[block_table].reshape(B, T, KV, hd)

    qg = q.reshape(B, KV, G, hd).astype(jnp.float32)
    logits = jnp.einsum("bkgd,btkd->bkgt", qg,
                        k_seq.astype(jnp.float32)) / np.sqrt(hd)
    valid = jnp.arange(T)[None, :] < seq_lens[:, None]        # [B, T]
    logits = jnp.where(valid[:, None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgt,btkd->bkgd", probs, v_seq.astype(jnp.float32))
    return out.reshape(B, H, hd).astype(q.dtype)


# ----------------------------------------------------------------------
# RWKV6 (Finch) time-mix recurrence with data-dependent per-channel decay
# ----------------------------------------------------------------------
def rwkv6_scan_ref(r: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                   w: jnp.ndarray, u: jnp.ndarray,
                   state: Optional[jnp.ndarray] = None
                   ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Sequential oracle of the RWKV6 recurrence.

    r,k,v: [B, T, NH, hd]; w: [B, T, NH, hd] (per-channel decay in (0,1),
    already exp(-exp(.)) transformed); u: [NH, hd] bonus.
    state: [B, NH, hd, hd] (key-dim x value-dim), default zeros.
    Returns (out [B,T,NH,hd], final_state).

      out_t = (S_t^T r_t) + (r_t . (u*k_t)) v_t
      S_{t+1} = diag(w_t) S_t + k_t v_t^T
    """
    B, T, NH, hd = r.shape
    if state is None:
        state = jnp.zeros((B, NH, hd, hd), jnp.float32)
    rf, kf, vf, wf = (x.astype(jnp.float32) for x in (r, k, v, w))
    uf = u.astype(jnp.float32)

    def step(S, inp):
        rt, kt, vt, wt = inp                      # [B, NH, hd]
        # state contribution: sum_c r[c] * S[c, :]
        y = jnp.einsum("bhc,bhcj->bhj", rt, S)
        # bonus (current token) contribution
        y = y + jnp.einsum("bhc,bhc->bh", rt, uf[None] * kt)[..., None] * vt
        S = wt[..., :, None] * S + kt[..., :, None] * vt[..., None, :]
        return S, y

    xs = (jnp.moveaxis(rf, 1, 0), jnp.moveaxis(kf, 1, 0),
          jnp.moveaxis(vf, 1, 0), jnp.moveaxis(wf, 1, 0))
    state, ys = jax.lax.scan(step, state, xs)
    return jnp.moveaxis(ys, 0, 1).astype(r.dtype), state


# ----------------------------------------------------------------------
# Mamba2 SSD recurrence (scalar-per-head decay)
# ----------------------------------------------------------------------
def mamba2_ssd_ref(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
                   B_mat: jnp.ndarray, C_mat: jnp.ndarray,
                   D: Optional[jnp.ndarray] = None,
                   state: Optional[jnp.ndarray] = None
                   ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Sequential oracle of the Mamba2 state-space recurrence.

    x: [B, T, NH, P] inputs; dt: [B, T, NH] (softplus-ed step, > 0);
    A: [NH] (negative; decay = exp(A*dt)); B_mat/C_mat: [B, T, N] (shared
    across heads, 1 group); D: [NH] skip, optional;
    state: [B, NH, N, P], default zeros.

      S_t = exp(A dt_t) S_{t-1} + B_t (dt_t x_t)^T
      y_t = S_t^T C_t + D x_t
    """
    Bsz, T, NH, P = x.shape
    N = B_mat.shape[-1]
    if state is None:
        state = jnp.zeros((Bsz, NH, N, P), jnp.float32)
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Bf = B_mat.astype(jnp.float32)
    Cf = C_mat.astype(jnp.float32)
    Af = A.astype(jnp.float32)

    def step(S, inp):
        xt, dtt, Bt, Ct = inp                      # [B,NH,P],[B,NH],[B,N],[B,N]
        decay = jnp.exp(Af[None] * dtt)            # [B, NH]
        S = (decay[..., None, None] * S
             + Bt[:, None, :, None] * (dtt[..., None] * xt)[:, :, None, :])
        y = jnp.einsum("bhnp,bn->bhp", S, Ct)
        return S, y

    xs = (jnp.moveaxis(xf, 1, 0), jnp.moveaxis(dtf, 1, 0),
          jnp.moveaxis(Bf, 1, 0), jnp.moveaxis(Cf, 1, 0))
    state, ys = jax.lax.scan(step, state, xs)
    y = jnp.moveaxis(ys, 0, 1)
    if D is not None:
        y = y + D.astype(jnp.float32)[None, None, :, None] * xf
    return y.astype(x.dtype), state
