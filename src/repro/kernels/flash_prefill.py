"""Pallas TPU kernel: causal flash attention for the prefill stage.

Prefill is the compute-bound stage (paper section II-A) and sets TTFT. The
kernel is an online-softmax flash attention with:

  * BlockSpec VMEM tiling: q tile [bq, G*hd] stays resident; K/V stream
    through VMEM in [bk, hd] tiles (HBM -> VMEM pipelined by pallas grid).
  * GQA folded into the q tile: the grid iterates kv-heads and each q tile
    carries its G = H/KV query heads, so K/V tiles are fetched once per
    kv-head (not once per query head) — GQA's bandwidth saving realized.
  * MXU-aligned tiles (q block 256, kv block 256; hd is 64/80/128 padded to
    a lane multiple by the caller).
  * Causal block skipping: kv-blocks strictly above the diagonal contribute
    nothing and are skipped with pl.when (the dominant saving at 32k seq).
  * Optional sliding window (zamba2's shared block at long context).

Accumulators (m, l, acc) live in VMEM scratch and persist across the
innermost (kv) grid dimension — TPU grids execute sequentially, which is
what makes this single-pass online softmax legal.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, window: int, bq: int, bk: int,
                  seq_len: int, q_offset: int):
    qi = pl.program_id(2)          # query block index
    kj = pl.program_id(3)          # kv block index
    nk = pl.num_programs(3)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Block-level causal/window skip: query rows span
    # [q_offset + qi*bq, q_offset + (qi+1)*bq); kv cols span [kj*bk, (kj+1)*bk).
    q_lo = q_offset + qi * bq
    q_hi = q_lo + bq - 1
    k_lo = kj * bk
    k_hi = k_lo + bk - 1
    needed = True
    if causal:
        needed = k_lo <= q_hi
    if window > 0:
        needed = jnp.logical_and(needed, k_hi > q_lo - window)

    @pl.when(needed)
    def _body():
        q = q_ref[...].reshape(bq * q_ref.shape[-2], q_ref.shape[-1])
        k = k_ref[...].reshape(bk, k_ref.shape[-1])
        v = v_ref[...].reshape(bk, v_ref.shape[-1])
        g = q_ref.shape[-2]

        s = jax.lax.dot_general(
            q.astype(jnp.float32), k.astype(jnp.float32),
            (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # [bq*G, bk]

        qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, g), 0)
        qpos = qpos.reshape(bq * g, 1)
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)
        mask = kpos < seq_len
        if causal:
            mask = jnp.logical_and(mask, kpos <= qpos)
        if window > 0:
            mask = jnp.logical_and(mask, kpos > qpos - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_next = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_next)
        p = jnp.exp(s - m_next)
        l_next = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v.astype(jnp.float32), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_next
        l_ref[...] = l_next

    @pl.when(kj == nk - 1)
    def _finalize():
        l = l_ref[...]
        safe = jnp.where(l == 0.0, 1.0, l)
        out = acc_ref[...] / safe
        o_ref[...] = out.reshape(o_ref.shape).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "q_offset", "block_q", "block_k",
                     "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: int = 0, q_offset: int = 0,
                    block_q: int = 256, block_k: int = 256,
                    interpret: bool = False) -> jnp.ndarray:
    """q: [B, S, H, hd]; k, v: [B, T, KV, hd] -> [B, S, H, hd]."""
    B, S, H, hd = q.shape
    T, KV = k.shape[1], k.shape[2]
    G = H // KV
    bq = min(block_q, S)
    bk = min(block_k, T)
    nq = pl.cdiv(S, bq)
    nk = pl.cdiv(T, bk)
    scale = 1.0 / np.sqrt(hd)

    qg = q.reshape(B, S, KV, G, hd).transpose(0, 2, 1, 3, 4)  # [B,KV,S,G,hd]
    kg = k.transpose(0, 2, 1, 3)                              # [B,KV,T,hd]
    vg = v.transpose(0, 2, 1, 3)
    # zero-pad to block multiples: OOB block reads would otherwise feed
    # undefined values into p @ v (0 * garbage != 0 when garbage is NaN);
    # the in-kernel kpos < seq_len mask keeps the math exact
    if nq * bq > S:
        qg = jnp.pad(qg, [(0, 0), (0, 0), (0, nq * bq - S), (0, 0), (0, 0)])
    if nk * bk > T:
        pad = [(0, 0), (0, 0), (0, nk * bk - T), (0, 0)]
        kg = jnp.pad(kg, pad)
        vg = jnp.pad(vg, pad)

    grid = (B, KV, nq, nk)
    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window, bq=bq,
        bk=bk, seq_len=T, q_offset=q_offset)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, G, hd), lambda b, h, i, j: (b, h, i, 0, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j: (b, h, j, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j: (b, h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, G, hd),
                               lambda b, h, i, j: (b, h, i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, KV, nq * bq, G, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq * G, 1), jnp.float32),   # running max m
            pltpu.VMEM((bq * G, 1), jnp.float32),   # running denom l
            pltpu.VMEM((bq * G, hd), jnp.float32),  # output accumulator
        ],
        interpret=interpret,
    )(qg, kg, vg)

    out = out[:, :, :S].transpose(0, 2, 1, 3, 4).reshape(B, S, H, hd)
    return out
