"""jit'd dispatch wrappers over the Pallas kernels and their jnp oracles.

Every model/eingine call site goes through this module. Backend selection:

  auto             -> 'pallas' on TPU, 'ref' elsewhere (CPU container,
                      dry-run lowering, XLA-fused reference path)
  ref              -> pure-jnp oracle (kernels/ref.py)
  pallas           -> compiled Pallas TPU kernel
  pallas_interpret -> Pallas kernel body executed in Python on CPU
                      (correctness validation in this container)

Set the process-wide default with ``set_default_backend`` or the
REPRO_KERNEL_BACKEND environment variable.
"""
from __future__ import annotations

import os
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from . import flash_prefill as _flash
from . import mamba2_ssd as _ssd
from . import paged_decode as _paged
from . import ref
from . import rwkv6_scan as _rwkv

_DEFAULT = os.environ.get("REPRO_KERNEL_BACKEND", "auto")


def set_default_backend(backend: str) -> None:
    global _DEFAULT
    assert backend in ("auto", "ref", "pallas", "pallas_interpret"), backend
    _DEFAULT = backend


def resolve_backend(backend: Optional[str]) -> str:
    b = backend or _DEFAULT
    if b == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "ref"
    return b


# ----------------------------------------------------------------------
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    q_offset: int = 0, backend: Optional[str] = None):
    b = resolve_backend(backend)
    if b == "ref":
        return ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                       q_offset=q_offset)
    return _flash.flash_attention(q, k, v, causal=causal, window=window,
                                  q_offset=q_offset,
                                  interpret=(b == "pallas_interpret"))


def paged_attention(q, k_pages, v_pages, block_table, seq_lens, *,
                    backend: Optional[str] = None):
    b = resolve_backend(backend)
    if b == "ref":
        return ref.paged_attention_ref(q, k_pages, v_pages, block_table,
                                       seq_lens)
    return _paged.paged_attention(q, k_pages, v_pages, block_table, seq_lens,
                                  interpret=(b == "pallas_interpret"))


# ----------------------------------------------------------------------
def _pad_seq(x, chunk, axis=1, value=0.0):
    T = x.shape[axis]
    pad = (-T) % chunk
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def rwkv6(r, k, v, w, u, state, *, chunk: int = 64,
          backend: Optional[str] = None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    b = resolve_backend(backend)
    if b == "ref":
        return ref.rwkv6_scan_ref(r, k, v, w, u, state)
    if state is None:
        B, _, NH, hd = r.shape
        state = jnp.zeros((B, NH, hd, hd), jnp.float32)
    T = r.shape[1]
    # pad to chunk multiple: w=1 (zero log-decay), k=0 -> recurrence no-op
    rp = _pad_seq(r, chunk)
    kp = _pad_seq(k, chunk)
    vp = _pad_seq(v, chunk)
    wp = _pad_seq(w, chunk, value=1.0)
    y, s = _rwkv.rwkv6_scan(rp, kp, vp, wp, u, state, chunk=chunk,
                            interpret=(b == "pallas_interpret"))
    return y[:, :T], s


def rwkv6_step(r, k, v, w, u, state) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Single-token recurrent step (decode). r..w: [B, NH, hd]."""
    rf, kf, vf, wf = (x.astype(jnp.float32) for x in (r, k, v, w))
    y = jnp.einsum("bhc,bhcj->bhj", rf, state)
    y = y + jnp.einsum("bhc,bhc->bh", rf,
                       u.astype(jnp.float32)[None] * kf)[..., None] * vf
    state = wf[..., :, None] * state + kf[..., :, None] * vf[..., None, :]
    return y.astype(r.dtype), state


def mamba2(x, dt, A, B_mat, C_mat, D, state, *, chunk: int = 128,
           backend: Optional[str] = None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    b = resolve_backend(backend)
    if b == "ref":
        return ref.mamba2_ssd_ref(x, dt, A, B_mat, C_mat, D, state)
    if state is None:
        B, _, NH, P = x.shape
        state = jnp.zeros((B, NH, B_mat.shape[-1], P), jnp.float32)
    T = x.shape[1]
    xp = _pad_seq(x, chunk)
    dtp = _pad_seq(dt, chunk)     # dt=0 -> decay 1, contribution 0: no-op
    Bp = _pad_seq(B_mat, chunk)
    Cp = _pad_seq(C_mat, chunk)
    y, s = _ssd.mamba2_ssd(xp, dtp, A, Bp, Cp, D, state, chunk=chunk,
                           interpret=(b == "pallas_interpret"))
    return y[:, :T], s


def mamba2_step(x, dt, A, B_mat, C_mat, D, state
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Single-token SSM step (decode). x: [B,NH,P]; dt: [B,NH];
    B_mat/C_mat: [B,N]; state: [B,NH,N,P]."""
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    decay = jnp.exp(A.astype(jnp.float32)[None] * dtf)       # [B, NH]
    state = (decay[..., None, None] * state
             + B_mat.astype(jnp.float32)[:, None, :, None]
             * (dtf[..., None] * xf)[:, :, None, :])
    y = jnp.einsum("bhnp,bn->bhp", state, C_mat.astype(jnp.float32))
    y = y + D.astype(jnp.float32)[None, :, None] * xf
    return y.astype(x.dtype), state
