"""The declarative Experiment spec (DESIGN.md section 12).

The paper's contribution is a benchmark *matrix* — setup x KV-transfer
medium x load x frequency — and every knob of one cell lives here as a
frozen value object:

  * ``Experiment``: arch + ``FleetSpec`` (shape, per-instance phi,
    routers, governor) + a workload descriptor + the scoring SLO.
  * ``ClosedLoop``: the paper's RandomDataset (batch at t=0), including
    the RAG-displaced-document variant ``reuse_bench`` measures.
  * ``OpenLoop``: arrival process x length mix x n x seed — the
    DistServe-style load axis.
  * ``ReuseSpec``: the prefix-cache / PIC configuration of the KV-reuse
    experiment (section II-C) — defined in ``repro.kvstore`` (where the
    tiered extension lives, DESIGN.md section 15) and re-exported here.

A spec is canonically JSON-serializable (``to_json`` / ``from_json``
round-trip exactly) and content-addressed: ``spec_hash()`` is the
sha256 of the canonical JSON, stable across processes and Python
versions, and is the cache key of ``repro.exp.cache`` together with the
``RunRecord`` schema version. Everything an ``Experiment`` references —
``FleetSpec``, arrival processes, length mixes, ``SLO`` — is encoded by
registry kind + dataclass fields, so adding a new arrival process or
mix automatically extends the spec language.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from dataclasses import dataclass, field, replace
from typing import Any, Dict, List, Optional, Tuple, Union

import numpy as np

from repro.core.request import Request, SLO, random_workload
from repro.fleet.spec import FleetSpec, as_fleet_spec, setup_label
from repro.kvstore import ReuseSpec, TierSpec, as_reuse_spec
from repro.workload.arrivals import _ARRIVALS, ArrivalProcess
from repro.workload.lengths import (_MIXES, LengthMix, MixtureLengths,
                                    PaperFixedLengths)
from repro.workload.spec import WorkloadSpec

__all__ = ["ClosedLoop", "OpenLoop", "ReuseSpec", "TierSpec",
           "Experiment", "encode_slo", "decode_slo", "registered_arch",
           "apply_spec_knobs", "as_cacheable"]


# ----------------------------------------------------------------------
# registry-based encoding for the polymorphic pieces
# ----------------------------------------------------------------------
_ARRIVAL_KINDS = {cls: kind for kind, cls in _ARRIVALS.items()}
_MIX_KINDS = {cls: kind for kind, cls in _MIXES.items()}
_MIXTURE_KIND = "mixture"


def _encode_fields(obj) -> Dict[str, Any]:
    """Shallow dataclass fields -> JSON-safe dict (tuples become lists)."""
    out = {}
    for f in dataclasses.fields(obj):
        v = getattr(obj, f.name)
        if isinstance(v, tuple):
            v = list(v)
        out[f.name] = v
    return out


def encode_arrivals(proc: ArrivalProcess) -> Dict[str, Any]:
    kind = _ARRIVAL_KINDS.get(type(proc))
    if kind is None:
        raise TypeError(
            f"arrival process {type(proc).__name__} is not in the "
            f"repro.workload.arrivals registry; register it to make it "
            f"spec-addressable")
    return {"kind": kind, **_encode_fields(proc)}


def decode_arrivals(d: Dict[str, Any]) -> ArrivalProcess:
    d = dict(d)
    return _ARRIVALS[d.pop("kind")](**d)


def encode_lengths(mix: LengthMix) -> Dict[str, Any]:
    if isinstance(mix, MixtureLengths):
        return {"kind": _MIXTURE_KIND,
                "components": [[w, encode_lengths(m)]
                               for w, m in mix.components]}
    kind = _MIX_KINDS.get(type(mix))
    if kind is None:
        raise TypeError(
            f"length mix {type(mix).__name__} is not in the "
            f"repro.workload.lengths registry; register it to make it "
            f"spec-addressable")
    return {"kind": kind, **_encode_fields(mix)}


def decode_lengths(d: Dict[str, Any]) -> LengthMix:
    d = dict(d)
    kind = d.pop("kind")
    if kind == _MIXTURE_KIND:
        return MixtureLengths(components=tuple(
            (w, decode_lengths(m)) for w, m in d["components"]))
    return _MIXES[kind](**d)


def encode_slo(slo: Optional[SLO]) -> Optional[Dict[str, Any]]:
    if slo is None:
        return None
    return {"ttft_s": slo.ttft_s, "tpot_s": slo.tpot_s}


def decode_slo(d: Optional[Dict[str, Any]]) -> Optional[SLO]:
    if d is None:
        return None
    return SLO(ttft_s=d.get("ttft_s"), tpot_s=d.get("tpot_s"))


def encode_fleet(spec: FleetSpec) -> Dict[str, Any]:
    d = _encode_fields(spec)
    if spec.controller is None:
        # static fleets omit the key entirely: canonical JSON (hence
        # every pre-controller spec hash and cached result) is unchanged
        d.pop("controller")
    else:
        d["controller"] = _encode_fields(spec.controller)
    if spec.reuse is None:
        # same omit-when-None rule for fleet-level KV reuse (PR 8):
        # every pre-reuse spec hash survives bit-identical
        d.pop("reuse")
    else:
        d["reuse"] = spec.reuse.encode()
    if spec.scheduler is None:
        # omit-when-None again (repro.sched): pre-scheduler hashes pinned
        d.pop("scheduler")
    else:
        d["scheduler"] = _encode_fields(spec.scheduler)
    if spec.n_intra == 0:
        # the intra-GPU shape keys only exist for intra fleets — every
        # co / xP:yD spec hash survives bit-identical
        d.pop("n_intra")
        d.pop("intra_split")
    return d


def decode_fleet(d: Dict[str, Any]) -> FleetSpec:
    d = dict(d)
    for k in ("phi_prefill", "phi_decode", "governor"):
        if isinstance(d.get(k), list):
            d[k] = tuple(d[k])
    return FleetSpec(**d)


# ----------------------------------------------------------------------
# workload descriptors
# ----------------------------------------------------------------------
@dataclass(frozen=True)
class ClosedLoop:
    """The paper's RandomDataset: ``batch`` requests at t=0.

    ``rag_doc_len`` > 0 reproduces the reuse benchmark's RAG workload: a
    shared document of that many tokens is written at ``rag_doc_offset``
    into every prompt (openings differ, so plain prefix matching
    whiffs). ``shared_prefix_len`` > 0 is the simpler identical-prefix
    variant. Both need ``vocab_size`` > 0 (real token ids)."""
    batch: int
    input_len: int = 16_384
    output_len: int = 256
    seed: int = 0
    vocab_size: int = 0
    shared_prefix_len: int = 0
    rag_doc_len: int = 0
    rag_doc_offset: int = 1024

    def build(self, slo: Optional[SLO] = None) -> List[Request]:
        reqs = random_workload(self.batch, input_len=self.input_len,
                               output_len=self.output_len,
                               vocab_size=self.vocab_size, seed=self.seed,
                               shared_prefix_len=self.shared_prefix_len)
        if self.rag_doc_len:
            assert self.vocab_size > 0, "rag_doc_len needs real token ids"
            # same draw order as the historical reuse_bench RAG builder:
            # the shared document comes from its own seeded stream, then
            # is spliced over every prompt at the displacement offset
            rng = np.random.default_rng(self.seed)
            doc = rng.integers(0, self.vocab_size, self.rag_doc_len)
            lo = self.rag_doc_offset
            for r in reqs:
                r.prompt_tokens[lo:lo + self.rag_doc_len] = doc
        if slo is not None:
            for r in reqs:
                r.slo = dataclasses.replace(slo)
        return reqs

    def encode(self) -> Dict[str, Any]:
        return {"kind": "closed", **_encode_fields(self)}


@dataclass(frozen=True)
class OpenLoop:
    """An open-loop workload: arrival process x length mix x n x seed.

    The SLO stamped on the materialized requests is the *experiment's*
    (``Experiment.slo``) — one scoring SLO per cell, the DistServe
    setting — so the same ``OpenLoop`` can be reused across SLO axes."""
    arrivals: ArrivalProcess
    lengths: LengthMix = field(default_factory=PaperFixedLengths)
    n: int = 24
    seed: int = 0
    vocab_size: int = 0

    @classmethod
    def make(cls, rate: float, n: int, *, arrival: str = "poisson",
             lengths: Optional[LengthMix] = None, seed: int = 0,
             vocab_size: int = 0, **arrival_kw) -> "OpenLoop":
        """Mirror of ``repro.workload.open_loop_workload``'s argument
        conventions (incl. the ramp's rate0/ramp_s defaults), returning
        the spec instead of the materialized requests."""
        from repro.workload.arrivals import make_arrivals
        if arrival == "ramp":
            arrival_kw.setdefault("rate1", rate)
            arrival_kw.setdefault("rate0", rate / 4.0)
            arrival_kw.setdefault("ramp_s", 0.5 * n / rate)
            proc = make_arrivals("ramp", **arrival_kw)
        else:
            proc = make_arrivals(arrival, rate=rate, **arrival_kw)
        return cls(arrivals=proc,
                   lengths=lengths if lengths is not None
                   else PaperFixedLengths(),
                   n=n, seed=seed, vocab_size=vocab_size)

    @property
    def rate(self) -> float:
        return self.arrivals.nominal_rate

    def with_rate(self, rate: float) -> "OpenLoop":
        """Same process family at a different nominal rate (the load
        axis of a ``Grid``). Processes with a single ``rate`` field are
        replaced in place; the ramp rescales rate0/rate1 by the ratio."""
        proc = self.arrivals
        if hasattr(proc, "rate"):
            proc = replace(proc, rate=float(rate))
        elif hasattr(proc, "rate1"):
            scale = float(rate) / proc.rate1
            proc = replace(proc, rate0=proc.rate0 * scale,
                           rate1=float(rate))
        else:
            raise TypeError(f"cannot re-rate {type(proc).__name__}")
        return replace(self, arrivals=proc)

    def build(self, slo: Optional[SLO] = None) -> List[Request]:
        return WorkloadSpec(arrivals=self.arrivals, lengths=self.lengths,
                            n=self.n, seed=self.seed, slo=slo,
                            vocab_size=self.vocab_size).build()

    def encode(self) -> Dict[str, Any]:
        return {"kind": "open", "arrivals": encode_arrivals(self.arrivals),
                "lengths": encode_lengths(self.lengths), "n": self.n,
                "seed": self.seed, "vocab_size": self.vocab_size}


Workload = Union[ClosedLoop, OpenLoop]


def decode_workload(d: Dict[str, Any]) -> Workload:
    d = dict(d)
    kind = d.pop("kind")
    if kind == "closed":
        return ClosedLoop(**d)
    if kind == "open":
        return OpenLoop(arrivals=decode_arrivals(d["arrivals"]),
                        lengths=decode_lengths(d["lengths"]), n=d["n"],
                        seed=d["seed"], vocab_size=d.get("vocab_size", 0))
    raise ValueError(f"unknown workload kind {kind!r}")


def as_workload(w) -> Workload:
    """Normalize the accepted workload forms: a descriptor passes
    through; a ``repro.workload.WorkloadSpec`` converts to ``OpenLoop``
    (its embedded SLO is dropped — the experiment's SLO governs)."""
    if isinstance(w, (ClosedLoop, OpenLoop)):
        return w
    if isinstance(w, WorkloadSpec):
        return OpenLoop(arrivals=w.arrivals, lengths=w.lengths, n=w.n,
                        seed=w.seed, vocab_size=w.vocab_size)
    raise TypeError(f"not a workload descriptor: {type(w).__name__}")


# ----------------------------------------------------------------------
@dataclass(frozen=True, eq=True)
class Experiment:
    """One cell of the benchmark matrix, fully determined and hashable.

    ``fleet`` accepts a ``FleetSpec``, a legacy setup name ("dis-ici"),
    or a fleet-shape string ("2P2D-ici"); ``setup`` is the display /
    sweep-row label and defaults to the name the fleet was given (so a
    cell built from "dis-ici" reports as "dis-ici", not "1P1D-ici").

    Identity is content-addressed: ``spec_hash()`` over the canonical
    JSON is the cache key; ``==`` and ``hash()`` follow it.
    """
    arch: str
    fleet: FleetSpec
    workload: Workload
    slo: Optional[SLO] = None
    setup: Optional[str] = None
    reuse: Optional[ReuseSpec] = None
    # simulator knobs that historically traveled as cluster kwargs
    prefill_token_budget: int = 8192
    page_size: int = 16

    def __post_init__(self):
        label = self.setup
        if not isinstance(self.fleet, FleetSpec):
            if label is None and isinstance(self.fleet, str):
                label = self.fleet
            object.__setattr__(self, "fleet", as_fleet_spec(self.fleet))
        object.__setattr__(self, "workload", as_workload(self.workload))
        object.__setattr__(self, "setup",
                           label if label is not None else self.fleet.name)
        if self.reuse is not None and not isinstance(self.reuse,
                                                     ReuseSpec):
            object.__setattr__(self, "reuse", as_reuse_spec(self.reuse))

    # ------------------------------------------------------------------
    # canonical serialization / content address
    # ------------------------------------------------------------------
    def to_dict(self) -> Dict[str, Any]:
        return {
            "arch": self.arch,
            "fleet": encode_fleet(self.fleet),
            "workload": self.workload.encode(),
            "slo": encode_slo(self.slo),
            "setup": self.setup,
            "reuse": self.reuse.encode() if self.reuse else None,
            "prefill_token_budget": self.prefill_token_budget,
            "page_size": self.page_size,
        }

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "Experiment":
        return cls(arch=d["arch"], fleet=decode_fleet(d["fleet"]),
                   workload=decode_workload(d["workload"]),
                   slo=decode_slo(d.get("slo")), setup=d.get("setup"),
                   reuse=as_reuse_spec(d["reuse"]) if d.get("reuse")
                   else None,
                   prefill_token_budget=d.get("prefill_token_budget", 8192),
                   page_size=d.get("page_size", 16))

    def to_json(self) -> str:
        """Canonical form: sorted keys, no whitespace variance — the
        string whose sha256 is the content address."""
        return json.dumps(self.to_dict(), sort_keys=True,
                          separators=(",", ":"))

    @classmethod
    def from_json(cls, s: str) -> "Experiment":
        return cls.from_dict(json.loads(s))

    def spec_hash(self) -> str:
        return hashlib.sha256(self.to_json().encode()).hexdigest()

    def __hash__(self):
        # SLO is a plain (unhashable) dataclass; identity is the
        # canonical JSON, consistent with the content-addressed cache
        return hash(self.to_json())

    # ------------------------------------------------------------------
    # axis helpers (the Grid's setters; also pleasant by hand)
    # ------------------------------------------------------------------
    def with_fleet(self, fleet) -> "Experiment":
        label = fleet if isinstance(fleet, str) else setup_label(fleet)
        return replace(self, fleet=as_fleet_spec(fleet), setup=label)

    def with_phi(self, phi=None, phi_prefill=None,
                 phi_decode=None) -> "Experiment":
        return replace(self, fleet=self.fleet.with_phi(
            phi=phi, phi_prefill=phi_prefill, phi_decode=phi_decode))

    def with_governor(self, governor) -> "Experiment":
        return replace(self, fleet=replace(self.fleet, governor=governor))

    def with_controller(self, controller) -> "Experiment":
        """Attach (or with None, detach) an online fleet controller —
        a policy name, kwargs dict, or ``ControllerSpec``."""
        return replace(self, fleet=replace(self.fleet,
                                           controller=controller))

    def with_reuse(self, reuse) -> "Experiment":
        """Attach (or with None, detach) experiment-level KV reuse — a
        mode string, kwargs dict (``tiers`` as a nested dict is fine),
        or ``ReuseSpec``. Fleet-level reuse (``FleetSpec.reuse``) is the
        other home: identical simulation, distinct cache hash."""
        return replace(self, reuse=as_reuse_spec(reuse))

    def with_scheduler(self, scheduler) -> "Experiment":
        """Attach (or with None, detach) a per-step scheduler policy
        (repro.sched) — a composer/admission name, kwargs dict, or
        ``SchedulerSpec``. None is the legacy engine byte-for-byte."""
        return replace(self, fleet=replace(self.fleet,
                                           scheduler=scheduler))

    def with_workload(self, **kw) -> "Experiment":
        return replace(self, workload=replace(self.workload, **kw))

    def with_rate(self, rate: float) -> "Experiment":
        return replace(self, workload=self.workload.with_rate(rate))

    # ------------------------------------------------------------------
    # constructors for the two canonical cell families
    # ------------------------------------------------------------------
    @classmethod
    def closed(cls, setup, batch: int, *, arch: str = "llama32-3b",
               input_len: int = 16_384, output_len: int = 256,
               seed: int = 0, slo: Optional[SLO] = None,
               **kw) -> "Experiment":
        """The paper's Experiment-1 cell: ``batch`` requests at t=0."""
        return cls(arch=arch, fleet=setup,
                   workload=ClosedLoop(batch=batch, input_len=input_len,
                                       output_len=output_len, seed=seed),
                   slo=slo, **kw)

    @classmethod
    def open(cls, setup, rate: float, *, arch: str = "llama32-3b",
             n: int = 24, arrival: str = "poisson",
             lengths: Optional[LengthMix] = None, seed: int = 0,
             slo: Optional[SLO] = None, vocab_size: int = 0,
             arrival_kw: Optional[Dict[str, Any]] = None,
             **kw) -> "Experiment":
        """An open-loop cell: named arrival process at ``rate`` req/s."""
        return cls(arch=arch, fleet=setup,
                   workload=OpenLoop.make(rate, n, arrival=arrival,
                                          lengths=lengths, seed=seed,
                                          vocab_size=vocab_size,
                                          **(arrival_kw or {})),
                   slo=slo, **kw)


# ----------------------------------------------------------------------
# the shims' shared gating rules: what may be content-addressed, and how
# legacy cluster kwargs map onto the spec. One definition — the sweep,
# dvfs, and benchmark entrypoints must not drift in what gets cached.
# ----------------------------------------------------------------------
def registered_arch(cfg) -> Optional[str]:
    """``cfg`` -> registry arch name, or None when the config is
    off-registry or a modified copy. Only the registered object itself
    may be content-addressed: a tweaked config under the same name must
    never alias a cached cell of a different cost model."""
    from repro.configs import REGISTRY
    name = getattr(cfg, "name", None)
    if name is not None and REGISTRY.get(name) == cfg:
        return name
    return None


def apply_spec_knobs(exp: "Experiment", kw: Dict[str, Any]):
    """Map the legacy cluster kwargs that have spec equivalents —
    ``phi`` / ``phi_prefill`` / ``phi_decode`` / ``governor`` — onto
    ``exp``. Returns ``(exp, leftovers)``; the caller decides whether
    leftovers are a TypeError (benchmark helpers) or a fall-back to
    direct simulation (the shims)."""
    kw = dict(kw)
    phi = {k: kw.pop(k) for k in ("phi", "phi_prefill", "phi_decode")
           if k in kw}
    if phi:
        exp = exp.with_phi(**phi)
    if "governor" in kw:
        exp = exp.with_governor(kw.pop("governor"))
    if "controller" in kw:
        exp = exp.with_controller(kw.pop("controller"))
    if "reuse" in kw:
        exp = exp.with_reuse(kw.pop("reuse"))
    if "scheduler" in kw:
        exp = exp.with_scheduler(kw.pop("scheduler"))
    return exp, kw


def as_cacheable(exp: "Experiment") -> Optional["Experiment"]:
    """``exp`` iff it can be content-addressed (every polymorphic piece
    is registry-encodable), else None — an unregistered arrival process
    or length mix means direct, uncached simulation."""
    try:
        exp.to_json()
    except TypeError:
        return None
    return exp
