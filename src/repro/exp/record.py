"""RunRecord: the one result schema behind every figure and report.

A ``RunRecord`` is the JSON-stable aggregate of a single ``Experiment``
run — workload metrics, per-component and per-stage energy, goodput
scoring, governor activity — and is what the content-addressed cache
stores. The schema is versioned: ``SCHEMA_VERSION`` is part of the
cache key, so changing the record's meaning (new fields are fine;
changed semantics are not) must bump it, which invalidates every cached
cell at once instead of silently mixing generations.

Float fidelity: values round-trip through JSON exactly (Python floats
serialize via repr), so a cache hit is value-identical to the
simulation that produced it — the figure-parity goldens rely on this.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Dict, List, Optional

from repro.core.request import WorkloadMetrics

__all__ = ["SCHEMA_VERSION", "EnergyView", "RunRecord",
           "prefill_side_j", "decode_side_j"]

# bump on any semantic change to the record (field meaning, energy
# attribution, metric definition); every cached cell re-simulates
SCHEMA_VERSION = 1


def prefill_side_j(by_stage: Dict[str, float]) -> float:
    """Active energy attributed to the prefill side of a run: the stage
    itself plus the KV store leg it drives. THE per-leg attribution
    rule (store -> prefill, fetch -> decode) — fig5, the F6 claim
    check, and the DVFS sweeps all call this, so changing the rule
    changes all of them together. Tiered-KV traffic (DESIGN.md section
    15) is prefill-side by the same rule: demand fetches precede (and
    delay) the prefill that consumes the pages, spills are driven by
    prefill-side inserts. These stages only exist for tiered specs, so
    pre-PR records are numerically unchanged (no schema bump)."""
    return by_stage.get("prefill", 0.0) \
        + by_stage.get("transfer-store", 0.0) \
        + by_stage.get("tier-fetch", 0.0) \
        + by_stage.get("tier-spill", 0.0)


def decode_side_j(by_stage: Dict[str, float]) -> float:
    """Decode-side twin of ``prefill_side_j``: decode + the fetch leg
    that occupies the decode engine at admission."""
    return by_stage.get("decode", 0.0) + by_stage.get("transfer-fetch",
                                                      0.0)


@dataclass(frozen=True)
class EnergyView:
    """The slice of ``EnergyMeter`` the figures consume, reconstructed
    from a record: totals plus the component/stage attributions."""
    joules: Dict[str, float]
    by_stage: Dict[str, float]

    @property
    def total_j(self) -> float:
        return sum(self.joules.values())

    def breakdown(self) -> Dict[str, float]:
        return dict(self.joules)


@dataclass(frozen=True)
class RunRecord:
    """Stable result schema, shared by all figures and report tooling."""
    schema_version: int
    spec_hash: str
    spec: Dict[str, Any]               # Experiment.to_dict()
    setup: str                         # display label (sweep-row key)
    arch: str
    metrics: WorkloadMetrics
    energy_by_component: Dict[str, float]
    energy_by_stage: Dict[str, float]
    makespan_s: float
    total_tokens: int
    governor_decisions: int = 0
    # goodput scoring: against the experiment's SLO when it has one,
    # else each request's own (absent targets pass — the t=0 batches)
    goodput: Optional[Dict[str, float]] = None
    # fleet-controller activity (scale/flip/sleep ops logged during the
    # run); additive with a default, so pre-controller cached records
    # deserialize unchanged
    controller_actions: int = 0
    # observability snapshot (repro.obs.metrics.MetricsRegistry
    # .snapshot()): latency histograms, fastpath coalescing stats, tier
    # hit rates, router decision counts. Additive with a default — the
    # same no-bump contract as controller_actions
    obs: Optional[Dict[str, Any]] = None

    # ------------------------------------------------------------------
    @property
    def energy(self) -> EnergyView:
        return EnergyView(joules=dict(self.energy_by_component),
                          by_stage=dict(self.energy_by_stage))

    @property
    def total_j(self) -> float:
        return sum(self.energy_by_component.values())

    @property
    def idle_j(self) -> float:
        return self.energy_by_stage.get("idle", 0.0)

    @property
    def prefill_side_j(self) -> float:
        return prefill_side_j(self.energy_by_stage)

    @property
    def decode_side_j(self) -> float:
        return decode_side_j(self.energy_by_stage)

    @property
    def joules_per_token(self) -> float:
        return self.total_j / max(self.total_tokens, 1)

    @property
    def attainment(self) -> float:
        return self.goodput["attainment"] if self.goodput else 1.0

    @property
    def goodput_rps(self) -> float:
        return self.goodput["goodput_rps"] if self.goodput else 0.0

    # ------------------------------------------------------------------
    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d["metrics"] = dataclasses.asdict(self.metrics)
        return d

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "RunRecord":
        d = dict(d)
        d["metrics"] = WorkloadMetrics(**d["metrics"])
        return cls(**d)

    # ------------------------------------------------------------------
    @classmethod
    def from_result(cls, exp, result, *, governor_decisions: int = 0,
                    controller_actions: int = 0,
                    requests: Optional[List] = None,
                    obs: Optional[Dict[str, Any]] = None) -> "RunRecord":
        """Build the record from a finished ``SetupResult``; when the
        experiment carries an SLO the goodput block is scored with it
        (same arithmetic as ``repro.workload.evaluate``)."""
        goodput = None
        if requests:
            from repro.workload.goodput import evaluate
            rep = evaluate(requests, exp.slo)
            goodput = {"n": rep.n, "attained": rep.attained,
                       "attainment": rep.attainment,
                       "duration_s": rep.duration_s,
                       "goodput_rps": rep.goodput_rps,
                       "offered_rps": rep.offered_rps}
        return cls(schema_version=SCHEMA_VERSION,
                   spec_hash=exp.spec_hash(), spec=exp.to_dict(),
                   setup=exp.setup, arch=exp.arch, metrics=result.metrics,
                   energy_by_component=dict(result.energy.joules),
                   energy_by_stage=dict(result.energy.by_stage),
                   makespan_s=result.makespan_s,
                   total_tokens=result.total_tokens,
                   governor_decisions=governor_decisions,
                   controller_actions=controller_actions,
                   goodput=goodput, obs=obs)
