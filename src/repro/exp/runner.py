"""run(exp) / run_grid(grid): the one driver behind every figure,
sweep, and CLI (DESIGN.md section 12).

``run`` memoizes through the content-addressed ``ResultCache``; a hit
returns the stored ``RunRecord`` without touching the simulator, a miss
simulates, stores, and returns. ``run_grid`` expands a ``Grid`` (or
takes an experiment list), dedupes identical cells, serves hits from
the cache, and fans the misses out over a process pool — the grid is
embarrassingly parallel because every cell is a pure function of its
spec (seeded workloads, seeded routers, no global state).

``SIM_COUNT`` counts actual simulations in this process; the warm-cache
CI lane asserts it stays zero on a second pass.
"""
from __future__ import annotations

import os
from concurrent.futures import ProcessPoolExecutor
from typing import Iterable, List, Optional, Sequence, Union

from repro.configs import get_config

from .cache import ResultCache
from .grid import Grid
from .record import RunRecord
from .spec import Experiment

__all__ = ["run", "run_grid", "simulate", "default_cache",
           "set_default_cache", "sim_count"]

# process-wide simulation counter (cache-layer-independent, so a
# ``cache=None`` run still counts); read via sim_count()
SIM_COUNT = 0
# simulations the legacy entrypoints ran OUTSIDE repro.exp (the
# documented fallbacks in workload.sweep / core.dvfs for off-registry
# configs and non-spec workloads). Counted separately so the warm-cache
# CI contract can also assert no benchmark path regressed into the
# uncached branch.
UNCACHED_SIM_COUNT = 0

_DEFAULT_CACHE: Optional[ResultCache] = None
_NO_CACHE = object()     # sentinel: "explicitly uncached"


def sim_count() -> int:
    return SIM_COUNT


def uncached_sim_count() -> int:
    return UNCACHED_SIM_COUNT


def count_uncached_sim() -> None:
    """Called by the legacy entrypoints' direct-simulation fallbacks."""
    global UNCACHED_SIM_COUNT
    UNCACHED_SIM_COUNT += 1


def default_cache() -> ResultCache:
    global _DEFAULT_CACHE
    if _DEFAULT_CACHE is None:
        _DEFAULT_CACHE = ResultCache()
    return _DEFAULT_CACHE


def set_default_cache(cache: Optional[ResultCache]) -> None:
    """Swap the process-default cache (tests point it at a tmpdir)."""
    global _DEFAULT_CACHE
    _DEFAULT_CACHE = cache


def _resolve_cache(cache) -> Optional[ResultCache]:
    if cache is _NO_CACHE:
        return default_cache()
    return cache


# ----------------------------------------------------------------------
def simulate(exp: Experiment, *, executor_factory=None,
             tracer=None) -> RunRecord:
    """One uncached simulation of a cell. ``executor_factory`` switches
    the engines to real execution (launch.serve --real); real runs are
    never cached — the record schema captures the simulation aggregate,
    not token streams. ``tracer`` (a ``repro.obs.Tracer``) records the
    run's full event stream; it is purely observational, so the record
    is bit-identical with or without it."""
    global SIM_COUNT
    SIM_COUNT += 1
    from repro.fleet.cluster import FleetCluster
    cfg = get_config(exp.arch)
    reqs = exp.workload.build(exp.slo)
    cluster = FleetCluster(
        exp.fleet, cfg, prefill_token_budget=exp.prefill_token_budget,
        page_size=exp.page_size, executor_factory=executor_factory,
        tracer=tracer)
    if exp.reuse is not None and exp.reuse.tiers is None:
        # flat shared reuse: this pre-tier branch is kept VERBATIM so
        # cached reuse_bench results replay bit-identical
        from repro.core.prefix_cache import PrefixCache
        pc = PrefixCache(capacity_pages=exp.reuse.capacity_pages,
                         page_size=exp.reuse.page_size,
                         pic=(exp.reuse.mode == "pic"),
                         recompute_frac=exp.reuse.recompute_frac)
        if exp.reuse.warm and reqs and reqs[0].prompt_tokens is not None:
            pc.insert(reqs[0].prompt_tokens)
        for e in cluster.engines:
            e.prefix_cache = pc
    elif exp.reuse is not None:
        # tiered: per-engine stores; warming happens inside run() via
        # the cluster's _warm_stores (spills priced at t=0)
        cluster._attach_reuse(exp.reuse)
    result = cluster.run(reqs)
    decisions = sum(len(e.governor.decisions) for e in cluster.engines
                    if e.governor is not None)
    actions = len(getattr(cluster, "controller_log", []) or [])
    from repro.obs.metrics import collect_run_metrics
    obs = collect_run_metrics(cluster, reqs).snapshot()
    return RunRecord.from_result(exp, result,
                                 governor_decisions=decisions,
                                 controller_actions=actions,
                                 requests=reqs, obs=obs)


def run(exp: Experiment, *, cache=_NO_CACHE,
        force: bool = False, executor_factory=None,
        tracer=None) -> RunRecord:
    """The memoized driver: cache hit -> stored record; miss ->
    simulate + store. ``cache=None`` bypasses the cache entirely;
    ``force=True`` re-simulates and overwrites. Real-execution runs
    (``executor_factory``) and traced runs (``tracer``) are always
    uncached — a hit would leave the tracer empty."""
    if executor_factory is not None or tracer is not None:
        return simulate(exp, executor_factory=executor_factory,
                        tracer=tracer)
    cache = _resolve_cache(cache)
    if cache is not None and not force:
        rec = cache.get(exp)
        if rec is not None:
            return rec
    rec = simulate(exp)
    if cache is not None:
        cache.put(rec)
    return rec


# ----------------------------------------------------------------------
def _worker_simulate(exp_json: str) -> dict:
    """Process-pool entry: specs travel as canonical JSON, records come
    back as dicts (both trivially picklable and version-checked)."""
    rec = simulate(Experiment.from_json(exp_json))
    return rec.to_dict()


def run_grid(grid: Union[Grid, Sequence[Experiment]], *,
             parallel: int = 1, cache=_NO_CACHE,
             force: bool = False) -> List[RunRecord]:
    """Run every cell of a grid, returning records in expansion order.

    Identical cells (same content address) are simulated once; cache
    hits cost a JSON read; misses fan out over ``parallel`` worker
    processes (``parallel <= 1`` stays in-process — the right choice
    for small grids, where worker startup dwarfs the simulation).
    """
    exps = grid.expand() if isinstance(grid, Grid) else list(grid)
    cache = _resolve_cache(cache)

    # dedupe on the content address, preserving first-seen order
    order: List[str] = []
    unique = {}
    for e in exps:
        h = e.spec_hash()
        order.append(h)
        if h not in unique:
            unique[h] = e

    records = {}
    misses = []
    for h, e in unique.items():
        rec = cache.get(e) if (cache is not None and not force) else None
        if rec is not None:
            records[h] = rec
        else:
            misses.append((h, e))

    if misses and parallel > 1:
        global SIM_COUNT
        from concurrent.futures import as_completed
        first_error = None
        with ProcessPoolExecutor(max_workers=parallel) as pool:
            futs = {pool.submit(_worker_simulate, e.to_json()): h
                    for h, e in misses}
            # persist every record the moment its worker finishes: one
            # failed cell must not discard the completed simulations of
            # the rest of the batch, so survivors are cached before the
            # first failure is re-raised
            for fut in as_completed(futs):
                try:
                    rec = RunRecord.from_dict(fut.result())
                except Exception as e:  # noqa: BLE001 — re-raised below
                    if first_error is None:
                        first_error = e
                    continue
                records[futs[fut]] = rec
                SIM_COUNT += 1
                if cache is not None:
                    cache.put(rec)
        if first_error is not None:
            raise first_error
    else:
        for h, e in misses:
            records[h] = simulate(e)
            if cache is not None:
                cache.put(records[h])

    return [records[h] for h in order]
