"""Content-addressed on-disk result cache (DESIGN.md section 12).

One JSON file per simulated cell under

    <root>/v<SCHEMA_VERSION>/<spec_hash>.json

The key is the experiment's content address x the record schema
version: same spec -> same file, forever; a schema bump moves the
whole cache to a new subdirectory, so stale-generation records can
never be returned (the old tree is inert, delete it at leisure).

Writes are atomic (tmp file + ``os.replace``) so concurrent
process-pool workers and parallel CI lanes can share a cache directory;
a torn/corrupt file is treated as a miss and overwritten. Stats are
per-``ResultCache``-instance (hits / misses / puts), which is what the
warm-cache CI lane asserts on ("second pass performs zero
simulations").
"""
from __future__ import annotations

import json
import os
import tempfile
from dataclasses import dataclass, field
from typing import Dict, Optional

from .record import RunRecord, SCHEMA_VERSION

__all__ = ["CacheStats", "ResultCache", "default_cache_root"]


def default_cache_root() -> str:
    """``$REPRO_EXP_CACHE_DIR`` when set; else ``benchmarks/out/cache``
    next to this checkout (the ISSUE-designated artifact location); else
    a user cache dir for installed copies without a benchmarks tree."""
    env = os.environ.get("REPRO_EXP_CACHE_DIR")
    if env:
        return env
    here = os.path.dirname(os.path.abspath(__file__))
    repo = os.path.dirname(os.path.dirname(os.path.dirname(here)))
    bench = os.path.join(repo, "benchmarks")
    if os.path.isdir(bench):
        return os.path.join(bench, "out", "cache")
    return os.path.join(os.path.expanduser("~"), ".cache", "repro-exp")


@dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    puts: int = 0

    def as_dict(self) -> Dict[str, int]:
        return {"hits": self.hits, "misses": self.misses,
                "puts": self.puts}


@dataclass
class ResultCache:
    root: str = field(default_factory=default_cache_root)
    schema_version: int = SCHEMA_VERSION
    stats: CacheStats = field(default_factory=CacheStats)

    # ------------------------------------------------------------------
    @property
    def dir(self) -> str:
        return os.path.join(self.root, f"v{self.schema_version}")

    def path_for(self, spec_hash: str) -> str:
        return os.path.join(self.dir, f"{spec_hash}.json")

    # ------------------------------------------------------------------
    def get(self, exp) -> Optional[RunRecord]:
        path = self.path_for(exp.spec_hash())
        try:
            with open(path) as f:
                rec = RunRecord.from_dict(json.load(f))
        except FileNotFoundError:
            self.stats.misses += 1
            return None
        except (json.JSONDecodeError, KeyError, TypeError):
            # torn write or foreign file: treat as a miss, re-simulate
            self.stats.misses += 1
            return None
        if rec.schema_version != self.schema_version:
            self.stats.misses += 1
            return None
        self.stats.hits += 1
        return rec

    def put(self, rec: RunRecord) -> str:
        os.makedirs(self.dir, exist_ok=True)
        path = self.path_for(rec.spec_hash)
        fd, tmp = tempfile.mkstemp(dir=self.dir, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(rec.to_dict(), f)
            os.replace(tmp, path)
        finally:
            if os.path.exists(tmp):
                os.unlink(tmp)
        self.stats.puts += 1
        return path

    # ------------------------------------------------------------------
    def __len__(self) -> int:
        try:
            return sum(1 for n in os.listdir(self.dir)
                       if n.endswith(".json"))
        except FileNotFoundError:
            return 0

    def clear(self) -> int:
        """Remove every record of THIS schema generation; returns the
        number of files deleted."""
        n = 0
        try:
            names = os.listdir(self.dir)
        except FileNotFoundError:
            return 0
        for name in names:
            if name.endswith(".json"):
                os.unlink(os.path.join(self.dir, name))
                n += 1
        return n
