"""Grid: cartesian expansion of experiment axes (DESIGN.md section 12).

A ``Grid`` is a base ``Experiment`` plus named axes; ``expand()``
returns the cartesian product as a list of concrete experiments, last
axis fastest (``itertools.product`` order over the axes' insertion
order), so a grid's expansion — hence the order of its records — is
deterministic.

Axis names map to spec transforms:

  setup / fleet   legacy setup name, fleet-shape string, or FleetSpec
  phi             every stage (FleetSpec.with_phi)
  phi_prefill / phi_decode     one stage (scalar or per-instance tuple)
  governor        online DVFS controller name(s)
  batch           ClosedLoop batch size
  rate            OpenLoop nominal arrival rate
  n / seed        workload size / seed
  arch            model architecture id
  slo             scoring SLO
  workload        a whole ClosedLoop / OpenLoop / WorkloadSpec

Anything else must be a dotted dataclass path rooted at the experiment
(e.g. ``workload.input_len``, ``fleet.router``), applied with nested
``dataclasses.replace`` — new knobs are sweepable without touching this
module.
"""
from __future__ import annotations

import dataclasses
import itertools
from dataclasses import dataclass, field, replace
from typing import Any, Callable, Dict, List, Mapping, Sequence

from .spec import Experiment, as_workload

__all__ = ["Grid"]


def _set_path(obj, path: List[str], value):
    """Nested frozen-dataclass update along a dotted path."""
    if len(path) == 1:
        return dataclasses.replace(obj, **{path[0]: value})
    child = getattr(obj, path[0])
    return dataclasses.replace(
        obj, **{path[0]: _set_path(child, path[1:], value)})


_SETTERS: Dict[str, Callable[[Experiment, Any], Experiment]] = {
    "setup": lambda e, v: e.with_fleet(v),
    "fleet": lambda e, v: e.with_fleet(v),
    "phi": lambda e, v: e.with_phi(phi=v),
    "phi_prefill": lambda e, v: e.with_phi(phi_prefill=v),
    "phi_decode": lambda e, v: e.with_phi(phi_decode=v),
    "governor": lambda e, v: e.with_governor(v),
    "batch": lambda e, v: e.with_workload(batch=v),
    "rate": lambda e, v: e.with_rate(v),
    "n": lambda e, v: e.with_workload(n=v),
    "seed": lambda e, v: e.with_workload(seed=v),
    "arch": lambda e, v: replace(e, arch=v),
    "slo": lambda e, v: replace(e, slo=v),
    "workload": lambda e, v: replace(e, workload=as_workload(v)),
}


def apply_axis(exp: Experiment, name: str, value) -> Experiment:
    setter = _SETTERS.get(name)
    if setter is not None:
        return setter(exp, value)
    if "." in name:
        return _set_path(exp, name.split("."), value)
    raise KeyError(
        f"unknown axis {name!r}: use one of {sorted(_SETTERS)} or a "
        f"dotted dataclass path like 'workload.input_len'")


@dataclass(frozen=True)
class Grid:
    """``Grid(base, {"setup": SETUPS, "batch": (2, 8, 32)})``."""
    base: Experiment
    axes: Mapping[str, Sequence[Any]] = field(default_factory=dict)

    def __post_init__(self):
        for name, values in self.axes.items():
            if not isinstance(values, Sequence) or isinstance(values, str):
                raise TypeError(f"axis {name!r}: values must be a "
                                f"sequence, got {type(values).__name__}")
            if len(values) == 0:
                raise ValueError(f"axis {name!r} is empty")

    def __len__(self) -> int:
        n = 1
        for values in self.axes.values():
            n *= len(values)
        return n

    def expand(self) -> List[Experiment]:
        names = list(self.axes)
        out = []
        for combo in itertools.product(*(self.axes[n] for n in names)):
            exp = self.base
            for name, value in zip(names, combo):
                exp = apply_axis(exp, name, value)
            out.append(exp)
        return out

    def with_axis(self, name: str, values: Sequence[Any]) -> "Grid":
        axes = dict(self.axes)
        axes[name] = values
        return Grid(base=self.base, axes=axes)
