"""repro.exp — the declarative Experiment API (DESIGN.md section 12).

One spine for the paper's whole benchmark matrix: describe a cell as a
frozen ``Experiment`` (arch x fleet x workload x SLO x reuse), expand
axes with ``Grid``, and execute through ``run`` / ``run_grid`` — which
memoize through a content-addressed on-disk cache keyed by
``spec_hash x SCHEMA_VERSION`` and fan cache misses out over a process
pool. Every figure script, ``validate_claims``, the sweeps in
``repro.workload`` / ``repro.core.dvfs``, and ``launch.serve`` route
through here; new media, governors, and workloads extend the spec
instead of adding another entrypoint.
"""
from .cache import CacheStats, ResultCache, default_cache_root
from .grid import Grid
from .record import (EnergyView, RunRecord, SCHEMA_VERSION,
                     decode_side_j, prefill_side_j)
from .runner import (default_cache, run, run_grid, set_default_cache,
                     sim_count, simulate, uncached_sim_count)
from .spec import (ClosedLoop, Experiment, OpenLoop, ReuseSpec, TierSpec,
                   apply_spec_knobs, as_cacheable, registered_arch)

__all__ = [
    "Experiment", "ClosedLoop", "OpenLoop", "ReuseSpec", "TierSpec",
    "Grid",
    "RunRecord", "EnergyView", "SCHEMA_VERSION",
    "prefill_side_j", "decode_side_j",
    "ResultCache", "CacheStats", "default_cache_root",
    "run", "run_grid", "simulate", "default_cache", "set_default_cache",
    "sim_count", "uncached_sim_count",
    "registered_arch", "apply_spec_knobs", "as_cacheable",
]
