"""Open-loop arrival processes (DESIGN.md section 9).

The paper's RandomDataset submits every request at t=0 ("infinite
rate"); DistServe (arXiv 2401.09670) frames the co-vs-dis comparison as
SLO-attainment goodput under an *open-loop* arrival process instead.
Every process here is seed-deterministic: ``times(n, seed)`` returns the
same non-decreasing float64 array for the same arguments, so a workload
is fully reproducible from ``(process, n, seed)``.

Conventions shared by all processes:

  * ``rate`` is the nominal long-run request rate in requests/second
    (``nominal_rate`` for processes whose instantaneous rate varies).
  * the first arrival is at the first inter-arrival gap (not t=0), so
    a rate sweep degrades gracefully into the paper's t=0 batch as
    ``rate -> inf`` rather than pinning one request to the origin.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


class ArrivalProcess:
    """Base: ``times(n, seed)`` -> sorted arrival times, seconds."""

    def times(self, n: int, seed: int = 0) -> np.ndarray:
        raise NotImplementedError

    @property
    def nominal_rate(self) -> float:
        raise NotImplementedError

    # ------------------------------------------------------------------
    def _finalize(self, t: np.ndarray) -> np.ndarray:
        t = np.asarray(t, dtype=np.float64)
        assert np.all(np.diff(t) >= 0.0), "arrival times must be sorted"
        assert t.size == 0 or t[0] >= 0.0
        return t


@dataclass(frozen=True)
class PoissonArrivals(ArrivalProcess):
    """Homogeneous Poisson process: i.i.d. exponential gaps, mean 1/rate."""
    rate: float

    def times(self, n: int, seed: int = 0) -> np.ndarray:
        assert self.rate > 0 and n >= 0
        rng = np.random.default_rng(seed)
        gaps = rng.exponential(1.0 / self.rate, size=n)
        return self._finalize(np.cumsum(gaps))

    @property
    def nominal_rate(self) -> float:
        return self.rate


@dataclass(frozen=True)
class GammaArrivals(ArrivalProcess):
    """Renewal process with gamma gaps: mean 1/rate, coefficient of
    variation ``cv``. ``cv > 1`` is burstier than Poisson (the FlowKV
    arXiv 2504.03775 regime where transfer media separate), ``cv < 1``
    smoother, ``cv == 1`` recovers Poisson exactly."""
    rate: float
    cv: float = 2.0

    def times(self, n: int, seed: int = 0) -> np.ndarray:
        assert self.rate > 0 and self.cv > 0 and n >= 0
        rng = np.random.default_rng(seed)
        shape = 1.0 / (self.cv ** 2)
        scale = self.cv ** 2 / self.rate           # shape*scale = 1/rate
        gaps = rng.gamma(shape, scale, size=n)
        return self._finalize(np.cumsum(gaps))

    @property
    def nominal_rate(self) -> float:
        return self.rate


@dataclass(frozen=True)
class RampArrivals(ArrivalProcess):
    """Non-homogeneous Poisson ramp: instantaneous rate climbs linearly
    from ``rate0`` to ``rate1`` over ``ramp_s`` seconds, then holds at
    ``rate1``. Sampled exactly by inverting the cumulative intensity
    Lambda(t) against unit-rate exponential increments (no thinning, so
    the draw count — hence determinism — is independent of the rates)."""
    rate0: float
    rate1: float
    ramp_s: float

    def times(self, n: int, seed: int = 0) -> np.ndarray:
        assert self.rate0 > 0 and self.rate1 > 0 and self.ramp_s > 0
        rng = np.random.default_rng(seed)
        targets = np.cumsum(rng.exponential(1.0, size=n))  # Lambda targets
        r0, r1, d = self.rate0, self.rate1, self.ramp_s
        a = (r1 - r0) / (2.0 * d)                  # Lambda(t)=r0 t + a t^2
        lam_ramp_end = 0.5 * (r0 + r1) * d
        out = np.empty(n, dtype=np.float64)
        for i, lam in enumerate(targets):
            if lam >= lam_ramp_end:                # past the ramp: linear
                out[i] = d + (lam - lam_ramp_end) / r1
            elif abs(a) < 1e-12:                   # flat ramp
                out[i] = lam / r0
            else:                                  # invert the quadratic
                out[i] = (np.sqrt(r0 * r0 + 4.0 * a * lam) - r0) / (2.0 * a)
        return self._finalize(out)

    @property
    def nominal_rate(self) -> float:
        return self.rate1


@dataclass(frozen=True)
class DiurnalArrivals(ArrivalProcess):
    """Non-homogeneous Poisson diurnal cycle: instantaneous rate

        r(t) = rate * (floor + (1 - floor) * (1 - cos(2 pi t/period)) / 2)

    — a raised-cosine day/night swing between ``rate * floor`` (trough)
    and ``rate`` (peak), period ``period_s``. This is the traffic shape
    autoscaling papers (P/D-Serve, DualScale) target: long low-rate
    valleys where a static fleet burns its idle floor and an adaptive
    one sleeps. Sampled exactly like ``RampArrivals``: unit-exponential
    targets inverted against the closed-form cumulative intensity

        Lambda(t) = rate * (floor t + (1-floor)(t - (p/2pi) sin(2pi t/p))/2)

    by bisection (Lambda is strictly increasing; r(t) >= rate*floor > 0
    bounds the bracket), so the draw count is n regardless of rates."""
    rate: float                 # peak rate, req/s
    period_s: float = 60.0
    floor: float = 0.1          # trough fraction of peak, in (0, 1]

    def _cum(self, t: np.ndarray) -> np.ndarray:
        p, f = self.period_s, self.floor
        w = 2.0 * np.pi / p
        return self.rate * (f * t + (1.0 - f) * 0.5
                            * (t - np.sin(w * t) / w))

    def times(self, n: int, seed: int = 0) -> np.ndarray:
        assert self.rate > 0 and self.period_s > 0 and 0 < self.floor <= 1
        rng = np.random.default_rng(seed)
        targets = np.cumsum(rng.exponential(1.0, size=n))
        rate_min = self.rate * self.floor
        lo = np.zeros(n)
        hi = targets / rate_min + 1.0      # Lambda(hi) >= targets always
        for _ in range(200):               # bisection to float64 limits
            mid = 0.5 * (lo + hi)
            below = self._cum(mid) < targets
            lo = np.where(below, mid, lo)
            hi = np.where(below, hi, mid)
            if np.all(hi - lo <= 1e-12 * np.maximum(hi, 1.0)):
                break
        return self._finalize(np.maximum.accumulate(0.5 * (lo + hi)))

    @property
    def nominal_rate(self) -> float:
        """Long-run average rate (the mean of the raised cosine)."""
        return self.rate * (1.0 + self.floor) / 2.0


@dataclass(frozen=True)
class DeterministicArrivals(ArrivalProcess):
    """Fixed inter-arrival interval 1/rate (the closed-form staggered
    schedule; seed is accepted for interface uniformity and ignored)."""
    rate: float

    def times(self, n: int, seed: int = 0) -> np.ndarray:
        assert self.rate > 0 and n >= 0
        return self._finalize((np.arange(n, dtype=np.float64) + 1.0)
                              / self.rate)

    @property
    def nominal_rate(self) -> float:
        return self.rate


# ----------------------------------------------------------------------
_ARRIVALS = {
    "poisson": PoissonArrivals,
    "gamma": GammaArrivals,
    "ramp": RampArrivals,
    "diurnal": DiurnalArrivals,
    "deterministic": DeterministicArrivals,
}


def make_arrivals(kind: str, **kw) -> ArrivalProcess:
    """Registry constructor, e.g. ``make_arrivals("poisson", rate=4.0)``."""
    try:
        cls = _ARRIVALS[kind]
    except KeyError:
        raise ValueError(f"unknown arrival process {kind!r}; "
                         f"choose from {sorted(_ARRIVALS)}") from None
    return cls(**kw)
