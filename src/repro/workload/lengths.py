"""Request length mixes (DESIGN.md section 9).

A length mix turns ``(n, seed)`` into n ``ReqShape`` draws — prompt
length, output length, and an optional shared-prefix length for the RAG
scenario. Like the arrival processes, every mix is seed-deterministic.

The paper's RandomDataset is the degenerate mix ``PaperFixedLengths``
(16,384 / 256). The others cover the shapes the paper's "depends on the
request load" caveat implies but never measures: ShareGPT-style
long-tail chat traces, short interactive chatbot turns, and
RAG-with-shared-prefix retrieval prompts. ``MixtureLengths`` composes
any of them into a multi-tenant blend.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Sequence, Tuple

import numpy as np


@dataclass(frozen=True)
class ReqShape:
    prompt_len: int
    output_len: int
    prefix_len: int = 0      # leading tokens shared across the tenant
    tenant: int = 0          # which mixture component drew this shape


class LengthMix:
    """Base: ``sample(n, seed)`` -> list of n ``ReqShape``."""

    def sample(self, n: int, seed: int = 0) -> List[ReqShape]:
        raise NotImplementedError


@dataclass(frozen=True)
class PaperFixedLengths(LengthMix):
    """The paper's RandomDataset shape: fixed input/output lengths."""
    prompt_len: int = 16_384
    output_len: int = 256

    def sample(self, n: int, seed: int = 0) -> List[ReqShape]:
        return [ReqShape(self.prompt_len, self.output_len)
                for _ in range(n)]


@dataclass(frozen=True)
class ShareGPTLengths(LengthMix):
    """ShareGPT-like long tail: lognormal prompts and outputs, clipped.

    Medians/sigmas default to the shape of the public ShareGPT trace
    (most prompts near 1k tokens, a heavy tail into the tens of
    thousands; outputs a few hundred with a shorter tail)."""
    prompt_median: int = 1024
    prompt_sigma: float = 1.0
    output_median: int = 128
    output_sigma: float = 0.8
    prompt_min: int = 16
    prompt_max: int = 32_768
    output_min: int = 2
    output_max: int = 2_048

    def sample(self, n: int, seed: int = 0) -> List[ReqShape]:
        rng = np.random.default_rng(seed)
        p = rng.lognormal(np.log(self.prompt_median), self.prompt_sigma, n)
        o = rng.lognormal(np.log(self.output_median), self.output_sigma, n)
        p = np.clip(np.rint(p), self.prompt_min, self.prompt_max)
        o = np.clip(np.rint(o), self.output_min, self.output_max)
        return [ReqShape(int(pi), int(oi)) for pi, oi in zip(p, o)]


@dataclass(frozen=True)
class ChatbotLengths(LengthMix):
    """Short interactive turns: uniform small prompts and outputs."""
    prompt_min: int = 32
    prompt_max: int = 512
    output_min: int = 32
    output_max: int = 256

    def sample(self, n: int, seed: int = 0) -> List[ReqShape]:
        rng = np.random.default_rng(seed)
        p = rng.integers(self.prompt_min, self.prompt_max + 1, n)
        o = rng.integers(self.output_min, self.output_max + 1, n)
        return [ReqShape(int(pi), int(oi)) for pi, oi in zip(p, o)]


@dataclass(frozen=True)
class RAGSharedPrefixLengths(LengthMix):
    """RAG retrieval: a long prefix shared by every request of the
    tenant (paper section II-C's KV-reuse scenario) plus a short
    per-request question, with short grounded answers."""
    prefix_len: int = 8_192
    suffix_min: int = 64
    suffix_max: int = 512
    output_min: int = 32
    output_max: int = 192

    def sample(self, n: int, seed: int = 0) -> List[ReqShape]:
        rng = np.random.default_rng(seed)
        s = rng.integers(self.suffix_min, self.suffix_max + 1, n)
        o = rng.integers(self.output_min, self.output_max + 1, n)
        return [ReqShape(self.prefix_len + int(si), int(oi),
                         prefix_len=self.prefix_len)
                for si, oi in zip(s, o)]


@dataclass(frozen=True)
class MixtureLengths(LengthMix):
    """Multi-tenant blend: ``components`` = ((weight, mix), ...).

    Each request independently draws its tenant with probability
    proportional to the weights, then its shape from that tenant's mix;
    ``ReqShape.tenant`` records the component index so per-tenant SLOs
    and metrics can be split downstream."""
    components: Tuple[Tuple[float, LengthMix], ...]

    def sample(self, n: int, seed: int = 0) -> List[ReqShape]:
        assert self.components, "empty mixture"
        rng = np.random.default_rng(seed)
        w = np.array([c[0] for c in self.components], dtype=np.float64)
        assert np.all(w > 0), "mixture weights must be positive"
        tenants = rng.choice(len(self.components), size=n, p=w / w.sum())
        # pre-draw each tenant's shapes with a derived (deterministic) seed
        per_tenant = {
            t: iter(self.components[t][1].sample(
                int(np.sum(tenants == t)), seed=seed * 1009 + 7 * t + 1))
            for t in set(int(t) for t in tenants)
        }
        out = []
        for t in tenants:
            shape = next(per_tenant[int(t)])
            out.append(ReqShape(shape.prompt_len, shape.output_len,
                                prefix_len=shape.prefix_len,
                                tenant=int(t)))
        return out


# ----------------------------------------------------------------------
_MIXES = {
    "paper-fixed": PaperFixedLengths,
    "sharegpt": ShareGPTLengths,
    "chatbot": ChatbotLengths,
    "rag-shared-prefix": RAGSharedPrefixLengths,
}


def make_lengths(kind: str, **kw) -> LengthMix:
    """Registry constructor, e.g. ``make_lengths("sharegpt")``."""
    try:
        cls = _MIXES[kind]
    except KeyError:
        raise ValueError(f"unknown length mix {kind!r}; "
                         f"choose from {sorted(_MIXES)}") from None
    return cls(**kw)
