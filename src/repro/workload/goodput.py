"""SLO attainment and goodput (DESIGN.md section 9).

Goodput follows DistServe (arXiv 2401.09670): the number of completed
requests per second that meet BOTH their TTFT and TPOT SLOs. A request
with no decode phase (single-token output, ``tpot_s is None``) is judged
on TTFT alone. ``max_goodput_rate`` is the paper-style capacity number:
the highest offered rate a setup sustains while attaining the SLO on at
least ``target_attainment`` of requests, located by bisection.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, List, Optional, Sequence, Union

from repro.core.request import Request, SLO, goodput_stats


# the interactive SLO the benchmarks, example, and regression tests
# share; the documented ~3.6 req/s dis-ici crossover (DESIGN.md
# section 9) is calibrated to it, so tune it HERE, not per-caller
DEFAULT_INTERACTIVE_SLO = SLO(ttft_s=2.0, tpot_s=0.0075)


@dataclass(frozen=True)
class GoodputReport:
    n: int
    attained: int
    attainment: float          # attained / n
    duration_s: float          # first arrival -> last finish
    goodput_rps: float         # attained / duration
    offered_rps: float         # observed arrival rate


def evaluate(reqs: Sequence[Request],
             slo: Optional[SLO] = None) -> GoodputReport:
    """Score a finished workload. ``slo`` overrides each request's own
    SLO when given (one global SLO, the DistServe setting)."""
    assert reqs and all(r.done for r in reqs), "workload not finished"
    attained, duration, offered = goodput_stats(reqs, slo)
    return GoodputReport(
        n=len(reqs), attained=attained, attainment=attained / len(reqs),
        duration_s=duration,
        goodput_rps=attained / max(duration, 1e-9),
        offered_rps=offered)


# ----------------------------------------------------------------------
RunAtRate = Callable[[float], List[Request]]


def _default_attains(setup, cfg, slo: Optional[SLO],
                     target_attainment: float, **runner_kw):
    """rate -> does ``setup`` attain the SLO target at that rate?

    Each probe is one ``run_rate_point`` cell — i.e. a ``repro.exp``
    experiment served from the content-addressed cache whenever the
    cell is spec-expressible — so repeated bisections (fig7's capacity
    search, CI reruns) re-simulate nothing."""
    from .sweep import run_rate_point

    def attains(rate: float) -> bool:
        pt = run_rate_point(setup, cfg, rate, slo=slo, **runner_kw)
        return pt.attainment >= target_attainment

    return attains


def max_goodput_rate(setup: Union[str, "FleetSpec", RunAtRate],  # noqa: F821
                     cfg=None, *,
                     slo: SLO,
                     lo: float = 0.25, hi: float = 32.0,
                     target_attainment: float = 0.9,
                     rel_tol: float = 0.08, max_iters: int = 12,
                     **runner_kw) -> float:
    """Highest offered rate with SLO attainment >= ``target_attainment``.

    ``setup`` is a setup name or ``FleetSpec`` (a fresh cluster per
    probe, the real sweep) or a callable ``rate -> finished requests``
    (stubbed cost models in tests). Assumes attainment is non-increasing
    in rate — true of every work-conserving setup here. Returns 0.0 when even
    ``lo`` misses the target; returns ``hi`` when ``hi`` still attains
    it (the bracket saturated, not a fixed point).
    """
    if callable(setup):
        if cfg is not None or runner_kw:
            raise ValueError(
                "with a callable runner, cfg/workload kwargs are the "
                f"callable's own business: got cfg={cfg!r}, "
                f"kwargs={sorted(runner_kw)}")
        run = setup

        def attains(rate: float) -> bool:
            reqs = run(rate)
            return evaluate(reqs, slo).attainment >= target_attainment
    else:
        attains = _default_attains(setup, cfg, slo, target_attainment,
                                   **runner_kw)

    if not attains(lo):
        return 0.0
    if attains(hi):
        return hi
    for _ in range(max_iters):
        mid = (lo + hi) / 2.0
        if attains(mid):
            lo = mid
        else:
            hi = mid
        if hi - lo <= rel_tol * lo:
            break
    return lo
