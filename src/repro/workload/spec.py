"""WorkloadSpec: arrival process x length mix -> a concrete request list.

``build()`` is the single materialization point: same spec -> identical
``Request`` list (ids, arrival times, lengths, SLOs, and — in real mode
— token payloads). Requests are numbered in arrival order because the
engines use ``req_id`` as the FCFS priority key.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import List, Optional

import numpy as np

from repro.core.request import Request, SLO

from .arrivals import ArrivalProcess
from .lengths import LengthMix

# distinct, fixed salts so the arrival / length / token streams are
# independent draws from one user-facing seed
_ARRIVAL_SALT, _LENGTH_SALT, _TOKEN_SALT = 0x5EED1, 0x5EED2, 0x5EED3


@dataclass(frozen=True)
class WorkloadSpec:
    """A reproducible open-loop workload.

    vocab_size > 0 additionally materializes real token ids (the
    bit-exact integration-test mode); requests from the same tenant
    sharing a ``prefix_len`` then share the identical token prefix, so
    the prefix cache sees real reuse.
    """
    arrivals: ArrivalProcess
    lengths: LengthMix
    n: int
    seed: int = 0
    slo: Optional[SLO] = None
    vocab_size: int = 0

    def build(self) -> List[Request]:
        times = self.arrivals.times(self.n, seed=self.seed + _ARRIVAL_SALT)
        shapes = self.lengths.sample(self.n, seed=self.seed + _LENGTH_SALT)
        rng = np.random.default_rng(self.seed + _TOKEN_SALT)
        prefixes = {}            # (tenant, prefix_len) -> shared tokens
        reqs: List[Request] = []
        for i, (t, shape) in enumerate(zip(times, shapes)):
            tokens = None
            if self.vocab_size > 0:
                tokens = rng.integers(0, self.vocab_size, shape.prompt_len)
                if shape.prefix_len > 0:
                    key = (shape.tenant, shape.prefix_len)
                    if key not in prefixes:
                        prefixes[key] = rng.integers(0, self.vocab_size,
                                                     shape.prefix_len)
                    tokens[:shape.prefix_len] = prefixes[key]
            slo = (dataclasses.replace(self.slo)
                   if self.slo is not None else SLO())
            reqs.append(Request(req_id=i, prompt_len=shape.prompt_len,
                                output_len=shape.output_len,
                                arrival_s=float(t), slo=slo,
                                prompt_tokens=tokens))
        return reqs

    @property
    def nominal_rate(self) -> float:
        return self.arrivals.nominal_rate


def open_loop_workload(rate: float, n: int, *,
                       lengths: Optional[LengthMix] = None,
                       arrival: str = "poisson",
                       slo: Optional[SLO] = None, seed: int = 0,
                       vocab_size: int = 0, **arrival_kw) -> List[Request]:
    """One-call convenience: Poisson (or named) arrivals at ``rate`` over
    the paper's fixed 16k/256 shape unless another mix is given.

    ``rate`` means the process's nominal rate; for the ramp (which has
    no single rate) it is the terminal ``rate1``, warming up from
    ``rate0 = rate/4`` over half the nominal schedule unless overridden
    via ``arrival_kw``."""
    from .arrivals import make_arrivals
    from .lengths import PaperFixedLengths
    if arrival == "ramp":
        arrival_kw.setdefault("rate1", rate)
        arrival_kw.setdefault("rate0", rate / 4.0)
        arrival_kw.setdefault("ramp_s", 0.5 * n / rate)
        proc = make_arrivals("ramp", **arrival_kw)
    else:
        proc = make_arrivals(arrival, rate=rate, **arrival_kw)
    mix = lengths if lengths is not None else PaperFixedLengths()
    return WorkloadSpec(arrivals=proc, lengths=mix, n=n, seed=seed,
                        slo=slo, vocab_size=vocab_size).build()
