"""Rate x setup x medium grid sweeps and the load-crossover locator.

The paper's caveat — disaggregation's benefit "depends on the request
load and KV transfer mediums" — becomes a measurable object here: the
*crossover load*, the offered rate at which the SLO-goodput winner
between a dis-* setup and the equal-resource co-2gpus baseline flips.
On this cost model (repo findings F1/F2) colocation wins below the
crossover — while arrivals rarely overlap there is no interference for
disaggregation to remove, so the KV handoff is pure overhead — and
disaggregation wins above it, where colocated prefill-priority stalls
decode (TPOT inflation) and, past the KV-pool limit, preemption churn
triggers the recompute cliff. Slower media shift the crossover upward;
dis-disk typically never crosses at all.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple, Union

from repro.core.orchestrator import SETUPS, SetupResult, make_cluster
from repro.core.request import SLO
from repro.fleet.spec import FleetSpec, setup_label

from .goodput import GoodputReport, evaluate
from .lengths import LengthMix
from .spec import open_loop_workload

# every sweep knob takes either a legacy setup name or a fleet shape;
# FleetSpec is frozen/hashable, so both forms key the goodput caches
Setup = Union[str, FleetSpec]


@dataclass(frozen=True)
class RatePoint:
    setup: str
    rate: float
    attainment: float
    goodput_rps: float
    offered_rps: float
    median_ttft_s: float
    p99_ttft_s: float
    median_tpot_s: float
    makespan_s: float
    joules_per_token: float
    total_evictions: int
    # energy view (repro.govern): totals + the idle-state share, so
    # rate sweeps expose the idle-power floor alongside goodput
    total_j: float = 0.0
    idle_j: float = 0.0

    def as_row(self) -> List:
        return [self.setup, self.rate, round(self.attainment, 4),
                round(self.goodput_rps, 4), round(self.median_ttft_s, 4),
                round(self.p99_ttft_s, 4),
                round(self.median_tpot_s * 1e3, 3),
                round(self.makespan_s, 2),
                round(self.joules_per_token, 4), self.total_evictions,
                round(self.total_j, 2), round(self.idle_j, 2)]

    ROW_HEADER = ["setup", "rate_rps", "slo_attainment", "goodput_rps",
                  "median_ttft_s", "p99_ttft_s", "median_tpot_ms",
                  "makespan_s", "j_per_token", "evictions",
                  "total_j", "idle_j"]


def _as_experiment(setup: Setup, cfg, rate: float, *, lengths, slo, n,
                   seed, arrival, cluster_kw):
    """The cell as a cacheable ``repro.exp`` spec, or None when it
    cannot be content-addressed (an off-registry / modified config,
    cluster kwargs with no spec equivalent, or unregistered workload
    pieces) and must simulate directly. The gating rules live in
    ``repro.exp.spec`` — shared with the DVFS shims."""
    from repro.exp.spec import (Experiment, apply_spec_knobs,
                                as_cacheable, registered_arch)
    arch = registered_arch(cfg)
    if arch is None:
        return None
    exp = Experiment.open(setup, rate, arch=arch, n=n, arrival=arrival,
                          lengths=lengths, seed=seed, slo=slo)
    exp, leftovers = apply_spec_knobs(exp, cluster_kw)
    if leftovers:
        return None
    return as_cacheable(exp)


def run_rate_point(setup: Setup, cfg, rate: float, *,
                   lengths: Optional[LengthMix] = None,
                   slo: Optional[SLO] = None, n: int = 24, seed: int = 0,
                   arrival: str = "poisson",
                   **cluster_kw) -> RatePoint:
    """One grid cell: an open-loop workload served on ``setup``.

    Routed through ``repro.exp.run`` whenever the cell is expressible
    as a spec — which is every benchmark call — so rate grids,
    crossover bisections, and capacity searches share one
    content-addressed cache across processes. Off-registry configs and
    exotic cluster kwargs fall back to a direct (uncached) simulation."""
    exp = _as_experiment(setup, cfg, rate, lengths=lengths, slo=slo, n=n,
                         seed=seed, arrival=arrival, cluster_kw=cluster_kw)
    if exp is not None:
        from repro.exp import run as run_exp
        rec = run_exp(exp)
        m = rec.metrics
        g = rec.goodput
        return RatePoint(setup=rec.setup, rate=rate,
                         attainment=g["attainment"],
                         goodput_rps=g["goodput_rps"],
                         offered_rps=g["offered_rps"],
                         median_ttft_s=m.median_ttft_s,
                         p99_ttft_s=m.p99_ttft_s,
                         median_tpot_s=m.median_tpot_s,
                         makespan_s=m.makespan_s,
                         joules_per_token=rec.joules_per_token,
                         total_evictions=m.total_evictions,
                         total_j=rec.total_j,
                         idle_j=rec.idle_j)
    from repro.exp.runner import count_uncached_sim
    count_uncached_sim()
    reqs = open_loop_workload(rate, n, lengths=lengths, slo=slo,
                              arrival=arrival, seed=seed)
    res: SetupResult = make_cluster(setup, cfg, **cluster_kw).run(reqs)
    rep: GoodputReport = evaluate(reqs, slo)
    m = res.metrics
    return RatePoint(setup=setup_label(setup), rate=rate,
                     attainment=rep.attainment,
                     goodput_rps=rep.goodput_rps,
                     offered_rps=rep.offered_rps,
                     median_ttft_s=m.median_ttft_s,
                     p99_ttft_s=m.p99_ttft_s,
                     median_tpot_s=m.median_tpot_s,
                     makespan_s=m.makespan_s,
                     joules_per_token=res.joules_per_token,
                     total_evictions=m.total_evictions,
                     total_j=res.energy.total_j,
                     idle_j=res.energy.by_stage.get("idle", 0.0))


def rate_grid(cfg, rates: Sequence[float],
              setups: Sequence[Setup] = SETUPS, **kw) -> List[RatePoint]:
    """The full rate x setup grid (media are setups: dis-ici/host/disk;
    entries may be ``FleetSpec`` shapes, e.g. a P:D-ratio sweep)."""
    return [run_rate_point(s, cfg, r, **kw) for s in setups for r in rates]


# ----------------------------------------------------------------------
def goodput_gap(setup: Setup, baseline: Setup, cfg, rate: float,
                cache: Optional[Dict[Tuple[Setup, float], float]] = None,
                **kw) -> float:
    """goodput(setup) - goodput(baseline) at one offered rate.

    ``cache`` maps (setup, rate) -> goodput_rps and is consulted/filled
    so bisections sharing a baseline (or following a ``rate_grid``) do
    not re-simulate identical cells; entries are only valid for one
    fixed (cfg, workload, slo) combination — the caller's scope."""
    def goodput(s: Setup) -> float:
        key = (s, rate)
        if cache is not None and key in cache:
            return cache[key]
        g = run_rate_point(s, cfg, rate, **kw).goodput_rps
        if cache is not None:
            cache[key] = g
        return g

    return goodput(setup) - goodput(baseline)


@dataclass(frozen=True)
class Crossover:
    """The load at which the goodput winner flips between two setups."""
    rate: float
    winner_below: str
    winner_above: str


def crossover_rate(setup: Setup, cfg, *, baseline: Setup = "co-2gpus",
                   lo: float, hi: float, iters: int = 5,
                   cache: Optional[Dict[Tuple[Setup, float], float]] = None,
                   **kw) -> Optional[Crossover]:
    """Bisect for the offered rate where the goodput winner between
    ``setup`` and ``baseline`` flips, in either orientation.

    On this simulator's seeded physics (findings F1/F2) the flip runs
    co->dis: below the crossover the colocated baseline matches or beats
    dis-* (the KV handoff buys nothing while there is no interference to
    avoid), above it colocated prefill-priority interference — and, past
    the pool limit, preemption churn — hands the win to disaggregation.
    DistServe's orientation (dis wins low, co wins at saturation) is the
    mirror image; ``Crossover`` records who wins on each side rather
    than assuming one. Returns None when there is no sign change inside
    [lo, hi]: one side wins the whole bracket (dis-disk typically never
    beats co-2gpus at any rate).
    """
    if cache is None:
        cache = {}          # at least dedupe within this bisection
    g_lo = goodput_gap(setup, baseline, cfg, lo, cache=cache, **kw)
    g_hi = goodput_gap(setup, baseline, cfg, hi, cache=cache, **kw)
    if g_lo == 0.0 or (g_lo > 0) == (g_hi > 0):
        return None
    lo_wins_setup = g_lo > 0
    for _ in range(iters):
        mid = (lo + hi) / 2.0
        if (goodput_gap(setup, baseline, cfg, mid, cache=cache, **kw) > 0) \
                == lo_wins_setup:
            lo = mid
        else:
            hi = mid
    mid = (lo + hi) / 2.0
    s_label, b_label = setup_label(setup), setup_label(baseline)
    return Crossover(rate=mid,
                     winner_below=s_label if lo_wins_setup else b_label,
                     winner_above=b_label if lo_wins_setup else s_label)
