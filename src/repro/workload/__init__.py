"""Open-loop workload subsystem: arrival processes, length mixes, SLO
goodput, and rate sweeps (DESIGN.md section 9).

This is the load axis of the paper's central caveat — "the performance
benefit of disaggregation is not guaranteed; it depends on the request
load and KV transfer mediums" — made executable: build a seed-
deterministic open-loop workload with ``WorkloadSpec``, serve it on any
of the five setups, score it with DistServe-style SLO goodput, and
locate the crossover load with ``crossover_rate`` / ``max_goodput_rate``.
"""
from .arrivals import (ArrivalProcess, DeterministicArrivals,
                       DiurnalArrivals, GammaArrivals, PoissonArrivals,
                       RampArrivals, make_arrivals)
from .goodput import (DEFAULT_INTERACTIVE_SLO, GoodputReport, evaluate,
                      max_goodput_rate)
from .lengths import (ChatbotLengths, LengthMix, MixtureLengths,
                      PaperFixedLengths, RAGSharedPrefixLengths, ReqShape,
                      ShareGPTLengths, make_lengths)
from .spec import WorkloadSpec, open_loop_workload
from .sweep import (Crossover, RatePoint, crossover_rate, goodput_gap,
                    rate_grid, run_rate_point)

__all__ = [
    "ArrivalProcess", "PoissonArrivals", "GammaArrivals", "RampArrivals",
    "DiurnalArrivals", "DeterministicArrivals", "make_arrivals",
    "LengthMix", "PaperFixedLengths", "ShareGPTLengths", "ChatbotLengths",
    "RAGSharedPrefixLengths", "MixtureLengths", "ReqShape", "make_lengths",
    "WorkloadSpec", "open_loop_workload",
    "DEFAULT_INTERACTIVE_SLO", "GoodputReport", "evaluate",
    "max_goodput_rate",
    "Crossover", "RatePoint", "run_rate_point", "rate_grid",
    "goodput_gap", "crossover_rate",
]
