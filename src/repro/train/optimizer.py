"""Pure-JAX AdamW + LR schedules (no optax dependency in this container).

Optimizer state is a params-shaped pytree pair (m, v) plus a scalar count,
so the same NamedShardings as the parameters apply — which is what the
train-step builder relies on for sharded optimizer state (ZeRO-style: the
state shards with the TP/EP layout of its parameter).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    m: Any
    v: Any
    count: jnp.ndarray


class Optimizer(NamedTuple):
    init: Callable[[Any], AdamWState]
    update: Callable[[Any, AdamWState, Any], Tuple[Any, AdamWState]]


def adamw(learning_rate, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.1,
          grad_clip_norm: Optional[float] = 1.0) -> Optimizer:
    """learning_rate: float or callable(step) -> float."""

    def lr_at(count):
        if callable(learning_rate):
            return learning_rate(count)
        return learning_rate

    def init(params) -> AdamWState:
        zeros = lambda p: jnp.zeros_like(
            p, dtype=jnp.float32)   # f32 moments under bf16 params
        return AdamWState(m=jax.tree.map(zeros, params),
                          v=jax.tree.map(zeros, params),
                          count=jnp.zeros((), jnp.int32))

    def update(grads, state: AdamWState, params) -> Tuple[Any, AdamWState]:
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        if grad_clip_norm is not None:
            gnorm = global_norm(grads)
            scale = jnp.minimum(1.0, grad_clip_norm / (gnorm + 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)
        count = state.count + 1
        lr = lr_at(count)
        c1 = 1.0 - b1 ** count.astype(jnp.float32)
        c2 = 1.0 - b2 ** count.astype(jnp.float32)
        m = jax.tree.map(lambda mm, g: b1 * mm + (1 - b1) * g,
                         state.m, grads)
        v = jax.tree.map(lambda vv, g: b2 * vv + (1 - b2) * g * g,
                         state.v, grads)

        def upd(p, mm, vv):
            step = (mm / c1) / (jnp.sqrt(vv / c2) + eps)
            step = step + weight_decay * p.astype(jnp.float32)
            return (-lr * step).astype(p.dtype)

        updates = jax.tree.map(upd, params, m, v)
        return updates, AdamWState(m=m, v=v, count=count)

    return Optimizer(init=init, update=update)


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: p + u, params, updates)


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(l.astype(jnp.float32)))
              for l in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


# ----------------------------------------------------------------------
def cosine_schedule(peak_lr: float, warmup_steps: int = 200,
                    total_steps: int = 10_000,
                    final_frac: float = 0.1) -> Callable:
    def lr(count):
        c = count.astype(jnp.float32)
        warm = peak_lr * c / max(warmup_steps, 1)
        prog = jnp.clip((c - warmup_steps)
                        / max(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = final_frac + (1 - final_frac) * 0.5 * (
            1 + jnp.cos(jnp.pi * prog))
        return jnp.where(c < warmup_steps, warm, peak_lr * cos)
    return lr
