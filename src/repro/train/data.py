"""Synthetic data pipeline with a resumable cursor.

Deterministic function of (seed, step): a restart from a checkpointed
cursor reproduces the exact same batch stream — the property the
fault-tolerance tests assert (restarted loss curve == uninterrupted one).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np

from repro.configs.base import ModelConfig


@dataclass
class DataCursor:
    seed: int = 0
    step: int = 0

    def as_dict(self) -> Dict:
        return {"seed": self.seed, "step": self.step}

    @classmethod
    def from_dict(cls, d: Dict) -> "DataCursor":
        return cls(seed=int(d["seed"]), step=int(d["step"]))


class SyntheticLM:
    """Random-token LM batches (the RandomDataset analogue for training)."""

    def __init__(self, cfg: ModelConfig, batch_size: int, seq_len: int,
                 seed: int = 0):
        self.cfg = cfg
        self.batch = batch_size
        self.seq = seq_len
        self.cursor = DataCursor(seed=seed, step=0)

    def restore(self, cursor_dict: Dict) -> None:
        self.cursor = DataCursor.from_dict(cursor_dict)

    def _rng(self) -> np.random.Generator:
        return np.random.default_rng(
            (self.cursor.seed * 1_000_003 + self.cursor.step) & 0x7FFFFFFF)

    def _token_stream(self, rng: np.random.Generator, B: int,
                      S: int) -> np.ndarray:
        """Learnable synthetic LM stream: a noisy +stride walk over the
        vocab. 90% of transitions are deterministic, so a working training
        loop must push loss well below ln(vocab) — the property the
        fault-tolerance and end-to-end tests assert."""
        V = self.cfg.vocab_size
        stride = 1 + (self.cursor.seed % 7)
        toks = np.empty((B, S + 1), np.int64)
        toks[:, 0] = rng.integers(0, V, B)
        noise = rng.random((B, S)) < 0.1
        rand = rng.integers(0, V, (B, S))
        for t in range(S):
            nxt = (toks[:, t] + stride) % V
            toks[:, t + 1] = np.where(noise[:, t], rand[:, t], nxt)
        return toks

    def next_batch(self) -> Dict[str, np.ndarray]:
        rng = self._rng()
        cfg = self.cfg
        B, S = self.batch, self.seq
        out: Dict[str, np.ndarray] = {}
        if cfg.family == "vlm":
            Np = cfg.vision.num_patches
            S_txt = max(S - Np, 1)
            out["patches"] = rng.standard_normal(
                (B, Np, cfg.vision.frontend_dim)).astype(np.float32) * 0.1
            toks = self._token_stream(rng, B, S_txt)
        elif cfg.family == "encdec":
            out["src_embeds"] = rng.standard_normal(
                (B, S, cfg.encdec.frontend_dim)).astype(np.float32) * 0.1
            toks = self._token_stream(rng, B, S)
        else:
            toks = self._token_stream(rng, B, S)
        out["tokens"] = toks[:, :-1].astype(np.int32)
        out["targets"] = toks[:, 1:].astype(np.int32)
        self.cursor.step += 1
        return out
