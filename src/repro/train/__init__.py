from . import optimizer
from .optimizer import AdamWState, Optimizer, adamw, apply_updates, \
    cosine_schedule, global_norm
