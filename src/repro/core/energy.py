"""Energy accounting + latency-energy Pareto utilities (paper Figs 3-5).

``EnergyMeter`` integrates instantaneous power over intervals per hardware
component — the simulation analogue of the paper's pynvml / RAPL / IPMI
measurement stack. Components: one entry per accelerator ("acc0", "acc1"),
plus "cpu", "dram", "disk", "ici"/"pcie" transfer media.

Accelerator busy intervals are logged with (phi, utilization) so the DVFS
study (Experiment 2) can attribute stage-wise energy at each frequency.
When a ``PowerTrace`` is attached (every ``FleetCluster`` run attaches
one), timestamped ``add_power`` calls additionally append power samples,
giving each component a plottable idle/active power timeline
(``repro.govern.telemetry``, DESIGN.md section 11).
"""
from __future__ import annotations

import collections
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Tuple

import numpy as np

from repro.govern.telemetry import ACTIVE, IDLE, PowerTrace


def seq_sum(base: float, vals: np.ndarray) -> float:
    """Left-fold ``base + vals[0] + vals[1] + ...`` with rounding
    identical to the scalar loop (np.cumsum accumulates sequentially, so
    the result is bit-equal to repeated ``+=``). The coalescing fast
    stepper uses this to replay a run's worth of float accumulation in
    one vector op without perturbing golden totals."""
    if len(vals) == 0:
        return base
    return float(np.cumsum(np.concatenate(((base,), vals)))[-1])


@dataclass
class EnergyMeter:
    joules: Dict[str, float] = field(
        default_factory=lambda: collections.defaultdict(float))
    # per-stage attribution (prefill / decode / transfer-store /
    # transfer-fetch / idle)
    by_stage: Dict[str, float] = field(
        default_factory=lambda: collections.defaultdict(float))
    # optional sampled power timeline; purely observational — the joule
    # totals above are accumulated by the identical call sequence
    # whether or not a trace is attached (golden parity stays bit-exact)
    trace: Optional[PowerTrace] = None

    def add(self, component: str, joules: float, stage: str = "other"):
        self.joules[component] += joules
        self.by_stage[stage] += joules

    def add_power(self, component: str, watts: float, seconds: float,
                  stage: str = "other", t0: Optional[float] = None,
                  state: Optional[str] = None):
        self.add(component, watts * seconds, stage)
        if self.trace is not None and t0 is not None:
            if state is None:
                state = IDLE if stage == "idle" else ACTIVE
            self.trace.record(component, t0, t0 + seconds, watts, stage,
                              state=state)

    def add_power_run(self, component: str, watts: np.ndarray,
                      seconds: np.ndarray, stage: str,
                      t0s: Optional[np.ndarray] = None):
        """Bulk equivalent of ``len(watts)`` sequential ``add_power``
        calls: joules fold left-to-right (bit-equal to the scalar loop,
        see ``seq_sum``) and the trace — when attached — gains one
        ``PowerSample`` per element with ``t1 = t0 + seconds`` computed
        elementwise exactly as the scalar path does."""
        vals = watts * seconds
        self.joules[component] = seq_sum(self.joules[component], vals)
        self.by_stage[stage] = seq_sum(self.by_stage[stage], vals)
        if self.trace is not None and t0s is not None:
            self.trace.record_run(component, t0s, t0s + seconds, watts,
                                  stage,
                                  state=IDLE if stage == "idle" else ACTIVE)

    @property
    def total_j(self) -> float:
        return sum(self.joules.values())

    def breakdown(self) -> Dict[str, float]:
        return dict(self.joules)

    def merge(self, other: "EnergyMeter") -> "EnergyMeter":
        out = EnergyMeter()
        for src in (self, other):
            for k, v in src.joules.items():
                out.joules[k] += v
            for k, v in src.by_stage.items():
                out.by_stage[k] += v
        return out


# ----------------------------------------------------------------------
# Pareto frontier (paper Fig 5): (latency, energy) points over a freq grid
# ----------------------------------------------------------------------
@dataclass(frozen=True)
class ParetoPoint:
    phi: float            # relative frequency (or (phi_p, phi_d) encoded)
    latency_s: float
    energy_j: float
    label: str = ""


def pareto_frontier(points: Iterable[ParetoPoint]) -> List[ParetoPoint]:
    """Non-dominated subset (lower latency AND lower energy is better)."""
    pts = sorted(points, key=lambda p: (p.latency_s, p.energy_j))
    front: List[ParetoPoint] = []
    best_e = float("inf")
    for p in pts:
        if p.energy_j < best_e:
            front.append(p)
            best_e = p.energy_j
    return front


def min_energy_under_slo(points: Iterable[ParetoPoint],
                         latency_slo_s: Optional[float]
                         ) -> Optional[ParetoPoint]:
    """SLO-aware frequency selection: min energy s.t. latency <= SLO."""
    feasible = [p for p in points
                if latency_slo_s is None or p.latency_s <= latency_slo_s]
    if not feasible:
        return None
    return min(feasible, key=lambda p: p.energy_j)


def sweet_spot(points: Iterable[ParetoPoint]) -> ParetoPoint:
    """Unconstrained minimum-energy point (bottom of the U-curve)."""
    return min(points, key=lambda p: p.energy_j)
