"""Cluster orchestration: the paper's five experimental setups + event loop.

  co-1gpu    one colocated accelerator, full batch (DistServe's baseline)
  co-2gpus   two colocated accelerators, batch split evenly (the paper's
             new equal-resource baseline)
  dis-ici    prefill acc + decode acc, KV over the interconnect (dis-gpu)
  dis-host   prefill acc + decode acc, KV staged in host DRAM  (dis-cpu)
  dis-disk   prefill acc + decode acc, KV staged on NVMe       (dis-disk)

The orchestrator runs a discrete-event loop over engine steps and transfer
legs, integrates energy (busy + idle + host-node baseline, mirroring the
paper's pynvml/RAPL/IPMI stack), and returns per-request metrics.
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

from repro.configs.base import ModelConfig
from .costs import AcceleratorSpec, CostModel, HostSpec
from .energy import EnergyMeter
from .engine import Engine, EngineSeq, RealExecutor
from .kvcache import PagedKVPool
from .request import Request, WorkloadMetrics, summarize
from .transfer import TransferPath, make_path

SETUPS = ("co-1gpu", "co-2gpus", "dis-ici", "dis-host", "dis-disk")
DIS_PATH = {"dis-ici": "ici", "dis-host": "host", "dis-disk": "disk"}


@dataclass
class SetupResult:
    setup: str
    metrics: WorkloadMetrics
    energy: EnergyMeter
    requests: List[Request]
    makespan_s: float
    total_tokens: int

    @property
    def joules_per_token(self) -> float:
        return self.energy.total_j / max(self.total_tokens, 1)


class Cluster:
    def __init__(self, setup: str, cfg: ModelConfig, *,
                 acc: Optional[AcceleratorSpec] = None,
                 host: Optional[HostSpec] = None,
                 phi: float = 1.0, phi_prefill: Optional[float] = None,
                 phi_decode: Optional[float] = None,
                 page_size: int = 16,
                 prefill_token_budget: int = 8192,
                 pool_bytes: Optional[float] = None,
                 executor_factory: Optional[Callable[[TransferPath],
                                                     RealExecutor]] = None):
        assert setup in SETUPS, setup
        self.setup = setup
        self.cfg = cfg
        self.acc = acc or AcceleratorSpec()
        self.host = host or HostSpec()
        self.cost = CostModel(cfg, self.acc, self.host)
        self.meter = EnergyMeter()
        self.phi_p = phi_prefill if phi_prefill is not None else phi
        self.phi_d = phi_decode if phi_decode is not None else phi
        pool_bytes = pool_bytes or self.acc.kv_pool_gb * 1e9
        kv_per_tok = max(self.cost.kv_bytes_per_token, 1)

        def new_pool():
            return PagedKVPool.from_bytes(pool_bytes, kv_per_tok, page_size)

        self.path: Optional[TransferPath] = None
        self.engines: List[Engine] = []
        self._events: List = []   # heap of (t, tiebreak, fn)
        self._counter = itertools.count()

        if setup in ("co-1gpu", "co-2gpus"):
            n = 1 if setup == "co-1gpu" else 2
            for i in range(n):
                ex = executor_factory(None) if executor_factory else None
                self.engines.append(Engine(
                    f"acc{i}", "colocated", self.cost, new_pool(),
                    self.meter, phi=self.phi_p,
                    prefill_token_budget=prefill_token_budget, executor=ex))
        else:
            self.path = make_path(DIS_PATH[setup], self.host)
            ex_p = executor_factory(self.path) if executor_factory else None
            ex_d = executor_factory(self.path) if executor_factory else None
            pre = Engine("acc0", "prefill", self.cost, new_pool(),
                         self.meter, phi=self.phi_p,
                         prefill_token_budget=prefill_token_budget,
                         executor=ex_p, on_prefill_done=self._transfer)
            dec = Engine("acc1", "decode", self.cost, new_pool(),
                         self.meter, phi=self.phi_d,
                         prefill_token_budget=prefill_token_budget,
                         executor=ex_d)
            self.engines = [pre, dec]
            self._decode_engine = dec

    # ------------------------------------------------------------------
    def _push(self, t: float, fn: Callable[[], None]) -> None:
        heapq.heappush(self._events, (t, next(self._counter), fn))

    # ------------------------------------------------------------------
    def _transfer(self, engine: Engine, seq: EngineSeq, t_done: float):
        """Store leg: runs right after prefill; pages stay held on the
        prefill accelerator until the store completes."""
        nbytes = self.cost.kv_bytes(seq.ctx)
        store = self.path.store_cost(nbytes)
        fetch = self.path.fetch_cost(nbytes)
        for comp, joules in store.energy_j.items():
            self.meter.add(comp, joules, stage="transfer")
        handle = None
        if engine.executor is not None:
            handle = engine.executor.store(seq)

        t_arrive = t_done + store.latency_s
        seq.req.transfer_done_s = t_arrive

        def deliver():
            engine.pool.free_seq(seq.seq_id)
            # both engines resume no earlier than the store completion:
            # the prefill engine may have been blocked on pool space
            engine.t = max(engine.t, t_arrive)
            self._decode_engine.enqueue_decode(seq, handle, fetch)
            self._decode_engine.t = max(self._decode_engine.t, t_arrive)

        self._push(t_arrive, deliver)

    # ------------------------------------------------------------------
    def submit(self, requests: List[Request]) -> None:
        """Route every request through the event heap at its
        ``arrival_s``: an engine never sees a request before it arrives
        (submitting upfront let a staggered arrival be prefilled at t=0,
        yielding negative TTFT). ``Engine.submit`` fast-forwards an idle
        engine's clock to the arrival instant; a busy engine (clock
        already past it) just queues the request."""
        for i, r in enumerate(requests):
            # co-2gpus: even split, round-robin (paper section IV-F)
            eng = self.engines[i % 2 if self.setup == "co-2gpus" else 0]
            self._push(r.arrival_s, lambda e=eng, r=r: e.submit(r))

    # ------------------------------------------------------------------
    def run(self, requests: List[Request],
            max_steps: int = 2_000_000) -> SetupResult:
        self.submit(requests)
        steps = 0
        stalled = set()   # engines that made no progress; wait for an event
        while steps < max_steps:
            steps += 1
            candidates = [e for e in self.engines
                          if e not in stalled and e.has_work()]
            t_next_event = self._events[0][0] if self._events else None
            if candidates:
                eng = min(candidates, key=lambda e: e.t)
                # <= so an arrival at exactly the engine's clock is
                # admitted before the step that starts at that instant
                if t_next_event is not None and t_next_event <= eng.t:
                    _, _, fn = heapq.heappop(self._events)
                    fn()
                    stalled.clear()
                    continue
                if not eng.step():
                    # no progress (e.g. pool blocked by in-flight stores):
                    # park until the next event frees resources
                    stalled.add(eng)
                continue
            if self._events:
                _, _, fn = heapq.heappop(self._events)
                fn()
                stalled.clear()
                continue
            break

        unfinished = [r for r in requests if not r.done]
        assert not unfinished, (
            f"{self.setup}: {len(unfinished)} requests never finished "
            f"after {steps} loop iterations (deadlock?)")

        makespan = max(r.finish_s for r in requests) - \
            min(r.arrival_s for r in requests)
        # idle (static) accelerator power over the inference period
        for e in self.engines:
            idle_s = max(makespan - e.busy_s, 0.0)
            self.meter.add_power(e.name, self.cost.idle_power_w(), idle_s,
                                 stage="idle")
        # host-node baseline draw (IPMI-style whole-node accounting)
        self.meter.add_power("cpu", self.host.cpu_idle_w, makespan, "idle")
        self.meter.add_power("dram", self.host.dram_idle_w, makespan, "idle")
        self.meter.add_power("disk", self.host.disk_idle_w, makespan, "idle")

        total_tokens = sum(r.prompt_len + r.generated for r in requests)
        return SetupResult(setup=self.setup, metrics=summarize(requests),
                           energy=self.meter, requests=requests,
                           makespan_s=makespan, total_tokens=total_tokens)


# ----------------------------------------------------------------------
def run_setup(setup: str, cfg: ModelConfig, requests: List[Request],
              **kw) -> SetupResult:
    return Cluster(setup, cfg, **kw).run(requests)
