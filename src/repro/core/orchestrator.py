"""Cluster orchestration: the paper's five experimental setups.

  co-1gpu    one colocated accelerator, full batch (DistServe's baseline)
  co-2gpus   two colocated accelerators, batch split by the load-aware
             least-outstanding-tokens router (the paper's equal-resource
             baseline; the old static ``i % 2`` split ignored queue
             depth and inflated p99 TTFT on bursty arrivals)
  dis-ici    prefill acc + decode acc, KV over the interconnect (dis-gpu)
  dis-host   prefill acc + decode acc, KV staged in host DRAM  (dis-cpu)
  dis-disk   prefill acc + decode acc, KV staged on NVMe       (dis-disk)

``Cluster`` is a thin facade: each setup is the smallest possible
``repro.fleet`` fleet (1P:1D disaggregated, or 1-2 colocated), and the
discrete-event loop, transfer legs, and energy integration all live in
``FleetCluster`` (DESIGN.md section 10). Arbitrary xP:yD shapes go
through ``make_cluster`` / ``run_setup`` with a ``FleetSpec``.
"""
from __future__ import annotations

from typing import List, Optional, Union

from repro.configs.base import ModelConfig
from repro.fleet.cluster import FleetCluster, SetupResult
from repro.fleet.spec import DIS_PATH, SETUPS, FleetSpec, as_fleet_spec

from .request import Request

__all__ = ["SETUPS", "DIS_PATH", "SetupResult", "Cluster", "make_cluster",
           "run_setup"]


class Cluster(FleetCluster):
    """The five legacy setups as minimal fleets; same constructor
    signature and run() semantics as the pre-fleet orchestrator."""

    def __init__(self, setup: str, cfg: ModelConfig, *,
                 phi: float = 1.0, phi_prefill: Optional[float] = None,
                 phi_decode: Optional[float] = None, **kw):
        assert setup in SETUPS, setup
        super().__init__(FleetSpec.from_setup(setup), cfg, phi=phi,
                         phi_prefill=phi_prefill, phi_decode=phi_decode,
                         **kw)
        self.setup = setup      # report under the legacy name


# ----------------------------------------------------------------------
def make_cluster(setup: Union[str, FleetSpec], cfg: ModelConfig,
                 **kw) -> FleetCluster:
    """A cluster for a legacy setup name (reported under that name),
    a ``FleetSpec``, or a fleet-shape string like ``"2P2D-ici"``."""
    if isinstance(setup, str) and setup in SETUPS:
        return Cluster(setup, cfg, **kw)
    return FleetCluster(as_fleet_spec(setup), cfg, **kw)


def run_setup(setup: Union[str, FleetSpec], cfg: ModelConfig,
              requests: List[Request], *, stepper: Optional[str] = None,
              max_steps: int = 2_000_000, **kw) -> SetupResult:
    """Build and run a cluster. ``stepper`` picks the simulation core:
    "fast" (coalescing, the default), "exact" (reference event loop);
    None defers to ``repro.fleet.cluster.DEFAULT_STEPPER`` /
    ``REPRO_STEPPER``. Remaining kwargs go to the constructor."""
    return make_cluster(setup, cfg, **kw).run(requests, max_steps=max_steps,
                                              stepper=stepper)
