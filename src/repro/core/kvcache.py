"""Paged KV cache pool: the paper's central serving data structure.

Host-side bookkeeping (block tables, freelist, LRU eviction) that drives
every scheduling decision in the engines. It is deliberately independent of
whether KV bytes are physically resident (TPU-scale simulation) or backed by
real device pages (``DevicePagedKV`` below, used by the tiny-model
integration path and the Pallas paged-decode kernel).

Eviction semantics mirror vLLM's recompute-preemption: evicting a sequence
frees ALL its pages; the sequence must re-run prefill over its full context
(prompt + generated so far) before decoding can continue. That recompute is
what produces the paper's co-2gpus TPOT cliff (finding F2).
"""
from __future__ import annotations

import collections
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

import numpy as np


class OutOfPages(Exception):
    pass


@dataclass
class SeqAlloc:
    seq_id: int
    pages: List[int] = field(default_factory=list)
    tokens: int = 0                    # tokens currently materialized


class PagedKVPool:
    """Fixed-size page pool with per-sequence block tables + LRU eviction."""

    def __init__(self, num_pages: int, page_size: int = 16):
        assert num_pages > 0
        self.num_pages = num_pages
        self.page_size = page_size
        # Lazy freelist: pages never granted yet are the implicit range
        # [_next_fresh, num_pages); returned pages form an explicit LIFO
        # stack. Grant order (returned pages LIFO first, then fresh
        # ascending) is identical to the eager list(range(N-1, -1, -1))
        # this replaces — page ids are observable through block tables —
        # while construction is O(1) instead of O(num_pages), which
        # matters when a fleet sweep builds hundreds of ~1M-page pools.
        self._returned: List[int] = []
        self._next_fresh = 0
        self.seqs: Dict[int, SeqAlloc] = {}
        self._lru: "collections.OrderedDict[int, None]" = \
            collections.OrderedDict()

    # ------------------------------------------------------------------
    # freelist sanity cap: state-only archs (kv_bytes_per_token == 0, e.g.
    # rwkv6) would otherwise size the pool at pool_bytes/page_size pages —
    # a billion-entry freelist. 2^20 pages = 16M tokens never binds.
    MAX_PAGES = 1 << 20

    @classmethod
    def from_bytes(cls, pool_bytes: float, kv_bytes_per_token: int,
                   page_size: int = 16) -> "PagedKVPool":
        per_page = max(kv_bytes_per_token, 1) * page_size
        pages = min(max(int(pool_bytes // per_page), 1), cls.MAX_PAGES)
        return cls(num_pages=pages, page_size=page_size)

    # ------------------------------------------------------------------
    def pages_for(self, tokens: int) -> int:
        return -(-tokens // self.page_size)

    @property
    def used_pages(self) -> int:
        return self.num_pages - self.free_pages

    @property
    def free_pages(self) -> int:
        return len(self._returned) + (self.num_pages - self._next_fresh)

    @property
    def free(self) -> List[int]:
        """Materialized freelist in the eager layout this class used to
        keep (fresh pages descending, then returned pages in return
        order; ``pop()`` order from the end matches ``_pop_free``).
        O(num_pages) — for invariant checks and tests only."""
        return list(range(self.num_pages - 1, self._next_fresh - 1, -1)) \
            + self._returned

    def _pop_free(self) -> int:
        if self._returned:
            return self._returned.pop()
        page = self._next_fresh
        self._next_fresh += 1
        return page

    def block_table(self, seq_id: int) -> List[int]:
        return list(self.seqs[seq_id].pages)

    def tokens_of(self, seq_id: int) -> int:
        return self.seqs[seq_id].tokens

    def has_seq(self, seq_id: int) -> bool:
        return seq_id in self.seqs

    # ------------------------------------------------------------------
    def can_fit(self, tokens: int) -> bool:
        return self.pages_for(tokens) <= self.free_pages

    def allocate(self, seq_id: int, tokens: int) -> List[int]:
        """Materialize ``tokens`` MORE tokens for seq_id; returns any newly
        granted pages. Raises OutOfPages when the freelist is exhausted."""
        alloc = self.seqs.setdefault(seq_id, SeqAlloc(seq_id))
        new_total = alloc.tokens + tokens
        need = self.pages_for(new_total) - len(alloc.pages)
        if need > self.free_pages:
            raise OutOfPages(
                f"seq {seq_id}: need {need} pages, {self.free_pages} free")
        # bulk grant, identical order to `need` sequential _pop_free()
        # calls (returned LIFO first, then fresh ascending) without the
        # per-page call overhead — a 2048-token prefill grants 128 pages
        granted = []
        if need:
            take = min(need, len(self._returned))
            if take:
                granted = self._returned[-take:][::-1]
                del self._returned[-take:]
            fresh = need - take
            if fresh:
                granted.extend(range(self._next_fresh,
                                     self._next_fresh + fresh))
                self._next_fresh += fresh
        alloc.pages.extend(granted)
        alloc.tokens = new_total
        self.touch(seq_id)
        return granted

    def free_seq(self, seq_id: int) -> int:
        """Release a sequence's pages; returns how many were freed."""
        alloc = self.seqs.pop(seq_id, None)
        self._lru.pop(seq_id, None)
        if alloc is None:
            return 0
        self._returned.extend(alloc.pages)
        return len(alloc.pages)

    # ------------------------------------------------------------------
    def touch(self, seq_id: int) -> None:
        self._lru[seq_id] = None
        self._lru.move_to_end(seq_id)

    def lru_candidates(self, exclude: Optional[Set[int]] = None
                       ) -> List[int]:
        exclude = exclude or set()
        return [s for s in self._lru if s not in exclude]

    def evict_lru(self, exclude: Optional[Set[int]] = None) -> Optional[int]:
        """Evict the least-recently-used sequence; returns its id."""
        for seq_id in self.lru_candidates(exclude):
            self.free_seq(seq_id)
            return seq_id
        return None

    # invariant checks (property tests assert these hold under any op mix)
    def check_invariants(self) -> None:
        held = [p for a in self.seqs.values() for p in a.pages]
        all_pages = held + self.free
        assert len(all_pages) == self.num_pages, "page leak/duplication"
        assert len(set(all_pages)) == self.num_pages, "page double-grant"
        for a in self.seqs.values():
            assert len(a.pages) == self.pages_for(a.tokens), \
                f"seq {a.seq_id}: page count mismatch"


# ----------------------------------------------------------------------
# Device-backed pool for the dense-family real path (tiny models on CPU,
# Pallas paged kernel on TPU): physical pages [L, P, page, KV, hd].
# ----------------------------------------------------------------------
class DevicePagedKV:
    def __init__(self, pool: PagedKVPool, num_layers: int, kv_heads: int,
                 head_dim: int, dtype=np.float32):
        import jax.numpy as jnp
        self.pool = pool
        shape = (num_layers, pool.num_pages, pool.page_size, kv_heads,
                 head_dim)
        self.k = jnp.zeros(shape, dtype)
        self.v = jnp.zeros(shape, dtype)

    def write_prefill(self, seq_id: int, ks, vs) -> None:
        """ks/vs: [L, S, KV, hd] dense prefill output -> scatter to pages."""
        import jax.numpy as jnp
        pages = self.pool.block_table(seq_id)
        S = ks.shape[1]
        ps = self.pool.page_size
        for i, page in enumerate(pages):
            lo, hi = i * ps, min((i + 1) * ps, S)
            if lo >= S:
                break
            chunk_k = ks[:, lo:hi]
            chunk_v = vs[:, lo:hi]
            self.k = self.k.at[:, page, :hi - lo].set(chunk_k)
            self.v = self.v.at[:, page, :hi - lo].set(chunk_v)

    def write_token(self, seq_id: int, k_tok, v_tok, pos: int) -> None:
        """k_tok/v_tok: [L, KV, hd] one token at absolute position pos."""
        pages = self.pool.block_table(seq_id)
        page = pages[pos // self.pool.page_size]
        slot = pos % self.pool.page_size
        self.k = self.k.at[:, page, slot].set(k_tok)
        self.v = self.v.at[:, page, slot].set(v_tok)

    def gather_dense(self, seq_id: int):
        """-> (k [L, S, KV, hd], v) contiguous view for verification."""
        import jax.numpy as jnp
        pages = self.pool.block_table(seq_id)
        S = self.pool.tokens_of(seq_id)
        k = jnp.concatenate([self.k[:, p] for p in pages], axis=1)[:, :S]
        v = jnp.concatenate([self.v[:, p] for p in pages], axis=1)[:, :S]
        return k, v
