"""KV cache sharing & reuse (paper section II-C).

Two reuse strategies over page-granular token-content hashes:

  * **Prefix matching** (vLLM/SGLang-style): a chain-hash trie keyed on
    page content; a new request reuses the longest prefix of full pages
    whose chain hash matches a previously inserted sequence.
  * **Position-independent caching** (PIC / CacheBlend-style): full pages
    are matched by content hash REGARDLESS of position; reused blocks then
    selectively recompute a fraction of tokens (``recompute_frac``, the
    cross-attention repair CacheBlend performs) — so reuse saves
    (1 - recompute_frac) of the matched tokens' prefill work.

The cache tracks hit statistics and computes the prefill-token savings the
engines feed to the cost model. Page eviction is LRU by insertion/touch.
"""
from __future__ import annotations

import collections
import hashlib
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


def _stable_digest(salt: int, payload: bytes) -> int:
    """64-bit blake2b digest of ``salt || payload``, identical across
    processes. The builtin ``hash()`` is salted per-process by
    PYTHONHASHSEED, which would make page keys — and therefore hit
    statistics, tier residency, and router affinity scores — differ
    between a worker and the process that warmed the cache."""
    h = hashlib.blake2b(digest_size=8)
    h.update(salt.to_bytes(8, "little", signed=True))
    h.update(payload)
    return int.from_bytes(h.digest(), "little", signed=True)


def _page_hash(tokens: np.ndarray, salt: int = 0) -> int:
    return _stable_digest(salt, tokens.tobytes())


@dataclass
class ReuseResult:
    matched_tokens: int          # tokens whose KV can be reused
    recompute_tokens: int        # tokens that must still be (re)computed
    mode: str                    # "prefix" | "pic" | "none"

    def saved_tokens(self, total: int) -> int:
        """Prefill tokens avoided relative to computing all ``total``."""
        return total - self.recompute_tokens


class PrefixCache:
    """Chain-hash prefix trie + position-independent page index."""

    def __init__(self, capacity_pages: int, page_size: int = 16,
                 pic: bool = False, recompute_frac: float = 0.15):
        self.capacity = capacity_pages
        self.page_size = page_size
        self.pic = pic
        self.recompute_frac = recompute_frac
        # chain hash -> page payload (prefix matching)
        self._prefix: "collections.OrderedDict[int, int]" = \
            collections.OrderedDict()
        # content hash -> page payload (position independent)
        self._content: "collections.OrderedDict[int, int]" = \
            collections.OrderedDict()
        self.hits = 0
        self.misses = 0

    # ------------------------------------------------------------------
    def _pages(self, tokens: Sequence[int]) -> List[np.ndarray]:
        arr = np.asarray(tokens, dtype=np.int64)
        n_full = len(arr) // self.page_size
        return [arr[i * self.page_size:(i + 1) * self.page_size]
                for i in range(n_full)]

    @staticmethod
    def _chain(prev: int, page: np.ndarray) -> int:
        return _stable_digest(prev, page.tobytes())

    def _touch(self, table, key) -> None:
        table.move_to_end(key)

    def _insert(self, table, key, val=1) -> None:
        table[key] = val
        table.move_to_end(key)
        while len(table) > self.capacity:
            table.popitem(last=False)   # LRU

    # ------------------------------------------------------------------
    def insert(self, tokens: Sequence[int]) -> None:
        chain = 0
        for page in self._pages(tokens):
            chain = self._chain(chain, page)
            self._insert(self._prefix, chain)
            if self.pic:
                self._insert(self._content, _page_hash(page))

    # ------------------------------------------------------------------
    def peek_match(self, tokens: Sequence[int]) -> int:
        """Matched tokens a ``lookup`` would report, WITHOUT touching LRU
        order or hit counters — the prefix-affinity router probes every
        engine's cache per request, and a probe must not reorder
        eviction or inflate statistics."""
        matched_pages = 0
        if not self.pic:
            chain = 0
            for page in self._pages(tokens):
                chain = self._chain(chain, page)
                if chain not in self._prefix:
                    break
                matched_pages += 1
        else:
            matched_pages = sum(1 for page in self._pages(tokens)
                                if _page_hash(page) in self._content)
        return matched_pages * self.page_size

    # ------------------------------------------------------------------
    def lookup(self, tokens: Sequence[int]) -> ReuseResult:
        pages = self._pages(tokens)
        total = len(tokens)

        # longest matching prefix of full pages
        chain = 0
        prefix_pages = 0
        for page in pages:
            chain = self._chain(chain, page)
            if chain in self._prefix:
                self._touch(self._prefix, chain)
                prefix_pages += 1
            else:
                break

        if not self.pic:
            matched = prefix_pages * self.page_size
            if matched:
                self.hits += 1
            else:
                self.misses += 1
            return ReuseResult(matched_tokens=matched,
                               recompute_tokens=total - matched,
                               mode="prefix" if matched else "none")

        # PIC: any full page matched by content, anywhere in the sequence
        matched_pages = 0
        for page in pages:
            key = _page_hash(page)
            if key in self._content:
                self._touch(self._content, key)
                matched_pages += 1
        matched = matched_pages * self.page_size
        # CacheBlend-style selective recompute over reused spans
        repair = int(np.ceil(matched * self.recompute_frac))
        if matched:
            self.hits += 1
        else:
            self.misses += 1
        return ReuseResult(matched_tokens=matched,
                           recompute_tokens=total - matched + repair,
                           mode="pic" if matched else "none")
