"""KV-cache transfer paths between prefill and decode accelerators.

The paper's benchmarked variable (section IV-F). TPU adaptation per
DESIGN.md section 2:

  ici    GPU-P2P analogue: slice-to-slice ICI transfer (one hop, pushed
         directly into the decode accelerator's HBM)         -> dis-gpu
  host   CPU-DRAM staging: device ->PCIe-> host DRAM, then DRAM ->PCIe->
         device, with a lookup-table round trip (Redis)      -> dis-cpu
  disk   NVMe staging: host path + O_DIRECT-style full write+read
         (page cache bypassed, as the paper forces)          -> dis-disk

Every path is split into a STORE half (prefill side; its latency lands in
TTFT) and a FETCH half (decode side; it occupies the decode engine at
admission, so slower media degrade TPOT) — mirroring the LMCache connector
structure the paper instruments. For the ici path the store pushes straight
into decode HBM and the fetch is free.

``store()``/``fetch()`` also REALLY move the state pytree at test scale
(integration tests assert bit-exact round trips, including the disk
serialization).
"""
from __future__ import annotations

import io
import os
import pickle
import tempfile
from dataclasses import dataclass, field
from typing import Any, Dict, Optional

from .costs import HostSpec


@dataclass
class LegCost:
    latency_s: float
    energy_j: Dict[str, float] = field(default_factory=dict)
    busy: Dict[str, float] = field(default_factory=dict)


class TransferPath:
    name = "base"

    def __init__(self, host: Optional[HostSpec] = None):
        self.host = host or HostSpec()

    # timing/energy model ------------------------------------------------
    def store_cost(self, nbytes: int) -> LegCost:
        raise NotImplementedError

    def fetch_cost(self, nbytes: int) -> LegCost:
        raise NotImplementedError

    # real byte movement (integration tests) ------------------------------
    def store(self, state: Any) -> Any:
        """state pytree -> opaque handle held by the medium."""
        return state

    def fetch(self, handle: Any) -> Any:
        """handle -> state pytree on the decode side."""
        return handle


class ICIPath(TransferPath):
    """Device-to-device over the inter-slice interconnect (dis-gpu analog)."""

    name = "ici"

    def __init__(self, host=None, ici_bw: float = 200e9,
                 launch_latency_s: float = 20e-6):
        super().__init__(host)
        self.ici_bw = ici_bw
        self.launch_latency_s = launch_latency_s

    def store_cost(self, nbytes: int) -> LegCost:
        t = self.launch_latency_s + nbytes / self.ici_bw
        return LegCost(latency_s=t,
                       energy_j={"ici": nbytes * self.host.ici_pj_per_byte
                                 * 1e-12},
                       busy={"ici": t})

    def fetch_cost(self, nbytes: int) -> LegCost:
        return LegCost(latency_s=0.0)   # already resident in decode HBM

    def store(self, state: Any) -> Any:
        import jax
        return jax.tree.map(lambda x: jax.device_put(x), state)

    def fetch(self, handle: Any) -> Any:
        return handle


class HostPath(TransferPath):
    """Device -> host DRAM -> device staging (dis-cpu analog)."""

    name = "host"

    def __init__(self, host=None, lookup_latency_s: float = 200e-6):
        super().__init__(host)
        self.lookup_latency_s = lookup_latency_s   # Redis index round trip

    def _leg(self, nbytes: int) -> LegCost:
        h = self.host
        t = nbytes / h.pcie_bw + self.lookup_latency_s
        return LegCost(
            latency_s=t,
            energy_j={
                "pcie": nbytes * h.pcie_pj_per_byte * 1e-12,
                "dram": nbytes * h.dram_pj_per_byte * 1e-12,
                "cpu": (h.cpu_active_w - h.cpu_idle_w) * t,
            },
            busy={"cpu": t, "dram": t},
        )

    def store_cost(self, nbytes: int) -> LegCost:
        return self._leg(nbytes)

    def fetch_cost(self, nbytes: int) -> LegCost:
        return self._leg(nbytes)

    def store(self, state: Any) -> Any:
        import jax
        import numpy as np
        return jax.tree.map(lambda x: np.asarray(x), state)   # -> host DRAM

    def fetch(self, handle: Any) -> Any:
        import jax
        return jax.tree.map(lambda x: jax.device_put(x), handle)


class DiskPath(TransferPath):
    """Host staging + NVMe write/read, page cache bypassed (dis-disk)."""

    name = "disk"

    def __init__(self, host=None, scratch_dir: Optional[str] = None,
                 lookup_latency_s: float = 200e-6):
        super().__init__(host)
        self.scratch_dir = scratch_dir
        self.lookup_latency_s = lookup_latency_s

    def store_cost(self, nbytes: int) -> LegCost:
        h = self.host
        t_disk = nbytes / h.disk_write_bw
        t = nbytes / h.pcie_bw + t_disk + self.lookup_latency_s
        return LegCost(
            latency_s=t,
            energy_j={
                "pcie": nbytes * h.pcie_pj_per_byte * 1e-12,
                "dram": nbytes * h.dram_pj_per_byte * 1e-12,
                "disk": nbytes * h.disk_nj_per_byte * 1e-9,
                "cpu": (h.cpu_active_w - h.cpu_idle_w) * t,
            },
            busy={"cpu": t, "dram": t, "disk": t_disk},
        )

    def fetch_cost(self, nbytes: int) -> LegCost:
        h = self.host
        t_disk = nbytes / h.disk_read_bw
        t = t_disk + nbytes / h.pcie_bw + self.lookup_latency_s
        return LegCost(
            latency_s=t,
            energy_j={
                "pcie": nbytes * h.pcie_pj_per_byte * 1e-12,
                "dram": nbytes * h.dram_pj_per_byte * 1e-12,
                "disk": nbytes * h.disk_nj_per_byte * 1e-9,
                "cpu": (h.cpu_active_w - h.cpu_idle_w) * t,
            },
            busy={"cpu": t, "dram": t, "disk": t_disk},
        )

    def store(self, state: Any) -> Any:
        import jax
        import numpy as np
        buf = io.BytesIO()
        pickle.dump(jax.tree.map(lambda x: np.asarray(x), state), buf)
        data = buf.getvalue()
        fd, path = tempfile.mkstemp(dir=self.scratch_dir, suffix=".kv")
        with os.fdopen(fd, "wb") as f:
            f.write(data)
            f.flush()
            os.fsync(f.fileno())     # defeat write-back caching
        return path

    def fetch(self, handle: Any) -> Any:
        import jax
        with open(handle, "rb") as f:
            restored = pickle.load(f)
        os.unlink(handle)
        return jax.tree.map(lambda x: jax.device_put(x), restored)


PATHS = {"ici": ICIPath, "host": HostPath, "disk": DiskPath}


def make_path(name: str, host: Optional[HostSpec] = None,
              **kw) -> TransferPath:
    return PATHS[name](host=host, **kw)
