"""Serving engine: one accelerator running prefill, decode, or both.

One class, three roles (DESIGN.md section 4):

  colocated   vLLM-V1-style continuous batching: progressive per-chunk KV
              allocation, prefill-priority, and preemption-by-recompute of
              the lowest-priority sequence when the pool is exhausted. The
              serialized prefill/decode timeline IS the interference the
              paper measures; the preemption churn at high batch IS the
              paper's co-2gpus TPOT cliff (finding F2).
  prefill     prefill-only; finished sequences are handed to the
              orchestrator, which runs the KV store leg of the transfer.
              Pages stay held until the store completes (backpressure).
  decode      decode-only; admits transferred sequences when prompt + full
              output reservation fits (waves, never churn); the KV FETCH
              leg occupies the engine, so slower media degrade TPOT.

Timing comes from the roofline CostModel at the engine's DVFS setting
``phi`` (compute scales 1/phi, memory/interconnect do not). Energy is
integrated per step at P(phi, utilization). In real mode the engine also
executes a tiny model so token streams are bit-comparable across setups —
the KV-handoff correctness test.
"""
from __future__ import annotations

import bisect
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from .costs import CostModel, StepCost
from .energy import EnergyMeter
from .kvcache import OutOfPages, PagedKVPool
from .request import Request
from repro.obs.trace import NULL_TRACER


@dataclass(eq=False)
class EngineSeq:
    req: Request
    prefill_target: int = 0        # tokens to prefill (prompt, or recompute)
    prefill_done: int = 0
    ctx: int = 0                   # materialized KV tokens in the pool
    # real-mode payload
    state: Any = None              # decode-state pytree (batch axis 1, B=1)
    last_logits: Any = None
    next_token: Optional[int] = None
    # tiered-KV bookkeeping (repro.kvstore): the TierLookup from submit
    # (carries fetch/spill legs + pinned page keys) and a consumed-once
    # flag so preemption/re-admission never double-charges the fetch
    tier_hit: Any = None
    tier_charged: bool = False
    # admission-order override (repro.sched): a tuple key computed by
    # SchedulerSpec.admission_key at every waiting-queue insert; None
    # under FCFS, keeping the legacy int req_id priority bit-for-bit
    admission_key: Optional[tuple] = None

    @property
    def seq_id(self) -> int:
        return self.req.req_id

    @property
    def priority(self):
        # FCFS: lower req_id = earlier arrival = higher priority; an
        # SJF/SRPT/prefix-aware scheduler substitutes its tuple key
        # (whose trailing element is req_id — deterministic tie-break)
        if self.admission_key is not None:
            return self.admission_key
        return self.req.req_id


class Engine:
    def __init__(self, name: str, role: str, cost: CostModel,
                 pool: PagedKVPool, meter: EnergyMeter, *,
                 phi: float = 1.0, prefill_token_budget: int = 8192,
                 executor: Optional["RealExecutor"] = None,
                 on_prefill_done: Optional[Callable] = None,
                 prefix_cache=None):
        assert role in ("colocated", "prefill", "decode")
        self.name = name
        self.role = role
        self.cost = cost
        self.pool = pool
        self.meter = meter
        self.phi = phi
        self.budget = prefill_token_budget
        self.executor = executor
        # online DVFS controller (repro.govern): set by the cluster;
        # invoked at the top of every scheduler step. None = no retuning
        # (identical to the default StaticGovernor).
        self.governor = None
        # observability sink (repro.obs, DESIGN.md section 16): the
        # cluster installs a live Tracer; the default is the no-op
        # NULL_TRACER, so every hook below costs one attribute read
        self.tracer = NULL_TRACER
        self.on_prefill_done = on_prefill_done   # (engine, seq, t) -> None
        # KV reuse (paper section II-C): prefill work for matched tokens is
        # skipped. Simulation-only — in real mode the matched KV bytes are
        # not actually materialized, so reuse is disabled there.
        self.prefix_cache = prefix_cache if executor is None else None
        # tiered KV store (repro.kvstore, DESIGN.md section 15): set by
        # the fleet when the spec's ReuseSpec carries a TierSpec.
        # Mutually exclusive with prefix_cache (the fleet attaches one
        # or the other); a tiered engine is never fast-path eligible.
        self.kv_store = None
        # per-step batch composition + admission order (repro.sched,
        # DESIGN.md section 17): a SchedulerSpec set by the cluster.
        # None = the legacy serialize-prefill FCFS paths, byte-for-byte;
        # a non-coalescible spec also disables the fast path.
        self.scheduler = None
        # chunked-interleave audit log: (req_id, c0, c1) per scheduled
        # prefill chunk — the conservation invariant tests read this
        self.chunk_log: List[Tuple[int, int, int]] = []

        self.t = 0.0                 # engine-local clock
        self.busy_s = 0.0
        # fleet-controller lifecycle flags (repro.fleet.controller): a
        # sleeping or draining engine stops ACCEPTING new routed work but
        # keeps stepping what it already holds. Static fleets never
        # clear this, so the flag is free for them.
        self.accepting = True
        # pages reserved on this engine by in-flight KV transfers (the
        # kv-free-space router subtracts these; only decode-role engines
        # accumulate them, but a flipped engine needs the attribute)
        self.inflight_kv_pages = 0
        self.waiting: List[EngineSeq] = []       # priority-sorted
        self.prefilling: List[EngineSeq] = []    # priority-sorted
        self.running: List[EngineSeq] = []       # decode set
        self.decode_queue: deque = deque()       # (seq, handle, fetch_cost)
        self.pending_fetch: deque = deque()
        # tier demand-fetches awaiting their priced latency/energy step
        # (seqs whose submit-time lookup promoted pages out of DRAM/disk)
        self.pending_tier_fetch: deque = deque()
        self.steps = 0
        self.preemptions = 0
        # cached steady-state decode run (repro.core.fastpath); always
        # validated against live state before reuse, so stale entries
        # are harmless
        self._fastrun = None

    # ------------------------------------------------------------------
    def _quiescent(self) -> bool:
        """No queued or in-flight work of any kind."""
        return not (self.waiting or self.prefilling or self.running
                    or self.decode_queue or self.pending_fetch
                    or self.pending_tier_fetch)

    def submit(self, req: Request) -> None:
        # A request cannot be worked on before it arrives: a QUIESCENT
        # engine's clock fast-forwards to the arrival instant. An engine
        # that still holds work must NOT be clamped — the old
        # unconditional max() teleported a blocked engine's clock past
        # its queued work, billing that work a phantom wait (the latent
        # single-engine drift this PR's unit tests pin down). Instead,
        # _admit gates each sequence on arrival_s <= clock, and step()
        # skips an idle clock forward when all queued work lies in the
        # future — so prefill_start_s >= arrival_s still always holds.
        if self._quiescent():
            self.t = max(self.t, req.arrival_s)
        seq = EngineSeq(req=req, prefill_target=req.prompt_len)
        if self.kv_store is not None and req.prompt_tokens is not None:
            if self.tracer.enabled:
                self.kv_store.now = self.t   # clock for tier instants
            hit = self.kv_store.lookup(req.prompt_tokens)
            seq.tier_hit = hit
            saved = hit.saved_tokens(req.prompt_len)
            if saved > 0:
                seq.prefill_done = min(req.prompt_len - hit.recompute_tokens,
                                       req.prompt_len - 1)
                req.reused_tokens = seq.prefill_done
        elif self.prefix_cache is not None and req.prompt_tokens is not None:
            hit = self.prefix_cache.lookup(req.prompt_tokens)
            saved = hit.saved_tokens(req.prompt_len)
            if saved > 0:
                # matched KV is reused: only the remainder is computed
                # (always leave >=1 token so the last-position logits run)
                seq.prefill_done = min(req.prompt_len - hit.recompute_tokens,
                                       req.prompt_len - 1)
                req.reused_tokens = seq.prefill_done
        self._enqueue_waiting(seq)

    def _enqueue_waiting(self, seq: EngineSeq) -> None:
        if self.scheduler is not None:
            # recomputed at every insert: a preempted-and-requeued
            # sequence re-sorts by its live remaining work (SRPT)
            seq.admission_key = self.scheduler.admission_key(seq, self)
        bisect.insort(self.waiting, seq, key=lambda s: s.priority)

    def enqueue_decode(self, seq: EngineSeq, handle: Any, fetch_cost) -> None:
        self.decode_queue.append((seq, handle, fetch_cost))

    # ------------------------------------------------------------------
    def outstanding_tokens(self) -> int:
        """Remaining work queued on THIS engine, in tokens. This is the
        load signal the fleet's least-outstanding-tokens router balances
        on — unlike a request count, it weighs a 16k prompt ~64x heavier
        than a chat turn. Only work this engine will actually execute
        counts: a prefill-role engine hands its sequences off at
        prefill-done, so their decode tokens are the *decode* engine's
        outstanding work, not this one's."""
        decode_here = self.role != "prefill"
        tot = 0
        for s in self.waiting:
            tot += (s.prefill_target - s.prefill_done) \
                + (s.req.output_len - s.req.generated if decode_here else 0)
        for s in self.prefilling:
            tot += (s.prefill_target - s.prefill_done) \
                + (s.req.output_len - s.req.generated if decode_here else 0)
        for s in self.running:
            tot += s.req.output_len - s.req.generated
        for s, _, _ in self.decode_queue:
            tot += s.req.output_len - s.req.generated
        for s, _, _ in self.pending_fetch:
            tot += s.req.output_len - s.req.generated
        return tot

    # ------------------------------------------------------------------
    def has_work(self) -> bool:
        if self.prefilling or self.running or self.pending_fetch \
                or self.pending_tier_fetch:
            return True
        if self.waiting and self.role in ("colocated", "prefill"):
            # progressive allocation: a single free page is enough to start
            return self.pool.free_pages > 0
        if self.decode_queue and self._can_admit_decode(
                self.decode_queue[0][0]):
            return True
        return False

    # ------------------------------------------------------------------
    def _can_admit_decode(self, seq: EngineSeq) -> bool:
        # reserve prompt + full output budget: disaggregated decode never
        # preempts (waves instead of churn)
        need = seq.ctx + (seq.req.output_len - seq.req.generated) + 1
        return self.pool.can_fit(need)

    def _admit(self) -> None:
        if self.role in ("colocated", "prefill"):
            # V1-style: admission is cheap; per-chunk allocation throttles.
            # Only ARRIVED sequences are admitted (arrival_s <= clock):
            # priority order is req_id, which need not be arrival order,
            # so each entry is gated individually rather than head-only.
            i = 0
            while i < len(self.waiting) and self.pool.free_pages > 0:
                seq = self.waiting[i]
                if seq.req.arrival_s > self.t:
                    i += 1
                    continue
                self.waiting.pop(i)
                if seq.req.prefill_start_s is None:
                    seq.req.prefill_start_s = self.t
                    if self.tracer.enabled:
                        self.tracer.lifecycle("prefill_start",
                                              seq.req.req_id, self.t,
                                              engine=self.name)
                if seq.tier_hit is not None and not seq.tier_charged \
                        and (seq.tier_hit.fetch_legs
                             or seq.tier_hit.spill_legs):
                    # the submit-time lookup pulled pages up the tier
                    # hierarchy: run the priced fetch leg before this
                    # sequence's prefill (step() drains it first)
                    self.pending_tier_fetch.append(seq)
                bisect.insort(self.prefilling, seq,
                              key=lambda s: s.priority)
        if self.role == "decode":
            while (self.decode_queue
                   and self._can_admit_decode(self.decode_queue[0][0])):
                seq, handle, fetch_cost = self.decode_queue.popleft()
                reserve = seq.ctx + (seq.req.output_len
                                     - seq.req.generated) + 1
                self.pool.allocate(seq.seq_id, reserve)
                self.pending_fetch.append((seq, handle, fetch_cost))

    # ------------------------------------------------------------------
    # one scheduler step; returns True if any progress was made
    # ------------------------------------------------------------------
    def step(self) -> bool:
        if self.governor is not None:
            # retune phi from live signals BEFORE the step so the step's
            # timing and power integrate at the decided frequency
            self.governor.on_step(self)
        self._admit()
        if self.pending_fetch:
            self._fetch_step()
            return True
        if self.pending_tier_fetch:
            self._tier_fetch_step()
            return True
        if self.prefilling:
            return self._compose_step()
        if self.running:
            return self._decode_step()
        if self.waiting and self.pool.free_pages > 0 \
                and self.role in ("colocated", "prefill"):
            # nothing schedulable now but queued arrivals lie in the
            # future: an otherwise-idle engine skips its clock to the
            # earliest one (a bare engine driven by step() alone must
            # not deadlock; in a cluster an event usually fires first)
            t_next = min(s.req.arrival_s for s in self.waiting)
            if t_next > self.t:
                self.t = t_next
                self._admit()
                if self.prefilling:
                    return self._compose_step()
        return False

    def _compose_step(self):
        """Route a step with prefill work through the configured step
        composer: the legacy serialize-prefill path, or the Sarathi-style
        chunked-interleave composer (repro.sched)."""
        if self.scheduler is not None and self.scheduler.interleaves:
            return self._interleaved_step()
        return self._prefill_step()

    # ------------------------------------------------------------------
    def _account(self, cost: StepCost, stage: str) -> float:
        dt = cost.time(self.phi)
        util = cost.utilization(self.phi)
        self.meter.add_power(self.name, self.cost.power_w(self.phi, util),
                             dt, stage=stage, t0=self.t)
        t0 = self.t
        self.t += dt
        self.busy_s += dt
        self.steps += 1
        if self.tracer.enabled:
            self.tracer.span(self.name, stage, t0, self.t, steps=1)
        return self.t

    # ------------------------------------------------------------------
    def _fetch_step(self) -> float:
        """Run the KV fetch leg for one admitted sequence (decode role)."""
        seq, handle, leg = self.pending_fetch.popleft()
        # the fetch leg belongs to the DECODE side of the handoff: its
        # joules (and the engine-occupancy power below) are tagged
        # transfer-fetch so the DVFS sweeps attribute them to decode
        # energy, per the routed path's actual LegCost (the store leg is
        # tagged transfer-store by the fleet's _transfer)
        for comp, joules in leg.energy_j.items():
            self.meter.add(comp, joules, stage="transfer-fetch")
        # the engine is occupied while the fetch lands in its HBM
        self.meter.add_power(self.name, self.cost.idle_power_w(),
                             leg.latency_s, stage="transfer-fetch",
                             t0=self.t)
        t0 = self.t
        self.t += leg.latency_s
        self.busy_s += leg.latency_s
        if self.tracer.enabled:
            self.tracer.span(self.name, "transfer-fetch", t0, self.t,
                             steps=0, req=seq.req.req_id)
            self.tracer.lifecycle("fetch_start", seq.req.req_id, t0,
                                  engine=self.name)
        if self.executor is not None and handle is not None:
            seq.state, seq.last_logits = self.executor.fetch(handle)
        if seq.req.decode_start_s is None:
            seq.req.decode_start_s = self.t
        if seq.req.first_token_s is None:
            # dis-*: the first token (argmax of the transferred prefill
            # logits) is released once the KV lands on the decode side —
            # so TTFT = prefill + store + queue + fetch (medium-sensitive)
            seq.req.first_token_s = self.t
            seq.req.generated = 1
            if seq.next_token is not None:
                seq.req.output_tokens.append(int(seq.next_token))
            if self.tracer.enabled:
                self.tracer.lifecycle("first_token", seq.req.req_id,
                                      self.t, engine=self.name)
        if seq.req.generated >= seq.req.output_len:
            # single-token outputs finish at the first token
            seq.req.finish_s = self.t
            self.pool.free_seq(seq.seq_id)
            if self.tracer.enabled:
                self.tracer.lifecycle("finish", seq.req.req_id, self.t,
                                      engine=self.name)
        else:
            self.running.append(seq)
        return self.t

    # ------------------------------------------------------------------
    def _tier_fetch_step(self) -> float:
        """Meter one sequence's tiered-KV movement (DESIGN.md section
        15). Demand-fetch legs occupy the engine at idle power for
        their latency — stage ``tier-fetch``, sampled into the
        PowerTrace, landing in TTFT exactly like a transfer fetch.
        Spill legs displaced by the promotion are asynchronous DMA:
        energy only, stage ``tier-spill``, no engine occupancy."""
        seq = self.pending_tier_fetch.popleft()
        hit = seq.tier_hit
        seq.tier_charged = True
        latency = 0.0
        for leg in hit.fetch_legs:
            for comp, joules in leg.energy_j.items():
                self.meter.add(comp, joules, stage="tier-fetch")
            latency += leg.latency_s
        for leg in hit.spill_legs:
            for comp, joules in leg.energy_j.items():
                self.meter.add(comp, joules, stage="tier-spill")
        if latency > 0.0:
            self.meter.add_power(self.name, self.cost.idle_power_w(),
                                 latency, stage="tier-fetch", t0=self.t)
            t0 = self.t
            self.t += latency
            self.busy_s += latency
            if self.tracer.enabled:
                self.tracer.span(self.name, "tier-fetch", t0, self.t,
                                 steps=0, req=seq.req.req_id)
        return self.t

    # ------------------------------------------------------------------
    # preemption (vLLM recompute-style)
    # ------------------------------------------------------------------
    def _victims_below(self, priority) -> List[EngineSeq]:
        """Sequences holding pages, strictly lower priority, lowest first.

        (A decode-victims-first variant was hypothesized to keep TTFT
        clean under churn; measured: it TRIPLES recompute volume and
        worsens both TTFT and TPOT — vLLM's pure arrival-priority order
        is kept. See EXPERIMENTS.md reproduction caveats.)"""
        holders = [s for s in self.running + self.prefilling
                   if s.priority > priority
                   and self.pool.has_seq(s.seq_id)]
        # reverse=True, not key=-priority: admission keys may be tuples
        return sorted(holders, key=lambda s: s.priority, reverse=True)

    def _preempt(self, seq: EngineSeq) -> None:
        self.pool.free_seq(seq.seq_id)
        self.preemptions += 1
        if self.tracer.enabled:
            self.tracer.instant(self.name, "preempt", self.t,
                                req=seq.req.req_id)
        if seq in self.running:
            self.running.remove(seq)
            seq.req.evictions += 1
            redo = seq.req.prompt_len + seq.req.generated
            seq.req.recomputed_tokens += redo
            seq.prefill_target = redo
        elif seq in self.prefilling:
            self.prefilling.remove(seq)
            seq.req.evictions += 1
            seq.req.recomputed_tokens += seq.prefill_done
        seq.prefill_done = 0
        seq.ctx = 0
        seq.state = None
        self._enqueue_waiting(seq)

    def _alloc_or_preempt(self, seq: EngineSeq, tokens: int) -> bool:
        """Allocate; on exhaustion preempt strictly-lower-priority holders.
        Returns False if the allocation is impossible right now."""
        while True:
            try:
                self.pool.allocate(seq.seq_id, tokens)
                return True
            except OutOfPages:
                victims = self._victims_below(seq.priority)
                if not victims:
                    return False
                self._preempt(victims[0])

    # ------------------------------------------------------------------
    def _prefill_step(self) -> float:
        budget = self.budget
        chunks: List[Tuple[EngineSeq, int, int]] = []
        for seq in list(self.prefilling):
            if budget <= 0:
                break
            if seq not in self.prefilling:
                continue   # preempted by an earlier seq's allocation
            remaining = seq.prefill_target - seq.prefill_done
            take = min(remaining, budget)
            if take <= 0:
                continue
            if not self._alloc_or_preempt(seq, take):
                # pool exhausted by higher-priority holders: take whatever
                # fits (vLLM V1 chunked prefill absorbs the free slack —
                # the behavior behind the co-* preemption churn at high
                # batch, finding F2)
                take = min(take,
                           self.pool.free_pages * self.pool.page_size)
                if take <= 0 or not self._alloc_or_preempt(seq, take):
                    break
            chunks.append((seq, seq.prefill_done, seq.prefill_done + take))
            budget -= take
        if not chunks:
            # nothing schedulable: fall through to decode if possible
            if self.running:
                return self._decode_step()
            return False

        cost = self.cost.prefill_step_cost(
            [(c1 - c0, c0, c1) for _, c0, c1 in chunks])
        t_end = self._account(cost, "prefill")

        for seq, c0, c1 in chunks:
            if not self.pool.has_seq(seq.seq_id):
                continue   # preempted later in the same step's alloc loop
            seq.prefill_done = c1
            seq.ctx = c1
            if seq.prefill_done >= seq.prefill_target:
                self._complete_prefill(seq, t_end)
        return True

    def _complete_prefill(self, seq: EngineSeq, t_end: float) -> None:
        """Bookkeeping when a sequence's LAST prefill chunk lands —
        shared by the serial and chunked-interleave step composers:
        reuse-layer insert/release, executor prefill, and either the
        colocated first-token release or the disaggregated handoff."""
        self.prefilling.remove(seq)
        seq.req.prefill_done_s = t_end
        if self.tracer.enabled:
            self.tracer.lifecycle("prefill_done", seq.req.req_id,
                                  t_end, engine=self.name)
            if self.kv_store is not None:
                self.kv_store.now = t_end
        self.pool.touch(seq.seq_id)
        if self.kv_store is not None and \
                seq.req.prompt_tokens is not None:
            # newly computed pages are born in HBM; demotions
            # forced by the overflow — and by releasing this
            # sequence's pins — are priced spill legs
            legs = self.kv_store.insert(seq.req.prompt_tokens)
            if seq.tier_hit is not None:
                legs += self.kv_store.release(seq.tier_hit.pins)
            for leg in legs:
                for comp, joules in leg.energy_j.items():
                    self.meter.add(comp, joules,
                                   stage="tier-spill")
        elif self.prefix_cache is not None and \
                seq.req.prompt_tokens is not None:
            self.prefix_cache.insert(seq.req.prompt_tokens)
        if self.executor is not None:
            seq.state, seq.last_logits, seq.next_token = \
                self.executor.prefill(seq)
        if self.role == "colocated":
            if seq.req.first_token_s is None:
                # first token sampled from prefill logits (vLLM)
                seq.req.first_token_s = t_end
                seq.req.generated = 1
                if seq.next_token is not None:
                    seq.req.output_tokens.append(int(seq.next_token))
                if self.tracer.enabled:
                    self.tracer.lifecycle(
                        "first_token", seq.req.req_id, t_end,
                        engine=self.name)
            if seq.req.generated >= seq.req.output_len:
                # single-token outputs finish at the first token
                seq.req.finish_s = t_end
                self.pool.free_seq(seq.seq_id)
                if self.tracer.enabled:
                    self.tracer.lifecycle(
                        "finish", seq.req.req_id, t_end,
                        engine=self.name)
            else:
                self.running.append(seq)
        else:
            self.on_prefill_done(self, seq, t_end)

    # ------------------------------------------------------------------
    def _decode_step(self) -> float:
        # grow each running seq by one token (colocated; decode pre-reserved)
        if self.role != "decode":
            for seq in sorted(self.running, key=lambda s: s.priority):
                if seq not in self.running:
                    continue   # preempted by an earlier seq's growth
                if not self._alloc_or_preempt(seq, 1):
                    # lowest-priority holder and no room: preempt self
                    self._preempt(seq)
        if not self.running:
            return False
        batch = list(self.running)
        total_ctx = sum(s.ctx for s in batch)
        cost = self.cost.decode_cost(len(batch), total_ctx)
        t_end = self._account(cost, "decode")

        if self.executor is not None:
            self.executor.decode_batch(batch)

        for seq in batch:
            if seq not in self.running:
                continue   # preempted during the growth loop
            self._complete_decode_token(seq, t_end)
        return True

    def _complete_decode_token(self, seq: EngineSeq, t_end: float) -> None:
        """One emitted token's bookkeeping — shared by the serial decode
        step and the chunked-interleave composed step."""
        seq.ctx += 1
        self.pool.touch(seq.seq_id)
        seq.req.generated += 1
        if seq.next_token is not None:
            seq.req.output_tokens.append(int(seq.next_token))
        if seq.req.generated >= seq.req.output_len:
            seq.req.finish_s = t_end
            self.pool.free_seq(seq.seq_id)
            self.running.remove(seq)
            if self.tracer.enabled:
                self.tracer.lifecycle("finish", seq.req.req_id,
                                      t_end, engine=self.name)

    # ------------------------------------------------------------------
    def _interleaved_step(self) -> float:
        """Sarathi-style composed step (the ``chunked-interleave``
        composer, repro.sched): grow the running decode batch by one
        token each AND pack prefill chunks into the remainder of the
        step's ``chunk_tokens`` budget. Stall-free batching: every
        composed step emits one token per running sequence, so the
        worst decode inter-token gap is ONE chunk-bounded step — the
        prefill backlog can no longer starve TPOT the way the serial
        composer's full-budget prefill steps do. Priced exactly by
        ``CostModel.mixed_step_cost`` (weights stream once for both
        halves; compute and HBM traffic add)."""
        sched = self.scheduler
        # decode side first — identical growth/preemption discipline to
        # _decode_step (decode-role engines are pre-reserved, no growth)
        if self.role != "decode":
            for seq in sorted(self.running, key=lambda s: s.priority):
                if seq not in self.running:
                    continue   # preempted by an earlier seq's growth
                if not self._alloc_or_preempt(seq, 1):
                    self._preempt(seq)
        # prefill side: one decode token per running sequence is spent
        # from the composed budget before any chunk is packed — that IS
        # the stall-free guarantee (decode work is never displaced)
        budget = max(sched.chunk_tokens - len(self.running), 0)
        chunks: List[Tuple[EngineSeq, int, int]] = []
        for seq in list(self.prefilling):
            if budget <= 0:
                break
            if seq not in self.prefilling:
                continue   # preempted by an earlier seq's allocation
            remaining = seq.prefill_target - seq.prefill_done
            take = min(remaining, budget)
            if take <= 0:
                continue
            if not self._alloc_or_preempt(seq, take):
                # pool exhausted by higher-priority holders: absorb the
                # free slack, exactly like the serial composer
                take = min(take,
                           self.pool.free_pages * self.pool.page_size)
                if take <= 0 or not self._alloc_or_preempt(seq, take):
                    break
            chunks.append((seq, seq.prefill_done, seq.prefill_done + take))
            budget -= take
        # chunk packing may have preempted grown decode sequences:
        # compose the batch AFTER packing so pricing matches execution
        batch = list(self.running)
        if not chunks and not batch:
            return False
        total_ctx = sum(s.ctx for s in batch)
        if chunks and batch:
            cost = self.cost.mixed_step_cost(
                [(c1 - c0, c0, c1) for _, c0, c1 in chunks],
                len(batch), total_ctx)
            stage = "mixed"
        elif chunks:
            cost = self.cost.prefill_step_cost(
                [(c1 - c0, c0, c1) for _, c0, c1 in chunks])
            stage = "prefill"
        else:
            cost = self.cost.decode_cost(len(batch), total_ctx)
            stage = "decode"
        t0 = self.t
        t_end = self._account(cost, stage)

        for seq, c0, c1 in chunks:
            self.chunk_log.append((seq.req.req_id, c0, c1))
        if self.tracer.enabled:
            # scheduler decisions are first-class trace events: an
            # instant on the engine track, plus one span per chunk on a
            # dedicated sched:<engine> track (Perfetto-visible chunks)
            self.tracer.instant(self.name, "sched", t0,
                                decode_batch=len(batch),
                                prefill_tokens=sum(
                                    c1 - c0 for _, c0, c1 in chunks),
                                chunks=len(chunks))
            for seq, c0, c1 in chunks:
                self.tracer.span(f"sched:{self.name}", "chunk", t0,
                                 t_end, steps=0, req=seq.req.req_id,
                                 c0=c0, c1=c1)

        if self.executor is not None and batch:
            self.executor.decode_batch(batch)
        for seq in batch:
            if seq not in self.running:
                continue   # preempted during the packing loop
            self._complete_decode_token(seq, t_end)
        for seq, c0, c1 in chunks:
            if not self.pool.has_seq(seq.seq_id):
                continue   # preempted later in the same step's alloc loop
            seq.prefill_done = c1
            seq.ctx = c1
            if seq.prefill_done >= seq.prefill_target:
                self._complete_prefill(seq, t_end)
        return True


# ----------------------------------------------------------------------
# Real execution (tiny models on CPU): timing stays simulated, but tokens
# are really computed so setups can be compared bit-for-bit.
# ----------------------------------------------------------------------
class RealExecutor:
    """Executes prefill/decode with an actual model; greedy sampling."""

    def __init__(self, model, params, transfer_path=None):
        import jax
        import jax.numpy as jnp
        self.model = model
        self.params = params
        self.path = transfer_path
        self._jnp = jnp
        self._jax = jax

    def _context_tokens(self, seq: EngineSeq) -> np.ndarray:
        """prompt + already-emitted tokens (recompute path needs both)."""
        toks = list(seq.req.prompt_tokens)
        need = seq.prefill_target - len(toks)
        if need > 0:
            toks = toks + seq.req.output_tokens[:need]
        return np.asarray(toks[:seq.prefill_target], dtype=np.int32)

    def prefill(self, seq: EngineSeq):
        jnp = self._jnp
        toks = jnp.asarray(self._context_tokens(seq))[None, :]
        s_max = seq.req.prompt_len + seq.req.output_len + 2
        logits, state = self.model.prefill(
            self.params, {"tokens": toks}, s_max=s_max)
        next_token = int(jnp.argmax(logits[0]))
        return state, logits, next_token

    def store(self, seq: EngineSeq):
        payload = (seq.state, seq.last_logits)
        if self.path is None:
            return payload
        return self.path.store(payload)

    def fetch(self, handle):
        if self.path is None:
            return handle
        return self.path.fetch(handle)

    def decode_batch(self, batch: List[EngineSeq]) -> None:
        jax, jnp = self._jax, self._jnp
        tokens = jnp.asarray([s.next_token for s in batch], jnp.int32)
        pos = jnp.asarray([s.ctx for s in batch], jnp.int32)
        states = [s.state for s in batch]
        joined = jax.tree.map(
            lambda *xs: jnp.concatenate(xs, axis=1), *states)
        logits, new_state = self.model.decode_step(
            self.params, tokens, joined, pos)
        nxt = jnp.argmax(logits, axis=-1)
        for i, seq in enumerate(batch):
            seq.state = jax.tree.map(
                lambda x: x[:, i:i + 1] if x.ndim > 1 else x[i:i + 1],
                new_state)
            seq.next_token = int(nxt[i])
