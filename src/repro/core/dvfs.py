"""DVFS benchmarking (paper Experiment 2 / Fig 5).

Mirrors the paper's methodology: fix the workload, sweep the frequency grid
(applied to every accelerator in the setup), measure median TTFT / median
TPOT and per-stage energy, and build the latency-energy Pareto frontiers.

Stage-wise *independent* frequency selection — disaggregation's unique
capability — is then evaluated by combining the prefill frontier at
phi_p with the decode frontier at phi_d (the engines are physically
separate, so the sweep points compose), and comparing against the best
colocated single-phi point.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Tuple, Union

from repro.configs.base import ModelConfig
from repro.fleet.spec import FleetSpec, as_fleet_spec, setup_label
from .costs import DEFAULT_FREQ_GRID
from .energy import ParetoPoint, min_energy_under_slo, pareto_frontier
from .orchestrator import SetupResult, make_cluster
from .request import Request

Setup = Union[str, FleetSpec]


@dataclass
class FrequencySweep:
    setup: str
    prefill_points: List[ParetoPoint]   # (phi, median TTFT, prefill energy)
    decode_points: List[ParetoPoint]    # (phi, median TPOT, decode energy)
    results: Dict[float, SetupResult]

    def prefill_frontier(self) -> List[ParetoPoint]:
        return pareto_frontier(self.prefill_points)

    def decode_frontier(self) -> List[ParetoPoint]:
        return pareto_frontier(self.decode_points)


def _materialize(workload) -> List[Request]:
    """Accept either a zero-arg factory (the legacy t=0 batches) or any
    object with a ``build()`` method (``repro.workload.WorkloadSpec``);
    each call must yield a FRESH request list (requests are mutated by a
    run), which both forms guarantee."""
    build = getattr(workload, "build", None)
    if callable(build):
        return build()
    return workload()


def _route_exp(setup: Setup, cfg, workload, cluster_kw):
    """The sweep cell as a ``repro.exp`` Experiment base (phi applied
    per grid point by the caller) when it is spec-expressible: a
    registered config, no out-of-band cluster kwargs, and a declarative
    workload (``WorkloadSpec`` / ``ClosedLoop`` / ``OpenLoop``). A
    factory callable cannot be content-addressed -> None (direct,
    uncached simulation, the original behavior)."""
    if cluster_kw:
        return None
    from repro.exp.spec import (ClosedLoop, Experiment, OpenLoop,
                                as_cacheable, registered_arch)
    from repro.workload.spec import WorkloadSpec
    arch = registered_arch(cfg)
    if arch is None:
        return None
    if isinstance(workload, WorkloadSpec):
        exp = Experiment(arch=arch, fleet=setup, workload=workload,
                         slo=workload.slo)
    elif isinstance(workload, (ClosedLoop, OpenLoop)):
        exp = Experiment(arch=arch, fleet=setup, workload=workload)
    else:
        return None
    return as_cacheable(exp)


def sweep_frequencies(setup: Setup, cfg: ModelConfig,
                      workload: Callable[[], List[Request]],
                      freq_grid: Tuple[float, ...] = DEFAULT_FREQ_GRID,
                      **cluster_kw) -> FrequencySweep:
    """Run the fixed workload at each grid frequency (set on ALL
    accelerators, as the paper does) and collect per-stage points.
    ``setup`` is a legacy setup name or any ``FleetSpec``; ``workload``
    is a request-list factory or a ``WorkloadSpec``.

    This is the legacy sweep signature, kept as a shim over
    ``repro.exp``: a spec-expressible call routes each grid point
    through the content-addressed result cache (``results`` values are
    then ``RunRecord``s — same ``.metrics`` / ``.energy`` surface);
    factory workloads and custom configs simulate directly as before."""
    label = setup_label(setup)
    base = _route_exp(setup, cfg, workload, cluster_kw)
    # function-local imports keep the core <-> exp import direction
    # acyclic at module load; hoisted above the loop
    from repro.exp import run as _run_exp
    from repro.exp.record import decode_side_j, prefill_side_j
    from repro.exp.runner import count_uncached_sim
    prefill_pts, decode_pts, results = [], [], {}
    for phi in freq_grid:
        if base is not None:
            res = _run_exp(base.with_phi(phi=phi))
        else:
            count_uncached_sim()
            res = make_cluster(setup, cfg, phi=phi, **cluster_kw).run(
                _materialize(workload))
        # each handoff leg is attributed to the stage that runs it,
        # using the routed TransferPath's actual LegCosts (tagged at the
        # call sites): the STORE leg is driven by the prefill side, the
        # FETCH leg occupies the decode engine at admission. One rule,
        # shared with fig5 and the F6 claim check (repro.exp.record).
        prefill_pts.append(ParetoPoint(
            phi=phi, latency_s=res.metrics.median_ttft_s,
            energy_j=prefill_side_j(res.energy.by_stage), label=label))
        decode_pts.append(ParetoPoint(
            phi=phi, latency_s=res.metrics.median_tpot_s,
            energy_j=decode_side_j(res.energy.by_stage), label=label))
        results[phi] = res
    return FrequencySweep(setup=label, prefill_points=prefill_pts,
                          decode_points=decode_pts, results=results)


def sweep_independent(setup: Setup, cfg: ModelConfig,
                      workload: Callable[[], List[Request]],
                      freq_grid: Tuple[float, ...] = DEFAULT_FREQ_GRID,
                      **cluster_kw) -> List[Dict]:
    """True stage-wise independent scaling for disaggregated setups: run
    the workload at every (phi_prefill, phi_decode) pair. This is the
    capability colocated serving cannot express (one clock drives both
    stages) — the paper's Experiment 2 question is whether any pair beats
    the colocated frontier. Returns one record per pair. Works for any
    disaggregated fleet shape: the pair sets every instance of a stage."""
    assert as_fleet_spec(setup).is_disaggregated, \
        "independent scaling needs separate prefill/decode engines"
    base = _route_exp(setup, cfg, workload, cluster_kw)
    from repro.exp import run as _run_exp
    from repro.exp.record import decode_side_j, prefill_side_j
    from repro.exp.runner import count_uncached_sim
    records = []
    for phi_p in freq_grid:
        for phi_d in freq_grid:
            if base is not None:
                res = _run_exp(base.with_phi(phi_prefill=phi_p,
                                             phi_decode=phi_d))
            else:
                count_uncached_sim()
                res = make_cluster(setup, cfg, phi_prefill=phi_p,
                                   phi_decode=phi_d,
                                   **cluster_kw).run(_materialize(workload))
            records.append({
                "phi_prefill": phi_p, "phi_decode": phi_d,
                "ttft_s": res.metrics.median_ttft_s,
                "tpot_s": res.metrics.median_tpot_s,
                "energy_j": (prefill_side_j(res.energy.by_stage)
                             + decode_side_j(res.energy.by_stage)),
                "total_energy_j": res.energy.total_j,
            })
    return records


def best_independent(records: List[Dict],
                     ttft_slo_s: Optional[float] = None,
                     tpot_slo_s: Optional[float] = None) -> Optional[Dict]:
    feasible = [r for r in records
                if (ttft_slo_s is None or r["ttft_s"] <= ttft_slo_s)
                and (tpot_slo_s is None or r["tpot_s"] <= tpot_slo_s)]
    if not feasible:
        return None
    return min(feasible, key=lambda r: r["energy_j"])


def best_total_energy(sweep: FrequencySweep,
                      ttft_slo_s: Optional[float] = None,
                      tpot_slo_s: Optional[float] = None) -> Dict:
    """Minimum total (prefill + decode) energy under optional SLOs.

    Colocated: one phi drives both stages -> choose a single grid point.
    Disaggregated: phi_p and phi_d are independent -> choose the best pair
    (this is the 'independent frequency optimization' the paper tests).
    """
    colocated = sweep.setup.startswith("co")
    best = None
    if colocated:
        for pp, dp in zip(sweep.prefill_points, sweep.decode_points):
            if ttft_slo_s is not None and pp.latency_s > ttft_slo_s:
                continue
            if tpot_slo_s is not None and dp.latency_s > tpot_slo_s:
                continue
            tot = pp.energy_j + dp.energy_j
            if best is None or tot < best["energy_j"]:
                best = {"phi_prefill": pp.phi, "phi_decode": dp.phi,
                        "energy_j": tot, "ttft_s": pp.latency_s,
                        "tpot_s": dp.latency_s}
    else:
        for pp in sweep.prefill_points:
            if ttft_slo_s is not None and pp.latency_s > ttft_slo_s:
                continue
            for dp in sweep.decode_points:
                if tpot_slo_s is not None and dp.latency_s > tpot_slo_s:
                    continue
                tot = pp.energy_j + dp.energy_j
                if best is None or tot < best["energy_j"]:
                    best = {"phi_prefill": pp.phi, "phi_decode": dp.phi,
                            "energy_j": tot, "ttft_s": pp.latency_s,
                            "tpot_s": dp.latency_s}
    return best
