"""DVFS benchmarking (paper Experiment 2 / Fig 5).

Mirrors the paper's methodology: fix the workload, sweep the frequency grid
(applied to every accelerator in the setup), measure median TTFT / median
TPOT and per-stage energy, and build the latency-energy Pareto frontiers.

Stage-wise *independent* frequency selection — disaggregation's unique
capability — is then evaluated by combining the prefill frontier at
phi_p with the decode frontier at phi_d (the engines are physically
separate, so the sweep points compose), and comparing against the best
colocated single-phi point.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Tuple, Union

from repro.configs.base import ModelConfig
from repro.fleet.spec import FleetSpec, as_fleet_spec, setup_label
from .costs import DEFAULT_FREQ_GRID
from .energy import ParetoPoint, min_energy_under_slo, pareto_frontier
from .orchestrator import SetupResult, make_cluster
from .request import Request

Setup = Union[str, FleetSpec]


@dataclass
class FrequencySweep:
    setup: str
    prefill_points: List[ParetoPoint]   # (phi, median TTFT, prefill energy)
    decode_points: List[ParetoPoint]    # (phi, median TPOT, decode energy)
    results: Dict[float, SetupResult]

    def prefill_frontier(self) -> List[ParetoPoint]:
        return pareto_frontier(self.prefill_points)

    def decode_frontier(self) -> List[ParetoPoint]:
        return pareto_frontier(self.decode_points)


def _materialize(workload) -> List[Request]:
    """Accept either a zero-arg factory (the legacy t=0 batches) or any
    object with a ``build()`` method (``repro.workload.WorkloadSpec``);
    each call must yield a FRESH request list (requests are mutated by a
    run), which both forms guarantee."""
    build = getattr(workload, "build", None)
    if callable(build):
        return build()
    return workload()


def sweep_frequencies(setup: Setup, cfg: ModelConfig,
                      workload: Callable[[], List[Request]],
                      freq_grid: Tuple[float, ...] = DEFAULT_FREQ_GRID,
                      **cluster_kw) -> FrequencySweep:
    """Run the fixed workload at each grid frequency (set on ALL
    accelerators, as the paper does) and collect per-stage points.
    ``setup`` is a legacy setup name or any ``FleetSpec``; ``workload``
    is a request-list factory or a ``WorkloadSpec``."""
    label = setup_label(setup)
    prefill_pts, decode_pts, results = [], [], {}
    for phi in freq_grid:
        res = make_cluster(setup, cfg, phi=phi, **cluster_kw).run(
            _materialize(workload))
        e_prefill = res.energy.by_stage.get("prefill", 0.0)
        e_decode = res.energy.by_stage.get("decode", 0.0)
        # each handoff leg is attributed to the stage that runs it,
        # using the routed TransferPath's actual LegCosts (tagged at the
        # call sites): the STORE leg is driven by the prefill side, the
        # FETCH leg occupies the decode engine at admission. The old
        # 50/50 split was arbitrary and visibly wrong for asymmetric
        # media — ici stores device-to-device and fetches for free, disk
        # pays different write/read bandwidths per leg.
        e_store = res.energy.by_stage.get("transfer-store", 0.0)
        e_fetch = res.energy.by_stage.get("transfer-fetch", 0.0)
        prefill_pts.append(ParetoPoint(
            phi=phi, latency_s=res.metrics.median_ttft_s,
            energy_j=e_prefill + e_store, label=label))
        decode_pts.append(ParetoPoint(
            phi=phi, latency_s=res.metrics.median_tpot_s,
            energy_j=e_decode + e_fetch, label=label))
        results[phi] = res
    return FrequencySweep(setup=label, prefill_points=prefill_pts,
                          decode_points=decode_pts, results=results)


def sweep_independent(setup: Setup, cfg: ModelConfig,
                      workload: Callable[[], List[Request]],
                      freq_grid: Tuple[float, ...] = DEFAULT_FREQ_GRID,
                      **cluster_kw) -> List[Dict]:
    """True stage-wise independent scaling for disaggregated setups: run
    the workload at every (phi_prefill, phi_decode) pair. This is the
    capability colocated serving cannot express (one clock drives both
    stages) — the paper's Experiment 2 question is whether any pair beats
    the colocated frontier. Returns one record per pair. Works for any
    disaggregated fleet shape: the pair sets every instance of a stage."""
    assert as_fleet_spec(setup).is_disaggregated, \
        "independent scaling needs separate prefill/decode engines"
    records = []
    for phi_p in freq_grid:
        for phi_d in freq_grid:
            res = make_cluster(setup, cfg, phi_prefill=phi_p,
                               phi_decode=phi_d,
                               **cluster_kw).run(_materialize(workload))
            records.append({
                "phi_prefill": phi_p, "phi_decode": phi_d,
                "ttft_s": res.metrics.median_ttft_s,
                "tpot_s": res.metrics.median_tpot_s,
                "energy_j": (res.energy.by_stage.get("prefill", 0.0)
                             + res.energy.by_stage.get("decode", 0.0)
                             + res.energy.by_stage.get("transfer-store",
                                                       0.0)
                             + res.energy.by_stage.get("transfer-fetch",
                                                       0.0)),
                "total_energy_j": res.energy.total_j,
            })
    return records


def best_independent(records: List[Dict],
                     ttft_slo_s: Optional[float] = None,
                     tpot_slo_s: Optional[float] = None) -> Optional[Dict]:
    feasible = [r for r in records
                if (ttft_slo_s is None or r["ttft_s"] <= ttft_slo_s)
                and (tpot_slo_s is None or r["tpot_s"] <= tpot_slo_s)]
    if not feasible:
        return None
    return min(feasible, key=lambda r: r["energy_j"])


def best_total_energy(sweep: FrequencySweep,
                      ttft_slo_s: Optional[float] = None,
                      tpot_slo_s: Optional[float] = None) -> Dict:
    """Minimum total (prefill + decode) energy under optional SLOs.

    Colocated: one phi drives both stages -> choose a single grid point.
    Disaggregated: phi_p and phi_d are independent -> choose the best pair
    (this is the 'independent frequency optimization' the paper tests).
    """
    colocated = sweep.setup.startswith("co")
    best = None
    if colocated:
        for pp, dp in zip(sweep.prefill_points, sweep.decode_points):
            if ttft_slo_s is not None and pp.latency_s > ttft_slo_s:
                continue
            if tpot_slo_s is not None and dp.latency_s > tpot_slo_s:
                continue
            tot = pp.energy_j + dp.energy_j
            if best is None or tot < best["energy_j"]:
                best = {"phi_prefill": pp.phi, "phi_decode": dp.phi,
                        "energy_j": tot, "ttft_s": pp.latency_s,
                        "tpot_s": dp.latency_s}
    else:
        for pp in sweep.prefill_points:
            if ttft_slo_s is not None and pp.latency_s > ttft_slo_s:
                continue
            for dp in sweep.decode_points:
                if tpot_slo_s is not None and dp.latency_s > tpot_slo_s:
                    continue
                tot = pp.energy_j + dp.energy_j
                if best is None or tot < best["energy_j"]:
                    best = {"phi_prefill": pp.phi, "phi_decode": dp.phi,
                            "energy_j": tot, "ttft_s": pp.latency_s,
                            "tpot_s": dp.latency_s}
    return best
