"""Roofline cost model + DVFS power model for the TPU-target benchmarks.

This container is CPU-only; TPU v5e is the target. Per DESIGN.md section 2,
all TPU-scale step times come from the three-term roofline —

    T(f) = max( T_compute / phi,  T_memory,  T_interconnect ),  phi = f/f_max

— and energy from  P(phi) = P_static + P_dyn * u * phi^3  (V proportional to
f cube law; HBM/ICI clocks are independent domains and do not scale, the
same assumption GPU DVFS studies make for SM-clock-only scaling).

The serving "accelerator" unit is a v5e-4 slice (4 chips): 64 GB HBM is the
natural TPU unit comparable to the paper's A100-40GB per-GPU setup, and we
keep the paper's 28 GB KV pool so the eviction cliff lands at the same
batch size. Documented hardware-adaptation decision (DESIGN.md section 2).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import numpy as np

from repro.configs.base import ModelConfig


# ----------------------------------------------------------------------
# hardware constants (TPU v5e + host, per assignment + public specs)
# ----------------------------------------------------------------------
@dataclass(frozen=True)
class ChipSpec:
    peak_flops: float = 197e12          # bf16 FLOP/s per chip
    hbm_bw: float = 819e9               # B/s per chip
    ici_bw_per_link: float = 50e9       # B/s per ICI link
    ici_links: int = 4
    hbm_gb: float = 16.0
    # power model (200 W-class chip): static + dynamic at full utilization
    p_static_w: float = 65.0
    p_dyn_w: float = 135.0
    # deep-sleep residual draw (rails down, HBM in self-refresh) — what a
    # scale-to-zero fleet pays instead of p_static_w; waking costs the
    # controller-configured wake latency, not extra energy beyond idle draw
    p_sleep_w: float = 5.0


@dataclass(frozen=True)
class HostSpec:
    pcie_bw: float = 16e9               # B/s device<->host (per direction)
    dram_bw: float = 100e9              # B/s host DRAM
    disk_read_bw: float = 3.0e9         # B/s NVMe (page cache bypassed)
    disk_write_bw: float = 2.0e9
    # active/idle power per component (RAPL-style constants, modeled)
    cpu_active_w: float = 150.0
    cpu_idle_w: float = 50.0
    dram_active_w: float = 25.0
    dram_idle_w: float = 8.0
    disk_active_w: float = 12.0
    disk_idle_w: float = 2.0
    # per-byte transfer energy (modeled; pJ/B)
    ici_pj_per_byte: float = 10.0
    pcie_pj_per_byte: float = 60.0
    dram_pj_per_byte: float = 20.0
    disk_nj_per_byte: float = 1.5


@dataclass(frozen=True)
class AcceleratorSpec:
    """One serving 'accelerator' = a v5e slice of ``chips`` chips."""
    chips: int = 4
    chip: ChipSpec = ChipSpec()
    kv_pool_gb: float = 28.0            # paper's per-GPU KV budget

    @property
    def peak_flops(self) -> float:
        return self.chips * self.chip.peak_flops

    @property
    def hbm_bw(self) -> float:
        return self.chips * self.chip.hbm_bw

    @property
    def hbm_gb(self) -> float:
        return self.chips * self.chip.hbm_gb

    @property
    def ici_bw(self) -> float:
        """Slice-to-slice interconnect bandwidth (the dis-ici path)."""
        return self.chip.ici_bw_per_link * self.chip.ici_links

    @property
    def p_static_w(self) -> float:
        return self.chips * self.chip.p_static_w

    @property
    def p_dyn_w(self) -> float:
        return self.chips * self.chip.p_dyn_w

    @property
    def p_sleep_w(self) -> float:
        return self.chips * self.chip.p_sleep_w


# frequency grid mirroring the paper's 0.36..1.26 GHz sweep of a 1.41 GHz
# part: phi = f/f_max in [0.26, 0.90] plus full speed.
DEFAULT_FREQ_GRID: Tuple[float, ...] = (
    0.26, 0.34, 0.42, 0.50, 0.58, 0.66, 0.74, 0.82, 0.90, 1.00)


# ----------------------------------------------------------------------
# model-derived step costs
# ----------------------------------------------------------------------
@dataclass
class StepCost:
    compute_s: float
    memory_s: float
    interconnect_s: float = 0.0

    def time(self, phi: float = 1.0) -> float:
        return max(self.compute_s / phi, self.memory_s, self.interconnect_s)

    def utilization(self, phi: float = 1.0) -> float:
        """Compute-unit busy fraction during the step (drives P_dyn)."""
        t = self.time(phi)
        return 0.0 if t <= 0 else min(1.0, (self.compute_s / phi) / t)


class CostModel:
    """Per-scheduler-step roofline costs for one accelerator."""

    def __init__(self, cfg: ModelConfig, acc: AcceleratorSpec = None,
                 host: HostSpec = None):
        self.cfg = cfg
        self.acc = acc or AcceleratorSpec()
        self.host = host or HostSpec()
        bytes_per_param = 2  # bf16 serving weights
        self.param_bytes_active = cfg.param_count(active_only=True) * \
            bytes_per_param
        self.flops_per_token = 2 * cfg.param_count(active_only=True)
        self.kv_bytes_per_token = cfg.kv_bytes_per_token()
        self.state_bytes = cfg.state_bytes()
        # attention flops per (token, context) pair: qk^T and pv
        hd = cfg.head_dim
        attn_layers = cfg.num_layers
        if cfg.family == "hybrid":
            attn_layers = cfg.num_layers // cfg.hybrid.shared_attn_every
        if cfg.family == "encdec":
            attn_layers = cfg.encdec.num_decoder_layers
        self.attn_flops_per_tok_ctx = (
            0 if cfg.family == "ssm" else 4 * attn_layers * cfg.num_heads * hd)

    # ------------------------------------------------------------------
    def prefill_cost(self, chunk_tokens: int, ctx_begin: int,
                     ctx_end: int) -> StepCost:
        """One prefill chunk of ``chunk_tokens`` tokens spanning absolute
        context [ctx_begin, ctx_end) of its sequence(s)."""
        flops = self.flops_per_token * chunk_tokens
        # causal attention over growing context: sum of ctx over the chunk
        avg_ctx = 0.5 * (ctx_begin + ctx_end)
        flops += self.attn_flops_per_tok_ctx * chunk_tokens * avg_ctx
        # weights stream once per step; KV written for each new token
        bytes_moved = (self.param_bytes_active
                       + self.kv_bytes_per_token * chunk_tokens)
        return StepCost(compute_s=flops / self.acc.peak_flops,
                        memory_s=bytes_moved / self.acc.hbm_bw)

    def prefill_step_cost(self, chunks) -> StepCost:
        """One fused scheduler step over ``chunks`` = [(tokens, c0, c1), ...]
        (vLLM-V1-style token-budget step possibly spanning sequences).
        Weights stream once for the fused step; attention/KV per chunk."""
        flops = 0.0
        kv_bytes = 0.0
        for tokens, c0, c1 in chunks:
            flops += self.flops_per_token * tokens
            flops += self.attn_flops_per_tok_ctx * tokens * 0.5 * (c0 + c1)
            kv_bytes += self.kv_bytes_per_token * tokens
        bytes_moved = self.param_bytes_active + kv_bytes
        return StepCost(compute_s=flops / self.acc.peak_flops,
                        memory_s=bytes_moved / self.acc.hbm_bw)

    def mixed_step_cost(self, chunks, batch: int,
                        total_ctx_tokens: int) -> StepCost:
        """One composed chunked-interleave step (repro.sched): prefill
        ``chunks`` = [(tokens, c0, c1), ...] fused with a decode step
        emitting one token for each of ``batch`` sequences whose
        contexts sum to ``total_ctx_tokens``. Compute adds; HBM traffic
        adds EXCEPT the weight stream, which both halves share — the
        whole point of piggybacking decode on a prefill step (Sarathi):
        the second weight read is subtracted back out."""
        p = self.prefill_step_cost(chunks)
        d = self.decode_cost(batch, total_ctx_tokens)
        dup_weights_s = self.param_bytes_active / self.acc.hbm_bw
        return StepCost(
            compute_s=p.compute_s + d.compute_s,
            memory_s=p.memory_s + d.memory_s - dup_weights_s)

    def decode_cost(self, batch: int, total_ctx_tokens: int) -> StepCost:
        """One decode step emitting 1 token for each of ``batch`` sequences
        whose context lengths sum to ``total_ctx_tokens``."""
        flops = self.flops_per_token * batch
        flops += self.attn_flops_per_tok_ctx * total_ctx_tokens
        bytes_moved = (self.param_bytes_active
                       + self.kv_bytes_per_token * total_ctx_tokens
                       + self.state_bytes * batch  # recurrent-state archs
                       + self.kv_bytes_per_token * batch)  # new-token write
        return StepCost(compute_s=flops / self.acc.peak_flops,
                        memory_s=bytes_moved / self.acc.hbm_bw)

    def decode_step_arrays(self, batch: int, ctx0_sum: int, k: int,
                           phi: float = 1.0
                           ) -> Optional[Tuple[np.ndarray, np.ndarray]]:
        """Per-step ``(dt, watts)`` arrays for ``k`` consecutive decode
        steps of a fixed ``batch`` whose context sum starts at
        ``ctx0_sum`` and grows by ``batch`` each step — the uniform run
        the coalescing fast stepper consumes (DESIGN.md section 13).

        Element ``i`` reproduces the scalar pipeline
        ``decode_cost(batch, ctx0_sum + i*batch)`` -> ``StepCost.time``
        / ``utilization`` -> ``power_w`` bit-for-bit: the flop/byte
        counts are exact integers that convert exactly to float64 below
        2**53, and every float op keeps the scalar expression's
        association. Returns ``None`` when that guarantee would not hold
        (astronomical contexts) so callers fall back to the exact
        stepper rather than drift."""
        ctx_max = ctx0_sum + (k - 1) * batch
        flops_max = self.flops_per_token * batch \
            + self.attn_flops_per_tok_ctx * ctx_max
        bytes_max = (self.param_bytes_active
                     + self.kv_bytes_per_token * ctx_max
                     + self.state_bytes * batch
                     + self.kv_bytes_per_token * batch)
        if max(flops_max, bytes_max) >= 2 ** 53:
            return None
        ctx = ctx0_sum + np.arange(k, dtype=np.int64) * batch
        flops = self.flops_per_token * batch \
            + self.attn_flops_per_tok_ctx * ctx
        bytes_moved = (self.param_bytes_active
                       + self.kv_bytes_per_token * ctx
                       + self.state_bytes * batch
                       + self.kv_bytes_per_token * batch)
        scaled = (flops / self.acc.peak_flops) / phi
        memory_s = bytes_moved / self.acc.hbm_bw
        dt = np.maximum(scaled, memory_s)       # interconnect term is 0
        util = np.minimum(1.0, scaled / dt)
        watts = self.acc.p_static_w + self.acc.p_dyn_w * util * phi ** 3
        return dt, watts

    # ------------------------------------------------------------------
    # first-order per-token rates: the signals online governors and the
    # min-energy router act on (full-precision projections would mean
    # simulating the future; these are roofline steady-states)
    # ------------------------------------------------------------------
    def prefill_rate_tok_s(self, phi: float = 1.0,
                           chunk: int = 8192) -> float:
        """Steady-state prefill throughput at ``phi``: one full
        ``chunk``-token scheduler step amortizing a single weight
        stream, context term at zero (optimistic for long prompts —
        callers carry a safety factor)."""
        c = self.prefill_step_cost([(chunk, 0, chunk)])
        return chunk / c.time(phi)

    def prefill_time_s(self, tokens: int, ctx_begin: int = 0,
                       phi: float = 1.0, chunk: int = 8192) -> float:
        """Latency to prefill ``tokens`` starting at absolute context
        ``ctx_begin``, chunked the way the engine actually schedules it
        (one weight stream per ``chunk``-token step, causal attention
        over the growing context) — the governor's TTFT projection."""
        t = 0.0
        pos = ctx_begin
        end = ctx_begin + tokens
        while pos < end:
            take = min(chunk, end - pos)
            t += self.prefill_step_cost([(take, pos, pos + take)]).time(phi)
            pos += take
        return t

    def joules_per_token(self, phi: float = 1.0, chunk: int = 8192,
                         ctx_tokens: int = 0) -> float:
        """Projected marginal joules per prefill-equivalent token at
        ``phi``: step power (static + utilization-scaled dynamic) over
        the steady-state token rate. Monotone pieces pull opposite ways
        — dynamic J/token grows ~phi^2, static J/token shrinks as 1/phi
        on compute-bound steps — which is exactly the U-curve the
        min-energy router and fig8 trade along."""
        c = self.prefill_step_cost([(chunk, ctx_tokens,
                                     ctx_tokens + chunk)])
        t = c.time(phi)
        return self.power_w(phi, c.utilization(phi)) * t / chunk

    # ------------------------------------------------------------------
    def kv_bytes(self, ctx_tokens: int) -> int:
        """Handoff payload for one sequence at context length ctx."""
        return self.kv_bytes_per_token * ctx_tokens + self.state_bytes

    # ------------------------------------------------------------------
    def power_w(self, phi: float, utilization: float) -> float:
        """Accelerator power at relative frequency phi and compute util."""
        return (self.acc.p_static_w
                + self.acc.p_dyn_w * utilization * phi ** 3)

    def idle_power_w(self) -> float:
        return self.acc.p_static_w

    def sleep_power_w(self) -> float:
        """Deep-sleep residual draw (fleet controller's scale-to-zero)."""
        return self.acc.p_sleep_w

    # ------------------------------------------------------------------
    def slice(self, frac: float) -> "CostModel":
        """An SM-partition slice of this accelerator (RAPID-Serve-style
        intra-GPU P/D disaggregation): compute, HBM bandwidth, and ALL
        power rails (static/dynamic/sleep) scale by ``frac``, so two
        complementary slices sum back to the whole accelerator's
        roofline and power envelope. Model-derived constants
        (``kv_bytes_per_token``, ``param_bytes_active``, ...) are
        cfg-derived and unchanged — KV pages are the same size on a
        slice, which is what lets the two slices share one pool."""
        if not 0.0 < frac <= 1.0:
            raise ValueError(f"slice fraction must be in (0, 1], "
                             f"got {frac}")
        chip = self.acc.chip
        sliced = dataclasses.replace(
            chip,
            peak_flops=chip.peak_flops * frac,
            hbm_bw=chip.hbm_bw * frac,
            p_static_w=chip.p_static_w * frac,
            p_dyn_w=chip.p_dyn_w * frac,
            p_sleep_w=chip.p_sleep_w * frac)
        return CostModel(self.cfg,
                         dataclasses.replace(self.acc, chip=sliced),
                         self.host)
