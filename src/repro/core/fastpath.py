"""Event-coalescing fast stepper for steady-state decode.

The exact discrete-event loop (``FleetCluster._run_loop`` with
``fast=False``) walks one scheduler step per token per engine; at fleet
scale that Python loop is the cold-simulation bottleneck. This module
implements the coalesced alternative: between two "interesting" instants
(the next heap event, or a non-coalescible engine becoming the min-clock
candidate) an engine in *steady-state decode* executes a fully
predetermined run of uniform steps — fixed batch membership, context sum
growing by ``batch`` per step — so its per-step (dt, watts) sequence and
the cumulative folds of its clock, busy time, and joules can be
precomputed once per run (``RunCache``) and consumed as O(1) slices per
window.

Correctness contract (locked by ``tests/test_fastpath_parity.py``): a
fast run is observably identical to the exact stepper — bit-equal
metrics, per-request timestamps, per-component joules, and power-trace
samples. Two narrow exceptions, both verified by the parity harness:

  - ``EnergyMeter.by_stage``: engines advance independently inside a
    window, so the *order* in which their per-step joules fold into the
    shared per-stage accumulator differs from the exact interleave.
    Float addition is commutative but not associative, so per-stage
    totals agree only to ~1e-12 relative (per-component totals fold in
    engine order and stay bit-exact; ``total_j`` sums bit-exact
    per-component values and is therefore bit-exact too).
  - physical KV page ids: bulk growth grants each sequence its run's
    pages contiguously instead of round-robin per step. Pages are
    fungible — counts, LRU order, and pool invariants still match.

Independent advance is sound because coalesced decode steps neither
push heap events nor read another engine's state: all cross-engine
coupling (routing, transfers, admissions) happens in exact steps or
event callbacks, and the window ends before any of those can run.

An engine is coalescible only when every per-step decision the exact
stepper would make is provably a no-op for the whole run
(``fast_decode_eligible`` + ``_build_run``):

  - no real executor (token streams must replay step-by-step),
  - governor absent or ``coalescible`` (StaticGovernor): online
    controllers read live queues every step,
  - nothing schedulable besides the running decode batch (no waiting /
    prefilling / pending_fetch; decode_queue head not admissible),
  - colocated/prefill-role growth for the whole run fits the free pool
    (otherwise preemption semantics apply -> exact stepper),
  - flop/byte counts below 2**53 so int->float64 stays exact.

Everything else — prefill chunking, KV fetch legs, admissions,
preemption churn, online governors — always goes through the unchanged
``Engine.step``. See DESIGN.md section 13.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np


def fast_decode_eligible(e) -> bool:
    """True when ``e``'s next exact step is guaranteed to be a pure
    decode step of its current running batch (see module docstring)."""
    if e.executor is not None:
        return False
    if getattr(e, "kv_store", None) is not None:
        return False               # tiered KV store: exact only (s15)
    if e.pending_fetch or getattr(e, "pending_tier_fetch", None) \
            or e.prefilling or e.waiting or not e.running:
        return False
    gov = e.governor
    if gov is not None and not gov.coalescible:
        return False
    sched = getattr(e, "scheduler", None)
    if sched is not None and not sched.coalescible:
        return False               # chunked/SRPT scheduler: exact (s17)
    if e.decode_queue and e._can_admit_decode(e.decode_queue[0][0]):
        return False               # exact stepper would admit: bail
    return True


class RunCache:
    """One uniform decode run, precomputed: per-step arrays plus the
    cumulative sequential folds of clock (tcum), busy seconds (bcum) and
    this engine's component joules (jcum), all anchored at the engine
    state when the run was built. ``np.cumsum`` accumulates left-to-
    right, so ``tcum[j]`` is bit-equal to j repeated ``t += dt`` — a
    window consumes steps [j0, j1) by slicing, and the cache survives
    across windows (validated against live state on reuse)."""

    __slots__ = ("B", "S0", "k0", "phi", "grow", "j",
                 "watts", "tcum", "jcum", "bcum", "t0s")

    def __init__(self, e, batch, k, grow, dt, watts):
        self.B = len(batch)
        self.S0 = sum(s.ctx for s in batch)
        self.k0 = k
        self.phi = e.phi
        self.grow = grow
        self.j = 0                  # steps already consumed
        self.watts = watts
        vals = watts * dt
        self.tcum = np.cumsum(np.concatenate(((e.t,), dt)))
        self.jcum = np.cumsum(np.concatenate(
            ((e.meter.joules[e.name],), vals)))
        self.bcum = np.cumsum(np.concatenate(((e.busy_s,), dt)))
        self.t0s = self.tcum[:k]    # clock before each step


def _build_run(e) -> Optional[RunCache]:
    """Plan the next uniform run for an eligible engine, or None when
    bit-exact coalescing cannot be guaranteed (caller bails to exact)."""
    batch = e.running
    k = min(s.req.output_len - s.req.generated for s in batch)
    if k <= 0:
        return None
    grow = e.role != "decode"
    if grow:
        pool = e.pool
        need = 0
        for s in batch:
            need += pool.pages_for(s.ctx + k) \
                - len(pool.seqs[s.seq_id].pages)
        if need > pool.free_pages:
            return None             # pool pressure: preemption -> exact
    arrays = e.cost.decode_step_arrays(
        len(batch), sum(s.ctx for s in batch), k, e.phi)
    if arrays is None:
        return None
    rc = RunCache(e, batch, k, grow, *arrays)
    e._fastrun = rc
    return rc


def _get_run(e) -> Optional[RunCache]:
    """Reuse the engine's cached run when its live state still sits
    exactly on the cached trajectory; rebuild otherwise. The key is
    state-derived — batch size, context sum, remaining tokens, phi,
    clock, joules, busy seconds — so any intervening exact step, event
    callback, or retune either matches the cached fold bit-for-bit
    (and may legitimately resume it) or forces a rebuild."""
    rc = e._fastrun
    if rc is not None:
        # O(1) happy path: every mutation of the running batch happens
        # either in _apply (which keeps rc.j in sync) or inside an exact
        # step / event callback that moves the engine clock — so a clock
        # still bit-equal to the cached fold at the cursor, with the same
        # batch size and phi, implies the batch and its context sums are
        # exactly where the cache left them
        if e.t == rc.tcum[rc.j] and e.phi == rc.phi \
                and len(e.running) == rc.B:
            return rc
        batch = e.running
        k = min(s.req.output_len - s.req.generated for s in batch)
        j = rc.k0 - k
        if (rc.j < j < rc.k0 and len(batch) == rc.B and e.phi == rc.phi
                and rc.S0 + j * rc.B == sum(s.ctx for s in batch)
                and e.t == rc.tcum[j]
                and e.meter.joules[e.name] == rc.jcum[j]
                and e.busy_s == rc.bcum[j]):
            # j > rc.j means exact decode steps walked the same
            # trajectory in between (their scalar math is bit-equal);
            # fast-forward the cursor and keep the cache
            rc.j = j
            return rc
        e._fastrun = None
    return _build_run(e)


def _consume(e, rc: RunCache, t_event: Optional[float],
             barrier: Optional[Tuple[float, int]], idx: int) -> int:
    """Advance the engine along its cached run as far as the window
    limits allow; O(1) scalar updates plus one trace extend."""
    t0s = rc.t0s                    # clock before each step
    hi = rc.k0
    if t_event is not None:
        # a step may start only strictly before the next heap event
        # (the exact loop fires an event due at-or-before the clock)
        hi = min(hi, int(np.searchsorted(t0s, t_event, side="left")))
    if barrier is not None:
        bt, bidx = barrier
        # exact tie-break is (clock, engine-list position): at equal
        # clocks the earlier-listed engine steps first
        side = "right" if idx < bidx else "left"
        hi = min(hi, int(np.searchsorted(t0s, bt, side=side)))
    j = rc.j
    n = hi - j
    if n <= 0:
        return 0
    meter = e.meter
    meter.joules[e.name] = float(rc.jcum[hi])
    # shared per-stage accumulator: order across engines is relaxed
    # (module docstring); value matches exact to float commutativity
    meter.by_stage["decode"] += float(rc.jcum[hi] - rc.jcum[j])
    if meter.trace is not None:
        # tcum[i+1] == tcum[i] + dt[i] exactly, so these are the same
        # (t0, t1, watts) samples the exact stepper records one by one;
        # slices of one strictly-increasing cumsum are contiguous by
        # construction, so the trace can skip its run check
        meter.trace.record_run(e.name, rc.tcum[j:hi], rc.tcum[j + 1:hi + 1],
                               rc.watts[j:hi], "decode", contiguous=True)
    e.t = float(rc.tcum[hi])
    e.busy_s = float(rc.bcum[hi])
    e.steps += n
    rc.j = hi
    if e.tracer.enabled:
        # one window-level span carrying the step count, where the
        # exact stepper emits n unit spans back to back — identical
        # after Tracer.coalesced() (the window-span contract, s16)
        e.tracer.span(e.name, "decode", float(rc.tcum[j]), e.t, steps=n)
    return n


def _apply(e, rc: RunCache, n: int) -> None:
    """Per-sequence bookkeeping for the ``n`` steps just consumed:
    context growth, page allocation/touch, finishes. Deferred to window
    boundaries — nothing reads this state mid-window — and the final
    LRU order and page counts match the exact per-step updates."""
    pool = e.pool
    if rc.grow:
        # exact grows 1 token/seq/step in priority order; one bulk
        # allocate per seq yields the same page counts and the same
        # pre-touch LRU order (_build_run verified the run fits)
        for s in sorted(e.running, key=lambda s: s.priority):
            pool.allocate(s.seq_id, n)
    t_end = e.t
    for s in list(e.running):
        s.ctx += n
        s.req.generated += n
        if s.req.generated >= s.req.output_len:
            # only a fully consumed run can finish (k = min remaining)
            s.req.finish_s = t_end
            pool.free_seq(s.seq_id)
            e.running.remove(s)
            if e.tracer.enabled:
                e.tracer.lifecycle("finish", s.req.req_id, t_end,
                                   engine=e.name)
        else:
            pool.touch(s.seq_id)


def _advance_engine(e, idx: int, t_event: Optional[float],
                    barrier: Optional[Tuple[float, int]]) -> int:
    """Chain coalesced runs on one engine up to the window limits."""
    total = 0
    tuned = False
    while True:
        if t_event is not None and e.t >= t_event:
            break
        if barrier is not None and barrier < (e.t, idx):
            break
        if not tuned and e.governor is not None:
            # the exact stepper retunes before every step; a coalescible
            # governor's decision is run-invariant, so once per window —
            # at the same clock the exact first step would use — suffices
            e.governor.on_step(e)
            tuned = True
        rc = _get_run(e)
        if rc is None:
            break
        n = _consume(e, rc, t_event, barrier, idx)
        if n == 0:
            break
        total += n
        _apply(e, rc, n)
        if rc.j < rc.k0:
            break                   # window limit reached mid-run
        e._fastrun = None           # run complete: maybe chain the next
        if not fast_decode_eligible(e):
            break
    return total


# ----------------------------------------------------------------------
def coalesce_window(candidates: List, order: Dict,
                    t_event: Optional[float]) -> int:
    """Advance every coalescible candidate through uniform decode runs
    up to the next interesting time — the heap event at ``t_event`` or
    the instant a non-coalescible engine becomes the min-clock pick.
    Returns the number of engine steps executed (0: nothing was
    coalescible; the caller falls back to one exact step)."""
    fast: List = []
    barrier: Optional[Tuple[float, int]] = None
    for e in candidates:
        if fast_decode_eligible(e):
            fast.append(e)
        else:
            key = (e.t, order[e])
            if barrier is None or key < barrier:
                barrier = key
    executed = 0
    for e in fast:
        executed += _advance_engine(e, order[e], t_event, barrier)
    return executed
