"""Requests, per-request metrics, and the paper's synthetic workload.

The workload mirrors the paper's RandomDataset setup (section IV-D):
fixed input length 16,384, output length 256, batch size swept 2..64,
request rate infinite (all requests submitted at t=0).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import List, Optional

import numpy as np


@dataclass
class SLO:
    ttft_s: Optional[float] = None   # time-to-first-token target
    tpot_s: Optional[float] = None   # time-per-output-token target


@dataclass(eq=False)
class Request:
    req_id: int
    prompt_len: int
    output_len: int
    arrival_s: float = 0.0
    slo: SLO = field(default_factory=SLO)
    # real-mode payload (tiny models in integration tests): actual token ids
    prompt_tokens: Optional[np.ndarray] = None

    # ------------------------------------------------------------------
    # lifecycle bookkeeping (filled in by the engines)
    # ------------------------------------------------------------------
    prefill_start_s: Optional[float] = None
    prefill_done_s: Optional[float] = None
    transfer_done_s: Optional[float] = None
    first_token_s: Optional[float] = None      # first decode-step output
    finish_s: Optional[float] = None
    decode_start_s: Optional[float] = None     # first decode step time
    generated: int = 0
    output_tokens: List[int] = field(default_factory=list)
    # recompute accounting (the paper's eviction cliff mechanism)
    evictions: int = 0
    recomputed_tokens: int = 0
    # KV reuse (paper section II-C): prefill tokens skipped via cache hits
    reused_tokens: int = 0

    # ------------------------------------------------------------------
    @property
    def ttft_s(self) -> Optional[float]:
        if self.first_token_s is None:
            return None
        return self.first_token_s - self.arrival_s

    @property
    def tpot_s(self) -> Optional[float]:
        """Mean inter-token time once decoding has begun (paper's TPOT)."""
        if self.finish_s is None or self.first_token_s is None:
            return None
        n = self.generated
        if n <= 1:
            return 0.0
        return (self.finish_s - self.first_token_s) / (n - 1)

    @property
    def done(self) -> bool:
        return self.finish_s is not None


def random_workload(batch_size: int, *, input_len: int = 16_384,
                    output_len: int = 256, vocab_size: int = 0,
                    seed: int = 0, arrival_s: float = 0.0,
                    shared_prefix_len: int = 0) -> List[Request]:
    """The paper's RandomDataset: ``batch_size`` requests at t=0.

    ``shared_prefix_len`` > 0 gives every request an identical prefix
    (the KV-reuse / RAG scenario of section II-C).
    """
    rng = np.random.default_rng(seed)
    prefix = None
    if shared_prefix_len and vocab_size:
        prefix = rng.integers(0, vocab_size, shared_prefix_len)
    reqs = []
    for i in range(batch_size):
        tokens = None
        if vocab_size:
            tokens = rng.integers(0, vocab_size, input_len)
            if prefix is not None:
                tokens[:shared_prefix_len] = prefix
        reqs.append(Request(req_id=i, prompt_len=input_len,
                            output_len=output_len, arrival_s=arrival_s,
                            prompt_tokens=tokens))
    return reqs


# ----------------------------------------------------------------------
# aggregate metrics over a finished workload
# ----------------------------------------------------------------------
@dataclass
class WorkloadMetrics:
    median_ttft_s: float
    p99_ttft_s: float
    median_tpot_s: float
    p99_tpot_s: float
    prefill_throughput_tok_s: float
    decode_throughput_tok_s: float
    makespan_s: float
    total_evictions: int
    total_recomputed_tokens: int


def summarize(reqs: List[Request]) -> WorkloadMetrics:
    assert all(r.done for r in reqs), "workload not finished"
    ttfts = np.array([r.ttft_s for r in reqs])
    tpots = np.array([r.tpot_s for r in reqs])
    t0 = min(r.arrival_s for r in reqs)
    prefill_end = max(r.prefill_done_s for r in reqs)
    makespan = max(r.finish_s for r in reqs) - t0
    prefill_tokens = sum(r.prompt_len + r.recomputed_tokens
                         - r.reused_tokens for r in reqs)
    decode_tokens = sum(r.generated for r in reqs)
    return WorkloadMetrics(
        median_ttft_s=float(np.median(ttfts)),
        p99_ttft_s=float(np.percentile(ttfts, 99)),
        median_tpot_s=float(np.median(tpots)),
        p99_tpot_s=float(np.percentile(tpots, 99)),
        prefill_throughput_tok_s=prefill_tokens / max(prefill_end - t0, 1e-9),
        decode_throughput_tok_s=decode_tokens / max(makespan, 1e-9),
        makespan_s=float(makespan),
        total_evictions=sum(r.evictions for r in reqs),
        total_recomputed_tokens=sum(r.recomputed_tokens for r in reqs),
    )
