"""Requests, per-request metrics, and the paper's synthetic workload.

``random_workload`` mirrors the paper's RandomDataset setup (section
IV-D): fixed input length 16,384, output length 256, batch size swept
2..64, request rate infinite (all requests submitted at t=0). Finite-
rate open-loop workloads — arrival processes, length mixes, SLO goodput
— live in ``repro.workload`` (DESIGN.md section 9); ``Request.arrival_s``
is honored by the orchestrator event heap, so a request is never served
before it arrives and TTFT is always >= 0.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import List, Optional, Tuple

import numpy as np


@dataclass
class SLO:
    ttft_s: Optional[float] = None   # time-to-first-token target
    tpot_s: Optional[float] = None   # time-per-output-token target


@dataclass(eq=False)
class Request:
    req_id: int
    prompt_len: int
    output_len: int
    arrival_s: float = 0.0
    slo: SLO = field(default_factory=SLO)
    # real-mode payload (tiny models in integration tests): actual token ids
    prompt_tokens: Optional[np.ndarray] = None

    # ------------------------------------------------------------------
    # lifecycle bookkeeping (filled in by the engines)
    # ------------------------------------------------------------------
    prefill_start_s: Optional[float] = None
    prefill_done_s: Optional[float] = None
    transfer_done_s: Optional[float] = None
    first_token_s: Optional[float] = None      # first decode-step output
    finish_s: Optional[float] = None
    decode_start_s: Optional[float] = None     # first decode step time
    generated: int = 0
    output_tokens: List[int] = field(default_factory=list)
    # recompute accounting (the paper's eviction cliff mechanism)
    evictions: int = 0
    recomputed_tokens: int = 0
    # KV reuse (paper section II-C): prefill tokens skipped via cache hits
    reused_tokens: int = 0

    # ------------------------------------------------------------------
    @property
    def ttft_s(self) -> Optional[float]:
        if self.first_token_s is None:
            return None
        return self.first_token_s - self.arrival_s

    @property
    def tpot_s(self) -> Optional[float]:
        """Mean inter-token time once decoding has begun (paper's TPOT).

        ``None`` when fewer than two tokens were generated: a
        single-token request has no inter-token interval, and a 0.0
        placeholder would drag median/p99 TPOT toward zero (``summarize``
        excludes these requests from the TPOT percentiles)."""
        if self.finish_s is None or self.first_token_s is None:
            return None
        n = self.generated
        if n <= 1:
            return None
        return (self.finish_s - self.first_token_s) / (n - 1)

    @property
    def queue_s(self) -> Optional[float]:
        """Arrival -> first prefill scheduling (open-loop queueing delay)."""
        if self.prefill_start_s is None:
            return None
        return self.prefill_start_s - self.arrival_s

    @property
    def done(self) -> bool:
        return self.finish_s is not None


def meets_slo(req: Request, slo: Optional[SLO] = None) -> bool:
    """DistServe-style attainment: BOTH targets must hold (a request with
    no decode phase — ``tpot_s is None`` — is judged on TTFT alone).
    ``slo`` overrides the request's own SLO; absent targets pass."""
    s = slo if slo is not None else req.slo
    if s is None:
        return True
    if s.ttft_s is not None:
        if req.ttft_s is None or req.ttft_s > s.ttft_s:
            return False
    if s.tpot_s is not None and req.tpot_s is not None \
            and req.tpot_s > s.tpot_s:
        return False
    return True


def goodput_stats(reqs: List["Request"],
                  slo: Optional[SLO] = None) -> Tuple[int, float, float]:
    """The single source of the goodput arithmetic, shared by
    ``summarize`` and ``repro.workload.goodput.evaluate``:
    (attained count, duration first-arrival->last-finish, observed
    offered rate — inf for a t=0 batch)."""
    attained = sum(1 for r in reqs if meets_slo(r, slo))
    t0 = min(r.arrival_s for r in reqs)
    duration = max(r.finish_s for r in reqs) - t0
    span = max(r.arrival_s for r in reqs) - t0
    offered = (len(reqs) - 1) / span if span > 0 else float("inf")
    return attained, duration, offered


def random_workload(batch_size: int, *, input_len: int = 16_384,
                    output_len: int = 256, vocab_size: int = 0,
                    seed: int = 0, arrival_s: float = 0.0,
                    shared_prefix_len: int = 0) -> List[Request]:
    """The paper's RandomDataset: ``batch_size`` requests at t=0.

    ``shared_prefix_len`` > 0 gives every request an identical prefix
    (the KV-reuse / RAG scenario of section II-C).
    """
    rng = np.random.default_rng(seed)
    prefix = None
    if shared_prefix_len and vocab_size:
        prefix = rng.integers(0, vocab_size, shared_prefix_len)
    reqs = []
    for i in range(batch_size):
        tokens = None
        if vocab_size:
            tokens = rng.integers(0, vocab_size, input_len)
            if prefix is not None:
                tokens[:shared_prefix_len] = prefix
        reqs.append(Request(req_id=i, prompt_len=input_len,
                            output_len=output_len, arrival_s=arrival_s,
                            prompt_tokens=tokens))
    return reqs


# ----------------------------------------------------------------------
# aggregate metrics over a finished workload
# ----------------------------------------------------------------------
@dataclass
class WorkloadMetrics:
    median_ttft_s: float
    p99_ttft_s: float
    median_tpot_s: float
    p99_tpot_s: float
    prefill_throughput_tok_s: float
    decode_throughput_tok_s: float
    makespan_s: float
    total_evictions: int
    total_recomputed_tokens: int
    # prefill tokens skipped via KV-reuse cache hits (section II-C)
    total_reused_tokens: int = 0
    # open-loop / goodput view (DESIGN.md section 9)
    num_requests: int = 0
    offered_rps: float = float("inf")   # observed arrival rate; inf at t=0
    median_queue_s: float = 0.0         # arrival -> prefill scheduling
    slo_attainment: float = 1.0         # fraction meeting their own SLO
    goodput_rps: float = 0.0            # attained requests / makespan


def summarize(reqs: List[Request]) -> WorkloadMetrics:
    assert all(r.done for r in reqs), "workload not finished"
    ttfts = np.array([r.ttft_s for r in reqs], dtype=np.float64)
    # single-token requests have no inter-token interval: excluded
    tpots = np.array([r.tpot_s for r in reqs if r.tpot_s is not None],
                     dtype=np.float64)
    queues = np.array([r.queue_s for r in reqs if r.queue_s is not None],
                      dtype=np.float64)
    t0 = min(r.arrival_s for r in reqs)
    prefill_end = max(r.prefill_done_s for r in reqs)
    prefill_tokens = sum(r.prompt_len + r.recomputed_tokens
                         - r.reused_tokens for r in reqs)
    decode_tokens = sum(r.generated for r in reqs)
    # goodput_stats' duration IS the makespan (first arrival->last finish)
    attained, makespan, offered = goodput_stats(reqs)
    return WorkloadMetrics(
        median_ttft_s=float(np.median(ttfts)),
        p99_ttft_s=float(np.percentile(ttfts, 99)),
        median_tpot_s=float(np.median(tpots)) if tpots.size else 0.0,
        p99_tpot_s=float(np.percentile(tpots, 99)) if tpots.size else 0.0,
        prefill_throughput_tok_s=prefill_tokens / max(prefill_end - t0, 1e-9),
        decode_throughput_tok_s=decode_tokens / max(makespan, 1e-9),
        makespan_s=float(makespan),
        total_evictions=sum(r.evictions for r in reqs),
        total_recomputed_tokens=sum(r.recomputed_tokens for r in reqs),
        total_reused_tokens=sum(r.reused_tokens for r in reqs),
        num_requests=len(reqs),
        offered_rps=offered,
        median_queue_s=float(np.median(queues)) if queues.size else 0.0,
        slo_attainment=attained / len(reqs),
        goodput_rps=attained / max(makespan, 1e-9),
    )
