"""The paper's subject: disaggregated LLM serving — engines, paged KV pool,
KV transfer paths, DVFS energy model, and the co/dis experiment setups."""
from . import (costs, dvfs, energy, engine, kvcache, orchestrator,
               prefix_cache, request, transfer)
from .costs import AcceleratorSpec, ChipSpec, CostModel, HostSpec, \
    DEFAULT_FREQ_GRID
from .energy import EnergyMeter, ParetoPoint, pareto_frontier, \
    min_energy_under_slo, sweet_spot
from .engine import Engine, RealExecutor
from .kvcache import DevicePagedKV, OutOfPages, PagedKVPool
from .orchestrator import SETUPS, Cluster, SetupResult, make_cluster, \
    run_setup
from .prefix_cache import PrefixCache, ReuseResult
from .request import Request, SLO, WorkloadMetrics, meets_slo, \
    random_workload, summarize
from .transfer import DiskPath, HostPath, ICIPath, TransferPath, make_path
from .dvfs import FrequencySweep, best_total_energy, sweep_frequencies

__all__ = [
    "AcceleratorSpec", "ChipSpec", "CostModel", "HostSpec",
    "DEFAULT_FREQ_GRID", "EnergyMeter", "ParetoPoint", "pareto_frontier",
    "min_energy_under_slo", "sweet_spot", "Engine", "RealExecutor",
    "DevicePagedKV", "OutOfPages", "PagedKVPool", "SETUPS", "Cluster",
    "SetupResult", "run_setup", "make_cluster", "PrefixCache",
    "ReuseResult", "Request",
    "SLO", "WorkloadMetrics", "meets_slo", "random_workload", "summarize",
    "DiskPath",
    "HostPath", "ICIPath", "TransferPath", "make_path",
    "FrequencySweep", "best_total_energy", "sweep_frequencies",
]
