"""Multi-tier KV store: HBM -> DRAM -> disk, every movement priced.

DESIGN.md section 15. The flat ``PrefixCache`` (core/prefix_cache.py)
answers "which prefill tokens can this request skip?" but models a
single bottomless-ish pool: pages are either cached or gone. Production
KV offload (NVIDIA Dynamo, LMCache, MoonCake) instead keeps hot pages in
accelerator HBM and spills colder ones down a memory hierarchy — reuse
then costs real tier traffic, priced by the same PCIe/DRAM/NVMe media
the paper's transfer study already models (``core/transfer.py``).

``TieredKVStore`` is that hierarchy at page granularity:

  * Three tiers in fixed order — ``hbm``, ``dram``, ``disk`` — each an
    LRU list with a page-count capacity from ``TierSpec``. A tier with
    capacity 0 is disabled; overflow past the last enabled tier drops
    pages (a free eviction, like the flat cache's LRU popitem).
  * Global-recency inclusion: an access promotes the page to HBM MRU;
    an HBM overflow demotes the HBM-LRU page to DRAM's MRU end (it is
    still hotter than everything already in DRAM), DRAM overflow
    demotes to disk the same way. The concatenation hbm+dram+disk is
    therefore the global LRU order, so the resident set under a larger
    total budget is a superset of the smaller one — hit rate is
    monotone in capacity (tests/test_kvstore.py locks this).
  * **Pins**: pages matched by a lookup are pinned until the consuming
    sequence finishes its prefill (the engine calls ``release``);
    pinned pages are skipped by eviction — demoting KV that a running
    prefill is actively reading would be a use-after-free. A tier may
    transiently exceed capacity when every resident page is pinned.
  * **Pricing**: a demand fetch from DRAM/disk is one batched
    ``fetch_cost`` leg per source tier (stage ``tier-fetch`` — it
    occupies the engine and delays the prefill, landing in TTFT and the
    PowerTrace); a demotion is a ``store_cost`` leg per page (stage
    ``tier-spill`` — asynchronous DMA energy, metered without engine
    occupancy). Every movement is also appended to ``events``, the
    ledger the invariant tests reconcile against the meter.
  * Optional **prefetch**: a demand fetch from a tier drags along up to
    ``prefetch_pages`` of that tier's hottest remaining pages in the
    same batched leg — read-ahead for the sequential consumers a shared
    prefix implies.

Page keys come from ``core.prefix_cache``'s stable blake2b digests:
chain hashes in ``prefix`` mode (position-dependent, longest-prefix
match) and content hashes in ``pic`` mode (position-independent, with
CacheBlend-style ``recompute_frac`` repair) — so a store's residency is
comparable across processes and across engines.

``ReuseSpec`` lives here (re-exported by ``repro.exp`` for backward
compatibility) and gains the optional ``tiers`` field; its ``encode()``
omits ``tiers`` when None so every pre-PR experiment cache hash
survives unchanged.
"""
from __future__ import annotations

import collections
from dataclasses import dataclass, fields
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from typing import TYPE_CHECKING

if TYPE_CHECKING:  # typing only — see the runtime imports below
    from repro.core.transfer import LegCost

# repro.core imports live inside the functions that need them:
# core.__init__ transitively imports fleet.cluster, which imports this
# module — a top-level import here would make ``import repro.kvstore``
# as the first repro import blow up on the half-initialized cycle.

TIER_ORDER = ("hbm", "dram", "disk")
REUSE_MODES = ("prefix", "pic")


def _encode_dc(obj) -> dict:
    """Dataclass -> plain dict with tuples as lists (json-canonical)."""
    out = {}
    for f in fields(obj):
        v = getattr(obj, f.name)
        out[f.name] = list(v) if isinstance(v, tuple) else v
    return out


# ----------------------------------------------------------------------
# specs
# ----------------------------------------------------------------------
@dataclass(frozen=True)
class TierSpec:
    """Per-tier page budgets. ``disk_pages=0`` disables the disk tier
    (DRAM overflow drops); ``prefetch_pages`` is read-ahead per demand
    fetch (0 = demand-only)."""
    hbm_pages: int = 1024
    dram_pages: int = 4096
    disk_pages: int = 0
    prefetch_pages: int = 0

    def __post_init__(self):
        assert self.hbm_pages >= 1, "HBM tier cannot be empty"
        assert self.dram_pages >= 0 and self.disk_pages >= 0
        assert self.prefetch_pages >= 0

    @property
    def total_pages(self) -> int:
        return self.hbm_pages + self.dram_pages + self.disk_pages

    def capacity(self, tier: str) -> int:
        return {"hbm": self.hbm_pages, "dram": self.dram_pages,
                "disk": self.disk_pages}[tier]

    def encode(self) -> dict:
        return _encode_dc(self)


def as_tier_spec(t) -> Optional["TierSpec"]:
    if t is None or isinstance(t, TierSpec):
        return t
    if isinstance(t, dict):
        return TierSpec(**t)
    raise TypeError(f"cannot interpret {t!r} as a TierSpec")


@dataclass(frozen=True)
class ReuseSpec:
    """KV reuse configuration (paper section II-C + tiered extension).

    ``tiers is None`` -> the flat shared ``PrefixCache`` (pre-PR
    behavior, fast-stepper safe). ``tiers`` set -> one ``TieredKVStore``
    per engine with priced cross-tier traffic (the fast stepper bails
    to exact, DESIGN.md section 15). ``capacity_pages`` only applies to
    the flat cache; the tiered store's budget IS the TierSpec.
    """
    mode: str = "prefix"          # "prefix" | "pic"
    capacity_pages: int = 200_000
    page_size: int = 16
    recompute_frac: float = 0.15
    warm: bool = True             # pre-insert request 0's prompt
    tiers: Optional[TierSpec] = None

    def __post_init__(self):
        assert self.mode in REUSE_MODES, self.mode
        object.__setattr__(self, "tiers", as_tier_spec(self.tiers))

    def encode(self) -> dict:
        d = _encode_dc(self)
        if self.tiers is None:
            d.pop("tiers")        # pre-PR hashes must survive
        else:
            d["tiers"] = self.tiers.encode()
        return d


def as_reuse_spec(r) -> Optional["ReuseSpec"]:
    """None | ReuseSpec | mode string | dict (tiers as nested dict ok)."""
    if r is None or isinstance(r, ReuseSpec):
        return r
    if isinstance(r, str):
        return ReuseSpec(mode=r)
    if isinstance(r, dict):
        return ReuseSpec(**r)     # __post_init__ normalizes tiers
    raise TypeError(f"cannot interpret {r!r} as a ReuseSpec")


# ----------------------------------------------------------------------
# the store
# ----------------------------------------------------------------------
@dataclass
class TierLookup:
    """Result of ``TieredKVStore.lookup``: the reuse arithmetic (same
    fields/semantics as ``prefix_cache.ReuseResult``) plus the priced
    legs the engine must meter and the pins it must later release."""
    matched_tokens: int
    recompute_tokens: int
    mode: str                     # "prefix" | "pic" | "none"
    fetch_legs: List[LegCost]     # one batched leg per source tier
    spill_legs: List[LegCost]     # demotions displaced by the promotion
    pins: Tuple[int, ...]         # page keys held until release()

    def saved_tokens(self, total: int) -> int:
        return total - self.recompute_tokens


class TieredKVStore:
    """Per-engine HBM->DRAM->disk page store with LRU-with-pin eviction.

    Not thread-safe and not shared: each engine owns one (what KV an
    engine "holds" is exactly what the prefix-affinity router scores).
    """

    def __init__(self, tiers: TierSpec, *, mode: str = "prefix",
                 page_size: int = 16, recompute_frac: float = 0.15,
                 page_bytes: int, host=None):
        assert mode in REUSE_MODES, mode
        self.spec = as_tier_spec(tiers)
        self.mode = mode
        self.page_size = page_size
        self.recompute_frac = recompute_frac
        self.page_bytes = int(page_bytes)
        assert self.page_bytes > 0
        # DRAM sits behind the host-staging path, disk behind NVMe —
        # the exact media the paper's transfer study prices
        from repro.core.transfer import DiskPath, HostPath
        self._paths = {"dram": HostPath(host), "disk": DiskPath(host)}
        # key -> None, LRU order (popitem(last=False) side is coldest)
        self._tier: Dict[str, "collections.OrderedDict[int, None]"] = {
            t: collections.OrderedDict() for t in TIER_ORDER}
        self._pins: Dict[int, int] = {}   # key -> pin count
        self.hits = 0
        self.misses = 0
        # movement ledger: every insert/promote/fetch/spill/drop,
        # reconciled against the EnergyMeter by tests/test_kvstore.py
        self.events: List[dict] = []
        # observability (repro.obs): the owning engine installs its
        # tracer and stamps `now` with its clock before lookup/insert,
        # so tier movements land as instants on the "tier" track
        from repro.obs.trace import NULL_TRACER
        self.tracer = NULL_TRACER
        self.now = 0.0

    # -- residency ------------------------------------------------------
    def _where(self, key: int) -> Optional[str]:
        for t in TIER_ORDER:
            if key in self._tier[t]:
                return t
        return None

    def resident_pages(self) -> int:
        return sum(len(d) for d in self._tier.values())

    def _keys(self, tokens: Sequence[int]) -> List[int]:
        from repro.core.prefix_cache import PrefixCache, _page_hash
        arr = np.asarray(tokens, dtype=np.int64)
        n_full = len(arr) // self.page_size
        pages = [arr[i * self.page_size:(i + 1) * self.page_size]
                 for i in range(n_full)]
        if self.mode == "prefix":
            keys, chain = [], 0
            for p in pages:
                chain = PrefixCache._chain(chain, p)
                keys.append(chain)
            return keys
        return [_page_hash(p) for p in pages]

    # -- ledger ---------------------------------------------------------
    def _event(self, op: str, src: Optional[str], dst: Optional[str],
               pages: int, leg: Optional[LegCost] = None) -> None:
        self.events.append({
            "op": op, "src": src, "dst": dst, "pages": pages,
            "nbytes": pages * self.page_bytes,
            "latency_s": leg.latency_s if leg else 0.0,
            "energy_j": dict(leg.energy_j) if leg else {}})
        if self.tracer.enabled:
            self.tracer.instant("tier", op, self.now,
                                src=src or "", dst=dst or "",
                                pages=pages)

    def ledger_energy_j(self, ops: Sequence[str] = ("fetch", "spill"),
                        ) -> Dict[str, float]:
        out: Dict[str, float] = collections.defaultdict(float)
        for ev in self.events:
            if ev["op"] in ops:
                for c, j in ev["energy_j"].items():
                    out[c] += j
        return dict(out)

    # -- eviction -------------------------------------------------------
    def _demote_one(self, tier: str, spill_legs: List[LegCost]) -> bool:
        """Demote this tier's LRU unpinned page one level down (or drop
        it past the last enabled tier). False when every resident page
        is pinned — the tier then transiently exceeds capacity rather
        than evict KV a running prefill is reading."""
        victim = next((k for k in self._tier[tier]
                       if not self._pins.get(k)), None)
        if victim is None:
            return False
        del self._tier[tier][victim]
        dst = next((t for t in TIER_ORDER[TIER_ORDER.index(tier) + 1:]
                    if self.spec.capacity(t) > 0), None)
        if dst is None:
            self._event("drop", tier, None, 1)
            return True
        # the demoted page is hotter than everything already in dst
        # (it was resident one tier up), so it lands at dst's MRU end —
        # preserving the global-recency inclusion property
        leg = self._paths[dst].store_cost(self.page_bytes)
        self._tier[dst][victim] = None
        self._event("spill", tier, dst, 1, leg)
        spill_legs.append(leg)
        return True

    def _enforce(self, spill_legs: List[LegCost]) -> None:
        for t in TIER_ORDER:
            cap = self.spec.capacity(t)
            while len(self._tier[t]) > cap:
                if not self._demote_one(t, spill_legs):
                    break

    # -- pins -----------------------------------------------------------
    def pin(self, keys: Sequence[int]) -> None:
        for k in keys:
            self._pins[k] = self._pins.get(k, 0) + 1

    def release(self, keys: Sequence[int]) -> List[LegCost]:
        """Drop pins, then re-enforce capacities: a tier that ran over
        budget while fully pinned demotes its overflow the moment the
        pins come off — returned as priced spill legs for the caller to
        meter (the invariant "over capacity => nothing evictable" must
        hold at every quiescent point, not just after inserts)."""
        for k in keys:
            c = self._pins.get(k, 0)
            if c <= 1:
                self._pins.pop(k, None)
            else:
                self._pins[k] = c - 1
        spill: List[LegCost] = []
        self._enforce(spill)
        return spill

    # -- operations -----------------------------------------------------
    def insert(self, tokens: Sequence[int]) -> List[LegCost]:
        """Store every full page of ``tokens`` at HBM MRU; returns the
        priced spill legs for demotions the overflow forced. Pages the
        engine just (re)computed are born in HBM for free; pages found
        in a lower tier are promoted without a fetch leg — their KV was
        just recomputed/repaired in HBM by the prefill that triggered
        this insert, so no tier read occurred."""
        spill: List[LegCost] = []
        n = 0
        promoted = {"dram": 0, "disk": 0}
        for key in self._keys(tokens):
            t = self._where(key)
            if t is not None and t != "hbm":
                del self._tier[t][key]
                promoted[t] += 1
            self._tier["hbm"][key] = None
            self._tier["hbm"].move_to_end(key)
            n += 1
        self._event("insert", None, "hbm", n)
        for src, k in promoted.items():
            if k:
                # free promotion (no leg), but still ledgered: the
                # conservation audit tracks every page leaving a tier
                self._event("promote", src, "hbm", k)
        self._enforce(spill)
        return spill

    def lookup(self, tokens: Sequence[int]) -> TierLookup:
        """Match, promote to HBM, pin. Demand fetches are batched into
        one ``fetch_cost`` leg per source tier (plus read-ahead when
        ``prefetch_pages > 0``); promotions may displace HBM pages,
        priced as spill legs. The caller owns metering both and calling
        ``release(result.pins)`` when its prefill completes."""
        keys = self._keys(tokens)
        total = len(tokens)
        if self.mode == "prefix":
            matched_keys: List[int] = []
            for key in keys:
                if self._where(key) is None:
                    break
                matched_keys.append(key)
        else:
            matched_keys = [k for k in keys if self._where(k) is not None]

        by_src = {"dram": 0, "disk": 0}
        for key in matched_keys:
            src = self._where(key)
            if src != "hbm":
                del self._tier[src][key]
                by_src[src] += 1
            self._tier["hbm"][key] = None
            self._tier["hbm"].move_to_end(key)
        self.pin(matched_keys)

        fetch_legs: List[LegCost] = []
        for src in ("dram", "disk"):
            demand = by_src[src]
            if demand == 0:
                continue
            # read-ahead: drag the source tier's hottest unpinned
            # leftovers along in the same batched leg
            ahead = 0
            for _ in range(self.spec.prefetch_pages):
                extra = next((k for k in reversed(self._tier[src])
                              if not self._pins.get(k)), None)
                if extra is None:
                    break
                del self._tier[src][extra]
                self._tier["hbm"][extra] = None
                self._tier["hbm"].move_to_end(extra)
                ahead += 1
            pages = demand + ahead
            leg = self._paths[src].fetch_cost(pages * self.page_bytes)
            self._event("fetch", src, "hbm", pages, leg)
            fetch_legs.append(leg)

        spill_legs: List[LegCost] = []
        self._enforce(spill_legs)

        matched = len(matched_keys) * self.page_size
        if matched:
            self.hits += 1
        else:
            self.misses += 1
        if self.mode == "pic":
            repair = int(np.ceil(matched * self.recompute_frac))
            return TierLookup(matched, total - matched + repair,
                              "pic" if matched else "none",
                              fetch_legs, spill_legs,
                              tuple(matched_keys))
        return TierLookup(matched, total - matched,
                          "prefix" if matched else "none",
                          fetch_legs, spill_legs, tuple(matched_keys))

    def peek_match(self, tokens: Sequence[int]) -> int:
        """Matched tokens a ``lookup`` would report — no promotion, no
        pins, no LRU touch, no counters (router probes must be free)."""
        keys = self._keys(tokens)
        if self.mode == "prefix":
            n = 0
            for key in keys:
                if self._where(key) is None:
                    break
                n += 1
        else:
            n = sum(1 for k in keys if self._where(k) is not None)
        return n * self.page_size

    # -- invariants (tests/test_kvstore.py) -----------------------------
    def check_invariants(self) -> None:
        seen: set = set()
        for t in TIER_ORDER:
            keys = set(self._tier[t])
            leaked = seen & keys
            assert not leaked, f"pages resident in two tiers: {leaked}"
            seen |= keys
            cap = self.spec.capacity(t)
            if len(keys) > cap:
                unpinned = [k for k in keys if not self._pins.get(k)]
                assert not unpinned, \
                    (f"{t} over capacity ({len(keys)} > {cap}) with "
                     f"unpinned evictable pages {unpinned[:4]}")
        for k, c in self._pins.items():
            assert c > 0, f"non-positive pin count for {k}"
            assert k in seen, f"pinned page {k} is not resident"


__all__ = ["TIER_ORDER", "REUSE_MODES", "TierSpec", "ReuseSpec",
           "TierLookup", "TieredKVStore", "as_tier_spec",
           "as_reuse_spec"]
