import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
"""Multi-pod dry-run: prove the distribution config is coherent.

For every (architecture x input shape) cell, lower + compile the
appropriate step (train_step / prefill / serve_step) on the production
meshes — single-pod (16 data x 16 model = 256 chips) and multi-pod
(2 pod x 16 x 16 = 512 chips) — and report memory_analysis (fits?) +
cost_analysis (FLOPs/bytes for the roofline).

The XLA_FLAGS line above MUST run before any jax import: jax locks the
device count at first init. Do not move it; do not set it globally.

Cost-number methodology (DESIGN.md section 2): XLA counts a while-loop
body once, so the full-size compile (rolled scan; fast, and the actual
compile/memory proof) cannot give whole-model FLOPs. Roofline terms come
from two small FULLY-UNROLLED lowerings at 1 and 2 layer-periods and exact
linear extrapolation (layer stacks are homogeneous, so cost(L) = a + b*L).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-34b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both \
      --out dryrun_results.json
"""
import argparse
import dataclasses
import json
import sys
import time
import traceback
from typing import Dict, Optional, Tuple

import jax

from repro.configs import (ALL_SHAPES, ASSIGNED_ARCHS, SHAPES, applicable,
                           get_config, skip_reason)
from repro.configs.base import ModelConfig
from repro.dist.hlo_analysis import (RooflineTerms, collective_stats,
                                     cost_numbers, linear_extrapolate,
                                     model_flops, structural_memory_floor,
                                     vmem_resident_traffic)
from repro.launch.mesh import make_production_mesh
from repro.models import layers as model_layers
from repro.serve.steps import build_step


# ----------------------------------------------------------------------
def with_periods(cfg: ModelConfig, n: int) -> ModelConfig:
    """Same arch at n layer-periods (for the unrolled cost lowerings)."""
    if cfg.family == "hybrid":
        return cfg.replace(num_layers=n * cfg.hybrid.shared_attn_every)
    if cfg.family == "encdec":
        return cfg.replace(
            num_layers=n,
            encdec=dataclasses.replace(cfg.encdec, num_encoder_layers=n,
                                       num_decoder_layers=n))
    if cfg.family == "moe":
        return cfg.replace(num_layers=cfg.moe.first_k_dense + n)
    return cfg.replace(num_layers=n)


def full_periods(cfg: ModelConfig) -> int:
    if cfg.family == "hybrid":
        return cfg.num_layers // cfg.hybrid.shared_attn_every
    if cfg.family == "encdec":
        return cfg.encdec.num_decoder_layers
    if cfg.family == "moe":
        return cfg.num_layers - cfg.moe.first_k_dense
    return cfg.num_layers


def _lower_compile(cfg, shape, mesh, unroll) -> Tuple:
    model_layers.set_scan_unroll(unroll)
    try:
        with mesh:
            bundle = build_step(shape.kind, cfg, mesh, shape)
            lowered = bundle.fn.lower(*bundle.abstract_args)
            compiled = lowered.compile()
        return lowered, compiled
    finally:
        model_layers.set_scan_unroll(1)


# ----------------------------------------------------------------------
def run_cell(arch: str, shape_name: str, multi_pod: bool,
             verbose: bool = True, analyze: bool = True) -> Dict:
    """Lower + compile one (arch, shape, mesh) cell; returns the record."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n_chips = 512 if multi_pod else 256
    rec: Dict = {"arch": arch, "shape": shape_name,
                 "mesh": "2x16x16" if multi_pod else "16x16",
                 "kind": shape.kind}
    if not applicable(cfg, shape):
        rec["status"] = "skip"
        rec["reason"] = skip_reason(cfg, shape)
        return rec
    try:
        # --- 1) full-size rolled compile: THE dry-run proof -----------
        t0 = time.time()
        mesh = make_production_mesh(multi_pod=multi_pod)
        lowered, compiled = _lower_compile(cfg, shape, mesh, unroll=1)
        mem = compiled.memory_analysis()
        hlo = compiled.as_text()
        coll_rolled = collective_stats(hlo)
        rec.update({
            "status": "ok",
            "compile_s": round(time.time() - t0, 1),
            "argument_bytes": int(mem.argument_size_in_bytes),
            "output_bytes": int(mem.output_size_in_bytes),
            "temp_bytes": int(mem.temp_size_in_bytes),
            "collectives_rolled": {
                "bytes_by_kind": coll_rolled.bytes_by_kind,
                "count_by_kind": coll_rolled.count_by_kind,
            },
        })

        # --- 2) roofline terms via small unrolled lowerings -----------
        if analyze:
            t1 = time.time()
            n_full = full_periods(cfg)
            n1, n2 = 1, 2
            vals = {}
            for n in (n1, n2):
                c_small = with_periods(cfg, n)
                _, comp = _lower_compile(c_small, shape, mesh, unroll=True)
                fl, by = cost_numbers(comp)
                cb = collective_stats(comp.as_text()).total_bytes
                vals[n] = (fl, by, cb)
            flops = linear_extrapolate(vals[n1][0], vals[n2][0], n1, n2,
                                       n_full)
            hbm = linear_extrapolate(vals[n1][1], vals[n2][1], n1, n2,
                                     n_full)
            coll = linear_extrapolate(vals[n1][2], vals[n2][2], n1, n2,
                                      n_full)
            terms = RooflineTerms(
                flops=flops, hbm_bytes=hbm, collective_bytes=coll,
                n_chips=n_chips,
                model_flops=model_flops(cfg, shape, n_chips),
                vmem_resident_bytes=vmem_resident_traffic(cfg, shape,
                                                          n_chips),
                memory_floor_bytes=structural_memory_floor(cfg, shape,
                                                           n_chips))
            rec["roofline"] = terms.as_dict()
            rec["analyze_s"] = round(time.time() - t1, 1)
    except Exception as e:   # a failure here is a sharding bug — report it
        rec["status"] = "fail"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    if verbose:
        _print_rec(rec)
    return rec


def _print_rec(rec: Dict) -> None:
    if rec["status"] == "skip":
        print(f"[SKIP] {rec['arch']:22s} {rec['shape']:12s} {rec['mesh']:8s}"
              f" -- {rec['reason'][:60]}", flush=True)
        return
    if rec["status"] == "fail":
        print(f"[FAIL] {rec['arch']:22s} {rec['shape']:12s} {rec['mesh']:8s}"
              f" -- {rec['error'][:120]}", flush=True)
        return
    msg = (f"[ OK ] {rec['arch']:22s} {rec['shape']:12s} {rec['mesh']:8s} "
           f"args={rec['argument_bytes']/2**30:8.1f}GiB "
           f"temp={rec['temp_bytes']/2**30:7.1f}GiB "
           f"compile={rec['compile_s']:5.0f}s")
    if "roofline" in rec:
        r = rec["roofline"]
        msg += (f" | comp={r['compute_s']:.3f}s mem={r['memory_s']:.3f}s "
                f"coll={r['collective_s']:.3f}s dom={r['dominant']}"
                f" useful={r['useful_flops_ratio']:.2f}")
    print(msg, flush=True)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None,
                    help="architecture id (default: all assigned)")
    ap.add_argument("--shape", default=None,
                    help="shape name (default: all four)")
    ap.add_argument("--mesh", choices=("single", "multi", "both"),
                    default="single")
    ap.add_argument("--all", action="store_true",
                    help="all assigned archs x all shapes")
    ap.add_argument("--no-analyze", action="store_true",
                    help="compile proof only (skip roofline lowerings)")
    ap.add_argument("--out", default=None, help="write JSON records here")
    args = ap.parse_args(argv)

    archs = [args.arch] if args.arch else list(ASSIGNED_ARCHS)
    shapes = [args.shape] if args.shape else [s.name for s in ALL_SHAPES]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    records = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                # roofline table is single-pod only (assignment)
                records.append(run_cell(arch, shape, mp,
                                        analyze=not args.no_analyze
                                        and not mp))

    n_fail = sum(r["status"] == "fail" for r in records)
    n_ok = sum(r["status"] == "ok" for r in records)
    n_skip = sum(r["status"] == "skip" for r in records)
    print(f"\n== dry-run: {n_ok} ok, {n_skip} skip, {n_fail} fail "
          f"/ {len(records)} cells")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(records, f, indent=1)
        print(f"wrote {args.out}")
    return 1 if n_fail else 0


if __name__ == "__main__":
    sys.exit(main())
