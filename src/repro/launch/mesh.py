"""Production meshes. Functions, never module-level constants, so importing
this module never touches jax device state (assignment requirement)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips (data, model). Multi-pod: 2 pods =
    512 chips with a leading 'pod' axis (cross-pod data parallelism, or
    pod-level prefill/decode disaggregation per DESIGN.md section 5)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_axis: int = 1):
    """Whatever this host actually has (tests / examples on CPU)."""
    n = len(jax.devices())
    data = n // model_axis
    return jax.make_mesh((data, model_axis), ("data", "model"))
