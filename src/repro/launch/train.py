"""Training launcher: restartable, checkpointed, straggler-watched.

On the CPU container this runs reduced configs end-to-end (the ~100M-class
example); on a real pod the same entry point runs the full config — the
step builder, sharding rules, checkpoints and watchdog are identical.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b --smoke \
      --steps 50 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time
from typing import Optional

import jax
import numpy as np

from repro.configs import get_config, reduce_for_smoke
from repro.configs.shapes import InputShape
from repro.dist import fault
from repro.dist.fault import SimulatedFailure, StragglerWatchdog
from repro.launch.mesh import make_host_mesh
from repro.serve.steps import build_train_step
from repro.train.data import SyntheticLM
from repro.train.optimizer import adamw, cosine_schedule


def train(arch: str, *, smoke: bool = True, steps: int = 50,
          batch_size: int = 8, seq_len: int = 128,
          ckpt_dir: Optional[str] = None, ckpt_every: int = 10,
          seed: int = 0, fail_at: Optional[int] = None,
          log_every: int = 10, verbose: bool = True):
    """Returns (losses, watchdog). Restart-safe when ckpt_dir is set."""
    cfg = get_config(arch)
    if smoke:
        cfg = reduce_for_smoke(cfg)
    mesh = make_host_mesh()
    shape = InputShape("cli", seq_len, batch_size, "train")
    opt = adamw(cosine_schedule(1e-3, warmup_steps=max(steps // 10, 1),
                                total_steps=steps))
    bundle = build_train_step(cfg, mesh, shape, optimizer=opt)
    model = bundle.model

    data = SyntheticLM(cfg, batch_size, seq_len, seed=seed)
    start_step = 0
    params = opt_state = None
    if ckpt_dir:
        latest = fault.latest_checkpoint(ckpt_dir)
        if latest:
            payload = fault.load_checkpoint(latest)
            params, opt_state, start_step, cursor = fault.restore_sharded(
                payload, bundle.shardings[0], bundle.shardings[1])
            data.restore(cursor)
            if verbose:
                print(f"[train] restored step {start_step} from {latest}")
    if params is None:
        params = model.init(jax.random.PRNGKey(seed))
        opt_state = opt.init(params)

    watchdog = StragglerWatchdog(threshold=3.0)
    losses = []
    for step in range(start_step, steps):
        if fail_at is not None and step == fail_at:
            raise SimulatedFailure(f"injected failure at step {step}")
        t0 = time.time()
        batch = data.next_batch()
        params, opt_state, loss = bundle.fn(params, opt_state, batch)
        loss = float(loss)
        losses.append(loss)
        watchdog.observe(step, time.time() - t0)
        if verbose and (step % log_every == 0 or step == steps - 1):
            print(f"[train] step {step:5d} loss {loss:.4f}")
        if ckpt_dir and ((step + 1) % ckpt_every == 0 or step == steps - 1):
            fault.save_checkpoint(ckpt_dir, step + 1, params, opt_state,
                                  data.cursor.as_dict())
    return losses, watchdog


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama32-3b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--fail-at", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    losses, wd = train(args.arch, smoke=args.smoke, steps=args.steps,
                       batch_size=args.batch_size, seq_len=args.seq_len,
                       ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                       seed=args.seed, fail_at=args.fail_at)
    print(f"[train] done: {len(losses)} steps, final loss "
          f"{losses[-1]:.4f}, {len(wd.flagged)} straggler steps")


if __name__ == "__main__":
    main()
