"""Serving launcher: run the paper's setups on any zoo architecture.

Two modes:
  * simulation (default): one declarative ``repro.exp`` Experiment —
    TPU-target timing/energy via the roofline cost model, memoized in
    the content-addressed result cache like every figure cell.
  * --real: reduced config executed on CPU with real KV transfers between
    engines (correctness mode; token streams are printed/compared). Real
    runs use an off-registry reduced config and live executors, so they
    simulate directly and are never cached.

``--setup`` takes a legacy setup name, the intra-GPU P/D split
("intra-gpu" / "intra-<k>": SM-sliced prefill+decode engines sharing
one KV pool, repro.sched), or any fleet shape ("2P2D-ici", "co-3"; see
repro.fleet.FleetSpec.parse).

  PYTHONPATH=src python -m repro.launch.serve --arch llama32-3b \
      --setup dis-ici --batch-size 16
  PYTHONPATH=src python -m repro.launch.serve --setup 2P2D-ici
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import get_config, reduce_for_smoke
from repro.core import RealExecutor, SETUPS, make_cluster, random_workload
from repro.exp import Experiment
from repro.exp import run as run_exp
from repro.fleet import FleetSpec
from repro.models import get_model


def serve(arch: str, setup: str, *, batch_size: int = 16,
          input_len: int = 16_384, output_len: int = 256,
          phi: float = 1.0, governor: str = None, real: bool = False,
          seed: int = 0, verbose: bool = True):
    if real:
        cfg = reduce_for_smoke(get_config(arch))
        input_len = min(input_len, 64)
        output_len = min(output_len, 8)
        model = get_model(cfg)
        params = model.init(jax.random.PRNGKey(seed))

        def executor_factory(path):
            return RealExecutor(model, params, transfer_path=path)

        reqs = random_workload(batch_size, input_len=input_len,
                               output_len=output_len,
                               vocab_size=cfg.vocab_size, seed=seed)
        kw = {"governor": governor} if governor else {}
        res = make_cluster(setup, cfg, phi=phi,
                           executor_factory=executor_factory,
                           **kw).run(reqs)
    else:
        exp = Experiment.closed(setup, batch_size, arch=arch,
                                input_len=input_len,
                                output_len=output_len,
                                seed=seed).with_phi(phi=phi)
        if governor:
            exp = exp.with_governor(governor)
        res = run_exp(exp)
    if verbose:
        m = res.metrics
        gov = f" governor={governor}" if governor else ""
        print(f"[serve] {setup} arch={arch} bs={batch_size} "
              f"phi={phi}{gov}")
        print(f"  median TTFT {m.median_ttft_s:.3f}s  "
              f"median TPOT {m.median_tpot_s * 1e3:.2f}ms")
        print(f"  prefill tput {m.prefill_throughput_tok_s:.0f} tok/s  "
              f"decode tput {m.decode_throughput_tok_s:.0f} tok/s")
        print(f"  energy {res.energy.total_j / 1e3:.2f} kJ  "
              f"({res.joules_per_token:.4f} J/token)  "
              f"evictions={m.total_evictions}")
        print(f"  breakdown: " + "  ".join(
            f"{k}={v / 1e3:.2f}kJ" for k, v in
            sorted(res.energy.breakdown().items())))
    return res


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama32-3b")
    ap.add_argument("--setup", default="dis-ici",
                    help=f"one of {SETUPS}, the intra-GPU P/D split "
                         "'intra-gpu' (repro.sched), or a fleet shape "
                         "like '2P2D-ici' / 'co-3' / 'intra-2'")
    ap.add_argument("--batch-size", type=int, default=16)
    ap.add_argument("--input-len", type=int, default=16_384)
    ap.add_argument("--output-len", type=int, default=256)
    ap.add_argument("--phi", type=float, default=1.0)
    ap.add_argument("--governor", default=None,
                    help="online DVFS governor (repro.govern): "
                         "static / queue-depth / slo-slack")
    ap.add_argument("--real", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    if args.setup not in SETUPS:
        try:
            FleetSpec.parse(args.setup)
        except ValueError as e:
            ap.error(str(e))          # usage error, not a traceback
    serve(args.arch, args.setup, batch_size=args.batch_size,
          input_len=args.input_len, output_len=args.output_len,
          phi=args.phi, governor=args.governor, real=args.real,
          seed=args.seed)


if __name__ == "__main__":
    main()
