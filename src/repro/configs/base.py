"""Config system: frozen dataclasses describing every supported architecture.

Every assigned architecture gets one module in this package exporting
``CONFIG``; the registry in ``__init__.py`` resolves ``--arch <id>``.
Configs are *declarative* — model code in ``repro.models`` interprets them.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts FFN configuration (DeepSeekMoE-style fine-grained)."""

    num_experts: int                 # routed experts
    top_k: int                      # experts activated per token
    num_shared_experts: int = 0     # always-on shared experts
    d_expert: int = 0               # per-expert hidden dim (fine-grained)
    # Layers [0, first_k_dense) use a dense FFN of width dense_d_ff instead.
    first_k_dense: int = 0
    dense_d_ff: int = 0
    router_aux_loss: float = 0.001  # load-balance auxiliary loss weight
    capacity_factor: float = 1.25   # train-time expert capacity
    # decode-time capacity: C = min(T*K, ceil(T*K/E * this)). Large enough
    # that drops are statistically negligible, 8x cheaper than dropless
    # C = T*K (which computes a worst-case all-tokens-to-one-expert buffer)
    decode_capacity_factor: float = 8.0


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 selective-state-space configuration."""

    state_dim: int = 64             # N: per-channel SSM state size
    head_dim: int = 64              # P: channels per SSM head
    expand: int = 2                 # d_inner = expand * d_model
    conv_width: int = 4             # depthwise causal conv kernel
    chunk_size: int = 256           # SSD chunk length


@dataclass(frozen=True)
class RWKVConfig:
    """RWKV6 (Finch) time-mix configuration."""

    head_dim: int = 64
    decay_lora: int = 64            # low-rank dim for data-dependent decay w_t
    mix_lora: int = 32              # low-rank dim for token-shift mixers
    gate_lora: int = 64


@dataclass(frozen=True)
class EncDecConfig:
    """Encoder-decoder split (seamless-m4t style)."""

    num_encoder_layers: int = 12
    num_decoder_layers: int = 12
    # encoder input is a precomputed frame-embedding stub (modality frontend
    # is out of scope per assignment).
    frontend_dim: int = 1024
    max_source_len: int = 4096


@dataclass(frozen=True)
class VisionStubConfig:
    """VLM frontend stub: input_specs() provides precomputed patch embeddings."""

    frontend_dim: int = 1024        # InternViT feature dim (pre-projector)
    num_patches: int = 1024         # patches per image after pixel-shuffle
    images_per_seq: int = 1


@dataclass(frozen=True)
class HybridConfig:
    """Zamba2-style hybrid: Mamba2 backbone + shared attention block."""

    # A single *shared* (weight-tied) attention block is invoked every
    # ``shared_attn_every`` layers, concatenating the residual stream with the
    # original embedding (Zamba2's "concatenated" input; we model the cheap
    # variant: plain residual input).
    shared_attn_every: int = 6
    # At 500k context the shared full-attention block gets a sliding window to
    # stay sub-quadratic (DESIGN.md §8).
    long_context_window: int = 4096


@dataclass(frozen=True)
class ModelConfig:
    """One architecture. Field semantics follow the assignment table."""

    name: str
    family: str                     # dense | moe | ssm | hybrid | vlm | encdec
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 -> d_model // num_heads
    # --- attention options ---
    qk_norm: bool = False           # qwen3: RMSNorm on q,k per head
    attn_qkv_bias: bool = False     # qwen2: bias on qkv projections
    attn_out_bias: bool = False
    mlp_bias: bool = False
    rope_theta: float = 10_000.0
    sliding_window: int = 0         # 0 -> full causal attention
    # --- misc ---
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    act: str = "silu"               # silu (swiglu) | gelu (plain)
    # --- sub-configs (at most one of moe/ssm/rwkv/hybrid per family) ---
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    rwkv: Optional[RWKVConfig] = None
    hybrid: Optional[HybridConfig] = None
    encdec: Optional[EncDecConfig] = None
    vision: Optional[VisionStubConfig] = None
    # --- numerics ---
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    # provenance
    source: str = ""

    # ------------------------------------------------------------------
    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        assert self.num_heads % max(self.num_kv_heads, 1) == 0, (
            f"{self.name}: num_heads={self.num_heads} not divisible by "
            f"num_kv_heads={self.num_kv_heads}")

    # ------------------------------------------------------------------
    @property
    def q_per_kv(self) -> int:
        return self.num_heads // self.num_kv_heads

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch serve 500k+ contexts without O(S^2) attention?"""
        return self.family in ("ssm", "hybrid")

    @property
    def has_decoder(self) -> bool:
        """Does the arch autoregressively decode (i.e. support decode shapes)?"""
        return True  # every assigned arch is generative or enc-dec

    # ------------------------------------------------------------------
    # Parameter counting (exact, from the same formulas the init code uses).
    # Used for MODEL_FLOPS = 6 N D in the roofline analysis.
    # ------------------------------------------------------------------
    def _attn_params(self, d_model: int) -> int:
        h, kv, hd = self.num_heads, self.num_kv_heads, self.head_dim
        p = d_model * (h * hd) + 2 * d_model * (kv * hd) + (h * hd) * d_model
        if self.attn_qkv_bias:
            p += (h + 2 * kv) * hd
        if self.qk_norm:
            p += 2 * hd
        return p

    def _dense_ffn_params(self, d_model: int, d_ff: int) -> int:
        # SwiGLU: gate + up + down
        n_mats = 3 if self.act == "silu" else 2
        return n_mats * d_model * d_ff

    def _moe_ffn_params(self) -> Tuple[int, int]:
        """(total, active) FFN params for one MoE layer."""
        m = self.moe
        per_exp = self._dense_ffn_params(self.d_model, m.d_expert)
        router = self.d_model * m.num_experts
        total = m.num_experts * per_exp + m.num_shared_experts * per_exp + router
        active = (m.top_k + m.num_shared_experts) * per_exp + router
        return total, active

    def _mamba2_params(self) -> int:
        s = self.ssm
        d_in = s.expand * self.d_model
        n_heads = d_in // s.head_dim
        # in_proj -> [z, x, B, C, dt]; conv on (x,B,C); out_proj; norm; A,D,dt_bias
        conv_dim = d_in + 2 * s.state_dim * 1  # grouped: x plus B,C (1 group)
        p = self.d_model * (2 * d_in + 2 * s.state_dim + n_heads)
        p += conv_dim * s.conv_width
        p += d_in * self.d_model
        p += d_in                     # gated RMSNorm
        p += 2 * n_heads + n_heads    # A_log, D, dt_bias
        return p

    def _rwkv6_params(self) -> int:
        r, d = self.rwkv, self.d_model
        # time-mix: r,k,v,g,o projections + lora decays + token-shift mixers
        p = 5 * d * d
        p += 2 * (d * r.decay_lora + r.decay_lora * d)     # w lora (decay)
        p += 5 * (d * r.mix_lora + r.mix_lora * d)         # x lora mixers
        p += d // r.head_dim * r.head_dim                  # u ("bonus") per head
        p += 2 * d                                         # ln_x scale/bias
        # channel-mix: k,v,r
        p += d * self.d_ff + self.d_ff * d + d * d
        return p

    def param_count(self, active_only: bool = False) -> int:
        """Exact parameter count (matches models.init shapes)."""
        d = self.d_model
        emb = self.vocab_size * d
        head = 0 if self.tie_embeddings else self.vocab_size * d
        norms_per_layer = 2 * d
        total = emb + head + d  # final norm

        if self.family in ("dense", "vlm"):
            per_layer = (self._attn_params(d)
                         + self._dense_ffn_params(d, self.d_ff)
                         + norms_per_layer)
            total += self.num_layers * per_layer
            if self.family == "vlm":
                total += self.vision.frontend_dim * d + d  # projector
        elif self.family == "moe":
            m = self.moe
            moe_total, moe_active = self._moe_ffn_params()
            for li in range(self.num_layers):
                ffn = (self._dense_ffn_params(d, m.dense_d_ff)
                       if li < m.first_k_dense
                       else (moe_active if active_only else moe_total))
                total += self._attn_params(d) + ffn + norms_per_layer
        elif self.family == "ssm":
            per_layer = self._rwkv6_params() + norms_per_layer
            total += self.num_layers * per_layer
        elif self.family == "hybrid":
            per_layer = self._mamba2_params() + norms_per_layer
            total += self.num_layers * per_layer
            total += self._attn_params(d) + 2 * d  # one shared attention block
        elif self.family == "encdec":
            e = self.encdec
            enc_layer = (self._attn_params(d)
                         + self._dense_ffn_params(d, self.d_ff)
                         + norms_per_layer)
            dec_layer = (2 * self._attn_params(d)   # self + cross
                         + self._dense_ffn_params(d, self.d_ff)
                         + 3 * d)
            total += e.num_encoder_layers * enc_layer
            total += e.num_decoder_layers * dec_layer
            total += e.frontend_dim * d + d  # frontend projector stub
        else:
            raise ValueError(self.family)
        return int(total)

    # KV-cache bytes per token (the paper's central quantity).
    def kv_bytes_per_token(self, dtype_bytes: int = 2) -> int:
        if self.family == "ssm":
            return 0  # fixed-size state, not per-token
        layers = self.num_layers
        if self.family == "hybrid":
            # Only shared-attention-block invocations hold per-token KV; the
            # block is weight-tied but each invocation caches its own K/V.
            layers = self.num_layers // self.hybrid.shared_attn_every
        if self.family == "encdec":
            layers = self.encdec.num_decoder_layers
        return 2 * layers * self.num_kv_heads * self.head_dim * dtype_bytes

    def state_bytes(self, dtype_bytes: int = 2) -> int:
        """Fixed-size recurrent state per sequence (SSM/hybrid)."""
        if self.family == "ssm":
            n_heads = self.d_model // self.rwkv.head_dim
            per_layer = n_heads * self.rwkv.head_dim * self.rwkv.head_dim
            return self.num_layers * (per_layer + 2 * self.d_model) * dtype_bytes
        if self.family == "hybrid":
            s = self.ssm
            d_in = s.expand * self.d_model
            n_heads = d_in // s.head_dim
            per_layer = n_heads * s.head_dim * s.state_dim + d_in * s.conv_width
            return self.num_layers * per_layer * dtype_bytes
        return 0

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# ----------------------------------------------------------------------
# Reduced configs for CPU smoke tests: same family & options, tiny dims.
# ----------------------------------------------------------------------
def reduce_for_smoke(cfg: ModelConfig) -> ModelConfig:
    kw = dict(
        num_layers=min(cfg.num_layers, 2),
        d_model=128,
        num_heads=4,
        num_kv_heads=min(cfg.num_kv_heads, 2) if cfg.num_kv_heads < cfg.num_heads else 4,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        param_dtype="float32",
        compute_dtype="float32",
    )
    if cfg.moe is not None:
        # capacity_factor sized so smoke tests never drop tokens (keeps the
        # prefill+decode == forward consistency checks exact).
        kw["moe"] = dataclasses.replace(
            cfg.moe, num_experts=8, top_k=2, d_expert=64,
            dense_d_ff=256 if cfg.moe.first_k_dense else 0,
            capacity_factor=float(8))
    if cfg.ssm is not None:
        kw["ssm"] = dataclasses.replace(
            cfg.ssm, state_dim=16, head_dim=32, chunk_size=32)
    if cfg.rwkv is not None:
        kw["rwkv"] = dataclasses.replace(
            cfg.rwkv, head_dim=32, decay_lora=16, mix_lora=8, gate_lora=16)
    if cfg.hybrid is not None:
        kw["hybrid"] = dataclasses.replace(cfg.hybrid, shared_attn_every=2)
    if cfg.encdec is not None:
        kw["encdec"] = dataclasses.replace(
            cfg.encdec, num_encoder_layers=2, num_decoder_layers=2,
            frontend_dim=64, max_source_len=64)
        kw["num_layers"] = 2
    if cfg.vision is not None:
        kw["vision"] = dataclasses.replace(
            cfg.vision, frontend_dim=64, num_patches=16)
    return cfg.replace(**kw)
