"""deepseek-moe-16b: 2 shared + 64 routed top-6, fine-grained
[arXiv:2401.06066]."""
from .base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1408,                # per-expert width (fine-grained)
    vocab_size=102400,
    moe=MoEConfig(num_experts=64, top_k=6, num_shared_experts=2,
                  d_expert=1408, first_k_dense=1, dense_d_ff=10944),
    source="arXiv:2401.06066",
)
