"""moonshot-v1-16b-a3b (Moonlight): 64-expert top-6 MoE
[hf:moonshotai/Moonlight-16B-A3B]."""
from .base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1408,                # per-expert width (fine-grained)
    vocab_size=163840,
    moe=MoEConfig(num_experts=64, top_k=6, num_shared_experts=2,
                  d_expert=1408, first_k_dense=1, dense_d_ff=11264),
    source="hf:moonshotai/Moonlight-16B-A3B",
)
