"""zamba2-2.7b: Mamba2 backbone + shared attention blocks [arXiv:2411.15242]."""
from .base import HybridConfig, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    num_layers=54,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,          # the shared attention block is MHA
    head_dim=80,
    d_ff=10240,
    vocab_size=32000,
    ssm=SSMConfig(state_dim=64, head_dim=64, expand=2, conv_width=4,
                  chunk_size=256),
    hybrid=HybridConfig(shared_attn_every=6, long_context_window=4096),
    source="arXiv:2411.15242",
)
