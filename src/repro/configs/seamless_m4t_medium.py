"""seamless-m4t-medium: enc-dec multimodal backbone [arXiv:2308.11596].

Backbone only — the speech frontend is a stub providing precomputed frame
embeddings (assignment: modality frontend is a STUB).
"""
from .base import EncDecConfig, ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="encdec",
    num_layers=12,            # per-stack depth (12 enc + 12 dec)
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=256206,
    act="gelu",               # conformer-lineage FFN (plain, not gated)
    encdec=EncDecConfig(num_encoder_layers=12, num_decoder_layers=12,
                        frontend_dim=1024, max_source_len=4096),
    source="arXiv:2308.11596",
)
