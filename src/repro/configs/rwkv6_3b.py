"""rwkv6-3b (Finch): attention-free, data-dependent decay [arXiv:2404.05892]."""
from .base import ModelConfig, RWKVConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    family="ssm",
    num_layers=32,
    d_model=2560,
    num_heads=40,             # d_model / rwkv.head_dim
    num_kv_heads=40,
    head_dim=64,
    d_ff=8960,
    vocab_size=65536,
    rwkv=RWKVConfig(head_dim=64, decay_lora=64, mix_lora=32, gate_lora=64),
    source="arXiv:2404.05892",
)
