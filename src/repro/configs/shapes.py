"""Assigned input shapes and (arch x shape) applicability.

Four shapes per architecture (40 cells total):
  train_4k     seq 4,096   global_batch 256   -> lowers train_step
  prefill_32k  seq 32,768  global_batch 32    -> lowers prefill (serve)
  decode_32k   seq 32,768  global_batch 128   -> lowers serve_step (1 new token)
  long_500k    seq 524,288 global_batch 1     -> serve_step; sub-quadratic only
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List

from .base import ModelConfig


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


TRAIN_4K = InputShape("train_4k", 4_096, 256, "train")
PREFILL_32K = InputShape("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = InputShape("decode_32k", 32_768, 128, "decode")
LONG_500K = InputShape("long_500k", 524_288, 1, "decode")

ALL_SHAPES: List[InputShape] = [TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K]
SHAPES = {s.name: s for s in ALL_SHAPES}


def applicable(cfg: ModelConfig, shape: InputShape) -> bool:
    """Whether the (arch, shape) cell runs or is a recorded skip.

    ``long_500k`` requires sub-quadratic attention: it runs for the SSM
    (rwkv6) and hybrid (zamba2, whose single shared attention block gets a
    sliding window at long context) families and is skipped for the eight
    pure full-attention archs (DESIGN.md section 8).
    """
    if shape.name == "long_500k":
        return cfg.sub_quadratic
    return True


def skip_reason(cfg: ModelConfig, shape: InputShape) -> str:
    if applicable(cfg, shape):
        return ""
    return (f"{cfg.name} is pure full-attention; long_500k requires "
            f"sub-quadratic attention (DESIGN.md section 8)")
