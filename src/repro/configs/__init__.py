"""Architecture registry: ``get_config("yi-34b")`` etc.

The ten assigned architectures plus the paper's own benchmark model
(llama32-3b). ``reduce_for_smoke`` produces the CPU-testable reduced config
of the same family.
"""
from __future__ import annotations

from typing import Dict, List

from .base import (EncDecConfig, HybridConfig, ModelConfig, MoEConfig,
                   RWKVConfig, SSMConfig, VisionStubConfig, reduce_for_smoke)
from .shapes import (ALL_SHAPES, DECODE_32K, LONG_500K, PREFILL_32K, SHAPES,
                     TRAIN_4K, InputShape, applicable, skip_reason)

from . import (command_r_35b, deepseek_moe_16b, internvl2_2b, llama32_3b,
               moonshot_v1_16b_a3b, qwen2_0_5b, qwen3_1_7b, rwkv6_3b,
               seamless_m4t_medium, yi_34b, zamba2_2_7b)

_MODULES = [
    yi_34b, qwen3_1_7b, command_r_35b, qwen2_0_5b, zamba2_2_7b, rwkv6_3b,
    internvl2_2b, seamless_m4t_medium, moonshot_v1_16b_a3b, deepseek_moe_16b,
    llama32_3b,
]

REGISTRY: Dict[str, ModelConfig] = {m.CONFIG.name: m.CONFIG for m in _MODULES}

ASSIGNED_ARCHS: List[str] = [
    "yi-34b", "qwen3-1.7b", "command-r-35b", "qwen2-0.5b", "zamba2-2.7b",
    "rwkv6-3b", "internvl2-2b", "seamless-m4t-medium", "moonshot-v1-16b-a3b",
    "deepseek-moe-16b",
]


def get_config(name: str) -> ModelConfig:
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(REGISTRY)}")
    return REGISTRY[name]


def list_archs() -> List[str]:
    return list(REGISTRY)


__all__ = [
    "ModelConfig", "MoEConfig", "SSMConfig", "RWKVConfig", "HybridConfig",
    "EncDecConfig", "VisionStubConfig", "InputShape", "REGISTRY",
    "ASSIGNED_ARCHS", "ALL_SHAPES", "SHAPES", "TRAIN_4K", "PREFILL_32K",
    "DECODE_32K", "LONG_500K", "get_config", "list_archs", "applicable",
    "skip_reason", "reduce_for_smoke",
]
