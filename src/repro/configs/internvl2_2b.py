"""internvl2-2b: InternViT(stub) + InternLM2 backbone [arXiv:2404.16821].

The assignment specifies the transformer BACKBONE only; the vision frontend
is a stub — input_specs() provides precomputed patch embeddings which a
learned projector maps into the LM embedding space.
"""
from .base import ModelConfig, VisionStubConfig

CONFIG = ModelConfig(
    name="internvl2-2b",
    family="vlm",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=92553,
    rope_theta=1_000_000.0,
    vision=VisionStubConfig(frontend_dim=1024, num_patches=256,
                            images_per_seq=1),
    source="arXiv:2404.16821",
)
