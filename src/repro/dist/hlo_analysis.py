"""HLO-derived evidence: collective traffic + roofline terms.

The multi-pod dry run (``repro.launch.dryrun``) proves a distribution
config by lowering and compiling it; this module turns the compiled
artifact into numbers (DESIGN.md sections 2 and 6): ``collective_stats``
parses the collective ops (and their payload bytes) out of HLO text,
``cost_numbers`` reads XLA's cost analysis, and ``RooflineTerms`` combines
them into the three-term step-time model

    step = max(compute, memory, collective)

that ``benchmarks.roofline`` tabulates and ``benchmarks.perf_iterate``
diffs across perf-flag sets. Because XLA counts a while-loop body once,
whole-model numbers come from two small fully-unrolled lowerings and
``linear_extrapolate`` (layer stacks are homogeneous: cost(L) = a + b*L).
"""
from __future__ import annotations

import math
import re
from dataclasses import dataclass, field
from typing import Dict, Tuple

from repro.configs.base import ModelConfig
from repro.configs.shapes import InputShape
from repro.core.costs import ChipSpec

_CHIP = ChipSpec()
PEAK_FLOPS = _CHIP.peak_flops                       # bf16 FLOP/s per chip
HBM_BW = _CHIP.hbm_bw                               # B/s per chip
ICI_BW = _CHIP.ici_bw_per_link * _CHIP.ici_links    # B/s per chip

# ----------------------------------------------------------------------
# HLO collective parsing
# ----------------------------------------------------------------------
_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
          "collective-permute", "all-to-all")

# "%x = TYPE kind(...)" where TYPE is "bf16[8,16,128]{2,1,0}" or a tuple.
# Async pairs: count the -start, skip the -done (it is the same transfer).
_INSTR_RE = re.compile(
    r"=\s*(?P<ty>\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s*"
    r"(?P<kind>" + "|".join(_KINDS) + r")(?P<suffix>-start|-done)?\(")

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")


def _shape_bytes_list(ty: str) -> list:
    out = []
    for dtype, dims in _SHAPE_RE.findall(ty):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        out.append(n * _DTYPE_BYTES.get(dtype, 2))
    return out


@dataclass
class CollectiveStats:
    """Collective op counts and payload bytes parsed from one HLO module."""
    count_by_kind: Dict[str, int] = field(default_factory=dict)
    bytes_by_kind: Dict[str, int] = field(default_factory=dict)

    @property
    def total_count(self) -> int:
        return sum(self.count_by_kind.values())

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())


def collective_stats(hlo_text: str) -> CollectiveStats:
    """Parse all-gather / all-reduce / reduce-scatter / collective-permute
    / all-to-all instructions (sync or async ``-start``) and sum their
    result-shape bytes per kind."""
    st = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _INSTR_RE.search(line)
        if m is None or m.group("suffix") == "-done":
            continue
        kind = m.group("kind")
        shapes = _shape_bytes_list(m.group("ty"))
        # async '-start' ops are tuple-typed (operand, result, ...): the
        # transfer is the result, so take the largest element, not the
        # sum — summing would double-count the aliased input shard.
        # Sync tuple types (all-to-all) really are multiple outputs.
        if m.group("suffix") == "-start" and m.group("ty").startswith("("):
            payload = max(shapes) if shapes else 0
        else:
            payload = sum(shapes)
        st.count_by_kind[kind] = st.count_by_kind.get(kind, 0) + 1
        st.bytes_by_kind[kind] = st.bytes_by_kind.get(kind, 0) + payload
    return st


# ----------------------------------------------------------------------
# compiled-artifact cost numbers + extrapolation
# ----------------------------------------------------------------------
def cost_numbers(compiled) -> Tuple[float, float]:
    """(flops, bytes_accessed) from a compiled executable's cost analysis
    (per-device numbers under SPMD)."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return float(ca.get("flops", 0.0)), float(ca.get("bytes accessed", 0.0))


def linear_extrapolate(y1: float, y2: float, n1: float, n2: float,
                       n: float) -> float:
    """Exact extrapolation of cost(L) = a + b*L from two measured sizes."""
    slope = (y2 - y1) / (n2 - n1)
    return y1 + slope * (n - n1)


# ----------------------------------------------------------------------
# three-term roofline
# ----------------------------------------------------------------------
@dataclass
class RooflineTerms:
    """Per-chip roofline for one compiled step.

    ``flops`` / ``hbm_bytes`` / ``collective_bytes`` are per-device
    numbers (XLA cost analysis of the SPMD module);
    ``vmem_resident_bytes`` is traffic the Pallas kernels keep on-chip
    and is credited against the HBM term; ``model_flops`` (the 6ND /
    2ND ideal) gives the useful-FLOPs ratio.
    """
    flops: float
    hbm_bytes: float
    collective_bytes: float
    n_chips: int
    model_flops: float = 0.0
    vmem_resident_bytes: float = 0.0
    memory_floor_bytes: float = 0.0

    @property
    def compute_s(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def memory_s_raw(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def memory_s(self) -> float:
        return max(self.hbm_bytes - self.vmem_resident_bytes, 0.0) / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.collective_bytes / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        return self.model_flops / self.flops if self.flops > 0 else 0.0

    def as_dict(self) -> Dict:
        return {
            "flops": self.flops,
            "hbm_bytes": self.hbm_bytes,
            "collective_bytes": self.collective_bytes,
            "n_chips": self.n_chips,
            "model_flops": self.model_flops,
            "vmem_resident_bytes": self.vmem_resident_bytes,
            "memory_floor_bytes": self.memory_floor_bytes,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "memory_s_raw": self.memory_s_raw,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "step_time_s": self.step_time_s,
            "useful_flops_ratio": self.useful_flops_ratio,
        }


# ----------------------------------------------------------------------
# model-derived ideals (per chip)
# ----------------------------------------------------------------------
def _tokens(shape: InputShape) -> int:
    if shape.kind in ("train", "prefill"):
        return shape.global_batch * shape.seq_len
    return shape.global_batch  # decode: one new token per sequence


def model_flops(cfg: ModelConfig, shape: InputShape, n_chips: int) -> float:
    """The 6ND (train) / 2ND (forward-only) ideal, per chip, on ACTIVE
    params — the MoE useful-work denominator, not the parameter count."""
    n_active = cfg.param_count(active_only=True)
    mult = 6 if shape.kind == "train" else 2
    return mult * n_active * _tokens(shape) / n_chips


def _attn_layers(cfg: ModelConfig) -> int:
    if cfg.family == "ssm":
        return 0
    if cfg.family == "hybrid":
        return cfg.num_layers // cfg.hybrid.shared_attn_every
    if cfg.family == "encdec":
        return cfg.encdec.num_decoder_layers
    return cfg.num_layers


def vmem_resident_traffic(cfg: ModelConfig, shape: InputShape,
                          n_chips: int) -> float:
    """Bytes the fused Pallas kernels keep VMEM-resident that XLA's cost
    analysis charges to HBM: attention logits+probs (flash attention never
    materializes them) and the recurrent scan-state stream (rwkv6/mamba2
    keep the running state on-chip across the chunk). Per chip."""
    B, S = shape.global_batch, shape.seq_len
    total = 0.0
    la = _attn_layers(cfg)
    if la:
        if shape.kind == "decode":
            pair_elems = B * cfg.num_heads * S           # one query row
        else:
            pair_elems = B * cfg.num_heads * S * S / 2   # causal half
        total += 2 * 4.0 * la * pair_elems               # logits + probs, f32
    state = cfg.state_bytes()
    if state:
        steps = 1 if shape.kind == "decode" else S
        total += 2.0 * state * B * steps / max(
            1, getattr(cfg.ssm, "chunk_size", 1) if cfg.ssm else 1)
    return total / n_chips


def structural_memory_floor(cfg: ModelConfig, shape: InputShape,
                            n_chips: int) -> float:
    """Bytes this cell cannot avoid holding per chip: bf16 weights (fully
    sharded), the batch's KV/recurrent state, and the token buffers. The
    sanity line the dry-run's memory_analysis is compared against."""
    B, S = shape.global_batch, shape.seq_len
    params = 2.0 * cfg.param_count()
    kv = (cfg.kv_bytes_per_token() * S + cfg.state_bytes()) * B
    tokens = 4.0 * B * (S if shape.kind != "decode" else 1)
    return (params + kv + tokens) / n_chips
