"""Sharding rules: legal PartitionSpecs for every arch on every mesh.

This is the placement half of the disaggregation story (DESIGN.md
sections 5-6): parameters get a tensor-parallel layout over the 'model'
axis (replicated across 'data'/'pod'), batches shard over the data axes,
and the decode state — the KV cache the paper transfers between stages —
gets its own rules, including the ``seq_shard_kv`` resharding lever.
Prefill and decode engines therefore share one parameter layout while
their activation/state layouts differ, which is exactly what pod-level
prefill/decode placement needs.

Every rule is divisibility-checked against the actual mesh axis sizes and
falls back along a fixed chain that ends fully replicated — an arch whose
dims don't divide the mesh still lowers, it just shards less
(``tests/test_sharding.py`` asserts legality for every registered arch on
both production meshes).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Sequence, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.dist import opt_flags

MODEL_AXIS = "model"
# data-parallel axes in outer-to-inner order; 'pod' exists on the
# multi-pod mesh only (cross-pod DP, or pod-level prefill/decode split).
_DATA_AXIS_ORDER = ("pod", "data")


# ----------------------------------------------------------------------
# mesh introspection (works for Mesh and AbstractMesh alike)
# ----------------------------------------------------------------------
def _axis_sizes(mesh) -> Dict[str, int]:
    return dict(mesh.shape)


def data_axes(mesh) -> Tuple[str, ...]:
    """The data-parallel axis names present on this mesh, outer first."""
    sizes = _axis_sizes(mesh)
    return tuple(a for a in _DATA_AXIS_ORDER if a in sizes)


def _data_size(mesh) -> int:
    sizes = _axis_sizes(mesh)
    return math.prod(sizes[a] for a in data_axes(mesh)) or 1


def _model_size(mesh) -> int:
    return _axis_sizes(mesh).get(MODEL_AXIS, 1)


def replicated(mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


# ----------------------------------------------------------------------
# parameters
# ----------------------------------------------------------------------
def _is_norm(name: str) -> bool:
    return "norm" in name or name.startswith("ln_")


def _is_stacked(parts: Sequence[str]) -> bool:
    """Leading [num_layers] axis from a vmapped per-layer init?"""
    return any(p.endswith("layers") or p in ("encoder", "decoder")
               for p in parts[:-1])


def param_spec(path: str, shape: Tuple[int, ...], mesh,
               cfg: ModelConfig) -> P:
    """Tensor-parallel spec for one parameter.

    Rules, in order:
      1. norm scales/biases replicate (tiny, and TP-summed activations
         need them whole on every shard);
      2. stacked MoE expert weights [L, E, d, f] shard the expert axis —
         expert parallelism keeps each expert's matmul local;
      3. otherwise the largest 'model'-divisible dim is sharded
         (later dim wins ties -> column-parallel for square weights;
         vocab-parallel embeddings when the vocab divides, d_model
         fallback when it does not);
      4. nothing divides -> fully replicated.
    """
    parts = path.split("/")
    ndim = len(shape)
    spec = [None] * ndim
    if _is_norm(parts[-1]):
        return P(*spec)

    tp = _model_size(mesh)
    if "moe_layers" in parts and ndim == 4 and shape[1] % tp == 0:
        spec[1] = MODEL_AXIS
        return P(*spec)

    start = 1 if (_is_stacked(parts) and ndim > 1) else 0
    candidates = [d for d in range(start, ndim)
                  if shape[d] > 1 and shape[d] % tp == 0]
    if candidates:
        best = max(candidates, key=lambda d: (shape[d], d))
        spec[best] = MODEL_AXIS
    return P(*spec)


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
    return "/".join(parts)


def param_shardings(cfg: ModelConfig, abstract_params: Any, mesh) -> Any:
    """NamedSharding pytree matching ``abstract_params``."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(abstract_params)
    out = [NamedSharding(mesh, param_spec(_path_str(path), leaf.shape,
                                          mesh, cfg))
           for path, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, out)


# ----------------------------------------------------------------------
# batches
# ----------------------------------------------------------------------
def batch_spec(shape: Tuple[int, ...], mesh) -> P:
    """Batch tensors shard dim 0 over ALL data axes (pod included), with
    a fallback to 'data' alone, then replicated (long_500k's batch of 1
    can never shard)."""
    spec = [None] * len(shape)
    if not shape:
        return P(*spec)
    dax = data_axes(mesh)
    sizes = _axis_sizes(mesh)
    if dax and shape[0] % math.prod(sizes[a] for a in dax) == 0:
        spec[0] = dax
    elif "data" in sizes and shape[0] % sizes["data"] == 0:
        spec[0] = ("data",)
    return P(*spec)


def batch_shardings(abstract_batch: Any, mesh) -> Any:
    return jax.tree.map(
        lambda l: NamedSharding(mesh, batch_spec(l.shape, mesh)),
        abstract_batch)


# ----------------------------------------------------------------------
# decode state (KV caches / recurrent states)
# ----------------------------------------------------------------------
def state_spec(shape: Tuple[int, ...], mesh) -> P:
    """Decode-state layout. Leaves follow the repo conventions
    [L, B, ...feature dims]: batch shards over the data axes and the
    trailing feature dim (head_dim, or the kv-head dim when head_dim
    doesn't divide) shards over 'model'.

    With the ``seq_shard_kv`` perf flag, 5-D KV caches [L, B, S, KV, hd]
    shard the SEQUENCE axis on 'model' instead — the decode-state
    resharding lever the roofline's collective term responds to.
    Recurrent (<=4-D) states are unaffected by the flag.
    """
    ndim = len(shape)
    spec = [None] * ndim
    if ndim < 2:
        return P(*spec)
    dax = data_axes(mesh)
    sizes = _axis_sizes(mesh)
    if dax and shape[1] % _data_size(mesh) == 0:
        spec[1] = dax
    elif "data" in sizes and shape[1] % sizes["data"] == 0:
        # same fallback chain as batch_spec: a batch that divides 'data'
        # but not pod*data must still give batch and state ONE layout
        spec[1] = ("data",)
    tp = _model_size(mesh)
    if (ndim == 5 and opt_flags.enabled("seq_shard_kv")
            and shape[2] % tp == 0):
        spec[2] = MODEL_AXIS
        return P(*spec)
    for d in (ndim - 1, ndim - 2):
        if d <= 1:
            break
        if shape[d] % tp == 0 and shape[d] > 1:
            spec[d] = MODEL_AXIS
            break
    return P(*spec)


def state_shardings(abstract_state: Any, mesh) -> Any:
    return jax.tree.map(
        lambda l: NamedSharding(mesh, state_spec(l.shape, mesh)),
        abstract_state)


# ----------------------------------------------------------------------
# optimizer state (ZeRO over data on top of the TP layout)
# ----------------------------------------------------------------------
def opt_state_shardings(param_sh: Any, abstract_params: Any, mesh) -> Any:
    """AdamW moments: take each parameter's TP spec and additionally shard
    the first free divisible dim over the data axes (ZeRO-1 style) — f32
    m+v replicated over 256 chips would not fit HBM for the 34B archs."""
    dax = data_axes(mesh)
    dsize = _data_size(mesh)

    def one(sh: NamedSharding, leaf) -> NamedSharding:
        spec = list(sh.spec) + [None] * (len(leaf.shape) - len(sh.spec))
        if dax:
            for d, entry in enumerate(spec):
                if entry is None and leaf.shape[d] > 1 \
                        and leaf.shape[d] % dsize == 0:
                    spec[d] = dax
                    break
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(one, param_sh, abstract_params)
