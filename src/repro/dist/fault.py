"""Fault tolerance for the training path: checkpoints + straggler watch.

A multi-pod run WILL lose workers; the contract here is the one
``tests/test_fault.py`` enforces: checkpoints are atomic (a crash mid-save
never leaves a loadable partial file), restarts are bit-exact (restored
params + optimizer moments + data cursor reproduce the uninterrupted loss
stream step-for-step), rotation keeps disk bounded, and a straggler
watchdog flags slow steps — the scheduling signal a pod-level
prefill/decode split would act on (DESIGN.md section 7).

Checkpoints are host numpy (pickle of a step/params/opt_state/cursor
payload); ``restore_sharded`` re-places the arrays onto the production
NamedShardings so a restart resumes with the exact layout the step was
compiled for.
"""
from __future__ import annotations

import os
import pickle
import statistics
import tempfile
from collections import deque
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np

_PREFIX = "ckpt_"
_SUFFIX = ".pkl"


class SimulatedFailure(RuntimeError):
    """Injected worker failure (``train --fail-at N``)."""


# ----------------------------------------------------------------------
# atomic checkpoint save / load / rotation
# ----------------------------------------------------------------------
def _to_host(tree: Any) -> Any:
    return jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)


def checkpoint_path(ckpt_dir: str, step: int) -> str:
    return os.path.join(ckpt_dir, f"{_PREFIX}{step:08d}{_SUFFIX}")


def save_checkpoint(ckpt_dir: str, step: int, params: Any, opt_state: Any,
                    cursor: Dict, keep: Optional[int] = None) -> str:
    """Atomically write a checkpoint; returns its path.

    Write goes to a ``.tmp`` file first and is published with
    ``os.replace`` — readers either see a complete checkpoint or none.
    ``keep=N`` deletes all but the newest N after a successful save.
    """
    os.makedirs(ckpt_dir, exist_ok=True)
    payload = {
        "step": int(step),
        "params": _to_host(params),
        "opt_state": _to_host(opt_state),
        "cursor": dict(cursor),
    }
    path = checkpoint_path(ckpt_dir, step)
    fd, tmp = tempfile.mkstemp(dir=ckpt_dir, suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            pickle.dump(payload, f, protocol=pickle.HIGHEST_PROTOCOL)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise
    if keep is not None:
        for _, old in sorted_checkpoints(ckpt_dir)[:-keep]:
            os.unlink(old)
    return path


def sorted_checkpoints(ckpt_dir: str) -> List[Tuple[int, str]]:
    """[(step, path), ...] ascending by step; ignores temp/foreign files."""
    out = []
    if not os.path.isdir(ckpt_dir):
        return out
    for name in os.listdir(ckpt_dir):
        if name.startswith(_PREFIX) and name.endswith(_SUFFIX):
            try:
                step = int(name[len(_PREFIX):-len(_SUFFIX)])
            except ValueError:
                continue
            out.append((step, os.path.join(ckpt_dir, name)))
    return sorted(out)


def latest_checkpoint(ckpt_dir: str) -> Optional[str]:
    ckpts = sorted_checkpoints(ckpt_dir)
    return ckpts[-1][1] if ckpts else None


def load_checkpoint(path: str) -> Dict:
    with open(path, "rb") as f:
        return pickle.load(f)


def restore_sharded(payload: Dict, param_shardings: Any,
                    opt_shardings: Any) -> Tuple[Any, Any, int, Dict]:
    """Re-place a loaded payload onto production shardings.

    Returns ``(params, opt_state, step, cursor)``.
    """
    params = jax.device_put(payload["params"], param_shardings)
    opt_state = jax.device_put(payload["opt_state"], opt_shardings)
    return params, opt_state, int(payload["step"]), payload["cursor"]


# ----------------------------------------------------------------------
# straggler watchdog
# ----------------------------------------------------------------------
class StragglerWatchdog:
    """Flags step times that are outliers vs the rolling median.

    ``observe(step, duration_s)`` returns True when the step is flagged:
    either ``duration > threshold * median`` of the last ``window``
    steps, or past the hard ``deadline_s``. Flagged steps accumulate in
    ``.flagged`` and fire the optional ``on_straggler(step, duration,
    median)`` callback — the hook a pod scheduler would use to evict or
    re-place a slow worker.
    """

    def __init__(self, threshold: float = 2.0, window: int = 20,
                 deadline_s: Optional[float] = None):
        self.threshold = threshold
        self.window = window
        self.deadline_s = deadline_s
        self.durations: deque = deque(maxlen=window)
        self.flagged: List[Tuple[int, float]] = []
        self.on_straggler: Optional[Callable[[int, float, float], Any]] = None

    def observe(self, step: int, duration_s: float) -> bool:
        median = (statistics.median(self.durations)
                  if self.durations else duration_s)
        slow = bool(self.durations) and duration_s > self.threshold * median
        if self.deadline_s is not None and duration_s > self.deadline_s:
            slow = True
        if slow:
            self.flagged.append((step, duration_s))
            if self.on_straggler is not None:
                self.on_straggler(step, duration_s, median)
        self.durations.append(duration_s)
        return slow
