"""Distribution subsystem: how the reproduction scales past one chip.

The serving core (``repro.core``) measures the paper's five setups on a
cost model; this package is what makes the same models *actually place* on
production meshes — the single-pod 16x16 and the multi-pod 2x16x16 that
``repro.launch.dryrun`` lowers and compiles against (DESIGN.md section 6).
Disaggregation at pod scale is a placement problem: prefill and decode
stages run the SAME parameter layout but different batch/state layouts,
and every piece of that story lives here:

  sharding      PartitionSpec rules for params / batches / decode state —
                the per-stage layouts DistServe-style placement needs.
  opt_flags     named, globally-registered perf optimizations so a flag
                set can be A/B'd through one re-lowering
                (``benchmarks.perf_iterate``).
  collectives   shard_map-level building blocks (ring passes, halo
                exchange, bucketed / int8-compressed all-reduce).
  fault         atomic checkpoints + straggler watchdog for the training
                path (DESIGN.md section 7).
  hlo_analysis  parse compiled HLO into roofline terms — the evidence the
                dry-run proof and the perf loop read.
"""
from . import collectives, fault, hlo_analysis, opt_flags, sharding

__all__ = ["collectives", "fault", "hlo_analysis", "opt_flags", "sharding"]
