"""Global perf-flag registry for the optimization iteration loop.

Disaggregated serving tunes prefill and decode *independently*; each named
flag here is one such tuning lever, applied process-wide so a single
re-lowering (``benchmarks.perf_iterate`` / ``repro.launch.dryrun``) can
A/B a flag set against the baseline without touching model code. Flags are
consumed at trace time by ``repro.models.layers`` / ``repro.models.moe``
and by the sharding rules (``repro.dist.sharding``); every flag must be
output-preserving — ``tests/test_opt_flags.py`` asserts forward/grad
equivalence for each.

Subprocess harnesses pass a flag set through the ``REPRO_OPT`` environment
variable (read once at import).
"""
from __future__ import annotations

import os
from typing import FrozenSet, Tuple

# name -> what it changes (the registry IS the documentation the perf log
# references; unknown names are rejected so a typo'd experiment cannot
# silently measure the baseline).
FLAGS = {
    "remat_dots": (
        "activation-checkpoint policy saves matmul outputs (XLA "
        "dots-saveable) instead of recomputing them in backward"),
    "bf16_logits": (
        "keep the LM-head matmul and logits tensor in bf16; softmax/loss "
        "still upcast to f32"),
    "seq_shard_kv": (
        "shard the KV cache on the sequence axis over 'model' instead of "
        "the head axis (decode-state resharding lever)"),
    "local_moe_dispatch": (
        "MoE sort/rank/scatter per data-shard-sized token group instead "
        "of one global sort; only the expert einsum crosses shards"),
    "masked_cache_update": (
        "decode KV write as an elementwise select over the sequence dim "
        "instead of a scatter (partitions cleanly under SPMD)"),
    "pad_heads": (
        "GQA head regrouping: duplicate kv heads so the q-head dim "
        "divides the model axis (bit-exact, enables head sharding)"),
    "head_shard_attn": (
        "constrain attention q/k/v head dims to 'model' when divisible"),
}

_active: FrozenSet[str] = frozenset()


def set_flags(csv: str) -> None:
    """Replace the active set with a comma-separated flag list ('' clears).

    Raises ``ValueError`` on any unknown name.
    """
    global _active
    names = [n.strip() for n in csv.split(",") if n.strip()]
    unknown = [n for n in names if n not in FLAGS]
    if unknown:
        raise ValueError(
            f"unknown perf flag(s) {unknown}; known: {sorted(FLAGS)}")
    _active = frozenset(names)


def enabled(name: str) -> bool:
    if name not in FLAGS:
        raise ValueError(f"unknown perf flag {name!r}; known: {sorted(FLAGS)}")
    return name in _active


def active() -> Tuple[str, ...]:
    """Currently enabled flags, sorted (falsy when none are set)."""
    return tuple(sorted(_active))


# subprocess harnesses (perf_iterate) hand the flag set down via env
set_flags(os.environ.get("REPRO_OPT", ""))
