"""shard_map-level collective building blocks.

The disaggregated multi-pod runs live or die on collective traffic: the
DP gradient all-reduce in training, the KV/state movement between stages
in serving, and halo exchange for sequence-sharded attention
(``seq_shard_kv``). These are the manual, compiler-visible primitives the
step builders and the roofline's "collective-bound -> next lever" advice
refer to — every function here is written against ``jax.lax`` axis
primitives, so it runs inside ``shard_map`` over any mesh axis.

All axis sizes are resolved with ``lax.psum(1, axis)`` which constant-
folds at trace time, so Python loops over ring steps stay static.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax


def _axis_size(axis_name: str) -> int:
    return lax.psum(1, axis_name)  # constant-folded at trace time


# ----------------------------------------------------------------------
def ring_pass(x: jnp.ndarray, axis_name: str, shift: int = 1) -> jnp.ndarray:
    """Cyclic shift along the mesh axis: device i receives from i-shift."""
    n = _axis_size(axis_name)
    perm = [(i, (i + shift) % n) for i in range(n)]
    return lax.ppermute(x, axis_name, perm)


def ring_allgather(x: jnp.ndarray, axis_name: str) -> jnp.ndarray:
    """All-gather via n-1 ring passes; shards concatenate along dim 0 in
    global axis-index order on every device.

    The bandwidth-optimal schedule on a torus link (what XLA emits for
    all-gather anyway); written out manually so the per-hop traffic is
    explicit in the collective stats.
    """
    n = _axis_size(axis_name)
    idx = lax.axis_index(axis_name)
    out = jnp.zeros((n,) + x.shape, x.dtype)
    cur = x
    for k in range(n):
        src = (idx - k) % n  # after k passes we hold shard idx-k
        out = lax.dynamic_update_slice(
            out, cur[None].astype(x.dtype), (src,) + (0,) * x.ndim)
        if k < n - 1:
            cur = ring_pass(cur, axis_name)
    return out.reshape((n * x.shape[0],) + tuple(x.shape[1:])) \
        if x.ndim else out.reshape(n)


def halo_exchange(x: jnp.ndarray, axis_name: str, *, halo: int = 1,
                  seq_axis: int = 1) -> jnp.ndarray:
    """Prepend the previous shard's trailing ``halo`` slices along
    ``seq_axis`` (shard 0 receives zeros — the sequence boundary).

    This is the boundary traffic of sequence-sharded attention / conv:
    each shard needs its left neighbor's tail to compute its first
    positions.
    """
    n = _axis_size(axis_name)
    s = x.shape[seq_axis]
    tail = lax.slice_in_dim(x, s - halo, s, axis=seq_axis)
    # non-cyclic: rank 0 has no sender, ppermute fills it with zeros
    recv = lax.ppermute(tail, axis_name, [(i, i + 1) for i in range(n - 1)])
    return jnp.concatenate([recv, x], axis=seq_axis)


# ----------------------------------------------------------------------
def bucketed_psum(tree: Any, axis_name: str,
                  bucket_bytes: int = 4 << 20) -> Any:
    """psum a gradient pytree in flattened buckets of ~``bucket_bytes``.

    Numerically identical to per-leaf psum; the point is launch overhead —
    hundreds of tiny per-parameter all-reduces become a few fused ones
    (the "bucket small collectives" lever in the roofline advice).
    """
    leaves, treedef = jax.tree.flatten(tree)
    buckets, cur, cur_bytes = [], [], 0
    for i, leaf in enumerate(leaves):
        nbytes = leaf.size * leaf.dtype.itemsize
        if cur and cur_bytes + nbytes > bucket_bytes:
            buckets.append(cur)
            cur, cur_bytes = [], 0
        cur.append(i)
        cur_bytes += nbytes
    if cur:
        buckets.append(cur)

    out = [None] * len(leaves)
    for idxs in buckets:
        dt = jnp.result_type(*[leaves[i].dtype for i in idxs])
        flat = jnp.concatenate(
            [leaves[i].reshape(-1).astype(dt) for i in idxs])
        summed = lax.psum(flat, axis_name)
        off = 0
        for i in idxs:
            leaf = leaves[i]
            out[i] = summed[off:off + leaf.size].reshape(
                leaf.shape).astype(leaf.dtype)
            off += leaf.size
    return jax.tree.unflatten(treedef, out)


# ----------------------------------------------------------------------
def compressed_psum(tree: Any, axis_name: str,
                    err: Optional[Any] = None) -> Tuple[Any, Any]:
    """int8-quantized gradient all-reduce with error feedback.

    Each leaf is scaled to int8 by its local absmax (the wire format is
    q:int8 + scale:f32, an ~4x reduction of DP all-reduce bytes), the
    dequantized values are mean-reduced, and the local quantization
    residual is returned as the error-feedback carry: feed it back as
    ``err`` on the next step and the accumulated update stays unbiased
    (``tests/test_collectives.py`` holds 50 steps within 1%).

    Returns ``(mean_tree, err_tree)``.
    """
    leaves, treedef = jax.tree.flatten(tree)
    if err is None:
        errs_in = [jnp.zeros(l.shape, jnp.float32) for l in leaves]
    else:
        errs_in = jax.tree.leaves(err)

    means, errs_out = [], []
    for g, e in zip(leaves, errs_in):
        val = g.astype(jnp.float32) + e
        scale = jnp.maximum(jnp.max(jnp.abs(val)) / 127.0, 1e-30)
        q = jnp.clip(jnp.round(val / scale), -127.0, 127.0)
        deq = q * scale  # what actually crosses the wire, dequantized
        means.append(lax.pmean(deq, axis_name).astype(g.dtype))
        errs_out.append(val - deq)
    return (jax.tree.unflatten(treedef, means),
            jax.tree.unflatten(treedef, errs_out))
