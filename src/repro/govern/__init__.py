"""Energy governance subsystem: online DVFS governors, power-state
telemetry, and the machinery behind the energy-aware fleet control
experiments (DESIGN.md section 11).

The paper's energy story is measured offline (one phi per run,
integrated joules). ``repro.govern`` upgrades both halves to online
form: ``PowerTrace`` gives every component a sampled power timeline
with explicit idle/active states (the idle-energy floor becomes
plottable), and the ``Governor`` classes retune each engine's phi from
live signals inside the event loop — ``static`` (the offline sweeps),
``queue-depth`` (race-to-idle on backlog), ``slo-slack``
(DualScale-style: lowest phi that preserves SLO attainment).
``benchmarks/fig8_governor_pareto.py`` overlays the realized governor
points on the static Pareto frontier and reproduces the paper's
negative result against adaptive policies.

Import direction: ``repro.core.energy`` imports ``.telemetry``, so
nothing in this package may import ``repro.core`` at module level
(``.governors`` resolves its grid default lazily).
"""
from .governors import (GOVERNORS, Governor, GovernorDecision,
                        QueueDepthGovernor, SLOSlackGovernor,
                        StaticGovernor, make_governor)
from .telemetry import ABSENT, ACTIVE, IDLE, SLEEP, PowerSample, PowerTrace

__all__ = [
    "PowerTrace", "PowerSample", "ACTIVE", "IDLE", "SLEEP", "ABSENT",
    "Governor", "GovernorDecision", "StaticGovernor",
    "QueueDepthGovernor", "SLOSlackGovernor", "GOVERNORS",
    "make_governor",
]
