"""Power-state telemetry: per-component time-series power traces.

The paper measures power with pynvml / RAPL / IPMI *samplers* — a power
timeline, not just integrated joules — and its central energy finding is
about the shape of that timeline: disaggregated serving keeps more
accelerator-seconds in the idle state (static draw with no work), so its
integrated energy stays higher even when stage-wise DVFS trims the
active draw. ``PowerTrace`` is the simulation analogue of that sampler:
every ``EnergyMeter.add_power`` call that knows *when* its interval
happened appends a ``PowerSample``; after a run the cluster fills each
accelerator's uncovered gaps with explicit idle-state samples, so the
idle-energy floor becomes a first-class, plottable quantity
(``energy_j(state="idle")``, ``timeline()``).

This module is dependency-free (stdlib + numpy only): ``repro.core``
imports it, so it must not import ``repro.core`` back.
"""
from __future__ import annotations

import collections
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

ACTIVE, IDLE = "active", "idle"


@dataclass(frozen=True)
class PowerSample:
    """One sampled interval of constant power draw on one component."""
    component: str
    t0: float
    t1: float
    watts: float
    stage: str              # prefill / decode / transfer-* / idle / other
    state: str = ACTIVE     # "active" (work) or "idle" (static floor)

    @property
    def seconds(self) -> float:
        return self.t1 - self.t0

    @property
    def joules(self) -> float:
        return self.watts * self.seconds


class PowerTrace:
    """Append-only per-component power timeline.

    Purely observational: the authoritative joule totals live in
    ``EnergyMeter.joules`` (identical call sequence as before traces
    existed, so golden-metric parity is bit-exact); the trace is the
    sampled view a plotter or governor post-mortem reads. The two agree
    to fp rounding wherever an interval was recorded with a timestamp.
    """

    def __init__(self):
        self.samples: Dict[str, List[PowerSample]] = \
            collections.defaultdict(list)

    # ------------------------------------------------------------------
    def record(self, component: str, t0: float, t1: float, watts: float,
               stage: str = "other", state: str = ACTIVE) -> None:
        if t1 <= t0:
            return                      # zero-length interval: nothing
        self.samples[component].append(
            PowerSample(component, t0, t1, watts, stage, state))

    @property
    def components(self) -> List[str]:
        return sorted(self.samples)

    # ------------------------------------------------------------------
    def intervals(self, component: str) -> List[Tuple[float, float]]:
        """Covered (t0, t1) intervals, merged and sorted."""
        ivs = sorted((s.t0, s.t1) for s in self.samples.get(component, []))
        merged: List[Tuple[float, float]] = []
        for t0, t1 in ivs:
            if merged and t0 <= merged[-1][1]:
                merged[-1] = (merged[-1][0], max(merged[-1][1], t1))
            else:
                merged.append((t0, t1))
        return merged

    def gaps(self, component: str, t0: float,
             t1: float) -> List[Tuple[float, float]]:
        """Sub-intervals of [t0, t1] with no sample on ``component``."""
        out: List[Tuple[float, float]] = []
        cursor = t0
        for a, b in self.intervals(component):
            if b <= t0:
                continue
            if a >= t1:
                break
            if a > cursor:
                out.append((cursor, min(a, t1)))
            cursor = max(cursor, b)
        if cursor < t1:
            out.append((cursor, t1))
        return out

    def fill_idle(self, component: str, t0: float, t1: float,
                  idle_watts: float, stage: str = "idle") -> float:
        """Record an idle-state sample over every uncovered gap of
        [t0, t1]; returns the idle seconds filled. This is how the
        cluster turns 'makespan minus busy' into an explicit power-state
        timeline after a run."""
        filled = 0.0
        for a, b in self.gaps(component, t0, t1):
            self.record(component, a, b, idle_watts, stage, state=IDLE)
            filled += b - a
        return filled

    # ------------------------------------------------------------------
    def energy_j(self, component: Optional[str] = None,
                 state: Optional[str] = None) -> float:
        """Trace-integrated joules, filterable by component / state."""
        comps = [component] if component is not None else self.components
        return sum(s.joules
                   for c in comps for s in self.samples.get(c, [])
                   if state is None or s.state == state)

    def busy_s(self, component: str) -> float:
        return sum(s.seconds for s in self.samples.get(component, [])
                   if s.state == ACTIVE)

    def span(self, component: str) -> Tuple[float, float]:
        ss = self.samples.get(component, [])
        if not ss:
            return (0.0, 0.0)
        return (min(s.t0 for s in ss), max(s.t1 for s in ss))

    def covers(self, component: str, t0: float, t1: float,
               tol: float = 1e-9) -> bool:
        """True when [t0, t1] has no uncovered gap wider than ``tol``."""
        return all(b - a <= tol for a, b in self.gaps(component, t0, t1))

    # ------------------------------------------------------------------
    def timeline(self, component: str, n: int = 200
                 ) -> Tuple[List[float], List[float]]:
        """(times, watts) resampled on an ``n``-point uniform grid over
        the component's span — the plottable power curve. Overlapping
        samples (they should not happen for an accelerator, which has
        one clock) sum, matching the energy integral."""
        t0, t1 = self.span(component)
        if t1 <= t0:
            return ([], [])
        step = (t1 - t0) / n
        times = [t0 + (i + 0.5) * step for i in range(n)]
        watts = [0.0] * n
        for s in self.samples.get(component, []):
            # uniform grid: each sample covers a contiguous index range
            # (O(samples + n) total, not O(samples * n))
            lo = max(0, int((s.t0 - t0) / step) - 1)
            hi = min(n - 1, int((s.t1 - t0) / step) + 1)
            for i in range(lo, hi + 1):
                if s.t0 <= times[i] < s.t1:
                    watts[i] += s.watts
        return (times, watts)

    # ------------------------------------------------------------------
    def state_summary(self) -> Dict[str, Dict[str, float]]:
        """{component: {"active_j", "idle_j", "active_s", "idle_s"}} —
        the idle-floor table fig8 and the energy report print."""
        out: Dict[str, Dict[str, float]] = {}
        for c in self.components:
            row = {"active_j": 0.0, "idle_j": 0.0,
                   "active_s": 0.0, "idle_s": 0.0}
            for s in self.samples[c]:
                key = "active" if s.state == ACTIVE else "idle"
                row[f"{key}_j"] += s.joules
                row[f"{key}_s"] += s.seconds
            out[c] = row
        return out
