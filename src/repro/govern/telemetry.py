"""Power-state telemetry: per-component time-series power traces.

The paper measures power with pynvml / RAPL / IPMI *samplers* — a power
timeline, not just integrated joules — and its central energy finding is
about the shape of that timeline: disaggregated serving keeps more
accelerator-seconds in the idle state (static draw with no work), so its
integrated energy stays higher even when stage-wise DVFS trims the
active draw. ``PowerTrace`` is the simulation analogue of that sampler:
every ``EnergyMeter.add_power`` call that knows *when* its interval
happened appends a ``PowerSample``; after a run the cluster fills each
accelerator's uncovered gaps with explicit idle-state samples, so the
idle-energy floor becomes a first-class, plottable quantity
(``energy_j(state="idle")``, ``timeline()``).

This module is dependency-free (stdlib + numpy only): ``repro.core``
imports it, so it must not import ``repro.core`` back.
"""
from __future__ import annotations

import collections
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

ACTIVE, IDLE = "active", "idle"
# Deep-sleep (powered down to a residual draw, wake costs latency) and
# absent (instance not part of the fleet over that interval — scaled in
# late or never provisioned). fill_idle must NEVER back-fill these
# windows with idle joules; the fleet controller records them
# explicitly so state_summary() attributes the floor honestly.
SLEEP, ABSENT = "sleep", "absent"


@dataclass(frozen=True)
class PowerSample:
    """One sampled interval of constant power draw on one component."""
    component: str
    t0: float
    t1: float
    watts: float
    stage: str              # prefill / decode / transfer-* / tier-fetch
                            #   (tiered-KV promotions, DESIGN.md s15) /
                            #   idle / other
    state: str = ACTIVE     # "active" (work) or "idle" (static floor)

    @property
    def seconds(self) -> float:
        return self.t1 - self.t0

    @property
    def joules(self) -> float:
        return self.watts * self.seconds


class _RunBlock:
    """A contiguous run of constant-stage samples, stored as arrays and
    expanded to ``PowerSample`` objects only when somebody reads the
    per-sample view. ``t1s[i] == t0s[i+1]`` (checked at record time), so
    for coverage/energy queries the block acts as one interval."""

    __slots__ = ("t0s", "t1s", "watts", "stage", "state")

    def __init__(self, t0s, t1s, watts, stage, state):
        self.t0s = t0s
        self.t1s = t1s
        self.watts = watts
        self.stage = stage
        self.state = state

    def expand(self, component: str) -> List[PowerSample]:
        # direct __dict__ fill: a frozen dataclass pays one
        # object.__setattr__ per field in __init__, which dominates when
        # a fleet run expands tens of thousands of samples
        new = object.__new__
        out = []
        for a, b, w in zip(self.t0s.tolist(), self.t1s.tolist(),
                           self.watts.tolist()):
            s = new(PowerSample)
            s.__dict__.update(component=component, t0=a, t1=b, watts=w,
                              stage=self.stage, state=self.state)
            out.append(s)
        return out


class PowerTrace:
    """Append-only per-component power timeline.

    Purely observational: the authoritative joule totals live in
    ``EnergyMeter.joules`` (identical call sequence as before traces
    existed, so golden-metric parity is bit-exact); the trace is the
    sampled view a plotter or governor post-mortem reads. The two agree
    to fp rounding wherever an interval was recorded with a timestamp.

    Internally a component's timeline is a list of chunks — single
    ``PowerSample`` objects or lazily-expanded ``_RunBlock`` runs from
    the coalescing fast stepper. Coverage and energy queries consume
    chunks directly; ``samples`` materializes the flat per-sample lists
    (identical, in order, to recording every sample individually).
    """

    def __init__(self):
        self._chunks: Dict[str, List[object]] = collections.defaultdict(list)
        # per-component expansion cache: (expanded list, chunks consumed)
        self._expanded: Dict[str, Tuple[List[PowerSample], int]] = {}

    # ------------------------------------------------------------------
    def record(self, component: str, t0: float, t1: float, watts: float,
               stage: str = "other", state: str = ACTIVE) -> None:
        if t1 <= t0:
            return                      # zero-length interval: nothing
        self._chunks[component].append(
            PowerSample(component, t0, t1, watts, stage, state))

    def record_run(self, component: str, t0s, t1s, watts,
                   stage: str = "other", state: str = ACTIVE,
                   contiguous: bool = False) -> None:
        """Bulk ``record``: one sample per element, zero-length intervals
        skipped — observably identical to n sequential calls. A
        contiguous strictly-positive run (the only thing the fast
        stepper emits) is kept as one ``_RunBlock``; anything else falls
        back to per-sample records. ``contiguous=True`` asserts the run
        property (t1s[i] == t0s[i+1] > t0s[i]) without the O(n) check —
        for callers that slice the run from one strictly-increasing
        cumulative-time array."""
        n = len(t0s)
        if n == 0:
            return
        if contiguous or (bool((t1s > t0s).all()) and
                          (n == 1 or bool((t0s[1:] == t1s[:-1]).all()))):
            self._chunks[component].append(
                _RunBlock(t0s, t1s, watts, stage, state))
            return
        for a, b, w in zip(t0s.tolist(), t1s.tolist(), watts.tolist()):
            self.record(component, a, b, w, stage, state)

    @property
    def components(self) -> List[str]:
        return sorted(self._chunks)

    # ------------------------------------------------------------------
    def _samples_of(self, component: str) -> List[PowerSample]:
        """Flat per-sample list for one component (cached; chunk lists
        are append-only, so the cache only ever expands the new tail)."""
        chunks = self._chunks.get(component)
        if not chunks:
            return []
        out, done = self._expanded.get(component, ([], 0))
        for chunk in chunks[done:]:
            if isinstance(chunk, _RunBlock):
                out.extend(chunk.expand(component))
            else:
                out.append(chunk)
        self._expanded[component] = (out, len(chunks))
        return out

    @property
    def samples(self) -> Dict[str, List[PowerSample]]:
        return {c: self._samples_of(c) for c in self._chunks}

    # ------------------------------------------------------------------
    def intervals(self, component: str) -> List[Tuple[float, float]]:
        """Covered (t0, t1) intervals, merged and sorted."""
        ivs = []
        for chunk in self._chunks.get(component, []):
            if isinstance(chunk, _RunBlock):
                # contiguous by construction: one interval per run
                ivs.append((float(chunk.t0s[0]), float(chunk.t1s[-1])))
            else:
                ivs.append((chunk.t0, chunk.t1))
        # engine samples arrive in clock order, so this list is almost
        # always already sorted; Timsort makes the check effectively free
        ivs.sort()
        merged: List[Tuple[float, float]] = []
        for t0, t1 in ivs:
            if merged and t0 <= merged[-1][1]:
                merged[-1] = (merged[-1][0], max(merged[-1][1], t1))
            else:
                merged.append((t0, t1))
        return merged

    def gaps(self, component: str, t0: float,
             t1: float) -> List[Tuple[float, float]]:
        """Sub-intervals of [t0, t1] with no sample on ``component``."""
        out: List[Tuple[float, float]] = []
        cursor = t0
        for a, b in self.intervals(component):
            if b <= t0:
                continue
            if a >= t1:
                break
            if a > cursor:
                out.append((cursor, min(a, t1)))
            cursor = max(cursor, b)
        if cursor < t1:
            out.append((cursor, t1))
        return out

    def fill_idle(self, component: str, t0: float, t1: float,
                  idle_watts: float, stage: str = "idle") -> float:
        """Record an idle-state sample over every uncovered gap of
        [t0, t1]; returns the idle seconds filled. This is how the
        cluster turns 'makespan minus busy' into an explicit power-state
        timeline after a run."""
        filled = 0.0
        for a, b in self.gaps(component, t0, t1):
            self.record(component, a, b, idle_watts, stage, state=IDLE)
            filled += b - a
        return filled

    # ------------------------------------------------------------------
    def energy_j(self, component: Optional[str] = None,
                 state: Optional[str] = None) -> float:
        """Trace-integrated joules, filterable by component / state."""
        comps = [component] if component is not None else self.components
        total = 0.0
        for c in comps:
            for chunk in self._chunks.get(c, []):
                if state is not None and chunk.state != state:
                    continue
                if isinstance(chunk, _RunBlock):
                    total += float(np.dot(chunk.watts,
                                          chunk.t1s - chunk.t0s))
                else:
                    total += chunk.joules
        return total

    def busy_s(self, component: str) -> float:
        total = 0.0
        for chunk in self._chunks.get(component, []):
            if chunk.state != ACTIVE:
                continue
            if isinstance(chunk, _RunBlock):
                total += float(chunk.t1s[-1] - chunk.t0s[0])  # contiguous
            else:
                total += chunk.seconds
        return total

    def span(self, component: str) -> Tuple[float, float]:
        chunks = self._chunks.get(component, [])
        if not chunks:
            return (0.0, 0.0)
        t0s, t1s = [], []
        for chunk in chunks:
            if isinstance(chunk, _RunBlock):
                t0s.append(float(chunk.t0s[0]))
                t1s.append(float(chunk.t1s[-1]))
            else:
                t0s.append(chunk.t0)
                t1s.append(chunk.t1)
        return (min(t0s), max(t1s))

    def covers(self, component: str, t0: float, t1: float,
               tol: float = 1e-9) -> bool:
        """True when [t0, t1] has no uncovered gap wider than ``tol``."""
        return all(b - a <= tol for a, b in self.gaps(component, t0, t1))

    # ------------------------------------------------------------------
    def timeline(self, component: str, n: int = 200
                 ) -> Tuple[List[float], List[float]]:
        """(times, watts) resampled on an ``n``-point uniform grid over
        the component's span — the plottable power curve. Overlapping
        samples (they should not happen for an accelerator, which has
        one clock) sum, matching the energy integral."""
        t0, t1 = self.span(component)
        if n <= 0 or t1 <= t0:
            # empty/unknown component, a single zero-width sample, or a
            # degenerate grid: an empty curve, never a ZeroDivisionError
            return ([], [])
        step = (t1 - t0) / n
        times = [t0 + (i + 0.5) * step for i in range(n)]
        watts = [0.0] * n
        for s in self._samples_of(component):
            # uniform grid: each sample covers a contiguous index range
            # (O(samples + n) total, not O(samples * n))
            lo = max(0, int((s.t0 - t0) / step) - 1)
            hi = min(n - 1, int((s.t1 - t0) / step) + 1)
            for i in range(lo, hi + 1):
                if s.t0 <= times[i] < s.t1:
                    watts[i] += s.watts
        return (times, watts)

    # ------------------------------------------------------------------
    def state_summary(self) -> Dict[str, Dict[str, float]]:
        """{component: {"active_j", "idle_j", "sleep_j", "absent_j",
        "active_s", ...}} — the idle-floor table fig8 and the energy
        report print. Buckets by the sample's ACTUAL state: before the
        fleet controller existed every non-active sample was counted as
        idle, silently back-filling deep-sleep / not-yet-provisioned
        windows into the idle-energy floor. States outside the standard
        four get their own keys."""
        out: Dict[str, Dict[str, float]] = {}
        for c in self.components:
            row = {f"{k}_{u}": 0.0
                   for k in (ACTIVE, IDLE, SLEEP, ABSENT) for u in "js"}
            for chunk in self._chunks[c]:
                key = chunk.state
                row.setdefault(f"{key}_j", 0.0)
                row.setdefault(f"{key}_s", 0.0)
                if isinstance(chunk, _RunBlock):
                    row[f"{key}_j"] += float(np.dot(
                        chunk.watts, chunk.t1s - chunk.t0s))
                    row[f"{key}_s"] += float(chunk.t1s[-1] - chunk.t0s[0])
                else:
                    row[f"{key}_j"] += chunk.joules
                    row[f"{key}_s"] += chunk.seconds
            out[c] = row
        return out
