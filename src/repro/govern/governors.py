"""Online DVFS governors: per-instance frequency controllers.

The paper's Experiment 2 evaluates *offline* grids — one phi fixed for
a whole run (``repro.core.dvfs``). A real deployment runs a *governor*:
a controller that retunes each accelerator's frequency online from the
signals it can actually observe (queue depth, SLO slack). DualScale
(PAPERS.md) is the reference design for the phase-aware variant. The
question fig8 asks with these classes is whether ANY realizable governor
lets disaggregation's stage-wise independent scaling close the energy
gap the paper measures — and the answer stays no, because the gap is an
idle-power floor, not an active-power inefficiency.

Contract: ``Governor.on_step(engine)`` is invoked by the engine event
loop immediately before each scheduler step; it inspects the engine
(queues, cost model, clock), writes ``engine.phi``, and appends a
``GovernorDecision`` whenever the setting changes. Decisions are pure
functions of engine state, so a fleet run stays bit-reproducible from
``(spec, workload)`` — no wall clocks, no unseeded randomness.

This module must not import ``repro.core`` at module level
(``repro.core.energy`` imports ``repro.govern.telemetry``, so the
package inits would cycle); the frequency-grid default resolves lazily.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple


def _default_grid() -> Tuple[float, ...]:
    from repro.core.costs import DEFAULT_FREQ_GRID   # lazy: avoid cycle
    return DEFAULT_FREQ_GRID


@dataclass(frozen=True)
class GovernorDecision:
    """One frequency change: when, who, to what, and why."""
    t: float
    engine: str
    phi: float
    signal: str          # human-readable trigger, e.g. "outstanding=9216"


class Governor:
    """Base controller: subclasses implement ``decide(engine) -> phi``."""

    name = "base"
    # True only when decide() is constant over a steady-state decode run
    # (no dependence on queues/clock), so the coalescing fast stepper
    # may invoke on_step once per run instead of once per token-step.
    # Online controllers (queue-depth, slo-slack) read live signals every
    # step and MUST keep False: the fast path then bails to the exact
    # stepper whenever they are installed (DESIGN.md section 13).
    coalescible = False

    def __init__(self, grid: Optional[Sequence[float]] = None,
                 seed: int = 0):
        self.grid: Tuple[float, ...] = tuple(
            sorted(grid if grid is not None else _default_grid()))
        assert self.grid and all(p > 0 for p in self.grid), self.grid
        self.seed = seed                       # determinism bookkeeping
        self.decisions: List[GovernorDecision] = []

    # ------------------------------------------------------------------
    def on_step(self, engine) -> float:
        """Event-loop hook: retune ``engine.phi`` before a scheduler
        step. Records a decision only when the setting changes (the
        trace stays small on steady workloads)."""
        phi, signal = self.decide(engine)
        if phi != engine.phi:
            self.decisions.append(GovernorDecision(
                t=engine.t, engine=engine.name, phi=phi, signal=signal))
            engine.phi = phi
            tr = getattr(engine, "tracer", None)
            if tr is not None and tr.enabled:
                # same payload as the decision record — one schema, two
                # views (repro.obs.trace.event_from_governor_decision).
                # Sound under the fast stepper: only coalescible
                # governors coalesce, and _advance_engine invokes
                # on_step at the same clock the exact first step would
                tr.instant("governor", "phi", engine.t,
                           engine=engine.name, phi=phi, signal=signal)
        return phi

    def decide(self, engine) -> Tuple[float, str]:
        raise NotImplementedError


class StaticGovernor(Governor):
    """No-op controller reproducing the offline sweeps: the engine keeps
    the phi its ``FleetSpec`` configured (or ``phi`` when given). This
    is the default on every cluster, and with the spec's phi it is
    bit-identical to pre-governor behavior — the parity goldens in
    ``tests/test_fleet.py`` run through it."""

    name = "static"
    coalescible = True      # decide() ignores queues/clock: run-invariant

    def __init__(self, phi: Optional[float] = None, **kw):
        super().__init__(**kw)
        self.phi = phi

    def decide(self, engine):
        return (engine.phi if self.phi is None else self.phi, "static")


class QueueDepthGovernor(Governor):
    """Race-to-idle on backlog: map the engine's outstanding tokens
    linearly onto the frequency grid. An empty queue coasts at the grid
    floor; ``high_tokens`` of backlog (default: one full prefill token
    budget) runs flat out. The simplest load-following policy a serving
    stack actually ships — it needs no SLO knowledge at all."""

    name = "queue-depth"

    def __init__(self, low_tokens: int = 0,
                 high_tokens: Optional[int] = None, **kw):
        super().__init__(**kw)
        assert high_tokens is None or high_tokens > low_tokens >= 0
        self.low_tokens = low_tokens
        self.high_tokens = high_tokens    # None: the engine's budget

    def decide(self, engine):
        load = engine.outstanding_tokens()
        high = self.high_tokens if self.high_tokens is not None \
            else max(engine.budget, self.low_tokens + 1)
        frac = (load - self.low_tokens) / (high - self.low_tokens)
        frac = min(max(frac, 0.0), 1.0)
        idx = round(frac * (len(self.grid) - 1))
        return (self.grid[idx], f"outstanding={load}")


class SLOSlackGovernor(Governor):
    """DualScale-style: pick the LOWEST phi whose projected TTFT and
    TPOT keep every queued request inside ``safety`` x its SLO.

    Projections are first-order roofline estimates from the engine's own
    cost model — prefill throughput for a full-budget chunk, one decode
    step for the current running batch — deliberately ignoring transfer
    legs and cross-stage interleave; ``safety`` (< 1) absorbs that
    optimism. A request with no SLO target never constrains. When even
    the top of the grid cannot meet a projection the governor pins flat
    out (attainment first, energy second)."""

    name = "slo-slack"

    def __init__(self, safety: float = 0.7, **kw):
        super().__init__(**kw)
        assert 0.0 < safety <= 1.0
        self.safety = safety

    # -- projections ---------------------------------------------------
    def _tpot_ok(self, engine, phi: float) -> bool:
        batch = list(engine.running)
        if not batch or engine.role == "prefill":
            return True
        total_ctx = sum(s.ctx for s in batch)
        step = engine.cost.decode_cost(len(batch), total_ctx).time(phi)
        stall = 0.0
        if engine.role == "colocated":
            # prefill-priority interference (paper finding F2): queued
            # prefill work stalls every running sequence for the full
            # backlog drain before their next tokens come out
            backlog = sum(s.prefill_target - s.prefill_done
                          for s in engine.waiting + engine.prefilling)
            if backlog > 0:
                sched = getattr(engine, "scheduler", None)
                if sched is not None and sched.interleaves:
                    # chunked-interleave composer (repro.sched): decode
                    # shares EVERY step, so a running sequence stalls at
                    # most one chunk-bounded composed step — not the
                    # whole backlog drain. The governor sees scheduler
                    # state and prices interference accordingly.
                    stall = engine.cost.prefill_time_s(
                        min(backlog, sched.chunk_tokens), phi=phi,
                        chunk=sched.chunk_tokens)
                else:
                    stall = engine.cost.prefill_time_s(
                        backlog, phi=phi, chunk=engine.budget)
        for s in batch:
            target = s.req.slo.tpot_s if s.req.slo is not None else None
            if not target:
                continue
            # slack tracking, not open-loop projection: anchor each
            # sequence's final mean TPOT to the inter-token time it has
            # ALREADY accumulated (which contains every past stall —
            # including interference the governor never predicted), plus
            # the remaining steps at the candidate phi and the current
            # backlog stall. Sequences that have eaten their slack force
            # phi up; fresh sequences in quiet periods let it fall.
            intervals = max(s.req.output_len - 1, 1)
            spent = 0.0 if s.req.first_token_s is None \
                else engine.t - s.req.first_token_s
            owed = max(s.req.output_len - s.req.generated, 0)
            projected = (spent + owed * step + stall) / intervals
            if projected > self.safety * target:
                return False
        return True

    def _ttft_ok(self, engine, phi: float) -> bool:
        if engine.role == "decode":
            return True
        pending = sorted(engine.prefilling + engine.waiting,
                         key=lambda s: s.priority)
        if not pending:
            return True
        eta = engine.t                 # queued prefills run serialized
        for s in pending:
            eta += engine.cost.prefill_time_s(
                s.prefill_target - s.prefill_done, ctx_begin=s.prefill_done,
                phi=phi, chunk=engine.budget)
            target = s.req.slo.ttft_s if s.req.slo is not None else None
            if not target:
                continue
            if eta > s.req.arrival_s + self.safety * target:
                return False
        return True

    def decide(self, engine):
        for phi in self.grid:
            if self._tpot_ok(engine, phi) and self._ttft_ok(engine, phi):
                return (phi, f"lowest feasible of {len(self.grid)}")
        return (self.grid[-1], "no feasible phi: pinned to max")


GOVERNORS = {
    StaticGovernor.name: StaticGovernor,
    QueueDepthGovernor.name: QueueDepthGovernor,
    SLOSlackGovernor.name: SLOSlackGovernor,
}


def make_governor(name: str, **kw) -> Governor:
    """Build a fresh governor (controllers are stateful: one per
    engine). ``name`` is a registry key; kwargs go to the class."""
    try:
        cls = GOVERNORS[name]
    except KeyError:
        raise ValueError(f"unknown governor {name!r}; "
                         f"choose from {sorted(GOVERNORS)}") from None
    return cls(**kw)
