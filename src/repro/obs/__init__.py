"""repro.obs — simulation-clock observability.

Request-lifecycle tracing (``trace``), a counter/gauge/histogram
registry (``metrics``), Chrome trace-event / Perfetto export
(``export``), and per-request SLO-violation attribution (``slo``).
See DESIGN.md section 16 for the determinism and fastpath-equivalence
contracts.
"""
from .trace import (CONTROLLER_TRACK, GOVERNOR_TRACK, INSTANT,
                    LIFECYCLE_TRACK, NULL_TRACER, SPAN, TIER_TRACK,
                    TraceEvent, Tracer, controller_action_from_event,
                    event_from_controller_action,
                    event_from_governor_decision,
                    governor_decision_from_event)
from .metrics import (LATENCY_BOUNDS_S, Counter, Gauge, Histogram,
                      MetricsRegistry, collect_run_metrics)
from .export import (assert_complete_lifecycles, chrome_trace,
                     request_lifecycles, text_summary,
                     validate_chrome_trace)
from .slo import (Attribution, attribute_run, attribute_tpot,
                  attribute_ttft, blame_table, transfer_queue_share)

__all__ = [
    "TraceEvent", "Tracer", "NULL_TRACER", "SPAN", "INSTANT",
    "LIFECYCLE_TRACK", "GOVERNOR_TRACK", "CONTROLLER_TRACK", "TIER_TRACK",
    "event_from_governor_decision", "governor_decision_from_event",
    "event_from_controller_action", "controller_action_from_event",
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "LATENCY_BOUNDS_S", "collect_run_metrics",
    "chrome_trace", "validate_chrome_trace", "request_lifecycles",
    "assert_complete_lifecycles", "text_summary",
    "Attribution", "attribute_ttft", "attribute_tpot", "attribute_run",
    "blame_table", "transfer_queue_share",
]
