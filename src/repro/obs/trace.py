"""Simulation-clock tracing: the one event schema behind observability.

``Tracer`` collects :class:`TraceEvent` records — engine phase/batch
spans, per-request lifecycle instants, KV-transfer spans, tier
movements, governor retunes, controller ops — stamped with the
*simulation* clock, so a trace is a pure function of ``(spec,
workload)`` and bit-reproducible like everything else in the simulator.

Determinism contract (DESIGN.md section 16, locked by
``tests/test_obs.py``):

  * tracer **off** (the ``NULL_TRACER`` default) the hooks are a single
    attribute read + branch — behavior is byte-identical to a build
    without them;
  * tracer **on** the hooks only *read* simulation state — every
    metric, timestamp, and joule stays bit-identical to an untraced
    run (a new parity axis fuzzes this);
  * **fast vs exact stepper**: a coalesced decode window emits ONE
    window-level span carrying its step count where the exact stepper
    emits one span per step. After :meth:`Tracer.coalesced` — maximal
    merging of adjacent same-name spans per track, summing ``steps`` —
    the two steppers' engine traces are identical, and the lifecycle /
    governor / controller instants are identical as timestamped sets
    (a coalesced window batches its finish emissions, so only the
    cross-engine interleaving of the event *list* may differ).

This module is dependency-free at import time (stdlib only):
``repro.core.engine`` imports it, so it must not import ``repro``
back. The converters at the bottom single-source the three event
formats that used to live apart — obs events, ``GovernorDecision``
records, and the ``FleetCluster.controller_log`` action dicts — with
JSON round-trips tested in ``tests/test_obs.py``.
"""
from __future__ import annotations

from collections import defaultdict
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

__all__ = ["SPAN", "INSTANT", "LIFECYCLE_TRACK", "GOVERNOR_TRACK",
           "CONTROLLER_TRACK", "TIER_TRACK", "TraceEvent", "Tracer",
           "NULL_TRACER", "event_from_governor_decision",
           "governor_decision_from_event", "event_from_controller_action",
           "controller_action_from_event"]

SPAN, INSTANT = "span", "instant"

# Reserved track names. Engine tracks use the engine's own name
# ("acc0", ...); KV-transfer spans ride on "xfer:<src>-><dst>".
LIFECYCLE_TRACK = "lifecycle"
GOVERNOR_TRACK = "governor"
CONTROLLER_TRACK = "controller"
TIER_TRACK = "tier"
_RESERVED_TRACKS = (LIFECYCLE_TRACK, GOVERNOR_TRACK, CONTROLLER_TRACK,
                    TIER_TRACK)

# Lifecycle instants: the arrival/first_token/finish triple is emitted
# exactly once per request (the property suite pins this); the rest may
# legitimately repeat (a preempted prefill completes twice, a parked
# request is routed twice).
LIFECYCLE_ONCE = ("arrival", "first_token", "finish")


@dataclass
class TraceEvent:
    """One trace record. ``t1 == t0`` for instants; ``args`` is a flat
    JSON-safe dict (ints/floats/strings only, by convention)."""
    name: str
    track: str
    t0: float
    t1: float
    kind: str = SPAN
    args: Dict[str, Any] = field(default_factory=dict)

    @property
    def dur(self) -> float:
        return self.t1 - self.t0

    def to_dict(self) -> Dict[str, Any]:
        return {"name": self.name, "track": self.track, "t0": self.t0,
                "t1": self.t1, "kind": self.kind, "args": dict(self.args)}

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "TraceEvent":
        return cls(name=d["name"], track=d["track"], t0=d["t0"],
                   t1=d["t1"], kind=d["kind"], args=dict(d["args"]))


class Tracer:
    """Append-only event sink. Hot paths guard on ``tracer.enabled``
    before computing any event arguments, so the disabled default costs
    one attribute read per hook site."""

    enabled = True

    def __init__(self):
        self.events: List[TraceEvent] = []

    # ---- emission ----------------------------------------------------
    def span(self, track: str, name: str, t0: float, t1: float,
             **args) -> None:
        self.events.append(TraceEvent(name, track, float(t0), float(t1),
                                      SPAN, args))

    def instant(self, track: str, name: str, t: float, **args) -> None:
        t = float(t)
        self.events.append(TraceEvent(name, track, t, t, INSTANT, args))

    def lifecycle(self, name: str, req_id: int, t: float, **args) -> None:
        """One per-request lifecycle instant (track ``lifecycle``)."""
        self.instant(LIFECYCLE_TRACK, name, t, req=int(req_id), **args)

    # ---- views -------------------------------------------------------
    def spans(self, track: Optional[str] = None) -> List[TraceEvent]:
        return [e for e in self.events if e.kind == SPAN
                and (track is None or e.track == track)]

    def instants(self, track: Optional[str] = None) -> List[TraceEvent]:
        return [e for e in self.events if e.kind == INSTANT
                and (track is None or e.track == track)]

    def engine_tracks(self) -> List[str]:
        """Tracks carrying engine phase spans (everything that is not a
        reserved track or a transfer-pair track)."""
        seen = []
        for e in self.events:
            if e.kind == SPAN and e.track not in _RESERVED_TRACKS \
                    and not e.track.startswith("xfer:") \
                    and e.track not in seen:
                seen.append(e.track)
        return sorted(seen)

    def coalesced(self, track: str) -> List[Tuple[str, float, float, int]]:
        """Engine spans of ``track`` after maximal merging of adjacent
        same-name spans (``next.t0 == cur.t1``), summing step counts —
        the normalization under which fast and exact steppers emit
        identical traces (the window-span contract)."""
        out: List[Tuple[str, float, float, int]] = []
        for e in self.spans(track):
            steps = int(e.args.get("steps", 0))
            if out and out[-1][0] == e.name and out[-1][2] == e.t0:
                name, t0, _, n = out[-1]
                out[-1] = (name, t0, e.t1, n + steps)
            else:
                out.append((e.name, e.t0, e.t1, steps))
        return out

    # ---- per-request lifecycle ---------------------------------------
    def lifecycle_events(self) -> Dict[int, Dict[str, List[TraceEvent]]]:
        """{req_id: {event name: events in emission (= time) order}}."""
        out: Dict[int, Dict[str, List[TraceEvent]]] = defaultdict(
            lambda: defaultdict(list))
        for e in self.instants(LIFECYCLE_TRACK):
            out[e.args["req"]][e.name].append(e)
        return {k: dict(v) for k, v in out.items()}

    def request_ids(self) -> List[int]:
        return sorted({e.args["req"]
                       for e in self.instants(LIFECYCLE_TRACK)})

    def derive_lifecycle(self, req_id: int) -> List[Tuple[str, float,
                                                          float]]:
        """The request's journey as contiguous (stage, t0, t1) spans:
        ``queue -> prefill [-> transfer -> decode-queue -> fetch] ->
        decode``, derived from the lifecycle instants. Adjacent spans
        share their boundary instant, so the set covers
        arrival..finish with no gap — the "complete lifecycle span
        set" the Perfetto export and the CI check consume."""
        evs = {}
        for e in self.instants(LIFECYCLE_TRACK):
            if e.args["req"] != req_id:
                continue
            evs.setdefault(e.name, []).append(e.t0)
        if "arrival" not in evs or "first_token" not in evs:
            return []
        arrival = evs["arrival"][0]
        first = evs["first_token"][0]
        finish = evs.get("finish", [first])[0]
        out = []
        if "prefill_start" not in evs:
            return [("queue", arrival, first), ("decode", first, finish)]
        ps = evs["prefill_start"][0]
        out.append(("queue", arrival, ps))
        if "transfer_done" not in evs:
            # colocated: the first token is sampled from the prefill
            # logits, so everything between prefill_start and
            # first_token (chunk waits, interference, recompute) is the
            # prefill stage
            out.append(("prefill", ps, first))
        else:
            td = evs["transfer_done"][-1]
            pd = max(t for t in evs.get("prefill_done", [td]) if t <= td)
            out.append(("prefill", ps, pd))
            out.append(("transfer", pd, td))
            if "fetch_start" in evs:
                fs = evs["fetch_start"][0]
                out.append(("decode-queue", td, fs))
                out.append(("fetch", fs, first))
            else:
                out.append(("decode-queue", td, first))
        out.append(("decode", first, finish))
        return out


class _NullTracer(Tracer):
    """The zero-overhead default: ``enabled`` is False and every
    emission method is a no-op, so un-guarded call sites stay cheap and
    guarded ones cost one attribute read."""

    enabled = False

    def span(self, track, name, t0, t1, **args):
        pass

    def instant(self, track, name, t, **args):
        pass

    def lifecycle(self, name, req_id, t, **args):
        pass


NULL_TRACER = _NullTracer()


# ----------------------------------------------------------------------
# Format converters: the obs event is the canonical record; the legacy
# shapes (GovernorDecision, controller_log dicts) are derived views.
# ----------------------------------------------------------------------
def event_from_governor_decision(d) -> TraceEvent:
    """``repro.govern.GovernorDecision`` -> instant on the governor
    track (same payload ``Governor.on_step`` emits live)."""
    return TraceEvent(name="phi", track=GOVERNOR_TRACK, t0=float(d.t),
                      t1=float(d.t), kind=INSTANT,
                      args={"engine": d.engine, "phi": d.phi,
                            "signal": d.signal})


def governor_decision_from_event(ev: TraceEvent):
    assert ev.track == GOVERNOR_TRACK and ev.name == "phi", ev
    from repro.govern.governors import GovernorDecision  # lazy: no cycle
    return GovernorDecision(t=ev.t0, engine=ev.args["engine"],
                            phi=ev.args["phi"], signal=ev.args["signal"])


def event_from_controller_action(d: Dict[str, Any]) -> TraceEvent:
    """A ``FleetCluster.controller_log`` entry (``{"t", "op", "engine",
    **kw}``) -> instant on the controller track."""
    args = {"engine": d["engine"]}
    args.update({k: v for k, v in d.items()
                 if k not in ("t", "op", "engine")})
    return TraceEvent(name=d["op"], track=CONTROLLER_TRACK,
                      t0=float(d["t"]), t1=float(d["t"]), kind=INSTANT,
                      args=args)


def controller_action_from_event(ev: TraceEvent) -> Dict[str, Any]:
    assert ev.track == CONTROLLER_TRACK, ev
    out: Dict[str, Any] = {"t": ev.t0, "op": ev.name,
                           "engine": ev.args["engine"]}
    out.update({k: v for k, v in ev.args.items() if k != "engine"})
    return out
