"""Counter / gauge / fixed-bucket-histogram registry.

The aggregate face of observability: where ``obs.trace`` records every
event, the registry holds a small deterministic summary — latency
histograms, fastpath coalescing stats, tier hit rates, router decision
counts — cheap enough to collect on EVERY run (it reads end-of-run
state; no hot-path hooks) and JSON-stable enough to snapshot into
``RunRecord.obs``. Buckets are fixed at registration, so two runs of
the same spec produce byte-identical snapshots (the ``repro.exp``
warm-cache contract extends to this field).

Dependency-free at import time (stdlib only), like ``obs.trace``:
``collect_run_metrics`` duck-types the cluster it summarizes.
"""
from __future__ import annotations

import bisect
from typing import Any, Dict, List, Optional, Sequence, Tuple

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "LATENCY_BOUNDS_S", "collect_run_metrics"]

# Shared log-spaced latency buckets (seconds): wide enough for queue
# delays at saturation, fine enough to separate TPOT targets. Fixed
# here — per-run adaptive buckets would break snapshot comparability.
LATENCY_BOUNDS_S = (0.001, 0.002, 0.005, 0.01, 0.02, 0.05, 0.1, 0.2,
                    0.5, 1.0, 2.0, 5.0, 10.0, 20.0, 50.0)


class Counter:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def inc(self, v=1):
        self.value += v


class Gauge:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v):
        self.value = v


class Histogram:
    """Fixed-bucket histogram: ``counts[i]`` counts observations
    ``<= bounds[i]`` (and ``counts[-1]`` the overflow), plus the exact
    count/sum pair so means survive the bucketing."""

    __slots__ = ("bounds", "counts", "count", "sum")

    def __init__(self, bounds: Sequence[float] = LATENCY_BOUNDS_S):
        self.bounds: Tuple[float, ...] = tuple(bounds)
        assert list(self.bounds) == sorted(self.bounds), bounds
        self.counts: List[int] = [0] * (len(self.bounds) + 1)
        self.count = 0
        self.sum = 0.0

    def observe(self, v: float) -> None:
        self.counts[bisect.bisect_left(self.bounds, v)] += 1
        self.count += 1
        self.sum += v

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0


class MetricsRegistry:
    """Name -> instrument map with a JSON-safe snapshot. Names are
    dotted paths (``fastpath.coalesced_steps``); get-or-create, so
    collection code never pre-declares."""

    def __init__(self):
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, Histogram] = {}

    def counter(self, name: str) -> Counter:
        return self._counters.setdefault(name, Counter())

    def gauge(self, name: str) -> Gauge:
        return self._gauges.setdefault(name, Gauge())

    def histogram(self, name: str,
                  bounds: Sequence[float] = LATENCY_BOUNDS_S) -> Histogram:
        h = self._histograms.get(name)
        if h is None:
            h = self._histograms[name] = Histogram(bounds)
        else:
            assert h.bounds == tuple(bounds), (name, h.bounds, bounds)
        return h

    def snapshot(self) -> Dict[str, Any]:
        return {
            "counters": {k: c.value
                         for k, c in sorted(self._counters.items())},
            "gauges": {k: g.value
                       for k, g in sorted(self._gauges.items())},
            "histograms": {
                k: {"bounds": list(h.bounds), "counts": list(h.counts),
                    "count": h.count, "sum": h.sum}
                for k, h in sorted(self._histograms.items())},
        }

    @classmethod
    def from_snapshot(cls, snap: Dict[str, Any]) -> "MetricsRegistry":
        reg = cls()
        for k, v in snap.get("counters", {}).items():
            reg.counter(k).inc(v)
        for k, v in snap.get("gauges", {}).items():
            reg.gauge(k).set(v)
        for k, d in snap.get("histograms", {}).items():
            h = reg.histogram(k, d["bounds"])
            h.counts = list(d["counts"])
            h.count = d["count"]
            h.sum = d["sum"]
        return reg


# ----------------------------------------------------------------------
def collect_run_metrics(cluster, requests,
                        reg: Optional[MetricsRegistry] = None
                        ) -> MetricsRegistry:
    """Summarize a finished run into a registry: request latency
    histograms, fastpath coalescing stats, tier hit rates, router
    decision counts, governor/controller activity. Pure read of
    end-of-run state — calling it never perturbs the cluster."""
    reg = reg or MetricsRegistry()
    h_ttft = reg.histogram("request.ttft_s")
    h_tpot = reg.histogram("request.tpot_s")
    h_queue = reg.histogram("request.queue_s")
    for r in requests:
        if r.ttft_s is not None:
            h_ttft.observe(r.ttft_s)
        if r.tpot_s is not None:
            h_tpot.observe(r.tpot_s)
        if r.queue_s is not None:
            h_queue.observe(r.queue_s)
    reg.counter("request.total").inc(len(requests))
    reg.counter("request.evictions").inc(
        sum(r.evictions for r in requests))
    reg.counter("request.recomputed_tokens").inc(
        sum(r.recomputed_tokens for r in requests))
    reg.counter("request.reused_tokens").inc(
        sum(r.reused_tokens for r in requests))

    engines = getattr(cluster, "engines", [])
    total_steps = sum(e.steps for e in engines)
    reg.counter("engine.steps").inc(total_steps)
    reg.counter("engine.preemptions").inc(
        sum(e.preemptions for e in engines))

    # fastpath coalescing (satellite: perf regressions diagnosable)
    windows = getattr(cluster, "coalesce_windows", 0)
    coalesced = getattr(cluster, "coalesced_steps", 0)
    reg.counter("fastpath.windows").inc(windows)
    reg.counter("fastpath.coalesced_steps").inc(coalesced)
    reg.gauge("fastpath.coalesced_step_fraction").set(
        coalesced / total_steps if total_steps else 0.0)

    # tiered-KV residency (per-store ledgers already exist; fold them)
    hits = misses = 0
    tier_ops: Dict[str, int] = {}
    for e in engines:
        store = getattr(e, "kv_store", None)
        if store is None:
            continue
        hits += store.hits
        misses += store.misses
        for ev in store.events:
            tier_ops[ev["op"]] = tier_ops.get(ev["op"], 0) + 1
    if hits or misses:
        reg.counter("tier.hits").inc(hits)
        reg.counter("tier.misses").inc(misses)
        reg.gauge("tier.hit_rate").set(hits / (hits + misses))
        for op, n in sorted(tier_ops.items()):
            reg.counter(f"tier.{op}").inc(n)

    # router decision counts (Router.picks, maintained per pick)
    for label in ("frontend", "kv_router"):
        router = getattr(cluster, label, None)
        if router is None:
            continue
        key = "kv" if label == "kv_router" else label
        for name, n in sorted(getattr(router, "picks", {}).items()):
            reg.counter(f"router.{key}.{name}").inc(n)

    reg.counter("governor.decisions").inc(
        sum(len(e.governor.decisions) for e in engines
            if getattr(e, "governor", None) is not None))
    reg.counter("controller.actions").inc(
        len(getattr(cluster, "controller_log", []) or []))
    return reg
