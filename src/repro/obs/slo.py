"""Per-request SLO-violation attribution and the per-setup blame table.

For each request that misses its TTFT or TPOT target, decompose the
*overrun* (measured − target) into named stage terms that sum exactly
to the overrun, so "why did this request violate" has a machine-checked
answer instead of a prose verdict.

TTFT decomposes along the request's derived lifecycle (see
``Tracer.derive_lifecycle``): ``queue`` / ``prefill`` for colocated
requests, plus ``transfer`` / ``decode-queue`` / ``fetch`` for
disaggregated ones. The segment durations already telescope to the
measured TTFT (shared boundary instants), so scaling each by
``overrun / ttft`` yields terms that sum to the overrun; a residual
correction on the largest term absorbs the last float ulp, keeping the
sum *exact* (ISSUE acceptance: within 1e-9 — we deliver 0.0).

TPOT decomposes by overlapping the decode engine's phase spans with the
request's decode interval ``[first_token, finish]``: time the engine
spent decoding (``decode``), prefilling other requests
(``prefill-interference``), fetching KV (``fetch-interference``), and
anything uncovered (``stall`` — queue/preemption dead time). Per-token
shares then scale to the overrun the same way.

``blame_table`` aggregates attributions per setup;
``transfer_queue_share`` is the scalar CI asserts for the fig6
narrative (below the crossover, dis violations are transfer+queue
dominated, not compute dominated).

Stdlib-only at import time; requests are duck-typed (``Request``
fields: arrival_s, first_token_s, finish_s, generated, ttft_s, tpot_s,
slo).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

__all__ = ["Attribution", "attribute_ttft", "attribute_tpot",
           "attribute_run", "blame_table", "transfer_queue_share",
           "TRANSFER_QUEUE_TERMS", "COMPUTE_TERMS"]

# Term families for the fig6 claim: a violation is "transfer+queue
# dominated" when these terms out-blame the compute terms.
TRANSFER_QUEUE_TERMS = ("queue", "transfer", "decode-queue", "fetch",
                        "fetch-interference", "stall")
COMPUTE_TERMS = ("prefill", "decode", "prefill-interference")


@dataclass
class Attribution:
    """One violating (request, metric) pair. ``terms`` maps stage name
    -> seconds of overrun blamed on it; values sum to ``overrun_s``
    exactly (enforced at construction)."""
    req_id: int
    metric: str                  # "ttft" | "tpot"
    measured_s: float
    target_s: float
    overrun_s: float
    terms: Dict[str, float] = field(default_factory=dict)

    def __post_init__(self):
        s = sum(self.terms.values())
        assert abs(s - self.overrun_s) <= 1e-9 * max(1.0, self.overrun_s), \
            (self.req_id, self.metric, s, self.overrun_s)

    def to_dict(self) -> Dict[str, Any]:
        return {"req_id": self.req_id, "metric": self.metric,
                "measured_s": self.measured_s, "target_s": self.target_s,
                "overrun_s": self.overrun_s, "terms": dict(self.terms)}


def _exact_scale(segments: Dict[str, float], total: float,
                 overrun: float) -> Dict[str, float]:
    """Scale non-negative ``segments`` (which sum ~total) by
    overrun/total, then absorb the float residual into the largest term
    so the returned terms sum to ``overrun`` exactly."""
    if total <= 0.0 or not segments:
        return {"stall": overrun} if overrun else {}
    f = overrun / total
    terms = {k: v * f for k, v in segments.items() if v > 0.0}
    if not terms:
        return {"stall": overrun} if overrun else {}
    big = max(terms, key=lambda k: terms[k])
    terms[big] += overrun - sum(terms.values())
    return terms


# ----------------------------------------------------------------------
def attribute_ttft(req, target_s: float,
                   lifecycle: Sequence[Tuple[str, float, float]]
                   ) -> Optional[Attribution]:
    """Attribute a TTFT overrun along the derived lifecycle (the spans
    before ``decode`` telescope from arrival to first token). Returns
    None when the request meets the target."""
    ttft = req.ttft_s
    if ttft is None or target_s is None or ttft <= target_s:
        return None
    overrun = ttft - target_s
    segments: Dict[str, float] = {}
    for stage, t0, t1 in lifecycle:
        if stage == "decode":
            continue
        segments[stage] = segments.get(stage, 0.0) + (t1 - t0)
    return Attribution(req_id=req.req_id, metric="ttft", measured_s=ttft,
                       target_s=target_s, overrun_s=overrun,
                       terms=_exact_scale(segments, sum(segments.values()),
                                          overrun))


_TPOT_TERM = {"decode": "decode", "prefill": "prefill-interference",
              # a chunked-interleave composed step (repro.sched) makes
              # token progress for every running sequence, so its span
              # is productive decode time, not interference — this is
              # what lets fig11 measure the blame-share shrink
              "mixed": "decode",
              "transfer-fetch": "fetch-interference",
              "tier-fetch": "fetch-interference"}


def attribute_tpot(req, target_s: float,
                   engine_spans: Sequence[Tuple[str, float, float, int]]
                   ) -> Optional[Attribution]:
    """Attribute a TPOT overrun by overlapping the decode engine's phase
    spans (``Tracer.coalesced(engine)`` rows) with the request's decode
    interval. Whatever the spans don't cover is ``stall``."""
    tpot = req.tpot_s
    if tpot is None or target_s is None or tpot <= target_s:
        return None
    overrun = tpot - target_s
    lo, hi = req.first_token_s, req.finish_s
    window = hi - lo
    segments: Dict[str, float] = {}
    covered = 0.0
    for name, t0, t1, _steps in engine_spans:
        o = min(t1, hi) - max(t0, lo)
        if o <= 0.0:
            continue
        term = _TPOT_TERM.get(name, "stall")
        segments[term] = segments.get(term, 0.0) + o
        covered += o
    if window - covered > 1e-12:
        segments["stall"] = segments.get("stall", 0.0) + (window - covered)
    return Attribution(req_id=req.req_id, metric="tpot", measured_s=tpot,
                       target_s=target_s, overrun_s=overrun,
                       terms=_exact_scale(segments, sum(segments.values()),
                                          overrun))


# ----------------------------------------------------------------------
def attribute_run(requests, slo, tracer) -> List[Attribution]:
    """All violating (request, metric) attributions for a traced run.
    ``slo`` needs ``ttft_s`` / ``tpot_s`` attributes (either may be
    None); ``tracer`` is the run's :class:`~repro.obs.trace.Tracer`."""
    lcs = tracer.lifecycle_events()
    coalesced_cache: Dict[str, List[Tuple[str, float, float, int]]] = {}
    out: List[Attribution] = []
    for req in requests:
        if getattr(slo, "ttft_s", None) is not None:
            a = attribute_ttft(req, slo.ttft_s,
                               tracer.derive_lifecycle(req.req_id))
            if a is not None:
                out.append(a)
        if getattr(slo, "tpot_s", None) is not None and req.tpot_s is not None:
            # the engine that emitted this request's first_token decodes it
            evs = lcs.get(req.req_id, {})
            ft = evs.get("first_token")
            engine = ft[0].args.get("engine") if ft else None
            if engine is not None:
                spans = coalesced_cache.get(engine)
                if spans is None:
                    spans = coalesced_cache[engine] = tracer.coalesced(engine)
                a = attribute_tpot(req, slo.tpot_s, spans)
                if a is not None:
                    out.append(a)
    return out


def blame_table(attrs: Sequence[Attribution]) -> Dict[str, Any]:
    """Aggregate attributions into a per-metric blame table:
    total overrun seconds per term, violation counts, and the
    transfer+queue share of total blame."""
    by_metric: Dict[str, Dict[str, float]] = {}
    counts: Dict[str, int] = {}
    for a in attrs:
        row = by_metric.setdefault(a.metric, {})
        counts[a.metric] = counts.get(a.metric, 0) + 1
        for term, v in a.terms.items():
            row[term] = row.get(term, 0.0) + v
    table = {}
    for metric, row in sorted(by_metric.items()):
        total = sum(row.values())
        table[metric] = {
            "violations": counts[metric],
            "total_overrun_s": total,
            "terms": {k: row[k] for k in sorted(row)},
            "transfer_queue_share": (
                sum(v for k, v in row.items()
                    if k in TRANSFER_QUEUE_TERMS) / total if total else 0.0),
        }
    return {"metrics": table, "violations": len(attrs)}


def transfer_queue_share(table: Dict[str, Any]) -> Optional[float]:
    """Overall transfer+queue blame share across all metrics of a
    :func:`blame_table` result (None when there are no violations)."""
    rows = table.get("metrics", {})
    total = sum(r["total_overrun_s"] for r in rows.values())
    if not total:
        return None
    tq = sum(r["total_overrun_s"] * r["transfer_queue_share"]
             for r in rows.values())
    return tq / total
