"""Chrome trace-event (Perfetto-loadable) export + text Gantt summary.

``chrome_trace`` turns a finished :class:`~repro.obs.trace.Tracer` into
the JSON object format of the Trace Event spec — load the file in
https://ui.perfetto.dev (or chrome://tracing) and every engine is a
thread of phase slices, every request an async track of lifecycle
stages, and governor/controller activity a row of instants.

Mapping:

  engine span            -> "X" complete event on that engine's tid
  transfer span          -> "X" on the pair's ``xfer:src->dst`` tid
  request lifecycle      -> "b"/"e" async pairs, ``cat="request"``,
                            ``id=req_id`` (one derived contiguous
                            stage chain per request)
  governor / controller  -> "i" instant events on their own tids
  track names            -> "M" thread_name metadata

Timestamps are microseconds of *simulation* time (the spec's ``ts``
unit), so a trace is bit-reproducible and directly comparable across
setups. ``validate_chrome_trace`` is the structural checker CI runs on
the exported artifact; ``text_summary`` renders the terminal
Gantt/flame view behind ``benchmarks.report --trace``.
"""
from __future__ import annotations

from collections import defaultdict
from typing import Any, Dict, List, Optional, Tuple

from .trace import (CONTROLLER_TRACK, GOVERNOR_TRACK, LIFECYCLE_TRACK,
                    SPAN, Tracer)

__all__ = ["chrome_trace", "validate_chrome_trace",
           "request_lifecycles", "assert_complete_lifecycles",
           "text_summary"]

_PID = 1
_US = 1e6


def _tid_map(tracer: Tracer) -> Dict[str, int]:
    """Stable track -> tid assignment: engines first (sorted), then
    transfer pairs, then governor/controller."""
    tracks = tracer.engine_tracks()
    xfer = sorted({e.track for e in tracer.events
                   if e.track.startswith("xfer:")})
    tail = [t for t in (GOVERNOR_TRACK, CONTROLLER_TRACK)
            if any(e.track == t for e in tracer.events)]
    return {t: i + 1 for i, t in enumerate(tracks + xfer + tail)}


def chrome_trace(tracer: Tracer, *, label: str = "repro-sim"
                 ) -> Dict[str, Any]:
    """Export the tracer as a Trace Event JSON object (dict)."""
    tids = _tid_map(tracer)
    out: List[Dict[str, Any]] = [
        {"ph": "M", "pid": _PID, "tid": 0, "name": "process_name",
         "args": {"name": label}}]
    for track, tid in tids.items():
        out.append({"ph": "M", "pid": _PID, "tid": tid,
                    "name": "thread_name", "args": {"name": track}})
    for e in tracer.events:
        if e.track == LIFECYCLE_TRACK:
            continue            # exported as derived async stages below
        base = {"pid": _PID, "tid": tids[e.track], "name": e.name,
                "ts": e.t0 * _US, "args": dict(e.args)}
        if e.kind == SPAN:
            base.update(ph="X", dur=e.dur * _US, cat="engine")
        else:
            base.update(ph="i", s="t", cat=e.track)
        out.append(base)
    for rid in tracer.request_ids():
        for stage, t0, t1 in tracer.derive_lifecycle(rid):
            common = {"pid": _PID, "tid": 0, "cat": "request",
                      "id": rid, "name": stage}
            out.append(dict(common, ph="b", ts=t0 * _US))
            out.append(dict(common, ph="e", ts=t1 * _US))
    out.sort(key=lambda ev: (ev["ts"] if "ts" in ev else -1.0,
                             ev["ph"] == "e"))
    return {"traceEvents": out, "displayTimeUnit": "ms"}


# ----------------------------------------------------------------------
def validate_chrome_trace(payload: Dict[str, Any]) -> int:
    """Structural validity check; returns the event count or raises
    ``ValueError``. Checks the invariants Perfetto's importer needs:
    known phases, numeric non-negative timestamps/durations, and
    balanced async begin/end pairs per (cat, id, name)."""
    if not isinstance(payload, dict) or "traceEvents" not in payload:
        raise ValueError("not a trace-event JSON object")
    events = payload["traceEvents"]
    if not isinstance(events, list) or not events:
        raise ValueError("empty traceEvents")
    open_async: Dict[Tuple, List[float]] = defaultdict(list)
    for i, ev in enumerate(events):
        ph = ev.get("ph")
        if ph not in ("X", "i", "b", "e", "M"):
            raise ValueError(f"event {i}: unknown phase {ph!r}")
        if "name" not in ev or "pid" not in ev:
            raise ValueError(f"event {i}: missing name/pid")
        if ph == "M":
            continue
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)) or ts < 0:
            raise ValueError(f"event {i}: bad ts {ts!r}")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                raise ValueError(f"event {i}: bad dur {dur!r}")
        if ph in ("b", "e"):
            key = (ev.get("cat"), ev.get("id"), ev["name"])
            if key[1] is None:
                raise ValueError(f"event {i}: async event without id")
            if ph == "b":
                open_async[key].append(ts)
            else:
                if not open_async[key]:
                    raise ValueError(f"event {i}: 'e' without 'b': {key}")
                t0 = open_async[key].pop()
                if ts < t0:
                    raise ValueError(f"event {i}: span ends before it "
                                     f"begins: {key}")
    dangling = {k: v for k, v in open_async.items() if v}
    if dangling:
        raise ValueError(f"unclosed async spans: {sorted(dangling)[:5]}")
    return len(events)


def request_lifecycles(payload: Dict[str, Any]
                       ) -> Dict[int, List[Tuple[str, float, float]]]:
    """Reconstruct {req_id: [(stage, t0_s, t1_s), ...]} from the async
    events of an exported trace (times back in seconds)."""
    begins: Dict[Tuple, List[float]] = defaultdict(list)
    spans: Dict[int, List[Tuple[float, str, float]]] = defaultdict(list)
    for ev in payload["traceEvents"]:
        if ev.get("cat") != "request":
            continue
        key = (ev["id"], ev["name"])
        if ev["ph"] == "b":
            begins[key].append(ev["ts"])
        elif ev["ph"] == "e":
            t0 = begins[key].pop(0)
            spans[ev["id"]].append((t0 / _US, ev["ts"] / _US, ev["name"]))
    # sort by (t0, t1) so a zero-length stage (e.g. queue on an idle
    # engine) precedes the stage starting at the same instant
    return {rid: [(n, t0, t1) for t0, t1, n in sorted(rows)]
            for rid, rows in spans.items()}


def assert_complete_lifecycles(payload: Dict[str, Any],
                               n_requests: Optional[int] = None,
                               tol: float = 0.0) -> int:
    """Every request in the trace must carry a contiguous lifecycle
    chain (each stage starting exactly where the previous ended)
    beginning with ``queue`` and ending with ``decode``. Returns the
    request count; raises ``ValueError`` otherwise. ``n_requests``
    additionally pins how many requests must be present."""
    lcs = request_lifecycles(payload)
    if n_requests is not None and len(lcs) != n_requests:
        raise ValueError(f"expected {n_requests} request lifecycles, "
                         f"got {len(lcs)}")
    if not lcs:
        raise ValueError("no request lifecycles in trace")
    for rid, chain in lcs.items():
        if not chain or chain[0][0] != "queue" or chain[-1][0] != "decode":
            raise ValueError(f"req {rid}: incomplete chain {chain}")
        for (_, _, t1), (name, t0, _) in zip(chain, chain[1:]):
            if abs(t0 - t1) > tol:
                raise ValueError(f"req {rid}: gap before {name}: "
                                 f"{t1} -> {t0}")
    return len(lcs)


# ----------------------------------------------------------------------
_GANTT_CH = {"prefill": "P", "decode": "D", "transfer-fetch": "F",
             "tier-fetch": "T"}


def text_summary(payload: Dict[str, Any], width: int = 64,
                 top: int = 5) -> str:
    """Terminal Gantt/flame view of an exported trace: per-track stage
    totals with an occupancy bar, plus the slowest requests' lifecycle
    waterfalls (``benchmarks.report --trace``)."""
    names = {ev["tid"]: ev["args"]["name"]
             for ev in payload["traceEvents"]
             if ev.get("ph") == "M" and ev["name"] == "thread_name"}
    spans: Dict[str, List[Tuple[float, float, str]]] = defaultdict(list)
    for ev in payload["traceEvents"]:
        if ev.get("ph") == "X":
            spans[names.get(ev["tid"], str(ev["tid"]))].append(
                (ev["ts"] / _US, (ev["ts"] + ev["dur"]) / _US, ev["name"]))
    all_spans = [s for rows in spans.values() for s in rows]
    lcs = request_lifecycles(payload)
    if not all_spans and not lcs:
        return "(empty trace)"
    t0 = min([s[0] for s in all_spans]
             + [c[0][1] for c in lcs.values() if c])
    t1 = max([s[1] for s in all_spans]
             + [c[-1][2] for c in lcs.values() if c])
    scale = width / max(t1 - t0, 1e-12)
    lines = [f"trace span [{t0:.3f}s, {t1:.3f}s]  "
             f"({len(all_spans)} spans, {len(lcs)} requests)", ""]
    for track in sorted(spans):
        rows = sorted(spans[track])
        by_stage: Dict[str, float] = defaultdict(float)
        for a, b, name in rows:
            by_stage[name] += b - a
        bar = ["."] * width
        for a, b, name in rows:
            lo = int((a - t0) * scale)
            hi = max(lo, min(width - 1, int((b - t0) * scale)))
            ch = _GANTT_CH.get(name, name[:1].upper() or "?")
            for i in range(lo, hi + 1):
                bar[i] = ch
        busy = sum(by_stage.values())
        stages = " ".join(f"{k}={v:.3f}s"
                          for k, v in sorted(by_stage.items()))
        lines.append(f"{track:>14s} |{''.join(bar)}|")
        lines.append(f"{'':>14s}  busy {busy:.3f}s  {stages}")
    if lcs:
        lines.append("")
        slowest = sorted(lcs.items(),
                         key=lambda kv: kv[1][0][1] - kv[1][-1][2])[:top]
        lines.append(f"slowest {len(slowest)} requests "
                     "(arrival-to-finish waterfall):")
        for rid, chain in slowest:
            total = chain[-1][2] - chain[0][1]
            parts = "  ".join(f"{name} {t1 - a:.3f}s"
                              for name, a, t1 in chain)
            lines.append(f"  req {rid:>4}  total {total:.3f}s: {parts}")
    return "\n".join(lines)
