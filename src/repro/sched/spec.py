"""SchedulerSpec: the per-step batch-composition policy of an engine.

Until this module existed the composition decision was hard-coded in
``Engine.step``: serialize whole prefills ahead of decode (prefill
priority), admit FCFS by req_id. That is the *weakest* colocation the
paper's headline claim can be measured against — DistServe frames
disaggregation's win as eliminating prefill/decode interference, and
Sarathi-Serve showed chunked-prefill interleaving removes most of that
interference without splitting the hardware. ``SchedulerSpec`` makes
the decision a frozen, hashable, spec-addressable value object on
``FleetSpec.scheduler`` with two pluggable layers:

  * **step composer** — ``serial`` (the legacy behavior, bit-for-bit)
    or ``chunked-interleave`` (each step packs the running decode batch
    plus up to ``chunk_tokens`` of chunked prefill; priced exactly via
    ``CostModel.mixed_step_cost``). The interleaved composer is
    *stall-free*: every composed step emits one token per running
    sequence, so the worst decode inter-token gap is a single
    chunk-bounded step instead of a whole prefill-backlog drain.
  * **admission order** — ``fcfs`` (legacy req_id order), ``sjf``
    (shortest predicted total job first), ``srpt`` (shortest predicted
    *remaining* work first, recomputed at every waiting-queue insert so
    preempted sequences re-sort by what is actually left), or
    ``prefix-aware`` (consults the engine's TieredKVStore / PrefixCache
    ``peek_match`` so cached-prefix requests jump the queue). Every
    non-FCFS key ends in ``req_id``, so ties break deterministically —
    two runs of the same spec produce the same order, always.

``None`` on ``FleetSpec.scheduler`` is the legacy engine, byte-for-byte
(spec encodings omit the key, so every pre-scheduler exp-cache hash is
preserved). Only the ``serial`` + ``fcfs`` spec is ``coalescible``: any
other composer/admission changes per-step decisions in ways the
coalescing fast stepper cannot vectorize, so those runs bail to the
exact stepper (the bail rule pinned by ``benchmarks/BENCH_simcore.json``).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple, Union

__all__ = ["COMPOSERS", "ADMISSIONS", "SchedulerSpec",
           "as_scheduler_spec"]

COMPOSERS = ("serial", "chunked-interleave")
ADMISSIONS = ("fcfs", "sjf", "srpt", "prefix-aware")


@dataclass(frozen=True)
class SchedulerSpec:
    """One engine scheduling policy: step composer x admission order."""
    composer: str = "serial"
    admission: str = "fcfs"
    # composed-step token budget of the chunked-interleave composer:
    # each step spends one token per running decode sequence and packs
    # prefill chunks into the remainder. Small values bound the decode
    # stall per step (TPOT); large values amortize the per-step weight
    # stream (TTFT). Ignored by the serial composer.
    chunk_tokens: int = 1024

    def __post_init__(self):
        if self.composer not in COMPOSERS:
            raise ValueError(f"unknown composer {self.composer!r}; "
                             f"choose from {COMPOSERS}")
        if self.admission not in ADMISSIONS:
            raise ValueError(f"unknown admission {self.admission!r}; "
                             f"choose from {ADMISSIONS}")
        if self.chunk_tokens < 1:
            raise ValueError(
                f"chunk_tokens must be >= 1, got {self.chunk_tokens}")

    # ------------------------------------------------------------------
    @property
    def interleaves(self) -> bool:
        return self.composer == "chunked-interleave"

    @property
    def coalescible(self) -> bool:
        """True only for the legacy-equivalent policy: the coalescing
        fast stepper may vectorize steady-state decode. Chunked
        interleave changes step composition mid-run and non-FCFS
        admission reorders the waiting queue on every insert — both
        invalidate the uniform-run precondition, so such runs take the
        exact stepper (tests/test_fastpath_parity.py fuzzes this axis;
        the perf lane pins the ratio near 1.0)."""
        return self.composer == "serial" and self.admission == "fcfs"

    # ------------------------------------------------------------------
    def admission_key(self, seq, engine) -> Optional[Tuple[int, ...]]:
        """The waiting-queue sort key for ``seq`` on ``engine``, or None
        for FCFS (the engine then keeps its legacy int req_id priority —
        bit-identical ordering AND representation). Recomputed at every
        ``_enqueue_waiting`` so a preempted-and-requeued sequence sorts
        by its live remaining work. Lower sorts earlier; the trailing
        req_id makes every ordering a deterministic total order."""
        if self.admission == "fcfs":
            return None
        req = seq.req
        rid = req.req_id
        if self.admission == "sjf":
            # shortest predicted total job: prompt + full output budget
            return (req.prompt_len + req.output_len, rid)
        remaining = (seq.prefill_target - seq.prefill_done) \
            + (req.output_len - req.generated)
        if self.admission == "srpt":
            return (remaining, rid)
        # prefix-aware: requests whose prompt prefix is already resident
        # in the engine's KV reuse layer jump the queue (their prefill
        # is mostly free, so serving them first is SRPT on *actual*
        # remaining compute). Without a reuse layer every match is 0 and
        # the order degrades to SRPT — documented, deterministic.
        matched = 0
        store = engine.kv_store if engine.kv_store is not None \
            else engine.prefix_cache
        if store is not None and req.prompt_tokens is not None:
            matched = store.peek_match(req.prompt_tokens)
        return (-matched, remaining, rid)


def as_scheduler_spec(value: Union[None, str, dict, SchedulerSpec]
                      ) -> Optional[SchedulerSpec]:
    """Normalize the accepted scheduler forms: None passes through (the
    legacy engine), a string names a composer OR an admission policy,
    a dict is SchedulerSpec kwargs."""
    if value is None or isinstance(value, SchedulerSpec):
        return value
    if isinstance(value, str):
        if value in COMPOSERS:
            return SchedulerSpec(composer=value)
        if value in ADMISSIONS:
            return SchedulerSpec(admission=value)
        raise ValueError(
            f"unknown scheduler {value!r}: expected a composer "
            f"{COMPOSERS}, an admission policy {ADMISSIONS}, a kwargs "
            f"dict, or a SchedulerSpec")
    if isinstance(value, dict):
        return SchedulerSpec(**value)
    raise TypeError(f"not a scheduler spec: {type(value).__name__}")
