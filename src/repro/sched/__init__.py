"""repro.sched: pluggable per-step batch composition + admission order.

DESIGN.md section 17. ``SchedulerSpec`` rides on ``FleetSpec.scheduler``
(None = the legacy serialize-prefill engine, byte-identical); the
chunked-interleave composer and the SJF/SRPT/prefix-aware admission
orders live in ``repro.core.engine``, priced by
``CostModel.mixed_step_cost``.
"""
from .spec import (ADMISSIONS, COMPOSERS, SchedulerSpec,
                   as_scheduler_spec)

__all__ = ["ADMISSIONS", "COMPOSERS", "SchedulerSpec",
           "as_scheduler_spec"]
