"""Fast qualitative checks of the paper's findings on the simulator (the
full quantitative reproduction lives in benchmarks/validate_claims.py)."""
import pytest

from repro.configs import get_config
from repro.core import Cluster, SETUPS, random_workload
from repro.core.dvfs import sweep_frequencies


CFG = get_config("llama32-3b")


def _run(setup, bs, **kw):
    reqs = random_workload(bs, input_len=16_384, output_len=256)
    return Cluster(setup, CFG, **kw).run(reqs)


@pytest.fixture(scope="module")
def sweep16():
    return {s: _run(s, 16) for s in SETUPS}


def test_f1_co2gpus_best_ttft(sweep16):
    co2 = sweep16["co-2gpus"].metrics.median_ttft_s
    for s, res in sweep16.items():
        if s != "co-2gpus":
            assert co2 <= res.metrics.median_ttft_s + 1e-9, \
                f"F1 violated by {s}"


def test_f3_transfer_tier_ordering(sweep16):
    ttft = {s: sweep16[s].metrics.median_ttft_s for s in sweep16}
    assert ttft["dis-ici"] < ttft["dis-host"] < ttft["dis-disk"]
    jt = {s: sweep16[s].joules_per_token for s in sweep16}
    assert jt["dis-ici"] < jt["dis-host"] < jt["dis-disk"]


def test_f2_colocated_tpot_cliff():
    lo = _run("co-2gpus", 16).metrics
    hi = _run("co-2gpus", 32).metrics
    assert hi.median_tpot_s > 1.8 * lo.median_tpot_s, "no cliff at 32"
    assert hi.total_recomputed_tokens > 0
    # disaggregated decode must NOT cliff
    dlo = _run("dis-ici", 16).metrics
    dhi = _run("dis-ici", 32).metrics
    assert dhi.median_tpot_s < 1.5 * dlo.median_tpot_s
    assert dhi.total_recomputed_tokens == 0


def test_f5_energy_amortizes_then_spikes():
    e4 = _run("co-2gpus", 4).joules_per_token
    e16 = _run("co-2gpus", 16).joules_per_token
    e32 = _run("co-2gpus", 32).joules_per_token
    assert e16 < e4                       # static amortization
    assert e32 > e16                      # eviction spike


def test_f6_no_dis_energy_win_at_batch16():
    """Even with independent frequencies, dis can't beat co-2gpus energy
    (paper takeaway 2) — checked on a coarse grid."""
    grid = (0.42, 0.58, 0.74, 1.0)
    wl = lambda: random_workload(16, input_len=16_384, output_len=256)
    co = sweep_frequencies("co-2gpus", CFG, wl, freq_grid=grid)
    dis = sweep_frequencies("dis-ici", CFG, wl, freq_grid=grid)
    co_best = min(p.energy_j + d.energy_j for p, d in
                  zip(co.prefill_points, co.decode_points))
    dis_best = min(p.energy_j for p in dis.prefill_points) + \
        min(d.energy_j for d in dis.decode_points)
    assert co_best < dis_best


def test_dis_tpot_beats_co_at_high_batch():
    """Paper: at high batch, dis wins TPOT (co is churning)."""
    co = _run("co-2gpus", 48).metrics.median_tpot_s
    dis = _run("dis-ici", 48).metrics.median_tpot_s
    assert dis < co
