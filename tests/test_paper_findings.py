"""Fast qualitative checks of the paper's findings on the simulator (the
full quantitative reproduction lives in benchmarks/validate_claims.py)."""
import pytest

from repro.configs import get_config
from repro.core import Cluster, SETUPS, random_workload
from repro.core.dvfs import sweep_frequencies
from repro.workload import (DEFAULT_INTERACTIVE_SLO, crossover_rate,
                            evaluate, max_goodput_rate,
                            open_loop_workload)


CFG = get_config("llama32-3b")


def _run(setup, bs, **kw):
    reqs = random_workload(bs, input_len=16_384, output_len=256)
    return Cluster(setup, CFG, **kw).run(reqs)


@pytest.fixture(scope="module")
def sweep16():
    return {s: _run(s, 16) for s in SETUPS}


def test_f1_co2gpus_best_ttft(sweep16):
    co2 = sweep16["co-2gpus"].metrics.median_ttft_s
    for s, res in sweep16.items():
        if s != "co-2gpus":
            assert co2 <= res.metrics.median_ttft_s + 1e-9, \
                f"F1 violated by {s}"


def test_f3_transfer_tier_ordering(sweep16):
    ttft = {s: sweep16[s].metrics.median_ttft_s for s in sweep16}
    assert ttft["dis-ici"] < ttft["dis-host"] < ttft["dis-disk"]
    jt = {s: sweep16[s].joules_per_token for s in sweep16}
    assert jt["dis-ici"] < jt["dis-host"] < jt["dis-disk"]


def test_f2_colocated_tpot_cliff():
    lo = _run("co-2gpus", 16).metrics
    hi = _run("co-2gpus", 32).metrics
    assert hi.median_tpot_s > 1.8 * lo.median_tpot_s, "no cliff at 32"
    assert hi.total_recomputed_tokens > 0
    # disaggregated decode must NOT cliff
    dlo = _run("dis-ici", 16).metrics
    dhi = _run("dis-ici", 32).metrics
    assert dhi.median_tpot_s < 1.5 * dlo.median_tpot_s
    assert dhi.total_recomputed_tokens == 0


def test_f5_energy_amortizes_then_spikes():
    e4 = _run("co-2gpus", 4).joules_per_token
    e16 = _run("co-2gpus", 16).joules_per_token
    e32 = _run("co-2gpus", 32).joules_per_token
    assert e16 < e4                       # static amortization
    assert e32 > e16                      # eviction spike


def test_f6_no_dis_energy_win_at_batch16():
    """Even with independent frequencies, dis can't beat co-2gpus energy
    (paper takeaway 2) — checked on a coarse grid."""
    grid = (0.42, 0.58, 0.74, 1.0)
    wl = lambda: random_workload(16, input_len=16_384, output_len=256)
    co = sweep_frequencies("co-2gpus", CFG, wl, freq_grid=grid)
    dis = sweep_frequencies("dis-ici", CFG, wl, freq_grid=grid)
    co_best = min(p.energy_j + d.energy_j for p, d in
                  zip(co.prefill_points, co.decode_points))
    dis_best = min(p.energy_j for p in dis.prefill_points) + \
        min(d.energy_j for d in dis.decode_points)
    assert co_best < dis_best


def test_dis_tpot_beats_co_at_high_batch():
    """Paper: at high batch, dis wins TPOT (co is churning)."""
    co = _run("co-2gpus", 48).metrics.median_tpot_s
    dis = _run("dis-ici", 48).metrics.median_tpot_s
    assert dis < co


# ----------------------------------------------------------------------
# the load axis (paper: "performance benefits ... depend on the request
# load and KV transfer mediums"), DistServe-style SLO goodput
# ----------------------------------------------------------------------
OPEN_SLO = DEFAULT_INTERACTIVE_SLO   # TTFT <= 2 s, TPOT <= 7.5 ms
OPEN_N = 24
LOW_RATE, MID_RATE, SAT_RATE = 2.0, 8.0, 20.0


def _open(setup, rate):
    reqs = open_loop_workload(rate, OPEN_N, slo=OPEN_SLO, seed=0)
    Cluster(setup, CFG).run(reqs)
    return reqs


@pytest.fixture(scope="module")
def load_points():
    setups = ("co-2gpus", "dis-ici", "dis-host", "dis-disk")
    return {(s, r): _open(s, r) for s in setups
            for r in (LOW_RATE, MID_RATE, SAT_RATE)}


def test_load_crossover(load_points):
    """The crossover load: below it co-2gpus matches/beats dis-ici on
    both median TTFT and SLO goodput (there is no interference to
    avoid, so the KV handoff is pure overhead); above it colocated
    prefill-priority stalls decode and the goodput winner flips to
    disaggregation, while the single dis prefill engine's queue hands
    the median-TTFT lead decisively to co-2gpus."""
    from repro.core import summarize
    med_ttft = {k: summarize(v).median_ttft_s
                for k, v in load_points.items()}
    good = {k: evaluate(v, OPEN_SLO).goodput_rps
            for k, v in load_points.items()}

    # low rate: dis-ici matches co-2gpus median TTFT (store leg only)...
    assert med_ttft[("dis-ici", LOW_RATE)] <= \
        1.15 * med_ttft[("co-2gpus", LOW_RATE)]
    # ...but co-2gpus still wins goodput: dis has not crossed yet
    assert good[("co-2gpus", LOW_RATE)] >= good[("dis-ici", LOW_RATE)]

    # saturating rate: the orderings invert — co-2gpus takes a clear
    # median-TTFT lead (2x prefill capacity vs the dis queue) while
    # dis-ici takes the goodput lead (co TPOT is interference-bound)
    assert med_ttft[("co-2gpus", SAT_RATE)] < \
        0.75 * med_ttft[("dis-ici", SAT_RATE)]
    assert good[("dis-ici", MID_RATE)] > good[("co-2gpus", MID_RATE)] + 0.5
    assert good[("dis-ici", SAT_RATE)] > good[("co-2gpus", SAT_RATE)] + 0.5

    # F3 at every rate: slower media only hurt TTFT
    for r in (LOW_RATE, MID_RATE, SAT_RATE):
        assert med_ttft[("dis-ici", r)] <= med_ttft[("dis-host", r)] \
            <= med_ttft[("dis-disk", r)]


def test_crossover_rate_bisection_locates_flip():
    c = crossover_rate("dis-ici", CFG, baseline="co-2gpus",
                       lo=LOW_RATE, hi=MID_RATE, iters=3,
                       slo=OPEN_SLO, n=OPEN_N, seed=0)
    assert c is not None, "no goodput crossover found in [2, 8] req/s"
    assert LOW_RATE < c.rate < MID_RATE
    assert c.winner_below == "co-2gpus"
    assert c.winner_above == "dis-ici"


def test_fleet_optimal_ratio_shifts_toward_prefill_with_prompt_len():
    """Fleet-scale corollary of the paper's load caveat: at a fixed
    4-instance budget, the goodput-optimal P:D ratio under the paper
    SLOs moves toward prefill as the offered prompt length grows —
    decode-heavy chat shapes want 1P:3D, the paper's long-prompt regime
    wants prefill-majority fleets. (The co->dis crossover orientation of
    ``test_load_crossover`` above is untouched: this is about splitting
    a dis fleet, not co vs dis.)"""
    from repro.core import make_cluster
    from repro.fleet import FleetSpec
    from repro.workload import PaperFixedLengths

    ratios = ((1, 3), (2, 2), (3, 1))
    ladder = [  # (prompt_len, output_len, offered rate)
        (512, 512, 16.0),     # decode-dominated interactive shape
        (8192, 128, 8.0),     # mixed
        (16_384, 64, 8.0),    # the paper's long-prompt regime
    ]
    best_frac = []
    for plen, olen, rate in ladder:
        goodput = {}
        for x, y in ratios:
            spec = FleetSpec.disaggregated(x, y, medium="ici")
            reqs = open_loop_workload(
                rate, OPEN_N, lengths=PaperFixedLengths(plen, olen),
                slo=OPEN_SLO, seed=0)
            make_cluster(spec, CFG).run(reqs)
            goodput[(x, y)] = evaluate(reqs, OPEN_SLO).goodput_rps
        x, y = max(goodput, key=goodput.get)
        best_frac.append(x / (x + y))
    assert best_frac == sorted(best_frac), \
        f"optimal prefill fraction not monotone in prompt len: {best_frac}"
    assert best_frac[-1] > best_frac[0], \
        f"no shift toward prefill: {best_frac}"


def test_max_goodput_rate_orders_capacities():
    """Under the interference-sensitive SLO, dis-ici sustains a higher
    offered rate at >=90% attainment than co-2gpus — the same crossover
    seen from the capacity side."""
    kw = dict(cfg=CFG, slo=OPEN_SLO, lo=1.0, hi=16.0, max_iters=4,
              rel_tol=0.1, n=OPEN_N, seed=0)
    cap_co = max_goodput_rate("co-2gpus", **kw)
    cap_dis = max_goodput_rate("dis-ici", **kw)
    assert 1.0 <= cap_co < cap_dis <= 16.0
    # and the crossover located by bisection sits above co's capacity
    # knee but below dis saturation
    assert cap_co < MID_RATE


def test_adaptive_fleet_energy(tmp_path):
    """Fig 9's qualitative result, pinned exactly: on diurnal traffic
    the adaptive controller (scale-to-zero + role flips) saves total
    energy vs the same static disaggregated fleet at matched SLO
    attainment — and whatever the gap-vs-colocated outcome was when the
    golden was captured, it stays bit-identical (same exact-float JSON
    discipline as the fig5/6/8 goldens)."""
    import json
    import os
    from benchmarks import fig9_adaptive_fleet
    payload = fig9_adaptive_fleet.run(
        smoke=True, out=str(tmp_path / "fig9.json"))
    norm = json.loads(json.dumps(payload))
    golden_path = os.path.join(os.path.dirname(__file__), "goldens",
                               "fig9_adaptive_fleet_smoke.json")
    with open(golden_path) as f:
        golden = json.load(f)
    assert norm == golden
    # the machine-checked claim itself, independent of the golden
    saves = payload["adaptive_saves_energy_at"]
    assert saves, "adaptive fleet never saved energy at matched SLO"
    assert all(s["saved_frac"] > 0 for s in saves)
