"""repro.govern: power-state telemetry, online DVFS governors, the
min-energy router, and the energy-accounting invariants (ISSUE 4).

The load-bearing regression: the default StaticGovernor runs inside the
event loop on EVERY cluster and must be bit-identical to pre-governor
behavior (the goldens in test_fleet.py also pin this) and to the
offline ``sweep_frequencies`` grid."""
import pytest

from repro.configs import get_config
from repro.core import Cluster, make_cluster, summarize
from repro.core.costs import DEFAULT_FREQ_GRID, CostModel
from repro.core.dvfs import sweep_frequencies
from repro.fleet import FleetCluster, FleetSpec, POLICIES, Router
from repro.govern import (GOVERNORS, PowerTrace, QueueDepthGovernor,
                          SLOSlackGovernor, StaticGovernor, make_governor)
from repro.workload import (DEFAULT_INTERACTIVE_SLO, PaperFixedLengths,
                            evaluate, open_loop_workload)

from hypothesis_compat import HAS_HYPOTHESIS, given, settings, st

CFG = get_config("llama32-3b")
SLO = DEFAULT_INTERACTIVE_SLO


# ----------------------------------------------------------------------
# PowerTrace
# ----------------------------------------------------------------------
def test_trace_records_and_integrates():
    tr = PowerTrace()
    tr.record("acc0", 0.0, 2.0, 100.0, "prefill")
    tr.record("acc0", 3.0, 4.0, 50.0, "decode")
    assert tr.energy_j("acc0") == pytest.approx(250.0)
    assert tr.busy_s("acc0") == pytest.approx(3.0)
    assert tr.span("acc0") == (0.0, 4.0)
    assert tr.gaps("acc0", 0.0, 4.0) == [(2.0, 3.0)]
    tr.record("acc0", 0.0, 0.0, 999.0, "noop")   # zero-length: dropped
    assert tr.energy_j("acc0") == pytest.approx(250.0)


def test_trace_fill_idle_covers_span():
    tr = PowerTrace()
    tr.record("acc0", 1.0, 2.0, 100.0, "prefill")
    filled = tr.fill_idle("acc0", 0.0, 5.0, 10.0)
    assert filled == pytest.approx(4.0)
    assert tr.covers("acc0", 0.0, 5.0)
    assert tr.energy_j("acc0", state="idle") == pytest.approx(40.0)
    assert tr.energy_j("acc0", state="active") == pytest.approx(100.0)
    s = tr.state_summary()["acc0"]
    assert s["idle_s"] == pytest.approx(4.0)
    assert s["active_s"] == pytest.approx(1.0)


def test_trace_timeline_matches_energy():
    tr = PowerTrace()
    tr.record("acc0", 0.0, 1.0, 100.0, "prefill")
    tr.record("acc0", 1.0, 4.0, 20.0, "idle", state="idle")
    times, watts = tr.timeline("acc0", n=400)
    assert len(times) == 400 and all(w >= 0 for w in watts)
    # midpoint-rule integral of the resampled curve ~ true joules
    integral = sum(watts) * (4.0 / 400)
    assert integral == pytest.approx(tr.energy_j("acc0"), rel=0.02)


# ----------------------------------------------------------------------
# governors: unit behavior on real engines
# ----------------------------------------------------------------------
def _loaded_prefill_engine(n_reqs, *, ttft_slo=None):
    eng = Cluster("dis-ici", CFG).prefill_engines[0]
    from repro.core.request import Request, SLO as ReqSLO
    for i in range(n_reqs):
        eng.submit(Request(req_id=i, prompt_len=4096, output_len=16,
                           slo=ReqSLO(ttft_s=ttft_slo)))
    return eng


def test_static_governor_is_a_noop():
    eng = _loaded_prefill_engine(2)
    eng.phi = 0.74
    g = StaticGovernor()
    assert g.on_step(eng) == 0.74 and eng.phi == 0.74
    assert g.decisions == []                 # no change, no record
    g2 = StaticGovernor(phi=0.5)
    assert g2.on_step(eng) == 0.5 and eng.phi == 0.5
    assert len(g2.decisions) == 1


def test_queue_depth_governor_scales_with_backlog():
    g = QueueDepthGovernor(high_tokens=8192)
    empty = _loaded_prefill_engine(0)
    assert g.decide(empty)[0] == min(g.grid)       # coast when idle
    full = _loaded_prefill_engine(4)               # 16k tokens queued
    assert g.decide(full)[0] == max(g.grid)        # flat out
    phis = [g.decide(_loaded_prefill_engine(n))[0] for n in range(4)]
    assert phis == sorted(phis)                    # monotone in load


def test_slo_slack_governor_tracks_ttft_slack():
    g = SLOSlackGovernor()
    # infinite slack -> grid floor
    assert g.decide(_loaded_prefill_engine(2, ttft_slo=1e6))[0] \
        == min(g.grid)
    # impossible target -> pinned to max
    eng = _loaded_prefill_engine(2, ttft_slo=1e-4)
    phi, signal = g.decide(eng)
    assert phi == max(g.grid) and "pinned" in signal
    # tighter targets never pick a lower phi
    phis = [g.decide(_loaded_prefill_engine(2, ttft_slo=t))[0]
            for t in (1e6, 8.0, 2.0, 0.7, 1e-4)]
    assert phis == sorted(phis)


def test_governor_registry():
    assert set(GOVERNORS) == {"static", "queue-depth", "slo-slack"}
    with pytest.raises(ValueError):
        make_governor("overclock-everything")
    g = make_governor("slo-slack", safety=0.5)
    assert isinstance(g, SLOSlackGovernor) and g.safety == 0.5
    assert g.grid == tuple(sorted(DEFAULT_FREQ_GRID))


# ----------------------------------------------------------------------
# spec plumbing
# ----------------------------------------------------------------------
def test_spec_governor_broadcast_and_validation():
    s = FleetSpec.disaggregated(2, 1, "ici", governor="queue-depth")
    assert s.governors == ("queue-depth",) * 3
    s2 = FleetSpec.disaggregated(
        1, 1, "ici", governor=("slo-slack", "static"))
    assert s2.governors == ("slo-slack", "static")
    assert hash(s2) != hash(s)                    # stays hashable
    with pytest.raises(ValueError):               # wrong arity
        FleetSpec.colocated(2, governor=("static",))
    with pytest.raises(ValueError):               # unknown name: engine
        FleetCluster(FleetSpec.colocated(1, governor="warp-speed"), CFG)


def test_cluster_governor_kwarg_overrides_spec():
    cl = make_cluster("dis-ici", CFG, governor="slo-slack")
    assert all(isinstance(e.governor, SLOSlackGovernor)
               for e in cl.engines)
    per = FleetCluster(FleetSpec.disaggregated(
        1, 1, "ici", governor=("queue-depth", "static")), CFG)
    assert isinstance(per.prefill_engines[0].governor, QueueDepthGovernor)
    assert isinstance(per.decode_engines[0].governor, StaticGovernor)


# ----------------------------------------------------------------------
# min-energy router policy
# ----------------------------------------------------------------------
def test_min_energy_router_prefers_cheap_low_clock_instances():
    assert "min-energy" in POLICIES
    cost = CostModel(CFG)

    class _E:
        def __init__(self, phi, outstanding):
            self.cost, self.phi, self.budget = cost, phi, 8192
            self._o = outstanding

        def outstanding_tokens(self):
            return self._o

    # equal queues: the downclocked instance's marginal token is cheaper
    fast, slow = _E(1.0, 1000), _E(0.5, 1000)
    assert Router([fast, slow], "min-energy", seed=0).pick() is slow
    # equal phi: the shorter queue drains for fewer joules
    busy, idle = _E(1.0, 50_000), _E(1.0, 10)
    assert Router([busy, idle], "min-energy", seed=0).pick() is idle


def test_min_energy_jpt_is_u_shaped_in_phi():
    """The projection the router ranks on reproduces the DVFS U-curve:
    the minimum-energy frequency is interior, not an endpoint."""
    cost = CostModel(CFG)
    jpt = [cost.joules_per_token(phi) for phi in DEFAULT_FREQ_GRID]
    best = jpt.index(min(jpt))
    assert 0 < best < len(jpt) - 1, jpt


# ----------------------------------------------------------------------
# parity: the default static governor is the offline sweep
# ----------------------------------------------------------------------
def test_static_governor_reproduces_sweep_frequencies_bit_identically():
    wl = lambda: open_loop_workload(   # noqa: E731
        6.0, 8, lengths=PaperFixedLengths(2048, 16), slo=SLO, seed=0)
    sw = sweep_frequencies("dis-ici", CFG, wl, freq_grid=(0.58, 1.0))
    for phi in (0.58, 1.0):
        res = make_cluster("dis-ici", CFG, phi=phi).run(wl())
        ref = sw.results[phi]
        assert res.energy.total_j == ref.energy.total_j
        assert res.metrics.median_ttft_s == ref.metrics.median_ttft_s
        assert res.metrics.median_tpot_s == ref.metrics.median_tpot_s


def test_adaptive_governor_beats_static_max_energy_on_dis():
    """The headline positive result behind fig8 check (a): at a load
    near the colocated knee, the SLO-slack governor on dis-ici keeps
    attainment >= 0.9 while burning less energy than static phi=1.0."""
    def run(**kw):
        reqs = open_loop_workload(4.0, 16, slo=SLO, seed=0)
        res = make_cluster("dis-ici", CFG, **kw).run(reqs)
        return res.energy.total_j, evaluate(reqs, SLO).attainment

    e_static, att_static = run(phi=1.0)
    e_gov, att_gov = run(governor="slo-slack")
    assert att_gov >= 0.9 and att_static >= 0.9
    assert e_gov < e_static, (e_gov, e_static)


def test_governor_decisions_are_recorded_and_deterministic():
    def once():
        reqs = open_loop_workload(6.0, 10, slo=SLO, seed=3)
        cl = make_cluster("dis-ici", CFG, governor="slo-slack")
        cl.run(reqs)
        return [(d.t, d.engine, d.phi) for e in cl.engines
                for d in e.governor.decisions]

    a, b = once(), once()
    assert a and a == b
    assert all(phi in make_governor("slo-slack").grid for _, _, phi in a)


# ----------------------------------------------------------------------
# energy-accounting invariants (hypothesis when available)
# ----------------------------------------------------------------------
def _check_energy_invariants(spec, arrival, rate, seed):
    reqs = open_loop_workload(rate, 6, arrival=arrival,
                              lengths=PaperFixedLengths(768, 6),
                              slo=SLO, seed=seed)
    cl = FleetCluster(spec, CFG)
    res = cl.run(reqs)
    meter = res.energy
    # stage attribution is a partition of the total
    assert sum(meter.by_stage.values()) == \
        pytest.approx(meter.total_j, rel=1e-9)
    trace = meter.trace
    t0 = min(r.arrival_s for r in reqs)
    t1 = max(r.finish_s for r in reqs)
    for e in cl.engines:
        samples = trace.samples.get(e.name, [])
        assert samples, f"{e.name} has no power samples"
        assert all(s.watts >= 0 and s.seconds >= 0 for s in samples)
        # the power-state timeline covers the whole run span
        assert trace.covers(e.name, t0, t1, tol=1e-6), \
            trace.gaps(e.name, t0, t1)
        # trace busy time agrees with the engine's own busy clock
        assert trace.busy_s(e.name) == pytest.approx(e.busy_s, rel=1e-9)
        # trace-integrated accelerator joules agree with the meter
        assert trace.energy_j(e.name) == \
            pytest.approx(meter.joules[e.name], rel=1e-6)
    for r in reqs:
        assert r.done


GOVS = sorted(GOVERNORS)


@settings(max_examples=20, deadline=None)
@given(colocated=st.booleans(),
       x=st.integers(min_value=1, max_value=2),
       y=st.integers(min_value=1, max_value=2),
       medium_i=st.integers(min_value=0, max_value=2),
       gov_i=st.integers(min_value=0, max_value=2),
       arrival=st.sampled_from(["poisson", "gamma", "deterministic"]),
       rate=st.sampled_from([4.0, 20.0]),
       seed=st.integers(min_value=0, max_value=2 ** 16))
def test_energy_invariants_any_fleet_governor_seed(
        colocated, x, y, medium_i, gov_i, arrival, rate, seed):
    """For ANY fleet shape, governor, arrival process, and seed:
    by_stage partitions total_j, power traces are non-negative and
    cover the full run span, and trace integrals match the meter."""
    gov = GOVS[gov_i]
    if colocated:
        spec = FleetSpec.colocated(1 + x % 2, governor=gov)
    else:
        spec = FleetSpec.disaggregated(
            x, y, ("ici", "host", "disk")[medium_i], governor=gov)
    _check_energy_invariants(spec, arrival, rate, seed)


if not HAS_HYPOTHESIS:
    def test_energy_invariants_fixed_examples():
        for gov in GOVS:
            _check_energy_invariants(
                FleetSpec.disaggregated(2, 1, "host", governor=gov),
                "gamma", 10.0, 11)
            _check_energy_invariants(
                FleetSpec.colocated(2, governor=gov), "poisson", 4.0, 3)
