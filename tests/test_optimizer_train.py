"""Optimizer + data pipeline + training-loop substrate."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import REGISTRY, reduce_for_smoke
from repro.train.data import SyntheticLM
from repro.train.optimizer import (adamw, apply_updates, cosine_schedule,
                                   global_norm)


def test_adamw_minimizes_quadratic():
    opt = adamw(0.1, weight_decay=0.0, grad_clip_norm=None)
    params = {"w": jnp.array([5.0, -3.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = jax.tree.map(lambda p: 2 * p, params)   # d/dp ||p||^2
        updates, state = opt.update(grads, state, params)
        params = apply_updates(params, updates)
    assert float(jnp.max(jnp.abs(params["w"]))) < 1e-2


def test_grad_clip_bounds_update():
    opt = adamw(1.0, grad_clip_norm=1.0, weight_decay=0.0)
    params = {"w": jnp.zeros(4)}
    state = opt.init(params)
    huge = {"w": jnp.full(4, 1e9)}
    updates, state = opt.update(huge, state, params)
    assert np.all(np.isfinite(np.asarray(updates["w"])))


def test_global_norm():
    t = {"a": jnp.array([3.0]), "b": jnp.array([4.0])}
    assert float(global_norm(t)) == pytest.approx(5.0)


def test_cosine_schedule_shape():
    lr = cosine_schedule(1.0, warmup_steps=10, total_steps=100,
                         final_frac=0.1)
    vals = [float(lr(jnp.asarray(s))) for s in (0, 5, 10, 55, 100)]
    assert vals[0] == 0.0
    assert vals[1] == pytest.approx(0.5)
    assert vals[2] == pytest.approx(1.0)
    assert 0.1 < vals[3] < 1.0
    assert vals[4] == pytest.approx(0.1)


def test_moments_are_f32_under_bf16_params():
    opt = adamw(1e-3)
    params = {"w": jnp.zeros((4,), jnp.bfloat16)}
    state = opt.init(params)
    assert state.m["w"].dtype == jnp.float32
    assert state.v["w"].dtype == jnp.float32


# ----------------------------------------------------------------------
def test_data_cursor_determinism_and_resume():
    cfg = reduce_for_smoke(REGISTRY["llama32-3b"])
    a = SyntheticLM(cfg, 4, 32, seed=7)
    stream = [a.next_batch() for _ in range(5)]
    b = SyntheticLM(cfg, 4, 32, seed=7)
    for _ in range(3):
        b.next_batch()
    c = SyntheticLM(cfg, 4, 32, seed=7)
    c.restore(b.cursor.as_dict())
    np.testing.assert_array_equal(c.next_batch()["tokens"],
                                  stream[3]["tokens"])
    np.testing.assert_array_equal(c.next_batch()["targets"],
                                  stream[4]["targets"])


def test_data_families_have_right_keys():
    for arch in ("internvl2-2b", "seamless-m4t-medium", "llama32-3b"):
        cfg = reduce_for_smoke(REGISTRY[arch])
        d = SyntheticLM(cfg, 2, 32, seed=0)
        batch = d.next_batch()
        assert "tokens" in batch and "targets" in batch
        if cfg.family == "vlm":
            assert batch["patches"].shape[1] == cfg.vision.num_patches
        if cfg.family == "encdec":
            assert batch["src_embeds"].shape[1] == 32


def test_training_reduces_loss():
    """Steps on a tiny model over the learnable synthetic stream must
    reduce loss measurably (deliverable b: end-to-end driver sanity)."""
    from repro.launch.train import train
    losses, wd = train("qwen2-0.5b", smoke=True, steps=40, batch_size=4,
                       seq_len=32, verbose=False)
    assert len(losses) == 40
    first = np.mean(losses[:5])
    last = np.mean(losses[-5:])
    assert last < first - 0.05, f"loss did not improve: {first} -> {last}"
