"""PowerTrace edge cases (ISSUE 9 satellite): empty traces, unknown
components, zero-length samples, degenerate timeline grids — the states
a post-run reporting path can hand the sampler, none of which may raise
or mis-count."""
import numpy as np
import pytest

from repro.govern.telemetry import ACTIVE, IDLE, PowerTrace


def test_empty_trace():
    tr = PowerTrace()
    assert tr.components == []
    assert tr.samples == {}
    assert tr.energy_j() == 0.0
    assert tr.state_summary() == {}
    assert tr.timeline("nope") == ([], [])
    assert tr.span("nope") == (0.0, 0.0)
    assert tr.busy_s("nope") == 0.0
    assert tr.intervals("nope") == []
    assert tr.gaps("nope", 0.0, 1.0) == [(0.0, 1.0)]


def test_zero_length_sample_is_dropped():
    tr = PowerTrace()
    tr.record("acc0", 1.0, 1.0, 300.0)          # t1 == t0
    tr.record("acc0", 2.0, 1.5, 300.0)          # t1 < t0
    assert tr.components == []
    assert tr.energy_j() == 0.0


def test_single_sample_summary_and_timeline():
    tr = PowerTrace()
    tr.record("acc0", 1.0, 3.0, 250.0, stage="decode")
    s = tr.state_summary()["acc0"]
    assert s["active_j"] == pytest.approx(500.0)
    assert s["active_s"] == pytest.approx(2.0)
    assert s["idle_j"] == 0.0
    times, watts = tr.timeline("acc0", n=4)
    assert len(times) == len(watts) == 4
    assert all(w == pytest.approx(250.0) for w in watts)


def test_timeline_degenerate_grids():
    tr = PowerTrace()
    tr.record("acc0", 1.0, 2.0, 100.0)
    assert tr.timeline("acc0", n=0) == ([], [])
    assert tr.timeline("acc0", n=-3) == ([], [])
    times, watts = tr.timeline("acc0", n=1)
    assert times == [pytest.approx(1.5)] and watts == [pytest.approx(100.0)]


def test_timeline_zero_width_span():
    """A component whose only samples were zero-length never materializes;
    but a span collapsed to a point via record_run must not divide by
    zero either."""
    tr = PowerTrace()
    tr.record_run("acc0", np.array([1.0]), np.array([1.0]),
                  np.array([50.0]))
    assert tr.timeline("acc0", n=16) == ([], [])
    assert tr.energy_j("acc0") == 0.0


def test_missing_component_energy_filters():
    tr = PowerTrace()
    tr.record("acc0", 0.0, 1.0, 10.0, state=ACTIVE)
    tr.record("acc0", 1.0, 2.0, 3.0, state=IDLE)
    assert tr.energy_j("acc1") == 0.0
    assert tr.energy_j("acc0", state=IDLE) == pytest.approx(3.0)
    assert tr.energy_j(state="sleep") == 0.0
    assert tr.busy_s("acc0") == pytest.approx(1.0)


def test_nonstandard_state_gets_own_summary_keys():
    tr = PowerTrace()
    tr.record("acc0", 0.0, 2.0, 5.0, state="boost")
    s = tr.state_summary()["acc0"]
    assert s["boost_j"] == pytest.approx(10.0)
    assert s["boost_s"] == pytest.approx(2.0)
    assert s["active_j"] == 0.0


def test_fill_idle_never_backfills_covered_time():
    tr = PowerTrace()
    tr.record("acc0", 1.0, 2.0, 100.0)
    filled = tr.fill_idle("acc0", 0.0, 3.0, idle_watts=7.0)
    assert filled == pytest.approx(2.0)
    assert tr.energy_j("acc0", state=IDLE) == pytest.approx(14.0)
    assert tr.covers("acc0", 0.0, 3.0)
    # idempotent: a second fill finds no gaps
    assert tr.fill_idle("acc0", 0.0, 3.0, idle_watts=7.0) == 0.0


def test_record_run_noncontiguous_falls_back_per_sample():
    tr = PowerTrace()
    t0s = np.array([0.0, 5.0])              # gap: not a contiguous run
    t1s = np.array([1.0, 6.0])
    tr.record_run("acc0", t0s, t1s, np.array([10.0, 20.0]))
    assert tr.intervals("acc0") == [(0.0, 1.0), (5.0, 6.0)]
    assert tr.energy_j("acc0") == pytest.approx(30.0)
    assert tr.busy_s("acc0") == pytest.approx(2.0)
