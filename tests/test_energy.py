"""Energy meter, DVFS power model, Pareto utilities (paper Experiment 2
machinery) — unit + hypothesis properties."""
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.configs import get_config
from repro.core import (CostModel, EnergyMeter, ParetoPoint,
                        min_energy_under_slo, pareto_frontier, sweet_spot)
from repro.core.costs import DEFAULT_FREQ_GRID, StepCost


def test_meter_accumulates_and_merges():
    a, b = EnergyMeter(), EnergyMeter()
    a.add_power("acc0", 100.0, 2.0, stage="prefill")
    b.add("cpu", 50.0, stage="transfer")
    m = a.merge(b)
    assert m.total_j == pytest.approx(250.0)
    assert m.joules["acc0"] == pytest.approx(200.0)
    assert m.by_stage["prefill"] == pytest.approx(200.0)


def test_power_model_monotone_in_phi():
    cost = CostModel(get_config("llama32-3b"))
    ps = [cost.power_w(phi, 1.0) for phi in DEFAULT_FREQ_GRID]
    assert all(p2 > p1 for p1, p2 in zip(ps, ps[1:]))
    assert cost.power_w(0.0, 1.0) == pytest.approx(cost.idle_power_w())


def test_step_cost_dvfs_semantics():
    c = StepCost(compute_s=1.0, memory_s=0.5)
    assert c.time(1.0) == 1.0
    assert c.time(0.5) == 2.0            # compute stretches
    m = StepCost(compute_s=0.1, memory_s=1.0)
    assert m.time(0.5) == 1.0            # memory-bound: phi is free
    assert m.utilization(1.0) == pytest.approx(0.1)


def test_energy_u_curve_exists():
    """E(phi) = P(phi) * T(phi) is U-shaped for a mixed-bound step: the
    paper's central DVFS observation."""
    cost = CostModel(get_config("llama32-3b"))
    step = StepCost(compute_s=1.0, memory_s=0.6)
    energies = [cost.power_w(phi, step.utilization(phi)) * step.time(phi)
                for phi in DEFAULT_FREQ_GRID]
    best = int(np.argmin(energies))
    assert 0 < best < len(energies) - 1, \
        f"sweet spot at the grid edge: {energies}"


# ----------------------------------------------------------------------
def _pts(vals):
    return [ParetoPoint(phi=0.1 * i, latency_s=l, energy_j=e)
            for i, (l, e) in enumerate(vals)]


def test_pareto_frontier_basic():
    pts = _pts([(1.0, 5.0), (2.0, 3.0), (3.0, 4.0), (4.0, 1.0)])
    front = pareto_frontier(pts)
    assert [(p.latency_s, p.energy_j) for p in front] == \
        [(1.0, 5.0), (2.0, 3.0), (4.0, 1.0)]


def test_slo_selection():
    pts = _pts([(1.0, 5.0), (2.0, 3.0), (4.0, 1.0)])
    assert min_energy_under_slo(pts, 2.5).energy_j == 3.0
    assert min_energy_under_slo(pts, 0.5) is None
    assert min_energy_under_slo(pts, None).energy_j == 1.0
    assert sweet_spot(pts).energy_j == 1.0


@settings(max_examples=50, deadline=None)
@given(st.lists(st.tuples(st.floats(0.01, 100), st.floats(0.01, 100)),
                min_size=1, max_size=30))
def test_pareto_frontier_is_nondominated(vals):
    pts = _pts(vals)
    front = pareto_frontier(pts)
    # 1) every frontier point is a real point
    assert all(p in pts for p in front)
    # 2) no frontier point dominates another
    for p in front:
        for q in front:
            if p is not q:
                assert not (q.latency_s <= p.latency_s
                            and q.energy_j <= p.energy_j
                            and (q.latency_s < p.latency_s
                                 or q.energy_j < p.energy_j))
    # 3) every non-frontier point is dominated by some frontier point
    for p in pts:
        if p not in front:
            assert any(q.latency_s <= p.latency_s
                       and q.energy_j <= p.energy_j for q in front)
