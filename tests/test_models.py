"""Per-architecture smoke tests (assignment deliverable f) + the
prefill/decode == full-forward consistency property for every family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, REGISTRY, get_config, \
    reduce_for_smoke
from repro.models import get_model

ALL_ARCHS = ASSIGNED_ARCHS + ["llama32-3b"]


@pytest.fixture(scope="module")
def rng():
    return jax.random.PRNGKey(0)


# ----------------------------------------------------------------------
@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_forward_and_train_step(arch, rng):
    """Reduced config: one forward + one train step; shapes + finiteness."""
    cfg = reduce_for_smoke(get_config(arch))
    model = get_model(cfg)
    params = model.init(rng)
    B, S = 2, 32
    batch = model.sample_batch(jax.random.fold_in(rng, 1), B, S)
    logits = model.forward(params, batch)
    S_out = batch["targets"].shape[1]
    assert logits.shape == (B, S_out, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))

    loss, metrics = model.loss(params, batch, remat=True)
    assert np.isfinite(float(loss))
    grads = jax.grad(lambda p: model.loss(p, batch, remat=False)[0])(params)
    gnorm = sum(float(jnp.sum(jnp.square(g.astype(jnp.float32))))
                for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_prefill_decode_matches_forward(arch, rng):
    """The KV/state handoff invariant: prefill(x[:-1]) + decode(x[-1])
    reproduces forward(x) logits at the last two positions."""
    cfg = reduce_for_smoke(get_config(arch))
    model = get_model(cfg)
    params = model.init(rng)
    B, S = 2, 16
    key = jax.random.fold_in(rng, 2)
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)

    if cfg.family == "encdec":
        src = jax.random.normal(jax.random.fold_in(rng, 3),
                                (B, 24, cfg.encdec.frontend_dim)) * 0.1
        full = model.forward(params, {"src_embeds": src, "tokens": toks})
        logits, state = model.prefill(
            params, {"src_embeds": src, "tokens": toks[:, :S - 1]},
            s_max=S)
        pos = jnp.full((B,), S - 1, jnp.int32)
    elif cfg.family == "vlm":
        Np = cfg.vision.num_patches
        patches = jax.random.normal(jax.random.fold_in(rng, 3),
                                    (B, Np, cfg.vision.frontend_dim)) * 0.1
        full = model.forward(params, {"patches": patches, "tokens": toks})
        logits, state = model.prefill(
            params, {"patches": patches, "tokens": toks[:, :S - 1]},
            s_max=Np + S)
        pos = jnp.full((B,), Np + S - 1, jnp.int32)
    else:
        full = model.forward(params, {"tokens": toks})
        logits, state = model.prefill(params, {"tokens": toks[:, :S - 1]},
                                      s_max=S)
        pos = jnp.full((B,), S - 1, jnp.int32)

    atol = 2e-4
    np.testing.assert_allclose(np.asarray(logits),
                               np.asarray(full[:, S - 2]), atol=atol,
                               rtol=1e-3)
    dec, _ = model.decode_step(params, toks[:, S - 1], state, pos)
    np.testing.assert_allclose(np.asarray(dec),
                               np.asarray(full[:, S - 1]), atol=atol,
                               rtol=1e-3)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_multi_step_decode_matches_forward(arch, rng):
    """Roll 4 decode steps and compare every step against full forward."""
    cfg = reduce_for_smoke(get_config(arch))
    if cfg.family in ("encdec", "vlm"):
        pytest.skip("covered by the single-step variant (dict inputs)")
    model = get_model(cfg)
    params = model.init(rng)
    B, S, K = 1, 12, 4
    toks = jax.random.randint(jax.random.fold_in(rng, 4), (B, S), 0,
                              cfg.vocab_size)
    full = model.forward(params, {"tokens": toks})
    prefix = S - K
    _, state = model.prefill(params, {"tokens": toks[:, :prefix]}, s_max=S)
    for i in range(K):
        pos = jnp.full((B,), prefix + i, jnp.int32)
        logits, state = model.decode_step(params, toks[:, prefix + i],
                                          state, pos)
        np.testing.assert_allclose(
            np.asarray(logits), np.asarray(full[:, prefix + i]),
            atol=3e-4, rtol=1e-3,
            err_msg=f"{arch}: decode step {i} diverged")


# ----------------------------------------------------------------------
@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_param_count_formula_matches_init(arch):
    """base.ModelConfig.param_count (used for MODEL_FLOPS) vs real init."""
    cfg = reduce_for_smoke(get_config(arch))
    model = get_model(cfg)
    exact = model.param_count()
    formula = cfg.param_count()
    # formulas track init to within a few percent (loras/mus differences
    # documented in base.py); MODEL_FLOPS only needs this accuracy
    assert abs(exact - formula) / exact < 0.08, \
        f"{arch}: init={exact} formula={formula}"


@pytest.mark.parametrize("arch", ["yi-34b", "deepseek-moe-16b", "rwkv6-3b",
                                  "zamba2-2.7b", "seamless-m4t-medium"])
def test_full_config_param_count_sane(arch):
    """Full (unreduced) configs: abstract param count matches the model's
    nameplate size to within 20%."""
    # seamless nameplate counts the speech frontend we stub per the
    # assignment; 0.88B is the text backbone + embeddings share.
    nameplate = {"yi-34b": 34.4e9, "deepseek-moe-16b": 16.4e9,
                 "rwkv6-3b": 3.1e9, "zamba2-2.7b": 2.7e9,
                 "seamless-m4t-medium": 0.88e9}
    cfg = get_config(arch)
    model = get_model(cfg)
    n = model.param_count()
    assert abs(n - nameplate[arch]) / nameplate[arch] < 0.35, \
        f"{arch}: {n / 1e9:.2f}B vs nameplate {nameplate[arch] / 1e9:.1f}B"


def test_kv_bytes_per_token_llama():
    """The paper's central quantity for its own model."""
    cfg = get_config("llama32-3b")
    assert cfg.kv_bytes_per_token() == 2 * 28 * 8 * 128 * 2  # = 114,688


def test_moe_active_params_smaller():
    cfg = get_config("deepseek-moe-16b")
    assert cfg.param_count(active_only=True) < 0.35 * cfg.param_count()


def test_ssm_has_no_kv_but_fixed_state():
    cfg = get_config("rwkv6-3b")
    assert cfg.kv_bytes_per_token() == 0
    assert cfg.state_bytes() > 0


def test_hybrid_kv_only_for_shared_blocks():
    cfg = get_config("zamba2-2.7b")
    dense_like = 2 * cfg.num_layers * cfg.num_kv_heads * cfg.head_dim * 2
    assert cfg.kv_bytes_per_token() == dense_like // 6  # every 6th layer
