"""Perf-marked acceptance tests for the simulator-core fast path.

Excluded from the default pytest run (see pytest.ini addopts); CI's
``perf`` lane runs them with ``-m perf``. Assertions are ratio-based —
fast vs exact on the same machine in the same process — so they hold on
slow CI boxes where absolute wall-clock would not.
"""
import json
import os
import sys

import pytest

sys.path.insert(0, os.path.dirname(os.path.dirname(__file__)))

from benchmarks import perf_bench  # noqa: E402

pytestmark = pytest.mark.perf


def test_fleet_scenario_speedup_meets_acceptance_bar():
    """The PR's headline number: on the fleet-scale scenario the
    coalescing stepper must be >=10x faster than the exact reference,
    cold (cluster construction included), while executing exactly the
    same number of engine steps."""
    exact = perf_bench.time_scenario("fleet", "exact", reps=2)
    fast = perf_bench.time_scenario("fleet", "fast", reps=2)
    assert exact["engine_steps"] == fast["engine_steps"]
    speedup = exact["wall_s"] / fast["wall_s"]
    assert speedup >= 10.0, \
        (f"fleet speedup {speedup:.1f}x < 10x "
         f"(exact {exact['wall_s']*1e3:.1f}ms, "
         f"fast {fast['wall_s']*1e3:.1f}ms)")


def test_small_scenarios_never_slower():
    """Coalescing must never lose: even the small single-engine scenario
    (least steady-state decode to harvest) stays clearly ahead."""
    for name in ("small", "medium"):
        exact = perf_bench.time_scenario(name, "exact", reps=2)
        fast = perf_bench.time_scenario(name, "fast", reps=2)
        assert exact["wall_s"] / fast["wall_s"] >= 1.5, name


def test_committed_baseline_is_well_formed():
    """benchmarks/BENCH_simcore.json is a tracked artifact other tooling
    (the CI --check gate) trusts: every scenario present, with both
    stepper rows and a recorded speedup that itself clears the bar the
    regression check defends."""
    with open(perf_bench.BASELINE) as f:
        base = json.load(f)
    assert set(base["scenarios"]) == set(perf_bench.SCENARIOS)
    for name, row in base["scenarios"].items():
        for stepper in ("exact", "fast"):
            assert row[stepper]["wall_s"] > 0
            assert row[stepper]["engine_steps"] > 0
        assert row["speedup"] > 1.0
    assert base["scenarios"]["fleet"]["speedup"] >= 10.0
