"""HLO collective parsing + roofline term machinery."""
import pytest

from repro.configs import SHAPES, get_config
from repro.dist.hlo_analysis import (RooflineTerms, collective_stats,
                                     linear_extrapolate, model_flops,
                                     vmem_resident_traffic)

HLO = """
HloModule jit_step
ENTRY main {
  %p = bf16[8,1024,128]{2,1,0} parameter(0)
  %ag = bf16[8,16384,128]{2,1,0} all-gather(%p), dimensions={1}
  %ar = f32[4096]{0} all-reduce(%x), to_apply=%add
  %ar2 = f32[4096]{0} all-reduce-start(%y), to_apply=%add
  %rs = bf16[2,64]{1,0} reduce-scatter(%z), dimensions={0}
  %cp = bf16[128,8]{1,0} collective-permute(%w)
  %a2a = (f32[16]{0}, f32[16]{0}) all-to-all(%u, %v)
  %dot = f32[64,64]{1,0} dot(%a, %b)
}
"""


def test_collective_stats_parses_all_kinds():
    st = collective_stats(HLO)
    assert st.count_by_kind == {"all-gather": 1, "all-reduce": 2,
                                "reduce-scatter": 1,
                                "collective-permute": 1, "all-to-all": 1}
    assert st.bytes_by_kind["all-gather"] == 8 * 16384 * 128 * 2
    assert st.bytes_by_kind["all-reduce"] == 2 * 4096 * 4
    assert st.bytes_by_kind["all-to-all"] == 2 * 16 * 4
    assert st.total_count == 6


def test_collective_stats_ignores_non_collectives():
    assert collective_stats("%d = f32[8]{0} dot(%a, %b)").total_bytes == 0


def test_roofline_terms_dominance():
    t = RooflineTerms(flops=197e12, hbm_bytes=819e9 * 3,
                      collective_bytes=0, n_chips=256)
    assert t.compute_s == pytest.approx(1.0)
    assert t.memory_s == pytest.approx(3.0)
    assert t.dominant == "memory"
    assert t.step_time_s == pytest.approx(3.0)


def test_vmem_adjustment_reduces_memory_term():
    t = RooflineTerms(flops=1e12, hbm_bytes=1e12, collective_bytes=0,
                      n_chips=256, vmem_resident_bytes=4e11)
    assert t.memory_s < t.memory_s_raw
    assert t.memory_s == pytest.approx((1e12 - 4e11) / 819e9)


def test_linear_extrapolate_exact():
    # f(L) = 10 + 3L
    assert linear_extrapolate(13, 16, 1, 2, 60) == pytest.approx(190)


def test_model_flops_train_vs_serve():
    cfg = get_config("llama32-3b")
    tr = model_flops(cfg, SHAPES["train_4k"], 256)
    pf = model_flops(cfg, SHAPES["prefill_32k"], 256)
    dc = model_flops(cfg, SHAPES["decode_32k"], 256)
    assert tr == pytest.approx(
        6 * cfg.param_count(active_only=True) * 256 * 4096 / 256)
    assert pf == pytest.approx(tr / 3)   # same token count, fwd-only
    assert dc < pf / 1000                # one token per seq


def test_moe_uses_active_params():
    cfg = get_config("deepseek-moe-16b")
    dense_equiv = 6 * cfg.param_count() * 256 * 4096 / 256
    assert model_flops(cfg, SHAPES["train_4k"], 256) < 0.4 * dense_equiv


def test_vmem_traffic_zero_for_pure_ssm_attention():
    cfg = get_config("rwkv6-3b")
    v = vmem_resident_traffic(cfg, SHAPES["train_4k"], 256)
    assert v > 0                          # scan-state stream
    cfg2 = get_config("yi-34b")
    v2 = vmem_resident_traffic(cfg2, SHAPES["train_4k"], 256)
    assert v2 > 0                         # attention logits
