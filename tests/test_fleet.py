"""Fleet subsystem tests: spec validation, router policies, property
tests over random fleet shapes, the 1P:1D / colocated parity regression
(golden metrics captured from the pre-fleet ``Cluster``), and the
least-outstanding-tokens routing fix for ``co-2gpus``."""
import pytest

from repro.configs import get_config
from repro.core import Cluster, make_cluster, random_workload, summarize
from repro.fleet import (FleetCluster, FleetSpec, POLICIES, Router,
                         as_fleet_spec, make_policy, setup_label)
from repro.workload import (DEFAULT_INTERACTIVE_SLO, GammaArrivals,
                            PaperFixedLengths, ShareGPTLengths,
                            WorkloadSpec, max_goodput_rate,
                            open_loop_workload)

from hypothesis_compat import HAS_HYPOTHESIS, given, settings, st

CFG = get_config("llama32-3b")
SLO = DEFAULT_INTERACTIVE_SLO


# ----------------------------------------------------------------------
# FleetSpec
# ----------------------------------------------------------------------
def test_spec_validation():
    with pytest.raises(ValueError):
        FleetSpec(n_colocated=2, n_prefill=1, n_decode=1, medium="ici")
    with pytest.raises(ValueError):
        FleetSpec(n_prefill=1, n_decode=0, medium="ici")
    with pytest.raises(ValueError):
        FleetSpec(n_prefill=1, n_decode=1, medium="nvlink")
    with pytest.raises(ValueError):
        FleetSpec.colocated(2, medium="ici")
    with pytest.raises(ValueError):   # wrong per-instance phi arity
        FleetSpec.disaggregated(2, 1, "ici", phi_prefill=(1.0, 0.8, 0.6))
    with pytest.raises(ValueError):   # non-positive phi
        FleetSpec.colocated(1, phi_prefill=0.0)


def test_spec_names_and_from_setup():
    assert FleetSpec.disaggregated(2, 3, "host").name == "2P3D-host"
    assert FleetSpec.colocated(2).name == "co-2"
    assert FleetSpec.from_setup("dis-ici") == \
        FleetSpec.disaggregated(1, 1, "ici")
    assert FleetSpec.from_setup("co-2gpus").n_colocated == 2
    assert as_fleet_spec("dis-disk").medium == "disk"
    assert setup_label("dis-ici") == "dis-ici"
    assert setup_label(FleetSpec.colocated(3)) == "co-3"
    with pytest.raises(ValueError):
        FleetSpec.from_setup("dis-nvlink")


def test_spec_parse_roundtrips_name():
    for spec in (FleetSpec.disaggregated(2, 2, "ici"),
                 FleetSpec.disaggregated(1, 3, "disk"),
                 FleetSpec.colocated(3)):
        assert FleetSpec.parse(spec.name) == spec
    assert FleetSpec.parse("dis-host") == \
        FleetSpec.disaggregated(1, 1, "host")
    for bad in ("2P2D-nvlink", "co-x", "co-0", "2P-ici", "gibberish"):
        with pytest.raises(ValueError):
            FleetSpec.parse(bad)


def test_spec_phi_broadcast_and_override():
    s = FleetSpec.disaggregated(2, 2, "ici", phi_prefill=(1.0, 0.8))
    assert s.phis_prefill == (1.0, 0.8)
    assert s.phis_decode == (1.0, 1.0)
    s2 = s.with_phi(phi=0.5)
    assert s2.phis_prefill == (0.5, 0.5) and s2.phis_decode == (0.5, 0.5)
    s3 = s.with_phi(phi=0.5, phi_decode=0.9)
    assert s3.phis_prefill == (0.5, 0.5) and s3.phis_decode == (0.9, 0.9)
    # frozen + hashable: sweep caches key on the spec itself
    assert len({s, s2, s3, s}) == 3
    # list/int phis canonicalize to their tuple/float twins, so the
    # cache contract holds for every spelling of the same fleet
    assert FleetSpec.disaggregated(2, 2, "ici", phi_prefill=[1, 0.8]) == s
    assert hash(FleetSpec.colocated(2, phi_prefill=1)) == \
        hash(FleetSpec.colocated(2))


# ----------------------------------------------------------------------
# Router policies
# ----------------------------------------------------------------------
class _FakeEngine:
    def __init__(self, outstanding, free_pages):
        self._o = outstanding
        self.pool = type("P", (), {"free_pages": free_pages})()
        self.decode_queue = []          # no routed-but-unadmitted work

    def outstanding_tokens(self):
        return self._o


def test_round_robin_rotates():
    engines = [_FakeEngine(0, 0) for _ in range(3)]
    r = Router(engines, "round-robin", seed=0)
    picks = [r.pick() for _ in range(6)]
    assert picks == engines + engines


def test_least_outstanding_tokens_picks_idle():
    busy, idle = _FakeEngine(1000, 0), _FakeEngine(10, 0)
    r = Router([busy, idle], "least-outstanding-tokens", seed=0)
    assert r.pick() is idle


def test_kv_free_space_picks_emptiest_pool():
    full, empty = _FakeEngine(0, 2), _FakeEngine(0, 50)
    r = Router([full, empty], "kv-free-space", seed=0)
    assert r.pick() is empty


def test_tie_break_is_seed_deterministic():
    engines = [_FakeEngine(5, 5) for _ in range(4)]   # all tied
    def picks(seed):
        r = Router(engines, "least-outstanding-tokens", seed=seed)
        return [engines.index(r.pick()) for _ in range(16)]
    assert picks(3) == picks(3)            # reproducible from the seed
    assert len(set(picks(3))) > 1          # ties genuinely spread


def test_kv_free_space_sees_inflight_transfers():
    """Transfers still in their store leg must count against the target
    (else a burst of prefill completions all routes to one instance)."""
    a, b = _FakeEngine(0, 50), _FakeEngine(0, 50)
    a.inflight_kv_pages = 40            # routed here, store leg pending
    r = Router([a, b], "kv-free-space", seed=0)
    assert r.pick() is b


def test_unknown_policy_rejected():
    with pytest.raises(ValueError):
        make_policy("most-vibes")
    assert set(POLICIES) == {"round-robin", "least-outstanding-tokens",
                             "kv-free-space", "min-energy",
                             "prefix-affinity"}


def test_engine_outstanding_tokens_is_role_aware():
    """A prefill engine's outstanding work is prefill-side only (decode
    happens elsewhere); a colocated engine owns both stages."""
    pre = Cluster("dis-ici", CFG).prefill_engines[0]
    assert pre.outstanding_tokens() == 0
    for r in random_workload(3, input_len=512, output_len=8):
        pre.submit(r)
    assert pre.outstanding_tokens() == 3 * 512
    co = Cluster("co-1gpu", CFG).engines[0]
    for r in random_workload(3, input_len=512, output_len=8):
        co.submit(r)
    assert co.outstanding_tokens() == 3 * (512 + 8)


# ----------------------------------------------------------------------
# parity regression: the facade reproduces the pre-fleet Cluster
# bit-for-bit (goldens captured at the refactor commit's parent)
# ----------------------------------------------------------------------
GOLDEN = {
    "dis-ici/open/seed0": {"median_ttft_s": 0.03943107493685272, "p99_ttft_s": 0.06021337592203507, "median_tpot_s": 0.002105340874236868, "p99_tpot_s": 0.002165812221620383, "makespan_s": 2.50723394394275, "goodput_rps": 4.786150901072041, "total_j": 1696.4141236396606},  # noqa: E501
    "dis-ici/open/seed7": {"median_ttft_s": 0.03943107493685277, "p99_ttft_s": 0.07152665725829731, "median_tpot_s": 0.0021262065329079485, "p99_tpot_s": 0.002174077954137622, "makespan_s": 3.138829125448233, "goodput_rps": 3.823081639809357, "total_j": 2062.739328912841},  # noqa: E501
    "dis-host/open/seed0": {"median_ttft_s": 0.09618252069685274, "p99_ttft_s": 0.11704275123827444, "median_tpot_s": 0.002105340874236868, "p99_tpot_s": 0.0030793353349812995, "makespan_s": 2.56398538970275, "goodput_rps": 4.680213876488272, "total_j": 1801.1198410668605},  # noqa: E501
    "dis-host/open/seed7": {"median_ttft_s": 0.09618252069685274, "p99_ttft_s": 0.12836566431744265, "median_tpot_s": 0.002216879798968058, "p99_tpot_s": 0.004090526171352947, "makespan_s": 3.195580571208233, "goodput_rps": 3.7551861806015614, "total_j": 2167.445046340041},  # noqa: E501
    "dis-disk/open/seed0": {"median_ttft_s": 0.5668132034220488, "p99_ttft_s": 0.7847000479414405, "median_tpot_s": 0.03056630032722834, "p99_tpot_s": 0.06293194235389502, "makespan_s": 2.955453763036083, "goodput_rps": 0.6767150361186625, "total_j": 2514.844979328194},  # noqa: E501
    "dis-disk/open/seed7": {"median_ttft_s": 0.6088131294164637, "p99_ttft_s": 0.8312020845581753, "median_tpot_s": 0.012008618884713856, "p99_tpot_s": 0.04437426091138053, "makespan_s": 3.590858888623066, "goodput_rps": 0.8354547179519956, "total_j": 2883.379952168644},  # noqa: E501
    "co-1gpu/open/seed0": {"median_ttft_s": 0.03706226469685281, "p99_ttft_s": 0.05902707763445898, "median_tpot_s": 0.002105340874236868, "p99_tpot_s": 0.003313341683413414, "makespan_s": 2.50486513370275, "goodput_rps": 4.790677086179614, "total_j": 1043.7189074919859},  # noqa: E501
    "co-1gpu/open/seed7": {"median_ttft_s": 0.03706226469685281, "p99_ttft_s": 0.06832807197888646, "median_tpot_s": 0.002233090450194962, "p99_tpot_s": 0.004597000440935254, "makespan_s": 3.136460315208233, "goodput_rps": 3.82596902049542, "total_j": 1245.8293655737405},  # noqa: E501
    "co-2gpus/batch": {"median_ttft_s": 0.0704618161666599, "p99_ttft_s": 0.0704618161666599, "median_tpot_s": 0.0022492960195360195, "p99_tpot_s": 0.0022492960195360195, "makespan_s": 0.1042012564597002, "goodput_rps": 76.77450610294702, "total_j": 137.12202487119546},  # noqa: E501
}


def _parity_workload(seed):
    return open_loop_workload(4.0, 12, lengths=PaperFixedLengths(4096, 32),
                              slo=SLO, seed=seed)


def _metric_dict(res):
    m = res.metrics
    return {"median_ttft_s": m.median_ttft_s, "p99_ttft_s": m.p99_ttft_s,
            "median_tpot_s": m.median_tpot_s, "p99_tpot_s": m.p99_tpot_s,
            "makespan_s": m.makespan_s, "goodput_rps": m.goodput_rps,
            "total_j": res.energy.total_j}


@pytest.mark.parametrize("setup", ["dis-ici", "dis-host", "dis-disk",
                                   "co-1gpu"])
@pytest.mark.parametrize("seed", [0, 7])
def test_facade_matches_prefleet_goldens(setup, seed):
    """A 1P:1D (or 1-colocated) fleet reproduces the pre-fleet Cluster
    metrics bit-identically for the same seeds."""
    got = _metric_dict(Cluster(setup, CFG).run(_parity_workload(seed)))
    want = GOLDEN[f"{setup}/open/seed{seed}"]
    for k, v in want.items():
        assert got[k] == pytest.approx(v, rel=1e-12, abs=0.0), (setup, k)


def test_co2gpus_batch_matches_prefleet_golden():
    """t=0 equal-length batches are routing-invariant (any balanced
    split gives the same per-engine timelines), so the co-2gpus golden
    survives the i%2 -> least-outstanding-tokens routing change."""
    reqs = random_workload(8, input_len=2048, output_len=16)
    got = _metric_dict(Cluster("co-2gpus", CFG).run(reqs))
    for k, v in GOLDEN["co-2gpus/batch"].items():
        assert got[k] == pytest.approx(v, rel=1e-12, abs=0.0), k


@pytest.mark.parametrize("setup,spec", [
    ("dis-ici", FleetSpec.disaggregated(1, 1, "ici")),
    ("dis-host", FleetSpec.disaggregated(1, 1, "host")),
    ("co-1gpu", FleetSpec.colocated(1)),
    ("co-2gpus", FleetSpec.colocated(2)),
])
def test_facade_is_exactly_a_minimal_fleet(setup, spec):
    """Cluster(setup) and FleetCluster(from_setup(setup)) must agree
    EXACTLY — per-request, not just in aggregate (locks the facade)."""
    a = Cluster(setup, CFG).run(_parity_workload(3))
    b = FleetCluster(spec, CFG).run(_parity_workload(3))
    for ra, rb in zip(a.requests, b.requests):
        assert ra.ttft_s == rb.ttft_s
        assert ra.finish_s == rb.finish_s
        assert ra.tpot_s == rb.tpot_s
    assert a.energy.total_j == b.energy.total_j


# ----------------------------------------------------------------------
# the co-2gpus routing fix (satellite): least-outstanding-tokens beats
# the old static i%2 round-robin split on bursty long-tail traffic
# ----------------------------------------------------------------------
@pytest.mark.parametrize("seed", [0, 1])
def test_lot_routing_beats_round_robin_p99_ttft(seed):
    wl = WorkloadSpec(arrivals=GammaArrivals(24.0, cv=4.0),
                      lengths=ShareGPTLengths(prompt_sigma=1.5),
                      n=64, seed=seed)
    p99 = {}
    for policy in ("round-robin", "least-outstanding-tokens"):
        reqs = wl.build()
        FleetCluster(FleetSpec.colocated(2, router=policy), CFG).run(reqs)
        p99[policy] = summarize(reqs).p99_ttft_s
    assert p99["least-outstanding-tokens"] < p99["round-robin"], p99


# ----------------------------------------------------------------------
# fleet behavior
# ----------------------------------------------------------------------
def test_per_pair_transfer_paths_are_distinct():
    cl = FleetCluster(FleetSpec.disaggregated(2, 3, "host"), CFG)
    assert set(cl.paths) == {(i, j) for i in range(2) for j in range(3)}
    assert len({id(p) for p in cl.paths.values()}) == 6
    assert all(p.name == "host" for p in cl.paths.values())
    assert cl.path is None                   # >1 pair: no single path
    assert cl.prefill_engines[0].role == "prefill"
    assert cl.decode_engines[-1].name == "acc4"


def test_kv_router_spreads_load_across_decodes():
    """Under sustained load every decode instance of a 1P:2D fleet must
    receive transfers (the kv-free-space policy spreads reservations)."""
    cl = FleetCluster(FleetSpec.disaggregated(1, 2, "ici"), CFG)
    reqs = open_loop_workload(8.0, 16, lengths=PaperFixedLengths(2048, 32),
                              slo=SLO, seed=0)
    cl.run(reqs)
    for e in cl.decode_engines:
        assert e.steps > 0, f"{e.name} never decoded"


def test_2p2d_outscales_1p1d():
    """The acceptance bar behind fig7: doubling both stages strictly
    raises the sustainable rate under the paper SLOs."""
    kw = dict(cfg=CFG, slo=SLO, lo=1.0, hi=64.0, max_iters=5,
              rel_tol=0.1, n=16, seed=0)
    cap1 = max_goodput_rate(FleetSpec.disaggregated(1, 1, "ici"), **kw)
    cap2 = max_goodput_rate(FleetSpec.disaggregated(2, 2, "ici"), **kw)
    assert cap2 > cap1, (cap1, cap2)


def test_heterogeneous_phi_slows_only_that_instance():
    """Per-instance DVFS: halving one prefill instance's clock shifts
    work to the fast one but must not change correctness."""
    spec = FleetSpec.disaggregated(2, 1, "ici", phi_prefill=(1.0, 0.26))
    cl = FleetCluster(spec, CFG)
    assert [e.phi for e in cl.prefill_engines] == [1.0, 0.26]
    reqs = open_loop_workload(6.0, 12, lengths=PaperFixedLengths(2048, 16),
                              slo=SLO, seed=0)
    cl.run(reqs)
    assert all(r.done for r in reqs)


def test_make_cluster_accepts_all_forms():
    assert isinstance(make_cluster("dis-ici", CFG), Cluster)
    fc = make_cluster(FleetSpec.disaggregated(3, 1, "disk"), CFG)
    assert isinstance(fc, FleetCluster) and not isinstance(fc, Cluster)
    assert fc.setup == "3P1D-disk"
    # fleet-shape strings dispatch through FleetSpec.parse
    assert make_cluster("2P2D-ici", CFG).setup == "2P2D-ici"
    assert make_cluster("co-3", CFG).spec.n_colocated == 3
    with pytest.raises(ValueError):
        make_cluster("dis-nvlink", CFG)


def test_dvfs_sweep_accepts_fleet_spec():
    from repro.core.dvfs import sweep_frequencies
    spec = FleetSpec.disaggregated(2, 2, "ici")
    wl = WorkloadSpec(arrivals=GammaArrivals(8.0, cv=1.0),
                      lengths=PaperFixedLengths(1024, 8), n=6, seed=0)
    sw = sweep_frequencies(spec, CFG, wl, freq_grid=(0.58, 1.0))
    assert sw.setup == "2P2D-ici"
    assert set(sw.results) == {0.58, 1.0}
    assert sw.results[0.58].metrics.median_ttft_s \
        >= sw.results[1.0].metrics.median_ttft_s


# ----------------------------------------------------------------------
# property tests: random fleet shapes x seeds x arrival processes
# ----------------------------------------------------------------------
def _random_spec(colocated, x, y, medium_i, policy_i):
    policies = sorted(POLICIES)
    if colocated:
        return FleetSpec.colocated(1 + x % 3,
                                   router=policies[policy_i % 3])
    return FleetSpec.disaggregated(
        x, y, ("ici", "host", "disk")[medium_i % 3],
        router=policies[policy_i % 3],
        kv_router=policies[(policy_i + 1) % 3])


@pytest.mark.parametrize("stepper", ["exact", "fast"])
@settings(max_examples=25, deadline=None)
@given(colocated=st.booleans(),
       x=st.integers(min_value=1, max_value=3),
       y=st.integers(min_value=1, max_value=3),
       medium_i=st.integers(min_value=0, max_value=2),
       policy_i=st.integers(min_value=0, max_value=2),
       arrival=st.sampled_from(["poisson", "gamma", "deterministic"]),
       rate=st.sampled_from([2.0, 10.0, 40.0]),
       seed=st.integers(min_value=0, max_value=2 ** 16))
def test_fleet_serves_every_request_exactly_once(
        stepper, colocated, x, y, medium_i, policy_i, arrival, rate, seed):
    """For ANY fleet shape, router mix, arrival process, seed, AND
    stepper: every submitted request completes exactly once, is never
    served before it arrives, TTFT >= queue delay >= 0, no KV pages
    leak, and the power-state timeline covers the whole run span."""
    spec = _random_spec(colocated, x, y, medium_i, policy_i)
    n = 7
    reqs = open_loop_workload(rate, n, arrival=arrival,
                              lengths=PaperFixedLengths(768, 6),
                              slo=SLO, seed=seed)
    cl = FleetCluster(spec, CFG)
    cl.run(reqs, stepper=stepper)
    assert summarize(reqs).num_requests == n
    for r in reqs:
        assert r.done and r.generated == r.output_len      # exactly once
        assert r.prefill_start_s >= r.arrival_s            # no time travel
        assert r.queue_s >= 0.0
        assert r.ttft_s >= r.queue_s >= 0.0
        assert r.finish_s >= r.first_token_s >= r.arrival_s
    t_start = min(r.arrival_s for r in reqs)
    t_end = max(r.finish_s for r in reqs)
    trace = cl.meter.trace
    assert trace is not None
    for e in cl.engines:
        e.pool.check_invariants()
        assert not e.pool.seqs, f"{e.name} leaked KV pages"
        # fill_idle plugged every gap: the trace accounts for every
        # second of [first arrival, last finish] on every accelerator
        assert trace.covers(e.name, t_start, t_end), \
            f"{e.name} trace has gaps under stepper={stepper}"


@pytest.mark.parametrize("stepper", ["exact", "fast"])
@settings(max_examples=10, deadline=None)
@given(x=st.integers(min_value=1, max_value=2),
       y=st.integers(min_value=1, max_value=2),
       seed=st.integers(min_value=0, max_value=2 ** 16))
def test_fleet_run_is_seed_deterministic(stepper, x, y, seed):
    """Same spec + same workload seed -> bit-identical results (the
    router tie-breaks come from the spec's seed, not global state)."""
    spec = FleetSpec.disaggregated(x, y, "ici")

    def once():
        reqs = open_loop_workload(20.0, 8, lengths=PaperFixedLengths(512, 4),
                                  slo=SLO, seed=seed)
        FleetCluster(spec, CFG).run(reqs, stepper=stepper)
        return [(r.ttft_s, r.finish_s) for r in reqs]

    assert once() == once()


if not HAS_HYPOTHESIS:
    # keep a deterministic slice of the property coverage even without
    # the dev extra: one fixed example of the invariants above
    @pytest.mark.parametrize("stepper", ["exact", "fast"])
    def test_fleet_property_fixed_example(stepper):
        spec = FleetSpec.disaggregated(2, 2, "host")
        reqs = open_loop_workload(10.0, 7, arrival="gamma",
                                  lengths=PaperFixedLengths(768, 6),
                                  slo=SLO, seed=11)
        cl = FleetCluster(spec, CFG)
        cl.run(reqs, stepper=stepper)
        for r in reqs:
            assert r.done and r.generated == r.output_len
            assert r.ttft_s >= r.queue_s >= 0.0
        for e in cl.engines:
            e.pool.check_invariants()
            assert not e.pool.seqs, f"{e.name} leaked KV pages"
