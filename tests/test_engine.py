"""Engine + scheduler behavior: the paper's serving mechanics at unit
scale — interference serialization, V1-style churn, decode-role waves."""
import pytest

from repro.configs import get_config
from repro.core import (Cluster, CostModel, EnergyMeter, Engine,
                        PagedKVPool, random_workload)


def _mk_engine(role, pool_pages=64, page_size=16, budget=64):
    cfg = get_config("llama32-3b")
    cost = CostModel(cfg)
    pool = PagedKVPool(num_pages=pool_pages, page_size=page_size)
    meter = EnergyMeter()
    return Engine("acc0", role, cost, pool, meter,
                  prefill_token_budget=budget), pool, meter


def _submit(engine, n, prompt=64, out=4):
    reqs = random_workload(n, input_len=prompt, output_len=out)
    for r in reqs:
        engine.submit(r)
    return reqs


# ----------------------------------------------------------------------
def test_colocated_runs_to_completion():
    eng, pool, meter = _mk_engine("colocated")
    reqs = _submit(eng, 3)
    for _ in range(500):
        if not eng.step():
            break
    assert all(r.done for r in reqs)
    assert pool.used_pages == 0               # everything freed
    pool.check_invariants()


def test_prefill_priority_interference():
    """Prefill steps serialize ahead of decode (the paper's interference):
    with enough waiting prefills, running decodes make no progress."""
    eng, pool, meter = _mk_engine("colocated", pool_pages=1024, budget=32)
    reqs = _submit(eng, 4, prompt=64, out=8)
    # run until the first prefill finishes -> it joins decode
    while not eng.running:
        eng.step()
    gen_before = reqs[0].generated
    eng.step()     # still prefilling others -> decode starved
    assert eng.prefilling and reqs[0].generated == gen_before


def test_ttft_at_prefill_completion_colocated():
    eng, pool, meter = _mk_engine("colocated")
    reqs = _submit(eng, 1, prompt=64, out=4)
    while not reqs[0].done:
        eng.step()
    assert reqs[0].first_token_s == reqs[0].prefill_done_s
    assert reqs[0].generated == 4


def test_preemption_churn_when_pool_small():
    """Pool < working set -> V1-style recompute churn must appear."""
    # 4 seqs x (64 prompt + 4 out) tokens = 272; pool 12 pages x 16 = 192
    eng, pool, meter = _mk_engine("colocated", pool_pages=12)
    reqs = _submit(eng, 4, prompt=64, out=4)
    for _ in range(2000):
        if not eng.step():
            break
    assert all(r.done for r in reqs)
    assert eng.preemptions > 0
    assert sum(r.recomputed_tokens for r in reqs) > 0
    pool.check_invariants()


def test_preemption_never_victimizes_higher_priority():
    """Victims are strictly lower priority (later arrivals)."""
    eng, pool, meter = _mk_engine("colocated", pool_pages=12)
    reqs = _submit(eng, 4, prompt=64, out=4)
    for _ in range(2000):
        if not eng.step():
            break
    # request 0 (highest priority) must never have been evicted
    assert reqs[0].evictions == 0
    assert all(r.done for r in reqs)


def test_decode_role_reserves_and_never_preempts():
    eng, pool, meter = _mk_engine("decode", pool_pages=32)
    cfg = get_config("llama32-3b")
    from repro.core.engine import EngineSeq
    from repro.core.transfer import ICIPath
    path = ICIPath()
    reqs = random_workload(4, input_len=128, output_len=8)
    for r in reqs:
        seq = EngineSeq(req=r, prefill_target=r.prompt_len)
        seq.ctx = r.prompt_len
        r.prefill_done_s = 0.0
        eng.enqueue_decode(seq, None, path.fetch_cost(1000))
    for _ in range(500):
        if not eng.step():
            break
    assert all(r.done for r in reqs)
    assert eng.preemptions == 0
    assert all(r.evictions == 0 for r in reqs)
    # pool 32 pages = 512 tokens; each seq reserves 128+8+1 -> 9 pages;
    # only 3 fit at once -> waves
    assert pool.used_pages == 0


def test_engine_energy_accounting_positive():
    eng, pool, meter = _mk_engine("colocated")
    reqs = _submit(eng, 2)
    while not all(r.done for r in reqs):
        eng.step()
    assert meter.total_j > 0
    assert meter.by_stage["prefill"] > 0
    assert meter.by_stage["decode"] > 0


# ----------------------------------------------------------------------
def test_dvfs_slows_compute_bound_steps():
    """phi < 1 stretches prefill (compute-bound) but decode (memory-bound)
    much less — the asymmetry behind the paper's Experiment 2."""
    cfg = get_config("llama32-3b")
    cost = CostModel(cfg)
    pc = cost.prefill_step_cost([(8192, 0, 8192)])
    dc = cost.decode_cost(16, 16 * 16384)
    slow_p = pc.time(0.5) / pc.time(1.0)
    slow_d = dc.time(0.5) / dc.time(1.0)
    assert slow_p > 1.6              # prefill nearly halves in speed
    assert slow_d < slow_p           # decode barely notices


# ----------------------------------------------------------------------
# the latent single-engine drift (satellite fix): submit() must clamp
# the clock forward only on a QUIESCENT engine, and a bare engine driven
# by step() alone must neither serve early nor deadlock on future work
# ----------------------------------------------------------------------
def test_submit_clamps_clock_only_when_quiescent():
    eng, pool, meter = _mk_engine("colocated")
    late = random_workload(2, input_len=64, output_len=4)
    late[0].arrival_s = 5.0
    eng.submit(late[0])
    assert eng.t == 5.0              # quiescent: fast-forward to arrival

    eng2, _, _ = _mk_engine("colocated")
    held = random_workload(1, input_len=64, output_len=4)[0]
    eng2.submit(held)                # arrival 0: engine now holds work
    late[1].arrival_s = 1000.0
    eng2.submit(late[1])
    # the old unconditional max() teleported the clock to 1000s here,
    # billing the queued request a phantom kilosecond of wait
    assert eng2.t == 0.0
    while not held.done:
        assert eng2.step()
    assert held.prefill_start_s < 1.0


def test_bare_engine_gates_admission_on_arrival():
    """step()-driven engine with staggered arrivals: every request is
    served after it arrives, and the idle fast-forward keeps a bare
    engine from deadlocking on all-future work."""
    eng, pool, meter = _mk_engine("colocated")
    reqs = random_workload(3, input_len=64, output_len=4)
    for i, r in enumerate(reqs):
        r.arrival_s = 2.0 * i + 1.0  # all strictly in the future
        eng.submit(r)
    for _ in range(2000):
        if not eng.step():
            break
    assert all(r.done for r in reqs)
    for r in reqs:
        assert r.prefill_start_s >= r.arrival_s


def test_governor_runs_on_bare_engine():
    """The governor hook is an Engine feature, not a fleet feature: a
    bare engine retunes phi from its own step loop."""
    from repro.govern import make_governor

    eng, pool, meter = _mk_engine("colocated")
    gov = make_governor("queue-depth", grid=(0.5, 1.0))
    eng.governor = gov
    reqs = _submit(eng, 4, prompt=256, out=8)
    for _ in range(2000):
        if not eng.step():
            break
    assert all(r.done for r in reqs)
    # backlog pushed phi to the grid ceiling, drain coasted at the floor
    phis = {d.phi for d in gov.decisions}
    assert phis, "governor never retuned a bare engine"
    assert phis <= {0.5, 1.0}
    assert eng.phi == 0.5            # empty queue at the end: floor


def test_add_power_run_matches_scalar_fold_bitwise():
    """The bulk accumulation API folds joules left-to-right exactly like
    n sequential add_power calls — the contract the coalescing fast
    stepper's cumulative-sum caches rely on."""
    import numpy as np

    watts = np.array([37.5, 912.0, 3.25e-3, 640.0, 1e6])
    secs = np.array([1e-7, 0.333, 42.0, 1e-3, 7e-9])
    a, b = EnergyMeter(), EnergyMeter()
    a.add("acc0", 1.0, "decode")
    b.add("acc0", 1.0, "decode")
    for w, s in zip(watts, secs):
        a.add_power("acc0", w, s, stage="decode")
    b.add_power_run("acc0", watts, secs, stage="decode")
    assert a.joules["acc0"] == b.joules["acc0"]
    assert a.by_stage["decode"] == b.by_stage["decode"]
