"""Graceful degradation when ``hypothesis`` is not installed.

Property tests are a dev-extra (see requirements-dev.txt); the plain unit
tests in the same modules must still collect and run without it. Import
``given / settings / st`` from here instead of from ``hypothesis``: with
the real package present this is a pass-through, without it ``@given``
becomes a skip marker and the strategy objects become inert stand-ins
(they are only ever evaluated at collection time, never executed).
"""
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAS_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised only without the dep
    import pytest

    HAS_HYPOTHESIS = False

    def given(*_a, **_k):
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*_a, **_k):
        def deco(fn):
            return fn
        return deco

    class _InertStrategies:
        """st.<anything>(...) -> None; enough to evaluate @given args."""

        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = strategies = _InertStrategies()
