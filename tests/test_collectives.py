"""Distributed collectives under shard_map on an 8-device host-platform
mesh. Runs in a SUBPROCESS so the forced device count never leaks into the
rest of the suite (smoke tests must see 1 device)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

_WORKER = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import functools
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import PartitionSpec as P

    from repro.compat import shard_map

    from repro.dist.collectives import (bucketed_psum, compressed_psum,
                                        halo_exchange, ring_allgather,
                                        ring_pass)

    mesh = jax.make_mesh((8,), ("dp",))
    results = {}

    # --- compressed all-reduce: mean within int8 tolerance + EF ----------
    key = jax.random.PRNGKey(0)
    grads = {"a": jax.random.normal(key, (8, 64)),
             "b": jax.random.normal(jax.random.fold_in(key, 1), (8, 17))}

    @functools.partial(shard_map, mesh=mesh,
                       in_specs=(P("dp"),), out_specs=P("dp"))
    def cmean(g):
        g = jax.tree.map(lambda x: x[0], g)          # local shard
        mean, err = compressed_psum(g, "dp")
        return jax.tree.map(lambda x: x[None], mean)

    got = cmean(grads)
    want = jax.tree.map(lambda x: jnp.mean(x, 0, keepdims=True)
                        .repeat(8, 0), grads)
    errs = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a - b)) /
                           (jnp.max(jnp.abs(b)) + 1e-9)), got, want)
    results["compressed_rel_err"] = max(jax.tree.leaves(errs))

    # --- error feedback makes repeated compression unbiased -------------
    @functools.partial(shard_map, mesh=mesh,
                       in_specs=(P("dp"),), out_specs=P("dp"))
    def accumulate(g):
        gl = jax.tree.map(lambda x: x[0], g)
        err = None
        tot = jax.tree.map(jnp.zeros_like, gl)
        for _ in range(50):
            mean, err = compressed_psum(gl, "dp", err)
            tot = jax.tree.map(lambda t, m: t + m, tot, mean)
        return jax.tree.map(lambda x: x[None], tot)

    tot = accumulate(grads)
    want_tot = jax.tree.map(
        lambda x: 50 * jnp.mean(x, 0, keepdims=True).repeat(8, 0), grads)
    ef_err = max(jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a - b)) /
                           (jnp.max(jnp.abs(b)) + 1e-9)), tot, want_tot)))
    results["ef_rel_err"] = ef_err

    # --- bucketed psum == plain psum -------------------------------------
    @functools.partial(shard_map, mesh=mesh,
                       in_specs=(P("dp"),), out_specs=P("dp"))
    def bsum(g):
        gl = jax.tree.map(lambda x: x[0], g)
        out = bucketed_psum(gl, "dp", bucket_bytes=256)
        return jax.tree.map(lambda x: x[None], out)

    got_b = bsum(grads)
    want_b = jax.tree.map(lambda x: jnp.sum(x, 0, keepdims=True)
                          .repeat(8, 0), grads)
    results["bucket_err"] = max(jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), got_b, want_b)))

    # --- halo exchange ----------------------------------------------------
    x = jnp.arange(8 * 4 * 2, dtype=jnp.float32).reshape(8, 4, 2)

    @functools.partial(shard_map, mesh=mesh,
                       in_specs=(P("dp"),), out_specs=P("dp"))
    def halo(xs):
        out = halo_exchange(xs, "dp", halo=1, seq_axis=1)
        return out

    h = halo(x)                       # [8, 5, 2] global (per-shard 1x5x2)
    ok = bool(jnp.all(h[1:, 0] == x[:-1, -1])) and bool(
        jnp.all(h[0, 0] == 0.0)) and bool(jnp.all(h[:, 1:] == x))
    results["halo_ok"] = ok

    # --- ring allgather == all values, correctly ordered -----------------
    v = jnp.arange(8, dtype=jnp.float32).reshape(8, 1)

    @functools.partial(shard_map, mesh=mesh,
                       in_specs=(P("dp"),), out_specs=P("dp"))
    def gather(vs):
        flat = ring_allgather(vs, "dp")          # [8] on every shard
        return flat.reshape(1, 8)

    g = gather(v)
    results["ring_ok"] = bool(jnp.all(
        g == jnp.arange(8, dtype=jnp.float32)[None, :]))

    print("RESULTS:" + json.dumps(results))
""").replace("json.dumps", "__import__('json').dumps")


@pytest.fixture(scope="module")
def worker_results():
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", _WORKER], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines()
            if l.startswith("RESULTS:")][0]
    return json.loads(line[len("RESULTS:"):])


def test_compressed_psum_close(worker_results):
    assert worker_results["compressed_rel_err"] < 0.02   # int8 tolerance


def test_error_feedback_unbiased(worker_results):
    """50 accumulated compressed steps stay within ~1% of the true sum —
    error feedback prevents drift."""
    assert worker_results["ef_rel_err"] < 0.01


def test_bucketed_psum_exact(worker_results):
    assert worker_results["bucket_err"] < 1e-5


def test_halo_exchange(worker_results):
    assert worker_results["halo_ok"]


def test_ring_allgather(worker_results):
    assert worker_results["ring_ok"]
