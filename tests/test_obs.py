"""repro.obs contracts (DESIGN.md section 16, ISSUE 9).

Three hard guarantees, each with its own test axis here:

  1. tracer OFF (the default) is byte-identical to a build without the
     hooks, and tracer ON is purely observational — every request
     timestamp, metric, and joule matches the untraced run bit-for-bit;
  2. fast vs exact steppers emit equivalent traces under the
     window-span contract: identical engine traces after
     ``Tracer.coalesced`` merging, identical lifecycle / governor /
     controller instants with no normalization at all;
  3. SLO attribution terms sum to the overrun exactly, and the derived
     lifecycle reconciles with the ``Request`` fields and the
     ``PowerTrace`` busy accounting to 1e-9.

Plus the format contracts: TraceEvent / governor-decision / controller
-action JSON round-trips (the event schema single-sources all three),
Chrome export structural validity + lifecycle completeness, and the
``RunRecord.obs`` metrics snapshot surviving the result cache.
"""
import dataclasses
import json

import pytest

from hypothesis_compat import given, settings, st

from repro.configs import get_config
from repro.core import SLO
from repro.core.orchestrator import make_cluster
from repro.fleet.spec import FleetSpec
from repro.obs import (Attribution, LIFECYCLE_TRACK, MetricsRegistry,
                       NULL_TRACER, TraceEvent, Tracer,
                       assert_complete_lifecycles, attribute_run,
                       blame_table, chrome_trace, collect_run_metrics,
                       controller_action_from_event,
                       event_from_controller_action,
                       event_from_governor_decision,
                       governor_decision_from_event, request_lifecycles,
                       text_summary, transfer_queue_share,
                       validate_chrome_trace)
from repro.obs.trace import LIFECYCLE_ONCE
from repro.workload import DEFAULT_INTERACTIVE_SLO, open_loop_workload

CFG = get_config("llama32-3b")
SETUPS = ("co-2gpus", "dis-ici", "dis-host", "dis-disk")

REQUEST_FIELDS = ("arrival_s", "prefill_start_s", "prefill_done_s",
                  "decode_start_s", "first_token_s", "finish_s",
                  "generated", "evictions", "recomputed_tokens",
                  "reused_tokens")


def traced_run(setup, *, rate=2.0, n=10, seed=0, stepper=None,
               tracer=None):
    reqs = open_loop_workload(rate, n, slo=DEFAULT_INTERACTIVE_SLO,
                              seed=seed)
    cluster = make_cluster(setup, CFG, tracer=tracer)
    res = cluster.run(reqs, stepper=stepper)
    return cluster, reqs, res


def req_state(reqs):
    return [tuple(getattr(r, f) for f in REQUEST_FIELDS) for r in reqs]


# ----------------------------------------------------------------------
# contract 1: tracing is purely observational
# ----------------------------------------------------------------------
@pytest.mark.parametrize("setup", SETUPS)
def test_tracer_on_is_bit_identical(setup):
    _, reqs_off, res_off = traced_run(setup)
    _, reqs_on, res_on = traced_run(setup, tracer=Tracer())
    assert req_state(reqs_off) == req_state(reqs_on)
    assert dataclasses.asdict(res_off.metrics) == \
        dataclasses.asdict(res_on.metrics)
    assert dict(res_off.energy.joules) == dict(res_on.energy.joules)
    assert dict(res_off.energy.by_stage) == dict(res_on.energy.by_stage)


def test_null_tracer_is_inert():
    assert not NULL_TRACER.enabled
    NULL_TRACER.span("acc0", "decode", 0.0, 1.0, steps=3)
    NULL_TRACER.instant("governor", "phi", 0.5)
    NULL_TRACER.lifecycle("arrival", 0, 0.0)
    assert NULL_TRACER.events == []


# ----------------------------------------------------------------------
# contract 2: fast vs exact window-span equivalence
# ----------------------------------------------------------------------
def _instant_view(tr, track):
    # sorted: instants carry their own timestamps, so cross-engine
    # emission order (which a coalesced window legitimately batches)
    # carries no information
    return sorted((e.name, e.t0, tuple(sorted(e.args.items())))
                  for e in tr.instants(track))


@pytest.mark.parametrize("setup", SETUPS)
def test_fast_exact_trace_equivalence(setup):
    tr_e = Tracer()
    tr_f = Tracer()
    traced_run(setup, stepper="exact", tracer=tr_e)
    traced_run(setup, stepper="fast", tracer=tr_f)
    assert tr_e.engine_tracks() == tr_f.engine_tracks()
    for track in tr_e.engine_tracks():
        assert tr_e.coalesced(track) == tr_f.coalesced(track), track
    for track in (LIFECYCLE_TRACK, "governor", "controller", "tier"):
        assert _instant_view(tr_e, track) == _instant_view(tr_f, track)
    # a coalesced decode window really did merge steps somewhere
    if setup != "co-2gpus":
        raw_f = len(tr_f.spans())
        raw_e = len(tr_e.spans())
        assert raw_f <= raw_e


# ----------------------------------------------------------------------
# contract 3: trace invariants and reconciliation
# ----------------------------------------------------------------------
@pytest.mark.parametrize("setup", SETUPS)
def test_lifecycle_once_and_matches_request(setup, rate=2.0, n=10):
    tr = Tracer()
    _, reqs, _ = traced_run(setup, rate=rate, n=n, tracer=tr)
    lcs = tr.lifecycle_events()
    assert sorted(lcs) == [r.req_id for r in reqs]
    for r in reqs:
        evs = lcs[r.req_id]
        for name in LIFECYCLE_ONCE:
            assert len(evs[name]) == 1, (r.req_id, name)
        assert evs["arrival"][0].t0 == r.arrival_s
        assert evs["first_token"][0].t0 == r.first_token_s
        assert evs["finish"][0].t0 == r.finish_s


@pytest.mark.parametrize("setup", SETUPS)
def test_engine_spans_monotone_nonoverlapping(setup):
    tr = Tracer()
    traced_run(setup, tracer=tr)
    assert tr.events, "trace must not be empty"
    for e in tr.events:
        assert e.t1 >= e.t0 >= 0.0, e
    for track in tr.engine_tracks():
        spans = tr.spans(track)
        for a, b in zip(spans, spans[1:]):
            assert b.t0 >= a.t1 - 1e-12, (track, a, b)


@pytest.mark.parametrize("setup", SETUPS)
def test_span_durations_reconcile_with_power_trace(setup):
    tr = Tracer()
    cluster, _, _ = traced_run(setup, tracer=tr)
    power = cluster.meter.trace
    for eng in cluster.engines:
        spanned = sum(e.dur for e in tr.spans(eng.name))
        assert spanned == pytest.approx(eng.busy_s, abs=1e-9)
        assert spanned == pytest.approx(power.busy_s(eng.name), abs=1e-9)


@pytest.mark.parametrize("setup", SETUPS)
def test_derived_lifecycle_is_contiguous(setup):
    tr = Tracer()
    _, reqs, _ = traced_run(setup, tracer=tr)
    for r in reqs:
        chain = tr.derive_lifecycle(r.req_id)
        assert chain[0][0] == "queue" and chain[-1][0] == "decode"
        assert chain[0][1] == r.arrival_s
        assert chain[-1][2] == r.finish_s
        for (_, _, t1), (_, t0, _) in zip(chain, chain[1:]):
            assert t0 == t1          # shared boundary instants: exact


@given(st.integers(0, 3), st.integers(1, 4), st.integers(0, 5))
@settings(max_examples=8, deadline=None)
def test_trace_invariants_fuzz(setup_i, rate, seed):
    setup = SETUPS[setup_i]
    tr = Tracer()
    cluster, reqs, _ = traced_run(setup, rate=float(rate), n=8,
                                  seed=seed, tracer=tr)
    lcs = tr.lifecycle_events()
    for r in reqs:
        for name in LIFECYCLE_ONCE:
            assert len(lcs[r.req_id][name]) == 1
        chain = tr.derive_lifecycle(r.req_id)
        assert chain[0][1] == r.arrival_s
        assert chain[-1][2] == r.finish_s
    for eng in cluster.engines:
        spanned = sum(e.dur for e in tr.spans(eng.name))
        assert spanned == pytest.approx(eng.busy_s, abs=1e-9)


# ----------------------------------------------------------------------
# SLO attribution
# ----------------------------------------------------------------------
def test_attribution_rejects_non_telescoping_terms():
    with pytest.raises(AssertionError):
        Attribution(req_id=0, metric="ttft", measured_s=3.0, target_s=1.0,
                    overrun_s=2.0, terms={"queue": 1.0})


@pytest.mark.parametrize("setup", ("co-2gpus", "dis-host", "dis-disk"))
def test_attribution_terms_sum_exactly(setup):
    tr = Tracer()
    slo = SLO(ttft_s=0.3, tpot_s=0.004)    # tight: force violations
    reqs = open_loop_workload(2.0, 10, slo=slo, seed=0)
    cluster = make_cluster(setup, CFG, tracer=tr)
    cluster.run(reqs)
    attrs = attribute_run(reqs, slo, tr)
    assert attrs, f"{setup}: tight SLO must produce violations"
    for a in attrs:
        assert a.overrun_s == pytest.approx(a.measured_s - a.target_s)
        assert sum(a.terms.values()) == pytest.approx(a.overrun_s,
                                                      abs=1e-9)
        assert all(v >= 0.0 for v in a.terms.values()), a.terms
    table = blame_table(attrs)
    assert table["violations"] == len(attrs)
    share = transfer_queue_share(table)
    assert share is not None and 0.0 <= share <= 1.0


def test_fig6_claim_shape_below_crossover():
    """The CI narrative at unit scale: at a low offered rate the slow-
    medium dis setup's violations are transfer+queue dominated."""
    tr = Tracer()
    slo = DEFAULT_INTERACTIVE_SLO
    reqs = open_loop_workload(1.0, 10, slo=slo, seed=0)
    cluster = make_cluster("dis-disk", CFG, tracer=tr)
    cluster.run(reqs)
    table = blame_table(attribute_run(reqs, slo, tr))
    assert table["violations"] > 0
    share = transfer_queue_share(table)
    assert share is not None and share > 0.5


def test_blame_table_empty():
    table = blame_table([])
    assert table == {"metrics": {}, "violations": 0}
    assert transfer_queue_share(table) is None


# ----------------------------------------------------------------------
# format round-trips: the event schema single-sources three formats
# ----------------------------------------------------------------------
def test_trace_event_json_roundtrip():
    ev = TraceEvent(name="decode", track="acc1", t0=1.25, t1=2.5,
                    args={"steps": 17, "req": 3})
    ev2 = TraceEvent.from_dict(json.loads(json.dumps(ev.to_dict())))
    assert ev2 == ev and ev2.dur == ev.dur


def test_governor_decision_roundtrip():
    from repro.govern.governors import GovernorDecision
    d = GovernorDecision(t=3.5, engine="acc0", phi=0.75, signal=0.42)
    ev = event_from_governor_decision(d)
    d2 = governor_decision_from_event(
        TraceEvent.from_dict(json.loads(json.dumps(ev.to_dict()))))
    assert d2 == d


def test_controller_action_roundtrip():
    action = {"t": 7.0, "op": "flip", "engine": "acc2",
              "from": "prefill", "to": "decode"}
    ev = event_from_controller_action(action)
    back = controller_action_from_event(
        TraceEvent.from_dict(json.loads(json.dumps(ev.to_dict()))))
    assert back == action


def test_live_governor_instants_match_decision_log():
    """The governor track is the same record ``Governor.decisions``
    keeps — derived through one converter, so they cannot drift."""
    tr = Tracer()
    spec = FleetSpec.disaggregated(1, 1, "ici", governor="queue-depth")
    reqs = open_loop_workload(6.0, 16, slo=DEFAULT_INTERACTIVE_SLO,
                              seed=0)
    cluster = make_cluster(spec, CFG, tracer=tr)
    cluster.run(reqs)
    decisions = [d for e in cluster.engines for d in e.governor.decisions]
    assert decisions, "queue-depth governor must retune under load"
    want = sorted((ev.t0, tuple(sorted(ev.args.items())))
                  for d in decisions
                  for ev in [event_from_governor_decision(d)])
    got = sorted((ev.t0, tuple(sorted(ev.args.items())))
                 for ev in tr.instants("governor"))
    assert got == want


def test_controller_log_matches_controller_track():
    tr = Tracer()
    spec = FleetSpec(n_prefill=2, n_decode=2, medium="ici",
                     controller="adaptive")
    reqs = open_loop_workload(12.0, 48, slo=DEFAULT_INTERACTIVE_SLO,
                              seed=0)
    cluster = make_cluster(spec, CFG, tracer=tr)
    cluster.run(reqs)
    derived = [controller_action_from_event(ev)
               for ev in tr.instants("controller")]
    assert derived == list(cluster.controller_log)


# ----------------------------------------------------------------------
# Chrome export
# ----------------------------------------------------------------------
@pytest.mark.parametrize("setup", SETUPS)
def test_chrome_export_valid_and_complete(setup, n=10):
    tr = Tracer()
    traced_run(setup, n=n, tracer=tr)
    payload = chrome_trace(tr, label=setup)
    payload = json.loads(json.dumps(payload))      # JSON-safe
    assert validate_chrome_trace(payload) > 0
    assert assert_complete_lifecycles(payload, n_requests=n) == n


def test_chrome_export_fast_exact_same_lifecycles():
    tr_e, tr_f = Tracer(), Tracer()
    traced_run("dis-host", stepper="exact", tracer=tr_e)
    traced_run("dis-host", stepper="fast", tracer=tr_f)
    lc_e = request_lifecycles(chrome_trace(tr_e))
    lc_f = request_lifecycles(chrome_trace(tr_f))
    assert lc_e == lc_f


def test_validate_rejects_malformed():
    with pytest.raises(ValueError):
        validate_chrome_trace({"traceEvents": []})
    with pytest.raises(ValueError):
        validate_chrome_trace({"traceEvents": [
            {"ph": "Z", "pid": 1, "name": "x"}]})
    with pytest.raises(ValueError):        # dangling async begin
        validate_chrome_trace({"traceEvents": [
            {"ph": "b", "pid": 1, "name": "queue", "cat": "request",
             "id": 0, "ts": 0.0}]})


def test_text_summary_renders():
    tr = Tracer()
    traced_run("dis-disk", tracer=tr)
    out = text_summary(chrome_trace(tr))
    assert "acc0" in out and "slowest" in out and "decode" in out
    assert text_summary({"traceEvents": [
        {"ph": "M", "pid": 1, "tid": 0, "name": "process_name",
         "args": {"name": "empty"}}]}) == "(empty trace)"


# ----------------------------------------------------------------------
# metrics registry + RunRecord.obs
# ----------------------------------------------------------------------
def test_metrics_registry_snapshot_roundtrip():
    reg = MetricsRegistry()
    reg.counter("a.b").inc(3)
    reg.gauge("g").set(0.25)
    h = reg.histogram("lat")
    for v in (0.0005, 0.003, 0.003, 42.0, 1e9):
        h.observe(v)
    snap = json.loads(json.dumps(reg.snapshot()))
    reg2 = MetricsRegistry.from_snapshot(snap)
    assert reg2.snapshot() == reg.snapshot()
    assert h.count == 5 and h.counts[0] == 1 and h.counts[-1] == 1
    assert h.mean == pytest.approx(h.sum / 5)


def test_collect_run_metrics_reads_without_perturbing():
    tr = Tracer()
    cluster, reqs, _ = traced_run("dis-host", tracer=tr)
    before = req_state(reqs)
    snap1 = collect_run_metrics(cluster, reqs).snapshot()
    snap2 = collect_run_metrics(cluster, reqs).snapshot()
    assert snap1 == snap2
    assert req_state(reqs) == before
    assert snap1["counters"]["request.total"] == len(reqs)
    assert snap1["counters"]["engine.steps"] == \
        sum(e.steps for e in cluster.engines)
    assert snap1["histograms"]["request.ttft_s"]["count"] == len(reqs)
    # the fast stepper coalesced something on a disaggregated pair
    assert snap1["counters"]["fastpath.windows"] > 0


def test_run_record_obs_survives_the_cache(tmp_path):
    from repro.exp import Experiment, ResultCache, run, set_default_cache
    from repro.exp import runner as runner_mod
    prev = runner_mod._DEFAULT_CACHE
    set_default_cache(ResultCache(str(tmp_path / "cache")))
    try:
        exp = Experiment.open("dis-ici", 4.0, n=6, seed=1,
                              slo=SLO(ttft_s=2.0, tpot_s=0.0075))
        rec = run(exp)
        assert rec.obs is not None
        assert rec.obs["counters"]["request.total"] == 6
        hit = run(exp)                      # cache hit: stored snapshot
        assert hit.obs == rec.obs
    finally:
        set_default_cache(prev)


def test_traced_exp_run_is_never_cached(tmp_path):
    from repro.exp import Experiment, ResultCache, run, set_default_cache
    from repro.exp import runner as runner_mod
    from repro.exp.runner import sim_count
    prev = runner_mod._DEFAULT_CACHE
    set_default_cache(ResultCache(str(tmp_path / "cache")))
    try:
        exp = Experiment.open("dis-ici", 4.0, n=6, seed=1)
        run(exp)                            # populate the cache
        n0 = sim_count()
        tr = Tracer()
        rec = run(exp, tracer=tr)
        assert sim_count() == n0 + 1        # simulated despite the hit
        assert tr.events, "tracer must observe the traced run"
        untraced = run(exp)
        assert untraced.obs == rec.obs      # observational: same metrics
    finally:
        set_default_cache(prev)
