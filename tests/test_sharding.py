"""Sharding rules: every parameter of every arch gets a legal spec on the
production meshes (divisibility respected; fallback chain ends replicated)."""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.compat import abstract_mesh
from repro.configs import ASSIGNED_ARCHS, get_config
from repro.dist.sharding import (batch_spec, param_spec, state_spec)
from repro.models import get_model

SINGLE = abstract_mesh((16, 16), ("data", "model"))
MULTI = abstract_mesh((2, 16, 16), ("pod", "data", "model"))


def _path_str(path):
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
    return "/".join(parts)


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
@pytest.mark.parametrize("mesh", [SINGLE, MULTI], ids=["1pod", "2pod"])
def test_all_param_specs_divide(arch, mesh):
    cfg = get_config(arch)
    model = get_model(cfg)
    abstract = model.abstract_params()
    flat = jax.tree_util.tree_flatten_with_path(abstract)[0]
    n_sharded = 0
    for path, leaf in flat:
        spec = param_spec(_path_str(path), leaf.shape, mesh, cfg)
        assert len(spec) <= len(leaf.shape)
        for dim, names in enumerate(spec):
            if names is None:
                continue
            size = mesh.shape[names] if isinstance(names, str) else \
                int(np.prod([mesh.shape[n] for n in names]))
            assert leaf.shape[dim] % size == 0, \
                f"{arch}: {_path_str(path)} dim {dim} " \
                f"({leaf.shape[dim]}) not divisible by {names}={size}"
            n_sharded += 1
    assert n_sharded > 0, f"{arch}: nothing sharded at all"


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_big_params_are_sharded(arch):
    """Every parameter >= 8M elements must shard on 'model' (a replicated
    34B matrix would never fit 16 GB HBM)."""
    cfg = get_config(arch)
    model = get_model(cfg)
    flat = jax.tree_util.tree_flatten_with_path(model.abstract_params())[0]
    for path, leaf in flat:
        n = int(np.prod(leaf.shape))
        if n >= 8_000_000:
            spec = param_spec(_path_str(path), leaf.shape, SINGLE, cfg)
            assert any(s is not None for s in spec), \
                f"{arch}: large param {_path_str(path)} {leaf.shape} " \
                f"replicated"


def test_moe_experts_expert_parallel():
    cfg = get_config("deepseek-moe-16b")
    spec = param_spec("moe_layers/ffn/w_gate", (27, 64, 2048, 1408),
                      SINGLE, cfg)
    assert spec[1] == "model"        # E dim after the layer-stack dim


def test_embedding_vocab_parallel_when_divisible():
    cfg = get_config("yi-34b")       # vocab 64000 = 16 * 4000
    spec = param_spec("embed/embedding", (64000, 7168), SINGLE, cfg)
    assert spec[0] == "model"
    # internvl vocab 92553 does NOT divide -> d_model fallback
    cfg2 = get_config("internvl2-2b")
    spec2 = param_spec("embed/embedding", (92553, 2048), SINGLE, cfg2)
    assert spec2[0] is None and spec2[1] == "model"


def test_norms_replicated():
    cfg = get_config("yi-34b")
    assert param_spec("layers/norm_attn", (60, 7168), SINGLE, cfg) == \
        P(None, None)


def test_batch_spec_handles_small_batch():
    assert batch_spec((256, 4096), SINGLE) == P(("data",), None)
    assert batch_spec((1, 524288), SINGLE) == P(None, None)   # long_500k
    assert batch_spec((256, 4096), MULTI) == P(("pod", "data"), None)


def test_state_spec_kv_cache():
    # [L, B, S, KV, hd]: batch on data, hd on model (KV=8 doesn't divide).
    # PartitionSpec normalizes 1-tuples to bare names.
    s = state_spec((28, 128, 32768, 8, 128), SINGLE)
    assert s[1] in ("data", ("data",))
    assert s[4] == "model"
    # rwkv state [L, B, NH, hd, hd]
    s2 = state_spec((32, 128, 40, 64, 64), SINGLE)
    assert s2[1] in ("data", ("data",)) and s2[4] == "model"
