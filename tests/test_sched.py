"""repro.sched: spec normalization, chunk conservation, stall-free
decode, SRPT determinism, and the intra-gpu (sixth setup) shape.

DESIGN.md section 17. The fast-stepper bail contract for schedulers is
locked by ``test_fastpath_parity.py`` (SCHEDULERS axis + grid cases);
this module owns the scheduler-level invariants themselves.
"""
import dataclasses

import pytest

from hypothesis_compat import HAS_HYPOTHESIS, given, settings, st

from repro.configs import get_config
from repro.core.costs import CostModel
from repro.core.orchestrator import make_cluster, run_setup
from repro.exp.spec import encode_fleet
from repro.fleet.spec import FleetSpec
from repro.sched import (ADMISSIONS, COMPOSERS, SchedulerSpec,
                         as_scheduler_spec)
from repro.workload import (DEFAULT_INTERACTIVE_SLO, PaperFixedLengths,
                            open_loop_workload)

CFG = get_config("llama32-3b")
CHUNKED = SchedulerSpec(composer="chunked-interleave")


# ----------------------------------------------------------------------
# spec normalization + validation
# ----------------------------------------------------------------------
def test_scheduler_spec_normalization():
    assert as_scheduler_spec(None) is None
    # a bare string names whichever axis it belongs to
    assert as_scheduler_spec("srpt") == SchedulerSpec(admission="srpt")
    assert as_scheduler_spec("chunked-interleave") == CHUNKED
    assert as_scheduler_spec({"admission": "sjf", "chunk_tokens": 512}) \
        == SchedulerSpec(admission="sjf", chunk_tokens=512)
    s = SchedulerSpec(admission="srpt")
    assert as_scheduler_spec(s) is s
    assert hash(SchedulerSpec()) == hash(SchedulerSpec())


def test_scheduler_spec_validation():
    with pytest.raises(ValueError):
        as_scheduler_spec("warp-speed")
    with pytest.raises(ValueError):
        SchedulerSpec(composer="bogus")
    with pytest.raises(ValueError):
        SchedulerSpec(admission="bogus")
    with pytest.raises(ValueError):
        SchedulerSpec(chunk_tokens=0)


def test_scheduler_spec_properties():
    assert SchedulerSpec().coalescible          # serial + fcfs: legacy
    assert not SchedulerSpec(admission="srpt").coalescible
    assert not CHUNKED.coalescible
    assert CHUNKED.interleaves
    assert not SchedulerSpec(admission="srpt").interleaves
    assert "serial" in COMPOSERS and "fcfs" in ADMISSIONS


def test_fleet_spec_scheduler_normalizes():
    spec = FleetSpec(n_colocated=1, scheduler="srpt")
    assert spec.scheduler == SchedulerSpec(admission="srpt")
    spec = FleetSpec(n_colocated=1,
                     scheduler={"composer": "chunked-interleave"})
    assert spec.scheduler == CHUNKED


def test_intra_spec_shape():
    spec = FleetSpec(n_intra=1)
    assert spec.is_intra and not spec.is_colocated \
        and not spec.is_disaggregated
    assert spec.num_engines == 2          # one prefill + one decode slice
    assert spec.name == "intra-gpu"
    assert FleetSpec.parse("intra-gpu") == spec
    assert FleetSpec.parse("intra-2").n_intra == 2
    with pytest.raises(ValueError):
        FleetSpec(n_intra=1, n_colocated=1)
    with pytest.raises(ValueError):
        FleetSpec(n_intra=1, intra_split=1.0)
    with pytest.raises(ValueError):
        FleetSpec(n_intra=1, controller="adaptive")


def test_legacy_hash_unchanged():
    """scheduler=None / n_intra=0 must vanish from the cache-key
    encoding, so every pre-scheduler spec hash survives this PR."""
    enc = encode_fleet(FleetSpec(n_colocated=2))
    assert "scheduler" not in enc
    assert "n_intra" not in enc and "intra_split" not in enc
    enc = encode_fleet(FleetSpec(n_colocated=2, scheduler="srpt"))
    assert enc["scheduler"]["admission"] == "srpt"
    assert "n_intra" in encode_fleet(FleetSpec(n_intra=1))


# ----------------------------------------------------------------------
# chunk conservation + stall-free decode
# ----------------------------------------------------------------------
def _run_chunked(rate=8.0, n=12, prefill=2048, out=64, seed=3,
                 spec=None):
    spec = spec or FleetSpec(n_colocated=1, scheduler=CHUNKED)
    reqs = open_loop_workload(rate=rate, n=n,
                              lengths=PaperFixedLengths(prefill, out),
                              slo=DEFAULT_INTERACTIVE_SLO, seed=seed)
    cluster = make_cluster(spec, CFG)
    cluster.run(reqs)
    return cluster, reqs


def test_chunk_conservation():
    """For every request that was never evicted, the engine's chunk log
    partitions [0, prefill_len) exactly: contiguous, non-overlapping,
    summing to the prompt."""
    cluster, reqs = _run_chunked()
    assert all(r.finish_s is not None for r in reqs)
    log = {}
    for e in cluster.engines:
        for rid, c0, c1 in e.chunk_log:
            assert c1 > c0 >= 0
            log.setdefault(rid, []).append((c0, c1))
    assert log, "chunked composer emitted no chunks"
    for r in reqs:
        if r.evictions:
            continue                     # recompute restarts the ledger
        chunks = sorted(log.get(r.req_id, []))
        assert chunks, f"req {r.req_id} prefetched no chunks"
        assert chunks[0][0] == 0
        assert chunks[-1][1] == r.prompt_len
        for (a0, a1), (b0, b1) in zip(chunks, chunks[1:]):
            assert a1 == b0, f"req {r.req_id}: gap/overlap {a1}->{b0}"


def test_chunk_budget_respected():
    spec = FleetSpec(n_colocated=1,
                     scheduler=SchedulerSpec(
                         composer="chunked-interleave", chunk_tokens=512))
    cluster, _ = _run_chunked(spec=spec)
    for e in cluster.engines:
        for _, c0, c1 in e.chunk_log:
            assert c1 - c0 <= 512


def test_stall_free_decode():
    """The composed step bounds prefill-priority stalls: at a rate where
    the serial composer blows the TPOT budget on this workload, the
    chunked composer keeps median TPOT strictly lower and attains more
    goodput. (The fig11 crossover-shift claim, at unit-test scale.)"""
    wk = dict(rate=6.0, n=14, prefill=8192, out=64, seed=1)
    serial = FleetSpec(n_colocated=1)
    chunked = FleetSpec(n_colocated=1, scheduler=CHUNKED)
    out = {}
    for name, spec in (("serial", serial), ("chunked", chunked)):
        reqs = open_loop_workload(
            rate=wk["rate"], n=wk["n"],
            lengths=PaperFixedLengths(wk["prefill"], wk["out"]),
            slo=DEFAULT_INTERACTIVE_SLO, seed=wk["seed"])
        res = run_setup(spec, CFG, reqs)
        out[name] = res.metrics
    assert out["chunked"].median_tpot_s < out["serial"].median_tpot_s
    assert out["chunked"].goodput_rps >= out["serial"].goodput_rps


# ----------------------------------------------------------------------
# admission orders
# ----------------------------------------------------------------------
def _finish_order(spec, seed=0):
    reqs = open_loop_workload(rate=16.0, n=12,
                              lengths=PaperFixedLengths(2048, 64),
                              seed=seed)
    run_setup(spec, CFG, reqs)
    assert all(r.finish_s is not None for r in reqs)
    return [r.req_id for r in
            sorted(reqs, key=lambda r: (r.finish_s, r.req_id))]


def test_srpt_deterministic():
    spec = FleetSpec(n_colocated=1, scheduler="srpt")
    assert _finish_order(spec) == _finish_order(spec)


def test_admission_reorders_fcfs():
    """On a simultaneous bimodal wave, FCFS serves the long job first
    (lowest req_id); SJF/SRPT jump every short job ahead of it. The
    first-token order is the observable."""
    from repro.core.request import Request

    def wave():
        return [Request(req_id=0, prompt_len=8192, output_len=8,
                        arrival_s=0.0)] + \
               [Request(req_id=i, prompt_len=256, output_len=8,
                        arrival_s=0.0) for i in range(1, 6)]

    for admission, long_first in (("fcfs", True), ("sjf", False),
                                  ("srpt", False)):
        reqs = wave()
        spec = FleetSpec(n_colocated=1, scheduler=admission)
        run_setup(spec, CFG, reqs)
        assert all(r.first_token_s is not None for r in reqs)
        long_ft = reqs[0].first_token_s
        shorts_ft = [r.first_token_s for r in reqs[1:]]
        if long_first:
            assert long_ft < min(shorts_ft), admission
        else:
            assert long_ft > max(shorts_ft), admission


def test_admission_key_tiebreak_total_order():
    spec = SchedulerSpec(admission="sjf")

    class _Seq:
        def __init__(self, rid, p, o):
            self.req = type("R", (), {"req_id": rid, "prompt_len": p,
                                      "output_len": o,
                                      "generated": 0})()
            self.prefill_target = p
            self.prefill_done = 0

    a = spec.admission_key(_Seq(1, 512, 64), None)
    b = spec.admission_key(_Seq(2, 512, 64), None)
    assert a < b                         # equal work: req_id breaks tie
    assert spec.admission_key(_Seq(3, 256, 64), None) < a


# ----------------------------------------------------------------------
# intra-gpu: the sixth setup
# ----------------------------------------------------------------------
def test_cost_model_slice_partitions():
    cm = CostModel(CFG)
    lo, hi = cm.slice(0.4), cm.slice(0.6)
    assert lo.acc.chip.peak_flops + hi.acc.chip.peak_flops \
        == pytest.approx(cm.acc.chip.peak_flops)
    assert lo.acc.chip.p_static_w + hi.acc.chip.p_static_w \
        == pytest.approx(cm.acc.chip.p_static_w)
    # the pool geometry is config-derived, NOT scaled: slices share HBM
    assert lo.kv_bytes_per_token == cm.kv_bytes_per_token
    with pytest.raises(ValueError):
        cm.slice(0.0)
    with pytest.raises(ValueError):
        cm.slice(1.5)


def test_intra_cluster_runs_with_zero_transfer():
    spec = FleetSpec(n_intra=1)
    reqs = open_loop_workload(rate=2.0, n=8,
                              lengths=PaperFixedLengths(2048, 64),
                              slo=DEFAULT_INTERACTIVE_SLO, seed=0)
    cluster = make_cluster(spec, CFG)
    cluster.run(reqs)
    assert all(r.finish_s is not None for r in reqs)
    # P and D slices of one accelerator share one physical KV pool
    ep, ed = cluster.engines
    assert ep.pool is ed.pool
    assert ep.role == "prefill" and ed.role == "decode"
    # the handoff is a pointer swap: no transfer stage is ever metered
    stages = cluster.meter.by_stage
    assert stages.get("transfer-store", 0.0) == 0.0
    assert stages.get("transfer-fetch", 0.0) == 0.0
    # both slices burn energy under their own (partial) power model
    assert cluster.meter.joules[ep.name] > 0
    assert cluster.meter.joules[ed.name] > 0


def test_intra_not_in_legacy_setups():
    """The paper's five-setup sweeps (goldens, full_sweep) must not
    silently grow a sixth member."""
    from repro.core import SETUPS
    assert "intra-gpu" not in SETUPS and len(SETUPS) == 5


# ----------------------------------------------------------------------
# hypothesis invariants
# ----------------------------------------------------------------------
if HAS_HYPOTHESIS:
    from hypothesis import HealthCheck

    @settings(max_examples=15, deadline=None,
              suppress_health_check=list(HealthCheck))
    @given(chunk=st.sampled_from((256, 512, 1024, 4096)),
           rate=st.sampled_from((4.0, 8.0, 16.0)),
           seed=st.integers(0, 2 ** 10),
           prefill=st.sampled_from((512, 2048, 8192)))
    def test_chunk_conservation_fuzz(chunk, rate, seed, prefill):
        spec = FleetSpec(n_colocated=1,
                         scheduler=SchedulerSpec(
                             composer="chunked-interleave",
                             chunk_tokens=chunk))
        cluster, reqs = _run_chunked(rate=rate, n=10, prefill=prefill,
                                     out=32, seed=seed, spec=spec)
        log = {}
        for e in cluster.engines:
            for rid, c0, c1 in e.chunk_log:
                assert 0 < c1 - c0 <= chunk
                log.setdefault(rid, []).append((c0, c1))
        for r in reqs:
            if r.evictions:
                continue
            chunks = sorted(log.get(r.req_id, []))
            covered = sum(c1 - c0 for c0, c1 in chunks)
            assert covered == r.prompt_len, r.req_id
            for (_, a1), (b0, _) in zip(chunks, chunks[1:]):
                assert a1 == b0

    @settings(max_examples=10, deadline=None,
              suppress_health_check=list(HealthCheck))
    @given(admission=st.sampled_from(("sjf", "srpt", "prefix-aware")),
           seed=st.integers(0, 2 ** 10))
    def test_admission_deterministic_fuzz(admission, seed):
        spec = FleetSpec(n_colocated=2, scheduler=admission)
        assert _finish_order(spec, seed) == _finish_order(spec, seed)
else:  # pragma: no cover - container without the dev extra
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_sched_fuzz():
        pass
