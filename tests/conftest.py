"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests and benches must
see the host's real single device; only launch/dryrun.py forces 512."""
import gc

import jax
import pytest


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)


@pytest.fixture(autouse=True, scope="session")
def _isolated_exp_cache(tmp_path_factory):
    """Point the repro.exp result cache at a session tmpdir: tests must
    never read stale records from (or write into) the developer's
    benchmarks/out/cache — a cost-model change would otherwise make
    cached sweeps disagree with fresh simulations mid-suite."""
    from repro.exp import ResultCache, set_default_cache
    set_default_cache(
        ResultCache(str(tmp_path_factory.mktemp("exp-cache"))))
    yield
    set_default_cache(None)


@pytest.fixture(autouse=True, scope="module")
def _clear_jax_caches_between_modules():
    """The full suite jits hundreds of programs; on the 35 GB container the
    accumulated executables eventually OOM LLVM's JIT ("Cannot allocate
    memory"). Dropping caches per module keeps memory bounded."""
    yield
    jax.clear_caches()
    gc.collect()
