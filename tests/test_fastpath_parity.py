"""Differential fuzzing: the coalescing fast stepper vs the exact one.

DESIGN.md section 13's correctness contract, executed: any (fleet shape,
router, governor, arrival process, length mix, seed) drawn here must
produce OBSERVABLY IDENTICAL results through both steppers — bit-equal
metrics, per-request timestamps, per-component joules, and power-trace
samples; per-stage joules to 1e-9 relative (cross-engine fold order is
relaxed, see fastpath module docstring). No tolerance anywhere else: a
single flipped bit anywhere in the simulation is a failure.

The deterministic grid below always runs (no hypothesis needed); the
``@given`` fuzz adds randomized shapes on top. CI's parity lane turns
the example count up via ``REPRO_PARITY_EXAMPLES`` (200+); the default
stays small enough for the tier-1 wall-clock budget.
"""
import dataclasses
import os

import pytest

from hypothesis_compat import HAS_HYPOTHESIS, given, settings, st

from repro.configs import get_config
from repro.core.orchestrator import run_setup
from repro.fleet.spec import FleetSpec
from repro.workload import (DEFAULT_INTERACTIVE_SLO, PaperFixedLengths,
                            RAGSharedPrefixLengths, ShareGPTLengths,
                            open_loop_workload)

CFG = get_config("llama32-3b")

REQUEST_FIELDS = ("arrival_s", "prefill_start_s", "prefill_done_s",
                  "decode_start_s", "first_token_s", "finish_s",
                  "generated", "evictions", "recomputed_tokens",
                  "reused_tokens")


def run_both(spec, wk):
    out = {}
    for stepper in ("exact", "fast"):
        reqs = open_loop_workload(**wk)
        out[stepper] = (run_setup(spec, CFG, reqs, stepper=stepper), reqs)
    return out


def assert_parity(spec, wk):
    both = run_both(spec, wk)
    (res_e, reqs_e), (res_f, reqs_f) = both["exact"], both["fast"]

    # workload metrics: every aggregate, bit-for-bit
    assert dataclasses.asdict(res_e.metrics) == \
        dataclasses.asdict(res_f.metrics)

    # per-request lifecycle timestamps and counters, bit-for-bit
    for a, b in zip(reqs_e, reqs_f):
        for f in REQUEST_FIELDS:
            assert getattr(a, f) == getattr(b, f), \
                (f"req {a.req_id} field {f}: "
                 f"{getattr(a, f)!r} != {getattr(b, f)!r}")

    # per-component joules fold in engine order on both paths: bit-exact
    assert res_e.energy.joules == res_f.energy.joules

    # per-stage joules: cross-engine accumulation order is relaxed
    se, sf = res_e.energy.by_stage, res_f.energy.by_stage
    assert set(se) == set(sf)
    for k in se:
        assert sf[k] == pytest.approx(se[k], rel=1e-9, abs=1e-12), k

    # the power-state timeline: identical samples, in order
    te, tf = res_e.energy.trace, res_f.energy.trace
    assert te.components == tf.components
    for c in te.components:
        assert te.samples[c] == tf.samples[c], \
            f"trace[{c}] samples diverge"


# ----------------------------------------------------------------------
# deterministic grid: every setup archetype x a workload that exercises
# admission waves, transfer legs, and steady-state decode
# ----------------------------------------------------------------------
GRID = [
    (FleetSpec(n_colocated=1),
     dict(rate=4.0, n=12, lengths=PaperFixedLengths(4096, 32),
          slo=DEFAULT_INTERACTIVE_SLO, seed=0)),
    (FleetSpec(n_colocated=2),
     dict(rate=8.0, n=16, lengths=PaperFixedLengths(2048, 128), seed=1)),
    (FleetSpec(n_prefill=1, n_decode=1, medium="ici"),
     dict(rate=4.0, n=12, lengths=PaperFixedLengths(4096, 32),
          slo=DEFAULT_INTERACTIVE_SLO, seed=0)),
    (FleetSpec(n_prefill=2, n_decode=2, medium="host",
               kv_router="least-outstanding-tokens"),
     dict(rate=8.0, n=16, lengths=PaperFixedLengths(2048, 128), seed=1)),
    (FleetSpec(n_prefill=1, n_decode=2, medium="disk",
               phi_decode=(0.8, 1.0)),
     dict(rate=2.0, n=10, lengths=ShareGPTLengths(), seed=2)),
    # online governor: fast path must bail to the exact stepper and
    # still match it bit-for-bit
    (FleetSpec(n_prefill=2, n_decode=1, medium="ici", phi_prefill=0.7,
               governor="queue-depth"),
     dict(rate=4.0, n=10, lengths=PaperFixedLengths(2048, 64), seed=3)),
    (FleetSpec(n_colocated=2, governor="slo-slack"),
     dict(rate=6.0, n=10, lengths=PaperFixedLengths(1024, 64),
          slo=DEFAULT_INTERACTIVE_SLO, seed=4)),
    # tiny pool pressure: colocated growth hits preemption -> exact
    (FleetSpec(n_colocated=1),
     dict(rate=16.0, n=12, lengths=PaperFixedLengths(8192, 256),
          seed=5)),
    # fleet controllers (DESIGN.md section 14): the no-op controller is
    # coalescible, so the fast stepper keeps vectorizing and must still
    # match exact; active controllers make fast bail to exact — parity
    # must hold either way (that IS the bail rule's contract)
    (FleetSpec(n_prefill=2, n_decode=2, medium="ici", controller="null"),
     dict(rate=8.0, n=14, lengths=PaperFixedLengths(2048, 64), seed=6)),
    (FleetSpec(n_prefill=2, n_decode=2, medium="ici",
               controller="adaptive"),
     dict(rate=6.0, n=12, lengths=PaperFixedLengths(1024, 128),
          slo=DEFAULT_INTERACTIVE_SLO, seed=7)),
    (FleetSpec(n_colocated=2, controller="schedule"),
     dict(rate=8.0, n=12, lengths=PaperFixedLengths(2048, 32), seed=8)),
    (FleetSpec(n_prefill=1, n_decode=2, medium="host",
               controller="schedule", governor="queue-depth"),
     dict(rate=4.0, n=10, lengths=PaperFixedLengths(2048, 64), seed=9)),
    # KV reuse (DESIGN.md section 15): a flat shared cache stays
    # fast-eligible and must coalesce bit-identically; tiered stores
    # make the fast stepper bail to exact — parity must hold either way
    # (that IS the bail rule's contract)
    (FleetSpec(n_colocated=2, reuse="prefix"),
     dict(rate=6.0, n=12, lengths=RAGSharedPrefixLengths(prefix_len=1024),
          vocab_size=512, seed=10)),
    (FleetSpec(n_colocated=2, router="prefix-affinity",
               reuse={"mode": "prefix",
                      "tiers": {"hbm_pages": 64, "dram_pages": 128,
                                "disk_pages": 256}}),
     dict(rate=6.0, n=12, lengths=RAGSharedPrefixLengths(prefix_len=1024),
          vocab_size=512, seed=11)),
    (FleetSpec(n_prefill=1, n_decode=1, medium="ici",
               router="prefix-affinity",
               reuse={"mode": "pic",
                      "tiers": {"hbm_pages": 32, "dram_pages": 64}}),
     dict(rate=4.0, n=10, lengths=RAGSharedPrefixLengths(prefix_len=2048),
          vocab_size=512, slo=DEFAULT_INTERACTIVE_SLO, seed=12)),
    (FleetSpec(n_prefill=2, n_decode=2, medium="host",
               reuse={"mode": "pic", "tiers": {"hbm_pages": 16,
                                               "dram_pages": 32,
                                               "prefetch_pages": 2}}),
     dict(rate=8.0, n=14, lengths=RAGSharedPrefixLengths(prefix_len=1024),
          vocab_size=512, seed=13)),
    # step schedulers (DESIGN.md section 17): non-coalescible composers
    # and admission orders make the fast stepper bail to exact — parity
    # must hold either way (that IS the bail rule's contract); the
    # intra-gpu shape bails wholesale (shared-pool coalescing unsound)
    (FleetSpec(n_colocated=1, scheduler={"composer": "chunked-interleave"}),
     dict(rate=8.0, n=14, lengths=PaperFixedLengths(2048, 64), seed=14)),
    (FleetSpec(n_colocated=2, scheduler={"admission": "srpt"}),
     dict(rate=8.0, n=14, lengths=PaperFixedLengths(2048, 128), seed=15)),
    (FleetSpec(n_prefill=1, n_decode=1, medium="ici",
               scheduler={"composer": "chunked-interleave",
                          "admission": "sjf", "chunk_tokens": 512}),
     dict(rate=4.0, n=12, lengths=PaperFixedLengths(4096, 32),
          slo=DEFAULT_INTERACTIVE_SLO, seed=16)),
    (FleetSpec(n_intra=1),
     dict(rate=2.0, n=10, lengths=PaperFixedLengths(2048, 64), seed=17)),
    (FleetSpec(n_intra=1, intra_split=0.3,
               scheduler={"composer": "chunked-interleave",
                          "admission": "srpt"}),
     dict(rate=2.0, n=10, lengths=PaperFixedLengths(1024, 128),
          slo=DEFAULT_INTERACTIVE_SLO, seed=18)),
]


@pytest.mark.parametrize("case", range(len(GRID)))
def test_parity_grid(case):
    spec, wk = GRID[case]
    assert_parity(spec, wk)


def test_stepper_arg_validation():
    reqs = open_loop_workload(rate=4.0, n=2,
                              lengths=PaperFixedLengths(256, 8), seed=0)
    with pytest.raises(AssertionError):
        run_setup("co-1gpu", CFG, reqs, stepper="warp")


# ----------------------------------------------------------------------
# randomized fuzz over the full spec product space
# ----------------------------------------------------------------------
MEDIA = ("ici", "host", "disk")
GOVERNORS = ("static", "queue-depth", "slo-slack")
ROUTERS = ("round-robin", "least-outstanding-tokens", "prefix-affinity")
KV_ROUTERS = ("kv-free-space", "least-outstanding-tokens")
ARRIVALS = ("poisson", "gamma")
# the controller axis: none / static-equivalent no-op / active
CONTROLLERS = (None, "null", "schedule", "adaptive")
# the reuse axis: none / flat shared cache (fast-eligible) / tiered
# stores (fast bails to exact); small budgets so evictions + tier
# traffic actually happen at fuzz workload sizes
REUSES = (None, "prefix", {"mode": "pic"},
          {"mode": "prefix", "tiers": {"hbm_pages": 16, "dram_pages": 32,
                                       "disk_pages": 32}},
          {"mode": "pic", "tiers": {"hbm_pages": 8, "dram_pages": 16,
                                    "prefetch_pages": 2}})
# the scheduler axis (DESIGN.md section 17): None keeps the legacy
# serial/FCFS paths (fast-eligible); a bare admission swap stays on the
# serial composer but bails; chunked composers bail wholesale
SCHEDULERS = (None, {"admission": "srpt"}, {"admission": "sjf"},
              {"composer": "chunked-interleave"},
              {"composer": "chunked-interleave", "admission": "srpt",
               "chunk_tokens": 512})

N_EXAMPLES = int(os.environ.get("REPRO_PARITY_EXAMPLES", "20"))


def _spec_strategy():
    colocated = st.builds(
        lambda n, gov, ctl, r, reuse, sched: FleetSpec(
            n_colocated=n, governor=gov, controller=ctl, router=r,
            reuse=reuse, scheduler=sched),
        st.integers(1, 2), st.sampled_from(GOVERNORS),
        st.sampled_from(CONTROLLERS), st.sampled_from(ROUTERS),
        st.sampled_from(REUSES), st.sampled_from(SCHEDULERS))
    disagg = st.builds(
        lambda p, d, m, r, kr, gov, ctl, phi_p, phi_d, reuse, sched:
        FleetSpec(
            n_prefill=p, n_decode=d, medium=m, router=r, kv_router=kr,
            governor=gov, controller=ctl, phi_prefill=phi_p,
            phi_decode=phi_d, reuse=reuse, scheduler=sched),
        st.integers(1, 3), st.integers(1, 3), st.sampled_from(MEDIA),
        st.sampled_from(ROUTERS), st.sampled_from(KV_ROUTERS),
        st.sampled_from(GOVERNORS), st.sampled_from(CONTROLLERS),
        st.sampled_from((0.6, 0.8, 1.0)), st.sampled_from((0.7, 1.0)),
        st.sampled_from(REUSES), st.sampled_from(SCHEDULERS))
    # the sixth setup: SM-partitioned P/D slices over one shared pool
    # (never fast-eligible — parity pins the wholesale bail)
    intra = st.builds(
        lambda n, split, gov, sched: FleetSpec(
            n_intra=n, intra_split=split, governor=gov, scheduler=sched),
        st.integers(1, 2), st.sampled_from((0.3, 0.5, 0.7)),
        st.sampled_from(GOVERNORS), st.sampled_from(SCHEDULERS))
    return st.one_of(colocated, disagg, intra)


def _workload_strategy():
    fixed = st.builds(
        lambda p, o: PaperFixedLengths(p, o),
        st.sampled_from((512, 2048, 4096, 8192)),
        st.sampled_from((1, 8, 32, 128, 256)))
    sharegpt = st.just(ShareGPTLengths())
    rag = st.builds(lambda p: RAGSharedPrefixLengths(prefix_len=p),
                    st.sampled_from((512, 1024, 2048)))
    return st.builds(
        lambda rate, n, lengths, arrival, slo, seed, vocab: dict(
            rate=rate, n=n, lengths=lengths, arrival=arrival,
            slo=slo, seed=seed, vocab_size=vocab),
        st.sampled_from((1.0, 4.0, 12.0, 32.0)),
        st.integers(2, 14),
        st.one_of(fixed, sharegpt, rag),
        st.sampled_from(ARRIVALS),
        st.sampled_from((None, DEFAULT_INTERACTIVE_SLO)),
        st.integers(0, 2 ** 16),
        # vocab_size=0 -> no prompt token arrays -> reuse stays inert;
        # both arms must hold parity
        st.sampled_from((0, 512)))


if HAS_HYPOTHESIS:
    from hypothesis import HealthCheck

    @settings(max_examples=N_EXAMPLES, deadline=None,
              suppress_health_check=list(HealthCheck))
    @given(spec=_spec_strategy(), wk=_workload_strategy())
    def test_parity_fuzz(spec, wk):
        assert_parity(spec, wk)
else:  # pragma: no cover - container without the dev extra
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_parity_fuzz():
        pass
