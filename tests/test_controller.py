"""Fleet-controller invariant layer (DESIGN.md section 14).

The adaptive-fleet machinery (autoscaling, P<->D role flips,
scale-to-zero) is only trustworthy if a set of invariants holds under
*any* controller schedule — including the adversarial random one
(``ScheduleController``). This module locks them down:

  * every submitted request completes exactly once, under any
    scale/flip/sleep schedule x router x arrival x seed;
  * no request is ever routed to a sleeping, draining, or absent
    instance (asserted at the submit/enqueue boundary itself);
  * causality: finish >= first token >= prefill start >= arrival;
  * no KV page leaks across role flips — every pool drains to empty
    and passes its own invariant check;
  * the power-state timeline covers the full run span per accelerator
    with no gaps and no overlaps, and ``state_summary`` buckets
    sleep/absent intervals honestly instead of back-filling idle
    joules (the fig9 energy claim rests on this).

The no-op ``NullController`` must additionally be *observably
invisible*: bit-identical results to ``controller=None`` on the fast
stepper, which is what keeps the fig5/6/8 goldens byte-stable.
"""
import dataclasses
import os

import pytest

from hypothesis_compat import HAS_HYPOTHESIS, given, settings, st

from repro.configs import get_config
from repro.fleet import (ControllerSpec, NullController, ScheduleController,
                         as_controller_spec, make_controller)
from repro.fleet.cluster import FleetCluster
from repro.fleet.spec import FleetSpec
from repro.govern import PowerTrace
from repro.workload import (DEFAULT_INTERACTIVE_SLO, PaperFixedLengths,
                            open_loop_workload)

CFG = get_config("llama32-3b")

REQUEST_FIELDS = ("arrival_s", "prefill_start_s", "prefill_done_s",
                  "decode_start_s", "first_token_s", "finish_s",
                  "generated", "evictions", "recomputed_tokens",
                  "reused_tokens")


# ----------------------------------------------------------------------
# spec plumbing
# ----------------------------------------------------------------------
def test_controller_spec_validation():
    with pytest.raises(ValueError):
        ControllerSpec(interval_s=0.0)
    with pytest.raises(ValueError):
        ControllerSpec(wake_latency_s=-1.0)
    with pytest.raises(ValueError):
        make_controller(ControllerSpec(policy="warp"))


def test_controller_spec_coercion():
    cs = as_controller_spec("adaptive")
    assert isinstance(cs, ControllerSpec) and cs.policy == "adaptive"
    cs2 = as_controller_spec({"policy": "schedule", "interval_s": 0.5})
    assert cs2.policy == "schedule" and cs2.interval_s == 0.5
    assert as_controller_spec(cs) is cs
    # FleetSpec coerces through __post_init__, keeping itself hashable
    fs = FleetSpec(n_prefill=1, n_decode=1, medium="ici",
                   controller={"policy": "null"})
    assert isinstance(fs.controller, ControllerSpec)
    hash(fs)
    # a controller-free spec stays controller-free (cache-key stability)
    assert FleetSpec(n_colocated=1).controller is None


def test_make_controller_registry():
    assert isinstance(make_controller("null"), NullController)
    sched = make_controller(ControllerSpec(policy="schedule"), seed=7)
    assert isinstance(sched, ScheduleController)
    assert make_controller("null").coalescible
    assert not make_controller("adaptive").coalescible


# ----------------------------------------------------------------------
# null controller: observably invisible
# ----------------------------------------------------------------------
@pytest.mark.parametrize("spec_kw", [
    dict(n_colocated=2),
    dict(n_prefill=2, n_decode=2, medium="ici"),
])
def test_null_controller_bit_identical(spec_kw):
    wk = dict(rate=6.0, n=12, lengths=PaperFixedLengths(2048, 64),
              slo=DEFAULT_INTERACTIVE_SLO, seed=3)
    results = {}
    for ctl in (None, "null"):
        reqs = open_loop_workload(**wk)
        cluster = FleetCluster(FleetSpec(controller=ctl, **spec_kw), CFG)
        results[ctl] = (cluster.run(reqs, stepper="fast"), reqs)
    (res_n, reqs_n), (res_0, reqs_0) = results[None], results["null"]
    assert dataclasses.asdict(res_n.metrics) == \
        dataclasses.asdict(res_0.metrics)
    for a, b in zip(reqs_n, reqs_0):
        for f in REQUEST_FIELDS:
            assert getattr(a, f) == getattr(b, f), (a.req_id, f)
    assert res_n.energy.joules == res_0.energy.joules
    assert res_n.energy.by_stage == res_0.energy.by_stage


# ----------------------------------------------------------------------
# the invariant harness
# ----------------------------------------------------------------------
def _guard_routing(cluster):
    """Assert at the submit/enqueue boundary that work only ever lands
    on an accepting (ACTIVE, non-draining) engine. The role-flip local
    handoff marks the engine accepting before re-enqueueing, so the
    guard holds there too."""
    for e in cluster.engines:
        orig_submit = e.submit
        orig_enq = e.enqueue_decode

        def submit(r, e=e, orig=orig_submit):
            assert e.accepting, \
                f"request {r.req_id} routed to non-accepting {e.name}"
            assert cluster.lifecycle_state(e) == "on", \
                f"request {r.req_id} routed to {e.name} " \
                f"({cluster.lifecycle_state(e)})"
            return orig(r)

        def enqueue_decode(seq, path, leg, e=e, orig=orig_enq):
            # the routing DECISION only picks accepting engines, but a
            # transfer already in flight may deliver to one that began
            # draining afterwards (drain completion waits on inflight
            # KV) — so the hard line is: never to a sleeping/absent one
            assert cluster.lifecycle_state(e) == "on", \
                f"seq {seq.seq_id} KV delivered to {e.name} " \
                f"({cluster.lifecycle_state(e)})"
            return orig(seq, path, leg)

        e.submit = submit
        e.enqueue_decode = enqueue_decode


def check_invariants(spec, wk, stepper="exact"):
    reqs = open_loop_workload(**wk)
    cluster = FleetCluster(spec, CFG)
    _guard_routing(cluster)
    res = cluster.run(reqs, stepper=stepper)

    # every request completes exactly once, causally ordered
    assert res.metrics.num_requests == len(reqs)
    for r in reqs:
        assert r.done and r.finish_s is not None
        assert r.prefill_start_s >= r.arrival_s         # queue delay >= 0
        assert r.first_token_s >= r.prefill_start_s     # TTFT >= queue
        assert r.finish_s >= r.first_token_s
        assert r.generated == r.output_len

    # no KV leaks across flips/sleeps: every pool empty + consistent
    for e in cluster.engines:
        e.pool.check_invariants()
        assert not e.pool.seqs, \
            f"{e.name} leaked {len(e.pool.seqs)} seq allocs"
        assert e.pool.used_pages == 0
        assert e.inflight_kv_pages == 0
    assert not cluster._parked_requests
    assert not cluster._parked_transfers
    assert not cluster._draining

    # power-state timeline: full span, no gaps, no overlaps
    trace = res.energy.trace
    t0 = min(r.arrival_s for r in reqs)
    t1 = max(r.finish_s for r in reqs)
    for e in cluster.engines:
        assert trace.covers(e.name, t0, t1), f"{e.name} trace has gaps"
        covered = sum(s.seconds for s in trace.samples[e.name])
        assert covered == pytest.approx(t1 - t0, abs=1e-6), \
            f"{e.name} trace overlaps: {covered} != {t1 - t0}"
    return cluster, res


SCHED = ControllerSpec(policy="schedule", interval_s=0.1,
                       wake_latency_s=0.3, sleep_after_s=0.2)


@pytest.mark.parametrize("spec", [
    FleetSpec(n_colocated=2, controller=SCHED),
    FleetSpec(n_prefill=2, n_decode=2, medium="ici", controller=SCHED),
    FleetSpec(n_prefill=1, n_decode=2, medium="host",
              kv_router="least-outstanding-tokens", controller=SCHED),
    FleetSpec(n_prefill=2, n_decode=1, medium="ici", controller="adaptive",
              governor="queue-depth"),
])
def test_invariants_grid(spec):
    wk = dict(rate=8.0, n=14, lengths=PaperFixedLengths(2048, 64),
              slo=DEFAULT_INTERACTIVE_SLO, seed=1)
    check_invariants(spec, wk)


def test_adaptive_sleeps_and_saves():
    """The controller's reason to exist: on a sparse workload the
    adaptive fleet sleeps idle instances and spends less total energy
    than the same static fleet, at identical request outcomes."""
    wk = dict(rate=4.0, n=40, lengths=PaperFixedLengths(1024, 128),
              slo=DEFAULT_INTERACTIVE_SLO, seed=0)
    ctl = ControllerSpec(policy="adaptive", interval_s=0.1,
                         sleep_after_s=0.3, initial_awake_prefill=1,
                         initial_awake_decode=1)
    cluster, res = check_invariants(
        FleetSpec(n_prefill=2, n_decode=2, medium="ici", controller=ctl),
        wk)
    reqs = open_loop_workload(**wk)
    static = FleetCluster(
        FleetSpec(n_prefill=2, n_decode=2, medium="ici"), CFG).run(reqs)
    assert cluster.controller_log, "adaptive controller never acted"
    ops = {entry["op"] for entry in cluster.controller_log}
    assert "sleep" in ops or "wake" in ops
    assert sum(res.energy.joules.values()) < \
        sum(static.energy.joules.values())
    assert res.energy.by_stage.get("sleep", 0.0) > 0.0


def test_schedule_controller_flips_roles():
    """The adversary actually exercises the flip machinery (otherwise
    the fuzz proves nothing about KV drains across flips)."""
    spec = FleetSpec(n_prefill=2, n_decode=2, medium="ici",
                     controller=SCHED, seed=5)
    wk = dict(rate=10.0, n=20, lengths=PaperFixedLengths(2048, 64),
              seed=5)
    cluster, _ = check_invariants(spec, wk)
    ops = [e["op"] for e in cluster.controller_log]
    assert any(op.startswith("flip") or op == "drain" for op in ops), ops


# ----------------------------------------------------------------------
# telemetry: sleep/absent intervals are bucketed, never idle-backfilled
# ----------------------------------------------------------------------
def test_state_summary_buckets_sleep_and_absent():
    tr = PowerTrace()
    tr.record("acc0", 0.0, 1.0, 100.0, stage="prefill", state="active")
    tr.record("acc0", 1.0, 3.0, 10.0, stage="idle", state="idle")
    tr.record("acc0", 3.0, 6.0, 2.0, stage="sleep", state="sleep")
    tr.record("acc0", 6.0, 10.0, 0.0, stage="absent", state="absent")
    row = tr.state_summary()["acc0"]
    assert row["active_j"] == pytest.approx(100.0)
    assert row["active_s"] == pytest.approx(1.0)
    assert row["idle_j"] == pytest.approx(20.0)
    assert row["idle_s"] == pytest.approx(2.0)
    assert row["sleep_j"] == pytest.approx(6.0)
    assert row["sleep_s"] == pytest.approx(3.0)
    assert row["absent_j"] == pytest.approx(0.0)
    assert row["absent_s"] == pytest.approx(4.0)


def test_no_idle_backfill_for_sleeping_engine():
    """Regression for the latent gap-fill assumption: an engine that
    deep-sleeps mid-run must show SLEEP intervals in its trace, not
    idle joules silently back-filled over the gap."""
    wk = dict(rate=4.0, n=24, lengths=PaperFixedLengths(1024, 64),
              slo=DEFAULT_INTERACTIVE_SLO, seed=2)
    ctl = ControllerSpec(policy="adaptive", interval_s=0.1,
                         sleep_after_s=0.2, initial_awake_prefill=1,
                         initial_awake_decode=1)
    cluster, res = check_invariants(
        FleetSpec(n_prefill=2, n_decode=2, medium="ici", controller=ctl),
        wk)
    summary = res.energy.trace.state_summary()
    slept = [e.name for e in cluster.engines
             if summary[e.name].get("sleep_s", 0.0)
             + summary[e.name].get("absent_s", 0.0) > 0.0]
    assert slept, "no engine ever slept — regression test lost its bite"
    idle_w = cluster.cost.idle_power_w()
    sleep_w = cluster.cost.sleep_power_w()
    assert sleep_w < idle_w
    for name in slept:
        row = summary[name]
        # the sleep/absent span is priced at sleep/zero watts — an idle
        # backfill would have put idle_w joules over those seconds
        off_s = row.get("sleep_s", 0.0) + row.get("absent_s", 0.0)
        off_j = row.get("sleep_j", 0.0) + row.get("absent_j", 0.0)
        assert off_j <= sleep_w * off_s + 1e-9
        assert off_j < idle_w * off_s


# ----------------------------------------------------------------------
# randomized schedules: the property layer
# ----------------------------------------------------------------------
N_EXAMPLES = int(os.environ.get("REPRO_CONTROLLER_EXAMPLES", "15"))


def _spec_strategy():
    controller = st.builds(
        lambda policy, interval, wake, sleep_after: ControllerSpec(
            policy=policy, interval_s=interval, wake_latency_s=wake,
            sleep_after_s=sleep_after),
        st.sampled_from(("schedule", "adaptive")),
        st.sampled_from((0.05, 0.1, 0.25)),
        st.sampled_from((0.0, 0.2, 0.5)),
        st.sampled_from((0.1, 0.4)))
    colocated = st.builds(
        lambda n, ctl, seed: FleetSpec(n_colocated=n, controller=ctl,
                                       seed=seed),
        st.integers(1, 3), controller, st.integers(0, 2 ** 10))
    disagg = st.builds(
        lambda p, d, m, r, kr, ctl, seed: FleetSpec(
            n_prefill=p, n_decode=d, medium=m, router=r, kv_router=kr,
            controller=ctl, seed=seed),
        st.integers(1, 3), st.integers(1, 3),
        st.sampled_from(("ici", "host", "disk")),
        st.sampled_from(("round-robin", "least-outstanding-tokens")),
        st.sampled_from(("kv-free-space", "least-outstanding-tokens")),
        controller, st.integers(0, 2 ** 10))
    return st.one_of(colocated, disagg)


def _workload_strategy():
    return st.builds(
        lambda rate, n, p, o, arrival, seed: dict(
            rate=rate, n=n, lengths=PaperFixedLengths(p, o),
            arrival=arrival, slo=DEFAULT_INTERACTIVE_SLO, seed=seed),
        st.sampled_from((2.0, 8.0, 24.0)),
        st.integers(2, 12),
        st.sampled_from((512, 2048, 4096)),
        st.sampled_from((1, 16, 64)),
        st.sampled_from(("poisson", "gamma", "diurnal")),
        st.integers(0, 2 ** 16))


if HAS_HYPOTHESIS:
    from hypothesis import HealthCheck

    @settings(max_examples=N_EXAMPLES, deadline=None,
              suppress_health_check=list(HealthCheck))
    @given(spec=_spec_strategy(), wk=_workload_strategy())
    def test_invariants_fuzz(spec, wk):
        check_invariants(spec, wk)
else:  # pragma: no cover - container without the dev extra
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_invariants_fuzz():
        pass
