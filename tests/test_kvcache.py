"""Paged KV pool: unit + hypothesis property tests (pool invariants hold
under any operation mix) + device-backed pages vs dense attention."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core.kvcache import DevicePagedKV, OutOfPages, PagedKVPool
from repro.kernels import ops, ref


# ----------------------------------------------------------------------
def test_basic_alloc_free():
    pool = PagedKVPool(num_pages=10, page_size=16)
    pages = pool.allocate(1, 40)            # 3 pages
    assert len(pages) == 3
    assert pool.used_pages == 3
    pool.allocate(1, 8)                     # 48 tokens -> still 3 pages
    assert pool.used_pages == 3
    pool.allocate(1, 1)                     # 49 -> 4 pages
    assert pool.used_pages == 4
    assert pool.free_seq(1) == 4
    assert pool.used_pages == 0


def test_out_of_pages_raises():
    pool = PagedKVPool(num_pages=4, page_size=16)
    pool.allocate(1, 64)
    with pytest.raises(OutOfPages):
        pool.allocate(2, 1)
    pool.check_invariants()


def test_lru_eviction_order():
    pool = PagedKVPool(num_pages=12, page_size=16)
    for sid in (1, 2, 3):
        pool.allocate(sid, 64)
    pool.touch(1)                            # 2 becomes LRU
    assert pool.evict_lru() == 2
    assert pool.evict_lru(exclude={3}) == 1
    pool.check_invariants()


def test_evict_from_empty_pool_returns_none():
    pool = PagedKVPool(num_pages=4, page_size=16)
    assert pool.evict_lru() is None
    assert pool.lru_candidates() == []
    pool.check_invariants()


def test_evict_exclude_covers_all_seqs():
    pool = PagedKVPool(num_pages=12, page_size=16)
    for sid in (1, 2, 3):
        pool.allocate(sid, 32)
    assert pool.evict_lru(exclude={1, 2, 3}) is None
    assert pool.lru_candidates(exclude={1, 2, 3}) == []
    assert pool.used_pages == 6              # nothing was freed
    # a partial exclude set still reports the rest in LRU order
    assert pool.lru_candidates(exclude={2}) == [1, 3]
    pool.check_invariants()


def test_touch_reorders_eviction_order():
    pool = PagedKVPool(num_pages=12, page_size=16)
    for sid in (1, 2, 3):
        pool.allocate(sid, 16)
    assert pool.lru_candidates() == [1, 2, 3]
    pool.touch(1)
    pool.touch(2)
    assert pool.lru_candidates() == [3, 1, 2]
    assert pool.evict_lru() == 3
    # allocate() touches too: seq 1 becomes MRU again
    pool.allocate(1, 1)
    assert pool.evict_lru() == 2
    pool.check_invariants()


def test_free_unknown_seq_is_noop():
    pool = PagedKVPool(num_pages=4, page_size=16)
    assert pool.free_seq(99) == 0
    pool.check_invariants()


def test_check_invariants_catches_corruption():
    pool = PagedKVPool(num_pages=8, page_size=16)
    pool.allocate(1, 32)
    pool.seqs[1].pages.append(pool.seqs[1].pages[0])   # double-grant
    with pytest.raises(AssertionError):
        pool.check_invariants()
    pool.seqs[1].pages.pop()
    pool.check_invariants()
    pool.seqs[1].tokens += 100                         # count mismatch
    with pytest.raises(AssertionError):
        pool.check_invariants()


def test_from_bytes_sizing():
    kv_per_tok = 114_688                     # llama32-3b
    pool = PagedKVPool.from_bytes(28e9, kv_per_tok, page_size=16)
    cap_tokens = pool.num_pages * pool.page_size
    assert abs(cap_tokens - 28e9 / kv_per_tok) <= pool.page_size


# ----------------------------------------------------------------------
# property: any sequence of (alloc, free, evict) ops keeps the invariants
# ----------------------------------------------------------------------
@settings(max_examples=60, deadline=None)
@given(st.lists(st.tuples(st.sampled_from(["alloc", "free", "evict"]),
                          st.integers(0, 7),       # seq id
                          st.integers(1, 120)),    # token count
                min_size=1, max_size=60))
def test_pool_invariants_hold(ops_list):
    pool = PagedKVPool(num_pages=32, page_size=16)
    for op, sid, tokens in ops_list:
        if op == "alloc":
            try:
                pool.allocate(sid, tokens)
            except OutOfPages:
                pass
        elif op == "free":
            pool.free_seq(sid)
        else:
            pool.evict_lru()
        pool.check_invariants()
        assert 0 <= pool.used_pages <= pool.num_pages


@settings(max_examples=30, deadline=None)
@given(st.integers(1, 200), st.integers(1, 64))
def test_pages_for_is_ceiling(tokens, page_size):
    pool = PagedKVPool(num_pages=1, page_size=page_size)
    assert pool.pages_for(tokens) == -(-tokens // page_size)


# ----------------------------------------------------------------------
# device-backed pages: prefill scatter + paged attention == dense
# ----------------------------------------------------------------------
def test_device_paged_kv_roundtrip():
    L, KV, hd, page = 2, 2, 32, 16
    pool = PagedKVPool(num_pages=8, page_size=page)
    dev = DevicePagedKV(pool, L, KV, hd, dtype=jnp.float32)
    S = 40
    ks = jax.random.normal(jax.random.PRNGKey(1), (L, S, KV, hd))
    vs = jax.random.normal(jax.random.PRNGKey(2), (L, S, KV, hd))
    pool.allocate(7, S)
    dev.write_prefill(7, ks, vs)
    k_back, v_back = dev.gather_dense(7)
    np.testing.assert_array_equal(np.asarray(k_back), np.asarray(ks))
    np.testing.assert_array_equal(np.asarray(v_back), np.asarray(vs))
    # single-token append
    k_tok = jax.random.normal(jax.random.PRNGKey(3), (L, KV, hd))
    v_tok = jax.random.normal(jax.random.PRNGKey(4), (L, KV, hd))
    pool.allocate(7, 1)
    dev.write_token(7, k_tok, v_tok, S)
    k_back, _ = dev.gather_dense(7)
    np.testing.assert_array_equal(np.asarray(k_back[:, S]),
                                  np.asarray(k_tok))


def test_paged_attention_over_pool_matches_dense():
    """Block-table attention over a REAL pool == dense cached attention."""
    KV, hd, page, H = 2, 32, 16, 4
    pool = PagedKVPool(num_pages=16, page_size=page)
    dev = DevicePagedKV(pool, 1, KV, hd, dtype=jnp.float32)
    lens = [37, 52]
    for sid, S in enumerate(lens):
        ks = jax.random.normal(jax.random.PRNGKey(10 + sid), (1, S, KV, hd))
        vs = jax.random.normal(jax.random.PRNGKey(20 + sid), (1, S, KV, hd))
        pool.allocate(sid, S)
        dev.write_prefill(sid, ks, vs)
    max_pages = max(len(pool.block_table(s)) for s in (0, 1))
    bt = np.zeros((2, max_pages), np.int32)
    for sid in (0, 1):
        t = pool.block_table(sid)
        bt[sid, :len(t)] = t
    q = jax.random.normal(jax.random.PRNGKey(5), (2, H, hd))
    out = ops.paged_attention(q, dev.k[0], dev.v[0], jnp.asarray(bt),
                              jnp.asarray(lens, jnp.int32), backend="ref")
    # compare against full attention over the densely gathered KV
    for sid, S in enumerate(lens):
        k_d, v_d = dev.gather_dense(sid)     # [1(L), S, KV, hd]
        qg = q[sid].reshape(KV, H // KV, hd).astype(jnp.float32)
        logits = jnp.einsum("kgd,tkd->kgt", qg,
                            k_d[0].astype(jnp.float32)) / np.sqrt(hd)
        probs = jax.nn.softmax(logits, axis=-1)
        want = jnp.einsum("kgt,tkd->kgd", probs,
                          v_d[0].astype(jnp.float32)).reshape(H, hd)
        np.testing.assert_allclose(np.asarray(out[sid]), np.asarray(want),
                                   atol=2e-4)


def test_from_bytes_caps_pages_for_state_only_archs():
    """kv_bytes_per_token == 0 (rwkv6) must not build a billion-entry
    freelist (regression: launch.serve hung for attention-free archs)."""
    pool = PagedKVPool.from_bytes(28e9, 0, page_size=16)
    assert pool.num_pages == PagedKVPool.MAX_PAGES
    pool.allocate(1, 100)
    pool.check_invariants()
