"""repro.exp: spec hashing / JSON round-trips, Grid expansion, cache
semantics (hit / miss / schema-bump invalidation / simulate-once), and
the figure-parity goldens locking the ported fig5/fig6/fig8 smoke
payloads to the pre-port outputs, value for value."""
import json
import os
import subprocess
import sys

import pytest

from repro.core import SLO
from repro.exp import (ClosedLoop, Experiment, Grid, OpenLoop, ResultCache,
                       ReuseSpec, SCHEMA_VERSION, run, run_grid,
                       set_default_cache)
from repro.exp.runner import sim_count
from repro.fleet import FleetSpec
from repro.workload import (GammaArrivals, MixtureLengths,
                            PaperFixedLengths, ShareGPTLengths)

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
GOLDENS = os.path.join(os.path.dirname(__file__), "goldens")


@pytest.fixture
def tmp_cache(tmp_path):
    """A per-test default cache (the session fixture already isolates
    the suite from the repo cache; this one gives a test its own empty
    cache and clean stats)."""
    from repro.exp import runner
    prev = runner._DEFAULT_CACHE
    cache = ResultCache(str(tmp_path / "cache"))
    set_default_cache(cache)
    yield cache
    set_default_cache(prev)


def _tiny_exp(**kw):
    return Experiment.closed("dis-ici", 2, input_len=512, output_len=4,
                             **kw)


# ----------------------------------------------------------------------
# spec round-trips and content addressing
# ----------------------------------------------------------------------
EXAMPLES = [
    Experiment.closed("dis-ici", 16),
    Experiment.closed("co-2gpus", 8, seed=3,
                      slo=SLO(ttft_s=1.0, tpot_s=0.01)),
    Experiment.open("dis-host", 4.0, n=12, seed=7,
                    slo=SLO(ttft_s=2.0, tpot_s=0.0075)),
    Experiment.open("2P2D-ici", 8.0, arrival="gamma",
                    arrival_kw={"cv": 3.0},
                    lengths=ShareGPTLengths(prompt_sigma=1.5)),
    Experiment.open("co-3", 6.0, arrival="ramp", n=32),
    Experiment(arch="llama32-3b",
               fleet=FleetSpec.disaggregated(2, 1, "disk",
                                             phi_prefill=(1.0, 0.58),
                                             governor=("static",
                                                       "queue-depth",
                                                       "slo-slack")),
               workload=OpenLoop(
                   arrivals=GammaArrivals(rate=5.0, cv=2.0),
                   lengths=MixtureLengths(components=(
                       (0.7, PaperFixedLengths(1024, 16)),
                       (0.3, ShareGPTLengths()))),
                   n=9, seed=2)),
    Experiment(arch="llama32-3b", fleet="co-2gpus",
               workload=ClosedLoop(batch=4, input_len=8192,
                                   vocab_size=1000, rag_doc_len=2048),
               reuse=ReuseSpec(mode="pic", recompute_frac=0.2)),
]


@pytest.mark.parametrize("i", range(len(EXAMPLES)))
def test_json_roundtrip_is_exact(i):
    e = EXAMPLES[i]
    e2 = Experiment.from_json(e.to_json())
    assert e2 == e
    assert e2.spec_hash() == e.spec_hash()
    assert e2.to_json() == e.to_json()


def test_legacy_setup_label_is_preserved():
    e = Experiment.closed("dis-ici", 4)
    assert e.setup == "dis-ici"
    assert e.fleet == FleetSpec.disaggregated(1, 1, "ici")
    assert Experiment.from_json(e.to_json()).setup == "dis-ici"
    # an explicit fleet shape labels as its canonical name
    assert Experiment.closed("2P2D-ici", 4).setup == "2P2D-ici"
    assert Experiment.closed(FleetSpec.colocated(3), 4).setup == "co-3"


def test_same_content_same_hash_different_content_different_hash():
    a, b = Experiment.closed("dis-ici", 16), Experiment.closed("dis-ici", 16)
    assert a == b and hash(a) == hash(b)
    assert a.spec_hash() == b.spec_hash()
    assert len({e.spec_hash() for e in EXAMPLES}) == len(EXAMPLES)
    # knob helpers change the address
    assert a.with_phi(phi=0.58).spec_hash() != a.spec_hash()
    assert a.with_governor("slo-slack").spec_hash() != a.spec_hash()


def test_spec_hash_stable_across_process_restarts():
    """The cache key must not depend on interpreter state (PYTHONHASHSEED,
    import order): a fresh process derives the identical address."""
    e = EXAMPLES[3]
    code = ("import sys; sys.path.insert(0, {src!r})\n"
            "from repro.exp import Experiment\n"
            "print(Experiment.from_json({j!r}).spec_hash())"
            .format(src=SRC, j=e.to_json()))
    out = subprocess.run([sys.executable, "-c", code],
                         capture_output=True, text=True, check=True,
                         env={**os.environ, "PYTHONHASHSEED": "12345"})
    assert out.stdout.strip() == e.spec_hash()


def test_workload_spec_converts_and_slo_is_experiment_level():
    from repro.workload import WorkloadSpec
    ws = WorkloadSpec(arrivals=GammaArrivals(rate=2.0, cv=1.5),
                      lengths=PaperFixedLengths(1024, 8), n=5, seed=1,
                      slo=SLO(ttft_s=1.0))
    e = Experiment(arch="llama32-3b", fleet="dis-ici", workload=ws)
    assert isinstance(e.workload, OpenLoop)
    assert e.workload.n == 5 and e.slo is None   # spec's slo is dropped


def test_closed_loop_rag_builder_matches_legacy_reuse_workload():
    """The spec-described RAG workload reproduces the historical inline
    builder: doc drawn first from the seed, then spliced at the offset."""
    import numpy as np
    from repro.core import random_workload
    wl = ClosedLoop(batch=3, input_len=4096, output_len=8,
                    vocab_size=1000, rag_doc_len=512, rag_doc_offset=128,
                    seed=5)
    reqs = wl.build()
    rng = np.random.default_rng(5)
    doc = rng.integers(0, 1000, 512)
    legacy = random_workload(3, input_len=4096, output_len=8,
                             vocab_size=1000, seed=5)
    for r in legacy:
        r.prompt_tokens[128:128 + 512] = doc
    for a, b in zip(reqs, legacy):
        assert (a.prompt_tokens == b.prompt_tokens).all()


# ----------------------------------------------------------------------
# Grid
# ----------------------------------------------------------------------
def test_grid_expands_cartesian_in_axis_order():
    g = Grid(_tiny_exp(), {"setup": ("co-1gpu", "dis-ici"),
                           "batch": (2, 4, 8)})
    exps = g.expand()
    assert len(g) == len(exps) == 6
    assert [(e.setup, e.workload.batch) for e in exps] == [
        ("co-1gpu", 2), ("co-1gpu", 4), ("co-1gpu", 8),
        ("dis-ici", 2), ("dis-ici", 4), ("dis-ici", 8)]


def test_grid_axes_cover_phi_governor_rate_and_dotted_paths():
    base = Experiment.open("dis-ici", 2.0, n=4)
    exps = Grid(base, {"phi": (0.58, 1.0), "governor": ("static",
                                                        "slo-slack"),
                       "rate": (2.0, 8.0)}).expand()
    assert len(exps) == 8
    assert exps[0].fleet.phi_prefill == 0.58
    assert exps[-1].fleet.governor == "slo-slack"
    assert exps[-1].workload.rate == 8.0
    # dotted dataclass path for knobs without a named axis
    e = Grid(_tiny_exp(), {"workload.input_len": (64,)}).expand()[0]
    assert e.workload.input_len == 64
    with pytest.raises(KeyError):
        Grid(_tiny_exp(), {"wat": (1,)}).expand()
    with pytest.raises(ValueError):
        Grid(_tiny_exp(), {"batch": ()})


def test_grid_roundtrips_through_json():
    for e in Grid(_tiny_exp(), {"setup": ("co-2gpus", "dis-disk"),
                                "phi": (0.42, 1.0)}).expand():
        assert Experiment.from_json(e.to_json()) == e


# ----------------------------------------------------------------------
# cache semantics
# ----------------------------------------------------------------------
def test_cache_hit_returns_value_identical_record(tmp_cache):
    e = _tiny_exp()
    s0 = sim_count()
    rec1 = run(e)
    rec2 = run(e)
    assert sim_count() - s0 == 1            # second call was a hit
    assert tmp_cache.stats.hits == 1 and tmp_cache.stats.misses == 1
    assert rec2.to_dict() == rec1.to_dict()  # exact, incl. float bits
    assert rec2.metrics.median_ttft_s == rec1.metrics.median_ttft_s
    assert rec2.total_j == rec1.total_j


def test_schema_version_bump_invalidates(tmp_cache, monkeypatch):
    """A SCHEMA_VERSION bump (records gain new semantics) must miss on
    every cell of the old generation and repopulate a fresh one."""
    e = _tiny_exp()
    old = run(e)
    assert old.schema_version == SCHEMA_VERSION
    # simulate the code-level bump: new records carry the new version,
    # the cache looks in the new generation's directory
    monkeypatch.setattr("repro.exp.record.SCHEMA_VERSION",
                        SCHEMA_VERSION + 1)
    bumped = ResultCache(tmp_cache.root,
                         schema_version=SCHEMA_VERSION + 1)
    assert bumped.get(e) is None             # old generation: a miss
    s0 = sim_count()
    rec = run(e, cache=bumped)
    assert sim_count() - s0 == 1             # re-simulated
    assert rec.schema_version == SCHEMA_VERSION + 1
    assert bumped.get(e) is not None
    # the old generation is untouched (inert, not corrupted)
    assert tmp_cache.get(e) is not None


def test_corrupt_cache_file_is_a_miss_not_a_crash(tmp_cache):
    e = _tiny_exp()
    rec = run(e)
    with open(tmp_cache.path_for(e.spec_hash()), "w") as f:
        f.write("{ not json")
    rec2 = run(e)
    assert rec2.to_dict() == rec.to_dict()


def test_run_grid_dedupes_and_orders(tmp_cache):
    e = _tiny_exp()
    exps = [e, e.with_phi(phi=0.58), e]      # duplicate cell
    s0 = sim_count()
    recs = run_grid(exps)
    assert sim_count() - s0 == 2             # dedupe: 2 unique cells
    assert [r.spec_hash for r in recs] == [exps[0].spec_hash(),
                                           exps[1].spec_hash(),
                                           exps[0].spec_hash()]


@pytest.mark.slow
def test_run_grid_parallel_matches_serial(tmp_cache):
    g = Grid(_tiny_exp(), {"setup": ("co-1gpu", "dis-ici"),
                           "batch": (2, 3)})
    serial = [r.to_dict() for r in run_grid(g, cache=None)]
    par = [r.to_dict() for r in run_grid(g, parallel=2, cache=None)]
    assert par == serial


def test_run_point_same_spec_is_simulated_exactly_once(tmp_cache):
    """Regression for the old benchmarks.common.run_point: passing any
    **kw silently bypassed its dict cache (and rebuilt the config
    twice). Spec-carried knobs must hit the content-addressed cache."""
    from benchmarks import common
    s0 = sim_count()
    a = common.run_point("dis-ici", 2, phi=0.74)
    b = common.run_point("dis-ici", 2, phi=0.74)
    assert sim_count() - s0 == 1
    assert b.to_dict() == a.to_dict()
    # and a knob typo is an error, not a silent uncached fork
    with pytest.raises(TypeError):
        common.run_point("dis-ici", 2, phii=0.74)


def test_rate_point_and_goodput_probe_share_the_cache(tmp_cache):
    from repro.configs import get_config
    from repro.workload import run_rate_point
    cfg = get_config("llama32-3b")
    slo = SLO(ttft_s=2.0, tpot_s=0.0075)
    s0 = sim_count()
    p1 = run_rate_point("dis-ici", cfg, 4.0, slo=slo, n=6)
    p2 = run_rate_point("dis-ici", cfg, 4.0, slo=slo, n=6)
    assert sim_count() - s0 == 1
    assert p1 == p2
    # a modified (off-registry) config falls back to direct simulation
    from repro.exp import uncached_sim_count
    s1, u1 = sim_count(), uncached_sim_count()
    run_rate_point("dis-ici", cfg.replace(num_layers=2), 4.0, slo=slo,
                   n=4)
    assert sim_count() == s1                 # not routed through exp
    assert uncached_sim_count() == u1 + 1    # ...but counted as such


def test_unregistered_workload_types_fall_back_uncached(tmp_cache):
    """An arrival process / length mix outside the registries cannot be
    content-addressed: the cell must simulate directly (and be counted
    as uncached), not crash in the spec encoder."""
    from dataclasses import dataclass
    from repro.configs import get_config
    from repro.exp import uncached_sim_count
    from repro.workload import run_rate_point
    from repro.workload.lengths import LengthMix, ReqShape

    @dataclass(frozen=True)
    class OneShape(LengthMix):
        def sample(self, n, seed=0):
            return [ReqShape(256, 4) for _ in range(n)]

    cfg = get_config("llama32-3b")
    s0, u0 = sim_count(), uncached_sim_count()
    pt = run_rate_point("dis-ici", cfg, 4.0, lengths=OneShape(),
                        slo=SLO(ttft_s=2.0, tpot_s=0.0075), n=4)
    assert pt.setup == "dis-ici" and pt.attainment >= 0.0
    assert sim_count() == s0
    assert uncached_sim_count() == u0 + 1


# ----------------------------------------------------------------------
# figure parity: ported fig5/fig6/fig8 smoke JSON payloads are value-
# identical to the pre-port outputs (captured as goldens)
# ----------------------------------------------------------------------
def _golden(name):
    with open(os.path.join(GOLDENS, name)) as f:
        return json.load(f)


def _as_json(payload):
    """Normalize an in-process payload the way the figure artifact is
    written (JSON stringifies non-string dict keys), so the comparison
    is value-level, not Python-type-level."""
    return json.loads(json.dumps(payload))


@pytest.mark.slow
def test_fig5_smoke_matches_preport_golden(tmp_cache, tmp_path):
    from benchmarks import fig5_pareto
    payload = fig5_pareto.run(smoke=True,
                              out=str(tmp_path / "fig5.json"))
    assert _as_json(payload) == _golden("fig5_pareto_smoke.json")


@pytest.mark.slow
def test_fig6_smoke_matches_preport_golden(tmp_cache):
    from benchmarks import fig6_load_crossover
    payload = fig6_load_crossover.run(smoke=True)
    assert _as_json(payload) == _golden("fig6_load_crossover_smoke.json")


@pytest.mark.slow
def test_fig8_smoke_matches_preport_golden(tmp_cache, tmp_path):
    from benchmarks import fig8_governor_pareto
    payload = fig8_governor_pareto.run(smoke=True,
                                       out=str(tmp_path / "fig8.json"))
    assert _as_json(payload) == _golden("fig8_governor_pareto_smoke.json")


@pytest.mark.slow
def test_figure_payloads_are_pure_cache_reads_when_warm(tmp_cache,
                                                        tmp_path):
    """The warm-cache contract behind the CI lane: re-rendering a figure
    from a warm cache simulates nothing and yields the identical
    payload."""
    from benchmarks import fig6_load_crossover
    cold = fig6_load_crossover.run(smoke=True)
    s0 = sim_count()
    warm = fig6_load_crossover.run(smoke=True)
    assert sim_count() == s0
    assert warm == cold
