"""Invariant/fuzz layer for the tiered KV store + prefix-affinity router
(DESIGN.md section 15).

Three layers of lockdown:

  1. Deterministic unit tests: spill/fetch/drop mechanics, pins, peek
     purity, prefetch read-ahead, spec encodings.
  2. Hypothesis fuzz: random op mixes x tier budgets x reuse mode x seed
     must keep ``TieredKVStore.check_invariants`` green (no page resident
     in two tiers, over-capacity only when fully pinned, pins positive
     and resident), keep the movement ledger conservative (every fetch
     from a tier is covered by earlier spills into it), keep every
     priced leg re-derivable from ``core.transfer``, and keep hit rate
     monotone in total capacity.
  3. Cluster integration: the per-stage joules the EnergyMeter reports
     (``tier-fetch`` / ``tier-spill``) reconcile EXACTLY against the
     stores' ledgers; the fast stepper provably bails to exact when a
     tiered store is attached; the prefix-affinity router is
     byte-identical to least-outstanding-tokens on cold prefixes; and
     every pre-PR spec hash survives bit-for-bit (constants pinned from
     the pre-PR tree).

``REPRO_KVSTORE_EXAMPLES`` turns the fuzz example count up in CI's
reuse lane (100+); the default stays inside the tier-1 budget.
"""
import dataclasses
import os
from types import SimpleNamespace

import numpy as np
import pytest

from hypothesis_compat import HAS_HYPOTHESIS, given, settings, st

from repro.configs import get_config
from repro.core.fastpath import fast_decode_eligible
from repro.core.orchestrator import run_setup
from repro.core.transfer import DiskPath, HostPath
from repro.fleet.cluster import FleetCluster
from repro.fleet.router import POLICIES, Router
from repro.fleet.spec import FleetSpec
from repro.kvstore import (REUSE_MODES, ReuseSpec, TierSpec, TieredKVStore,
                           as_reuse_spec, as_tier_spec)
from repro.workload import (DEFAULT_INTERACTIVE_SLO, PaperFixedLengths,
                            RAGSharedPrefixLengths, open_loop_workload)

CFG = get_config("llama32-3b")
PAGE_BYTES = 4096
N_EXAMPLES = int(os.environ.get("REPRO_KVSTORE_EXAMPLES", "25"))


def make_store(hbm=4, dram=8, disk=16, *, mode="prefix", prefetch=0,
               page_size=4):
    return TieredKVStore(
        TierSpec(hbm_pages=hbm, dram_pages=dram, disk_pages=disk,
                 prefetch_pages=prefetch),
        mode=mode, page_size=page_size, page_bytes=PAGE_BYTES)


def toks(seed, n):
    return np.random.default_rng(seed).integers(0, 97, n)


def audit_ledger(store):
    """The movement ledger's own conservation laws.

    * Every fetch from DRAM/disk is covered by earlier spills into that
      tier (a fetch with nothing resident would be a read of KV never
      written — "every fetch preceded by a store").
    * Final ledger balance equals actual lower-tier residency.
    * Every priced leg is exactly what core.transfer charges for that
      byte count today (no stale/copied prices in the ledger).
    """
    resident = {"dram": 0, "disk": 0}
    for ev in store.events:
        if ev["op"] == "spill":
            resident[ev["dst"]] += ev["pages"]
            if ev["src"] in resident:
                resident[ev["src"]] -= ev["pages"]
        elif ev["op"] in ("fetch", "drop", "promote") \
                and ev["src"] in resident:
            resident[ev["src"]] -= ev["pages"]
        assert resident["dram"] >= 0 and resident["disk"] >= 0, \
            f"fetch/drop without a preceding store: {ev}"
    assert resident["dram"] == len(store._tier["dram"])
    assert resident["disk"] == len(store._tier["disk"])

    for ev in store.events:
        if ev["op"] == "spill":
            leg = store._paths[ev["dst"]].store_cost(ev["nbytes"])
        elif ev["op"] == "fetch":
            leg = store._paths[ev["src"]].fetch_cost(ev["nbytes"])
        else:
            continue
        assert ev["latency_s"] == leg.latency_s
        assert ev["energy_j"] == leg.energy_j


# ----------------------------------------------------------------------
# spec encodings: pre-PR hashes must survive bit-for-bit
# ----------------------------------------------------------------------
def test_reuse_encode_omits_tiers_when_none():
    d = ReuseSpec().encode()
    # EXACTLY the pre-tier key set: adding a key would shift every
    # cached reuse-spec hash
    assert d == {"mode": "prefix", "capacity_pages": 200_000,
                 "page_size": 16, "recompute_frac": 0.15, "warm": True}


def test_reuse_encode_nests_tiers():
    r = ReuseSpec(mode="pic", tiers={"hbm_pages": 8, "dram_pages": 16})
    d = r.encode()
    assert d["tiers"] == {"hbm_pages": 8, "dram_pages": 16,
                          "disk_pages": 0, "prefetch_pages": 0}
    assert r.tiers == TierSpec(8, 16)


def test_as_reuse_spec_forms():
    assert as_reuse_spec(None) is None
    assert as_reuse_spec("pic") == ReuseSpec(mode="pic")
    r = as_reuse_spec({"mode": "prefix", "tiers": {"hbm_pages": 2}})
    assert r.tiers.hbm_pages == 2
    assert as_reuse_spec(r) is r
    with pytest.raises(TypeError):
        as_reuse_spec(3.14)
    with pytest.raises(TypeError):
        as_tier_spec("hbm")


def test_fleet_encode_omits_reuse_when_none():
    from repro.exp.spec import encode_fleet
    d = encode_fleet(FleetSpec(n_colocated=2))
    assert "reuse" not in d and "controller" not in d
    d2 = encode_fleet(FleetSpec(n_colocated=2, reuse="prefix"))
    assert d2["reuse"]["mode"] == "prefix" and "tiers" not in d2["reuse"]


def test_pre_pr_spec_hashes_pinned():
    """Constants computed from the pre-PR tree (git HEAD at 71ece66):
    the content-addressed result cache must keep hitting every record
    written before tiers existed."""
    from repro.exp import Experiment
    e1 = Experiment.open("co-2gpus", 4.0, n=16,
                         lengths=PaperFixedLengths(2048, 128), seed=3,
                         slo=DEFAULT_INTERACTIVE_SLO)
    e2 = Experiment.open(
        FleetSpec(n_prefill=2, n_decode=2, medium="host",
                  governor="queue-depth"), 8.0, n=8, seed=0)
    e3 = Experiment.closed("dis-ici", 4, input_len=4096, output_len=64,
                           reuse=ReuseSpec(mode="pic"))
    assert e1.spec_hash() == ("d39e1c20e4d355bb6b11257f823b87ff"
                              "41d9b89aa31cb068c9c7e3300de46e2b")
    assert e2.spec_hash() == ("2c10c966d915aa9cafb9eefd398da56d"
                              "7ac1ff6b4515b0ca71453c6dbfe75569")
    assert e3.spec_hash() == ("3063d59978f37d8cf96d22d0b81fbe5a"
                              "67d2a8673221dad9296cde27737a6863")


def test_experiment_reuse_tiers_roundtrip():
    from repro.exp import Experiment
    e = Experiment.open("co-2gpus", 4.0, n=4,
                        reuse={"mode": "prefix",
                               "tiers": {"hbm_pages": 8, "dram_pages": 4}})
    e2 = Experiment.from_json(e.to_json())
    assert e2 == e and e2.reuse.tiers == TierSpec(8, 4)
    assert e2.spec_hash() == e.spec_hash()


# ----------------------------------------------------------------------
# store mechanics (deterministic)
# ----------------------------------------------------------------------
def test_insert_overflows_down_the_hierarchy():
    s = make_store(hbm=2, dram=3, disk=4)
    spills = s.insert(toks(0, 10 * 4))           # 10 pages into hbm=2
    assert [len(s._tier[t]) for t in ("hbm", "dram", "disk")] == [2, 3, 4]
    assert s.resident_pages() == 9               # 10th page dropped
    # every hop is priced: 8 demotions hbm->dram, then 5 dram->disk
    assert len(spills) == 8 + 5
    drops = [e for e in s.events if e["op"] == "drop"]
    assert len(drops) == 1 and drops[0]["src"] == "disk"
    s.check_invariants()
    audit_ledger(s)


def test_drop_when_lower_tiers_disabled():
    s = make_store(hbm=2, dram=0, disk=0)
    spills = s.insert(toks(0, 5 * 4))
    assert spills == []                          # drops are free
    assert len(s._tier["hbm"]) == 2 and s.resident_pages() == 2
    assert sum(e["pages"] for e in s.events if e["op"] == "drop") == 3
    audit_ledger(s)


def test_lookup_fetches_batched_per_source_tier():
    s = make_store(hbm=2, dram=8, disk=8)
    t = toks(1, 6 * 4)
    s.insert(t)                                  # 2 hbm, 4 dram
    hit = s.lookup(t)
    assert hit.matched_tokens == 24
    assert len(hit.fetch_legs) == 1              # one batched dram leg
    fetches = [e for e in s.events if e["op"] == "fetch"]
    assert len(fetches) == 1 and fetches[0]["src"] == "dram"
    assert fetches[0]["pages"] == 4
    # priced exactly as the host-staging path for the batched bytes
    want = HostPath(None).fetch_cost(4 * PAGE_BYTES)
    assert hit.fetch_legs[0].energy_j == want.energy_j
    assert hit.fetch_legs[0].latency_s == want.latency_s
    s.release(hit.pins)
    s.check_invariants()
    audit_ledger(s)


def test_disk_fetch_priced_by_disk_path():
    s = make_store(hbm=1, dram=1, disk=16)
    t = toks(2, 8 * 4)
    s.insert(t)                                  # 1 hbm, 1 dram, 6 disk
    hit = s.lookup(t)
    srcs = {e["src"]: e for e in s.events if e["op"] == "fetch"}
    assert set(srcs) == {"dram", "disk"}
    assert srcs["disk"]["energy_j"] == \
        DiskPath(None).fetch_cost(6 * PAGE_BYTES).energy_j
    s.release(hit.pins)
    audit_ledger(s)


def test_pinned_pages_never_evicted():
    s = make_store(hbm=2, dram=2, disk=0)
    a = toks(3, 2 * 4)
    s.insert(a)
    hit = s.lookup(a)                            # pins both hbm pages
    s.insert(toks(4, 4 * 4))                     # pressure: 4 new pages
    for k in hit.pins:
        assert s._where(k) == "hbm", "pinned page left HBM"
    s.check_invariants()
    # release -> the same pressure now evicts them
    s.release(hit.pins)
    s.insert(toks(5, 4 * 4))
    assert all(s._where(k) != "hbm" for k in hit.pins)
    s.check_invariants()
    audit_ledger(s)


def test_fully_pinned_tier_exceeds_capacity_not_evicts():
    s = make_store(hbm=2, dram=2, disk=0)
    a = toks(6, 4 * 4)
    s.insert(a)                                  # 2 hbm + 2 dram
    hit = s.lookup(a)                            # promotes + pins all 4
    assert len(s._tier["hbm"]) == 4              # > cap: all pinned
    s.check_invariants()                         # legal while pinned
    spills = s.release(hit.pins)                 # pins off -> re-enforce
    assert len(s._tier["hbm"]) == 2
    assert len(spills) == 2                      # overflow demoted, priced
    s.check_invariants()
    audit_ledger(s)


def test_peek_match_is_pure():
    s = make_store(hbm=2, dram=8, disk=0)
    t = toks(9, 5 * 4)
    s.insert(t)
    before = ({k: list(s._tier[k]) for k in s._tier}, dict(s._pins),
              s.hits, s.misses, len(s.events))
    assert s.peek_match(t) == 20
    assert s.peek_match(toks(10, 4 * 4)) == 0
    after = ({k: list(s._tier[k]) for k in s._tier}, dict(s._pins),
             s.hits, s.misses, len(s.events))
    assert before == after, "peek_match mutated the store"
    # and it predicts exactly what lookup then reports
    assert s.lookup(t).matched_tokens == 20


def test_prefetch_drags_hot_leftovers():
    s = make_store(hbm=1, dram=8, disk=0, prefetch=2)
    t = toks(11, 6 * 4)
    s.insert(t)                                  # 1 hbm (MRU), 5 dram
    hit = s.lookup(t[:2 * 4])                    # demand: 2 dram pages
    fetch = next(e for e in s.events if e["op"] == "fetch")
    assert fetch["pages"] == 2 + 2               # demand + read-ahead
    assert len(hit.fetch_legs) == 1              # same batched leg
    s.release(hit.pins)
    s.check_invariants()
    audit_ledger(s)


def test_pic_mode_matches_displaced_and_repairs():
    s = make_store(hbm=8, dram=8, disk=0, mode="pic")
    shared = toks(12, 3 * 4)
    s.insert(np.concatenate([toks(13, 4), shared]))
    hit = s.lookup(np.concatenate([toks(14, 4), shared]))
    assert hit.matched_tokens == 12 and hit.mode == "pic"
    assert hit.recompute_tokens == 4 + int(np.ceil(12 * 0.15))
    # prefix mode on the same trace matches nothing (positions differ)
    p = make_store(hbm=8, dram=8, disk=0, mode="prefix")
    p.insert(np.concatenate([toks(13, 4), shared]))
    assert p.lookup(np.concatenate([toks(14, 4), shared])).mode == "none"


# ----------------------------------------------------------------------
# hypothesis fuzz: invariants + ledger conservation under any op mix
# ----------------------------------------------------------------------
if HAS_HYPOTHESIS:
    from hypothesis import HealthCheck

    _ops = st.lists(
        st.tuples(st.sampled_from(("insert", "lookup", "lookup_hold",
                                   "release_all", "peek")),
                  st.integers(0, 9),              # token-seed
                  st.integers(1, 40)),            # token count
        min_size=1, max_size=40)
    _tiers = st.builds(
        TierSpec,
        st.integers(1, 6), st.integers(0, 8), st.integers(0, 8),
        st.integers(0, 2))

    @settings(max_examples=N_EXAMPLES, deadline=None,
              suppress_health_check=list(HealthCheck))
    @given(ops=_ops, tiers=_tiers, mode=st.sampled_from(REUSE_MODES),
           seed=st.integers(0, 2 ** 16))
    def test_store_invariants_fuzz(ops, tiers, mode, seed):
        s = TieredKVStore(tiers, mode=mode, page_size=4,
                          page_bytes=PAGE_BYTES)
        rng = np.random.default_rng(seed)
        held = []
        for op, tseed, n in ops:
            t = rng.integers(0, 31, n) if tseed == 0 else toks(tseed, n)
            if op == "insert":
                s.insert(t)
            elif op == "lookup":
                s.release(s.lookup(t).pins)
            elif op == "lookup_hold":
                held.append(s.lookup(t).pins)
            elif op == "release_all":
                for pins in held:
                    s.release(pins)
                held = []
            else:
                s.peek_match(t)
            s.check_invariants()
        audit_ledger(s)
        for pins in held:
            s.release(pins)
        s.check_invariants()
        # with every pin released, no tier may stay over capacity
        s.insert(toks(99, 4))
        for t in ("hbm", "dram", "disk"):
            assert len(s._tier[t]) <= s.spec.capacity(t)

    @settings(max_examples=N_EXAMPLES, deadline=None,
              suppress_health_check=list(HealthCheck))
    @given(trace=st.lists(st.tuples(st.integers(0, 7), st.integers(4, 32)),
                          min_size=1, max_size=24),
           probes=st.lists(st.tuples(st.integers(0, 7), st.integers(4, 32)),
                           min_size=1, max_size=8),
           mode=st.sampled_from(REUSE_MODES))
    def test_hit_rate_monotone_in_capacity(trace, probes, mode):
        """Global-recency inclusion: the same insert trace through a
        ladder of growing total budgets leaves nested resident sets, so
        every probe's matched-token count is non-decreasing in capacity.
        (Probed with the pure ``peek_match`` so the probes themselves
        cannot perturb residency.)"""
        ladder = [TierSpec(1, 1, 0), TierSpec(2, 4, 0), TierSpec(2, 4, 8),
                  TierSpec(4, 12, 16)]
        rows = []
        for tiers in ladder:
            s = TieredKVStore(tiers, mode=mode, page_size=4,
                              page_bytes=PAGE_BYTES)
            for tseed, n in trace:
                s.insert(toks(tseed, n))
            s.check_invariants()
            rows.append([s.peek_match(toks(tseed, n))
                         for tseed, n in probes])
        for small, big in zip(rows, rows[1:]):
            for a, b in zip(small, big):
                assert a <= b, (rows, trace, probes)
else:  # pragma: no cover - container without the dev extra
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_store_invariants_fuzz():
        pass

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_hit_rate_monotone_in_capacity():
        pass


# ----------------------------------------------------------------------
# cluster integration: meter == ledger, bail rule, reuse engages
# ----------------------------------------------------------------------
TIERED = {"mode": "prefix", "page_size": 16,
          "tiers": {"hbm_pages": 64, "dram_pages": 128, "disk_pages": 256}}
RAG_WK = dict(rate=8.0, n=16, lengths=RAGSharedPrefixLengths(prefix_len=1024),
              vocab_size=512, slo=DEFAULT_INTERACTIVE_SLO, seed=0)


def test_tier_stage_joules_reconcile_with_ledger():
    """The EnergyMeter's tier stages are EXACTLY the ledger, re-priced:
    ``tier-spill`` is the summed spill-leg energy (async DMA, no
    occupancy); ``tier-fetch`` is the summed fetch-leg energy plus the
    engine idling at ``idle_power_w`` for the batched fetch latency."""
    spec = FleetSpec(n_colocated=2, router="prefix-affinity", reuse=TIERED)
    reqs = open_loop_workload(**RAG_WK)
    cluster = FleetCluster(spec, CFG)
    res = cluster.run(reqs, stepper="exact")
    assert res.metrics.total_reused_tokens > 0, "reuse never engaged"

    spill_j = fetch_j = fetch_lat = 0.0
    for e in cluster.engines:
        assert e.kv_store is not None
        audit_ledger(e.kv_store)
        for ev in e.kv_store.events:
            tot = sum(ev["energy_j"].values())
            if ev["op"] == "spill":
                spill_j += tot
            elif ev["op"] == "fetch":
                fetch_j += tot
                fetch_lat += ev["latency_s"]
    assert spill_j > 0 and fetch_j > 0
    idle_w = cluster.engines[0].cost.idle_power_w()
    by_stage = res.energy.by_stage
    assert by_stage["tier-spill"] == pytest.approx(spill_j, rel=1e-9)
    assert by_stage["tier-fetch"] == pytest.approx(
        fetch_j + idle_w * fetch_lat, rel=1e-9)
    # fetch occupancy also lands in the power trace (stage-tagged)
    assert any(s.stage == "tier-fetch"
               for c in res.energy.trace.components
               for s in res.energy.trace.samples[c])


def test_fast_stepper_bails_to_exact_when_tiered(monkeypatch):
    """The conservative rule, machine-checked at the call site: with a
    tiered store attached, run(stepper="fast") must never enter the
    coalescing window; flat reuse must still vectorize."""
    import repro.fleet.cluster as fc
    calls = []
    real = fc.coalesce_window

    def spy(*a, **kw):
        calls.append(1)
        return real(*a, **kw)

    monkeypatch.setattr(fc, "coalesce_window", spy)
    run_setup(FleetSpec(n_colocated=2, reuse=TIERED), CFG,
              open_loop_workload(**RAG_WK), stepper="fast")
    assert not calls, "fast stepper coalesced with a tiered store active"

    run_setup(FleetSpec(n_colocated=2, reuse="prefix"), CFG,
              open_loop_workload(**RAG_WK), stepper="fast")
    assert calls, "flat reuse must stay fast-eligible"


def test_fast_decode_eligible_rejects_kv_store():
    e = SimpleNamespace(executor=None, kv_store=None, governor=None,
                        pending_fetch=(), pending_tier_fetch=(),
                        prefilling=(), waiting=(), running=[1],
                        decode_queue=())
    assert fast_decode_eligible(e)
    e.kv_store = object()
    assert not fast_decode_eligible(e)
    e.kv_store = None
    e.pending_tier_fetch = [object()]
    assert not fast_decode_eligible(e)


def test_tiered_fast_vs_exact_same_result():
    """stepper="fast" with tiers bails internally, so both entry points
    must produce bit-identical records."""
    out = {}
    for stepper in ("exact", "fast"):
        reqs = open_loop_workload(**RAG_WK)
        res = run_setup(FleetSpec(n_colocated=2, reuse=TIERED), CFG, reqs,
                        stepper=stepper)
        out[stepper] = res
    a, b = out["exact"], out["fast"]
    assert dataclasses.asdict(a.metrics) == dataclasses.asdict(b.metrics)
    assert a.energy.joules == b.energy.joules


# ----------------------------------------------------------------------
# prefix-affinity router
# ----------------------------------------------------------------------
def _mock_engine(load, store=None):
    return SimpleNamespace(outstanding_tokens=lambda load=load: load,
                           kv_store=store, prefix_cache=None)


def test_prefix_affinity_registered():
    assert "prefix-affinity" in POLICIES


def test_prefix_affinity_cold_is_byte_identical_to_lot():
    """With no matches anywhere the score tuple degenerates to
    (0, outstanding): identical argmin candidates, identical seeded
    tie-breaks, identical pick sequence."""
    loads = [5, 3, 3, 9, 3, 7, 3]
    req = SimpleNamespace(prompt_tokens=toks(0, 64))
    for probe in (None, req):
        r_lot = Router([_mock_engine(v) for v in loads],
                       "least-outstanding-tokens", seed=7)
        r_aff = Router([_mock_engine(v) for v in loads],
                       "prefix-affinity", seed=7)
        picks_lot = [r_lot.pick(req=probe).outstanding_tokens()
                     for _ in range(64)]
        picks_aff = [r_aff.pick(req=probe).outstanding_tokens()
                     for _ in range(64)]
        assert picks_lot == picks_aff


def test_prefix_affinity_routes_to_warm_engine():
    warm = make_store(hbm=64, dram=64, disk=0, page_size=16)
    prompt = toks(1, 40 * 16)
    warm.insert(prompt)
    engines = [_mock_engine(1000, None), _mock_engine(4000, warm)]
    r = Router(engines, "prefix-affinity", seed=0)
    # loaded-but-warm beats idle-but-cold...
    assert r.pick(req=SimpleNamespace(prompt_tokens=prompt)) is engines[1]
    # ...and cold requests fall back to least-outstanding
    assert r.pick(req=SimpleNamespace(prompt_tokens=toks(2, 64))) \
        is engines[0]
    assert r.pick(req=None) is engines[0]


def test_prefix_affinity_no_reuse_full_run_identical():
    """End-to-end: without any reuse spec the prefix-affinity fleet is
    byte-identical to the least-outstanding-tokens fleet."""
    wk = dict(rate=8.0, n=16, lengths=PaperFixedLengths(2048, 128), seed=1)
    for shape in (dict(n_colocated=3),
                  dict(n_prefill=2, n_decode=2, medium="ici")):
        out = {}
        for router in ("least-outstanding-tokens", "prefix-affinity"):
            reqs = open_loop_workload(**wk)
            out[router] = run_setup(FleetSpec(router=router, **shape),
                                    CFG, reqs)
        a, b = out.values()
        assert dataclasses.asdict(a.metrics) == dataclasses.asdict(b.metrics)
        assert a.energy.joules == b.energy.joules


def test_affinity_beats_lot_on_shared_prefix_fleet():
    """The point of the policy: on a RAG workload over a tiered fleet,
    affinity routing must reuse at least as many tokens as blind LOT."""
    reused = {}
    for router in ("least-outstanding-tokens", "prefix-affinity"):
        reqs = open_loop_workload(**RAG_WK)
        res = run_setup(
            FleetSpec(n_colocated=2, router=router, reuse=TIERED),
            CFG, reqs, stepper="exact")
        reused[router] = res.metrics.total_reused_tokens
    assert reused["prefix-affinity"] >= reused["least-outstanding-tokens"]
    assert reused["prefix-affinity"] > 0
