"""SLO attainment, goodput, and the bisection sweeps — unit-level on
hand-built requests and a stubbed cost model (no Cluster runs except the
slow-marked full rate grid at the bottom).
"""
import numpy as np
import pytest

from repro.core import SLO, meets_slo
from repro.core.request import Request
from repro.workload import (Crossover, evaluate, max_goodput_rate,
                            run_rate_point)


def _req(i, arrival, ttft, tpot, out_len=9):
    """A finished request with exact latency metrics."""
    r = Request(req_id=i, prompt_len=16, output_len=out_len,
                arrival_s=arrival)
    r.prefill_start_s = arrival + ttft / 2
    r.prefill_done_s = r.first_token_s = arrival + ttft
    if tpot is None:
        r.generated = 1
        r.finish_s = r.first_token_s
    else:
        r.generated = out_len
        r.finish_s = r.first_token_s + (out_len - 1) * tpot
    return r


# ----------------------------------------------------------------------
def test_meets_slo_axes():
    r = _req(0, 0.0, ttft=0.5, tpot=0.01)
    assert meets_slo(r, SLO())                          # vacuous
    assert meets_slo(r, SLO(ttft_s=0.5, tpot_s=0.01))  # boundary passes
    assert not meets_slo(r, SLO(ttft_s=0.4))
    assert not meets_slo(r, SLO(tpot_s=0.005))
    assert meets_slo(r, SLO(ttft_s=1.0, tpot_s=0.02))


def test_meets_slo_single_token_judged_on_ttft_alone():
    r = _req(0, 0.0, ttft=0.5, tpot=None)
    assert meets_slo(r, SLO(ttft_s=1.0, tpot_s=1e-9))   # tpot can't fail
    assert not meets_slo(r, SLO(ttft_s=0.1, tpot_s=1e-9))


def test_meets_slo_uses_request_slo_by_default():
    r = _req(0, 0.0, ttft=0.5, tpot=0.01)
    r.slo = SLO(ttft_s=0.1)
    assert not meets_slo(r)
    assert meets_slo(r, SLO(ttft_s=1.0))                # override wins


def test_evaluate_exact_math():
    # 4 requests, arrivals 0..3; two meet (ttft 0.1), two miss (ttft 9)
    reqs = [_req(i, float(i), ttft=(0.1 if i < 2 else 9.0), tpot=0.01)
            for i in range(4)]
    rep = evaluate(reqs, SLO(ttft_s=1.0))
    assert rep.n == 4 and rep.attained == 2
    assert rep.attainment == 0.5
    dur = max(r.finish_s for r in reqs)                 # first arrival = 0
    assert rep.duration_s == pytest.approx(dur)
    assert rep.goodput_rps == pytest.approx(2 / dur)
    assert rep.offered_rps == pytest.approx(1.0)        # 3 gaps over 3 s


def test_evaluate_requires_finished_requests():
    r = _req(0, 0.0, ttft=0.1, tpot=0.01)
    r.finish_s = None
    with pytest.raises(AssertionError):
        evaluate([r])


# ----------------------------------------------------------------------
# bisection on a stubbed cost model: attainment degrades linearly in
# rate, so the capacity under a 90% target is known in closed form
# ----------------------------------------------------------------------
def _stub_runner(capacity_rps):
    """attainment(rate) = 1.0 below capacity, then linear decay with
    slope 1/capacity: attainment(capacity * (1+x)) = 1 - x."""
    def run(rate):
        n = 40
        frac = min(1.0, max(0.0, 2.0 - rate / capacity_rps))
        k = int(round(n * frac))
        return [_req(i, i / rate,
                     ttft=(0.1 if i < k else 9.0), tpot=0.001)
                for i in range(n)]
    return run


def test_max_goodput_rate_on_stub():
    cap = 6.0
    # attainment >= 0.9 holds up to rate = cap * 1.1 = 6.6
    got = max_goodput_rate(_stub_runner(cap), slo=SLO(ttft_s=1.0),
                           lo=0.5, hi=32.0, target_attainment=0.9,
                           rel_tol=0.02, max_iters=20)
    assert got == pytest.approx(6.6, rel=0.05)


def test_max_goodput_rate_degenerate_brackets():
    assert max_goodput_rate(_stub_runner(1.0), slo=SLO(ttft_s=1.0),
                            lo=16.0, hi=32.0) == 0.0   # lo already fails
    assert max_goodput_rate(_stub_runner(1e6), slo=SLO(ttft_s=1.0),
                            lo=1.0, hi=8.0) == 8.0     # never fails


def test_max_goodput_rate_monotone_in_stub_capacity():
    slo = SLO(ttft_s=1.0)
    caps = [max_goodput_rate(_stub_runner(c), slo=slo, lo=0.5, hi=64.0,
                             rel_tol=0.02, max_iters=20)
            for c in (2.0, 4.0, 8.0)]
    assert caps[0] < caps[1] < caps[2]


# ----------------------------------------------------------------------
# full rate-grid sweep on the real cost model (slow lane)
# ----------------------------------------------------------------------
@pytest.mark.slow
def test_full_rate_grid_medium_ordering():
    from repro.configs import get_config
    from repro.workload import rate_grid
    cfg = get_config("llama32-3b")
    slo = SLO(ttft_s=2.0, tpot_s=0.0075)
    rates = (1.0, 2.0, 4.0, 8.0, 16.0)
    setups = ("co-2gpus", "dis-ici", "dis-host", "dis-disk")
    pts = {(p.setup, p.rate): p
           for p in rate_grid(cfg, rates, setups=setups, slo=slo, n=24)}
    for r in rates:
        # F3 at every load level: slower media can only hurt TTFT
        assert pts[("dis-ici", r)].median_ttft_s \
            <= pts[("dis-host", r)].median_ttft_s \
            <= pts[("dis-disk", r)].median_ttft_s
        # goodput can never exceed the offered rate
        for s in setups:
            assert pts[(s, r)].goodput_rps <= pts[(s, r)].offered_rps + 1e-6
