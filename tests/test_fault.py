"""Fault tolerance: atomic checkpoints, restart continuity (bit-exact loss
curve), keep-N rotation, straggler watchdog."""
import os

import numpy as np
import pytest

from repro.dist import fault
from repro.dist.fault import SimulatedFailure, StragglerWatchdog
from repro.launch.train import train


def test_checkpoint_roundtrip(tmp_path):
    ckpt = str(tmp_path)
    params = {"w": np.arange(6.0).reshape(2, 3)}
    opt = {"m": {"w": np.zeros((2, 3))}}
    path = fault.save_checkpoint(ckpt, 7, params, opt, {"seed": 1,
                                                        "step": 7})
    payload = fault.load_checkpoint(path)
    assert payload["step"] == 7
    np.testing.assert_array_equal(payload["params"]["w"], params["w"])
    assert fault.latest_checkpoint(ckpt) == path


def test_keep_n_rotation(tmp_path):
    ckpt = str(tmp_path)
    for s in range(6):
        fault.save_checkpoint(ckpt, s, {"w": np.zeros(1)}, {}, {}, keep=3)
    steps = [s for s, _ in fault.sorted_checkpoints(ckpt)]
    assert steps == [3, 4, 5]


def test_no_partial_checkpoint_on_failure(tmp_path):
    """Temp files never survive as valid checkpoints."""
    ckpt = str(tmp_path)
    fault.save_checkpoint(ckpt, 1, {"w": np.zeros(1)}, {}, {})
    leftovers = [f for f in os.listdir(ckpt) if f.endswith(".tmp")]
    assert not leftovers


def test_restart_continuity_bit_exact(tmp_path):
    """Run A: 20 uninterrupted steps. Run B: fail at step 12, restart from
    the step-10 checkpoint. Loss streams must agree step-for-step."""
    ck_a, ck_b = str(tmp_path / "a"), str(tmp_path / "b")
    losses_a, _ = train("qwen2-0.5b", steps=20, batch_size=2, seq_len=16,
                        ckpt_dir=ck_a, ckpt_every=5, verbose=False)
    with pytest.raises(SimulatedFailure):
        train("qwen2-0.5b", steps=20, batch_size=2, seq_len=16,
              ckpt_dir=ck_b, ckpt_every=5, fail_at=12, verbose=False)
    losses_b2, _ = train("qwen2-0.5b", steps=20, batch_size=2, seq_len=16,
                         ckpt_dir=ck_b, ckpt_every=5, verbose=False)
    # restart resumed from step 10: its stream must equal A's tail exactly
    np.testing.assert_allclose(losses_b2, losses_a[10:], rtol=1e-6)


def test_straggler_watchdog_flags_outliers():
    wd = StragglerWatchdog(threshold=2.0, window=20)
    events = []
    wd.on_straggler = lambda s, d, m: events.append((s, d))
    for step in range(20):
        wd.observe(step, 0.1)
    assert not wd.flagged
    assert wd.observe(20, 0.5)          # 5x median -> straggler
    assert wd.flagged == [(20, 0.5)] and events


def test_straggler_deadline():
    wd = StragglerWatchdog(threshold=100.0, deadline_s=1.0)
    for step in range(6):
        wd.observe(step, 0.5)
    assert wd.observe(6, 1.5)           # hard deadline breach
