"""Prefix matching + position-independent caching (paper section II-C)."""
import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core.prefix_cache import PrefixCache


def test_exact_prefix_match():
    c = PrefixCache(capacity_pages=64, page_size=4)
    c.insert([1, 2, 3, 4, 5, 6, 7, 8])
    r = c.lookup([1, 2, 3, 4, 5, 6, 7, 8, 9, 9])
    assert r.matched_tokens == 8
    assert r.recompute_tokens == 2
    assert r.mode == "prefix"


def test_prefix_diverges_early():
    """Paper: prefix matching fails when openings differ."""
    c = PrefixCache(capacity_pages=64, page_size=4)
    c.insert([1, 2, 3, 4, 10, 11, 12, 13])
    r = c.lookup([9, 2, 3, 4, 10, 11, 12, 13])   # first token differs
    assert r.matched_tokens == 0
    assert r.mode == "none"


def test_pic_matches_displaced_content():
    """PIC reuses the shared block even at a different position."""
    shared = [10, 11, 12, 13, 14, 15, 16, 17]
    c = PrefixCache(capacity_pages=64, page_size=4, pic=True,
                    recompute_frac=0.25)
    c.insert([1, 2, 3, 4] + shared)
    r = c.lookup([9, 8, 7, 6] + shared + [5, 5, 5, 5])
    assert r.matched_tokens == 8                 # the two shared pages
    # recompute = unmatched (8) + repair fraction of matched (2)
    assert r.recompute_tokens == 8 + 2
    assert r.saved_tokens(16) == 6


def test_pic_beats_prefix_on_rag_workload():
    """RAG scenario: same documents, different user prompts."""
    doc = list(range(100, 164))                  # 64-token shared doc
    prefix = PrefixCache(1024, page_size=16)
    pic = PrefixCache(1024, page_size=16, pic=True, recompute_frac=0.15)
    for cache in (prefix, pic):
        cache.insert([1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11, 12, 13, 14, 15,
                      16] + doc)
    query = [77] * 16 + doc     # different prompt, same doc
    assert prefix.lookup(query).saved_tokens(len(query)) == 0
    assert pic.lookup(query).saved_tokens(len(query)) > 40


def test_lru_capacity_eviction():
    c = PrefixCache(capacity_pages=2, page_size=4)
    c.insert([1, 2, 3, 4])
    c.insert([5, 6, 7, 8])
    c.insert([9, 10, 11, 12])                    # evicts the oldest chain
    assert c.lookup([1, 2, 3, 4]).matched_tokens == 0
    assert c.lookup([9, 10, 11, 12]).matched_tokens == 4


@settings(max_examples=50, deadline=None)
@given(st.lists(st.integers(0, 50), min_size=0, max_size=64),
       st.integers(2, 16))
def test_insert_then_lookup_matches_all_full_pages(tokens, page_size):
    c = PrefixCache(capacity_pages=128, page_size=page_size)
    c.insert(tokens)
    r = c.lookup(tokens)
    full = (len(tokens) // page_size) * page_size
    assert r.matched_tokens == full
    assert r.recompute_tokens == len(tokens) - full
    assert 0 <= r.matched_tokens <= len(tokens)


@settings(max_examples=50, deadline=None)
@given(st.lists(st.integers(0, 50), min_size=0, max_size=64))
def test_lookup_never_exceeds_input(tokens):
    c = PrefixCache(capacity_pages=128, page_size=8, pic=True)
    c.insert(tokens)
    r = c.lookup(tokens)
    assert r.matched_tokens <= len(tokens)
    assert r.recompute_tokens >= 0
