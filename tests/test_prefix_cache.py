"""Prefix matching + position-independent caching (paper section II-C)."""
import json
import os
import subprocess
import sys

import numpy as np
import pytest
from hypothesis_compat import given, settings, st

from repro.core.prefix_cache import PrefixCache, _page_hash


def test_exact_prefix_match():
    c = PrefixCache(capacity_pages=64, page_size=4)
    c.insert([1, 2, 3, 4, 5, 6, 7, 8])
    r = c.lookup([1, 2, 3, 4, 5, 6, 7, 8, 9, 9])
    assert r.matched_tokens == 8
    assert r.recompute_tokens == 2
    assert r.mode == "prefix"


def test_prefix_diverges_early():
    """Paper: prefix matching fails when openings differ."""
    c = PrefixCache(capacity_pages=64, page_size=4)
    c.insert([1, 2, 3, 4, 10, 11, 12, 13])
    r = c.lookup([9, 2, 3, 4, 10, 11, 12, 13])   # first token differs
    assert r.matched_tokens == 0
    assert r.mode == "none"


def test_pic_matches_displaced_content():
    """PIC reuses the shared block even at a different position."""
    shared = [10, 11, 12, 13, 14, 15, 16, 17]
    c = PrefixCache(capacity_pages=64, page_size=4, pic=True,
                    recompute_frac=0.25)
    c.insert([1, 2, 3, 4] + shared)
    r = c.lookup([9, 8, 7, 6] + shared + [5, 5, 5, 5])
    assert r.matched_tokens == 8                 # the two shared pages
    # recompute = unmatched (8) + repair fraction of matched (2)
    assert r.recompute_tokens == 8 + 2
    assert r.saved_tokens(16) == 6


def test_pic_beats_prefix_on_rag_workload():
    """RAG scenario: same documents, different user prompts."""
    doc = list(range(100, 164))                  # 64-token shared doc
    prefix = PrefixCache(1024, page_size=16)
    pic = PrefixCache(1024, page_size=16, pic=True, recompute_frac=0.15)
    for cache in (prefix, pic):
        cache.insert([1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11, 12, 13, 14, 15,
                      16] + doc)
    query = [77] * 16 + doc     # different prompt, same doc
    assert prefix.lookup(query).saved_tokens(len(query)) == 0
    assert pic.lookup(query).saved_tokens(len(query)) > 40


def test_lru_capacity_eviction():
    c = PrefixCache(capacity_pages=2, page_size=4)
    c.insert([1, 2, 3, 4])
    c.insert([5, 6, 7, 8])
    c.insert([9, 10, 11, 12])                    # evicts the oldest chain
    assert c.lookup([1, 2, 3, 4]).matched_tokens == 0
    assert c.lookup([9, 10, 11, 12]).matched_tokens == 4


@settings(max_examples=50, deadline=None)
@given(st.lists(st.integers(0, 50), min_size=0, max_size=64),
       st.integers(2, 16))
def test_insert_then_lookup_matches_all_full_pages(tokens, page_size):
    c = PrefixCache(capacity_pages=128, page_size=page_size)
    c.insert(tokens)
    r = c.lookup(tokens)
    full = (len(tokens) // page_size) * page_size
    assert r.matched_tokens == full
    assert r.recompute_tokens == len(tokens) - full
    assert 0 <= r.matched_tokens <= len(tokens)


@settings(max_examples=50, deadline=None)
@given(st.lists(st.integers(0, 50), min_size=0, max_size=64))
def test_lookup_never_exceeds_input(tokens):
    c = PrefixCache(capacity_pages=128, page_size=8, pic=True)
    c.insert(tokens)
    r = c.lookup(tokens)
    assert r.matched_tokens <= len(tokens)
    assert r.recompute_tokens >= 0


# ----------------------------------------------------------------------
# stable hashing: page keys must not depend on PYTHONHASHSEED
# ----------------------------------------------------------------------
def test_page_hash_pinned_values():
    """blake2b digests, pinned: any change to the key derivation silently
    invalidates every cross-process residency comparison (the tiered
    store's ledger, the prefix-affinity router's peek scores)."""
    assert _page_hash(np.arange(16)) == -3027613264856255669
    assert _page_hash(np.arange(16), salt=7) == -8714504233280175492
    assert _page_hash(np.arange(16)) != _page_hash(np.arange(1, 17))


_HASHSEED_SCRIPT = """
import json
import numpy as np
from repro.core.prefix_cache import PrefixCache, _page_hash

rng = np.random.default_rng(0)
out = {"page_hash": _page_hash(np.arange(16))}
for pic in (False, True):
    c = PrefixCache(capacity_pages=8, page_size=4, pic=pic,
                    recompute_frac=0.25)
    rows = []
    for i in range(12):
        t = rng.integers(0, 13, rng.integers(4, 40))
        c.insert(t)
        probe = np.concatenate([t, rng.integers(0, 13, 8)]) \\
            if i % 2 else rng.integers(0, 13, rng.integers(4, 40))
        r = c.lookup(probe)
        rows.append([r.matched_tokens, r.recompute_tokens, r.mode])
    out[f"pic={pic}"] = {"rows": rows, "hits": c.hits, "misses": c.misses}
print(json.dumps(out, sort_keys=True))
"""


def test_hit_stats_identical_across_hash_seeds():
    """Regression for the builtin-``hash`` page keys: with process-salted
    hashing, two processes disagreed on which pages were "the same", so
    hit statistics depended on PYTHONHASHSEED. The blake2b keys must
    give byte-identical lookup stats under different seeds."""
    outs = []
    for seed in ("0", "12345"):
        env = dict(os.environ, PYTHONHASHSEED=seed,
                   PYTHONPATH=os.path.join(os.path.dirname(__file__),
                                           os.pardir, "src"))
        proc = subprocess.run([sys.executable, "-c", _HASHSEED_SCRIPT],
                              capture_output=True, text=True, env=env,
                              check=True)
        outs.append(proc.stdout)
    assert outs[0] == outs[1]
    stats = json.loads(outs[0])
    assert stats["page_hash"] == -3027613264856255669
    assert stats["pic=True"]["hits"] > 0     # the probe actually matched
