"""Per-kernel correctness: Pallas (interpret mode on CPU) vs jnp oracle,
swept over shapes and dtypes (assignment deliverable c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import flash_prefill, mamba2_ssd, ops, paged_decode, ref
from repro.kernels import rwkv6_scan


def _key(i):
    return jax.random.PRNGKey(i)


TOL = {jnp.float32: 2e-4, jnp.bfloat16: 2e-2}


# ----------------------------------------------------------------------
# flash attention (prefill)
# ----------------------------------------------------------------------
@pytest.mark.parametrize("B,S,T,H,KV,hd", [
    (1, 64, 64, 4, 4, 32),        # MHA square
    (2, 128, 128, 8, 2, 64),      # GQA
    (1, 96, 96, 4, 1, 64),        # MQA, ragged seq (pads internally)
    (2, 64, 192, 8, 4, 32),       # cross-size KV (q_offset chunk)
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_vs_ref(B, S, T, H, KV, hd, dtype):
    q = jax.random.normal(_key(1), (B, S, H, hd), dtype)
    k = jax.random.normal(_key(2), (B, T, KV, hd), dtype)
    v = jax.random.normal(_key(3), (B, T, KV, hd), dtype)
    off = T - S
    out = flash_prefill.flash_attention(
        q, k, v, causal=True, q_offset=off, block_q=32, block_k=64,
        interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=True, q_offset=off)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


@pytest.mark.parametrize("window", [16, 48])
def test_flash_sliding_window(window):
    B, S, H, hd = 1, 128, 4, 32
    q = jax.random.normal(_key(1), (B, S, H, hd))
    k = jax.random.normal(_key(2), (B, S, H, hd))
    v = jax.random.normal(_key(3), (B, S, H, hd))
    out = flash_prefill.flash_attention(q, k, v, causal=True, window=window,
                                        block_q=32, block_k=32,
                                        interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-4)


def test_flash_noncausal():
    B, S, H, hd = 2, 64, 4, 32
    q = jax.random.normal(_key(1), (B, S, H, hd))
    k = jax.random.normal(_key(2), (B, S, H, hd))
    v = jax.random.normal(_key(3), (B, S, H, hd))
    out = flash_prefill.flash_attention(q, k, v, causal=False, block_q=32,
                                        block_k=32, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-4)


# ----------------------------------------------------------------------
# paged attention (decode)
# ----------------------------------------------------------------------
@pytest.mark.parametrize("B,H,KV,hd,pages,page", [
    (2, 8, 2, 64, 16, 16),
    (3, 4, 4, 32, 8, 32),
    (1, 16, 16, 64, 32, 16),      # MHA
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_vs_ref(B, H, KV, hd, pages, page, dtype):
    max_pages = pages // 2
    q = jax.random.normal(_key(1), (B, H, hd), dtype)
    kp = jax.random.normal(_key(2), (pages, page, KV, hd), dtype)
    vp = jax.random.normal(_key(3), (pages, page, KV, hd), dtype)
    bt = jnp.stack([jax.random.permutation(_key(10 + b), pages)[:max_pages]
                    for b in range(B)]).astype(jnp.int32)
    lens = jax.random.randint(_key(4), (B,), 1, max_pages * page + 1)
    out = paged_decode.paged_attention(q, kp, vp, bt, lens, interpret=True)
    want = ref.paged_attention_ref(q, kp, vp, bt, lens)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype])


def test_paged_ragged_lengths():
    """Pages past seq_len must not contribute (pl.when skip)."""
    B, H, KV, hd, pages, page = 2, 4, 4, 32, 8, 16
    q = jax.random.normal(_key(1), (B, H, hd))
    kp = jax.random.normal(_key(2), (pages, page, KV, hd))
    vp = jax.random.normal(_key(3), (pages, page, KV, hd))
    bt = jnp.tile(jnp.arange(4, dtype=jnp.int32), (B, 1))
    lens = jnp.array([1, 64], jnp.int32)
    out = paged_decode.paged_attention(q, kp, vp, bt, lens, interpret=True)
    want = ref.paged_attention_ref(q, kp, vp, bt, lens)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-4)


# ----------------------------------------------------------------------
# rwkv6 chunked scan
# ----------------------------------------------------------------------
@pytest.mark.parametrize("B,T,NH,hd,chunk", [
    (1, 64, 2, 32, 16),
    (2, 96, 4, 64, 32),          # T not a chunk-multiple of 64 (pads)
    (1, 128, 1, 64, 64),
])
def test_rwkv6_vs_ref(B, T, NH, hd, chunk):
    r = jax.random.normal(_key(1), (B, T, NH, hd))
    k = jax.random.normal(_key(2), (B, T, NH, hd))
    v = jax.random.normal(_key(3), (B, T, NH, hd))
    w = jax.nn.sigmoid(jax.random.normal(_key(4), (B, T, NH, hd))) \
        * 0.5 + 0.45
    u = jax.random.normal(_key(5), (NH, hd)) * 0.1
    y, s = ops.rwkv6(r, k, v, w, u, None, chunk=chunk,
                     backend="pallas_interpret")
    y_ref, s_ref = ref.rwkv6_scan_ref(r, k, v, w, u)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=5e-4)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref), atol=5e-4)


def test_rwkv6_state_carry():
    """Scanning two halves with state carry == one full scan."""
    B, T, NH, hd = 1, 64, 2, 32
    r = jax.random.normal(_key(1), (B, T, NH, hd))
    k = jax.random.normal(_key(2), (B, T, NH, hd))
    v = jax.random.normal(_key(3), (B, T, NH, hd))
    w = jax.nn.sigmoid(jax.random.normal(_key(4), (B, T, NH, hd))) \
        * 0.5 + 0.45
    u = jax.random.normal(_key(5), (NH, hd)) * 0.1
    y_full, s_full = ref.rwkv6_scan_ref(r, k, v, w, u)
    h = T // 2
    y1, s1 = ops.rwkv6(r[:, :h], k[:, :h], v[:, :h], w[:, :h], u, None,
                       chunk=16, backend="pallas_interpret")
    y2, s2 = ops.rwkv6(r[:, h:], k[:, h:], v[:, h:], w[:, h:], u, s1,
                       chunk=16, backend="pallas_interpret")
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), atol=5e-4)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full),
                               atol=5e-4)


def test_rwkv6_step_matches_scan():
    """Single-token recurrent step == one-token scan (decode path)."""
    B, NH, hd = 2, 2, 32
    state = jax.random.normal(_key(9), (B, NH, hd, hd))
    r = jax.random.normal(_key(1), (B, 1, NH, hd))
    k = jax.random.normal(_key(2), (B, 1, NH, hd))
    v = jax.random.normal(_key(3), (B, 1, NH, hd))
    w = jax.nn.sigmoid(jax.random.normal(_key(4), (B, 1, NH, hd))) * 0.5 \
        + 0.45
    u = jax.random.normal(_key(5), (NH, hd)) * 0.1
    y_scan, s_scan = ref.rwkv6_scan_ref(r, k, v, w, u, state)
    y_step, s_step = ops.rwkv6_step(r[:, 0], k[:, 0], v[:, 0], w[:, 0], u,
                                    state)
    np.testing.assert_allclose(np.asarray(y_step), np.asarray(y_scan[:, 0]),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(s_step), np.asarray(s_scan),
                               atol=1e-5)


# ----------------------------------------------------------------------
# mamba2 SSD chunked scan
# ----------------------------------------------------------------------
@pytest.mark.parametrize("B,T,NH,P,N,chunk", [
    (1, 64, 2, 32, 16, 16),
    (2, 96, 4, 64, 64, 32),
    (1, 128, 1, 64, 32, 64),
])
def test_mamba2_vs_ref(B, T, NH, P, N, chunk):
    x = jax.random.normal(_key(1), (B, T, NH, P))
    dt = jax.nn.softplus(jax.random.normal(_key(2), (B, T, NH)))
    A = -jnp.abs(jax.random.normal(_key(3), (NH,)))
    Bm = jax.random.normal(_key(4), (B, T, N))
    Cm = jax.random.normal(_key(5), (B, T, N))
    D = jax.random.normal(_key(6), (NH,)) * 0.1
    y, s = ops.mamba2(x, dt, A, Bm, Cm, D, None, chunk=chunk,
                      backend="pallas_interpret")
    y_ref, s_ref = ref.mamba2_ssd_ref(x, dt, A, Bm, Cm, D)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=1e-3, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref),
                               atol=1e-3, rtol=1e-3)


def test_mamba2_step_matches_scan():
    B, NH, P, N = 2, 2, 32, 16
    state = jax.random.normal(_key(9), (B, NH, N, P))
    x = jax.random.normal(_key(1), (B, 1, NH, P))
    dt = jax.nn.softplus(jax.random.normal(_key(2), (B, 1, NH)))
    A = -jnp.abs(jax.random.normal(_key(3), (NH,)))
    Bm = jax.random.normal(_key(4), (B, 1, N))
    Cm = jax.random.normal(_key(5), (B, 1, N))
    D = jax.random.normal(_key(6), (NH,)) * 0.1
    y_scan, s_scan = ref.mamba2_ssd_ref(x, dt, A, Bm, Cm, D, state)
    y_step, s_step = ops.mamba2_step(x[:, 0], dt[:, 0], A, Bm[:, 0],
                                     Cm[:, 0], D, state)
    np.testing.assert_allclose(np.asarray(y_step), np.asarray(y_scan[:, 0]),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(s_step), np.asarray(s_scan),
                               atol=1e-5)


# ----------------------------------------------------------------------
# dispatch / backend plumbing
# ----------------------------------------------------------------------
def test_ops_backend_dispatch():
    assert ops.resolve_backend("ref") == "ref"
    assert ops.resolve_backend("pallas_interpret") == "pallas_interpret"
    # auto on CPU -> ref
    assert ops.resolve_backend(None) in ("ref", "pallas")
