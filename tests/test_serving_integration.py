"""End-to-end real-mode serving: identical token streams across ALL five
setups (the KV-handoff correctness proof), for multiple model families —
including the paper's dense case WITH eviction/recompute forced."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import REGISTRY, reduce_for_smoke
from repro.core import Cluster, RealExecutor, SETUPS, random_workload
from repro.models import get_model


def _run_all_setups(arch, *, n_req=3, in_len=48, out_len=6,
                    pool_tokens=None, page_size=8, budget=32, tmp=None):
    cfg = reduce_for_smoke(REGISTRY[arch])
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    def factory(path):
        return RealExecutor(model, params, transfer_path=path)

    kv_tok = max(cfg.kv_bytes_per_token(), 1)
    pool_bytes = kv_tok * (pool_tokens or (in_len + out_len) * n_req * 2)
    outs, results = {}, {}
    for setup in SETUPS:
        reqs = random_workload(n_req, input_len=in_len, output_len=out_len,
                               vocab_size=cfg.vocab_size, seed=11)
        res = Cluster(setup, cfg, executor_factory=factory,
                      pool_bytes=pool_bytes, page_size=page_size,
                      prefill_token_budget=budget).run(reqs)
        outs[setup] = [r.output_tokens for r in
                       sorted(res.requests, key=lambda r: r.req_id)]
        results[setup] = res
    return outs, results


@pytest.mark.parametrize("arch", ["llama32-3b", "qwen3-1.7b",
                                  "moonshot-v1-16b-a3b", "rwkv6-3b",
                                  "zamba2-2.7b"])
def test_identical_tokens_across_setups(arch):
    outs, _ = _run_all_setups(arch)
    base = outs["co-1gpu"]
    assert all(len(t) == 6 for t in base)
    for setup, toks in outs.items():
        assert toks == base, f"{setup} diverged from co-1gpu"


def test_identical_tokens_under_eviction():
    """Pool sized at ~1.5 sequences: colocated must preempt+recompute and
    STILL produce the same tokens (recompute correctness)."""
    outs, results = _run_all_setups("llama32-3b", n_req=4,
                                    pool_tokens=int(54 * 1.6))
    base = outs["co-1gpu"]
    for setup, toks in outs.items():
        assert toks == base, f"{setup} diverged under memory pressure"
    co = results["co-1gpu"].metrics
    assert co.total_evictions > 0, "pressure did not trigger eviction"


def test_disaggregated_metrics_structure():
    _, results = _run_all_setups("llama32-3b")
    for setup, res in results.items():
        m = res.metrics
        assert m.median_ttft_s > 0 and m.median_tpot_s >= 0
        assert res.energy.total_j > 0
        for r in res.requests:
            assert r.prefill_done_s is not None
            assert r.finish_s >= r.first_token_s >= r.arrival_s
            if setup.startswith("dis"):
                assert r.transfer_done_s is not None
                assert r.first_token_s >= r.prefill_done_s


def test_transfer_medium_orders_ttft():
    _, results = _run_all_setups("llama32-3b", n_req=4)
    ttft = {s: results[s].metrics.median_ttft_s for s in results}
    assert ttft["dis-ici"] <= ttft["dis-host"] <= ttft["dis-disk"]


def test_rwkv_state_handoff_is_tiny():
    """Attention-free arch: the transferred state must be seq-len
    independent (the degenerate-transfer case, DESIGN.md section 8)."""
    from repro.core import CostModel
    cfg = REGISTRY["rwkv6-3b"]
    cost = CostModel(cfg)
    assert cost.kv_bytes(16_384) == cost.kv_bytes(128)
    dense = CostModel(REGISTRY["llama32-3b"])
    assert dense.kv_bytes(16_384) > 100 * cost.kv_bytes(16_384)


def test_kv_reuse_improves_ttft_in_simulation():
    """PIC reuse on a warm cache must cut prefill work (paper II-C)."""
    import numpy as np
    from repro.configs import get_config
    from repro.core import Cluster, random_workload
    from repro.core.prefix_cache import PrefixCache
    cfg = get_config("llama32-3b")

    def wl():
        rng = np.random.default_rng(0)
        doc = rng.integers(0, cfg.vocab_size, 4096)
        reqs = random_workload(8, input_len=16_384, output_len=32,
                               vocab_size=cfg.vocab_size, seed=1)
        for r in reqs:
            r.prompt_tokens[512:512 + 4096] = doc
        return reqs

    base = Cluster("co-2gpus", cfg).run(wl())
    cache = PrefixCache(200_000, page_size=16, pic=True)
    reqs = wl()
    cache.insert(reqs[0].prompt_tokens)
    cluster = Cluster("co-2gpus", cfg)
    for e in cluster.engines:
        e.prefix_cache = cache
    reused = cluster.run(reqs)
    assert sum(r.reused_tokens for r in reused.requests) > 8 * 3000
    assert reused.metrics.median_ttft_s < base.metrics.median_ttft_s
